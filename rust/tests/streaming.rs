//! Durability integration tests for the mutable streaming index: WAL
//! crash-recovery at every byte boundary of the final record, and full
//! reopen-equals-live roundtrips through checkpoints (rust/DESIGN.md §7).

use std::path::Path;
use std::sync::Arc;

use unq::config::{SearchConfig, StreamConfig};
use unq::data::{synthetic::Generator, Family};
use unq::index::{Routing, StreamingIndex};
use unq::ivf::CoarseQuantizer;
use unq::quant::pq::Pq;
use unq::util::TempDir;

fn scfg(segment_rows: usize) -> StreamConfig {
    StreamConfig { segment_rows, compact_segments: 1000, wal_sync: 1 }
}

fn setup(n_base: usize)
         -> (unq::data::Dataset, unq::data::Dataset, unq::data::Dataset, Pq)
{
    let gen = Generator::new(Family::SiftLike, 88);
    let train = gen.generate(0, 900);
    let base = gen.generate(1, n_base);
    let queries = gen.generate(2, 5);
    let pq = Pq::train(&train.data, train.dim, 8, 32, 0, 6);
    (train, base, queries, pq)
}

/// Structural fingerprint of every segment (sealed, oldest first, then
/// the active tail): id, codes, row ids, list offsets, dead rows.
type SegPrint = (u64, Vec<u8>, Vec<u32>, Vec<usize>, Vec<usize>);

fn fingerprint(ix: &StreamingIndex) -> Vec<SegPrint> {
    let s = ix.snapshot();
    s.sealed
        .iter()
        .map(|a| a.as_ref())
        .chain(std::iter::once(s.active.as_ref()))
        .map(|g| {
            (
                g.seg_id,
                g.codes().codes.clone(),
                g.row_ids().to_vec(),
                g.offsets().to_vec(),
                (0..g.n()).filter(|&r| g.is_dead(r)).collect(),
            )
        })
        .collect()
}

/// The single `wal_<epoch>.log` in a durable index directory.
fn wal_path(dir: &Path) -> std::path::PathBuf {
    let mut wals: Vec<_> = std::fs::read_dir(dir)
        .unwrap()
        .flatten()
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("wal_") && n.ends_with(".log"))
        })
        .collect();
    assert_eq!(wals.len(), 1, "exactly one live wal epoch: {wals:?}");
    wals.pop().unwrap()
}

/// Copy a durable index directory, truncating its WAL to `cut` bytes —
/// a simulated crash image.
fn crash_image(src: &Path, dst: &Path, cut: u64) {
    std::fs::create_dir_all(dst).unwrap();
    for e in std::fs::read_dir(src).unwrap().flatten() {
        std::fs::copy(e.path(), dst.join(e.file_name())).unwrap();
    }
    let wal = wal_path(dst);
    let bytes = std::fs::read(&wal).unwrap();
    std::fs::write(&wal, &bytes[..cut as usize]).unwrap();
}

#[test]
fn reopen_equals_live_through_seals_and_checkpoint() {
    let (_, base, queries, pq) = setup(1400);
    let dir = TempDir::new("stream").unwrap();
    let root = dir.path().join("ix");
    let ix = StreamingIndex::open(&root, 8, None, scfg(250)).unwrap();
    let mut ids = Vec::new();
    for lo in (0..1000).step_by(230) {
        let hi = (lo + 230).min(1000);
        ids.extend(ix.insert_batch(&pq, base.rows(lo, hi)).unwrap());
    }
    let victims: Vec<u32> = ids.iter().copied().step_by(6).collect();
    ix.delete_batch(&victims).unwrap();
    assert!(ix.compact().unwrap(), "several sealed segments must merge");
    // post-checkpoint tail: more inserts + deletes live only in the WAL
    ids.extend(ix.insert_batch(&pq, base.rows(1000, 1300)).unwrap());
    ix.delete_batch(&ids[ids.len() - 7..]).unwrap();
    let want_print = fingerprint(&ix);
    let want_len = ix.len();
    let cfg = SearchConfig { rerank_l: 50, k: 10, ..Default::default() };
    let want_results: Vec<Vec<u32>> = (0..queries.len())
        .map(|qi| ix.search(&pq, queries.row(qi), &cfg))
        .collect();
    let next_id = *ids.last().unwrap() + 1;
    drop(ix);

    let back = StreamingIndex::open(&root, 8, None, scfg(250)).unwrap();
    assert_eq!(fingerprint(&back), want_print,
               "recovered state must equal the live state");
    assert_eq!(back.len(), want_len);
    for (qi, want) in want_results.iter().enumerate() {
        assert_eq!(&back.search(&pq, queries.row(qi), &cfg), want,
                   "query {qi}");
    }
    // the id counter survives recovery: the next insert continues the
    // monotonic sequence
    let got = back.insert_batch(&pq, base.rows(1300, 1301)).unwrap();
    assert_eq!(got, vec![next_id]);
}

#[test]
fn crash_recovery_at_every_byte_of_the_final_record() {
    // the ISSUE acceptance property: write a batch through the WAL,
    // truncate the log at every byte boundary of the final record,
    // replay, and the recovered index equals the pre-crash prefix
    let (_, base, _, pq) = setup(700);
    let dir = TempDir::new("stream").unwrap();
    let root = dir.path().join("ix");
    let ix = StreamingIndex::open(&root, 8, None, scfg(200)).unwrap();
    let ids = ix.insert_batch(&pq, base.rows(0, 420)).unwrap();
    ix.delete_batch(&ids[..30]).unwrap();
    ix.compact().unwrap(); // checkpoint: archives + fresh wal epoch
    ix.insert_batch(&pq, base.rows(420, 500)).unwrap();

    // penultimate state, then ONE final single-record operation
    let len_before = std::fs::metadata(wal_path(&root)).unwrap().len();
    let print_before = fingerprint(&ix);
    ix.insert_batch(&pq, base.rows(500, 501)).unwrap();
    let len_after = std::fs::metadata(wal_path(&root)).unwrap().len();
    let print_after = fingerprint(&ix);
    assert!(len_after > len_before, "final insert must hit the wal");
    drop(ix);

    for cut in len_before..=len_after {
        let img = dir.path().join(format!("crash_{cut}"));
        crash_image(&root, &img, cut);
        let rec = StreamingIndex::open(&img, 8, None, scfg(200)).unwrap();
        let want = if cut < len_after { &print_before } else { &print_after };
        assert_eq!(&fingerprint(&rec), want,
                   "cut at byte {cut} of [{len_before}, {len_after}]");
        drop(rec);
        std::fs::remove_dir_all(&img).unwrap();
    }
}

#[test]
fn crash_recovery_mid_delete_record_keeps_the_row_alive() {
    // same property with a delete as the final record: a torn delete
    // never half-applies — the row stays alive until the record is
    // fully durable
    let (_, base, _, pq) = setup(300);
    let dir = TempDir::new("stream").unwrap();
    let root = dir.path().join("ix");
    let ix = StreamingIndex::open(&root, 8, None, scfg(1000)).unwrap();
    let ids = ix.insert_batch(&pq, base.rows(0, 200)).unwrap();
    let len_before = std::fs::metadata(wal_path(&root)).unwrap().len();
    let print_before = fingerprint(&ix);
    ix.delete_batch(&ids[5..6]).unwrap();
    let len_after = std::fs::metadata(wal_path(&root)).unwrap().len();
    let print_after = fingerprint(&ix);
    drop(ix);

    for cut in len_before..=len_after {
        let img = dir.path().join(format!("crash_{cut}"));
        crash_image(&root, &img, cut);
        let rec = StreamingIndex::open(&img, 8, None, scfg(1000)).unwrap();
        let want = if cut < len_after { &print_before } else { &print_after };
        assert_eq!(&fingerprint(&rec), want, "cut at byte {cut}");
        let alive = rec.len();
        if cut < len_after {
            assert_eq!(alive, 200, "torn delete must not apply");
        } else {
            assert_eq!(alive, 199);
        }
        drop(rec);
        std::fs::remove_dir_all(&img).unwrap();
    }
}

#[test]
fn e2e_obs_families_move_on_the_write_and_read_path() {
    // the observability acceptance path (rust/DESIGN.md §10): drive the
    // full streaming write path (WAL appends + fsync, compaction) and the
    // batched read path (pool scan with live tombstones), then check every
    // touched metric family moved.  The registry is process-global and
    // other tests run concurrently, so all assertions are on deltas and
    // `>=` — never exact equality.
    let (_, base, queries, pq) = setup(900);
    let reg = unq::obs::global();
    let before = reg.snapshot();

    let dir = TempDir::new("stream").unwrap();
    let root = dir.path().join("ix");
    let ix = StreamingIndex::open(
        &root, 8, None,
        StreamConfig { segment_rows: 200, compact_segments: 2, wal_sync: 1 },
    )
    .unwrap();
    let mut ids = Vec::new();
    for lo in (0..800).step_by(200) {
        ids.extend(ix.insert_batch(&pq, base.rows(lo, lo + 200)).unwrap());
    }
    let victims: Vec<u32> = ids.iter().copied().step_by(5).collect();
    ix.delete_batch(&victims).unwrap();
    assert!(ix.compact().unwrap(), "sealed segments must merge");
    // tombstone a few rows AFTER compaction so the read path sees dead
    // rows and must over-fetch (stream.overfetch_rows)
    ix.delete_batch(&ids[1..4]).unwrap();

    let exec = unq::exec::Executor::new(2);
    let cfg = SearchConfig { rerank_l: 50, k: 10, num_threads: 2,
                             shard_rows: 64, ..Default::default() };
    let qs: Vec<&[f32]> =
        (0..queries.len()).map(|qi| queries.row(qi)).collect();
    let req = unq::index::SearchRequest::from_config(
        &cfg, vec![cfg.k; qs.len()]);
    // observability must be a read-only side channel: the same batch
    // with and without a live trace returns bit-identical ids
    let want = ix.search_batch_on(&pq, &exec, &qs, &req);
    let (trace, root_span) = unq::obs::Trace::begin("query");
    let got = ix.search_batch_on(&pq, &exec, &qs, &req);
    drop(root_span);
    assert_eq!(got, want, "tracing changed streaming search results");

    // the span tree saw the scan fan-out and its rendering names it
    assert!(trace.rows("scan_task") > 0, "scan_task spans must carry rows");
    let explain = trace.render();
    assert!(explain.contains("scan"), "EXPLAIN must show the scan stage:\
                                       \n{explain}");

    let d = reg.snapshot().delta(&before);
    for family in [
        "wal.appends", "wal.commits", "compaction.runs",
        "stream.segments_scanned", "stream.overfetch_rows",
        "scan.rows_f32", "scan.tasks", "exec.tasks",
    ] {
        assert!(d.counter(family) > 0, "family {family} must move: {d:?}");
    }
    // each query scans every sealed segment plus the active one
    assert!(d.counter("stream.segments_scanned") >= qs.len() as u64);
    for h in ["wal.fsync_us", "exec.task_us"] {
        assert!(d.hist(h).is_some_and(|h| h.count > 0),
                "histogram {h} must record: {d:?}");
    }
    assert!(d.hist("compaction.duration_us").is_some_and(|h| h.count > 0));
}

#[test]
fn routed_durable_recovery_preserves_results() {
    let (train, base, queries, _) = setup(900);
    let coarse = CoarseQuantizer::train(&train.data, train.dim, 6, 2, 6);
    // residual deployment: fine quantizer trained on residuals
    let mut res_train = train.data.clone();
    for i in 0..train.len() {
        let c = coarse.centroid(coarse.assign(train.row(i)) as usize);
        for (v, cv) in res_train[i * train.dim..(i + 1) * train.dim]
            .iter_mut()
            .zip(c)
        {
            *v -= cv;
        }
    }
    let pq = Pq::train(&res_train, train.dim, 8, 32, 0, 6);
    let routing = || Routing {
        coarse: Arc::new(coarse.clone()),
        residual: true,
    };
    let dir = TempDir::new("stream").unwrap();
    let root = dir.path().join("ix");
    let ix =
        StreamingIndex::open(&root, 8, Some(routing()), scfg(200)).unwrap();
    let mut ids = Vec::new();
    for lo in (0..800).step_by(180) {
        let hi = (lo + 180).min(800);
        ids.extend(ix.insert_batch(&pq, base.rows(lo, hi)).unwrap());
    }
    let victims: Vec<u32> = ids.iter().copied().step_by(4).collect();
    ix.delete_batch(&victims).unwrap();
    let want_print = fingerprint(&ix);
    let cfg = SearchConfig { rerank_l: 40, k: 8, nprobe: 3,
                             ..Default::default() };
    let want: Vec<Vec<u32>> = (0..queries.len())
        .map(|qi| ix.search(&pq, queries.row(qi), &cfg))
        .collect();
    drop(ix);
    let back =
        StreamingIndex::open(&root, 8, Some(routing()), scfg(200)).unwrap();
    assert_eq!(fingerprint(&back), want_print);
    for (qi, w) in want.iter().enumerate() {
        assert_eq!(&back.search(&pq, queries.row(qi), &cfg), w,
                   "query {qi}");
    }
    // a mismatched routing shape must be rejected, not mis-searched
    let wrong = CoarseQuantizer::train(&train.data, train.dim, 12, 2, 4);
    assert!(StreamingIndex::open(
        &root, 8,
        Some(Routing { coarse: Arc::new(wrong), residual: true }),
        scfg(200)
    )
    .is_err());
}
