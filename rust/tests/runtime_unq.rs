//! Runtime-backed integration tests: the AOT artifacts executed through
//! PJRT from the full Rust stack.  These are the cross-language
//! correctness gate (python-trained weights → HLO text → Rust results).
//!
//! Skips (with a loud message) when `make artifacts` has not been run —
//! the pure-Rust suite in `integration.rs` still covers everything else.

use std::path::PathBuf;

use unq::config::{AppConfig, QuantizerKind, SearchConfig};
use unq::data;
use unq::eval::harness;
use unq::index::{CompressedIndex, SearchEngine};
use unq::quant::{unq::UnqQuantizer, Quantizer};
use unq::runtime::UnqRuntime;

fn artifact_dir() -> Option<PathBuf> {
    let root = PathBuf::from("artifacts");
    unq::runtime::find_artifact(&root, "sift1m_8b")
}

macro_rules! require_artifacts {
    () => {
        match artifact_dir() {
            Some(d) => d,
            None => {
                eprintln!("SKIP: artifacts/sift1m_8b missing — run `make artifacts`");
                return;
            }
        }
    };
}

#[test]
fn runtime_loads_and_reports_manifest() {
    let dir = require_artifacts!();
    let rt = UnqRuntime::load(&dir).expect("load artifact");
    let m = &rt.handle.manifest;
    assert_eq!(m.dim, 128);
    assert_eq!(m.m, 8);
    assert_eq!(m.k, 256);
    assert!(m.param_count > 0);
}

#[test]
fn encode_lut_decode_are_consistent() {
    let dir = require_artifacts!();
    let rt = UnqRuntime::load(&dir).expect("load artifact");
    let q = UnqQuantizer::new(rt.handle.clone());

    let spec = data::spec_by_name("sift1m", 1.0).unwrap();
    let splits = data::load_or_generate(&spec, &PathBuf::from("data")).unwrap();
    let x = splits.base.rows(0, 64);

    // encode: valid codes, deterministic
    let codes1 = q.encode_batch(x);
    let codes2 = q.encode_batch(x);
    assert_eq!(codes1, codes2, "encoding must be deterministic");
    assert_eq!(codes1.len(), 64 * 8);

    // d2 self-consistency: own code should score better than most others
    let lut = q.lut(splits.base.row(0));
    let own = lut.score(&codes1[..8]);
    let mut better = 0;
    for i in 1..64 {
        if lut.score(&codes1[i * 8..(i + 1) * 8]) < own {
            better += 1;
        }
    }
    assert!(better < 32, "own code should rank in the top half ({better})");

    // decode: reconstruction should be closer to the original than to a
    // random other row, on average
    let mut rec = vec![0.0f32; 64 * 128];
    assert!(q.reconstruct_batch(&codes1, &mut rec));
    let mut closer = 0;
    for i in 0..64 {
        let r = &rec[i * 128..(i + 1) * 128];
        let d_self = unq::linalg::sq_l2(r, splits.base.row(i));
        let d_other = unq::linalg::sq_l2(r, splits.base.row((i + 13) % 64));
        if d_self < d_other {
            closer += 1;
        }
    }
    assert!(closer > 48, "decoder must reconstruct its own input ({closer}/64)");
}

#[test]
fn lut_batch_matches_single_query_luts() {
    let dir = require_artifacts!();
    let rt = UnqRuntime::load(&dir).expect("load artifact");
    let q = UnqQuantizer::new(rt.handle.clone());
    let spec = data::spec_by_name("sift1m", 1.0).unwrap();
    let splits = data::load_or_generate(&spec, &PathBuf::from("data")).unwrap();

    let queries: Vec<&[f32]> = (0..5).map(|i| splits.query.row(i)).collect();
    let batched = q.lut_batch(&queries);
    for (i, lutb) in batched.iter().enumerate() {
        let single = q.lut(queries[i]);
        let code = q.encode_batch(queries[i]);
        let (a, b) = (lutb.score(&code), single.score(&code));
        assert!((a - b).abs() < 1e-3 * a.abs().max(1.0),
                "query {i}: batched {a} vs single {b}");
    }
}

#[test]
fn unq_end_to_end_recall_is_sound_vs_opq() {
    let dir = require_artifacts!();
    let _ = dir;
    // small slice of the canonical corpus for test speed
    let mut cfg = AppConfig::default();
    cfg.dataset = "sift1m".into();
    cfg.quantizer = QuantizerKind::Unq;
    cfg.bytes_per_vector = 8;
    cfg.scale = 0.2; // 20k base
    let unq_exp = match harness::prepare(&cfg, "") {
        Ok(e) => e,
        Err(e) => {
            eprintln!("SKIP: {e:#}");
            return;
        }
    };
    let unq_r = unq_exp.run_recall(SearchConfig {
        rerank_l: 500, k: 100, ..Default::default()
    });

    cfg.quantizer = QuantizerKind::Opq;
    let opq_exp = harness::prepare(&cfg, "").unwrap();
    let opq_r = opq_exp.run_recall(SearchConfig {
        rerank_l: 500, k: 100, no_rerank: true, ..Default::default()
    });

    eprintln!("UNQ R@10 {:.1} vs OPQ R@10 {:.1}", unq_r.at10, opq_r.at10);
    // At the paper's training budget UNQ overtakes OPQ here (Table 2);
    // at this testbed's budget (rust/DESIGN.md §4) we gate on the
    // pipeline being *sound*: far above chance and within a bounded
    // factor of the fully-trained shallow baseline.
    assert!(unq_r.at100 > 10.0 * 100.0 * 100.0 / 20_000.0, // 10× chance
            "UNQ R@100 {} is at chance level", unq_r.at100);
    assert!(unq_r.at10 * 5.0 >= opq_r.at10,
            "UNQ ({}) collapsed relative to OPQ ({})",
            unq_r.at10, opq_r.at10);
}

#[test]
fn unq_serves_through_coordinator() {
    let dir = require_artifacts!();
    let rt = UnqRuntime::load(&dir).expect("load artifact");
    let q = UnqQuantizer::new(rt.handle.clone());

    let spec = data::spec_by_name("sift1m", 0.05).unwrap();
    let splits = data::load_or_generate(&spec, &PathBuf::from("data")).unwrap();
    let index = CompressedIndex::build(&q, &splits.base);
    let search = SearchConfig { rerank_l: 100, k: 10,
                                ..Default::default() };

    // offline reference
    let engine = SearchEngine::new(&q, &index, search);
    let want: Vec<Vec<u32>> = (0..4)
        .map(|qi| engine.search(splits.query.row(qi)))
        .collect();

    let server = unq::coordinator::pipeline::Server::start(
        std::sync::Arc::new(UnqQuantizer::new(rt.handle.clone())),
        std::sync::Arc::new(index),
        search,
        unq::config::ServeConfig { max_batch: 4, max_delay_us: 500,
                                   queue_depth: 32, num_threads: 2,
                                   shard_rows: 1024 },
    );
    for qi in 0..4 {
        let resp = server.search_blocking(splits.query.row(qi), 10).unwrap();
        assert_eq!(resp.neighbors, want[qi], "query {qi}");
    }
    server.shutdown();
}
