//! Cross-module integration tests: data → quantizer → index → search →
//! recall, plus the serving coordinator, on in-process synthetic corpora.
//! Runtime-backed (UNQ) paths are exercised in `runtime_unq.rs`, which
//! skips gracefully when artifacts are missing.

use std::sync::Arc;

use unq::config::{SearchConfig, ServeConfig};
use unq::data::{synthetic::Generator, Family};
use unq::eval::recall;
use unq::gt;
use unq::index::{CompressedIndex, SearchEngine};
use unq::ivf::{CoarseQuantizer, IndexBackend, IvfIndex};
use unq::quant::{additive::Additive, lattice::CatalystLattice, lsq, opq::Opq,
                 pq::Pq, Quantizer};

struct Corpus {
    train: unq::data::Dataset,
    base: unq::data::Dataset,
    query: unq::data::Dataset,
    truth: gt::GroundTruth,
}

fn corpus(family: Family, n_base: usize) -> Corpus {
    let gen = Generator::new(family, 77);
    let train = gen.generate(0, 4000);
    let base = gen.generate(1, n_base);
    let query = gen.generate(2, 100);
    let truth = gt::brute_force(&base, &query, 100);
    Corpus { train, base, query, truth }
}

fn recall_of(q: &dyn Quantizer, c: &Corpus, rerank: bool) -> unq::eval::Recall {
    let index = CompressedIndex::build(q, &c.base);
    let engine = SearchEngine::new(q, &index, SearchConfig {
        rerank_l: 200,
        k: 100,
        no_rerank: !rerank || !q.supports_rerank(),
        ..Default::default()
    });
    let qrefs: Vec<&[f32]> =
        (0..c.query.len()).map(|qi| c.query.row(qi)).collect();
    let results = engine.search_batch(&qrefs);
    recall(&results, &c.truth)
}

#[test]
fn full_stack_every_quantizer_beats_chance() {
    let c = corpus(Family::SiftLike, 10_000);
    // chance R@100 on 10k base = 1%
    let pq = Pq::train(&c.train.data, c.train.dim, 8, 64, 0, 8);
    let opq = Opq::train(&c.train.data, c.train.dim, 8, 64, 0, 2, 6);
    let rvq = Additive::train_rvq(&c.train.data, c.train.dim, 7, 64, 0, 8, "RVQ");
    let lsq = lsq::train_lsq(&c.train.data, c.train.dim, 7, 64,
                             &lsq::LsqConfig { iters: 2, ..Default::default() });
    let lat = CatalystLattice::train(&c.train.data, c.train.dim, 8);
    for (name, r) in [
        ("PQ", recall_of(&pq, &c, true)),
        ("OPQ", recall_of(&opq, &c, true)),
        ("RVQ", recall_of(&rvq, &c, true)),
        ("LSQ", recall_of(&lsq, &c, true)),
        ("Lattice", recall_of(&lat, &c, false)),
    ] {
        assert!(r.at100 > 20.0, "{name}: R@100 = {}", r.at100);
        assert!(r.at1 <= r.at10 && r.at10 <= r.at100, "{name} monotone");
    }
}

#[test]
fn rerank_does_not_hurt_recall_at_1() {
    let c = corpus(Family::SiftLike, 8000);
    let pq = Pq::train(&c.train.data, c.train.dim, 8, 64, 0, 8);
    let with = recall_of(&pq, &c, true);
    let without = recall_of(&pq, &c, false);
    // PQ ADC is exact wrt its reconstruction, so rerank should match or
    // improve at R@1 (small fluctuations allowed at the tie margin)
    assert!(with.at1 + 2.0 >= without.at1,
            "rerank hurt: {} vs {}", with.at1, without.at1);
}

#[test]
fn sixteen_bytes_beat_eight() {
    // sift-like: quantization budget dominates (deep-like at this toy
    // scale saturates into cluster noise)
    let c = corpus(Family::SiftLike, 8000);
    let pq8 = Pq::train(&c.train.data, c.train.dim, 8, 64, 0, 8);
    let pq16 = Pq::train(&c.train.data, c.train.dim, 16, 64, 0, 8);
    let r8 = recall_of(&pq8, &c, true);
    let r16 = recall_of(&pq16, &c, true);
    assert!(r16.at10 > r8.at10,
            "16B {} should beat 8B {}", r16.at10, r8.at10);
    assert!(r16.at100 >= r8.at100 - 1.0,
            "16B {} should beat 8B {} at R@100", r16.at100, r8.at100);
}

#[test]
fn additive_beats_pq_on_correlated_deep_data() {
    // the paper's core motivation: orthogonal decompositions lose on
    // strongly-coupled descriptors
    let c = corpus(Family::DeepLike, 8000);
    let pq = Pq::train(&c.train.data, c.train.dim, 8, 64, 0, 10);
    let lsq = lsq::train_lsq(&c.train.data, c.train.dim, 7, 64,
                             &lsq::LsqConfig { iters: 3, ..Default::default() });
    let mse_pq = unq::quant::reconstruction_mse(&pq, &c.base);
    let mse_lsq = unq::quant::reconstruction_mse(&lsq, &c.base);
    assert!(mse_lsq < mse_pq,
            "LSQ mse {mse_lsq} should beat PQ {mse_pq} on deep-like");
}

#[test]
fn coordinator_serves_same_results_as_offline_engine() {
    let c = corpus(Family::SiftLike, 6000);
    let pq = Pq::train(&c.train.data, c.train.dim, 8, 64, 0, 8);
    let index = CompressedIndex::build(&pq, &c.base);
    let search = SearchConfig { rerank_l: 100, k: 10,
                                ..Default::default() };
    let offline = SearchEngine::new(&pq, &index, search);
    let want: Vec<Vec<u32>> = (0..10)
        .map(|qi| offline.search(c.query.row(qi)))
        .collect();

    let server = unq::coordinator::pipeline::Server::start(
        Arc::new(Pq::train(&c.train.data, c.train.dim, 8, 64, 0, 8)),
        Arc::new(CompressedIndex::build(&pq, &c.base)),
        search,
        ServeConfig { max_batch: 4, max_delay_us: 300, queue_depth: 64,
                      num_threads: 2, shard_rows: 1000 },
    );
    for qi in 0..10 {
        let resp = server.search_blocking(c.query.row(qi), 10).unwrap();
        assert_eq!(resp.neighbors, want[qi], "query {qi}");
    }
    assert!(server.metrics.search_latency.count() >= 10);
    server.shutdown();
}

#[test]
fn coordinator_u16_fast_scan_serves_offline_engine_results() {
    // the scan-precision knob end to end: a coordinator configured for
    // u16 blocked fast-scan returns exactly what the offline engine
    // returns at the same precision, and (with rerank depth ≥ overlap
    // margins) answers in the same league as the f32 engine
    use unq::config::ScanPrecision;
    let c = corpus(Family::SiftLike, 6000);
    let pq = Pq::train(&c.train.data, c.train.dim, 8, 64, 0, 8);
    let mut index = CompressedIndex::build(&pq, &c.base);
    index.ensure_packed();
    // same explicit shard_rows on both sides: integer selection is
    // per-shard, so offline and server must agree on the decomposition
    // to be bit-identical (f32 needs no such care — DESIGN.md §6)
    let search = SearchConfig { rerank_l: 100, k: 10, shard_rows: 1000,
                                scan_precision: ScanPrecision::U16,
                                ..Default::default() };
    let offline = SearchEngine::new(&pq, &index, search);
    let want: Vec<Vec<u32>> = (0..10)
        .map(|qi| offline.search(c.query.row(qi)))
        .collect();

    let mut server_index = CompressedIndex::build(&pq, &c.base);
    server_index.ensure_packed();
    let server = unq::coordinator::pipeline::Server::start(
        Arc::new(Pq::train(&c.train.data, c.train.dim, 8, 64, 0, 8)),
        Arc::new(server_index),
        search,
        ServeConfig { max_batch: 4, max_delay_us: 300, queue_depth: 64,
                      num_threads: 2, shard_rows: 1000 },
    );
    for qi in 0..10 {
        let resp = server.search_blocking(c.query.row(qi), 10).unwrap();
        assert_eq!(resp.neighbors, want[qi], "query {qi}");
    }
    server.shutdown();
}

#[test]
fn backpressure_rejects_when_overloaded() {
    let c = corpus(Family::SiftLike, 2000);
    let pq = Pq::train(&c.train.data, c.train.dim, 8, 16, 0, 4);
    let index = CompressedIndex::build(&pq, &c.base);
    let server = unq::coordinator::pipeline::Server::start(
        Arc::new(pq),
        Arc::new(index),
        SearchConfig::default(),
        // tiny queue to force rejection
        ServeConfig { max_batch: 64, max_delay_us: 50_000, queue_depth: 1,
                      num_threads: 1, shard_rows: 0 },
    );
    let mut rejected = 0;
    let mut channels = Vec::new();
    for _ in 0..50 {
        let (tx, rx) = std::sync::mpsc::sync_channel(1);
        let req = unq::coordinator::Request::Search(
            unq::coordinator::SearchRequest {
                id: server.next_id(),
                query: c.query.row(0).to_vec(),
                k: 5,
                filter: None,
                submitted: std::time::Instant::now(),
                resp: tx,
            });
        match server.try_submit(req) {
            Err(unq::coordinator::SubmitError::Overloaded) => rejected += 1,
            Ok(()) => channels.push(rx),
            Err(e) => panic!("unexpected {e:?}"),
        }
    }
    assert!(rejected > 0, "tiny queue must shed load");
    // accepted requests still complete
    for rx in channels {
        let _ = rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
    }
    server.shutdown();
}

#[test]
fn ivf_stack_serves_sane_answers_with_fewer_probes() {
    // the full sub-linear path: coarse partition → residual encode →
    // coordinator with the Ivf backend → recall far above chance while
    // probing a fraction of the lists
    let c = corpus(Family::SiftLike, 10_000);
    let pq = Pq::train(&c.train.data, c.train.dim, 8, 64, 0, 8);
    let coarse = CoarseQuantizer::train(&c.train.data, c.train.dim, 32, 1, 10);
    let ivf = Arc::new(IvfIndex::build(&pq, &c.base, coarse, false));
    let search = SearchConfig { rerank_l: 200, k: 100, nprobe: 8,
                                ..Default::default() };
    let server = unq::coordinator::pipeline::Server::start_with_backend(
        Arc::new(pq),
        IndexBackend::Ivf(ivf),
        search,
        ServeConfig { max_batch: 8, max_delay_us: 300, queue_depth: 128,
                      num_threads: 2, shard_rows: 1024 },
    );
    let mut results = Vec::new();
    for qi in 0..c.query.len() {
        results.push(server.search_blocking(c.query.row(qi), 100)
                         .unwrap()
                         .neighbors);
    }
    server.shutdown();
    let r = recall(&results, &c.truth);
    // chance R@100 on 10k base = 1%; probing 8/32 lists must stay way up
    assert!(r.at100 > 20.0, "IVF nprobe=8 R@100 = {}", r.at100);
    assert!(r.at1 <= r.at10 && r.at10 <= r.at100);
}

#[test]
fn catalyst_opq_handles_both_families() {
    for family in [Family::SiftLike, Family::DeepLike] {
        let c = corpus(family, 5000);
        let q = unq::quant::lattice::CatalystOpq::train(
            &c.train.data, c.train.dim, 8, 64, 0);
        let r = recall_of(&q, &c, false);
        assert!(r.at100 > 10.0, "{family:?}: {}", r.at100);
    }
}
