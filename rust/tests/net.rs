//! End-to-end tests for the TCP front door (rust/DESIGN.md §12,
//! rust/PROTOCOL.md): the bit-identity property (TCP responses equal
//! in-process coordinator results at every backend and scan
//! precision, including pipelined out-of-order completion), typed
//! overload under saturation, wire-level robustness (torn / corrupt /
//! oversized frames, mid-pipeline disconnects, slow readers — always
//! a typed error or a clean close, never a hang), tenant quotas, and
//! the doc-sync check pinning PROTOCOL.md's opcode/error tables to
//! the `net::proto` enums.

use std::collections::HashMap;
use std::io::Write;
use std::net::{Shutdown, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use unq::config::{NetConfig, ScanPrecision, SearchConfig, ServeConfig,
                  StreamConfig, TenantQuota};
use unq::coordinator::pipeline::Server;
use unq::data::{synthetic::Generator, Dataset, Family};
use unq::index::{CompressedIndex, Filter, StreamingIndex};
use unq::ivf::disk::DiskIvfIndex;
use unq::ivf::{CoarseQuantizer, IndexBackend, IvfIndex};
use unq::net::proto::{encode_frame, encode_request, read_frame, ErrorCode,
                      NetRequest, Opcode, RequestBody, ResponseBody,
                      FRAME_HEADER};
use unq::net::{Client, NetServer};
use unq::quant::pq::Pq;
use unq::util::TempDir;

const READ_DEADLINE: Duration = Duration::from_secs(30);

struct Corpus {
    train: Dataset,
    base: Dataset,
    query: Dataset,
}

fn corpus(n_base: usize, nq: usize) -> Corpus {
    let gen = Generator::new(Family::SiftLike, 55);
    Corpus {
        train: gen.generate(0, 800),
        base: gen.generate(1, n_base),
        query: gen.generate(2, nq),
    }
}

fn train_pq(c: &Corpus) -> Pq {
    Pq::train(&c.train.data, c.train.dim, 8, 32, 0, 5)
}

fn serve_cfg() -> ServeConfig {
    ServeConfig { max_batch: 4, max_delay_us: 300, queue_depth: 64,
                  num_threads: 2, shard_rows: 256 }
}

fn net_cfg() -> NetConfig {
    NetConfig { listen: "127.0.0.1:0".into(), io_threads: 1,
                ..Default::default() }
}

struct Stack {
    net: NetServer,
    server: Arc<Server>,
}

fn start(pq: Pq, backend: IndexBackend, search: SearchConfig,
         serve: ServeConfig, net: NetConfig) -> Stack {
    let server = Arc::new(Server::start_with_backend(
        Arc::new(pq), backend, search, serve));
    let net = NetServer::start(server.clone(), net).expect("bind loopback");
    Stack { net, server }
}

fn stop(st: Stack) {
    st.net.shutdown();
    // connection threads may still hold the coordinator for a moment;
    // the process reaps them — only drain when fully quiesced
    if let Ok(s) = Arc::try_unwrap(st.server) {
        s.shutdown();
    }
}

fn flat_stack(c: &Corpus) -> Stack {
    let pq = train_pq(c);
    let index = Arc::new(CompressedIndex::build(&pq, &c.base));
    let search = SearchConfig { rerank_l: 64, k: 10, ..Default::default() };
    start(pq, IndexBackend::Flat(index), search, serve_cfg(), net_cfg())
}

fn streaming_backend(c: &Corpus) -> Arc<StreamingIndex> {
    let pq = train_pq(c);
    let ix = Arc::new(StreamingIndex::new(
        8, None, StreamConfig { segment_rows: 512, ..Default::default() }));
    ix.insert_batch(&pq, &c.base.data).expect("seed streaming backend");
    ix
}

fn client(st: &Stack) -> Client {
    let c = Client::connect(st.net.local_addr()).expect("connect");
    c.set_read_timeout(Some(READ_DEADLINE)).expect("read timeout");
    c
}

fn raw_conn(st: &Stack) -> TcpStream {
    let s = TcpStream::connect(st.net.local_addr()).expect("connect raw");
    s.set_read_timeout(Some(READ_DEADLINE)).expect("read timeout");
    s
}

/// Read one response frame off a raw socket; `None` = clean EOF.
fn raw_recv(s: &mut TcpStream) -> Option<unq::net::proto::NetResponse> {
    let payload = read_frame(s, 1 << 24).expect("well-formed frame")?;
    Some(unq::net::proto::decode_response(&payload).expect("decodable"))
}

// ------------------------------------------------------- bit identity

/// The tentpole property: for every index backend and scan precision,
/// responses over TCP are bit-identical to what the same in-process
/// coordinator returns — exercised through a fully pipelined client
/// whose responses complete out of order and are matched by id.
/// (Response payloads carry no timestamps, so equal decoded bodies ⇔
/// equal frames.)
#[test]
fn tcp_results_bit_identical_across_backends_and_precisions() {
    let c = corpus(2500, 8);
    let pq = train_pq(&c);

    let mut flat = CompressedIndex::build(&pq, &c.base);
    flat.ensure_packed(); // integer precisions need the packed mirror
    let flat = Arc::new(flat);
    let coarse = CoarseQuantizer::train(&c.train.data, c.train.dim, 8, 0, 6);
    let mut ivf = IvfIndex::build(&pq, &c.base, coarse, true);
    ivf.ensure_packed();
    let ivf = Arc::new(ivf);
    let dir = TempDir::new("netdisk").unwrap();
    let archive = dir.path().join("ivf.blocks");
    DiskIvfIndex::save_archive(&ivf, &archive).unwrap();
    let disk = Arc::new(DiskIvfIndex::open(&archive, 1 << 20).unwrap());
    let stream = streaming_backend(&c);

    for precision in [ScanPrecision::F32, ScanPrecision::U16,
                      ScanPrecision::U8] {
        let grid: Vec<(&str, IndexBackend)> = vec![
            ("flat", IndexBackend::Flat(flat.clone())),
            ("ivf", IndexBackend::Ivf(ivf.clone())),
            ("disk-ivf", IndexBackend::DiskIvf(disk.clone())),
            ("streaming", IndexBackend::Streaming(stream.clone())),
        ];
        for (name, backend) in grid {
            let search = SearchConfig {
                rerank_l: 64, k: 10, nprobe: 3,
                scan_precision: precision, ..Default::default()
            };
            let st = start(train_pq(&c), backend, search, serve_cfg(),
                           net_cfg());
            let want: Vec<Vec<u32>> = (0..c.query.len())
                .map(|qi| {
                    st.server.search_blocking(c.query.row(qi), 10)
                        .unwrap().neighbors
                })
                .collect();

            let mut cl = client(&st);
            let ids: Vec<u64> = (0..c.query.len())
                .map(|qi| {
                    cl.send(RequestBody::Search {
                        tenant: String::new(), k: 10,
                        query: c.query.row(qi).to_vec(), filter: None,
                    }).expect("pipelined send")
                })
                .collect();
            let mut got: HashMap<u64, Vec<u32>> = HashMap::new();
            for _ in &ids {
                let resp = cl.recv().expect("read").expect("open");
                match resp.body {
                    ResponseBody::SearchOk { neighbors } => {
                        assert!(got.insert(resp.id, neighbors).is_none(),
                                "{name}/{precision:?}: duplicate id");
                    }
                    other => panic!("{name}/{precision:?}: {other:?}"),
                }
            }
            for (qi, id) in ids.iter().enumerate() {
                assert_eq!(got[id], want[qi],
                           "{name}/{precision:?} query {qi}");
            }
            drop(cl);
            stop(st);
        }
    }
}

// ------------------------------------------------------------ overload

/// A saturated server answers typed `OVERLOADED` within the deadline —
/// it never hangs — and keeps serving the same connection afterwards.
#[test]
fn saturated_server_sheds_typed_overload_and_recovers() {
    let c = corpus(1500, 1);
    let pq = train_pq(&c);
    let index = Arc::new(CompressedIndex::build(&pq, &c.base));
    // a 20 ms batching deadline holds the first search in flight while
    // the rest of the burst arrives; max_inflight 1 sheds all of them
    let serve = ServeConfig { max_batch: 64, max_delay_us: 20_000,
                              queue_depth: 64, num_threads: 1,
                              shard_rows: 256 };
    let net = NetConfig { max_inflight: 1, ..net_cfg() };
    let st = start(pq, IndexBackend::Flat(index),
                   SearchConfig { rerank_l: 64, k: 10,
                                  ..Default::default() },
                   serve, net);

    let mut cl = client(&st);
    const BURST: usize = 30;
    for _ in 0..BURST {
        cl.send(RequestBody::Search {
            tenant: String::new(), k: 10,
            query: c.query.row(0).to_vec(), filter: None,
        }).expect("pipelined send");
    }
    let (mut ok, mut shed) = (0usize, 0usize);
    for _ in 0..BURST {
        // the read deadline is the "within deadline" part of the
        // property: a hang fails here, not in CI's global timeout
        match cl.recv().expect("deadline").expect("open").body {
            ResponseBody::SearchOk { .. } => ok += 1,
            ResponseBody::Error { code: ErrorCode::Overloaded, .. } => {
                shed += 1;
            }
            other => panic!("unexpected {other:?}"),
        }
    }
    assert_eq!(ok + shed, BURST);
    assert!(ok >= 1, "at least the first search must land");
    assert!(shed >= 1, "a 1-deep window must shed a 30-burst");

    // window drained: the same connection serves again
    let after = cl.search_ids("", c.query.row(0), 10).expect("recovered");
    assert_eq!(after.len(), 10);
    drop(cl);
    stop(st);
}

// ----------------------------------------------------- wire robustness

#[test]
fn torn_frame_closes_the_connection_cleanly() {
    let c = corpus(1200, 1);
    let st = flat_stack(&c);

    let mut s = raw_conn(&st);
    let frame = encode_request(&NetRequest {
        id: 1,
        body: RequestBody::Search { tenant: String::new(), k: 5,
                                    query: c.query.row(0).to_vec(),
                                    filter: None },
    });
    s.write_all(&frame[..FRAME_HEADER + 4]).unwrap();
    s.shutdown(Shutdown::Write).unwrap();
    // no reply owed for a torn frame: just EOF, within the deadline
    assert!(raw_recv(&mut s).is_none(), "torn frame must close silently");

    // the listener is unaffected
    let mut cl = client(&st);
    cl.ping().expect("server still alive");
    drop(cl);
    stop(st);
}

#[test]
fn corrupt_crc_answers_bad_request_then_closes() {
    let c = corpus(1200, 1);
    let st = flat_stack(&c);

    let mut frame = encode_request(&NetRequest {
        id: 9, body: RequestBody::Ping,
    });
    frame[FRAME_HEADER + 2] ^= 0x55; // corrupt the payload, not the header
    let mut s = raw_conn(&st);
    s.write_all(&frame).unwrap();
    let resp = raw_recv(&mut s).expect("typed reply");
    // the stream cannot be resynchronized past a CRC failure, so the id
    // is unknowable: the error carries id 0 and the connection closes
    assert_eq!(resp.id, 0);
    assert!(matches!(resp.body,
                     ResponseBody::Error { code: ErrorCode::BadRequest, .. }),
            "want BAD_REQUEST, got {:?}", resp.body);
    assert!(raw_recv(&mut s).is_none(), "connection must close");
    stop(st);
}

#[test]
fn oversized_frame_answers_frame_too_large_without_buffering() {
    let c = corpus(1200, 1);
    let st = {
        let pq = train_pq(&c);
        let index = Arc::new(CompressedIndex::build(&pq, &c.base));
        start(pq, IndexBackend::Flat(index),
              SearchConfig { rerank_l: 64, k: 10, ..Default::default() },
              serve_cfg(),
              NetConfig { max_frame: 1024, ..net_cfg() })
    };

    // header alone claims 1 MB; the server must answer on the header
    // without waiting for (or allocating) the payload
    let mut s = raw_conn(&st);
    let mut header = Vec::new();
    header.extend_from_slice(&(1u32 << 20).to_le_bytes());
    header.extend_from_slice(&0u32.to_le_bytes());
    s.write_all(&header).unwrap();
    let resp = raw_recv(&mut s).expect("typed reply");
    assert_eq!(resp.id, 0);
    assert!(matches!(
        resp.body,
        ResponseBody::Error { code: ErrorCode::FrameTooLarge, .. }),
            "want FRAME_TOO_LARGE, got {:?}", resp.body);
    assert!(raw_recv(&mut s).is_none(), "connection must close");
    stop(st);
}

#[test]
fn disconnect_mid_pipeline_leaves_the_server_serving() {
    let c = corpus(1500, 2);
    let st = flat_stack(&c);
    let want = st.server.search_blocking(c.query.row(1), 10)
        .unwrap().neighbors;

    {
        let mut cl = client(&st);
        for _ in 0..10 {
            cl.send(RequestBody::Search {
                tenant: String::new(), k: 10,
                query: c.query.row(0).to_vec(), filter: None,
            }).unwrap();
        }
        // vanish with ten requests in flight
    }
    let mut cl = client(&st);
    let got = cl.search_ids("", c.query.row(1), 10).expect("still serving");
    assert_eq!(got, want, "abandoned pipeline must not corrupt serving");
    drop(cl);
    stop(st);
}

/// A reader that never drains its responses is disconnected by the
/// write timeout instead of pinning server memory; the test itself is
/// the no-hang assertion (every blocking call has a deadline).
#[test]
fn slow_reader_is_disconnected_not_hung() {
    let c = corpus(1200, 1);
    let st = {
        let pq = train_pq(&c);
        let index = Arc::new(CompressedIndex::build(&pq, &c.base));
        start(pq, IndexBackend::Flat(index),
              SearchConfig { rerank_l: 64, k: 10, ..Default::default() },
              serve_cfg(),
              NetConfig { write_timeout_ms: 200, max_inflight: 4,
                          ..net_cfg() })
    };

    let mut s = raw_conn(&st);
    s.set_write_timeout(Some(Duration::from_millis(500))).unwrap();
    let frame = encode_request(&NetRequest { id: 1,
                                             body: RequestBody::Ping });
    // never read: pong frames pile up until the server's writer times
    // out and severs the socket, at which point our writes start failing
    let mut severed = false;
    for _ in 0..400_000 {
        if s.write_all(&frame).is_err() {
            severed = true;
            break;
        }
    }
    assert!(severed, "server let a never-reading client pin it");

    let mut cl = client(&st);
    cl.ping().expect("server healthy after shedding the slow reader");
    drop(cl);
    stop(st);
}

// ------------------------------------------------------------- tenants

#[test]
fn tenant_quotas_and_unknown_tenants_are_typed() {
    let c = corpus(1500, 1);
    let dim = c.base.dim;
    let stream = streaming_backend(&c);
    let row_bytes = (dim * 4) as u64;
    let net = NetConfig {
        tenants: vec![
            TenantQuota { name: "alice".into(), max_qps: 3,
                          max_insert_bytes: 0 },
            TenantQuota { name: "ingest".into(), max_qps: 0,
                          max_insert_bytes: 2 * row_bytes },
        ],
        ..net_cfg()
    };
    let st = start(train_pq(&c), IndexBackend::Streaming(stream),
                   SearchConfig { rerank_l: 64, k: 10,
                                  ..Default::default() },
                   serve_cfg(), net);
    let mut cl = client(&st);

    // a configured table is closed: unknown names — including the
    // implicit default — are typed UNKNOWN_TENANT
    for tenant in ["bob", ""] {
        let resp = cl.search(tenant, c.query.row(0), 10).unwrap();
        assert!(matches!(
            resp.body,
            ResponseBody::Error { code: ErrorCode::UnknownTenant, .. }),
                "tenant {tenant:?}: {:?}", resp.body);
    }

    // QPS bucket: capacity 3, refilled at 3/s — a quick burst of 10
    // lands ~3 and sheds the rest as QUOTA_EXCEEDED
    let (mut ok, mut quota) = (0usize, 0usize);
    for _ in 0..10 {
        match cl.search("alice", c.query.row(0), 10).unwrap().body {
            ResponseBody::SearchOk { .. } => ok += 1,
            ResponseBody::Error {
                code: ErrorCode::QuotaExceeded, .. } => quota += 1,
            other => panic!("unexpected {other:?}"),
        }
    }
    assert!(ok >= 3, "the initial bucket holds 3 tokens, served {ok}");
    assert!(quota >= 5, "burst must exhaust the bucket, shed {quota}");

    // insert-byte budget is lifetime and exact: 2 rows fit, the 3rd is
    // deterministically rejected
    for i in 0..2 {
        let resp = cl.insert("ingest", c.base.row(i), 1, dim as u32)
            .unwrap();
        assert!(matches!(resp.body,
                         ResponseBody::InsertOk { accepted: true, .. }),
                "row {i}: {:?}", resp.body);
    }
    let resp = cl.insert("ingest", c.base.row(2), 1, dim as u32).unwrap();
    assert!(matches!(
        resp.body,
        ResponseBody::Error { code: ErrorCode::QuotaExceeded, .. }),
            "byte budget must reject the 3rd row: {:?}", resp.body);

    // STATS reports the accounting the quota decisions came from
    let js = cl.stats("alice").unwrap();
    let parsed = unq::util::json::Json::parse(&js).unwrap();
    assert_eq!(parsed.get("tenant").and_then(|j| j.as_str()),
               Some("alice"));
    let requests = parsed.get("requests")
        .and_then(|j| j.as_f64).unwrap() as usize;
    let rejected = parsed.get("rejected")
        .and_then(|j| j.as_f64).unwrap() as usize;
    assert_eq!(requests, ok);
    assert_eq!(rejected, quota);
    drop(cl);
    stop(st);
}

// ------------------------------------------------- protocol semantics

#[test]
fn bad_version_is_typed_and_the_connection_survives() {
    let c = corpus(1200, 1);
    let st = flat_stack(&c);

    let mut s = raw_conn(&st);
    let mut payload = vec![Opcode::Ping.code(), 99]; // future version
    payload.extend_from_slice(&7u64.to_le_bytes());
    s.write_all(&encode_frame(&payload)).unwrap();
    let resp = raw_recv(&mut s).expect("typed reply");
    // the id offset is version-independent, so the reply echoes it
    assert_eq!(resp.id, 7);
    assert!(matches!(resp.body,
                     ResponseBody::Error { code: ErrorCode::BadVersion, .. }),
            "want BAD_VERSION, got {:?}", resp.body);

    // a decode error is not a framing error: the connection stays open
    s.write_all(&encode_request(&NetRequest {
        id: 8, body: RequestBody::Ping,
    })).unwrap();
    let pong = raw_recv(&mut s).expect("connection survived");
    assert_eq!(pong.id, 8);
    assert!(matches!(pong.body, ResponseBody::Pong));
    stop(st);
}

/// A hostile `k` (0 or u32::MAX) must die at the shape gate as
/// BAD_REQUEST — never reach the scan path, where it would size a
/// ~k-element heap allocation per query and abort the process.
#[test]
fn search_k_is_bounds_checked_before_any_allocation() {
    let c = corpus(1200, 1);
    let st = flat_stack(&c);
    let mut cl = client(&st);
    let q = c.query.row(0).to_vec();

    for k in [0u32, unq::net::proto::MAX_SEARCH_K + 1, u32::MAX] {
        let resp = cl.search("", &q, k).unwrap();
        assert!(matches!(resp.body,
                         ResponseBody::Error { code: ErrorCode::BadRequest,
                                               .. }),
                "k = {k}: {:?}", resp.body);
    }
    // the gate is per-request: the connection keeps serving, and an
    // in-range k (the cap itself) still answers normally
    let resp = cl.search("", &q, unq::net::proto::MAX_SEARCH_K).unwrap();
    assert!(matches!(resp.body, ResponseBody::SearchOk { .. }),
            "k at cap: {:?}", resp.body);
    drop(cl);
    stop(st);
}

#[test]
fn mutating_ops_roundtrip_and_frozen_backends_decline() {
    let c = corpus(1500, 2);
    let dim = c.base.dim as u32;

    // streaming backend: insert returns the assigned ids, delete
    // reports how many of them existed
    let stream = streaming_backend(&c);
    let st = start(train_pq(&c), IndexBackend::Streaming(stream),
                   SearchConfig { rerank_l: 64, k: 10,
                                  ..Default::default() },
                   serve_cfg(), net_cfg());
    let mut cl = client(&st);
    let resp = cl.insert("", c.base.rows(0, 3), 3, dim).unwrap();
    let ids = match resp.body {
        ResponseBody::InsertOk { accepted: true, ids } => ids,
        other => panic!("insert: {other:?}"),
    };
    assert_eq!(ids.len(), 3);
    let resp = cl.delete("", &ids).unwrap();
    match resp.body {
        ResponseBody::DeleteOk { accepted: true, removed } => {
            assert_eq!(removed, 3);
        }
        other => panic!("delete: {other:?}"),
    }
    cl.ping().unwrap();
    drop(cl);
    stop(st);

    // frozen (flat) backend: same wire ops answer accepted = false
    let st = flat_stack(&c);
    let mut cl = client(&st);
    let resp = cl.insert("", c.base.rows(0, 2), 2, dim).unwrap();
    assert!(matches!(resp.body,
                     ResponseBody::InsertOk { accepted: false, .. }),
            "frozen insert: {:?}", resp.body);
    let resp = cl.delete("", &[1, 2]).unwrap();
    assert!(matches!(resp.body,
                     ResponseBody::DeleteOk { accepted: false,
                                              removed: 0 }),
            "frozen delete: {:?}", resp.body);
    // shape violations are BAD_REQUEST before any quota spend
    let resp = cl.search("", &[0.0f32; 3], 10).unwrap();
    assert!(matches!(resp.body,
                     ResponseBody::Error { code: ErrorCode::BadRequest,
                                           .. }),
            "dim mismatch: {:?}", resp.body);
    drop(cl);
    stop(st);
}

// ------------------------------------------------------------- filtering

/// The SEARCH filter TLV is honored end to end: a tagged backend
/// serves only admitted ids over TCP, bit-identical to the in-process
/// filtered result, while the filterless frame keeps serving the
/// unfiltered ranking on the same connection.
#[test]
fn filter_tlv_is_honored_end_to_end_over_tcp() {
    let c = corpus(1500, 4);
    let pq = train_pq(&c);
    let mut index = CompressedIndex::build(&pq, &c.base);
    index.set_tags((0..c.base.len() as u64).map(|i| i % 2).collect());
    let st = start(pq, IndexBackend::Flat(Arc::new(index)),
                   SearchConfig { rerank_l: 64, k: 10,
                                  ..Default::default() },
                   serve_cfg(), net_cfg());
    let mut cl = client(&st);
    for qi in 0..c.query.len() {
        let q = c.query.row(qi);
        let want = st.server
            .search_blocking_filtered(q, 10, Some(Filter::TagEq(1)))
            .unwrap().neighbors;
        let got = match cl
            .search_filtered("", q, 10, Some(Filter::TagEq(1)))
            .unwrap().body
        {
            ResponseBody::SearchOk { neighbors } => neighbors,
            other => panic!("query {qi}: {other:?}"),
        };
        assert_eq!(got, want, "query {qi}: TCP vs in-process");
        assert!(!got.is_empty() && got.iter().all(|id| id % 2 == 1),
                "query {qi}: only odd-tagged rows admitted: {got:?}");
        // the filterless frame on the same connection is unaffected
        let plain = cl.search_ids("", q, 10).unwrap();
        let unfiltered =
            st.server.search_blocking(q, 10).unwrap().neighbors;
        assert_eq!(plain, unfiltered, "query {qi}: filterless frame");
    }
    // a predicate admitting no rows answers empty, not an error
    match cl.search_filtered("", c.query.row(0), 10,
                             Some(Filter::TagEq(42))).unwrap().body {
        ResponseBody::SearchOk { neighbors } => {
            assert!(neighbors.is_empty(), "selectivity 0: {neighbors:?}");
        }
        other => panic!("selectivity 0: {other:?}"),
    }
    drop(cl);
    stop(st);
}

// -------------------------------------------------------------- doc sync

/// Every opcode and error code in PROTOCOL.md's tables must match a
/// `net::proto` enum variant — both directions, code and name.  The
/// spec rows have the exact shape `| `0xNN` | `NAME` | ...`.
#[test]
fn protocol_doc_tables_pin_the_wire_enums() {
    let md = include_str!("../PROTOCOL.md");
    let mut doc: Vec<(u8, String)> = Vec::new();
    for line in md.lines() {
        let cells: Vec<&str> = line.split('|').map(str::trim).collect();
        if cells.len() < 3 {
            continue;
        }
        let Some(hex) = cells[1].strip_prefix("`0x")
            .and_then(|s| s.strip_suffix('`'))
        else {
            continue;
        };
        let Ok(code) = u8::from_str_radix(hex, 16) else { continue };
        let Some(name) = cells[2].strip_prefix('`')
            .and_then(|s| s.strip_suffix('`'))
        else {
            continue;
        };
        if !name.is_empty()
            && name.chars().all(|ch| ch.is_ascii_uppercase() || ch == '_')
        {
            doc.push((code, name.to_string()));
        }
    }

    let mut want: Vec<(u8, String)> = Opcode::all().iter()
        .map(|o| (o.code(), o.name().to_string()))
        .chain(ErrorCode::all().iter()
                   .map(|e| (e.code(), e.name().to_string())))
        .collect();
    doc.sort();
    doc.dedup();
    want.sort();
    assert!(!doc.is_empty(), "PROTOCOL.md spec tables not found");
    assert_eq!(doc, want,
               "PROTOCOL.md tables and net::proto enums diverged — \
                update them together");

    // the SEARCH filter TLV is spec'd in prose rather than a code
    // table; pin its tag byte to the doc the same way
    let tlv = format!("`0x{:02x}` (`FILTER_TAG_EQ`)",
                      unq::net::proto::FILTER_TAG_EQ);
    assert!(md.contains(&tlv),
            "PROTOCOL.md must spec the filter TLV tag as {tlv}");
}
