//! Overhead contract of the observability layer (rust/DESIGN.md §10):
//! with no live trace, span guards must cost one relaxed atomic load and
//! a branch — in particular they must never allocate — and metric
//! updates must be allocation-free always.
//!
//! Allocation counting is per-thread (a `thread_local` bumped by a
//! wrapping global allocator), so concurrently running tests in this
//! binary cannot pollute each other's counts.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

struct CountingAlloc;

thread_local! {
    static TL_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

// SAFETY: delegates verbatim to `System`; the only addition is a
// thread-local counter bump, which neither allocates nor unwinds
// (`try_with` covers TLS teardown).
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        let _ = TL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        // SAFETY: caller upholds the `GlobalAlloc::alloc` contract.
        unsafe { System.alloc(l) }
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        // SAFETY: `p`/`l` came from a matching `alloc` on `System`.
        unsafe { System.dealloc(p, l) }
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocs_here() -> u64 {
    TL_ALLOCS.with(|c| c.get())
}

#[test]
fn disabled_span_guards_allocate_nothing() {
    // warm up every lazily-initialised piece the fast path can touch
    // (global registry, the thread-local span stack)
    {
        let mut g = unq::obs::span::enter("warmup");
        g.add_rows(1);
        assert!(!g.is_active(), "no trace is live in this test");
    }
    let before = allocs_here();
    for i in 0..10_000u64 {
        let mut g = unq::obs::span::enter("scan_task");
        g.add_rows(i);
    }
    let after = allocs_here();
    assert_eq!(after - before, 0,
               "disabled span guards must not allocate (got {} allocations \
                over 10k guards)", after - before);
}

#[test]
fn metric_updates_allocate_nothing() {
    let reg = unq::obs::global();
    reg.scan_tasks.inc(); // force one-time registry init outside the window
    let before = allocs_here();
    for i in 0..10_000u64 {
        reg.scan_rows_f32.add(64);
        reg.scan_tasks.inc();
        reg.exec_queue_depth.inc();
        reg.exec_queue_depth.dec();
        reg.wal_fsync_us.record(i % 4096);
        reg.train_last_loss.set(0.25);
    }
    let after = allocs_here();
    assert_eq!(after - before, 0,
               "metric updates must be allocation-free (got {})",
               after - before);
}

#[test]
fn enabled_tracing_does_not_perturb_disabled_cost_after_drop() {
    // begin + drop a trace, then re-check the disabled path is inert
    // again: the global live-trace gate must fall back to zero
    {
        let (trace, root) = unq::obs::Trace::begin("query");
        {
            let mut g = unq::obs::span::enter("scan");
            g.add_rows(3);
            assert!(g.is_active());
        }
        drop(root);
        assert_eq!(trace.rows("scan"), 3);
    }
    let mut g = unq::obs::span::enter("scan");
    g.add_rows(1);
    // NOTE: other tests in this *binary* never begin traces concurrently
    // with this check except the block above, which has fully dropped
    assert!(!g.is_active(),
            "dropping the last trace must restore the inert fast path");
    drop(g);
    let before = allocs_here();
    for _ in 0..1000 {
        let _g = unq::obs::span::enter("scan_task");
    }
    assert_eq!(allocs_here() - before, 0);
}
