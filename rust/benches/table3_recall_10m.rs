//! Bench: regenerate the paper's Table 3 (recall at 10M scale (sim: 300k)) and time the
//! end-to-end evaluation. Heavy models/codes are cached under runs/, so
//! the first invocation trains and later ones measure search only.
//!
//! Run: `cargo bench --bench table3_recall_10m`

use unq::config::AppConfig;
use unq::eval::tables::{recall_table, table34_methods};
use unq::util::bench::Bench;

fn main() {
    let cfg = AppConfig::default().apply_env();
    let mut b = Bench::e2e();
    let mut rendered = String::new();
    b.run("table3 full evaluation", 1, || {
        let t = recall_table("Table 3 — 10M scale (sim: 300k)", &cfg, "sift10m", "deep10m",
                             &table34_methods(), &[8, 16]);
        rendered = t.render();
    });
    println!("{rendered}");
}
