//! Bench: regenerate the paper's Table 5 (training/search ablations on
//! BigANN1M-sim @ 8 bytes). Requires `make artifacts artifacts-ablation`.
//!
//! Run: `cargo bench --bench table5_ablation`

use unq::config::AppConfig;
use unq::eval::tables::table5_ablation;
use unq::util::bench::Bench;

fn main() {
    let cfg = AppConfig::default().apply_env();
    let mut b = Bench::e2e();
    b.run("table5 ablation evaluation", 1, || {
        if let Err(e) = table5_ablation(&cfg) {
            eprintln!("table5 skipped: {e:#}");
        }
    });
}
