//! Bench: the paper's §4.4 timing study (encode / LUT scan / rerank) plus
//! Table 1's measured train/encode complexity, and the serving-loop
//! throughput of the coordinator (§Perf e2e row).
//!
//! Run: `cargo bench --bench timings`

use unq::config::{AppConfig, QuantizerKind};
use unq::coordinator::demo::run_serve;
use unq::eval::tables::{table1_timings, table_timings};
use unq::util::bench::Bench;

fn main() {
    let cfg = AppConfig::default().apply_env();
    let mut b = Bench::e2e();
    b.run("table1 complexity measurements", 1, || {
        if let Err(e) = table1_timings(&cfg) {
            eprintln!("table1 skipped: {e:#}");
        }
    });
    b.run("§4.4 timings", 1, || {
        if let Err(e) = table_timings(&cfg) {
            eprintln!("timings skipped: {e:#}");
        }
    });
    // Coordinator serving loop (UNQ if artifacts exist, else PQ fallback).
    let mut scfg = cfg.clone();
    scfg.dataset = "sift1m".into();
    scfg.quantizer = QuantizerKind::Unq;
    b.run("serving loop 500 queries", 500, || {
        if let Err(e) = run_serve(&scfg, 500) {
            eprintln!("serve(UNQ) skipped: {e:#}");
            let mut pq = scfg.clone();
            pq.quantizer = QuantizerKind::Pq;
            let _ = run_serve(&pq, 500);
        }
    });
}
