//! Bench: the paper's §4.4 timing study (encode / LUT scan / rerank) plus
//! Table 1's measured train/encode complexity, the serving-loop
//! throughput of the coordinator, and the batch executor's scan
//! throughput at 1/2/4/8 threads (written to `BENCH_scan.json` so the
//! perf trajectory accumulates across PRs — see rust/DESIGN.md §2).
//!
//! Run: `cargo bench --bench timings`

use unq::config::{AppConfig, QuantizerKind};
use unq::coordinator::demo::run_serve;
use unq::eval::tables::{table1_timings, table_timings};
use unq::exec::Executor;
use unq::index::CompressedIndex;
use unq::quant::Lut;
use unq::util::bench::Bench;
use unq::util::json::Json;
use unq::util::rng::SplitMix64;

/// Sharded batch-scan throughput sweep over worker counts; returns the
/// per-thread-count results as JSON entries.
fn scan_thread_sweep(b: &mut Bench) -> Vec<Json> {
    let (n, m, nq) = (200_000usize, 8usize, 8usize);
    let mut rng = SplitMix64::new(71);
    let codes: Vec<u8> = (0..n * m).map(|_| rng.below(256) as u8).collect();
    let index = CompressedIndex::from_codes(n, m, codes);
    let luts: Vec<Lut> = (0..nq)
        .map(|_| {
            let tables: Vec<f32> =
                (0..m * 256).map(|_| rng.next_f32()).collect();
            Lut::Tables { m, k: 256, tables, bias: 0.0 }
        })
        .collect();
    let ks = vec![100usize; nq];
    let vectors_per_iter = (n * nq) as u64;

    let mut entries = Vec::new();
    for threads in [1usize, 2, 4, 8] {
        let exec = Executor::new(threads);
        b.run(
            &format!("scan_batch {nq}q n={n} m={m} threads={threads}"),
            vectors_per_iter,
            || exec.scan_batch(&luts, &index, &ks, 16_384),
        );
        let s = b.results().last().expect("bench just ran");
        let med = s.median();
        entries.push(Json::obj(vec![
            ("threads", Json::Num(threads as f64)),
            ("queries", Json::Num(nq as f64)),
            ("rows", Json::Num(n as f64)),
            ("code_bytes", Json::Num(m as f64)),
            ("shard_rows", Json::Num(16_384.0)),
            ("secs_per_batch", Json::Num(med)),
            ("vectors_per_sec", Json::Num(vectors_per_iter as f64 / med)),
        ]));
    }
    entries
}

fn main() {
    let cfg = AppConfig::default().apply_env();
    let mut b = Bench::e2e();
    b.run("table1 complexity measurements", 1, || {
        if let Err(e) = table1_timings(&cfg) {
            eprintln!("table1 skipped: {e:#}");
        }
    });
    b.run("§4.4 timings", 1, || {
        if let Err(e) = table_timings(&cfg) {
            eprintln!("timings skipped: {e:#}");
        }
    });

    // Batch executor scan throughput at 1/2/4/8 threads.
    let entries = scan_thread_sweep(&mut b);
    let report = Json::obj(vec![
        ("bench", Json::Str("scan_batch_thread_sweep".into())),
        ("results", Json::Arr(entries)),
    ]);
    match std::fs::write("BENCH_scan.json", report.render_pretty()) {
        Ok(()) => println!("[timings] wrote BENCH_scan.json"),
        Err(e) => eprintln!("[timings] BENCH_scan.json not written: {e}"),
    }

    // Coordinator serving loop (UNQ if artifacts exist, else PQ fallback),
    // driving the pooled batch executor end to end.
    let mut scfg = cfg.clone();
    scfg.dataset = "sift1m".into();
    scfg.quantizer = QuantizerKind::Unq;
    // default to a pooled serving loop, but let an explicit UNQ_THREADS
    // (already applied by apply_env) pick the inline path too
    if std::env::var("UNQ_THREADS").is_err() && scfg.serve.num_threads <= 1 {
        scfg.serve.num_threads = 2;
    }
    b.run("serving loop 500 queries", 500, || {
        if let Err(e) = run_serve(&scfg, 500) {
            eprintln!("serve(UNQ) skipped: {e:#}");
            let mut pq = scfg.clone();
            pq.quantizer = QuantizerKind::Pq;
            let _ = run_serve(&pq, 500);
        }
    });
}
