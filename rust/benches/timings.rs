//! Bench: the paper's §4.4 timing study (encode / LUT scan / rerank) plus
//! Table 1's measured train/encode complexity, the serving-loop
//! throughput of the coordinator, the batch executor's scan throughput
//! at 1/2/4/8 threads, the scan-precision (f32/u16/u8 at 256 codewords,
//! u4 at 16) sweep with per-precision scalar-vs-SIMD columns (all
//! written to `BENCH_scan.json`), and the IVF nprobe throughput/recall
//! sweep (written to `BENCH_ivf.json`).  Trajectory files land at the
//! *repository root* regardless of CWD so the numbers accumulate across
//! PRs — see rust/DESIGN.md §2, §5, §6 and §9.
//!
//! Run: `cargo bench --bench timings`
//!
//! `UNQ_BENCH_SMOKE=1` caps every sweep to tiny sizes and writes
//! `BENCH_*.smoke.json` instead (never clobbering measured numbers) —
//! the CI smoke job uses this to exercise the release-mode kernels and
//! keep the committed JSON schemas from rotting.

use unq::config::{AppConfig, QuantizerKind, ScanPrecision, SearchConfig};
use unq::coordinator::demo::run_serve;
use unq::data::{synthetic::Generator, Family};
use unq::eval::tables::{table1_timings, table_timings};
use unq::exec::{Executor, ScanSpec};
use unq::index::{simd, CompressedIndex, SearchEngine};
use unq::ivf::{CoarseQuantizer, IvfIndex};
use unq::obs;
use unq::quant::{pq::Pq, Lut};
use unq::util::bench::Bench;
use unq::util::json::Json;
use unq::util::rng::SplitMix64;

/// Tiny-size mode for the CI schema/kernel smoke job.
fn smoke() -> bool {
    std::env::var("UNQ_BENCH_SMOKE").map(|v| v != "0" && !v.is_empty())
        .unwrap_or(false)
}

/// Trajectory files accumulate at the repo root, not wherever the bench
/// happens to run (the old CWD-relative path silently dropped them into
/// `rust/` or `target/`).  Smoke runs write a `.smoke.json` sibling so
/// capped numbers never overwrite measured ones.
fn repo_root_path(name: &str) -> std::path::PathBuf {
    let name = if smoke() {
        name.replace(".json", ".smoke.json")
    } else {
        name.to_string()
    };
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join(name)
}

fn write_report(name: &str, report: &Json) {
    let path = repo_root_path(name);
    match std::fs::write(&path, report.render_pretty()) {
        Ok(()) => println!("[timings] wrote {}", path.display()),
        Err(e) => eprintln!("[timings] {} not written: {e}", path.display()),
    }
}

/// Sharded batch-scan throughput sweep over worker counts; returns the
/// per-thread-count results as JSON entries.
fn scan_thread_sweep(b: &mut Bench) -> Vec<Json> {
    let (n, m, nq) = if smoke() {
        (4_000usize, 8usize, 4usize)
    } else {
        (200_000, 8, 8)
    };
    let mut rng = SplitMix64::new(71);
    let codes: Vec<u8> = (0..n * m).map(|_| rng.below(256) as u8).collect();
    let index = CompressedIndex::from_codes(n, m, codes);
    let luts: Vec<Lut> = (0..nq)
        .map(|_| {
            let tables: Vec<f32> =
                (0..m * 256).map(|_| rng.next_f32()).collect();
            Lut::Tables { m, k: 256, tables, bias: 0.0 }
        })
        .collect();
    let ks = vec![100usize; nq];
    let vectors_per_iter = (n * nq) as u64;

    let threads_grid: &[usize] = if smoke() { &[1, 2] } else { &[1, 2, 4, 8] };
    let mut entries = Vec::new();
    for &threads in threads_grid {
        let exec = Executor::new(threads);
        b.run(
            &format!("scan_batch {nq}q n={n} m={m} threads={threads}"),
            vectors_per_iter,
            || exec.scan_batch(&luts, &index, &ks, 16_384,
                               &ScanSpec::default()),
        );
        let s = b.results().last().expect("bench just ran");
        let med = s.median();
        entries.push(Json::obj(vec![
            ("threads", Json::Num(threads as f64)),
            ("queries", Json::Num(nq as f64)),
            ("rows", Json::Num(n as f64)),
            ("code_bytes", Json::Num(m as f64)),
            ("shard_rows", Json::Num(16_384.0)),
            ("secs_per_batch", Json::Num(med)),
            ("vectors_per_sec", Json::Num(vectors_per_iter as f64 / med)),
        ]));
    }
    entries
}

/// Scan-precision sweep over the packed layout at the grid
/// n ∈ {100k, 1M} × m ∈ {8, 16}: every requested precision runs the
/// scalar kernel (forced via [`simd::set_force_scalar_for_bench`]) AND
/// the dispatched SIMD kernel, recording throughput, per-precision
/// SIMD-vs-scalar speedup, and recall@10 against the f32 scan.  `kw`
/// sizes the codebooks: 256 exercises f32/u16/u8, 16 the u4 in-register
/// path (codes stay below 16 so `ensure_packed` also builds the nibble
/// mirror — DESIGN.md §6, §9).  The f32 kernel has no SIMD variant and
/// contributes a single scalar row per dataset (the speedup baseline).
fn scan_precision_sweep(b: &mut Bench, kw: usize,
                        precisions: &[ScanPrecision]) -> Vec<Json> {
    let sizes: &[(usize, usize)] = if smoke() {
        &[(4_000, 8)]
    } else {
        &[(100_000, 8), (100_000, 16), (1_000_000, 8), (1_000_000, 16)]
    };
    let (nq, k, threads, shard_rows) = (8usize, 10usize, 4usize, 16_384usize);
    let mut entries = Vec::new();
    for &(n, m) in sizes {
        let mut rng = SplitMix64::new(97);
        let codes: Vec<u8> =
            (0..n * m).map(|_| rng.below(kw) as u8).collect();
        let mut index = CompressedIndex::from_codes(n, m, codes);
        index.ensure_packed();
        let luts: Vec<Lut> = (0..nq)
            .map(|_| {
                let tables: Vec<f32> =
                    (0..m * kw).map(|_| rng.next_f32()).collect();
                Lut::Tables { m, k: kw, tables, bias: 0.0 }
            })
            .collect();
        let ks = vec![k; nq];
        let exec = Executor::new(threads);
        let vectors_per_iter = (n * nq) as u64;
        let f32_ref =
            exec.scan_batch(&luts, &index, &ks, shard_rows,
                            &ScanSpec::default());
        let mut f32_secs = f64::NAN;
        for &prec in precisions {
            // f32 ignores dispatch entirely; integer precisions get a
            // scalar row first (the per-precision baseline), then the
            // dispatched row
            let modes: &[bool] = if prec == ScanPrecision::F32 {
                &[true]
            } else {
                &[true, false]
            };
            let mut scalar_secs = f64::NAN;
            for &force_scalar in modes {
                simd::set_force_scalar_for_bench(force_scalar);
                let mode = if force_scalar { "scalar" } else { "simd" };
                let kernel = if force_scalar {
                    "scalar"
                } else {
                    simd::active_name()
                };
                b.run(
                    &format!("scan {nq}q n={n} m={m} kw={kw} prec={} {mode}",
                             prec.name()),
                    vectors_per_iter,
                    || exec.scan_batch(
                        &luts, &index, &ks, shard_rows,
                        &ScanSpec { precision: prec, ..Default::default() }),
                );
                let secs =
                    b.results().last().expect("bench just ran").median();
                if prec == ScanPrecision::F32 {
                    f32_secs = secs;
                }
                if force_scalar {
                    scalar_secs = secs;
                }
                let got = exec.scan_batch(
                    &luts, &index, &ks, shard_rows,
                    &ScanSpec { precision: prec, ..Default::default() });
                let overlap: usize = got
                    .iter()
                    .zip(&f32_ref)
                    .map(|(g, w)| {
                        g.iter()
                            .filter(|p| w.iter().any(|q| q.1 == p.1))
                            .count()
                    })
                    .sum();
                let recall10 = 100.0 * overlap as f64 / (k * nq) as f64;
                entries.push(Json::obj(vec![
                    ("precision", Json::Str(prec.name().to_string())),
                    ("mode", Json::Str(mode.to_string())),
                    ("kernel", Json::Str(kernel.to_string())),
                    ("k_codewords", Json::Num(kw as f64)),
                    ("rows", Json::Num(n as f64)),
                    ("code_bytes", Json::Num(m as f64)),
                    ("queries", Json::Num(nq as f64)),
                    ("k", Json::Num(k as f64)),
                    ("threads", Json::Num(threads as f64)),
                    ("shard_rows", Json::Num(shard_rows as f64)),
                    ("secs_per_batch", Json::Num(secs)),
                    ("vectors_per_sec",
                     Json::Num(vectors_per_iter as f64 / secs)),
                    ("speedup_vs_f32", Json::Num(f32_secs / secs)),
                    ("simd_speedup_vs_scalar",
                     Json::Num(scalar_secs / secs)),
                    ("recall10_vs_f32_pct", Json::Num(recall10)),
                ]));
            }
        }
    }
    // leave the process on normal dispatch for whatever runs next
    simd::set_force_scalar_for_bench(false);
    entries
}

/// IVF nprobe sweep on the synthetic set: scan-stage throughput and
/// recall@10 against the flat exhaustive engine at nprobe ∈ {1, 4, 16,
/// all} — the sub-linear trade-off record (acceptance: ≥ 4× throughput
/// at nprobe ≤ num_lists / 8).
fn ivf_nprobe_sweep(b: &mut Bench) -> Vec<Json> {
    let (n, num_lists, nq, n_train, kw) = if smoke() {
        (8_000usize, 16usize, 16usize, 4_000usize, 64usize)
    } else {
        (100_000, 64, 64, 20_000, 256)
    };
    let gen = Generator::new(Family::SiftLike, 203);
    let train = gen.generate(0, n_train);
    let base = gen.generate(1, n);
    let queries = gen.generate(2, nq);
    let pq = Pq::train(&train.data, train.dim, 8, kw, 0, 10);
    let coarse = CoarseQuantizer::train(&train.data, train.dim,
                                        num_lists, 0, 10);
    let ivf = IvfIndex::build(&pq, &base, coarse, false);
    let flat = CompressedIndex::build(&pq, &base);
    let qs: Vec<&[f32]> = (0..nq).map(|qi| queries.row(qi)).collect();
    let ks = vec![10usize; nq];

    // scan-stage only (no_rerank) isolates the sub-linear effect; the
    // flat reference runs through the same executor
    let mut cfg = SearchConfig {
        k: 10, no_rerank: true, num_threads: 4, shard_rows: 8192,
        ..Default::default()
    };
    let exec = Executor::new(cfg.num_threads);
    b.run(&format!("flat scan {nq}q n={n}"), (n * nq) as u64, || {
        SearchEngine::new(&pq, &flat, cfg).search_batch_on(&exec, &qs)
    });
    let flat_secs = b.results().last().expect("bench just ran").median();
    let flat_results =
        SearchEngine::new(&pq, &flat, cfg).search_batch_on(&exec, &qs);

    let mut entries = Vec::new();
    let mut nprobes = vec![1usize, 4, 16, num_lists];
    nprobes.retain(|&p| p <= num_lists);
    nprobes.dedup();
    for nprobe in nprobes {
        cfg.nprobe = nprobe;
        let req = unq::index::SearchRequest::from_config(&cfg, ks.clone());
        b.run(
            &format!("ivf scan {nq}q n={n} L={num_lists} nprobe={nprobe}"),
            (n * nq) as u64 * nprobe as u64 / num_lists as u64,
            || ivf.search_batch_on(&pq, &exec, &qs, &req)
                .expect("ivf batch plan"),
        );
        let secs = b.results().last().expect("bench just ran").median();
        let got = ivf.search_batch_on(&pq, &exec, &qs, &req)
            .expect("ivf batch plan");
        let overlap: usize = got
            .iter()
            .zip(&flat_results)
            .map(|(g, w)| g.iter().filter(|&id| w.contains(id)).count())
            .sum();
        let recall10 = 100.0 * overlap as f64 / (10 * nq) as f64;
        entries.push(Json::obj(vec![
            ("nprobe", Json::Num(nprobe as f64)),
            ("num_lists", Json::Num(num_lists as f64)),
            ("rows", Json::Num(n as f64)),
            ("queries", Json::Num(nq as f64)),
            ("threads", Json::Num(cfg.num_threads as f64)),
            ("secs_per_batch", Json::Num(secs)),
            ("queries_per_sec", Json::Num(nq as f64 / secs)),
            ("speedup_vs_flat", Json::Num(flat_secs / secs)),
            ("recall10_vs_flat_pct", Json::Num(recall10)),
        ]));
    }
    entries.push(Json::obj(vec![
        ("flat_secs_per_batch", Json::Num(flat_secs)),
        ("flat_queries_per_sec", Json::Num(nq as f64 / flat_secs)),
    ]));
    entries
}

fn main() {
    let cfg = AppConfig::default().apply_env();
    let mut b = Bench::e2e();
    if smoke() {
        // the CI smoke job reads BOTH trajectory files afterwards; emit
        // placeholder shapes up front so a panic or write failure in one
        // sweep can never leave the other file missing (smoke files are
        // disposable — measured `.json` files are never pre-clobbered)
        for (name, label) in [("BENCH_scan.json", "scan_suite"),
                              ("BENCH_ivf.json", "ivf_nprobe_sweep")] {
            let placeholder = Json::obj(vec![
                ("bench", Json::Str(label.into())),
                ("status", Json::Str("incomplete: smoke run died before \
                                      this sweep finished".into())),
            ]);
            write_report(name, &placeholder);
        }
    }
    if !smoke() {
        b.run("table1 complexity measurements", 1, || {
            if let Err(e) = table1_timings(&cfg) {
                eprintln!("table1 skipped: {e:#}");
            }
        });
        b.run("§4.4 timings", 1, || {
            if let Err(e) = table_timings(&cfg) {
                eprintln!("timings skipped: {e:#}");
            }
        });
    }

    // Batch executor scan throughput at 1/2/4/8 threads, plus the
    // scan-precision sweeps with scalar-vs-SIMD columns — one
    // BENCH_scan.json suite.  256-codeword LUTs drive f32/u16/u8; a
    // separate 16-codeword dataset (with its own f32 reference) drives
    // the u4 in-register path, so its recall@10 delta vs f32 is
    // apples-to-apples.
    let obs0 = obs::global().snapshot();
    let thread_entries = scan_thread_sweep(&mut b);
    let precision_entries = scan_precision_sweep(
        &mut b, 256,
        &[ScanPrecision::F32, ScanPrecision::U16, ScanPrecision::U8]);
    let u4_entries = scan_precision_sweep(
        &mut b, 16, &[ScanPrecision::F32, ScanPrecision::U4]);
    // the metrics-registry delta over the whole suite rides in the
    // report: rows scanned per precision, dispatch counts, exec task
    // latencies (rust/DESIGN.md §10)
    let obs_scan = obs::global().snapshot().delta(&obs0);
    let report = Json::obj(vec![
        ("bench", Json::Str("scan_suite".into())),
        ("simd_kernel", Json::Str(simd::active_name().to_string())),
        ("thread_sweep", Json::Arr(thread_entries)),
        ("precision_sweep", Json::Arr(precision_entries)),
        ("u4_sweep", Json::Arr(u4_entries)),
        ("obs", obs_scan.to_json()),
    ]);
    write_report("BENCH_scan.json", &report);

    // IVF nprobe throughput/recall sweep on the synthetic set.
    let obs1 = obs::global().snapshot();
    let entries = ivf_nprobe_sweep(&mut b);
    let report = Json::obj(vec![
        ("bench", Json::Str("ivf_nprobe_sweep".into())),
        ("results", Json::Arr(entries)),
        ("obs", obs::global().snapshot().delta(&obs1).to_json()),
    ]);
    write_report("BENCH_ivf.json", &report);

    if smoke() {
        println!("[timings] smoke mode: coordinator serving loop skipped");
        return;
    }

    // Coordinator serving loop (UNQ if artifacts exist, else PQ fallback),
    // driving the pooled batch executor end to end.
    let mut scfg = cfg.clone();
    scfg.dataset = "sift1m".into();
    scfg.quantizer = QuantizerKind::Unq;
    // default to a pooled serving loop, but let an explicit UNQ_THREADS
    // (already applied by apply_env) pick the inline path too
    if std::env::var("UNQ_THREADS").is_err() && scfg.serve.num_threads <= 1 {
        scfg.serve.num_threads = 2;
    }
    b.run("serving loop 500 queries", 500, || {
        if let Err(e) = run_serve(&scfg, 500) {
            eprintln!("serve(UNQ) skipped: {e:#}");
            let mut pq = scfg.clone();
            pq.quantizer = QuantizerKind::Pq;
            let _ = run_serve(&pq, 500);
        }
    });
}
