//! CI recall gate: run the harness at smoke sizes across
//! {f32, u16, u8} × {flat, ivf} at 64 codewords, u4 × {flat, ivf} on a
//! dedicated 16-codeword config (the only regime where the real 4-bit
//! nibble kernel runs — at 64 codewords u4 silently falls back to the
//! f32 path, so gating it there never exercised the kernel), the disk
//! IVF tier under a deliberately thrashing cache budget (+ the
//! streaming write path, + the natively trained UNQ across
//! {flat, ivf}), write the measured recall@10 to
//! `BENCH_recall.smoke.json`, and FAIL (non-zero exit) when
//!
//! * a combination drops more than `tolerance_pct` below the floor
//!   committed in `BENCH_baseline.json` (null floors are skipped with a
//!   warning — populate them from the smoke report's numbers once a
//!   toolchain has measured them), or
//! * an *exactness invariant* breaks — these need no baseline and gate
//!   every merge from the first CI run:
//!     - IVF at `nprobe = all` (non-residual) must equal the flat
//!       engine's recall exactly at f32 (bit-identical results);
//!     - the disk IVF tier must equal the RAM IVF backend exactly at
//!       every measured precision, even with a cache budget far below
//!       the probed working set (rust/DESIGN.md §11);
//!     - the streaming index over freshly inserted rows must equal the
//!       flat engine's recall exactly at f32 (same codes, same ids);
//!     - u16/u8/u4 must stay within the tolerance of their same-config
//!       f32 siblings (integer selection feeds the same exact d1
//!       rerank).
//!
//! Run: `cargo bench --bench recall_gate` (tiny fixed sizes; caches
//! land under `target/ci-gate/` so reruns are warm).

use std::path::{Path, PathBuf};

use unq::config::{AppConfig, QuantizerKind, ScanPrecision, SearchConfig,
                  StreamConfig};
use unq::eval::{harness, recall};
use unq::exec::Executor;
use unq::index::{Filter, SearchEngine};
use unq::util::json::Json;

fn repo_root(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join(name)
}

/// One measured cell of the gate grid.
struct Cell {
    key: &'static str,
    recall_at10: f64,
}

fn main() {
    // bracket the whole gate with registry snapshots: the delta rides in
    // the smoke report so CI artifacts carry rows-scanned / dispatch /
    // training counters next to the recall numbers (rust/DESIGN.md §10)
    let obs0 = unq::obs::global().snapshot();
    let mut cfg = AppConfig::default();
    cfg.dataset = "sift1m".into();
    cfg.quantizer = QuantizerKind::Pq;
    cfg.bytes_per_vector = 8;
    cfg.k_codewords = 64;
    cfg.scale = 0.02; // ~2000 base vectors: seconds, not minutes
    cfg.ivf.num_lists = 8;
    cfg.ivf.residual = false;
    cfg.data_dir = "target/ci-gate/data".into();
    cfg.runs_dir = "target/ci-gate/runs".into();
    cfg.artifacts_dir = "target/ci-gate/artifacts".into();

    let mut exp = match harness::prepare(&cfg, "") {
        Ok(e) => e,
        Err(e) => {
            eprintln!("[recall-gate] harness prepare failed: {e:#}");
            std::process::exit(1);
        }
    };
    // rerank on for every cell (the integer precisions are defined by
    // their exact-rescore contract; gate them through it)
    let search = SearchConfig { rerank_l: 100, k: 100,
                                ..Default::default() };
    let nprobe_real = 4usize;

    let mut cells: Vec<Cell> = Vec::new();

    // flat × {f32, u16, u8} at 64 codewords; u4 moved to the dedicated
    // 16-codeword config below where the real nibble kernel runs
    let flat_pts = exp.run_precision_sweep(
        search,
        &[ScanPrecision::F32, ScanPrecision::U16, ScanPrecision::U8]);
    for pt in &flat_pts {
        let key = match pt.precision {
            ScanPrecision::F32 => "flat_f32",
            ScanPrecision::U16 => "flat_u16",
            ScanPrecision::U8 => "flat_u8",
            ScanPrecision::U4 => unreachable!("u4 gated at 16 codewords"),
        };
        cells.push(Cell { key, recall_at10: pt.recall.at10 as f64 });
    }

    // ivf × {f32, u16, u8} at the realistic sub-linear nprobe, plus the
    // f32 nprobe=all exactness point
    let mut ivf = match harness::build_or_load_ivf(
        &cfg, exp.quant.as_ref(), &exp.splits.train, &exp.splits.base, "")
    {
        Ok(ivf) => ivf,
        Err(e) => {
            eprintln!("[recall-gate] ivf build failed: {e:#}");
            std::process::exit(1);
        }
    };
    ivf.ensure_packed();
    for (prec, key) in [(ScanPrecision::F32, "ivf_f32"),
                        (ScanPrecision::U16, "ivf_u16"),
                        (ScanPrecision::U8, "ivf_u8")] {
        let mut s = search;
        s.scan_precision = prec;
        s.nprobe = nprobe_real;
        let pt = exp.sweep_point(&ivf, s);
        cells.push(Cell { key, recall_at10: pt.recall.at10 as f64 });
    }
    let ivf_all = {
        let mut s = search;
        s.nprobe = 0; // all lists: bit-identical to flat (non-residual)
        exp.sweep_point(&ivf, s).recall.at10 as f64
    };

    // disk IVF tier (rust/DESIGN.md §11): the same coarse partition
    // served from the block archive through a deliberately thrashing
    // 1MB hot-list cache — every probed list pages through block I/O,
    // and the recall must still equal the RAM backend exactly
    let mut dcfg = cfg.clone();
    dcfg.ivf.cache_mb = 1;
    let disk = match harness::build_or_load_disk_ivf(
        &dcfg, exp.quant.as_ref(), &exp.splits.train, &exp.splits.base, "")
    {
        Ok(d) => d,
        Err(e) => {
            eprintln!("[recall-gate] disk ivf build failed: {e:#}");
            std::process::exit(1);
        }
    };
    for (prec, key) in [(ScanPrecision::F32, "disk_ivf_f32"),
                        (ScanPrecision::U8, "disk_ivf_u8")] {
        let mut s = search;
        s.scan_precision = prec;
        s.nprobe = nprobe_real;
        match exp.sweep_point_disk(&disk, s) {
            Ok(pt) => cells.push(Cell {
                key,
                recall_at10: pt.recall.at10 as f64,
            }),
            Err(e) => {
                eprintln!("[recall-gate] disk sweep ({key}) failed: {e:#}");
                std::process::exit(1);
            }
        }
    }

    // ≤16-codeword config: the only regime where the u4 scan runs its
    // real packed-nibble kernel (wider codebooks fall back to f32, see
    // rust/DESIGN.md §9).  Separate runs dir — the model cache path
    // does not encode k_codewords, and a 64-codeword model must not be
    // served to this config.  Same-config f32 cells ride along as the
    // u4 cells' sibling baselines.
    let mut cfg16 = cfg.clone();
    cfg16.k_codewords = 16;
    cfg16.runs_dir = "target/ci-gate/runs-k16".into();
    let mut exp16 = match harness::prepare(&cfg16, "") {
        Ok(e) => e,
        Err(e) => {
            eprintln!("[recall-gate] k16 harness prepare failed: {e:#}");
            std::process::exit(1);
        }
    };
    let pts16 = exp16.run_precision_sweep(
        search, &[ScanPrecision::F32, ScanPrecision::U4]);
    for pt in &pts16 {
        let key = match pt.precision {
            ScanPrecision::F32 => "flat_f32_k16",
            ScanPrecision::U4 => "flat_u4",
            _ => unreachable!("k16 sweep is f32 + u4 only"),
        };
        cells.push(Cell { key, recall_at10: pt.recall.at10 as f64 });
    }
    let mut ivf16 = match harness::build_or_load_ivf(
        &cfg16, exp16.quant.as_ref(), &exp16.splits.train,
        &exp16.splits.base, "")
    {
        Ok(ivf) => ivf,
        Err(e) => {
            eprintln!("[recall-gate] k16 ivf build failed: {e:#}");
            std::process::exit(1);
        }
    };
    ivf16.ensure_packed();
    for (prec, key) in [(ScanPrecision::F32, "ivf_f32_k16"),
                        (ScanPrecision::U4, "ivf_u4")] {
        let mut s = search;
        s.scan_precision = prec;
        s.nprobe = nprobe_real;
        let pt = exp16.sweep_point(&ivf16, s);
        cells.push(Cell { key, recall_at10: pt.recall.at10 as f64 });
    }

    // streaming write path: fresh inserts must serve flat-identical
    // results (ids 0..n in row order — recall needs no remap)
    let stream = match harness::stream_ingest(
        exp.quant.as_ref(), &exp.splits.base, None,
        StreamConfig { segment_rows: 512, ..Default::default() }, 300)
    {
        Ok(ix) => ix,
        Err(e) => {
            eprintln!("[recall-gate] stream ingest failed: {e:#}");
            std::process::exit(1);
        }
    };
    let exec = Executor::new(search.num_threads);
    let queries: Vec<&[f32]> = (0..exp.splits.query.len())
        .map(|qi| exp.splits.query.row(qi))
        .collect();
    let mut results = Vec::with_capacity(queries.len());
    for chunk in queries.chunks(128) {
        let req = unq::index::SearchRequest::from_config(
            &search, vec![search.k; chunk.len()]);
        results.extend(stream.search_batch_on(
            exp.quant.as_ref(), &exec, chunk, &req));
    }
    let stream_f32 = recall(&results, &exp.gt).at10 as f64;
    cells.push(Cell { key: "stream_f32", recall_at10: stream_f32 });

    // filtered search (rust/DESIGN.md §13): tag rows id % 2 and search
    // under tag=0.  The filtered true NN is the first *admitted*
    // committed neighbor, and it must surface in the filtered top-10;
    // the in-scan filter must never leak an inadmissible row (that one
    // is an exactness invariant, asserted inline).
    let tags: Vec<u64> = (0..exp.index.n as u64).map(|i| i % 2).collect();
    exp.index.set_tags(tags.clone());
    ivf.set_tags(tags);
    let mut fcfg = search;
    fcfg.filter = Some(Filter::TagEq(0));
    let filtered_recall10 =
        |results: &[Vec<u32>], gt: &unq::gt::GroundTruth| -> f64 {
            let mut hits = 0usize;
            for (qi, got) in results.iter().enumerate() {
                for &id in got {
                    assert_eq!(id % 2, 0,
                               "query {qi}: filtered search leaked \
                                inadmissible id {id}");
                }
                let Some(&nn) =
                    gt.neighbors[qi].iter().find(|&&id| id % 2 == 0)
                else {
                    continue;
                };
                hits += usize::from(
                    got.iter().take(10).any(|&id| id == nn as u32));
            }
            100.0 * hits as f64 / results.len().max(1) as f64
        };
    let flat_filtered = {
        let engine =
            SearchEngine::new(exp.quant.as_ref(), &exp.index, fcfg);
        let mut results = Vec::with_capacity(queries.len());
        for chunk in queries.chunks(128) {
            results.extend(engine.search_batch_on(&exec, chunk));
        }
        filtered_recall10(&results, &exp.gt)
    };
    cells.push(Cell { key: "flat_filtered", recall_at10: flat_filtered });
    let ivf_filtered = {
        let mut s = fcfg;
        s.nprobe = nprobe_real;
        let req = unq::index::SearchRequest::from_config(
            &s, vec![s.k; queries.len()]);
        let results = ivf
            .search_batch_on(exp.quant.as_ref(), &exec, &queries, &req)
            .expect("ivf filtered plan");
        filtered_recall10(&results, &exp.gt)
    };
    cells.push(Cell { key: "ivf_filtered", recall_at10: ivf_filtered });

    // native UNQ (pure-Rust trained, quant::unq_native): flat + ivf
    // recall@10 at the same smoke sizes, with a tiny training budget.
    // The model retrains EVERY run (its runs dir is wiped first):
    // CI restores target/ from the actions cache, and gating a stale
    // cached model would let a training regression slip through — the
    // deeper training-quality gate lives in train_smoke.rs.
    let mut ncfg = cfg.clone();
    ncfg.quantizer = QuantizerKind::UnqNative;
    ncfg.runs_dir = "target/ci-gate/runs-native".into();
    let _ = std::fs::remove_dir_all(&ncfg.runs_dir);
    ncfg.unq_native.hidden = 48;
    ncfg.unq_native.epochs = 6;
    ncfg.unq_native.batch = 128;
    ncfg.unq_native.seed = 7;
    let nexp = match harness::prepare(&ncfg, "") {
        Ok(e) => e,
        Err(e) => {
            eprintln!("[recall-gate] native-unq prepare failed: {e:#}");
            std::process::exit(1);
        }
    };
    let native_flat = nexp.run_recall(search).at10 as f64;
    cells.push(Cell { key: "unq_native_flat", recall_at10: native_flat });
    let nivf = match harness::build_or_load_ivf(
        &ncfg, nexp.quant.as_ref(), &nexp.splits.train, &nexp.splits.base,
        "")
    {
        Ok(ivf) => ivf,
        Err(e) => {
            eprintln!("[recall-gate] native-unq ivf build failed: {e:#}");
            std::process::exit(1);
        }
    };
    let native_ivf = {
        let mut s = search;
        s.nprobe = nprobe_real;
        nexp.sweep_point(&nivf, s).recall.at10 as f64
    };
    cells.push(Cell { key: "unq_native_ivf", recall_at10: native_ivf });

    // ---- write the smoke report (uploaded as a CI artifact) -------------
    let report = Json::obj(vec![
        ("bench", Json::Str("recall_gate".into())),
        ("dataset", Json::Str(cfg.dataset.clone())),
        ("quantizer", Json::Str(cfg.quantizer.name().into())),
        ("scale", Json::Num(cfg.scale)),
        ("rows", Json::Num(exp.index.n as f64)),
        ("queries", Json::Num(exp.splits.query.len() as f64)),
        ("num_lists", Json::Num(cfg.ivf.num_lists as f64)),
        ("nprobe", Json::Num(nprobe_real as f64)),
        ("ivf_all_f32_recall_at10", Json::Num(ivf_all)),
        ("recall_at10", Json::Obj(
            cells
                .iter()
                .map(|c| (c.key.to_string(), Json::Num(c.recall_at10)))
                .collect(),
        )),
        ("obs", unq::obs::global().snapshot().delta(&obs0).to_json()),
    ]);
    let out = repo_root("BENCH_recall.smoke.json");
    match std::fs::write(&out, report.render_pretty()) {
        Ok(()) => println!("[recall-gate] wrote {}", out.display()),
        Err(e) => {
            eprintln!("[recall-gate] cannot write {}: {e}", out.display());
            std::process::exit(1);
        }
    }

    // ---- gate ------------------------------------------------------------
    let mut failures: Vec<String> = Vec::new();
    let lookup = |key: &str| -> Option<f64> {
        cells.iter().find(|c| c.key == key).map(|c| c.recall_at10)
    };
    let get = |key: &str| -> f64 {
        lookup(key).expect("gate-internal keys are always measured")
    };

    let baseline_path = repo_root("BENCH_baseline.json");
    let tolerance = match std::fs::read_to_string(&baseline_path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
    {
        Some(b) => {
            let tol =
                b.get("tolerance_pct").and_then(Json::as_f64).unwrap_or(2.0);
            if let Some(Json::Obj(floors)) = b.get("recall_at10") {
                for (key, floor) in floors {
                    // a baseline key the gate does not measure is a
                    // config mistake, not a panic: surface and continue
                    let Some(got) = lookup(key) else {
                        failures.push(format!(
                            "baseline key {key:?} is not a measured gate \
                             cell — fix BENCH_baseline.json"));
                        continue;
                    };
                    let Some(floor) = floor.as_f64() else {
                        eprintln!(
                            "[recall-gate] no committed floor for \
                             {key:?} yet — measured {got:.2} (populate \
                             BENCH_baseline.json from the smoke report)");
                        continue;
                    };
                    if got + tol < floor {
                        failures.push(format!(
                            "{key}: recall@10 {got:.2} dropped more than \
                             {tol:.2} below the committed floor {floor:.2}"
                        ));
                    }
                }
            }
            tol
        }
        None => {
            failures.push(format!(
                "baseline file {} missing or unparsable",
                baseline_path.display()));
            2.0
        }
    };

    // exactness invariants (baseline-free)
    let flat_f32 = get("flat_f32");
    if (ivf_all - flat_f32).abs() > 1e-6 {
        failures.push(format!(
            "ivf nprobe=all f32 recall {ivf_all:.4} != flat {flat_f32:.4} \
             (must be bit-identical)"));
    }
    if (stream_f32 - flat_f32).abs() > 1e-6 {
        failures.push(format!(
            "streaming f32 recall {stream_f32:.4} != flat {flat_f32:.4} \
             (fresh inserts must be flat-identical)"));
    }
    // the disk tier's bit-identity contract: same results as the RAM
    // IVF backend at every precision, regardless of cache budget
    for (disk_key, ram_key) in [("disk_ivf_f32", "ivf_f32"),
                                ("disk_ivf_u8", "ivf_u8")] {
        let (d, r) = (get(disk_key), get(ram_key));
        if (d - r).abs() > 1e-6 {
            failures.push(format!(
                "{disk_key}: recall@10 {d:.4} != {ram_key} {r:.4} \
                 (disk tier must be bit-identical to RAM)"));
        }
    }
    // native UNQ sanity (baseline-free until its floors are measured):
    // both cells must sit far above chance (random R@10 ≈ 0.5 here)
    for key in ["unq_native_flat", "unq_native_ivf"] {
        let got = get(key);
        if got < 1.0 {
            failures.push(format!(
                "{key}: recall@10 {got:.2} is indistinguishable from \
                 random — native UNQ training collapsed"));
        }
    }
    for (int_key, base_key, slack) in [
        ("flat_u16", "flat_f32", tolerance),
        ("flat_u8", "flat_f32", 2.0 * tolerance),
        ("flat_u4", "flat_f32_k16", 2.0 * tolerance),
        ("ivf_u16", "ivf_f32", tolerance),
        ("ivf_u8", "ivf_f32", 2.0 * tolerance),
        ("ivf_u4", "ivf_f32_k16", 2.0 * tolerance),
    ] {
        let (got, base) = (get(int_key), get(base_key));
        if got + slack < base {
            failures.push(format!(
                "{int_key}: recall@10 {got:.2} fell more than {slack:.2} \
                 below its f32 sibling {base:.2}"));
        }
    }

    println!("[recall-gate] recall@10:");
    for c in &cells {
        println!("  {:<12} {:>6.2}", c.key, c.recall_at10);
    }
    println!("  {:<12} {:>6.2}", "ivf_all_f32", ivf_all);
    if failures.is_empty() {
        println!("[recall-gate] PASS");
    } else {
        for f in &failures {
            eprintln!("[recall-gate] FAIL: {f}");
        }
        std::process::exit(1);
    }
}
