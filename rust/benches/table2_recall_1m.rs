//! Bench: regenerate the paper's Table 2 (recall at 1M scale (sim: 100k)) and time the
//! end-to-end evaluation. Heavy models/codes are cached under runs/, so
//! the first invocation trains and later ones measure search only.
//!
//! Run: `cargo bench --bench table2_recall_1m`

use unq::config::AppConfig;
use unq::eval::tables::{recall_table, table2_methods};
use unq::util::bench::Bench;

fn main() {
    let cfg = AppConfig::default().apply_env();
    let mut b = Bench::e2e();
    let mut rendered = String::new();
    b.run("table2 full evaluation", 1, || {
        let t = recall_table("Table 2 — 1M scale (sim: 100k)", &cfg, "sift1m", "deep1m",
                             &table2_methods(), &[8, 16]);
        rendered = t.render();
    });
    println!("{rendered}");
}
