//! CI train-smoke gate: train a tiny [`NativeUnq`] **from scratch, in
//! pure Rust** on synthetic data, train an OPQ baseline on the same
//! split, and FAIL (non-zero exit) when the native model's recall@10
//! lands more than `tolerance` below OPQ's — the merge gate that keeps
//! the paper's headline quantizer actually trainable, not just
//! compilable.  The per-epoch loss curve and both recall triples go to
//! `BENCH_train.smoke.json` (uploaded as a workflow artifact).
//!
//! Sizes are smoke-tiny by default and env-tunable, `UNQ_BENCH_SMOKE`
//! style:
//!
//! * `UNQ_TRAIN_SMOKE_SCALE` — dataset scale multiplier (default 0.05 ≈
//!   5000 base/train vectors),
//! * `UNQ_NATIVE_EPOCHS` / `UNQ_NATIVE_HIDDEN` / `UNQ_NATIVE_BATCH` /
//!   `UNQ_NATIVE_LR` / `UNQ_NATIVE_SEED` — training caps,
//! * `UNQ_TRAIN_SMOKE_TOL` — recall@10 tolerance vs OPQ in percentage
//!   points (default 2.0, matching the recall-gate tolerance).
//!
//! Run: `cargo bench --bench train_smoke` (caches land under
//! `target/ci-train/` so reruns are warm for data/GT, while both models
//! always retrain — training determinism itself is under test).

use std::path::{Path, PathBuf};

use unq::config::{SearchConfig, UnqNativeConfig};
use unq::data;
use unq::eval::{recall, Recall};
use unq::index::{CompressedIndex, SearchEngine};
use unq::quant::{opq::Opq, unq_native::NativeUnq, Quantizer};
use unq::util::json::Json;

fn repo_root(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join(name)
}

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn measure_recall(q: &dyn Quantizer, splits: &data::Splits,
                  gt: &unq::gt::GroundTruth) -> Recall {
    let index = CompressedIndex::build(q, &splits.base);
    let search = SearchConfig { rerank_l: 100, k: 100,
                                ..Default::default() };
    let engine = SearchEngine::new(q, &index, search);
    let queries: Vec<&[f32]> = (0..splits.query.len())
        .map(|qi| splits.query.row(qi))
        .collect();
    let mut results = Vec::with_capacity(queries.len());
    for chunk in queries.chunks(128) {
        results.extend(engine.search_batch(chunk));
    }
    recall(&results, gt)
}

fn main() {
    let scale = env_f64("UNQ_TRAIN_SMOKE_SCALE", 0.05);
    let tol = env_f64("UNQ_TRAIN_SMOKE_TOL", 2.0);
    let (m, k) = (8usize, 64usize);
    let ncfg = UnqNativeConfig {
        hidden: env_usize("UNQ_NATIVE_HIDDEN", 64),
        epochs: env_usize("UNQ_NATIVE_EPOCHS", 10),
        batch: env_usize("UNQ_NATIVE_BATCH", 128),
        lr: env_f64("UNQ_NATIVE_LR", 1e-3) as f32,
        seed: env_usize("UNQ_NATIVE_SEED", 0) as u64,
        ..Default::default()
    };

    let data_dir = PathBuf::from("target/ci-train/data");
    let spec = data::spec_by_name("sift1m", scale).expect("catalog entry");
    let splits = match data::load_or_generate(&spec, &data_dir) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("[train-smoke] dataset generation failed: {e:#}");
            std::process::exit(1);
        }
    };
    let gt = match unq::gt::load_or_compute(&data_dir, &spec.name,
                                            &splits.base, &splits.query,
                                            100) {
        Ok(g) => g,
        Err(e) => {
            eprintln!("[train-smoke] ground truth failed: {e:#}");
            std::process::exit(1);
        }
    };
    let dim = splits.train.dim;
    println!(
        "[train-smoke] sift1m @ scale {scale}: train {} base {} query {} \
         (dim {dim}, {m}B, K={k})",
        splits.train.len(), splits.base.len(), splits.query.len()
    );

    // the gate's subject: native UNQ trained from scratch
    let t0 = std::time::Instant::now();
    let native = NativeUnq::train(&splits.train.data, dim, m, k, &ncfg);
    let native_secs = t0.elapsed().as_secs_f64();
    println!(
        "[train-smoke] trained NativeUnq ({} params, {} epochs) in {:.1}s",
        native.param_count(), ncfg.epochs, native_secs
    );

    // the baseline: OPQ with the harness's stock hyperparameters
    let t1 = std::time::Instant::now();
    let opq = Opq::train(&splits.train.data, dim, m, k, 0, 4, 10);
    let opq_secs = t1.elapsed().as_secs_f64();
    println!("[train-smoke] trained OPQ baseline in {:.1}s", opq_secs);

    let r_native = measure_recall(&native, &splits, &gt);
    let r_opq = measure_recall(&opq, &splits, &gt);
    println!(
        "[train-smoke] recall@10: unq-native {:.2} vs OPQ {:.2} \
         (tolerance {tol:.2})",
        r_native.at10, r_opq.at10
    );

    // ---- loss-curve report (uploaded as a CI artifact) ------------------
    let curve: Vec<Json> = native
        .history
        .iter()
        .map(|s| Json::obj(vec![
            ("epoch", Json::Num(s.epoch as f64)),
            ("tau", Json::Num(s.tau as f64)),
            ("rec_loss", Json::Num(s.rec_loss)),
            ("cons_loss", Json::Num(s.cons_loss)),
        ]))
        .collect();
    let triple = |r: &Recall| {
        Json::obj(vec![
            ("at1", Json::Num(r.at1 as f64)),
            ("at10", Json::Num(r.at10 as f64)),
            ("at100", Json::Num(r.at100 as f64)),
        ])
    };
    let report = Json::obj(vec![
        ("bench", Json::Str("train_smoke".into())),
        ("dataset", Json::Str(spec.name.clone())),
        ("scale", Json::Num(scale)),
        ("m", Json::Num(m as f64)),
        ("k", Json::Num(k as f64)),
        ("hidden", Json::Num(ncfg.hidden as f64)),
        ("epochs", Json::Num(ncfg.epochs as f64)),
        ("seed", Json::Num(ncfg.seed as f64)),
        ("tolerance_pct", Json::Num(tol)),
        ("native_train_secs", Json::Num(native_secs)),
        ("opq_train_secs", Json::Num(opq_secs)),
        ("loss_curve", Json::Arr(curve)),
        ("recall_unq_native", triple(&r_native)),
        ("recall_opq", triple(&r_opq)),
    ]);
    let out = repo_root("BENCH_train.smoke.json");
    match std::fs::write(&out, report.render_pretty()) {
        Ok(()) => println!("[train-smoke] wrote {}", out.display()),
        Err(e) => {
            eprintln!("[train-smoke] cannot write {}: {e}", out.display());
            std::process::exit(1);
        }
    }

    // ---- gate -----------------------------------------------------------
    let mut failures: Vec<String> = Vec::new();
    if (r_native.at10 as f64) + tol < r_opq.at10 as f64 {
        failures.push(format!(
            "native UNQ recall@10 {:.2} dropped more than {tol:.2} below \
             the OPQ baseline {:.2}",
            r_native.at10, r_opq.at10
        ));
    }
    // the loss curve must be finite and non-exploding: the last epoch's
    // reconstruction term may not exceed the first epoch's by 10%
    if let (Some(first), Some(last)) =
        (native.history.first(), native.history.last())
    {
        if !last.rec_loss.is_finite() || !first.rec_loss.is_finite() {
            failures.push("non-finite training loss".into());
        } else if last.rec_loss > first.rec_loss * 1.10 {
            failures.push(format!(
                "training diverged: rec loss {:.5} (first epoch) → {:.5} \
                 (last epoch)",
                first.rec_loss, last.rec_loss
            ));
        }
    } else {
        failures.push("empty loss curve (0 epochs trained?)".into());
    }
    // absolute sanity floor: far above chance (random R@10 of n base
    // rows ≈ 1000/n percent), far below anything a trained model scores
    if r_native.at10 < 5.0 {
        failures.push(format!(
            "native UNQ recall@10 {:.2} is below the 5.0 sanity floor",
            r_native.at10
        ));
    }
    if failures.is_empty() {
        println!("[train-smoke] PASS");
    } else {
        for f in &failures {
            eprintln!("[train-smoke] FAIL: {f}");
        }
        std::process::exit(1);
    }
}
