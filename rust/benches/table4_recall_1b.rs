//! Bench: regenerate the paper's Table 4 (recall at 1B scale (sim: 1M)) and time the
//! end-to-end evaluation. Heavy models/codes are cached under runs/, so
//! the first invocation trains and later ones measure search only.
//!
//! Run: `cargo bench --bench table4_recall_1b`

use unq::config::AppConfig;
use unq::eval::tables::{recall_table, table34_methods};
use unq::util::bench::Bench;

fn main() {
    let cfg = AppConfig::default().apply_env();
    let mut b = Bench::e2e();
    let mut rendered = String::new();
    b.run("table4 full evaluation", 1, || {
        let t = recall_table("Table 4 — 1B scale (sim: 1M)", &cfg, "sift1b", "deep1b",
                             &table34_methods(), &[8, 16]);
        rendered = t.render();
    });
    println!("{rendered}");
}
