//! Bench: the 1B-scale (simulated) serving regime on the DISK tier —
//! the Table-4 scale point rebuilt on `ivf::disk::DiskIvfIndex`
//! (rust/DESIGN.md §11).  A synthetic corpus far larger than the
//! hot-list cache budget is archived once, then served round after
//! round so admissions, hits, and CLOCK evictions all mix while we
//! measure recall@10, QPS, and the cache hit-rate.  Results are also
//! cross-checked for exact equality against the RAM `IvfIndex` — the
//! tier's bit-identity contract, asserted at bench scale.
//!
//! Writes `BENCH_1b.json` at the repo root (the trajectory record).
//!
//! Run: `cargo bench --bench table4_recall_1b`
//!
//! `UNQ_BENCH_SMOKE=1` caps sizes to seconds and writes
//! `BENCH_1b.smoke.json` instead (never clobbering measured numbers).

use std::path::PathBuf;

use unq::config::SearchConfig;
use unq::data::{synthetic::Generator, Family};
use unq::eval::recall;
use unq::exec::Executor;
use unq::ivf::disk::DiskIvfIndex;
use unq::ivf::{CoarseQuantizer, IvfIndex};
use unq::obs;
use unq::quant::pq::Pq;
use unq::util::bench::Bench;
use unq::util::json::Json;

fn smoke() -> bool {
    std::env::var("UNQ_BENCH_SMOKE").map(|v| v != "0" && !v.is_empty())
        .unwrap_or(false)
}

fn repo_root_path(name: &str) -> PathBuf {
    let name = if smoke() {
        name.replace(".json", ".smoke.json")
    } else {
        name.to_string()
    };
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join(name)
}

fn main() {
    // honest scale: 1M rows of 8B codes archive to ~8MB, and each
    // fetched list roughly doubles resident (packed mirror), so the
    // 4MB budget can never hold the working set — every round pages
    let (n, n_train, nq, num_lists, kw, cache_bytes, nprobe, rounds) =
        if smoke() {
            (20_000usize, 4_000usize, 16usize, 16usize, 64usize,
             32usize << 10, 4usize, 3usize)
        } else {
            (1_000_000, 50_000, 64, 256, 256, 4 << 20, 8, 3)
        };
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("target/bench-1b");
    std::fs::create_dir_all(&dir).expect("create bench dir");

    let gen = Generator::new(Family::SiftLike, 411);
    let train = gen.generate(0, n_train);
    let base = gen.generate(1, n);
    let queries = gen.generate(2, nq);
    let gt = unq::gt::load_or_compute(&dir, "bench1b", &base, &queries, 10)
        .expect("ground truth");

    let mut b = Bench::e2e();
    let pq = Pq::train(&train.data, train.dim, 8, kw, 0, 10);
    let coarse =
        CoarseQuantizer::train(&train.data, train.dim, num_lists, 0, 10);
    let ivf = IvfIndex::build(&pq, &base, coarse, false);
    let archive = dir.join(format!("disk_ivf_n{n}_L{num_lists}.blocks"));
    if !archive.exists() {
        DiskIvfIndex::save_archive(&ivf, &archive).expect("write archive");
    }
    let archive_bytes =
        std::fs::metadata(&archive).map(|m| m.len()).unwrap_or(0);
    let disk = DiskIvfIndex::open(&archive, cache_bytes).expect("open");

    let cfg = SearchConfig {
        rerank_l: 100, k: 10, nprobe, num_threads: 4, shard_rows: 8192,
        ..Default::default()
    };
    let exec = Executor::new(cfg.num_threads);
    let qs: Vec<&[f32]> = (0..nq).map(|qi| queries.row(qi)).collect();
    let req = unq::index::SearchRequest::from_config(&cfg,
                                                     vec![cfg.k; nq]);

    // the bit-identity contract at bench scale: one full batch on each
    // tier must agree exactly
    let want = ivf.search_batch_on(&pq, &exec, &qs, &req)
        .expect("ram search");
    let got = disk
        .search_batch_on(&pq, &exec, &qs, &req)
        .expect("disk search");
    let ram_equal = got == want;
    assert!(ram_equal, "disk tier diverged from the RAM IvfIndex");
    let rec = recall(&got, &gt);

    // measured rounds: cache state carries across rounds, so round 1
    // is the cold sweep and later rounds mix hits with evictions
    let mut round_entries = Vec::new();
    for round in 0..rounds {
        let obs0 = obs::global().snapshot();
        b.run(
            &format!("disk-ivf {nq}q n={n} L={num_lists} nprobe={nprobe} \
                      cache={}KB round={round}", cache_bytes >> 10),
            nq as u64,
            || {
                disk.search_batch_on(&pq, &exec, &qs, &req)
                    .expect("disk search")
            },
        );
        let secs = b.results().last().expect("bench just ran").median();
        let d = obs::global().snapshot().delta(&obs0);
        let (h, m) = (d.counter("cache.hits"), d.counter("cache.misses"));
        round_entries.push(Json::obj(vec![
            ("round", Json::Num(round as f64)),
            ("secs_per_batch", Json::Num(secs)),
            ("queries_per_sec", Json::Num(nq as f64 / secs)),
            ("cache_hits", Json::Num(h as f64)),
            ("cache_misses", Json::Num(m as f64)),
            ("cache_hit_rate_pct",
             Json::Num(100.0 * h as f64 / (h + m).max(1) as f64)),
            ("cache_evictions",
             Json::Num(d.counter("cache.evictions") as f64)),
        ]));
    }

    let report = Json::obj(vec![
        ("bench", Json::Str("disk_ivf_1b".into())),
        ("rows", Json::Num(n as f64)),
        ("dim", Json::Num(base.dim as f64)),
        ("queries", Json::Num(nq as f64)),
        ("num_lists", Json::Num(num_lists as f64)),
        ("nprobe", Json::Num(nprobe as f64)),
        ("k_codewords", Json::Num(kw as f64)),
        ("threads", Json::Num(cfg.num_threads as f64)),
        ("cache_bytes", Json::Num(cache_bytes as f64)),
        ("archive_bytes", Json::Num(archive_bytes as f64)),
        ("cache_bytes_resident",
         Json::Num(disk.cache_bytes_resident() as f64)),
        ("recall_at10", Json::Num(rec.at10 as f64)),
        ("ram_identical", Json::Bool(ram_equal)),
        ("rounds", Json::Arr(round_entries)),
    ]);
    let out = repo_root_path("BENCH_1b.json");
    match std::fs::write(&out, report.render_pretty()) {
        Ok(()) => println!("[1b] wrote {}", out.display()),
        Err(e) => eprintln!("[1b] {} not written: {e}", out.display()),
    }
    println!(
        "[1b] disk-ivf n={n} L={num_lists} nprobe={nprobe} \
         cache={}KB: R@10 {:.1}, archive {:.1}MB, ram-identical {}",
        cache_bytes >> 10, rec.at10, archive_bytes as f64 / 1e6, ram_equal
    );
}
