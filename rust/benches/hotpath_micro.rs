//! Hot-path micro-benchmarks (rust/DESIGN.md §2): the ADC scan, top-k
//! selection, LUT construction, rerank, and the batch-first search
//! engine — the components whose sum is the paper's §4.4 search cost.
//!
//! Run: `cargo bench --bench hotpath_micro`

use unq::config::SearchConfig;
use unq::data::{synthetic::Generator, Family};
use unq::exec::Executor;
use unq::index::{scan_topk, CompressedIndex, SearchEngine};
use unq::linalg::TopK;
use unq::quant::{pq::Pq, Lut, Quantizer};
use unq::util::bench::Bench;
use unq::util::rng::SplitMix64;

fn main() {
    let mut b = Bench::default();

    // --- raw ADC scan: n × m LUT adds, the innermost loop -------------
    for (n, m) in [(100_000usize, 8usize), (100_000, 16), (1_000_000, 8)] {
        let mut rng = SplitMix64::new(1);
        let codes: Vec<u8> = (0..n * m).map(|_| rng.below(256) as u8).collect();
        let index = CompressedIndex::from_codes(n, m, codes);
        let tables: Vec<f32> = (0..m * 256).map(|_| rng.next_f32()).collect();
        let lut = Lut::Tables { m, k: 256, tables, bias: 0.0 };
        b.run(&format!("adc_scan n={n} m={m} top500"), n as u64, || {
            scan_topk(&lut, &index, 500)
        });
    }

    // --- top-k push throughput ----------------------------------------
    {
        let mut rng = SplitMix64::new(2);
        let scores: Vec<f32> = (0..1_000_000).map(|_| rng.next_f32()).collect();
        b.run("topk_push 1M → 100", scores.len() as u64, || {
            let mut t = TopK::new(100);
            for (i, &s) in scores.iter().enumerate() {
                t.push(s, i as u32);
            }
            t.into_sorted()
        });
    }

    // --- LUT construction (PQ: m·k subspace distances) -----------------
    {
        let gen = Generator::new(Family::SiftLike, 3);
        let train = gen.generate(0, 4000);
        let pq = Pq::train(&train.data, train.dim, 8, 256, 0, 8);
        let q = gen.generate(2, 1);
        b.run("pq_lut_build m=8 k=256", (8 * 256) as u64, || pq.lut(q.row(0)));
    }

    // --- rerank: decode + exact distance for 500 candidates -----------
    {
        let gen = Generator::new(Family::SiftLike, 4);
        let train = gen.generate(0, 4000);
        let base = gen.generate(1, 20_000);
        let pq = Pq::train(&train.data, train.dim, 8, 256, 0, 8);
        let index = CompressedIndex::build(&pq, &base);
        let q = gen.generate(2, 1);
        let cands: Vec<u32> = (0..500u32).collect();
        let engine = unq::index::SearchEngine::new(
            &pq, &index, unq::config::SearchConfig::default());
        b.run("rerank 500 candidates (PQ decode)", 500, || {
            engine.rerank(q.row(0), &cands, 100)
        });
    }

    // --- batch-first engine: QueryBatch × IndexShard execution ---------
    {
        let gen = Generator::new(Family::SiftLike, 6);
        let train = gen.generate(0, 4000);
        let base = gen.generate(1, 100_000);
        let pq = Pq::train(&train.data, train.dim, 8, 256, 0, 8);
        let index = CompressedIndex::build(&pq, &base);
        let queries = gen.generate(2, 64);
        let qrefs: Vec<&[f32]> =
            (0..queries.len()).map(|qi| queries.row(qi)).collect();
        for threads in [1usize, 2, 4] {
            let cfg = SearchConfig {
                rerank_l: 100, k: 10, num_threads: threads,
                shard_rows: 16_384, ..Default::default()
            };
            let engine = SearchEngine::new(&pq, &index, cfg);
            let exec = Executor::new(threads);
            b.run(
                &format!("search_batch 64q n=100k threads={threads}"),
                queries.len() as u64,
                || engine.search_batch_on(&exec, &qrefs),
            );
        }
    }

    // --- lattice direct scan (the non-LUT path) ------------------------
    {
        let gen = Generator::new(Family::DeepLike, 5);
        let train = gen.generate(0, 3000);
        let base = gen.generate(1, 100_000);
        let lat = unq::quant::lattice::CatalystLattice::train(
            &train.data, train.dim, 8);
        let index = CompressedIndex::build(&lat, &base);
        let lut = lat.lut(gen.generate(2, 1).row(0));
        b.run("lattice_direct_scan n=100k d_out=24", index.n as u64, || {
            scan_topk(&lut, &index, 500)
        });
    }
}
