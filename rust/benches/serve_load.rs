//! Bench: the network front door under load (rust/DESIGN.md §12).
//!
//! Spawns the full serving stack in-process — PQ over a streaming
//! backend, coordinator, TCP reactor on a loopback port — then drives
//! it with the in-tree load generator in both modes: a closed loop
//! (latency below saturation) and an open loop at a fixed arrival rate
//! (latency including queueing, measured from the scheduled departure
//! so coordinated omission is charged to the server).  Traffic is
//! mixed search + single-row insert, exercising admission, pipelined
//! out-of-order completion, and the ingest path end to end.
//!
//! Writes `BENCH_serve.json` at the repo root (QPS + p50/p99/p999 per
//! mode, plus the `net.*` counter delta).
//!
//! Run: `cargo bench --bench serve_load`
//!
//! `UNQ_BENCH_SMOKE=1` caps sizes to seconds and writes
//! `BENCH_serve.smoke.json` instead (never clobbering measured
//! numbers).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use unq::config::{NetConfig, SearchConfig, ServeConfig, StreamConfig};
use unq::coordinator::pipeline::Server;
use unq::data::{synthetic::Generator, Family};
use unq::index::StreamingIndex;
use unq::ivf::IndexBackend;
use unq::net::loadgen::{self, LoadMode, LoadgenConfig};
use unq::net::NetServer;
use unq::obs;
use unq::quant::pq::Pq;
use unq::util::json::Json;

fn smoke() -> bool {
    std::env::var("UNQ_BENCH_SMOKE").map(|v| v != "0" && !v.is_empty())
        .unwrap_or(false)
}

fn repo_root_path(name: &str) -> PathBuf {
    let name = if smoke() {
        name.replace(".json", ".smoke.json")
    } else {
        name.to_string()
    };
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join(name)
}

fn main() {
    let (n, n_train, clients, secs, open_rate) = if smoke() {
        (6_000usize, 2_000usize, 2usize, 2u64, 150.0f64)
    } else {
        (100_000, 20_000, 8, 10, 2_000.0)
    };
    let k = 10u32;
    let insert_pct = 10u32;

    // serving stack: PQ over a streaming backend (accepts the load
    // generator's insert mix), coordinator, TCP reactor on port 0
    let gen = Generator::new(Family::SiftLike, 907);
    let train = gen.generate(0, n_train);
    let base = gen.generate(1, n);
    let pq = Pq::train(&train.data, train.dim, 8, 64, 0, 8);
    let ix = Arc::new(StreamingIndex::new(
        8, None, StreamConfig { segment_rows: 8_192, ..Default::default() }));
    for lo in (0..base.len()).step_by(8_192) {
        let hi = (lo + 8_192).min(base.len());
        ix.insert_batch(&pq, base.rows(lo, hi)).expect("seed insert");
    }
    let search = SearchConfig { rerank_l: 64, k: 10, ..Default::default() };
    let serve = ServeConfig {
        max_batch: 16, max_delay_us: 200, queue_depth: 256,
        num_threads: 2, shard_rows: 4_096,
    };
    let server = Arc::new(Server::start_with_backend(
        Arc::new(pq), IndexBackend::Streaming(ix), search, serve));
    let net_cfg = NetConfig {
        listen: "127.0.0.1:0".into(),
        io_threads: 2,
        ..Default::default()
    };
    let net = NetServer::start(server.clone(), net_cfg)
        .expect("bind loopback");
    let addr = net.local_addr().to_string();
    println!("[serve-load] serving {n} rows (dim {}) at {addr}", base.dim);

    let obs0 = obs::global().snapshot();
    let mut runs = Vec::new();

    // closed loop: throughput self-limits to the server's pace
    let closed = loadgen::run(&LoadgenConfig {
        addr: addr.clone(),
        clients,
        duration: Duration::from_secs(secs),
        mode: LoadMode::Closed,
        insert_pct,
        k,
        family: Family::SiftLike,
        seed: 4_201,
        ..Default::default()
    })
    .expect("closed-loop run");
    closed.print();
    assert!(closed.ok > 0, "closed loop completed nothing");
    assert_eq!(closed.errors, 0, "closed loop saw hard errors");
    runs.push(closed.to_json());

    // open loop: fixed arrival rate, latency from scheduled departure
    let open = loadgen::run(&LoadgenConfig {
        addr: addr.clone(),
        clients,
        duration: Duration::from_secs(secs),
        mode: LoadMode::Open { rate_qps: open_rate },
        insert_pct,
        k,
        family: Family::SiftLike,
        seed: 4_202,
        ..Default::default()
    })
    .expect("open-loop run");
    open.print();
    assert!(open.ok > 0, "open loop completed nothing");
    let mut open_json = open.to_json();
    if let Json::Obj(kv) = &mut open_json {
        kv.push(("rate_qps".to_string(), Json::Num(open_rate)));
    }
    runs.push(open_json);

    let d = obs::global().snapshot().delta(&obs0);
    let hist_q = |q: f64| {
        d.hist("net.request_us").map_or(0, |h| h.quantile_us(q))
    };
    let report = Json::obj(vec![
        ("bench", Json::Str("serve_load".into())),
        ("status", Json::Str("measured".into())),
        ("dataset", Json::Str("synthetic-sift-like".into())),
        ("rows", Json::Num(n as f64)),
        ("dim", Json::Num(base.dim as f64)),
        ("k", Json::Num(k as f64)),
        ("clients", Json::Num(clients as f64)),
        ("duration_secs", Json::Num(secs as f64)),
        ("insert_pct", Json::Num(insert_pct as f64)),
        ("runs", Json::Arr(runs)),
        ("net", Json::obj(vec![
            ("connections", Json::Num(d.counter("net.connections") as f64)),
            ("requests", Json::Num(d.counter("net.requests") as f64)),
            ("responses", Json::Num(d.counter("net.responses") as f64)),
            ("overloaded", Json::Num(d.counter("net.overloaded") as f64)),
            ("quota_rejected",
             Json::Num(d.counter("net.quota_rejected") as f64)),
            ("frame_errors",
             Json::Num(d.counter("net.frame_errors") as f64)),
            ("bytes_in", Json::Num(d.counter("net.bytes_in") as f64)),
            ("bytes_out", Json::Num(d.counter("net.bytes_out") as f64)),
            ("request_p50_us", Json::Num(hist_q(0.50) as f64)),
            ("request_p99_us", Json::Num(hist_q(0.99) as f64)),
        ])),
    ]);
    let out = repo_root_path("BENCH_serve.json");
    match std::fs::write(&out, report.render_pretty()) {
        Ok(()) => println!("[serve-load] wrote {}", out.display()),
        Err(e) => eprintln!("[serve-load] {} not written: {e}",
                            out.display()),
    }

    net.shutdown();
    if let Ok(s) = Arc::try_unwrap(server) {
        s.shutdown();
    }
}
