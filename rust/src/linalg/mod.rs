//! Dense linear algebra substrate.
//!
//! Everything the shallow quantizers and the ground-truth engine need,
//! implemented natively (no BLAS dependency): squared-L2 / dot kernels
//! written to autovectorize, a blocked GEMM, Jacobi eigendecomposition of
//! symmetric matrices (powers PCA whitening and the OPQ Procrustes step),
//! and branch-light bounded top-k selection used by every scan.

mod topk;

pub use topk::TopK;

/// Squared Euclidean distance between two equal-length slices.
///
/// Written as a single fused loop over `f32`; LLVM autovectorizes this to
/// SIMD on x86-64 (the GT engine and reranker both sit on it).
#[inline]
pub fn sq_l2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc0 = 0.0f32;
    let mut acc1 = 0.0f32;
    let mut acc2 = 0.0f32;
    let mut acc3 = 0.0f32;
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        let d0 = a[j] - b[j];
        let d1 = a[j + 1] - b[j + 1];
        let d2 = a[j + 2] - b[j + 2];
        let d3 = a[j + 3] - b[j + 3];
        acc0 += d0 * d0;
        acc1 += d1 * d1;
        acc2 += d2 * d2;
        acc3 += d3 * d3;
    }
    for j in chunks * 4..a.len() {
        let d = a[j] - b[j];
        acc0 += d * d;
    }
    (acc0 + acc1) + (acc2 + acc3)
}

/// Dot product with 4-way unrolled accumulators.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc0 = 0.0f32;
    let mut acc1 = 0.0f32;
    let mut acc2 = 0.0f32;
    let mut acc3 = 0.0f32;
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc0 += a[j] * b[j];
        acc1 += a[j + 1] * b[j + 1];
        acc2 += a[j + 2] * b[j + 2];
        acc3 += a[j + 3] * b[j + 3];
    }
    for j in chunks * 4..a.len() {
        acc0 += a[j] * b[j];
    }
    (acc0 + acc1) + (acc2 + acc3)
}

/// Euclidean norm.
#[inline]
pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * *xi;
    }
}

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "Mat data length mismatch");
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// `self @ other` via an i-k-j loop (cache friendly, autovectorizes the
    /// inner j loop). Fine at the D ≤ a-few-hundred sizes we use.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            let out_row = &mut out.data[i * other.cols..(i + 1) * other.cols];
            for k in 0..self.cols {
                let a = self.data[i * self.cols + k];
                if a != 0.0 {
                    let b_row = &other.data[k * other.cols..(k + 1) * other.cols];
                    axpy(a, b_row, out_row);
                }
            }
        }
        out
    }

    /// Apply to a single vector: `self @ x`.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        (0..self.rows).map(|i| dot(self.row(i), x)).collect()
    }

    /// Frobenius norm of `self - other`.
    pub fn frob_dist(&self, other: &Mat) -> f32 {
        sq_l2(&self.data, &other.data).sqrt()
    }
}

/// Jacobi eigendecomposition of a symmetric matrix.
///
/// Returns `(eigenvalues, eigenvectors)` with eigenvalues sorted in
/// descending order and eigenvectors as *rows* of the returned matrix
/// (i.e. `v.row(i)` is the unit eigenvector of `eigenvalues[i]`).
/// Cyclic Jacobi with threshold sweeping — O(n³) per sweep, robust and
/// dependency-free; plenty fast for n ≤ 256 (our descriptor dims).
pub fn jacobi_eigen(a: &Mat, max_sweeps: usize) -> (Vec<f32>, Mat) {
    assert_eq!(a.rows, a.cols, "jacobi_eigen needs a square matrix");
    let n = a.rows;
    let mut m = a.clone();
    let mut v = Mat::eye(n);

    for _sweep in 0..max_sweeps {
        // off-diagonal Frobenius mass
        let mut off = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                off += (m.get(i, j) as f64).powi(2);
            }
        }
        if off.sqrt() < 1e-9 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m.get(p, q);
                if apq.abs() < 1e-12 {
                    continue;
                }
                let app = m.get(p, p);
                let aqq = m.get(q, q);
                let theta = 0.5 * (aqq - app) as f64 / apq as f64;
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    -1.0 / (-theta + (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                let (c, s) = (c as f32, s as f32);
                // rotate rows/cols p, q of m
                for k in 0..n {
                    let mkp = m.get(k, p);
                    let mkq = m.get(k, q);
                    m.set(k, p, c * mkp - s * mkq);
                    m.set(k, q, s * mkp + c * mkq);
                }
                for k in 0..n {
                    let mpk = m.get(p, k);
                    let mqk = m.get(q, k);
                    m.set(p, k, c * mpk - s * mqk);
                    m.set(q, k, s * mpk + c * mqk);
                }
                // accumulate eigenvectors (as rows of v)
                for k in 0..n {
                    let vpk = v.get(p, k);
                    let vqk = v.get(q, k);
                    v.set(p, k, c * vpk - s * vqk);
                    v.set(q, k, s * vpk + c * vqk);
                }
            }
        }
    }

    let mut eig: Vec<(f32, usize)> =
        (0..n).map(|i| (m.get(i, i), i)).collect();
    eig.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let vals: Vec<f32> = eig.iter().map(|&(l, _)| l).collect();
    let mut vecs = Mat::zeros(n, n);
    for (r, &(_, i)) in eig.iter().enumerate() {
        vecs.row_mut(r).copy_from_slice(v.row(i));
    }
    (vals, vecs)
}

/// Orthogonal Procrustes: the rotation `R` (d×d, row-major) minimizing
/// `‖X R - Y‖_F` over orthogonal matrices, given `C = Xᵀ Y`.
///
/// `R = U Vᵀ` for the SVD `C = U Σ Vᵀ`; computed here via two symmetric
/// Jacobi eigendecompositions (`CᵀC = V Σ² Vᵀ`, `U = C V Σ⁻¹`), which is
/// accurate enough for the well-conditioned covariance-like matrices OPQ
/// produces.
pub fn procrustes(c: &Mat) -> Mat {
    assert_eq!(c.rows, c.cols);
    let n = c.rows;
    let ctc = c.transpose().matmul(c);
    let (vals, vecs_rows) = jacobi_eigen(&ctc, 50); // rows are eigenvectors
    // V: columns = eigenvectors → V = vecs_rows^T
    let v = vecs_rows.transpose();
    // U = C V Σ^{-1}, with rank-deficient columns repaired afterwards:
    // directions with σ ≈ 0 are unconstrained by the data, so any choice
    // completing U to an orthogonal matrix is optimal.
    let cv = c.matmul(&v);
    let sigma_max = vals[0].max(0.0).sqrt().max(1e-20);
    let mut u = Mat::zeros(n, n);
    let mut degenerate = Vec::new();
    for j in 0..n {
        let sigma = vals[j].max(0.0).sqrt();
        if sigma > 1e-6 * sigma_max {
            for i in 0..n {
                u.set(i, j, cv.get(i, j) / sigma);
            }
        } else {
            degenerate.push(j);
        }
    }
    // Modified Gram–Schmidt over columns; degenerate columns get filled
    // from the canonical basis and orthogonalized.
    let mut basis_cursor = 0usize;
    for j in 0..n {
        if degenerate.contains(&j) {
            // seed with the next canonical vector
            for i in 0..n {
                u.set(i, j, 0.0);
            }
            u.set(basis_cursor % n, j, 1.0);
            basis_cursor += 1;
        }
        // orthogonalize against previous columns (twice for stability)
        for _pass in 0..2 {
            for p in 0..j {
                let mut proj = 0.0f32;
                for i in 0..n {
                    proj += u.get(i, j) * u.get(i, p);
                }
                for i in 0..n {
                    let v2 = u.get(i, j) - proj * u.get(i, p);
                    u.set(i, j, v2);
                }
            }
        }
        let mut nrm = 0.0f32;
        for i in 0..n {
            nrm += u.get(i, j) * u.get(i, j);
        }
        let nrm = nrm.sqrt();
        if nrm < 1e-6 {
            // fully degenerate after projection: pick a fresh basis vector
            for i in 0..n {
                u.set(i, j, 0.0);
            }
            u.set(basis_cursor % n, j, 1.0);
            basis_cursor += 1;
            // re-orthogonalize once
            for p in 0..j {
                let mut proj = 0.0f32;
                for i in 0..n {
                    proj += u.get(i, j) * u.get(i, p);
                }
                for i in 0..n {
                    let v2 = u.get(i, j) - proj * u.get(i, p);
                    u.set(i, j, v2);
                }
            }
            let mut n2 = 0.0f32;
            for i in 0..n {
                n2 += u.get(i, j) * u.get(i, j);
            }
            let n2 = n2.sqrt().max(1e-12);
            for i in 0..n {
                u.set(i, j, u.get(i, j) / n2);
            }
        } else {
            for i in 0..n {
                u.set(i, j, u.get(i, j) / nrm);
            }
        }
    }
    u.matmul(&v.transpose())
}

/// Solve `A X = B` for SPD `A` (n×n, flat row-major, **destroyed**) and
/// multi-column `B` (n×d, flat row-major). Returns `X` (n×d) or `None` if
/// the Cholesky factorization hits a non-positive pivot.
///
/// Used by the LSQ codebook update where `A = BᵀB + λI` (code
/// co-occurrence) and `B = BᵀX`; n = m·k can reach a few thousand, so the
/// inner loops are written over contiguous rows.
pub fn cholesky_solve_multi(a: &mut [f32], n: usize, b: &[f32], d: usize)
                            -> Option<Vec<f32>> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n * d);
    // In-place lower Cholesky: A = L Lᵀ (row-major, L in the lower part).
    for i in 0..n {
        for j in 0..=i {
            // dot of row i and row j over [0, j)
            let mut sum = 0.0f64;
            let (ri, rj) = (i * n, j * n);
            for t in 0..j {
                sum += a[ri + t] as f64 * a[rj + t] as f64;
            }
            if i == j {
                let diag = a[ri + i] as f64 - sum;
                if diag <= 0.0 {
                    return None;
                }
                a[ri + i] = diag.sqrt() as f32;
            } else {
                a[ri + j] = ((a[ri + j] as f64 - sum) / a[rj + j] as f64) as f32;
            }
        }
    }
    // Forward substitution: L Y = B
    let mut x = b.to_vec();
    for i in 0..n {
        let ri = i * n;
        for t in 0..i {
            let l = a[ri + t];
            if l != 0.0 {
                let (head, tail) = x.split_at_mut(i * d);
                let yi = &mut tail[..d];
                let yt = &head[t * d..(t + 1) * d];
                for (y, v) in yi.iter_mut().zip(yt) {
                    *y -= l * v;
                }
            }
        }
        let inv = 1.0 / a[ri + i];
        for y in &mut x[i * d..(i + 1) * d] {
            *y *= inv;
        }
    }
    // Back substitution: Lᵀ X = Y
    for i in (0..n).rev() {
        for t in (i + 1)..n {
            let l = a[t * n + i]; // Lᵀ[i][t] = L[t][i]
            if l != 0.0 {
                let (head, tail) = x.split_at_mut(t * d);
                let xi = &mut head[i * d..(i + 1) * d];
                let xt = &tail[..d];
                for (y, v) in xi.iter_mut().zip(xt) {
                    *y -= l * v;
                }
            }
        }
        let inv = 1.0 / a[i * n + i];
        for y in &mut x[i * d..(i + 1) * d] {
            *y *= inv;
        }
    }
    Some(x)
}

/// Mean of a set of row vectors stored flat.
pub fn mean_rows(data: &[f32], dim: usize) -> Vec<f32> {
    let n = data.len() / dim;
    let mut mu = vec![0.0f32; dim];
    for r in 0..n {
        axpy(1.0, &data[r * dim..(r + 1) * dim], &mut mu);
    }
    let inv = 1.0 / n.max(1) as f32;
    mu.iter_mut().for_each(|m| *m *= inv);
    mu
}

/// Covariance matrix (biased) of rows stored flat.
pub fn covariance(data: &[f32], dim: usize) -> Mat {
    let n = data.len() / dim;
    let mu = mean_rows(data, dim);
    let mut cov = Mat::zeros(dim, dim);
    let mut centered = vec![0.0f32; dim];
    for r in 0..n {
        let row = &data[r * dim..(r + 1) * dim];
        for j in 0..dim {
            centered[j] = row[j] - mu[j];
        }
        for i in 0..dim {
            let ci = centered[i];
            if ci != 0.0 {
                axpy(ci, &centered, cov.row_mut(i));
            }
        }
    }
    let inv = 1.0 / n.max(1) as f32;
    cov.data.iter_mut().for_each(|v| *v *= inv);
    cov
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: f32, b: f32, tol: f32) {
        assert!((a - b).abs() < tol, "{a} vs {b}");
    }

    #[test]
    fn sq_l2_matches_naive() {
        let a: Vec<f32> = (0..37).map(|i| i as f32 * 0.3).collect();
        let b: Vec<f32> = (0..37).map(|i| (i as f32).sin()).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
        approx(sq_l2(&a, &b), naive, 1e-3);
    }

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..101).map(|i| (i as f32).cos()).collect();
        let b: Vec<f32> = (0..101).map(|i| (i as f32 * 0.7).sin()).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        approx(dot(&a, &b), naive, 1e-3);
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_rows(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let i3 = Mat::eye(3);
        assert_eq!(a.matmul(&i3), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(2, 2, vec![1., 2., 3., 4.]);
        let b = Mat::from_rows(2, 2, vec![5., 6., 7., 8.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Mat::from_rows(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn jacobi_recovers_diagonal() {
        let mut a = Mat::zeros(4, 4);
        for (i, v) in [4.0, 3.0, 2.0, 1.0].iter().enumerate() {
            a.set(i, i, *v);
        }
        let (vals, _) = jacobi_eigen(&a, 30);
        for (got, want) in vals.iter().zip([4.0, 3.0, 2.0, 1.0]) {
            approx(*got, want, 1e-5);
        }
    }

    #[test]
    fn jacobi_reconstructs_symmetric() {
        // A = Q Λ Qᵀ reconstruction check on a random symmetric matrix.
        let n = 8;
        let mut a = Mat::zeros(n, n);
        let mut seed = 1u64;
        let mut rnd = || {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
            ((seed >> 33) as f32 / (1u64 << 31) as f32) - 0.5
        };
        for i in 0..n {
            for j in i..n {
                let v = rnd();
                a.set(i, j, v);
                a.set(j, i, v);
            }
        }
        let (vals, vecs) = jacobi_eigen(&a, 50);
        // rebuild: sum_i λ_i v_i v_iᵀ
        let mut rec = Mat::zeros(n, n);
        for i in 0..n {
            let v = vecs.row(i);
            for r in 0..n {
                for c in 0..n {
                    rec.data[r * n + c] += vals[i] * v[r] * v[c];
                }
            }
        }
        assert!(a.frob_dist(&rec) < 1e-3, "dist {}", a.frob_dist(&rec));
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let mut a = Mat::zeros(5, 5);
        for i in 0..5 {
            for j in 0..5 {
                a.set(i, j, 1.0 / (1.0 + (i + j) as f32));
            }
        }
        let (_, vecs) = jacobi_eigen(&a, 50);
        for i in 0..5 {
            for j in 0..5 {
                let d = dot(vecs.row(i), vecs.row(j));
                approx(d, if i == j { 1.0 } else { 0.0 }, 1e-4);
            }
        }
    }

    #[test]
    fn cholesky_solves_spd_system() {
        // A = M Mᵀ + I (SPD), random-ish M
        let n = 6;
        let d = 3;
        let mut seed = 5u64;
        let mut rnd = || {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
            ((seed >> 33) as f32 / (1u64 << 31) as f32) - 0.5
        };
        let m = Mat::from_rows(n, n, (0..n * n).map(|_| rnd()).collect());
        let mut a = m.matmul(&m.transpose());
        for i in 0..n {
            a.data[i * n + i] += 1.0;
        }
        let x_true: Vec<f32> = (0..n * d).map(|_| rnd()).collect();
        // B = A X
        let xm = Mat::from_rows(n, d, x_true.clone());
        let b = a.matmul(&xm);
        let mut a_work = a.data.clone();
        let x = cholesky_solve_multi(&mut a_work, n, &b.data, d).unwrap();
        for (got, want) in x.iter().zip(&x_true) {
            assert!((got - want).abs() < 1e-3, "{got} vs {want}");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = vec![1.0, 0.0, 0.0, -1.0]; // indefinite
        assert!(cholesky_solve_multi(&mut a, 2, &[1.0, 1.0], 1).is_none());
    }

    #[test]
    fn procrustes_recovers_rotation() {
        // Y = X R for a known rotation; C = XᵀY should give back R.
        let theta = 0.6f32;
        let r = Mat::from_rows(
            2, 2, vec![theta.cos(), -theta.sin(), theta.sin(), theta.cos()]);
        // X: some full-rank point set
        let x = Mat::from_rows(4, 2, vec![1., 0., 0., 1., 2., 1., -1., 3.]);
        let y = x.matmul(&r);
        let c = x.transpose().matmul(&y);
        let got = procrustes(&c);
        assert!(got.frob_dist(&r) < 1e-3, "dist {}", got.frob_dist(&r));
    }

    #[test]
    fn covariance_of_isotropic_cloud() {
        // two points symmetric about the origin along x
        let data = vec![1.0f32, 0.0, -1.0, 0.0];
        let cov = covariance(&data, 2);
        approx(cov.get(0, 0), 1.0, 1e-6);
        approx(cov.get(1, 1), 0.0, 1e-6);
        approx(cov.get(0, 1), 0.0, 1e-6);
    }

    #[test]
    fn mean_rows_simple() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0];
        assert_eq!(mean_rows(&data, 2), vec![2.0, 3.0]);
    }
}
