//! Bounded top-k selection (smallest distances win).
//!
//! A fixed-capacity binary max-heap keyed on the **lexicographic
//! `(distance, id)` total order**: the root is the *worst* retained
//! candidate, so the scan hot loop is a single branch (`d < root`) in
//! the common reject case.  Ordering ties by id — not by arrival — makes
//! every bounded selection *decomposition-invariant by construction*:
//! pushing the same multiset in any order (full scan, per-shard scans
//! merged in any interleaving, per-list IVF parts) retains exactly the
//! same `k` pairs.  Used by the ADC scan, the shard/list merges, the
//! ground-truth engine and the reranker.

/// Strict "worse than" under the lexicographic `(distance, id)` order —
/// the heap key.  Equal distances rank the larger id worse, so among
/// score ties the smallest ids always survive, matching the ascending-id
/// tie-break of a sequential scan regardless of push order.
#[inline]
fn worse(a: (f32, u32), b: (f32, u32)) -> bool {
    a.0 > b.0 || (a.0 == b.0 && a.1 > b.1)
}

/// Fixed-capacity top-k accumulator over `(distance, id)` pairs.
///
/// Keeps the `k` smallest pairs under `(distance, id)`; `push` is
/// O(log k) only when the candidate beats the current worst, O(1)
/// otherwise.
#[derive(Clone, Debug)]
pub struct TopK {
    k: usize,
    /// max-heap on `(distance, id)`: `heap[0]` is the worst retained pair.
    heap: Vec<(f32, u32)>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "TopK requires k > 0");
        TopK { k, heap: Vec::with_capacity(k) }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Current worst retained distance (`+inf` until the heap is full, so
    /// the hot-loop test `d < worst()` admits everything at the start).
    #[inline]
    pub fn worst(&self) -> f32 {
        if self.heap.len() < self.k {
            f32::INFINITY
        } else {
            self.heap[0].0
        }
    }

    /// Offer a candidate.
    #[inline]
    pub fn push(&mut self, dist: f32, id: u32) {
        if self.heap.len() < self.k {
            self.heap.push((dist, id));
            self.sift_up(self.heap.len() - 1);
        } else if worse(self.heap[0], (dist, id)) {
            self.heap[0] = (dist, id);
            self.sift_down(0);
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if worse(self.heap[i], self.heap[parent]) {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let l = 2 * i + 1;
            let r = 2 * i + 2;
            let mut largest = i;
            if l < n && worse(self.heap[l], self.heap[largest]) {
                largest = l;
            }
            if r < n && worse(self.heap[r], self.heap[largest]) {
                largest = r;
            }
            if largest == i {
                break;
            }
            self.heap.swap(i, largest);
            i = largest;
        }
    }

    /// Consume into `(distance, id)` pairs sorted ascending by distance
    /// (ties broken by id for determinism).
    pub fn into_sorted(mut self) -> Vec<(f32, u32)> {
        self.heap.sort_by(|a, b| {
            a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
        });
        self.heap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_k_smallest() {
        let mut t = TopK::new(3);
        for (i, d) in [5.0, 1.0, 4.0, 2.0, 3.0, 0.5].iter().enumerate() {
            t.push(*d, i as u32);
        }
        let out = t.into_sorted();
        assert_eq!(out.iter().map(|p| p.1).collect::<Vec<_>>(), vec![5, 1, 3]);
        assert_eq!(out[0].0, 0.5);
    }

    #[test]
    fn fewer_than_k() {
        let mut t = TopK::new(10);
        t.push(2.0, 7);
        t.push(1.0, 9);
        let out = t.into_sorted();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0], (1.0, 9));
    }

    #[test]
    fn worst_is_infinity_until_full() {
        let mut t = TopK::new(2);
        assert_eq!(t.worst(), f32::INFINITY);
        t.push(3.0, 0);
        assert_eq!(t.worst(), f32::INFINITY);
        t.push(1.0, 1);
        assert_eq!(t.worst(), 3.0);
        t.push(0.5, 2);
        assert_eq!(t.worst(), 1.0);
    }

    #[test]
    fn deterministic_tie_break() {
        let mut t = TopK::new(2);
        t.push(1.0, 5);
        t.push(1.0, 3);
        t.push(1.0, 4);
        // among equal distances the smallest ids win, regardless of
        // arrival order (the lexicographic (distance, id) heap order)
        assert_eq!(t.into_sorted(), vec![(1.0, 3), (1.0, 4)]);
    }

    #[test]
    fn ties_at_boundary_are_push_order_invariant() {
        // the decomposition-invariance contract: any permutation of the
        // same candidate multiset retains exactly the same pairs, even
        // with score ties straddling the k-th boundary
        let base: Vec<(f32, u32)> = vec![
            (2.0, 9), (1.0, 7), (1.0, 2), (1.0, 5), (0.5, 1), (2.0, 0),
            (1.0, 3),
        ];
        let mut want: Option<Vec<(f32, u32)>> = None;
        // a few deterministic permutations (rotations + reversal)
        for rot in 0..base.len() {
            for rev in [false, true] {
                let mut perm = base.clone();
                perm.rotate_left(rot);
                if rev {
                    perm.reverse();
                }
                let mut t = TopK::new(4);
                for (d, id) in perm {
                    t.push(d, id);
                }
                let got = t.into_sorted();
                match &want {
                    None => {
                        assert_eq!(got,
                                   vec![(0.5, 1), (1.0, 2), (1.0, 3),
                                        (1.0, 5)]);
                        want = Some(got);
                    }
                    Some(w) => assert_eq!(&got, w, "rot={rot} rev={rev}"),
                }
            }
        }
    }

    #[test]
    fn matches_full_sort_reference() {
        // pseudo-random stream; compare against sort-based selection
        let mut seed = 42u64;
        let mut rnd = || {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
            (seed >> 33) as f32 / (1u64 << 31) as f32
        };
        let data: Vec<f32> = (0..1000).map(|_| rnd()).collect();
        let mut t = TopK::new(25);
        for (i, d) in data.iter().enumerate() {
            t.push(*d, i as u32);
        }
        let got: Vec<u32> = t.into_sorted().iter().map(|p| p.1).collect();
        let mut pairs: Vec<(f32, u32)> =
            data.iter().enumerate().map(|(i, d)| (*d, i as u32)).collect();
        pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        let want: Vec<u32> = pairs[..25].iter().map(|p| p.1).collect();
        assert_eq!(got, want);
    }
}
