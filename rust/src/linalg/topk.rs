//! Bounded top-k selection (smallest distances win).
//!
//! A fixed-capacity binary max-heap keyed on distance: the root is the
//! *worst* retained candidate, so the scan hot loop is a single branch
//! (`d < root`) in the common reject case.  Used by the ADC scan, the
//! ground-truth engine and the reranker.

/// Fixed-capacity top-k accumulator over `(distance, id)` pairs.
///
/// Keeps the `k` smallest distances seen; `push` is O(log k) only when the
/// candidate beats the current worst, O(1) otherwise.
#[derive(Clone, Debug)]
pub struct TopK {
    k: usize,
    /// max-heap on distance: `heap[0]` is the worst retained pair.
    heap: Vec<(f32, u32)>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "TopK requires k > 0");
        TopK { k, heap: Vec::with_capacity(k) }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Current worst retained distance (`+inf` until the heap is full, so
    /// the hot-loop test `d < worst()` admits everything at the start).
    #[inline]
    pub fn worst(&self) -> f32 {
        if self.heap.len() < self.k {
            f32::INFINITY
        } else {
            self.heap[0].0
        }
    }

    /// Offer a candidate.
    #[inline]
    pub fn push(&mut self, dist: f32, id: u32) {
        if self.heap.len() < self.k {
            self.heap.push((dist, id));
            self.sift_up(self.heap.len() - 1);
        } else if dist < self.heap[0].0 {
            self.heap[0] = (dist, id);
            self.sift_down(0);
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.heap[i].0 > self.heap[parent].0 {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let l = 2 * i + 1;
            let r = 2 * i + 2;
            let mut largest = i;
            if l < n && self.heap[l].0 > self.heap[largest].0 {
                largest = l;
            }
            if r < n && self.heap[r].0 > self.heap[largest].0 {
                largest = r;
            }
            if largest == i {
                break;
            }
            self.heap.swap(i, largest);
            i = largest;
        }
    }

    /// Consume into `(distance, id)` pairs sorted ascending by distance
    /// (ties broken by id for determinism).
    pub fn into_sorted(mut self) -> Vec<(f32, u32)> {
        self.heap.sort_by(|a, b| {
            a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
        });
        self.heap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_k_smallest() {
        let mut t = TopK::new(3);
        for (i, d) in [5.0, 1.0, 4.0, 2.0, 3.0, 0.5].iter().enumerate() {
            t.push(*d, i as u32);
        }
        let out = t.into_sorted();
        assert_eq!(out.iter().map(|p| p.1).collect::<Vec<_>>(), vec![5, 1, 3]);
        assert_eq!(out[0].0, 0.5);
    }

    #[test]
    fn fewer_than_k() {
        let mut t = TopK::new(10);
        t.push(2.0, 7);
        t.push(1.0, 9);
        let out = t.into_sorted();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0], (1.0, 9));
    }

    #[test]
    fn worst_is_infinity_until_full() {
        let mut t = TopK::new(2);
        assert_eq!(t.worst(), f32::INFINITY);
        t.push(3.0, 0);
        assert_eq!(t.worst(), f32::INFINITY);
        t.push(1.0, 1);
        assert_eq!(t.worst(), 3.0);
        t.push(0.5, 2);
        assert_eq!(t.worst(), 1.0);
    }

    #[test]
    fn deterministic_tie_break() {
        let mut t = TopK::new(2);
        t.push(1.0, 5);
        t.push(1.0, 3);
        t.push(1.0, 4);
        let out = t.into_sorted();
        // among equal distances the smallest ids win deterministically in
        // sorted output ordering
        assert_eq!(out[0].0, 1.0);
        assert!(out[0].1 <= out[1].1);
    }

    #[test]
    fn matches_full_sort_reference() {
        // pseudo-random stream; compare against sort-based selection
        let mut seed = 42u64;
        let mut rnd = || {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
            (seed >> 33) as f32 / (1u64 << 31) as f32
        };
        let data: Vec<f32> = (0..1000).map(|_| rnd()).collect();
        let mut t = TopK::new(25);
        for (i, d) in data.iter().enumerate() {
            t.push(*d, i as u32);
        }
        let got: Vec<u32> = t.into_sorted().iter().map(|p| p.1).collect();
        let mut pairs: Vec<(f32, u32)> =
            data.iter().enumerate().map(|(i, d)| (*d, i as u32)).collect();
        pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        let want: Vec<u32> = pairs[..25].iter().map(|p| p.1).collect();
        assert_eq!(got, want);
    }
}
