//! PJRT runtime: load and execute the AOT artifacts from the Rust hot path.
//!
//! `python/compile/aot.py` leaves, per model configuration, a directory
//! `artifacts/<name>/` containing `manifest.json` plus three HLO-text
//! graphs with the trained weights baked in as dense literals:
//!
//! * `encode.hlo.txt` — `f32[E, D] → s32[E, M]` hard codes (eq. 4),
//! * `lut.hlo.txt`    — `f32[Q, D] → f32[Q, M, K]` per-query dot tables,
//! * `decode.hlo.txt` — `s32[B, M] → f32[B, D]` reconstructions.
//!
//! This module compiles them once on the PJRT CPU client
//! (`HloModuleProto::from_text_file` → `XlaComputation` → `compile`) and
//! serves execution requests.  The `xla` crate's handles wrap raw
//! pointers and are `!Send`, so a dedicated **runtime thread** owns the
//! client and executables; [`RuntimeHandle`] is the cheap, cloneable,
//! `Send + Sync` front door the quantizer and the serving coordinator
//! use.  Fixed AOT batch shapes are honored by padding inside the thread.

use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::Arc;

use anyhow::{anyhow, Context};

use crate::util::json::Json;
use crate::Result;

// All builds currently compile against the in-tree API stub (this image
// ships no PJRT library); the `pjrt` feature marks the seam where the
// real `xla` bindings plug in — see xla_stub.rs.
mod xla_stub;
use self::xla_stub as xla;

#[cfg(feature = "pjrt")]
compile_error!(
    "feature `pjrt` is a wiring placeholder: add the real `xla` crate to \
     rust/Cargo.toml, replace the `use self::xla_stub as xla` alias above \
     with the extern crate, and remove this guard (src/runtime/xla_stub.rs \
     documents the API surface the bindings must provide)"
);

/// Parsed `manifest.json` of one artifact bundle.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub dataset: String,
    pub variant: String,
    pub dim: usize,
    pub m: usize,
    pub k: usize,
    pub dc: usize,
    pub hidden: usize,
    pub encode_batch: usize,
    pub lut_batch: usize,
    pub decode_batch: usize,
    pub param_count: usize,
    pub param_bytes: usize,
    pub files: ManifestFiles,
    pub dir: PathBuf,
}

#[derive(Clone, Debug)]
pub struct ManifestFiles {
    pub encode: String,
    pub lut: String,
    pub decode: String,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} (run `make artifacts`)"))?;
        let j = Json::parse(&text).with_context(|| format!("parse {path:?}"))?;
        let files = j.get("files").ok_or_else(|| anyhow!("manifest missing files"))?;
        Ok(Manifest {
            name: j.req_str("name")?.to_string(),
            dataset: j.req_str("dataset")?.to_string(),
            variant: j.get("variant").and_then(Json::as_str)
                .unwrap_or("unq").to_string(),
            dim: j.req_usize("dim")?,
            m: j.req_usize("m")?,
            k: j.req_usize("k")?,
            dc: j.get("dc").and_then(Json::as_usize).unwrap_or(0),
            hidden: j.get("hidden").and_then(Json::as_usize).unwrap_or(0),
            encode_batch: j.req_usize("encode_batch")?,
            lut_batch: j.req_usize("lut_batch")?,
            decode_batch: j.req_usize("decode_batch")?,
            param_count: j.get("param_count").and_then(Json::as_usize).unwrap_or(0),
            param_bytes: j.get("param_bytes").and_then(Json::as_usize).unwrap_or(0),
            files: ManifestFiles {
                encode: files.req_str("encode")?.to_string(),
                lut: files.req_str("lut")?.to_string(),
                decode: files.req_str("decode")?.to_string(),
            },
            dir: dir.to_path_buf(),
        })
    }
}

enum Job {
    /// rows of f32[dim] → codes (i32 flattened, rows × m)
    Encode { data: Vec<f32>, rows: usize, resp: mpsc::SyncSender<Result<Vec<i32>>> },
    /// rows of f32[dim] → luts (f32, rows × m × k)
    Lut { data: Vec<f32>, rows: usize, resp: mpsc::SyncSender<Result<Vec<f32>>> },
    /// rows of i32[m] codes → reconstructions (f32, rows × dim)
    Decode { codes: Vec<i32>, rows: usize, resp: mpsc::SyncSender<Result<Vec<f32>>> },
    /// orderly shutdown (also triggered by channel disconnect)
    Stop,
}

/// Cheap cloneable handle to the runtime thread.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: mpsc::Sender<Job>,
    pub manifest: Arc<Manifest>,
}

/// The runtime thread plus its handle; dropping this joins the thread.
pub struct UnqRuntime {
    pub handle: RuntimeHandle,
    thread: Option<std::thread::JoinHandle<()>>,
    stop_tx: mpsc::Sender<Job>,
}

impl UnqRuntime {
    /// Load an artifact bundle and spin up its runtime thread.
    pub fn load(artifact_dir: &Path) -> Result<UnqRuntime> {
        let manifest = Arc::new(Manifest::load(artifact_dir)?);
        let (tx, rx) = mpsc::channel::<Job>();
        // compile errors must surface at load time: report over a channel
        let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<()>>(1);
        let m2 = manifest.clone();
        let thread = std::thread::Builder::new()
            .name(format!("unq-runtime-{}", manifest.name))
            .spawn(move || runtime_main(m2, rx, ready_tx))
            .context("spawn runtime thread")?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("runtime thread died during startup"))??;
        Ok(UnqRuntime {
            handle: RuntimeHandle { tx: tx.clone(), manifest },
            thread: Some(thread),
            stop_tx: tx,
        })
    }
}

impl Drop for UnqRuntime {
    fn drop(&mut self) {
        let _ = self.stop_tx.send(Job::Stop);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl RuntimeHandle {
    fn roundtrip<T>(&self, mk: impl FnOnce(mpsc::SyncSender<Result<T>>) -> Job)
                    -> Result<T> {
        let (resp_tx, resp_rx) = mpsc::sync_channel(1);
        self.tx
            .send(mk(resp_tx))
            .map_err(|_| anyhow!("runtime thread is gone"))?;
        resp_rx.recv().map_err(|_| anyhow!("runtime thread dropped reply"))?
    }

    /// Encode `rows` vectors (flat `rows × dim`) into `rows × m` byte codes.
    pub fn encode(&self, data: &[f32], rows: usize) -> Result<Vec<u8>> {
        assert_eq!(data.len(), rows * self.manifest.dim);
        let codes = self.roundtrip(|resp| Job::Encode {
            data: data.to_vec(), rows, resp,
        })?;
        Ok(codes.into_iter().map(|c| c as u8).collect())
    }

    /// LUT for `rows` queries: `rows × m × k` raw dot products
    /// ⟨net(q)_m, c_mk⟩ (larger = closer; the quantizer negates).
    pub fn lut(&self, queries: &[f32], rows: usize) -> Result<Vec<f32>> {
        assert_eq!(queries.len(), rows * self.manifest.dim);
        self.roundtrip(|resp| Job::Lut { data: queries.to_vec(), rows, resp })
    }

    /// Decode `rows` codes (flat `rows × m`, byte values) to `rows × dim`.
    pub fn decode(&self, codes: &[u8], rows: usize) -> Result<Vec<f32>> {
        assert_eq!(codes.len(), rows * self.manifest.m);
        let icodes: Vec<i32> = codes.iter().map(|&c| c as i32).collect();
        self.roundtrip(|resp| Job::Decode { codes: icodes, rows, resp })
    }
}

// ---------------------------------------------------------------------------
// runtime thread internals
// ---------------------------------------------------------------------------

struct Graphs {
    encode: xla::PjRtLoadedExecutable,
    lut: xla::PjRtLoadedExecutable,
    decode: xla::PjRtLoadedExecutable,
}

fn compile_graph(client: &xla::PjRtClient, path: &Path)
                 -> Result<xla::PjRtLoadedExecutable> {
    let path_str = path
        .to_str()
        .ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?;
    let proto = xla::HloModuleProto::from_text_file(path_str)
        .map_err(|e| anyhow!("parse HLO {path:?}: {e:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow!("compile {path:?}: {e:?}"))
}

fn runtime_main(manifest: Arc<Manifest>, rx: mpsc::Receiver<Job>,
                ready_tx: mpsc::SyncSender<Result<()>>) {
    let setup = (|| -> Result<(xla::PjRtClient, Graphs)> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
        let graphs = Graphs {
            encode: compile_graph(&client, &manifest.dir.join(&manifest.files.encode))?,
            lut: compile_graph(&client, &manifest.dir.join(&manifest.files.lut))?,
            decode: compile_graph(&client, &manifest.dir.join(&manifest.files.decode))?,
        };
        Ok((client, graphs))
    })();
    let graphs = match setup {
        Ok((_client, graphs)) => {
            let _ = ready_tx.send(Ok(()));
            graphs
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };

    while let Ok(job) = rx.recv() {
        match job {
            Job::Stop => break,
            Job::Encode { data, rows, resp } => {
                let r = run_f32_to_i32(&graphs.encode, &data, rows,
                                       manifest.dim, manifest.encode_batch,
                                       manifest.m);
                let _ = resp.send(r);
            }
            Job::Lut { data, rows, resp } => {
                let r = run_f32_to_f32(&graphs.lut, &data, rows, manifest.dim,
                                       manifest.lut_batch,
                                       manifest.m * manifest.k);
                let _ = resp.send(r);
            }
            Job::Decode { codes, rows, resp } => {
                let r = run_i32_to_f32(&graphs.decode, &codes, rows,
                                       manifest.m, manifest.decode_batch,
                                       manifest.dim);
                let _ = resp.send(r);
            }
        }
    }
}

/// Run a fixed-batch `f32[B, in] → s32[B, out]` graph over `rows` rows,
/// padding the tail chunk.
fn run_f32_to_i32(exe: &xla::PjRtLoadedExecutable, data: &[f32], rows: usize,
                  d_in: usize, batch: usize, d_out: usize) -> Result<Vec<i32>> {
    let mut out = Vec::with_capacity(rows * d_out);
    let mut chunk = vec![0.0f32; batch * d_in];
    let mut lo = 0usize;
    while lo < rows {
        let hi = (lo + batch).min(rows);
        let nrows = hi - lo;
        chunk[..nrows * d_in].copy_from_slice(&data[lo * d_in..hi * d_in]);
        chunk[nrows * d_in..].iter_mut().for_each(|v| *v = 0.0);
        let lit = xla::Literal::vec1(&chunk)
            .reshape(&[batch as i64, d_in as i64])
            .map_err(|e| anyhow!("reshape: {e:?}"))?;
        let result = exe.execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let vals = result.to_tuple1()
            .map_err(|e| anyhow!("tuple: {e:?}"))?
            .to_vec::<i32>()
            .map_err(|e| anyhow!("to_vec<i32>: {e:?}"))?;
        out.extend_from_slice(&vals[..nrows * d_out]);
        lo = hi;
    }
    Ok(out)
}

fn run_f32_to_f32(exe: &xla::PjRtLoadedExecutable, data: &[f32], rows: usize,
                  d_in: usize, batch: usize, d_out: usize) -> Result<Vec<f32>> {
    let mut out = Vec::with_capacity(rows * d_out);
    let mut chunk = vec![0.0f32; batch * d_in];
    let mut lo = 0usize;
    while lo < rows {
        let hi = (lo + batch).min(rows);
        let nrows = hi - lo;
        chunk[..nrows * d_in].copy_from_slice(&data[lo * d_in..hi * d_in]);
        chunk[nrows * d_in..].iter_mut().for_each(|v| *v = 0.0);
        let lit = xla::Literal::vec1(&chunk)
            .reshape(&[batch as i64, d_in as i64])
            .map_err(|e| anyhow!("reshape: {e:?}"))?;
        let result = exe.execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let vals = result.to_tuple1()
            .map_err(|e| anyhow!("tuple: {e:?}"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("to_vec<f32>: {e:?}"))?;
        out.extend_from_slice(&vals[..nrows * d_out]);
        lo = hi;
    }
    Ok(out)
}

fn run_i32_to_f32(exe: &xla::PjRtLoadedExecutable, data: &[i32], rows: usize,
                  d_in: usize, batch: usize, d_out: usize) -> Result<Vec<f32>> {
    let mut out = Vec::with_capacity(rows * d_out);
    let mut chunk = vec![0i32; batch * d_in];
    let mut lo = 0usize;
    while lo < rows {
        let hi = (lo + batch).min(rows);
        let nrows = hi - lo;
        chunk[..nrows * d_in].copy_from_slice(&data[lo * d_in..hi * d_in]);
        chunk[nrows * d_in..].iter_mut().for_each(|v| *v = 0);
        let lit = xla::Literal::vec1(&chunk)
            .reshape(&[batch as i64, d_in as i64])
            .map_err(|e| anyhow!("reshape: {e:?}"))?;
        let result = exe.execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let vals = result.to_tuple1()
            .map_err(|e| anyhow!("tuple: {e:?}"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("to_vec<f32>: {e:?}"))?;
        out.extend_from_slice(&vals[..nrows * d_out]);
        lo = hi;
    }
    Ok(out)
}

/// List available artifact bundles under an artifacts root.
pub fn list_artifacts(root: &Path) -> Vec<String> {
    let mut names = Vec::new();
    if let Ok(entries) = std::fs::read_dir(root) {
        for e in entries.flatten() {
            if e.path().join("manifest.json").exists() {
                if let Some(n) = e.file_name().to_str() {
                    names.push(n.to_string());
                }
            }
        }
    }
    names.sort();
    names
}

/// Helper for tests/benches: locate an artifact dir, returning `None`
/// (instead of an error) when artifacts have not been built.
pub fn find_artifact(root: &Path, name: &str) -> Option<PathBuf> {
    let dir = root.join(name);
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TempDir;

    #[test]
    fn manifest_parses_aot_format() {
        let dir = TempDir::new("manifest").unwrap();
        std::fs::write(dir.path().join("manifest.json"), r#"{
            "name": "t", "dataset": "sift1m", "variant": "unq",
            "dim": 128, "m": 8, "k": 256, "dc": 128, "hidden": 256,
            "encode_batch": 512, "lut_batch": 16, "decode_batch": 512,
            "param_count": 1000, "param_bytes": 4000,
            "files": {"encode": "e.hlo.txt", "lut": "l.hlo.txt",
                      "decode": "d.hlo.txt"}
        }"#).unwrap();
        let m = Manifest::load(dir.path()).unwrap();
        assert_eq!(m.dim, 128);
        assert_eq!(m.m, 8);
        assert_eq!(m.files.lut, "l.hlo.txt");
        assert_eq!(m.encode_batch, 512);
    }

    #[test]
    fn missing_manifest_is_error() {
        let dir = TempDir::new("manifest").unwrap();
        assert!(Manifest::load(dir.path()).is_err());
    }

    #[test]
    fn list_artifacts_finds_bundles() {
        let dir = TempDir::new("arts").unwrap();
        std::fs::create_dir_all(dir.path().join("a")).unwrap();
        std::fs::create_dir_all(dir.path().join("b")).unwrap();
        std::fs::write(dir.path().join("a/manifest.json"), "{}").unwrap();
        assert_eq!(list_artifacts(dir.path()), vec!["a".to_string()]);
        assert!(find_artifact(dir.path(), "a").is_some());
        assert!(find_artifact(dir.path(), "b").is_none());
    }
}
