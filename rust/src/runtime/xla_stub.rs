//! Inert stand-in for the `xla` PJRT bindings.
//!
//! The offline testbed image carries no PJRT shared library or `xla`
//! crate, so builds compile against this API-compatible stub: every
//! entry point type-checks, and [`PjRtClient::cpu`] fails with an
//! actionable message at runtime-thread startup — exactly the path every
//! caller (harness, tables, benches, tests) already handles by skipping
//! UNQ cells gracefully.  To execute real AOT artifacts, add the actual
//! `xla` dependency and swap the `use self::xla_stub as xla` alias in
//! `runtime/mod.rs` for the extern crate (the `pjrt` feature's
//! compile_error! there walks through it); this file doubles as the spec
//! of the API surface those bindings must provide.

#![allow(dead_code)]

use std::fmt;

/// Stub error carrying the "build without PJRT" explanation.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(
        "PJRT is unavailable in this build: the crate was compiled against \
         the in-tree xla stub (enable the `pjrt` feature and add the real \
         `xla` dependency to execute AOT artifacts)"
            .to_string(),
    ))
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation)
                   -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T])
                      -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1<T>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable()
    }

    pub fn to_tuple1(self) -> Result<Literal, Error> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
}
