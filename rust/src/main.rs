//! `unq` — the launcher CLI.
//!
//! Subcommands (hand-rolled parser; clap is unavailable offline):
//!
//! ```text
//! unq gen-data  [--datasets a,b] [--scale F]       generate synthetic corpora
//! unq gt        [--datasets a,b] [--r N]           exact ground truth (cached)
//! unq train     --quantizer Q --dataset D [--bytes B]   train + cache a baseline
//! unq eval      --quantizer Q --dataset D [--bytes B] [--no-rerank] [--exhaustive]
//! unq tables    [--table 1|2|3|4|5|mem|timings|all]    regenerate paper tables
//! unq serve     --dataset D [--quantizer Q] [--queries N]  run the coordinator
//!               [--listen ADDR] serve it over TCP instead (rust/SERVING.md)
//! unq loadgen   --addr ADDR [--mode closed|open] drive a serving endpoint
//! unq artifacts                                    list AOT bundles
//! ```

use std::collections::HashMap;

use anyhow::{bail, Context};

use unq::config::{AppConfig, IndexBackendKind, QuantizerKind, ScanPrecision};
use unq::coordinator;
use unq::data;
use unq::eval::harness;
use unq::Result;

mod tables;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal flag parser: `--key value` and boolean `--flag`.
pub struct Flags {
    cmd: String,
    vals: HashMap<String, String>,
    bools: Vec<String>,
}

impl Flags {
    pub fn parse(args: &[String]) -> Result<Flags> {
        let cmd = args.first().cloned().unwrap_or_else(|| "help".into());
        let mut vals = HashMap::new();
        let mut bools = Vec::new();
        let mut i = 1;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    vals.insert(key.to_string(), args[i + 1].clone());
                    i += 2;
                } else {
                    bools.push(key.to_string());
                    i += 1;
                }
            } else {
                bail!("unexpected argument {a:?}");
            }
        }
        Ok(Flags { cmd, vals, bools })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.vals.get(key).map(|s| s.as_str())
    }

    pub fn has(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key)
    }
}

pub fn base_config(f: &Flags) -> Result<AppConfig> {
    let mut cfg = AppConfig::default().apply_env();
    if let Some(d) = f.get("dataset") {
        cfg.dataset = d.to_string();
    }
    if let Some(q) = f.get("quantizer") {
        cfg.quantizer = QuantizerKind::parse(q)
            .with_context(|| format!("unknown quantizer {q:?}"))?;
    }
    if let Some(b) = f.get("bytes") {
        cfg.bytes_per_vector = b.parse().context("--bytes")?;
    }
    if let Some(kc) = f.get("codewords") {
        let kc: usize = kc.parse().context("--codewords")?;
        anyhow::ensure!(kc > 0, "--codewords must be positive");
        cfg.k_codewords = kc;
    }
    if let Some(s) = f.get("scale") {
        cfg.scale = s.parse().context("--scale")?;
    }
    if let Some(l) = f.get("rerank-l") {
        cfg.search.rerank_l = l.parse().context("--rerank-l")?;
    }
    if let Some(t) = f.get("threads") {
        let t: usize = t.parse().context("--threads")?;
        cfg.search.num_threads = t;
        cfg.serve.num_threads = t;
    }
    if let Some(s) = f.get("shard-rows") {
        let s: usize = s.parse().context("--shard-rows")?;
        cfg.search.shard_rows = s;
        cfg.serve.shard_rows = s;
    }
    if let Some(b) = f.get("backend") {
        cfg.ivf.backend = IndexBackendKind::parse(b)
            .with_context(|| format!("unknown backend {b:?}"))?;
    }
    if let Some(l) = f.get("lists") {
        let l: usize = l.parse().context("--lists")?;
        anyhow::ensure!(l > 0, "--lists must be positive");
        cfg.ivf.num_lists = l;
    }
    if let Some(n) = f.get("nprobe") {
        cfg.search.nprobe = n.parse().context("--nprobe")?;
    }
    if let Some(c) = f.get("cache-mb") {
        let c: usize = c.parse().context("--cache-mb")?;
        anyhow::ensure!(c > 0, "--cache-mb must be positive");
        cfg.ivf.cache_mb = c;
    }
    if let Some(s) = f.get("segment-rows") {
        let s: usize = s.parse().context("--segment-rows")?;
        anyhow::ensure!(s > 0, "--segment-rows must be positive");
        cfg.stream.segment_rows = s;
    }
    if let Some(c) = f.get("compact-segments") {
        let c: usize = c.parse().context("--compact-segments")?;
        anyhow::ensure!(c > 0, "--compact-segments must be positive");
        cfg.stream.compact_segments = c;
    }
    if let Some(e) = f.get("native-epochs") {
        cfg.unq_native.epochs = e.parse().context("--native-epochs")?;
    }
    if let Some(h) = f.get("native-hidden") {
        let h: usize = h.parse().context("--native-hidden")?;
        anyhow::ensure!(h > 0, "--native-hidden must be positive");
        cfg.unq_native.hidden = h;
    }
    if let Some(s) = f.get("native-seed") {
        cfg.unq_native.seed = s.parse().context("--native-seed")?;
    }
    if let Some(p) = f.get("precision") {
        cfg.search.scan_precision = ScanPrecision::parse(p)
            .with_context(|| format!("unknown scan precision {p:?} \
                                      (f32|u16|u8|u4)"))?;
    }
    if f.has("prefilter") {
        cfg.search.prefilter = true;
    }
    if let Some(m) = f.get("prefilter-margin") {
        let m: usize = m.parse().context("--prefilter-margin")?;
        anyhow::ensure!(m > 0, "--prefilter-margin must be positive");
        cfg.search.prefilter_margin = m;
    }
    if let Some(spec) = f.get("filter") {
        cfg.search.filter = Some(unq::index::Filter::parse(spec)
            .map_err(|e| anyhow::anyhow!("--filter: {e}"))?);
    }
    if f.has("residual") {
        cfg.ivf.residual = true;
    }
    if f.has("no-residual") {
        cfg.ivf.residual = false;
    }
    cfg.search.no_rerank = f.has("no-rerank");
    cfg.search.exhaustive_rerank = f.has("exhaustive");
    Ok(cfg)
}

fn run(args: &[String]) -> Result<()> {
    let f = Flags::parse(args)?;
    let res = match f.cmd.as_str() {
        "gen-data" => cmd_gen_data(&f),
        "gt" => cmd_gt(&f),
        "train" => cmd_train(&f),
        "eval" => cmd_eval(&f),
        "ivf-sweep" => cmd_ivf_sweep(&f),
        "precision-sweep" => cmd_precision_sweep(&f),
        "ingest" => cmd_ingest(&f),
        "search" => cmd_search(&f),
        "stats" => cmd_stats(&f),
        "tables" => tables::cmd_tables(&f),
        "serve" => cmd_serve(&f),
        "loadgen" => cmd_loadgen(&f),
        "artifacts" => cmd_artifacts(&f),
        "help" | "--help" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown subcommand {other:?} (try `unq help`)"),
    };
    // Work-doing verbs leave their metrics snapshot behind for a later
    // `unq stats` (a fresh process cannot see this one's counters).
    // `loadgen` is deliberately absent: it runs in a separate process
    // from the server it drives, and writing its (client-side, mostly
    // empty) snapshot would clobber the serve run's net.* families.
    const WORK_VERBS: [&str; 7] = ["train", "eval", "ivf-sweep",
                                   "precision-sweep", "ingest", "search",
                                   "serve"];
    if res.is_ok() && WORK_VERBS.contains(&f.cmd.as_str()) {
        if let Ok(cfg) = base_config(&f) {
            write_obs_stats(&cfg)?;
        }
    }
    res
}

/// Persist the global metrics snapshot to `<runs_dir>/obs_stats.json`
/// (rust/DESIGN.md §10) so `unq stats` can inspect the last run.
fn write_obs_stats(cfg: &AppConfig) -> Result<()> {
    let snap = unq::obs::global().snapshot();
    std::fs::create_dir_all(&cfg.runs_dir)?;
    let path = cfg.runs_dir.join("obs_stats.json");
    std::fs::write(&path, snap.to_json().render_pretty())
        .with_context(|| format!("write {path:?}"))?;
    Ok(())
}

const HELP: &str = "\
unq — Unsupervised Neural Quantization retrieval system

USAGE:
  unq gen-data  [--datasets a,b] [--scale F]
  unq gt        [--datasets a,b] [--r N]
  unq train     --quantizer Q --dataset D [--bytes B]
  unq eval      --quantizer Q --dataset D [--bytes B] [--no-rerank] [--exhaustive]
                [--filter-selectivity]
  unq ivf-sweep --quantizer Q --dataset D [--nprobes 1,4,16] [--lists N]
  unq precision-sweep --quantizer Q --dataset D [--precisions f32,u16,u8,u4]
  unq ingest    --quantizer Q --dataset D [--batch N] [--delete-pct F]
                [--resume]
  unq search    --quantizer Q --dataset D [--query I] [--queries N] [--k K]
                [--explain] [--filter tag=V]
  unq stats     [--json] [--schema FILE]
  unq tables    [--table 1|2|3|4|5|mem|timings|all]
  unq serve     --dataset D [--quantizer Q] [--queries N]
                [--listen ADDR] [--duration-secs N] [--max-conns N]
                [--max-inflight N] [--io-threads N] [--tenants SPECS]
  unq loadgen   --addr ADDR [--clients N] [--duration-secs N]
                [--mode closed|open] [--rate QPS] [--insert-pct P]
                [--k K] [--tenant T] [--seed S] [--connect-retries N]
                [--report FILE] [--filter tag=V]
  unq artifacts

Execution:  [--threads N] [--shard-rows R] size the batch scan executor
            (also via UNQ_THREADS / UNQ_SHARD_ROWS; defaults: inline);
            [--precision f32|u16|u8|u4] picks the ADC scan kernel (env
            UNQ_SCAN_PRECISION; u16/u8 = blocked integer fast-scan with
            exact f32 rescore, rust/DESIGN.md §6; u4 = in-register
            16-entry LUT gather for ≤16-codeword quantizers, §9; SIMD
            kernels auto-dispatch, UNQ_FORCE_SCALAR=1 pins scalar);
            [--prefilter] [--prefilter-margin N] enable the 1-bit sketch
            pre-filter that prunes to k·N candidates by Hamming distance
            before exact scoring (env UNQ_PREFILTER /
            UNQ_PREFILTER_MARGIN; recall-safe over-fetch, §9);
            [--filter tag=V] restricts search to rows whose metadata tag
            equals V, pruned inside the scan kernels before selection
            (env UNQ_FILTER; strict semantics: indexes without a tag
            column admit no rows, streaming inserts default to tag 0 —
            rust/DESIGN.md §13).  `unq eval --filter-selectivity` sweeps
            the filtered-search overhead at 100/50/10/1% admitted rows
            and reports the filter.* pruning counters
Index:      [--backend flat|ivf|disk-ivf] [--lists N] [--nprobe P]
            [--residual] pick the index organization for eval/serve (env
            UNQ_BACKEND / UNQ_LISTS / UNQ_NPROBE / UNQ_RESIDUAL; nprobe
            0 = all lists; residual wants a residual-trained quantizer,
            DESIGN.md §5).  disk-ivf keeps routing in RAM and pages
            per-list code blocks from a block archive through a
            [--cache-mb M] hot-list cache (env UNQ_CACHE_MB; default 64;
            bit-identical results to ivf at any budget, DESIGN.md §11)
Streaming:  [--segment-rows R] [--compact-segments S] size the mutable
            index's active segment and compaction trigger for `unq
            ingest` (env UNQ_SEGMENT_ROWS / UNQ_COMPACT_SEGMENTS /
            UNQ_WAL_SYNC; WAL-backed segments, DESIGN.md §7; --backend
            ivf routes inserts through a coarse codebook)
Quantizers: pq opq rvq lsq lsq+rerank catalyst-lattice catalyst-opq unq
            unq-native (also via env UNQ_QUANTIZER).  `unq` runs AOT
            artifacts through PJRT; `unq-native` trains the paper's DNN
            quantizer in pure Rust (`unq train --quantizer unq-native`;
            knobs: [--native-epochs N] [--native-hidden H]
            [--native-seed S], env UNQ_NATIVE_EPOCHS / UNQ_NATIVE_HIDDEN
            / UNQ_NATIVE_BATCH / UNQ_NATIVE_LR / UNQ_NATIVE_SEED, or the
            `unq_native` config section; rust/DESIGN.md §8).
            [--codewords K] overrides the paper's 256-entry codebooks —
            mostly for tiny smoke runs where 256 exceeds the train split
Observability: `unq search --explain` prints the per-query span tree
            (route → scan → rerank …, self-times and rows; DESIGN.md
            §10); every work-doing verb writes its metrics snapshot to
            runs/obs_stats.json, which `unq stats` renders ([--json] for
            the raw snapshot, [--schema FILE] to validate it; env
            UNQ_TRACE=1 turns span tracing on everywhere)
Serving:    `unq serve --listen HOST:PORT` exposes the coordinator over
            the in-tree TCP protocol (rust/PROTOCOL.md) with pipelined
            requests, per-tenant quotas and typed overload errors; env
            UNQ_LISTEN / UNQ_NET_THREADS / UNQ_MAX_CONNS /
            UNQ_MAX_INFLIGHT / UNQ_MAX_FRAME / UNQ_WRITE_TIMEOUT_MS /
            UNQ_TENANTS.  --tenants takes `name[:qps[:bytes]]` specs,
            comma-separated (0 = unlimited).  `unq loadgen` drives a
            running endpoint closed- or open-loop and reports QPS +
            p50/p99/p999 latency (operator runbook: rust/SERVING.md)
Datasets:   deep1m sift1m deep10m sift10m deep1b sift1b (simulated; see
            rust/DESIGN.md)
";

fn datasets_arg(f: &Flags, scale: f64) -> Vec<data::DatasetSpec> {
    match f.get("datasets") {
        Some(list) => list
            .split(',')
            .filter_map(|n| data::spec_by_name(n.trim(), scale))
            .collect(),
        None => data::catalog(scale),
    }
}

fn cmd_gen_data(f: &Flags) -> Result<()> {
    let cfg = base_config(f)?;
    for spec in datasets_arg(f, cfg.scale) {
        let t0 = std::time::Instant::now();
        let splits = data::load_or_generate(&spec, &cfg.data_dir)?;
        println!(
            "[gen-data] {}: train {} base {} query {} (dim {}) in {:.1}s",
            spec.name, splits.train.len(), splits.base.len(),
            splits.query.len(), spec.dim(), t0.elapsed().as_secs_f32()
        );
    }
    Ok(())
}

fn cmd_gt(f: &Flags) -> Result<()> {
    let cfg = base_config(f)?;
    let r: usize = f.get("r").map(|v| v.parse()).transpose()?.unwrap_or(100);
    for spec in datasets_arg(f, cfg.scale) {
        let splits = data::load_or_generate(&spec, &cfg.data_dir)?;
        let gt = unq::gt::load_or_compute(&cfg.data_dir, &spec.name,
                                          &splits.base, &splits.query, r)?;
        println!("[gt] {}: {} queries × top-{}", spec.name,
                 gt.neighbors.len(), gt.r);
    }
    Ok(())
}

fn cmd_train(f: &Flags) -> Result<()> {
    let cfg = base_config(f)?;
    if cfg.quantizer == QuantizerKind::Unq {
        bail!("UNQ is trained at build time: run `make artifacts`");
    }
    let spec = data::spec_by_name(&cfg.dataset, cfg.scale)
        .with_context(|| format!("unknown dataset {:?}", cfg.dataset))?;
    let splits = data::load_or_generate(&spec, &cfg.data_dir)?;
    std::fs::create_dir_all(&cfg.runs_dir)?;
    let (q, secs) = harness::train_or_load_shallow(&cfg, cfg.quantizer,
                                                   &splits.train)?;
    println!("[train] {} on {}: {:.1}s (cached if 0)", q.name(),
             cfg.dataset, secs);
    Ok(())
}

fn cmd_eval(f: &Flags) -> Result<()> {
    let cfg = base_config(f)?;
    let variant = f.get("variant").unwrap_or("");
    let exp = harness::prepare(&cfg, variant)?;
    let mut search = harness::paper_search_config(cfg.quantizer, &cfg.dataset,
                                                  cfg.search.k);
    search.no_rerank |= cfg.search.no_rerank;
    search.exhaustive_rerank = cfg.search.exhaustive_rerank;
    search.num_threads = cfg.search.num_threads;
    search.shard_rows = cfg.search.shard_rows;
    search.nprobe = cfg.search.nprobe;
    search.scan_precision = cfg.search.scan_precision;
    search.filter = cfg.search.filter;
    if f.has("filter-selectivity") {
        let mut exp = exp;
        println!(
            "[eval] filtered-search selectivity sweep: {} on {} \
             (flat, n={}, tags id % m, predicate tag=0)",
            exp.quant.name(), cfg.dataset, exp.index.n
        );
        println!("{:>8} {:>12} {:>14} {:>14} {:>12}",
                 "m", "admitted", "rows_pruned", "bitmaps", "ms/query");
        for pt in exp.run_filter_selectivity(search, &[1, 2, 10, 100]) {
            println!("{:>8} {:>11.1}% {:>14} {:>14} {:>12.3}",
                     pt.modulus, 100.0 * pt.selectivity, pt.rows_pruned,
                     pt.bitmaps_built, 1e3 * pt.secs_per_query);
        }
        return Ok(());
    }
    if cfg.ivf.backend == IndexBackendKind::Ivf {
        let ivf = harness::build_or_load_ivf(
            &cfg, exp.quant.as_ref(), &exp.splits.train, &exp.splits.base,
            variant)?;
        let pt = exp.sweep_point(&ivf, search);
        println!(
            "[eval] {} on {} ({}B, n={}, ivf L={} nprobe={}{}): R@1 {:.1}  \
             R@10 {:.1}  R@100 {:.1}  ({:.2} ms/query)",
            exp.quant.name(), cfg.dataset, cfg.bytes_per_vector, ivf.n(),
            ivf.num_lists(), pt.nprobe,
            if ivf.residual { " res" } else { "" },
            pt.recall.at1, pt.recall.at10, pt.recall.at100,
            1e3 * pt.secs_per_query
        );
        return Ok(());
    }
    if cfg.ivf.backend == IndexBackendKind::DiskIvf {
        let disk = harness::build_or_load_disk_ivf(
            &cfg, exp.quant.as_ref(), &exp.splits.train, &exp.splits.base,
            variant)?;
        let obs0 = unq::obs::global().snapshot();
        let pt = exp.sweep_point_disk(&disk, search)?;
        let d = unq::obs::global().snapshot().delta(&obs0);
        let (h, m) = (d.counter("cache.hits"), d.counter("cache.misses"));
        println!(
            "[eval] {} on {} ({}B, n={}, disk-ivf L={} nprobe={} \
             cache {}MB): R@1 {:.1}  R@10 {:.1}  R@100 {:.1}  \
             ({:.2} ms/query)",
            exp.quant.name(), cfg.dataset, cfg.bytes_per_vector, disk.n(),
            disk.num_lists(), pt.nprobe, cfg.ivf.cache_mb,
            pt.recall.at1, pt.recall.at10, pt.recall.at100,
            1e3 * pt.secs_per_query
        );
        println!(
            "[eval] cache: hit-rate {:.1}% ({h}/{}), {} eviction(s), \
             {} resident bytes",
            100.0 * h as f64 / (h + m).max(1) as f64, h + m,
            d.counter("cache.evictions"), disk.cache_bytes_resident()
        );
        return Ok(());
    }
    let t0 = std::time::Instant::now();
    let rec = exp.run_recall(search);
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "[eval] {} on {} ({}B, n={}): R@1 {:.1}  R@10 {:.1}  R@100 {:.1}  \
         ({:.2} ms/query)",
        exp.quant.name(), cfg.dataset, cfg.bytes_per_vector, exp.index.n,
        rec.at1, rec.at10, rec.at100,
        1e3 * secs / exp.splits.query.len().max(1) as f64
    );
    Ok(())
}

/// `unq ivf-sweep` — the recall@R-vs-nprobe trade-off table.
fn cmd_ivf_sweep(f: &Flags) -> Result<()> {
    let mut cfg = base_config(f)?;
    cfg.ivf.backend = IndexBackendKind::Ivf;
    let variant = f.get("variant").unwrap_or("");
    let exp = harness::prepare(&cfg, variant)?;
    let ivf = harness::build_or_load_ivf(
        &cfg, exp.quant.as_ref(), &exp.splits.train, &exp.splits.base,
        variant)?;
    let mut search = harness::paper_search_config(cfg.quantizer, &cfg.dataset,
                                                  cfg.search.k);
    search.no_rerank |= cfg.search.no_rerank;
    search.exhaustive_rerank = cfg.search.exhaustive_rerank;
    search.num_threads = cfg.search.num_threads;
    search.shard_rows = cfg.search.shard_rows;
    search.scan_precision = cfg.search.scan_precision;
    let nprobes: Vec<usize> = match f.get("nprobes") {
        Some(list) => list
            .split(',')
            .map(|p| p.trim().parse().context("--nprobes"))
            .collect::<Result<_>>()?,
        None => {
            let nl = ivf.num_lists();
            let mut v: Vec<usize> = [1usize, 4, 16, nl]
                .into_iter()
                .filter(|&p| p <= nl)
                .collect();
            v.dedup();
            v
        }
    };
    println!(
        "[ivf-sweep] {} on {} ({}B, n={}, L={}{})",
        exp.quant.name(), cfg.dataset, cfg.bytes_per_vector, ivf.n(),
        ivf.num_lists(), if ivf.residual { ", residual" } else { "" }
    );
    println!("{:>8} {:>8} {:>8} {:>8} {:>12}",
             "nprobe", "R@1", "R@10", "R@100", "ms/query");
    for pt in exp.run_ivf_nprobe_sweep(&ivf, search, &nprobes) {
        println!("{:>8} {:>8.1} {:>8.1} {:>8.1} {:>12.3}",
                 pt.nprobe, pt.recall.at1, pt.recall.at10, pt.recall.at100,
                 1e3 * pt.secs_per_query);
    }
    Ok(())
}

/// `unq precision-sweep` — recall × latency across scan precisions (the
/// throughput/accuracy trade-off of the blocked integer kernels).
fn cmd_precision_sweep(f: &Flags) -> Result<()> {
    let cfg = base_config(f)?;
    anyhow::ensure!(
        cfg.ivf.backend == IndexBackendKind::Flat,
        "precision-sweep measures the flat exhaustive engine; drop \
         `--backend ivf` (combine --precision with `unq eval` or \
         `unq ivf-sweep` to measure IVF at a given precision)"
    );
    let variant = f.get("variant").unwrap_or("");
    let mut exp = harness::prepare(&cfg, variant)?;
    let mut search = harness::paper_search_config(cfg.quantizer, &cfg.dataset,
                                                  cfg.search.k);
    search.no_rerank |= cfg.search.no_rerank;
    search.exhaustive_rerank = cfg.search.exhaustive_rerank;
    search.num_threads = cfg.search.num_threads;
    search.shard_rows = cfg.search.shard_rows;
    let precisions: Vec<ScanPrecision> = match f.get("precisions") {
        Some(list) => list
            .split(',')
            .map(|p| {
                ScanPrecision::parse(p.trim())
                    .with_context(|| format!("unknown precision {p:?}"))
            })
            .collect::<Result<_>>()?,
        None => ScanPrecision::all().to_vec(),
    };
    println!(
        "[precision-sweep] {} on {} ({}B, n={})",
        exp.quant.name(), cfg.dataset, cfg.bytes_per_vector, exp.index.n
    );
    println!("{:>10} {:>8} {:>8} {:>8} {:>12}",
             "precision", "R@1", "R@10", "R@100", "ms/query");
    for pt in exp.run_precision_sweep(search, &precisions) {
        println!("{:>10} {:>8.1} {:>8.1} {:>8.1} {:>12.3}",
                 pt.precision.name(), pt.recall.at1, pt.recall.at10,
                 pt.recall.at100, 1e3 * pt.secs_per_query);
    }
    Ok(())
}

/// `unq ingest` — the streaming write path end to end: open a WAL-backed
/// [`unq::index::StreamingIndex`] under `runs/`, insert the base set in
/// batches (encode-on-insert, fsync'd), tombstone a fraction, compact,
/// then verify the read path against a flat rebuild of the survivors.
fn cmd_ingest(f: &Flags) -> Result<()> {
    use std::sync::Arc;
    use unq::index::{CompressedIndex, Routing, SearchEngine,
                     StreamingIndex};
    use unq::ivf::CoarseQuantizer;

    let cfg = base_config(f)?;
    // bracket the run with metrics snapshots: everything below reports
    // through the obs registry delta instead of ad-hoc stopwatches
    // (rust/DESIGN.md §10)
    let obs0 = unq::obs::global().snapshot();
    let obs_summary = |label: &str| {
        let d = unq::obs::global().snapshot().delta(&obs0);
        println!("[ingest] observability ({label}):");
        for line in d.render_human().lines() {
            println!("  {line}");
        }
    };
    let batch: usize =
        f.get("batch").map(|v| v.parse()).transpose()?.unwrap_or(1024);
    let delete_pct: f64 = f
        .get("delete-pct")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(10.0);
    anyhow::ensure!((0.0..=100.0).contains(&delete_pct),
                    "--delete-pct must be in [0, 100]");
    if cfg.quantizer == QuantizerKind::Unq {
        bail!("ingest demos the shallow write path; UNQ artifacts are \
               frozen-trained (pick --quantizer pq/opq/...)");
    }
    let spec = data::spec_by_name(&cfg.dataset, cfg.scale)
        .with_context(|| format!("unknown dataset {:?}", cfg.dataset))?;
    let splits = data::load_or_generate(&spec, &cfg.data_dir)?;
    std::fs::create_dir_all(&cfg.runs_dir)?;
    let (quant, _) =
        harness::train_or_load_shallow(&cfg, cfg.quantizer, &splits.train)?;

    let routed = cfg.ivf.backend == IndexBackendKind::Ivf;
    let routing = if routed {
        let coarse = CoarseQuantizer::train(
            &splits.train.data, splits.train.dim, cfg.ivf.num_lists, 0, 15);
        Some(Routing { coarse: Arc::new(coarse),
                       residual: cfg.ivf.residual })
    } else {
        None
    };
    let dir = cfg.runs_dir.join(format!(
        "stream_{}_{}_{}b{}",
        cfg.dataset,
        cfg.quantizer.name().replace(['+', ' '], "_"),
        cfg.bytes_per_vector,
        if routed { format!("_L{}", cfg.ivf.num_lists) } else { String::new() }
    ));
    if !f.has("resume") && dir.exists() {
        std::fs::remove_dir_all(&dir)
            .with_context(|| format!("clear {dir:?} (use --resume to keep)"))?;
    }
    let ix = StreamingIndex::open(&dir, quant.code_bytes(), routing,
                                  cfg.stream)?;
    let preexisting = ix.len();

    // insert the base set in batches through the WAL
    let base = &splits.base;
    let t0 = std::time::Instant::now();
    let mut ids: Vec<u32> = Vec::with_capacity(base.len());
    for lo in (0..base.len()).step_by(batch.max(1)) {
        let hi = (lo + batch.max(1)).min(base.len());
        ids.extend(ix.insert_batch(quant.as_ref(), base.rows(lo, hi))?);
    }
    let ins_secs = t0.elapsed().as_secs_f64();
    println!(
        "[ingest] {} rows in {:.2}s ({:.0} rows/s, batch {batch}, \
         wal fsync every {})",
        ids.len(), ins_secs, ids.len() as f64 / ins_secs.max(1e-9),
        cfg.stream.wal_sync
    );
    {
        let d = unq::obs::global().snapshot().delta(&obs0);
        println!(
            "[ingest] wal: {} appends, {} commits, fsync p50 {}µs \
             p99 {}µs max {}µs",
            d.counter("wal.appends"), d.counter("wal.commits"),
            d.hist("wal.fsync_us").map_or(0, |h| h.quantile_us(0.50)),
            d.hist("wal.fsync_us").map_or(0, |h| h.quantile_us(0.99)),
            d.hist("wal.fsync_us").map_or(0, |h| h.max_us)
        );
    }

    // tombstone an evenly-spaced delete_pct fraction, then compact
    // (fractional accumulator, exact for any percentage — a rounded
    // stride would snap e.g. 70% all the way to 100%)
    let mut victims: Vec<u32> = Vec::new();
    let mut acc = 0.0f64;
    for &id in &ids {
        acc += delete_pct / 100.0;
        if acc >= 1.0 {
            acc -= 1.0;
            victims.push(id);
        }
    }
    let removed = if victims.is_empty() { 0 }
                  else { ix.delete_batch(&victims)? };
    let compacted = ix.compact()?;
    let st = ix.stats();
    let comp = unq::obs::global().snapshot().delta(&obs0);
    println!(
        "[ingest] deleted {removed}, compact(merged={compacted}, {} run(s), \
         {}µs max) → {} live / {} total rows, {} sealed segment(s), \
         generation {}",
        comp.counter("compaction.runs"),
        comp.hist("compaction.duration_us").map_or(0, |h| h.max_us),
        st.live_rows, st.total_rows, st.sealed_segments, st.generation
    );

    // read-path verification vs a flat rebuild of the survivors (exact
    // at f32 for the unrouted path; routed demos report overlap).  Only
    // meaningful when this run inserted everything the index serves: a
    // --resume into a populated index would verify against a rebuild
    // missing the earlier runs' rows and report spurious mismatches.
    if preexisting > 0 || ids.first() != Some(&0) {
        println!(
            "[ingest] resumed over a pre-used id space ({preexisting} \
             live rows before this run) — live-vs-rebuild verification \
             skipped (external ids no longer map to base rows)"
        );
        obs_summary("write path");
        return Ok(());
    }
    let survivors: Vec<u32> = ids
        .iter()
        .copied()
        .filter(|id| victims.binary_search(id).is_err())
        .collect();
    let mut kept = Vec::with_capacity(survivors.len() * base.dim);
    for &id in &survivors {
        kept.extend_from_slice(base.row(id as usize));
    }
    let kept = data::Dataset::new(base.dim, kept);
    let flat = CompressedIndex::build(quant.as_ref(), &kept);
    let mut search = harness::paper_search_config(cfg.quantizer,
                                                  &cfg.dataset, 10);
    search.num_threads = cfg.search.num_threads;
    search.shard_rows = cfg.search.shard_rows;
    search.nprobe = cfg.search.nprobe;
    search.scan_precision = cfg.search.scan_precision;
    let nq = splits.query.len().min(64);
    let qs: Vec<&[f32]> = (0..nq).map(|qi| splits.query.row(qi)).collect();
    let ks = vec![search.k; nq];
    let exec = unq::exec::Executor::new(search.num_threads);
    let req = unq::index::SearchRequest::from_config(&search, ks);
    let t2 = std::time::Instant::now();
    let got = ix.search_batch_on(quant.as_ref(), &exec, &qs, &req);
    let q_secs = t2.elapsed().as_secs_f64();
    let want =
        SearchEngine::new(quant.as_ref(), &flat, search).search_batch(&qs);
    let mut same = 0usize;
    let mut overlap = 0usize;
    let mut total = 0usize;
    for (g, w) in got.iter().zip(&want) {
        let w_ids: Vec<u32> =
            w.iter().map(|&row| survivors[row as usize]).collect();
        same += (*g == w_ids) as usize;
        overlap += g.iter().filter(|id| w_ids.contains(id)).count();
        total += w_ids.len();
    }
    println!(
        "[ingest] search: {nq} queries in {:.1} ms ({:.2} ms/query), \
         vs flat rebuild: {same}/{nq} identical, overlap {overlap}/{total}",
        1e3 * q_secs, 1e3 * q_secs / nq.max(1) as f64
    );
    obs_summary("write + read path");
    Ok(())
}

/// `unq search` — ad-hoc queries through the batch engine; `--explain`
/// prints the per-query span tree (rust/DESIGN.md §10) next to the
/// neighbor ids.
fn cmd_search(f: &Flags) -> Result<()> {
    use unq::exec::Executor;

    let cfg = base_config(f)?;
    let variant = f.get("variant").unwrap_or("");
    let mut exp = harness::prepare(&cfg, variant)?;
    let mut search = harness::paper_search_config(cfg.quantizer, &cfg.dataset,
                                                  cfg.search.k);
    search.no_rerank |= cfg.search.no_rerank;
    search.exhaustive_rerank = cfg.search.exhaustive_rerank;
    search.num_threads = cfg.search.num_threads;
    search.shard_rows = cfg.search.shard_rows;
    search.nprobe = cfg.search.nprobe;
    search.scan_precision = cfg.search.scan_precision;
    search.filter = cfg.search.filter;
    if let Some(k) = f.get("k") {
        search.k = k.parse().context("--k")?;
    }
    let explain = f.has("explain") || cfg.search.trace;
    search.trace = explain;
    if let Some(fl) = search.filter {
        println!("[search] filter: {fl} (strict — indexes without a \
                  tag column admit no rows)");
    }

    let qi: usize =
        f.get("query").map(|v| v.parse()).transpose()?.unwrap_or(0);
    let nq: usize =
        f.get("queries").map(|v| v.parse()).transpose()?.unwrap_or(1);
    let total = exp.splits.query.len();
    anyhow::ensure!(nq > 0, "--queries must be positive");
    anyhow::ensure!(qi + nq <= total,
                    "query range {qi}..{} exceeds the {total}-query set",
                    qi + nq);
    let queries: Vec<&[f32]> =
        (qi..qi + nq).map(|i| exp.splits.query.row(i)).collect();

    if search.scan_precision != ScanPrecision::F32 {
        exp.index.ensure_packed();
    }
    let exec = Executor::new(search.num_threads);
    let run = |exp: &harness::Experiment| -> Result<Vec<Vec<u32>>> {
        if cfg.ivf.backend == IndexBackendKind::Ivf {
            let ivf = harness::build_or_load_ivf(
                &cfg, exp.quant.as_ref(), &exp.splits.train,
                &exp.splits.base, variant)?;
            let req = unq::index::SearchRequest::from_config(
                &search, vec![search.k; queries.len()]);
            ivf.search_batch_on(exp.quant.as_ref(), &exec, &queries, &req)
        } else if cfg.ivf.backend == IndexBackendKind::DiskIvf {
            let disk = harness::build_or_load_disk_ivf(
                &cfg, exp.quant.as_ref(), &exp.splits.train,
                &exp.splits.base, variant)?;
            let req = unq::index::SearchRequest::from_config(
                &search, vec![search.k; queries.len()]);
            disk.search_batch_on(exp.quant.as_ref(), &exec, &queries, &req)
        } else {
            let engine = unq::index::SearchEngine::new(exp.quant.as_ref(),
                                                       &exp.index, search);
            Ok(engine.search_batch_on(&exec, &queries))
        }
    };

    let t0 = std::time::Instant::now();
    let (results, trace) = if explain {
        let (trace, root) = unq::obs::Trace::begin("search");
        let r = run(&exp)?;
        drop(root);
        (r, Some(trace))
    } else {
        (run(&exp)?, None)
    };
    let secs = t0.elapsed().as_secs_f64();

    for (i, ids) in results.iter().enumerate() {
        println!("[search] query {}: top-{} {:?}", qi + i, search.k, ids);
    }
    println!("[search] {} quer{} in {:.2} ms ({:.3} ms/query)",
             nq, if nq == 1 { "y" } else { "ies" }, 1e3 * secs,
             1e3 * secs / nq as f64);
    if let Some(trace) = trace {
        println!("[search] EXPLAIN ({} spans):", trace.len());
        print!("{}", trace.render());
    }
    Ok(())
}

/// `unq stats` — render the metrics snapshot the last work-doing verb
/// left at `<runs_dir>/obs_stats.json` (rust/DESIGN.md §10).
fn cmd_stats(f: &Flags) -> Result<()> {
    use unq::util::json::Json;

    let cfg = base_config(f)?;
    let path = cfg.runs_dir.join("obs_stats.json");
    let text = std::fs::read_to_string(&path).with_context(|| {
        format!("read {path:?} — run a work-doing verb \
                 (eval/ingest/search/...) first")
    })?;
    let j = Json::parse(&text).with_context(|| format!("parse {path:?}"))?;
    let snap = unq::obs::MetricsSnapshot::from_json(&j)
        .with_context(|| format!("decode snapshot {path:?}"))?;
    if let Some(sp) = f.get("schema") {
        let sj = Json::parse(
            &std::fs::read_to_string(sp).with_context(|| format!("read {sp}"))?,
        )
        .with_context(|| format!("parse schema {sp}"))?;
        let violations = snap.check_schema(&sj);
        if !violations.is_empty() {
            for v in &violations {
                eprintln!("[stats] schema violation: {v}");
            }
            bail!("{} schema violation(s) against {sp}", violations.len());
        }
        println!("[stats] snapshot satisfies schema {sp}");
    }
    if f.has("json") {
        println!("{}", snap.to_json().render_pretty());
    } else {
        print!("{}", snap.render_human());
    }
    Ok(())
}

fn cmd_serve(f: &Flags) -> Result<()> {
    let mut cfg = base_config(f)?;
    // --listen switches serve from the in-process closed-loop demo to
    // the TCP front door (rust/SERVING.md); the demo path is unchanged
    let Some(addr) = f.get("listen") else {
        let queries: usize =
            f.get("queries").map(|v| v.parse()).transpose()?.unwrap_or(1000);
        return coordinator::demo::run_demo(&cfg, queries);
    };
    cfg.net.listen = addr.to_string();
    if let Some(c) = f.get("max-conns") {
        let c: usize = c.parse().context("--max-conns")?;
        anyhow::ensure!(c > 0, "--max-conns must be positive");
        cfg.net.max_conns = c;
    }
    if let Some(c) = f.get("max-inflight") {
        let c: usize = c.parse().context("--max-inflight")?;
        anyhow::ensure!(c > 0, "--max-inflight must be positive");
        cfg.net.max_inflight = c;
    }
    if let Some(t) = f.get("io-threads") {
        cfg.net.io_threads = t.parse().context("--io-threads")?;
    }
    if let Some(specs) = f.get("tenants") {
        cfg.net.tenants = specs
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| {
                unq::config::TenantQuota::parse_spec(s.trim())
                    .with_context(|| format!("bad tenant spec {s:?} \
                                              (name[:qps[:bytes]])"))
            })
            .collect::<Result<_>>()?;
    }
    let duration: Option<u64> = f
        .get("duration-secs")
        .map(|v| v.parse())
        .transpose()
        .context("--duration-secs")?;
    unq::net::run_listen(&cfg, duration)
}

/// `unq loadgen` — drive a running `unq serve --listen` endpoint with
/// closed- or open-loop mixed traffic and print/write the QPS +
/// latency-percentile report (rust/SERVING.md).
fn cmd_loadgen(f: &Flags) -> Result<()> {
    use unq::net::loadgen::{self, LoadMode, LoadgenConfig};

    let cfg = base_config(f)?;
    let addr = f.get("addr").context(
        "--addr is required (host:port of a running `unq serve --listen`)")?;
    // queries must match the served index's dimensionality, which the
    // dataset family fixes
    let spec = data::spec_by_name(&cfg.dataset, cfg.scale)
        .with_context(|| format!("unknown dataset {:?}", cfg.dataset))?;
    let mut lg = LoadgenConfig {
        addr: addr.to_string(),
        family: spec.family,
        ..Default::default()
    };
    if let Some(c) = f.get("clients") {
        let c: usize = c.parse().context("--clients")?;
        anyhow::ensure!(c > 0, "--clients must be positive");
        lg.clients = c;
    }
    if let Some(d) = f.get("duration-secs") {
        lg.duration = std::time::Duration::from_secs(
            d.parse().context("--duration-secs")?);
    }
    match f.get("mode").unwrap_or("closed") {
        "closed" => lg.mode = LoadMode::Closed,
        "open" => {
            let rate: f64 = f
                .get("rate")
                .context("--mode open requires --rate QPS")?
                .parse()
                .context("--rate")?;
            lg.mode = LoadMode::Open { rate_qps: rate };
        }
        other => bail!("unknown mode {other:?} (closed|open)"),
    }
    if let Some(p) = f.get("insert-pct") {
        lg.insert_pct = p.parse().context("--insert-pct")?;
    }
    if let Some(k) = f.get("k") {
        lg.k = k.parse().context("--k")?;
    }
    if let Some(t) = f.get("tenant") {
        lg.tenant = t.to_string();
    }
    if let Some(s) = f.get("seed") {
        lg.seed = s.parse().context("--seed")?;
    }
    if let Some(r) = f.get("connect-retries") {
        lg.connect_retries = r.parse().context("--connect-retries")?;
    }
    // --filter rides every generated SEARCH as the wire predicate TLV
    lg.filter = cfg.search.filter;
    let report = loadgen::run(&lg)?;
    report.print();
    if let Some(path) = f.get("report") {
        std::fs::write(path, report.to_json().render_pretty())
            .with_context(|| format!("write {path}"))?;
        println!("[loadgen] report written to {path}");
    }
    Ok(())
}

fn cmd_artifacts(f: &Flags) -> Result<()> {
    let cfg = base_config(f)?;
    let names = unq::runtime::list_artifacts(&cfg.artifacts_dir);
    if names.is_empty() {
        println!("no artifacts under {:?} — run `make artifacts`",
                 cfg.artifacts_dir);
        return Ok(());
    }
    for n in names {
        match unq::runtime::Manifest::load(&cfg.artifacts_dir.join(&n)) {
            Ok(m) => println!(
                "{:<18} dataset={:<8} M={:<3} K={} dc={} hidden={} \
                 params={} ({:.1} MB)",
                m.name, m.dataset, m.m, m.k, m.dc, m.hidden, m.param_count,
                m.param_bytes as f64 / 1e6
            ),
            Err(e) => println!("{n:<18} (unreadable manifest: {e})"),
        }
    }
    Ok(())
}
