//! `unq` — the launcher CLI.
//!
//! Subcommands (hand-rolled parser; clap is unavailable offline):
//!
//! ```text
//! unq gen-data  [--datasets a,b] [--scale F]       generate synthetic corpora
//! unq gt        [--datasets a,b] [--r N]           exact ground truth (cached)
//! unq train     --quantizer Q --dataset D [--bytes B]   train + cache a baseline
//! unq eval      --quantizer Q --dataset D [--bytes B] [--no-rerank] [--exhaustive]
//! unq tables    [--table 1|2|3|4|5|mem|timings|all]    regenerate paper tables
//! unq serve     --dataset D [--quantizer Q] [--queries N]  run the coordinator
//! unq artifacts                                    list AOT bundles
//! ```

use std::collections::HashMap;

use anyhow::{bail, Context};

use unq::config::{AppConfig, IndexBackendKind, QuantizerKind, ScanPrecision};
use unq::coordinator;
use unq::data;
use unq::eval::harness;
use unq::Result;

mod tables;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal flag parser: `--key value` and boolean `--flag`.
pub struct Flags {
    cmd: String,
    vals: HashMap<String, String>,
    bools: Vec<String>,
}

impl Flags {
    pub fn parse(args: &[String]) -> Result<Flags> {
        let cmd = args.first().cloned().unwrap_or_else(|| "help".into());
        let mut vals = HashMap::new();
        let mut bools = Vec::new();
        let mut i = 1;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    vals.insert(key.to_string(), args[i + 1].clone());
                    i += 2;
                } else {
                    bools.push(key.to_string());
                    i += 1;
                }
            } else {
                bail!("unexpected argument {a:?}");
            }
        }
        Ok(Flags { cmd, vals, bools })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.vals.get(key).map(|s| s.as_str())
    }

    pub fn has(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key)
    }
}

pub fn base_config(f: &Flags) -> Result<AppConfig> {
    let mut cfg = AppConfig::default().apply_env();
    if let Some(d) = f.get("dataset") {
        cfg.dataset = d.to_string();
    }
    if let Some(q) = f.get("quantizer") {
        cfg.quantizer = QuantizerKind::parse(q)
            .with_context(|| format!("unknown quantizer {q:?}"))?;
    }
    if let Some(b) = f.get("bytes") {
        cfg.bytes_per_vector = b.parse().context("--bytes")?;
    }
    if let Some(s) = f.get("scale") {
        cfg.scale = s.parse().context("--scale")?;
    }
    if let Some(l) = f.get("rerank-l") {
        cfg.search.rerank_l = l.parse().context("--rerank-l")?;
    }
    if let Some(t) = f.get("threads") {
        let t: usize = t.parse().context("--threads")?;
        cfg.search.num_threads = t;
        cfg.serve.num_threads = t;
    }
    if let Some(s) = f.get("shard-rows") {
        let s: usize = s.parse().context("--shard-rows")?;
        cfg.search.shard_rows = s;
        cfg.serve.shard_rows = s;
    }
    if let Some(b) = f.get("backend") {
        cfg.ivf.backend = IndexBackendKind::parse(b)
            .with_context(|| format!("unknown backend {b:?}"))?;
    }
    if let Some(l) = f.get("lists") {
        let l: usize = l.parse().context("--lists")?;
        anyhow::ensure!(l > 0, "--lists must be positive");
        cfg.ivf.num_lists = l;
    }
    if let Some(n) = f.get("nprobe") {
        cfg.search.nprobe = n.parse().context("--nprobe")?;
    }
    if let Some(p) = f.get("precision") {
        cfg.search.scan_precision = ScanPrecision::parse(p)
            .with_context(|| format!("unknown scan precision {p:?} \
                                      (f32|u16|u8)"))?;
    }
    if f.has("residual") {
        cfg.ivf.residual = true;
    }
    if f.has("no-residual") {
        cfg.ivf.residual = false;
    }
    cfg.search.no_rerank = f.has("no-rerank");
    cfg.search.exhaustive_rerank = f.has("exhaustive");
    Ok(cfg)
}

fn run(args: &[String]) -> Result<()> {
    let f = Flags::parse(args)?;
    match f.cmd.as_str() {
        "gen-data" => cmd_gen_data(&f),
        "gt" => cmd_gt(&f),
        "train" => cmd_train(&f),
        "eval" => cmd_eval(&f),
        "ivf-sweep" => cmd_ivf_sweep(&f),
        "precision-sweep" => cmd_precision_sweep(&f),
        "tables" => tables::cmd_tables(&f),
        "serve" => cmd_serve(&f),
        "artifacts" => cmd_artifacts(&f),
        "help" | "--help" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown subcommand {other:?} (try `unq help`)"),
    }
}

const HELP: &str = "\
unq — Unsupervised Neural Quantization retrieval system

USAGE:
  unq gen-data  [--datasets a,b] [--scale F]
  unq gt        [--datasets a,b] [--r N]
  unq train     --quantizer Q --dataset D [--bytes B]
  unq eval      --quantizer Q --dataset D [--bytes B] [--no-rerank] [--exhaustive]
  unq ivf-sweep --quantizer Q --dataset D [--nprobes 1,4,16] [--lists N]
  unq precision-sweep --quantizer Q --dataset D [--precisions f32,u16,u8]
  unq tables    [--table 1|2|3|4|5|mem|timings|all]
  unq serve     --dataset D [--quantizer Q] [--queries N]
  unq artifacts

Execution:  [--threads N] [--shard-rows R] size the batch scan executor
            (also via UNQ_THREADS / UNQ_SHARD_ROWS; defaults: inline);
            [--precision f32|u16|u8] picks the ADC scan kernel (env
            UNQ_SCAN_PRECISION; u16/u8 = blocked integer fast-scan with
            exact f32 rescore, rust/DESIGN.md §6; default f32)
Index:      [--backend flat|ivf] [--lists N] [--nprobe P] [--residual]
            pick the index organization for eval/serve (env UNQ_BACKEND /
            UNQ_LISTS / UNQ_NPROBE / UNQ_RESIDUAL; nprobe 0 = all lists;
            residual wants a residual-trained quantizer, DESIGN.md §5)
Quantizers: pq opq rvq lsq lsq+rerank catalyst-lattice catalyst-opq unq
Datasets:   deep1m sift1m deep10m sift10m deep1b sift1b (simulated; see
            rust/DESIGN.md)
";

fn datasets_arg(f: &Flags, scale: f64) -> Vec<data::DatasetSpec> {
    match f.get("datasets") {
        Some(list) => list
            .split(',')
            .filter_map(|n| data::spec_by_name(n.trim(), scale))
            .collect(),
        None => data::catalog(scale),
    }
}

fn cmd_gen_data(f: &Flags) -> Result<()> {
    let cfg = base_config(f)?;
    for spec in datasets_arg(f, cfg.scale) {
        let t0 = std::time::Instant::now();
        let splits = data::load_or_generate(&spec, &cfg.data_dir)?;
        println!(
            "[gen-data] {}: train {} base {} query {} (dim {}) in {:.1}s",
            spec.name, splits.train.len(), splits.base.len(),
            splits.query.len(), spec.dim(), t0.elapsed().as_secs_f32()
        );
    }
    Ok(())
}

fn cmd_gt(f: &Flags) -> Result<()> {
    let cfg = base_config(f)?;
    let r: usize = f.get("r").map(|v| v.parse()).transpose()?.unwrap_or(100);
    for spec in datasets_arg(f, cfg.scale) {
        let splits = data::load_or_generate(&spec, &cfg.data_dir)?;
        let gt = unq::gt::load_or_compute(&cfg.data_dir, &spec.name,
                                          &splits.base, &splits.query, r)?;
        println!("[gt] {}: {} queries × top-{}", spec.name,
                 gt.neighbors.len(), gt.r);
    }
    Ok(())
}

fn cmd_train(f: &Flags) -> Result<()> {
    let cfg = base_config(f)?;
    if cfg.quantizer == QuantizerKind::Unq {
        bail!("UNQ is trained at build time: run `make artifacts`");
    }
    let spec = data::spec_by_name(&cfg.dataset, cfg.scale)
        .with_context(|| format!("unknown dataset {:?}", cfg.dataset))?;
    let splits = data::load_or_generate(&spec, &cfg.data_dir)?;
    std::fs::create_dir_all(&cfg.runs_dir)?;
    let (q, secs) = harness::train_or_load_shallow(&cfg, cfg.quantizer,
                                                   &splits.train)?;
    println!("[train] {} on {}: {:.1}s (cached if 0)", q.name(),
             cfg.dataset, secs);
    Ok(())
}

fn cmd_eval(f: &Flags) -> Result<()> {
    let cfg = base_config(f)?;
    let variant = f.get("variant").unwrap_or("");
    let exp = harness::prepare(&cfg, variant)?;
    let mut search = harness::paper_search_config(cfg.quantizer, &cfg.dataset,
                                                  cfg.search.k);
    search.no_rerank |= cfg.search.no_rerank;
    search.exhaustive_rerank = cfg.search.exhaustive_rerank;
    search.num_threads = cfg.search.num_threads;
    search.shard_rows = cfg.search.shard_rows;
    search.nprobe = cfg.search.nprobe;
    search.scan_precision = cfg.search.scan_precision;
    if cfg.ivf.backend == IndexBackendKind::Ivf {
        let ivf = harness::build_or_load_ivf(
            &cfg, exp.quant.as_ref(), &exp.splits.train, &exp.splits.base,
            variant)?;
        let pt = exp.sweep_point(&ivf, search);
        println!(
            "[eval] {} on {} ({}B, n={}, ivf L={} nprobe={}{}): R@1 {:.1}  \
             R@10 {:.1}  R@100 {:.1}  ({:.2} ms/query)",
            exp.quant.name(), cfg.dataset, cfg.bytes_per_vector, ivf.n(),
            ivf.num_lists(), pt.nprobe,
            if ivf.residual { " res" } else { "" },
            pt.recall.at1, pt.recall.at10, pt.recall.at100,
            1e3 * pt.secs_per_query
        );
        return Ok(());
    }
    let t0 = std::time::Instant::now();
    let rec = exp.run_recall(search);
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "[eval] {} on {} ({}B, n={}): R@1 {:.1}  R@10 {:.1}  R@100 {:.1}  \
         ({:.2} ms/query)",
        exp.quant.name(), cfg.dataset, cfg.bytes_per_vector, exp.index.n,
        rec.at1, rec.at10, rec.at100,
        1e3 * secs / exp.splits.query.len().max(1) as f64
    );
    Ok(())
}

/// `unq ivf-sweep` — the recall@R-vs-nprobe trade-off table.
fn cmd_ivf_sweep(f: &Flags) -> Result<()> {
    let mut cfg = base_config(f)?;
    cfg.ivf.backend = IndexBackendKind::Ivf;
    let variant = f.get("variant").unwrap_or("");
    let exp = harness::prepare(&cfg, variant)?;
    let ivf = harness::build_or_load_ivf(
        &cfg, exp.quant.as_ref(), &exp.splits.train, &exp.splits.base,
        variant)?;
    let mut search = harness::paper_search_config(cfg.quantizer, &cfg.dataset,
                                                  cfg.search.k);
    search.no_rerank |= cfg.search.no_rerank;
    search.exhaustive_rerank = cfg.search.exhaustive_rerank;
    search.num_threads = cfg.search.num_threads;
    search.shard_rows = cfg.search.shard_rows;
    search.scan_precision = cfg.search.scan_precision;
    let nprobes: Vec<usize> = match f.get("nprobes") {
        Some(list) => list
            .split(',')
            .map(|p| p.trim().parse().context("--nprobes"))
            .collect::<Result<_>>()?,
        None => {
            let nl = ivf.num_lists();
            let mut v: Vec<usize> = [1usize, 4, 16, nl]
                .into_iter()
                .filter(|&p| p <= nl)
                .collect();
            v.dedup();
            v
        }
    };
    println!(
        "[ivf-sweep] {} on {} ({}B, n={}, L={}{})",
        exp.quant.name(), cfg.dataset, cfg.bytes_per_vector, ivf.n(),
        ivf.num_lists(), if ivf.residual { ", residual" } else { "" }
    );
    println!("{:>8} {:>8} {:>8} {:>8} {:>12}",
             "nprobe", "R@1", "R@10", "R@100", "ms/query");
    for pt in exp.run_ivf_nprobe_sweep(&ivf, search, &nprobes) {
        println!("{:>8} {:>8.1} {:>8.1} {:>8.1} {:>12.3}",
                 pt.nprobe, pt.recall.at1, pt.recall.at10, pt.recall.at100,
                 1e3 * pt.secs_per_query);
    }
    Ok(())
}

/// `unq precision-sweep` — recall × latency across scan precisions (the
/// throughput/accuracy trade-off of the blocked integer kernels).
fn cmd_precision_sweep(f: &Flags) -> Result<()> {
    let cfg = base_config(f)?;
    anyhow::ensure!(
        cfg.ivf.backend == IndexBackendKind::Flat,
        "precision-sweep measures the flat exhaustive engine; drop \
         `--backend ivf` (combine --precision with `unq eval` or \
         `unq ivf-sweep` to measure IVF at a given precision)"
    );
    let variant = f.get("variant").unwrap_or("");
    let mut exp = harness::prepare(&cfg, variant)?;
    let mut search = harness::paper_search_config(cfg.quantizer, &cfg.dataset,
                                                  cfg.search.k);
    search.no_rerank |= cfg.search.no_rerank;
    search.exhaustive_rerank = cfg.search.exhaustive_rerank;
    search.num_threads = cfg.search.num_threads;
    search.shard_rows = cfg.search.shard_rows;
    let precisions: Vec<ScanPrecision> = match f.get("precisions") {
        Some(list) => list
            .split(',')
            .map(|p| {
                ScanPrecision::parse(p.trim())
                    .with_context(|| format!("unknown precision {p:?}"))
            })
            .collect::<Result<_>>()?,
        None => ScanPrecision::all().to_vec(),
    };
    println!(
        "[precision-sweep] {} on {} ({}B, n={})",
        exp.quant.name(), cfg.dataset, cfg.bytes_per_vector, exp.index.n
    );
    println!("{:>10} {:>8} {:>8} {:>8} {:>12}",
             "precision", "R@1", "R@10", "R@100", "ms/query");
    for pt in exp.run_precision_sweep(search, &precisions) {
        println!("{:>10} {:>8.1} {:>8.1} {:>8.1} {:>12.3}",
                 pt.precision.name(), pt.recall.at1, pt.recall.at10,
                 pt.recall.at100, 1e3 * pt.secs_per_query);
    }
    Ok(())
}

fn cmd_serve(f: &Flags) -> Result<()> {
    let cfg = base_config(f)?;
    let queries: usize =
        f.get("queries").map(|v| v.parse()).transpose()?.unwrap_or(1000);
    coordinator::demo::run_demo(&cfg, queries)
}

fn cmd_artifacts(f: &Flags) -> Result<()> {
    let cfg = base_config(f)?;
    let names = unq::runtime::list_artifacts(&cfg.artifacts_dir);
    if names.is_empty() {
        println!("no artifacts under {:?} — run `make artifacts`",
                 cfg.artifacts_dir);
        return Ok(());
    }
    for n in names {
        match unq::runtime::Manifest::load(&cfg.artifacts_dir.join(&n)) {
            Ok(m) => println!(
                "{:<18} dataset={:<8} M={:<3} K={} dc={} hidden={} \
                 params={} ({:.1} MB)",
                m.name, m.dataset, m.m, m.k, m.dc, m.hidden, m.param_count,
                m.param_bytes as f64 / 1e6
            ),
            Err(e) => println!("{n:<18} (unreadable manifest: {e})"),
        }
    }
    Ok(())
}
