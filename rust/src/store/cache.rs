//! Sharded hot-list cache with a byte budget (rust/DESIGN.md §11).
//!
//! Keeps recently-probed inverted lists resident in RAM in front of
//! the block archive ([`super::blocks`]).  Three policies, all chosen
//! for lock-cheapness over precision:
//!
//! * **Sharding** — keys hash (modulo) to independent `Mutex` shards,
//!   each owning `budget / shards` bytes, so concurrent searches over
//!   different lists rarely contend on one lock.
//! * **Admission on second touch** — the first miss of a key only
//!   records it in a bounded ghost set; the value is admitted when the
//!   key misses again.  One-shot scans (a full-index sweep at
//!   nprobe=all) therefore cannot wipe the genuinely hot lists.
//! * **CLOCK eviction** — a second-chance ring instead of strict LRU:
//!   hits set a referenced bit without touching any list order, and
//!   the eviction hand clears bits until it finds an unreferenced
//!   victim.
//!
//! Values are handed out as `Arc<T>` clones, so an in-flight scan that
//! holds a list pins it alive even if the cache evicts the entry
//! mid-scan — eviction drops the cache's reference, never the data
//! (the Arc-pinning correctness argument of DESIGN.md §11).  Misses
//! are the caller's problem: build the value, keep your own `Arc`, and
//! offer it back via [`ListCache::insert`]; whether the cache admits
//! it does not affect the caller's copy.

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex};

use crate::obs;

/// Ghost-set size bound per shard; beyond this the set is cleared
/// (losing some admission history, never correctness).
const GHOST_CAP: usize = 4096;

struct Entry<T> {
    value: Arc<T>,
    bytes: usize,
    referenced: bool,
}

struct Shard<T> {
    entries: HashMap<usize, Entry<T>>,
    /// CLOCK ring of resident keys; `hand` indexes the next victim
    /// candidate.  Evicted keys are swap-removed, so the ring is
    /// unordered but always exactly the resident key set.
    ring: Vec<usize>,
    hand: usize,
    /// Ghost set: keys offered once but not yet admitted.
    seen: HashSet<usize>,
    bytes: usize,
    budget: usize,
}

impl<T> Shard<T> {
    /// CLOCK sweep until the shard fits its budget again.
    fn evict_to_budget(&mut self) {
        let o = obs::global();
        while self.bytes > self.budget && !self.ring.is_empty() {
            if self.hand >= self.ring.len() {
                self.hand = 0;
            }
            let key = self.ring[self.hand];
            let e = self.entries.get_mut(&key).expect("ring/entries agree");
            if e.referenced {
                // second chance: clear the bit, advance the hand
                e.referenced = false;
                self.hand += 1;
                continue;
            }
            let victim = self.entries.remove(&key).expect("resident");
            self.ring.swap_remove(self.hand);
            self.bytes -= victim.bytes;
            o.cache_evictions.inc();
            o.cache_bytes_resident.sub(victim.bytes as u64);
            // the swapped-in key now sits under the hand; do not
            // advance, it deserves its own inspection next iteration
        }
    }
}

/// A byte-budgeted, sharded cache of `Arc`'d values keyed by `usize`
/// (list id).  All metrics flow to the global [`obs`] registry:
/// `cache.{hits,misses,evictions}` counters and the
/// `cache.bytes_resident` gauge.
pub struct ListCache<T> {
    shards: Vec<Mutex<Shard<T>>>,
}

impl<T> ListCache<T> {
    /// `budget_bytes` total across `shards` stripes (each gets an
    /// equal slice, at least 1 byte so tiny budgets still evict
    /// rather than divide by zero).
    pub fn new(budget_bytes: usize, shards: usize) -> ListCache<T> {
        let shards = shards.max(1);
        let per = (budget_bytes / shards).max(1);
        ListCache {
            shards: (0..shards)
                .map(|_| {
                    Mutex::new(Shard {
                        entries: HashMap::new(),
                        ring: Vec::new(),
                        hand: 0,
                        seen: HashSet::new(),
                        bytes: 0,
                        budget: per,
                    })
                })
                .collect(),
        }
    }

    fn shard(&self, key: usize) -> &Mutex<Shard<T>> {
        &self.shards[key % self.shards.len()]
    }

    /// Look up `key`.  A hit clones the `Arc` (pinning the value for
    /// the caller) and sets the CLOCK referenced bit; a miss only
    /// counts.
    pub fn get(&self, key: usize) -> Option<Arc<T>> {
        let mut s = self.shard(key).lock().expect("cache shard poisoned");
        match s.entries.get_mut(&key) {
            Some(e) => {
                e.referenced = true;
                let v = Arc::clone(&e.value);
                obs::global().cache_hits.inc();
                Some(v)
            }
            None => {
                obs::global().cache_misses.inc();
                None
            }
        }
    }

    /// Offer a freshly-built value.  First offer of a key goes to the
    /// ghost set only; the second offer admits (evicting via CLOCK to
    /// stay within budget).  Values larger than a whole shard budget
    /// are never admitted.  Returns whether the value is now resident.
    /// The caller's `Arc` is valid either way.
    pub fn insert(&self, key: usize, value: Arc<T>, bytes: usize) -> bool {
        let mut s = self.shard(key).lock().expect("cache shard poisoned");
        if let Some(e) = s.entries.get_mut(&key) {
            // already resident (raced with another thread): refresh
            e.referenced = true;
            return true;
        }
        if bytes > s.budget {
            return false;
        }
        if !s.seen.remove(&key) {
            // first touch: remember, do not admit
            if s.seen.len() >= GHOST_CAP {
                s.seen.clear();
            }
            s.seen.insert(key);
            return false;
        }
        s.entries.insert(key, Entry { value, bytes, referenced: true });
        s.ring.push(key);
        s.bytes += bytes;
        obs::global().cache_bytes_resident.add(bytes as u64);
        s.evict_to_budget();
        // under thrash the brand-new entry itself may be the only
        // evictable one; report residency as it actually stands
        s.entries.contains_key(&key)
    }

    /// Resident bytes across all shards (tests/diagnostics).
    pub fn bytes_resident(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().expect("cache shard poisoned").bytes)
            .sum()
    }

    /// Resident entry count across all shards.
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().expect("cache shard poisoned").entries.len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // admit `key` for real: first offer seeds the ghost set, second
    // offer admits
    fn admit(c: &ListCache<Vec<u8>>, key: usize, bytes: usize) -> bool {
        let v = Arc::new(vec![0u8; bytes]);
        c.insert(key, Arc::clone(&v), bytes);
        c.insert(key, v, bytes)
    }

    #[test]
    fn second_touch_admission() {
        let c: ListCache<Vec<u8>> = ListCache::new(1 << 20, 1);
        let v = Arc::new(vec![1u8; 100]);
        assert!(!c.insert(7, Arc::clone(&v), 100), "first offer: ghost only");
        assert!(c.get(7).is_none());
        assert!(c.insert(7, v, 100), "second offer admits");
        assert_eq!(c.get(7).as_deref().map(|v| v.len()), Some(100));
        assert_eq!(c.bytes_resident(), 100);
    }

    #[test]
    fn clock_evicts_to_budget_and_hits_survive() {
        let c: ListCache<Vec<u8>> = ListCache::new(300, 1);
        assert!(admit(&c, 1, 100));
        assert!(admit(&c, 2, 100));
        assert!(admit(&c, 3, 100));
        assert_eq!(c.len(), 3);
        // keep 2 hot so the clock's second chance protects it
        assert!(c.get(2).is_some());
        assert!(admit(&c, 4, 100));
        assert!(c.bytes_resident() <= 300, "budget enforced");
        assert!(c.get(2).is_some(), "referenced entry survived the sweep");
    }

    #[test]
    fn oversized_value_never_admitted_but_caller_arc_survives() {
        let c: ListCache<Vec<u8>> = ListCache::new(64, 1);
        let big = Arc::new(vec![0u8; 1000]);
        assert!(!c.insert(1, Arc::clone(&big), 1000));
        assert!(!c.insert(1, Arc::clone(&big), 1000));
        assert!(c.is_empty());
        assert_eq!(big.len(), 1000, "caller copy untouched");
    }

    #[test]
    fn eviction_does_not_invalidate_outstanding_arcs() {
        let c: ListCache<Vec<u8>> = ListCache::new(150, 1);
        assert!(admit(&c, 1, 100));
        let pinned = c.get(1).unwrap();
        // force 1 out: admit a second entry that busts the budget
        // (sweep clears 1's referenced bit, then evicts it)
        assert!(admit(&c, 2, 100));
        assert!(c.bytes_resident() <= 150);
        // the cache may have dropped its reference; ours still works
        assert_eq!(pinned.len(), 100);
    }

    #[test]
    fn shards_partition_keys() {
        let c: ListCache<Vec<u8>> = ListCache::new(1 << 20, 4);
        for k in 0..16 {
            admit(&c, k, 10);
        }
        assert_eq!(c.len(), 16);
        for k in 0..16 {
            assert!(c.get(k).is_some(), "key {k}");
        }
    }
}
