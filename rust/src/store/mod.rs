//! Tiny binary tensor store for trained baseline models and code blobs.
//!
//! A minimal, dependency-free container: a JSON header (name → shape,
//! dtype, byte offset, rendered by [`crate::util::json`]) followed by raw
//! little-endian payloads.  Used to cache trained quantizer codebooks and
//! encoded databases under `runs/` so benches re-run instantly.
//!
//! Siblings: [`blocks`] is the offset-addressable block archive the
//! disk IVF tier pages lists from, [`cache`] the byte-budgeted
//! hot-list cache in front of it (rust/DESIGN.md §11).

pub mod blocks;
pub mod cache;
pub mod wal;

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context};

use crate::util::json::Json;
use crate::Result;

const MAGIC: &[u8; 8] = b"UNQSTOR1";

/// The staging path `save` writes before renaming into place.
fn tmp_sibling(path: &Path) -> std::path::PathBuf {
    let mut name = path.file_name().unwrap_or_default().to_os_string();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Atomically replace `path` with `bytes`: write a `.tmp` sibling, fsync
/// it, and `rename` into place — the same crash contract as
/// [`Store::save`], shared by everything that commits small control
/// files (the streaming index's segment manifest, fresh WAL epochs).  A
/// crash at any point leaves either the old file or the new one, never a
/// torn mix.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let tmp = tmp_sibling(path);
    let mut f =
        File::create(&tmp).with_context(|| format!("create {tmp:?}"))?;
    f.write_all(bytes)?;
    f.sync_all()?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("commit {tmp:?} -> {path:?}"))?;
    sync_parent_dir(path)?;
    Ok(())
}

/// Fsync the directory holding `path`, so a just-committed rename (or
/// file creation) cannot be reordered after later operations by a
/// crash — the other half of the rename-commit contract.  Skipped on
/// platforms where directories cannot be opened as files.
fn sync_parent_dir(path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        if let Ok(d) = File::open(parent) {
            d.sync_all()
                .with_context(|| format!("fsync dir {parent:?}"))?;
        }
    }
    Ok(())
}

#[derive(Clone, Debug)]
struct Entry {
    dtype: String,
    shape: Vec<usize>,
    offset: u64,
    nbytes: u64,
}

impl Entry {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dtype", Json::Str(self.dtype.clone())),
            ("shape", Json::Arr(self.shape.iter().map(|&s| Json::Num(s as f64)).collect())),
            ("offset", Json::Num(self.offset as f64)),
            ("nbytes", Json::Num(self.nbytes as f64)),
        ])
    }

    fn from_json(j: &Json) -> Result<Entry> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .context("entry missing shape")?
            .iter()
            .map(|v| v.as_usize().context("bad shape element"))
            .collect::<Result<Vec<_>>>()?;
        Ok(Entry {
            dtype: j.req_str("dtype")?.to_string(),
            shape,
            offset: j.req_usize("offset")? as u64,
            nbytes: j.req_usize("nbytes")? as u64,
        })
    }
}

/// In-memory builder/reader of a tensor archive.
#[derive(Default)]
pub struct Store {
    f32s: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
    u8s: BTreeMap<String, (Vec<usize>, Vec<u8>)>,
    u32s: BTreeMap<String, (Vec<usize>, Vec<u32>)>,
    metas: BTreeMap<String, String>,
}

impl Store {
    pub fn new() -> Self {
        Store::default()
    }

    pub fn put_f32(&mut self, name: &str, shape: &[usize], data: Vec<f32>) {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        self.f32s.insert(name.to_string(), (shape.to_vec(), data));
    }

    pub fn put_u8(&mut self, name: &str, shape: &[usize], data: Vec<u8>) {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        self.u8s.insert(name.to_string(), (shape.to_vec(), data));
    }

    /// u32 tensors (inverted-list id-remap tables, list offsets).
    pub fn put_u32(&mut self, name: &str, shape: &[usize], data: Vec<u32>) {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        self.u32s.insert(name.to_string(), (shape.to_vec(), data));
    }

    /// Attach a small string metadata value (JSON-encode structured data).
    pub fn put_meta(&mut self, name: &str, value: &str) {
        self.metas.insert(name.to_string(), value.to_string());
    }

    pub fn get_f32(&self, name: &str) -> Option<(&[usize], &[f32])> {
        self.f32s.get(name).map(|(s, d)| (s.as_slice(), d.as_slice()))
    }

    pub fn get_u8(&self, name: &str) -> Option<(&[usize], &[u8])> {
        self.u8s.get(name).map(|(s, d)| (s.as_slice(), d.as_slice()))
    }

    pub fn get_u32(&self, name: &str) -> Option<(&[usize], &[u32])> {
        self.u32s.get(name).map(|(s, d)| (s.as_slice(), d.as_slice()))
    }

    pub fn get_meta(&self, name: &str) -> Option<&str> {
        self.metas.get(name).map(|s| s.as_str())
    }

    pub fn take_f32(&mut self, name: &str) -> Option<(Vec<usize>, Vec<f32>)> {
        self.f32s.remove(name)
    }

    pub fn take_u8(&mut self, name: &str) -> Option<(Vec<usize>, Vec<u8>)> {
        self.u8s.remove(name)
    }

    pub fn take_u32(&mut self, name: &str) -> Option<(Vec<usize>, Vec<u32>)> {
        self.u32s.remove(name)
    }

    /// Serialize to disk, atomically: the archive is written to a `.tmp`
    /// sibling and `rename`d into place, so a crash mid-save can never
    /// leave a torn file at `path` (the old archive, if any, survives
    /// intact until the rename commits).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut header: Vec<(String, Json)> = Vec::new();
        let mut offset = 0u64;
        for (name, (shape, data)) in &self.f32s {
            let nbytes = (data.len() * 4) as u64;
            header.push((name.clone(), Entry {
                dtype: "f32".into(), shape: shape.clone(), offset, nbytes,
            }.to_json()));
            offset += nbytes;
        }
        for (name, (shape, data)) in &self.u8s {
            let nbytes = data.len() as u64;
            header.push((name.clone(), Entry {
                dtype: "u8".into(), shape: shape.clone(), offset, nbytes,
            }.to_json()));
            offset += nbytes;
        }
        for (name, (shape, data)) in &self.u32s {
            let nbytes = (data.len() * 4) as u64;
            header.push((name.clone(), Entry {
                dtype: "u32".into(), shape: shape.clone(), offset, nbytes,
            }.to_json()));
            offset += nbytes;
        }
        let header_json = Json::Obj(header).render().into_bytes();
        let meta_json = Json::Obj(
            self.metas.iter()
                .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                .collect(),
        ).render().into_bytes();

        let tmp = tmp_sibling(path);
        let file =
            File::create(&tmp).with_context(|| format!("create {tmp:?}"))?;
        let mut w = BufWriter::new(file);
        w.write_all(MAGIC)?;
        w.write_all(&(header_json.len() as u64).to_le_bytes())?;
        w.write_all(&(meta_json.len() as u64).to_le_bytes())?;
        w.write_all(&header_json)?;
        w.write_all(&meta_json)?;
        for (_, (_, data)) in &self.f32s {
            // bulk little-endian write
            let mut buf = Vec::with_capacity(data.len() * 4);
            for v in data {
                buf.extend_from_slice(&v.to_le_bytes());
            }
            w.write_all(&buf)?;
        }
        for (_, (_, data)) in &self.u8s {
            w.write_all(data)?;
        }
        for (_, (_, data)) in &self.u32s {
            let mut buf = Vec::with_capacity(data.len() * 4);
            for v in data {
                buf.extend_from_slice(&v.to_le_bytes());
            }
            w.write_all(&buf)?;
        }
        w.flush()?;
        // durability before the commit point: the rename must not land a
        // file whose pages were never pushed to the OS
        w.into_inner()
            .map_err(|e| anyhow::anyhow!("flush {tmp:?}: {e}"))?
            .sync_all()?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("commit {tmp:?} -> {path:?}"))?;
        sync_parent_dir(path)?;
        Ok(())
    }

    /// Load an archive fully into memory.
    pub fn load(path: &Path) -> Result<Store> {
        let file = File::open(path).with_context(|| format!("open {path:?}"))?;
        let mut r = BufReader::new(file);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        ensure!(&magic == MAGIC, "bad store magic in {path:?}");
        let mut len8 = [0u8; 8];
        r.read_exact(&mut len8)?;
        let hlen = u64::from_le_bytes(len8) as usize;
        r.read_exact(&mut len8)?;
        let mlen = u64::from_le_bytes(len8) as usize;
        let mut hjson = vec![0u8; hlen];
        r.read_exact(&mut hjson)?;
        let mut mjson = vec![0u8; mlen];
        r.read_exact(&mut mjson)?;
        let header = Json::parse(std::str::from_utf8(&hjson)?)?;
        let metas_json = Json::parse(std::str::from_utf8(&mjson)?)?;

        let mut metas = BTreeMap::new();
        if let Json::Obj(pairs) = &metas_json {
            for (k, v) in pairs {
                if let Some(s) = v.as_str() {
                    metas.insert(k.clone(), s.to_string());
                }
            }
        }

        let payload_start = (8 + 16 + hlen + mlen) as u64;
        let mut store = Store { metas, ..Default::default() };
        let Json::Obj(entries) = &header else {
            bail!("store header is not an object in {path:?}");
        };
        for (name, ej) in entries {
            let e = Entry::from_json(ej)?;
            r.seek(SeekFrom::Start(payload_start + e.offset))?;
            let mut raw = vec![0u8; e.nbytes as usize];
            r.read_exact(&mut raw)?;
            match e.dtype.as_str() {
                "f32" => {
                    let data: Vec<f32> = raw
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect();
                    store.f32s.insert(name.clone(), (e.shape, data));
                }
                "u8" => {
                    store.u8s.insert(name.clone(), (e.shape, raw));
                }
                "u32" => {
                    let data: Vec<u32> = raw
                        .chunks_exact(4)
                        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect();
                    store.u32s.insert(name.clone(), (e.shape, data));
                }
                other => bail!("unknown dtype {other} in {path:?}"),
            }
        }
        Ok(store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TempDir;

    #[test]
    fn roundtrip() {
        let dir = TempDir::new("store").unwrap();
        let p = dir.path().join("m.store");
        let mut s = Store::new();
        s.put_f32("codebooks", &[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        s.put_u8("codes", &[4], vec![9, 8, 7, 6]);
        s.put_meta("cfg", "{\"m\":8}");
        s.save(&p).unwrap();

        let back = Store::load(&p).unwrap();
        let (shape, data) = back.get_f32("codebooks").unwrap();
        assert_eq!(shape, &[2, 3]);
        assert_eq!(data, &[1., 2., 3., 4., 5., 6.]);
        let (ushape, udata) = back.get_u8("codes").unwrap();
        assert_eq!(ushape, &[4]);
        assert_eq!(udata, &[9, 8, 7, 6]);
        assert_eq!(back.get_meta("cfg"), Some("{\"m\":8}"));
        assert!(back.get_f32("nope").is_none());
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = TempDir::new("store").unwrap();
        let p = dir.path().join("bad.store");
        std::fs::write(&p, b"NOTASTORE_____").unwrap();
        assert!(Store::load(&p).is_err());
    }

    #[test]
    fn multiple_tensors_order_independent() {
        let dir = TempDir::new("store").unwrap();
        let p = dir.path().join("m.store");
        let mut s = Store::new();
        s.put_f32("z", &[1], vec![3.0]);
        s.put_f32("a", &[1], vec![1.0]);
        s.put_u8("m", &[2], vec![1, 2]);
        s.save(&p).unwrap();
        let back = Store::load(&p).unwrap();
        assert_eq!(back.get_f32("a").unwrap().1, &[1.0]);
        assert_eq!(back.get_f32("z").unwrap().1, &[3.0]);
        assert_eq!(back.get_u8("m").unwrap().1, &[1, 2]);
    }

    #[test]
    fn u32_roundtrip() {
        let dir = TempDir::new("store").unwrap();
        let p = dir.path().join("u.store");
        let mut s = Store::new();
        s.put_u32("remap", &[2, 3], vec![0, 7, u32::MAX, 42, 1, 2]);
        s.put_f32("c", &[1], vec![0.5]);
        s.put_u8("b", &[1], vec![3]);
        s.save(&p).unwrap();
        let back = Store::load(&p).unwrap();
        let (shape, data) = back.get_u32("remap").unwrap();
        assert_eq!(shape, &[2, 3]);
        assert_eq!(data, &[0, 7, u32::MAX, 42, 1, 2]);
        assert!(back.get_u32("nope").is_none());
        // mixed dtypes coexist with correct payload offsets
        assert_eq!(back.get_f32("c").unwrap().1, &[0.5]);
        assert_eq!(back.get_u8("b").unwrap().1, &[3]);
        let mut owned = back;
        assert_eq!(owned.take_u32("remap").unwrap().1[2], u32::MAX);
        assert!(owned.get_u32("remap").is_none());
    }

    #[test]
    fn save_is_atomic_no_tmp_left_and_overwrite_safe() {
        let dir = TempDir::new("store").unwrap();
        let p = dir.path().join("a.store");
        let mut s = Store::new();
        s.put_f32("x", &[1], vec![1.0]);
        s.save(&p).unwrap();
        // a stale .tmp from a simulated crashed save must not break a
        // later save, and the commit must consume the staging file
        std::fs::write(tmp_sibling(&p), b"torn partial write").unwrap();
        let mut s2 = Store::new();
        s2.put_f32("x", &[1], vec![2.0]);
        s2.save(&p).unwrap();
        assert!(!tmp_sibling(&p).exists(), "staging file must be renamed");
        assert_eq!(Store::load(&p).unwrap().get_f32("x").unwrap().1, &[2.0]);
        // and a crash *before* the rename leaves the old archive intact:
        // a fresh torn .tmp alongside never affects loads of `p`
        std::fs::write(tmp_sibling(&p), b"torn").unwrap();
        assert_eq!(Store::load(&p).unwrap().get_f32("x").unwrap().1, &[2.0]);
    }

    #[test]
    fn large_tensor_roundtrip() {
        let dir = TempDir::new("store").unwrap();
        let p = dir.path().join("big.store");
        let data: Vec<f32> = (0..100_000).map(|i| i as f32 * 0.5).collect();
        let mut s = Store::new();
        s.put_f32("big", &[100, 1000], data.clone());
        s.save(&p).unwrap();
        let back = Store::load(&p).unwrap();
        assert_eq!(back.get_f32("big").unwrap().1, &data[..]);
    }
}
