//! Write-ahead log for the mutable streaming index.
//!
//! An append-only file of CRC-framed records describing every mutation
//! since the last checkpoint (`rust/DESIGN.md` §7):
//!
//! ```text
//! file   := header record*
//! header := "UNQWAL01" stride:u32le flags:u32le          (16 bytes)
//! record := len:u32le crc32:u32le payload[len]
//! payload:= 0x01 id:u32le list:u32le tag:u64le code[stride]   insert
//!         | 0x02 id:u32le                                     delete
//!         | 0x03 seg_id:u64le                                 seal
//! ```
//!
//! Appends are buffered and fsync'd in batches: [`Wal::append`] syncs
//! after `sync_every` pending records, and [`Wal::commit`] forces the
//! batch down before a write operation reports success to its caller.
//!
//! Crash contract: a torn tail (incomplete frame, short payload, CRC
//! mismatch) marks the end of the committed prefix — [`replay`] returns
//! every record before the tear plus the byte length of the valid
//! prefix, and [`Wal::open_append`] truncates the tear away before new
//! appends, so one crash can never corrupt the records that follow it.
//! The recovery property test in `index::segment` drives a truncation
//! through every byte boundary of the final record and checks the
//! recovered index equals the pre-crash prefix.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context};

use crate::Result;

const MAGIC: &[u8; 8] = b"UNQWAL01";
/// Header length: magic + stride + flags.
pub const HEADER_LEN: u64 = 16;
/// Upper bound on one record's payload — far above any real record
/// (1 + 16 + stride bytes), so a corrupt length field can't trigger a
/// giant allocation during replay.
const MAX_RECORD: usize = 1 << 20;

const KIND_INSERT: u8 = 1;
const KIND_DELETE: u8 = 2;
const KIND_SEAL: u8 = 3;

/// One logged mutation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WalRecord {
    /// A row was appended to the active segment: external id, routed
    /// list (0 for unrouted indexes), its metadata tag (0 for untagged
    /// inserts — rust/DESIGN.md §13), and its encoded code bytes —
    /// replay never re-encodes, so recovery needs no quantizer.
    Insert { id: u32, list: u32, tag: u64, code: Vec<u8> },
    /// An external id was tombstoned.
    Delete { id: u32 },
    /// The active segment was sealed as `seg_id`; replay seals at the
    /// same point so (segment, row) locations reproduce exactly.
    Seal { seg_id: u64 },
}

impl WalRecord {
    fn payload(&self) -> Vec<u8> {
        match self {
            WalRecord::Insert { id, list, tag, code } => {
                let mut p = Vec::with_capacity(17 + code.len());
                p.push(KIND_INSERT);
                p.extend_from_slice(&id.to_le_bytes());
                p.extend_from_slice(&list.to_le_bytes());
                p.extend_from_slice(&tag.to_le_bytes());
                p.extend_from_slice(code);
                p
            }
            WalRecord::Delete { id } => {
                let mut p = Vec::with_capacity(5);
                p.push(KIND_DELETE);
                p.extend_from_slice(&id.to_le_bytes());
                p
            }
            WalRecord::Seal { seg_id } => {
                let mut p = Vec::with_capacity(9);
                p.push(KIND_SEAL);
                p.extend_from_slice(&seg_id.to_le_bytes());
                p
            }
        }
    }

    /// Parse one payload; `None` marks corruption (unknown kind or a
    /// size that doesn't match it), which replay treats as a tear.
    fn parse(payload: &[u8], stride: usize) -> Option<WalRecord> {
        match payload.first()? {
            &KIND_INSERT if payload.len() == 17 + stride => {
                Some(WalRecord::Insert {
                    id: u32::from_le_bytes(payload[1..5].try_into().ok()?),
                    list: u32::from_le_bytes(payload[5..9].try_into().ok()?),
                    tag: u64::from_le_bytes(payload[9..17].try_into().ok()?),
                    code: payload[17..].to_vec(),
                })
            }
            &KIND_DELETE if payload.len() == 5 => {
                Some(WalRecord::Delete {
                    id: u32::from_le_bytes(payload[1..5].try_into().ok()?),
                })
            }
            &KIND_SEAL if payload.len() == 9 => {
                Some(WalRecord::Seal {
                    seg_id: u64::from_le_bytes(payload[1..9].try_into().ok()?),
                })
            }
            _ => None,
        }
    }
}

/// CRC-32 (IEEE, reflected — the zlib polynomial), bitwise: the log is
/// control-plane traffic, simplicity beats a table here.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// An open log accepting appends.
///
/// Batching is an explicit in-memory buffer (not a `BufWriter`): until
/// [`Wal::commit`] succeeds, buffered records have touched nothing on
/// disk, and a failed commit rolls the file back to the last durable
/// frontier and drops the batch — so a write error can never leave
/// phantom records that a *later* flush would resurrect.  If even the
/// rollback fails the log poisons itself and refuses further appends.
pub struct Wal {
    file: File,
    stride: usize,
    /// encoded records appended since the last successful commit
    buf: Vec<u8>,
    /// records currently in `buf`
    pending: usize,
    /// fsync after this many buffered records (1 = every record)
    sync_every: usize,
    /// durable, committed file length
    synced_len: u64,
    poisoned: bool,
}

impl Wal {
    /// Create a fresh log at `path` (truncating anything there), write
    /// and sync the header.
    pub fn create(path: &Path, stride: usize, sync_every: usize)
                  -> Result<Wal> {
        ensure!(stride > 0, "wal stride must be positive");
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut file = File::create(path)
            .with_context(|| format!("create wal {path:?}"))?;
        file.write_all(MAGIC)?;
        file.write_all(&(stride as u32).to_le_bytes())?;
        file.write_all(&0u32.to_le_bytes())?;
        file.sync_all()?;
        Ok(Wal {
            file,
            stride,
            buf: Vec::new(),
            pending: 0,
            sync_every: sync_every.max(1),
            synced_len: HEADER_LEN,
            poisoned: false,
        })
    }

    /// Reopen an existing log for appending after [`replay`] validated
    /// its prefix: the torn tail (if any) past `good_len` is truncated
    /// away so new records can never land behind garbage.
    pub fn open_append(path: &Path, stride: usize, good_len: u64,
                       sync_every: usize) -> Result<Wal> {
        ensure!(good_len >= HEADER_LEN,
                "wal prefix {good_len} shorter than the header");
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(path)
            .with_context(|| format!("open wal {path:?}"))?;
        file.set_len(good_len)?;
        file.sync_all()?;
        file.seek(SeekFrom::Start(good_len))?;
        Ok(Wal {
            file,
            stride,
            buf: Vec::new(),
            pending: 0,
            sync_every: sync_every.max(1),
            synced_len: good_len,
            poisoned: false,
        })
    }

    /// Append one record (buffered; syncs when the batch fills).
    pub fn append(&mut self, rec: &WalRecord) -> Result<()> {
        ensure!(!self.poisoned, "wal is poisoned after a failed rollback");
        if let WalRecord::Insert { code, .. } = rec {
            ensure!(code.len() == self.stride,
                    "insert code length {} != wal stride {}",
                    code.len(), self.stride);
        }
        crate::obs::global().wal_appends.inc();
        let payload = rec.payload();
        self.buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        self.buf.extend_from_slice(&crc32(&payload).to_le_bytes());
        self.buf.extend_from_slice(&payload);
        self.pending += 1;
        if self.pending >= self.sync_every {
            self.commit()?;
        }
        Ok(())
    }

    /// Force the pending batch to stable storage (the durability point a
    /// write operation reports success behind).  On failure the batch is
    /// DROPPED and the file rolled back to the previous durable frontier
    /// — the caller's operation fails as a unit, nothing half-lands.
    pub fn commit(&mut self) -> Result<()> {
        ensure!(!self.poisoned, "wal is poisoned after a failed rollback");
        if self.buf.is_empty() {
            return Ok(());
        }
        let t0 = std::time::Instant::now();
        let res = self
            .file
            .write_all(&self.buf)
            .and_then(|()| self.file.sync_data());
        let reg = crate::obs::global();
        reg.wal_commits.inc();
        reg.wal_fsync_us.record(t0.elapsed().as_micros() as u64);
        match res {
            Ok(()) => {
                self.synced_len += self.buf.len() as u64;
                self.buf.clear();
                self.pending = 0;
                Ok(())
            }
            Err(e) => {
                // drop the batch and truncate whatever partially landed
                self.buf.clear();
                self.pending = 0;
                let rollback = self
                    .file
                    .set_len(self.synced_len)
                    .and_then(|()| {
                        self.file.seek(SeekFrom::Start(self.synced_len))
                    });
                if rollback.is_err() {
                    self.poisoned = true;
                }
                Err(e).context("wal commit (batch dropped)")
            }
        }
    }

    /// Discard the records buffered since the last commit (successful or
    /// failed) without touching the file — callers drop a half-appended
    /// batch with this so no later commit can flush its remains.  (Every
    /// operation ends in `commit`, so the buffer only ever holds the
    /// current operation's records.)
    pub fn abort_batch(&mut self) {
        self.buf.clear();
        self.pending = 0;
    }

    /// Records appended but not yet durable.
    pub fn uncommitted(&self) -> usize {
        self.pending
    }

    /// Logical length in bytes (committed + buffered).
    pub fn len(&self) -> u64 {
        self.synced_len + self.buf.len() as u64
    }

    pub fn is_empty(&self) -> bool {
        self.len() <= HEADER_LEN
    }
}

/// Read a log back: every record of the valid prefix, plus that prefix's
/// byte length (pass it to [`Wal::open_append`]).  A torn or corrupt
/// tail ends the prefix silently — that is the crash contract, not an
/// error; only a missing/foreign header or a stride mismatch errors.
pub fn replay(path: &Path, stride: usize) -> Result<(Vec<WalRecord>, u64)> {
    let mut f =
        File::open(path).with_context(|| format!("open wal {path:?}"))?;
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)?;
    ensure!(bytes.len() >= HEADER_LEN as usize && &bytes[..8] == MAGIC,
            "wal {path:?} has no valid header");
    let got_stride =
        u32::from_le_bytes(bytes[8..12].try_into().expect("4 bytes")) as usize;
    if got_stride != stride {
        bail!("wal {path:?} stride {got_stride} != index stride {stride}");
    }
    let mut records = Vec::new();
    let mut pos = HEADER_LEN as usize;
    loop {
        let Some(frame) = bytes.get(pos..pos + 8) else { break };
        let len =
            u32::from_le_bytes(frame[0..4].try_into().expect("4 bytes"))
                as usize;
        let crc = u32::from_le_bytes(frame[4..8].try_into().expect("4 bytes"));
        if len == 0 || len > MAX_RECORD {
            break;
        }
        let Some(payload) = bytes.get(pos + 8..pos + 8 + len) else { break };
        if crc32(payload) != crc {
            break;
        }
        let Some(rec) = WalRecord::parse(payload, stride) else { break };
        records.push(rec);
        pos += 8 + len;
    }
    Ok((records, pos as u64))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TempDir;

    fn sample_records(stride: usize) -> Vec<WalRecord> {
        vec![
            WalRecord::Insert {
                id: 0,
                list: 0,
                tag: 0,
                code: (0..stride as u8).collect(),
            },
            WalRecord::Insert {
                id: 1,
                list: 3,
                tag: u64::MAX,
                code: vec![0xAB; stride],
            },
            WalRecord::Delete { id: 0 },
            WalRecord::Seal { seg_id: 7 },
            WalRecord::Insert {
                id: 2,
                list: u32::MAX,
                tag: 0xDEAD_BEEF,
                code: vec![0x11; stride],
            },
        ]
    }

    #[test]
    fn crc32_known_answer() {
        // the standard CRC-32/ISO-HDLC check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip_all_record_kinds() {
        let dir = TempDir::new("wal").unwrap();
        let p = dir.path().join("w.log");
        let recs = sample_records(6);
        let mut wal = Wal::create(&p, 6, 2).unwrap();
        for r in &recs {
            wal.append(r).unwrap();
        }
        wal.commit().unwrap();
        let (back, good) = replay(&p, 6).unwrap();
        assert_eq!(back, recs);
        assert_eq!(good, wal.len());
        assert_eq!(good, std::fs::metadata(&p).unwrap().len());
    }

    #[test]
    fn replay_rejects_header_problems() {
        let dir = TempDir::new("wal").unwrap();
        let p = dir.path().join("w.log");
        std::fs::write(&p, b"NOTAWAL!").unwrap();
        assert!(replay(&p, 4).is_err(), "foreign magic");
        let mut wal = Wal::create(&p, 4, 1).unwrap();
        wal.append(&WalRecord::Delete { id: 9 }).unwrap();
        wal.commit().unwrap();
        assert!(replay(&p, 8).is_err(), "stride mismatch");
    }

    #[test]
    fn prop_truncation_at_every_byte_recovers_the_prefix() {
        // record byte offsets, then chop the file at EVERY byte length
        // and check replay returns exactly the records that fully fit
        let dir = TempDir::new("wal").unwrap();
        let p = dir.path().join("w.log");
        let recs = sample_records(5);
        let mut wal = Wal::create(&p, 5, 1).unwrap();
        let mut ends = vec![wal.len()]; // ends[i] = length after i records
        for r in &recs {
            wal.append(r).unwrap();
            wal.commit().unwrap();
            ends.push(wal.len());
        }
        let full = std::fs::read(&p).unwrap();
        assert_eq!(full.len() as u64, *ends.last().unwrap());
        let cut_path = dir.path().join("cut.log");
        for cut in 0..=full.len() {
            std::fs::write(&cut_path, &full[..cut]).unwrap();
            if (cut as u64) < HEADER_LEN {
                assert!(replay(&cut_path, 5).is_err(),
                        "cut {cut} inside the header must error");
                continue;
            }
            let n_fit =
                ends.iter().filter(|&&e| e <= cut as u64).count() - 1;
            let (back, good) = replay(&cut_path, 5).unwrap();
            assert_eq!(back, recs[..n_fit], "cut at byte {cut}");
            assert_eq!(good, ends[n_fit], "cut at byte {cut}");
        }
    }

    #[test]
    fn corrupt_crc_ends_the_prefix_and_open_append_truncates_it() {
        let dir = TempDir::new("wal").unwrap();
        let p = dir.path().join("w.log");
        let recs = sample_records(3);
        let mut wal = Wal::create(&p, 3, 1).unwrap();
        let mut ends = vec![wal.len()];
        for r in &recs {
            wal.append(r).unwrap();
            wal.commit().unwrap();
            ends.push(wal.len());
        }
        // flip one payload byte of record 3 (its CRC now mismatches):
        // replay keeps records 0..3 and cuts there, even though record 4
        // is intact after it
        let mut bytes = std::fs::read(&p).unwrap();
        let idx = ends[3] as usize + 8;
        bytes[idx] ^= 0x40;
        std::fs::write(&p, &bytes).unwrap();
        let (back, good) = replay(&p, 3).unwrap();
        assert_eq!(back, recs[..3]);
        assert_eq!(good, ends[3]);
        // reopening for append truncates the garbage and new appends
        // extend the valid prefix
        let mut wal = Wal::open_append(&p, 3, good, 1).unwrap();
        wal.append(&WalRecord::Delete { id: 42 }).unwrap();
        wal.commit().unwrap();
        let (back, _) = replay(&p, 3).unwrap();
        assert_eq!(back.len(), 4);
        assert_eq!(back[3], WalRecord::Delete { id: 42 });
    }

    #[test]
    fn sync_every_batches_but_commit_always_lands() {
        let dir = TempDir::new("wal").unwrap();
        let p = dir.path().join("w.log");
        let mut wal = Wal::create(&p, 2, 100).unwrap();
        wal.append(&WalRecord::Delete { id: 1 }).unwrap();
        // buffered: on-disk file may still be header-only (don't assert
        // that — flush timing is the writer's business), but after
        // commit() the record must be durable and visible
        wal.commit().unwrap();
        let (back, _) = replay(&p, 2).unwrap();
        assert_eq!(back, vec![WalRecord::Delete { id: 1 }]);
    }
}
