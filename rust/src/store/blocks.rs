//! Offset-addressable block archive (rust/DESIGN.md §11).
//!
//! A single segment file whose header carries a per-block directory of
//! `(offset, len, rows, crc)` entries, so a reader can fetch exactly
//! the blocks it needs without loading the whole file:
//!
//! ```text
//! ┌──────────┬──────────┬───────────┬───────────┬────────────┬────┐
//! │ magic 8B │ meta_len │ dir_count │ meta JSON │ directory  │ …  │
//! │ UNQBLKS1 │ u64 LE   │ u64 LE    │ bytes     │ 32B/entry  │data│
//! └──────────┴──────────┴───────────┴───────────┴────────────┴────┘
//! directory entry: offset u64 · len u64 · rows u64 · crc32 u64
//! ```
//!
//! Offsets are absolute file positions; blocks are laid out
//! back-to-back after the directory in entry order.  Writes go through
//! the same tmp + rename + fsync path as [`super::Store::save`], so a
//! crash mid-write never leaves a torn archive at the destination.
//! Reads come in two flavors: [`BlockReader::read_all`] (wholesale,
//! today's behavior — kept as the oracle) and [`BlockReader::read_block`]
//! (a positioned `pread` of one block).  We use `pread` rather than
//! mmap: it needs no `unsafe`, the access pattern is whole-block (no
//! sub-page random touch for the page cache to win on), and the kernel
//! page cache already keeps hot blocks resident across calls.
//!
//! Every block is CRC32-checksummed (IEEE polynomial); a mismatch on
//! read is a typed error, never a panic, so a corrupted list degrades
//! to a failed query instead of a crashed server.

use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::os::unix::fs::FileExt;
use std::path::Path;

use anyhow::{bail, ensure, Context};

use crate::util::json::Json;
use crate::Result;

/// Archive magic — distinct from the tensor store's `UNQSTOR1`.
const MAGIC: &[u8; 8] = b"UNQBLKS1";

/// Bytes per directory entry: offset, len, rows, crc (each u64 LE).
const DIR_ENTRY_BYTES: usize = 32;

// ---------------------------------------------------------------- crc32

/// IEEE CRC32 table (polynomial 0xEDB88320), built at compile time —
/// the crate vendors no checksum dependency.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC32 (IEEE) of `data`.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = !0u32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    !c
}

// ------------------------------------------------------------- directory

/// One directory entry: where a block lives and how to verify it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockEntry {
    /// Absolute file offset of the block payload.
    pub offset: u64,
    /// Payload length in bytes.
    pub len: u64,
    /// Logical row count (caller-defined; 0 for non-tabular blocks).
    pub rows: u64,
    /// CRC32 of the payload.
    pub crc: u32,
}

// ---------------------------------------------------------------- writer

/// Write a block archive atomically: tmp sibling → buffered write →
/// flush → fsync → rename → parent-dir fsync (the [`super::Store`]
/// crash contract).  `blocks` are `(payload, rows)` pairs laid out in
/// order; `meta` is an arbitrary JSON object the reader hands back.
pub fn write_archive(path: &Path, meta: &Json,
                     blocks: &[(&[u8], u64)]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)
            .with_context(|| format!("create dir {parent:?}"))?;
    }
    let meta_bytes = meta.render().into_bytes();
    let header_len = MAGIC.len() + 16 + meta_bytes.len()
        + blocks.len() * DIR_ENTRY_BYTES;

    // directory first, so offsets are known before any payload is out
    let mut dir = Vec::with_capacity(blocks.len());
    let mut offset = header_len as u64;
    for (payload, rows) in blocks {
        dir.push(BlockEntry {
            offset,
            len: payload.len() as u64,
            rows: *rows,
            crc: crc32(payload),
        });
        offset += payload.len() as u64;
    }

    let tmp = super::tmp_sibling(path);
    let f = File::create(&tmp).with_context(|| format!("create {tmp:?}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&(meta_bytes.len() as u64).to_le_bytes())?;
    w.write_all(&(blocks.len() as u64).to_le_bytes())?;
    w.write_all(&meta_bytes)?;
    for e in &dir {
        w.write_all(&e.offset.to_le_bytes())?;
        w.write_all(&e.len.to_le_bytes())?;
        w.write_all(&e.rows.to_le_bytes())?;
        w.write_all(&(e.crc as u64).to_le_bytes())?;
    }
    for (payload, _) in blocks {
        w.write_all(payload)?;
    }
    w.flush()?;
    w.into_inner()
        .map_err(|e| anyhow::anyhow!("flush {tmp:?}: {e}"))?
        .sync_all()
        .with_context(|| format!("fsync {tmp:?}"))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename {tmp:?} → {path:?}"))?;
    super::sync_parent_dir(path)?;
    Ok(())
}

// ---------------------------------------------------------------- reader

/// Lazy reader over a block archive: the header and directory are
/// parsed once at open; block payloads are `pread` on demand and
/// CRC-verified on every read.  `&self` reads are positioned
/// (`read_exact_at`), so one reader is safely shared across threads.
pub struct BlockReader {
    file: File,
    /// Archive metadata JSON, as written.
    pub meta: Json,
    dir: Vec<BlockEntry>,
}

impl BlockReader {
    /// Open and validate an archive: magic, header layout, and every
    /// directory entry bounds-checked against the file length.
    pub fn open(path: &Path) -> Result<BlockReader> {
        let mut file =
            File::open(path).with_context(|| format!("open {path:?}"))?;
        let file_len = file.metadata()?.len();
        let mut fixed = [0u8; 24];
        file.read_exact(&mut fixed)
            .with_context(|| format!("short header in {path:?}"))?;
        ensure!(&fixed[..8] == MAGIC, "bad block-archive magic in {path:?}");
        let meta_len = u64::from_le_bytes(fixed[8..16].try_into().unwrap());
        let dir_count = u64::from_le_bytes(fixed[16..24].try_into().unwrap());
        let header_len = 24u64
            .checked_add(meta_len)
            .and_then(|v| v.checked_add(
                dir_count.checked_mul(DIR_ENTRY_BYTES as u64)?))
            .filter(|&v| v <= file_len);
        let Some(header_len) = header_len else {
            bail!("block-archive header overruns file in {path:?} \
                   (meta {meta_len}B, {dir_count} entries, file {file_len}B)");
        };
        let mut rest = vec![0u8; (header_len - 24) as usize];
        file.read_exact(&mut rest)
            .with_context(|| format!("short directory in {path:?}"))?;
        let meta_str = std::str::from_utf8(&rest[..meta_len as usize])
            .with_context(|| format!("non-utf8 meta in {path:?}"))?;
        let meta = Json::parse(meta_str)
            .with_context(|| format!("parse meta in {path:?}"))?;
        let mut dir = Vec::with_capacity(dir_count as usize);
        let mut cursor = meta_len as usize;
        for i in 0..dir_count {
            let e = &rest[cursor..cursor + DIR_ENTRY_BYTES];
            cursor += DIR_ENTRY_BYTES;
            let word = |j: usize| {
                u64::from_le_bytes(e[8 * j..8 * j + 8].try_into().unwrap())
            };
            let (offset, len, rows, crc) =
                (word(0), word(1), word(2), word(3));
            ensure!(
                offset >= header_len
                    && offset.checked_add(len).is_some_and(|e| e <= file_len),
                "block {i} spans {offset}..{} outside archive {path:?} \
                 ({file_len}B)",
                offset.saturating_add(len)
            );
            ensure!(crc <= u32::MAX as u64,
                    "block {i} crc field overflows u32 in {path:?}");
            dir.push(BlockEntry { offset, len, rows, crc: crc as u32 });
        }
        Ok(BlockReader { file, meta, dir })
    }

    pub fn num_blocks(&self) -> usize {
        self.dir.len()
    }

    /// Directory entry for block `i` (offset/len/rows/crc).
    pub fn entry(&self, i: usize) -> &BlockEntry {
        &self.dir[i]
    }

    /// `pread` one block and verify its CRC.  A mismatch (bit rot,
    /// torn write surviving a crash) is a typed error, not a panic.
    pub fn read_block(&self, i: usize) -> Result<Vec<u8>> {
        let e = *self.entry(i);
        let t0 = std::time::Instant::now();
        let mut buf = vec![0u8; e.len as usize];
        self.file
            .read_exact_at(&mut buf, e.offset)
            .with_context(|| format!("pread block {i} ({}B @ {})",
                                     e.len, e.offset))?;
        crate::obs::global()
            .blockio_read_us
            .record(t0.elapsed().as_micros() as u64);
        let got = crc32(&buf);
        if got != e.crc {
            bail!("block {i} crc mismatch: stored {:#010x}, computed \
                   {:#010x} ({}B @ {})", e.crc, got, e.len, e.offset);
        }
        Ok(buf)
    }

    /// Wholesale read of every block, in directory order — the oracle
    /// path equivalent to loading the whole file up front.
    pub fn read_all(&self) -> Result<Vec<Vec<u8>>> {
        (0..self.num_blocks()).map(|i| self.read_block(i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TempDir;

    fn blocks_of(parts: &[Vec<u8>]) -> Vec<(&[u8], u64)> {
        parts.iter().map(|p| (p.as_slice(), p.len() as u64)).collect()
    }

    #[test]
    fn crc32_known_vectors() {
        // canonical IEEE CRC32 test vector
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip_blocks_and_meta() {
        let dir = TempDir::new("blocks").unwrap();
        let path = dir.path().join("a.blocks");
        let parts = vec![vec![1u8, 2, 3], Vec::new(), vec![9u8; 4096]];
        let meta = Json::obj(vec![("kind", Json::Str("test".into()))]);
        write_archive(&path, &meta, &blocks_of(&parts)).unwrap();

        let r = BlockReader::open(&path).unwrap();
        assert_eq!(r.num_blocks(), 3);
        assert_eq!(r.meta.get("kind").and_then(Json::as_str), Some("test"));
        assert_eq!(r.entry(1).rows, 0);
        assert_eq!(r.entry(2).rows, 4096);
        for (i, want) in parts.iter().enumerate() {
            assert_eq!(&r.read_block(i).unwrap(), want, "block {i}");
        }
        assert_eq!(r.read_all().unwrap(), parts);
    }

    #[test]
    fn corrupted_block_is_typed_error() {
        let dir = TempDir::new("blocks").unwrap();
        let path = dir.path().join("a.blocks");
        let parts = vec![vec![7u8; 100], vec![8u8; 100]];
        write_archive(&path, &Json::Null, &blocks_of(&parts)).unwrap();

        // flip one payload bit of block 1 in place
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 50] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();

        let r = BlockReader::open(&path).unwrap();
        assert!(r.read_block(0).is_ok(), "untouched block still reads");
        let err = r.read_block(1).unwrap_err().to_string();
        assert!(err.contains("crc mismatch"), "typed error, got: {err}");
    }

    #[test]
    fn bad_magic_and_truncation_rejected() {
        let dir = TempDir::new("blocks").unwrap();
        let path = dir.path().join("a.blocks");
        write_archive(&path, &Json::Null,
                      &blocks_of(&[vec![1u8; 64]])).unwrap();

        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] ^= 0xff;
        let bad = dir.path().join("bad.blocks");
        std::fs::write(&bad, &bytes).unwrap();
        assert!(BlockReader::open(&bad).unwrap_err()
                    .to_string().contains("magic"));

        // truncate into the payload: open succeeds only if the
        // directory still fits, and then bounds-checking must fire
        let whole = std::fs::read(&path).unwrap();
        let cut = dir.path().join("cut.blocks");
        std::fs::write(&cut, &whole[..whole.len() - 32]).unwrap();
        assert!(BlockReader::open(&cut).is_err());
    }

    #[test]
    fn write_is_atomic_no_tmp_left_and_overwrite_safe() {
        let dir = TempDir::new("blocks").unwrap();
        let path = dir.path().join("a.blocks");
        write_archive(&path, &Json::Null,
                      &blocks_of(&[vec![1u8; 16]])).unwrap();
        write_archive(&path, &Json::Null,
                      &blocks_of(&[vec![2u8; 16], vec![3u8; 16]])).unwrap();
        let r = BlockReader::open(&path).unwrap();
        assert_eq!(r.num_blocks(), 2);
        assert_eq!(r.read_block(0).unwrap(), vec![2u8; 16]);
        let leftovers: Vec<_> = std::fs::read_dir(dir.path())
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| {
                e.path().extension().is_some_and(|x| x == "tmp")
            })
            .collect();
        assert!(leftovers.is_empty(), "tmp sibling left behind");
    }
}
