//! Multi-codebook quantizers: the paper's method and every baseline.
//!
//! All quantizers expose the two modules the paper identifies (§3.1): an
//! *encoder* `f(x) → codes` and a *distance function* `d(q, code)` usable
//! for exhaustive compressed-domain scan.  The scan contract is uniform:
//! a per-query [`Lut`] whose entries are *distance contributions* (lower =
//! closer), summed over code bytes by the index hot loop — exactly the
//! paper's eq. (1)/(8) lookup structure.  The Catalyst lattice quantizer,
//! which has no LUT decomposition, scores codes directly (the paper notes
//! its scan is ~1.5× slower for the same reason).
//!
//! | impl | paper row | encoding | distance |
//! |---|---|---|---|
//! | [`pq::Pq`] | OPQ's ancestor (Table 1) | per-subspace NN | exact ADC |
//! | [`opq::Opq`] | "OPQ" | rotate + PQ | exact ADC |
//! | [`additive::Additive`] greedy | "RVQ" | residual greedy | ADC + norm byte |
//! | [`lsq::Lsq`] | "LSQ" / "LSQ+rerank" | ICM + LS codebooks | ADC + norm byte |
//! | [`lattice::CatalystLattice`] | "Catalyst+Lattice" | whiten→sphere→lattice | direct dot |
//! | [`lattice::CatalystOpq`] | "Catalyst+OPQ" | whiten→sphere→OPQ | ADC in mapped space |
//! | [`unq::UnqQuantizer`] | "UNQ" | AOT encoder (PJRT) | learned-space ADC + decoder rerank |
//! | [`unq_native::NativeUnq`] | "UNQ-native" | trained in-process (`nn`) | learned-space ADC (`d2`) + decoder rerank (`d1`) |

pub mod additive;
pub mod lattice;
pub mod lsq;
pub mod opq;
pub mod pq;
pub mod unq;
pub mod unq_native;

use crate::data::Dataset;

/// Per-query scoring structure handed to the index scan.
pub enum Lut {
    /// Position-major lookup tables, the one layout every scan path
    /// relies on (`Lut::score` here and the `index::scan` hot loop):
    /// `tables[j * k + c]` is the distance contribution of byte value `c`
    /// at code position `j`, i.e. position `j`'s table row occupies
    /// `tables[j*k .. (j+1)*k]` contiguously.  `bias` is the
    /// rank-invariant query constant (kept so scores are interpretable as
    /// approximate squared distances).
    Tables { m: usize, k: usize, tables: Vec<f32>, bias: f32 },
    /// Direct scoring against a transformed query (lattice path).
    Direct { q: Vec<f32>, bias: f32 },
}

impl Lut {
    /// Score one code (lower = closer).
    #[inline]
    pub fn score(&self, code: &[u8]) -> f32 {
        match self {
            Lut::Tables { m, k, tables, bias } => {
                debug_assert_eq!(code.len(), *m);
                let mut acc = *bias;
                for (j, &c) in code.iter().enumerate() {
                    acc += tables[j * k + c as usize];
                }
                acc
            }
            Lut::Direct { q, bias } => {
                // code holds i8 lattice coordinates
                let mut dot = 0.0f32;
                let mut nrm = 0.0f32;
                for (qi, &c) in q.iter().zip(code) {
                    let z = c as i8 as f32;
                    dot += qi * z;
                    nrm += z * z;
                }
                // cosine distance on the sphere (q is unit-norm)
                bias - dot / nrm.sqrt().max(1e-12)
            }
        }
    }
}

/// Integer-quantized mirror of a [`Lut::Tables`], the scan-precision
/// axis of the blocked fast-scan kernels
/// (`index::scan::scan_lut_topk_{u16,u8}` — rust/DESIGN.md §6).
///
/// Per query, every table row `j` is shifted by its own minimum `lo_j`
/// and scaled by one shared `step`, so integer scores from different
/// positions stay comparable:
///
/// ```text
/// qtables[j·K + c] = clamp(round((tables[j·K + c] − lo_j) / step), 0, 2ʷ−1)
/// step             = max_j (hi_j − lo_j) / (2ʷ − 1)
/// f32 score        ≈ bias + step · Σ_j qtables[j·K + code[j]]
/// bias             = lut bias + Σ_j lo_j
/// ```
///
/// With `step` derived from the per-position min/max, no entry genuinely
/// saturates — the clamp only guards float rounding at the tails (a
/// narrower, outlier-trimmed `step` would trade tail saturation for
/// resolution; see DESIGN.md §6 on when u8 saturation matters).  The
/// per-entry rounding error is ≤ `step/2`, so an integer score deviates
/// from the exact f32 score by at most [`Self::max_score_error`] =
/// `m · step / 2`.
#[derive(Clone, Debug)]
pub enum QuantizedLut {
    /// 16-bit entries: integer scores ≤ `m · 65535`, and the constructor
    /// rejects any `m` that could push a score to 2²⁴ or beyond (see
    /// [`Self::quantize`]), so every integer score is exactly
    /// representable as f32.
    U16 { m: usize, k: usize, tables: Vec<u16>, step: f32, bias: f32 },
    /// 8-bit entries: coarser (bigger `step`), faster (quarter the table
    /// bytes of f32, denser in L1).
    U8 { m: usize, k: usize, tables: Vec<u8>, step: f32, bias: f32 },
    /// 4-bit codes with 8-bit entries (the fast-scan layout): only built
    /// when the source LUT has `k ≤ 16` codewords, so a whole table row
    /// fits one 16-byte register and the SIMD kernels gather it in-place
    /// with PSHUFB/TBL (rust/DESIGN.md §9).  `tables` is padded to a
    /// fixed 16 entries per position (`tables[j·16 + c]`); `k` keeps the
    /// logical codeword count.  `m ≤ 256` is enforced so 32-lane u16
    /// accumulators cannot overflow (`256 · 255 < 2¹⁶`).
    U4 { m: usize, k: usize, tables: Vec<u8>, step: f32, bias: f32 },
}

/// Fixed row width of the [`QuantizedLut::U4`] tables: one SIMD register.
pub const U4_ROW: usize = 16;

impl QuantizedLut {
    /// Quantize a [`Lut::Tables`] to u16 entries (`None` for the
    /// lattice's `Direct` scoring, which has no table decomposition).
    pub fn u16_from(lut: &Lut) -> Option<QuantizedLut> {
        let (m, k, vals, step, bias) = Self::quantize(lut, 16)?;
        let tables = vals.into_iter().map(|v| v as u16).collect();
        Some(QuantizedLut::U16 { m, k, tables, step, bias })
    }

    /// Quantize a [`Lut::Tables`] to u8 entries.
    pub fn u8_from(lut: &Lut) -> Option<QuantizedLut> {
        let (m, k, vals, step, bias) = Self::quantize(lut, 8)?;
        let tables = vals.into_iter().map(|v| v as u8).collect();
        Some(QuantizedLut::U8 { m, k, tables, step, bias })
    }

    /// Quantize a [`Lut::Tables`] to the 4-bit fast-scan layout: u8
    /// entries laid out in fixed [`U4_ROW`]-wide rows.  `None` when the
    /// LUT has more than 16 codewords per position (codes would not fit a
    /// nibble), when `m > 256` (u16 SIMD accumulator lanes could wrap),
    /// or for direct-scored LUTs — callers fall back to the exact f32
    /// kernel exactly as for the other widths.
    pub fn u4_from(lut: &Lut) -> Option<QuantizedLut> {
        if let Lut::Tables { m, k, .. } = lut {
            if *k > U4_ROW || *m > 256 {
                return None;
            }
        }
        let (m, k, vals, step, bias) = Self::quantize(lut, 8)?;
        let mut tables = vec![0u8; m * U4_ROW];
        for j in 0..m {
            for c in 0..k {
                tables[j * U4_ROW + c] = vals[j * k + c] as u8;
            }
        }
        Some(QuantizedLut::U4 { m, k, tables, step, bias })
    }

    /// The width-independent core shared by the constructors: derive
    /// the affine map (per-position minima, one step over the widest
    /// range, bias absorbing the minima) and quantize every entry into
    /// `[0, 2^bits − 1]` — the clamp saturates the tails against
    /// rounding fuzz.  Entries come back as u32 and are narrowed by the
    /// callers (every value fits their width by construction).
    ///
    /// Rejects (returns `None`) any `(m, bits)` whose worst-case integer
    /// score `m · (2^bits − 1)` reaches 2²⁴: past that, sums are no
    /// longer exactly representable as f32, so the blocked kernels'
    /// lexicographic `(score, id)` selection could silently merge
    /// distinct integer scores — and at `m ≥ 65536` the u16 kernel's u32
    /// accumulator lanes would overflow outright.  Rejected LUTs fall
    /// back to the exact f32 scan through the usual `Option` machinery
    /// (a wider `step` could not help: the score ceiling is width-driven,
    /// not range-driven).
    fn quantize(lut: &Lut, bits: u32)
                -> Option<(usize, usize, Vec<u32>, f32, f32)> {
        let (m, k, tables, bias) = match lut {
            Lut::Tables { m, k, tables, bias } => (*m, *k, tables, *bias),
            Lut::Direct { .. } => return None,
        };
        let max_code = (1u32 << bits) - 1;
        if (m as u64) * max_code as u64 >= 1 << 24 {
            return None;
        }
        let mut lows = Vec::with_capacity(m);
        let mut step = 0.0f32;
        for j in 0..m {
            let row = &tables[j * k..(j + 1) * k];
            let lo = row.iter().copied().fold(f32::INFINITY, f32::min);
            let hi = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            lows.push(lo);
            step = step.max((hi - lo) / max_code as f32);
        }
        let lo_sum: f32 = lows.iter().sum();
        if !step.is_finite() || !lo_sum.is_finite() {
            return None;
        }
        if step <= 0.0 {
            // constant tables: every entry quantizes to 0 and the exact
            // rescore settles any ordering
            step = 1.0;
        }
        let mut vals = Vec::with_capacity(m * k);
        for j in 0..m {
            for c in 0..k {
                let v = ((tables[j * k + c] - lows[j]) / step).round();
                vals.push(if v >= max_code as f32 {
                    max_code
                } else if v > 0.0 {
                    v as u32
                } else {
                    0
                });
            }
        }
        Some((m, k, vals, step, bias + lo_sum))
    }

    #[inline]
    pub fn m(&self) -> usize {
        match self {
            QuantizedLut::U16 { m, .. }
            | QuantizedLut::U8 { m, .. }
            | QuantizedLut::U4 { m, .. } => *m,
        }
    }

    /// The shared score step: one integer unit ≈ this many f32 units.
    #[inline]
    pub fn step(&self) -> f32 {
        match self {
            QuantizedLut::U16 { step, .. }
            | QuantizedLut::U8 { step, .. }
            | QuantizedLut::U4 { step, .. } => *step,
        }
    }

    /// Upper bound on `|approx(score_int(code)) − lut.score(code)|`:
    /// `m · step / 2` (per-entry round-to-nearest error, summed).
    #[inline]
    pub fn max_score_error(&self) -> f32 {
        self.m() as f32 * self.step() * 0.5
    }

    /// Integer ADC score of one code row (the reference path mirrored by
    /// the blocked kernels; lower = closer).
    #[inline]
    pub fn score_int(&self, code: &[u8]) -> u32 {
        fn sum_entries<T: Copy + Into<u32>>(tables: &[T], k: usize,
                                            code: &[u8]) -> u32 {
            code.iter()
                .enumerate()
                .map(|(j, &c)| tables[j * k + c as usize].into())
                .sum()
        }
        match self {
            QuantizedLut::U16 { m, k, tables, .. } => {
                debug_assert_eq!(code.len(), *m);
                sum_entries(tables, *k, code)
            }
            QuantizedLut::U8 { m, k, tables, .. } => {
                debug_assert_eq!(code.len(), *m);
                sum_entries(tables, *k, code)
            }
            QuantizedLut::U4 { m, tables, .. } => {
                // rows are padded to the fixed U4_ROW width
                debug_assert_eq!(code.len(), *m);
                sum_entries(tables, U4_ROW, code)
            }
        }
    }

    /// Map an integer score back into the f32 score domain.
    #[inline]
    pub fn approx(&self, score: u32) -> f32 {
        match self {
            QuantizedLut::U16 { step, bias, .. }
            | QuantizedLut::U8 { step, bias, .. }
            | QuantizedLut::U4 { step, bias, .. } => {
                bias + step * score as f32
            }
        }
    }
}

/// Bits per 1-bit sketch — one machine word per row.
pub const SKETCH_BITS: usize = 64;

/// Fixed seed for [`SketchPlanes::for_dim`]: query-side and row-side
/// sketches must come from the *same* hyperplanes, and deriving them
/// deterministically from the dimensionality avoids plumbing plane
/// state through every search path.
const SKETCH_SEED: u64 = 0x1b17_5eed;

/// The 1-bit sign quantizer behind the scan pre-filter (DESIGN.md §9):
/// [`SKETCH_BITS`] random hyperplanes; a vector's sketch sets bit `b`
/// when it lies on the positive side of plane `b`.  The Hamming distance
/// between two sketches estimates the angle between the vectors (the
/// classic sign-random-projection bound), which tracks the ADC score
/// well enough to prune scan candidates under an over-fetch margin.
pub struct SketchPlanes {
    pub dim: usize,
    /// `SKETCH_BITS × dim`, row-major.
    planes: Vec<f32>,
}

impl SketchPlanes {
    /// The canonical planes for a dimensionality (deterministic: every
    /// caller that agrees on `dim` agrees on the sketch function).
    pub fn for_dim(dim: usize) -> SketchPlanes {
        let mut rng = crate::util::rng::SplitMix64::new(SKETCH_SEED);
        let planes = (0..SKETCH_BITS * dim).map(|_| rng.normal()).collect();
        SketchPlanes { dim, planes }
    }

    /// Sign-sketch one vector.
    pub fn sketch(&self, v: &[f32]) -> u64 {
        debug_assert_eq!(v.len(), self.dim);
        let mut bits = 0u64;
        for b in 0..SKETCH_BITS {
            let row = &self.planes[b * self.dim..(b + 1) * self.dim];
            let dot: f32 = row.iter().zip(v).map(|(p, x)| p * x).sum();
            if dot >= 0.0 {
                bits |= 1 << b;
            }
        }
        bits
    }
}

/// Sign-sketch every row of a code matrix through the quantizer's
/// decoder: sketches are taken over the *reconstructions*, the same
/// vectors the ADC scan scores against, so Hamming(q, row) tracks the
/// scan score.  `None` when the quantizer has no meaningful decoder
/// (the lattice) — those indexes simply never pre-filter.
pub fn sketch_codes(quant: &dyn Quantizer, codes: &[u8], stride: usize)
                    -> Option<Vec<u64>> {
    assert_eq!(codes.len() % stride.max(1), 0, "codes must be n × stride");
    let dim = quant.dim();
    let planes = SketchPlanes::for_dim(dim);
    let n = codes.len() / stride.max(1);
    let chunk = 1024usize;
    let mut out = Vec::with_capacity(n);
    let mut recons = vec![0.0f32; chunk * dim];
    let mut lo = 0usize;
    while lo < n {
        let hi = (lo + chunk).min(n);
        let rows = hi - lo;
        if !quant.reconstruct_batch(&codes[lo * stride..hi * stride],
                                    &mut recons[..rows * dim]) {
            return None;
        }
        for r in 0..rows {
            out.push(planes.sketch(&recons[r * dim..(r + 1) * dim]));
        }
        lo = hi;
    }
    Some(out)
}

/// A trained quantizer: encoder + distance function (paper §3.1).
pub trait Quantizer: Send + Sync {
    /// Paper row label.
    fn name(&self) -> String;

    /// Bytes actually stored per vector (the index stride).
    fn code_bytes(&self) -> usize;

    /// Bytes charged against the paper's budget (= `code_bytes()` for all
    /// LUT methods; the lattice stores `d_out` small ints but is *charged*
    /// its nominal enumerative-coding budget — DESIGN.md §3).
    fn nominal_bytes(&self) -> usize {
        self.code_bytes()
    }

    /// Input dimensionality.
    fn dim(&self) -> usize;

    /// Encode one vector into `code_bytes()` bytes.
    fn encode_one(&self, x: &[f32], out: &mut [u8]);

    /// Encode a flat batch (default: row loop; UNQ overrides to batch
    /// through PJRT).
    fn encode_batch(&self, data: &[f32]) -> Vec<u8> {
        let dim = self.dim();
        let n = data.len() / dim;
        let cb = self.code_bytes();
        let mut out = vec![0u8; n * cb];
        for i in 0..n {
            self.encode_one(&data[i * dim..(i + 1) * dim],
                            &mut out[i * cb..(i + 1) * cb]);
        }
        out
    }

    /// Build the per-query scoring structure.
    fn lut(&self, q: &[f32]) -> Lut;

    /// Build LUTs for a batch of queries (default: loop; UNQ overrides to
    /// push whole batches through one PJRT execution).
    fn lut_batch(&self, queries: &[&[f32]]) -> Vec<Lut> {
        queries.iter().map(|q| self.lut(q)).collect()
    }

    /// Reconstruct the (approximate) vector from a code, for reranking
    /// with `d1(q, i) = ‖q − reconstruct(i)‖²`. Returns false if this
    /// method has no meaningful decoder in the original space (lattice).
    fn reconstruct(&self, code: &[u8], out: &mut [f32]) -> bool;

    /// Batch reconstruction into a flat `rows × dim` buffer (default: row
    /// loop; UNQ overrides to run its decoder graph in AOT batches).
    fn reconstruct_batch(&self, codes: &[u8], out: &mut [f32]) -> bool {
        let cb = self.code_bytes();
        let dim = self.dim();
        let rows = codes.len() / cb;
        for i in 0..rows {
            if !self.reconstruct(&codes[i * cb..(i + 1) * cb],
                                 &mut out[i * dim..(i + 1) * dim]) {
                return false;
            }
        }
        true
    }

    /// Whether the two-stage rerank is meaningful for this method.
    fn supports_rerank(&self) -> bool {
        true
    }
}

/// Encode a whole dataset.
pub fn encode_dataset(q: &dyn Quantizer, data: &Dataset) -> Vec<u8> {
    assert_eq!(q.dim(), data.dim);
    q.encode_batch(&data.data)
}

/// Mean squared reconstruction error over a dataset — the compression
/// quality measure shallow methods optimize directly.
pub fn reconstruction_mse(q: &dyn Quantizer, data: &Dataset) -> f32 {
    let dim = data.dim;
    let codes = encode_dataset(q, data);
    let cb = q.code_bytes();
    let mut rec = vec![0.0f32; dim];
    let mut sse = 0.0f64;
    let mut n_ok = 0usize;
    for i in 0..data.len() {
        if q.reconstruct(&codes[i * cb..(i + 1) * cb], &mut rec) {
            sse += crate::linalg::sq_l2(data.row(i), &rec) as f64;
            n_ok += 1;
        }
    }
    if n_ok == 0 {
        f32::NAN
    } else {
        (sse / n_ok as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lut_tables_scoring() {
        let lut = Lut::Tables {
            m: 2,
            k: 4,
            tables: vec![0., 1., 2., 3., 10., 20., 30., 40.],
            bias: 5.0,
        };
        assert_eq!(lut.score(&[0, 0]), 5.0 + 0.0 + 10.0);
        assert_eq!(lut.score(&[3, 2]), 5.0 + 3.0 + 30.0);
    }

    #[test]
    fn quantized_lut_error_within_bound() {
        use crate::util::rng::SplitMix64;
        let mut rng = SplitMix64::new(9);
        let (m, k) = (8usize, 64usize);
        let tables: Vec<f32> =
            (0..m * k).map(|_| rng.next_f32() * 7.0 - 2.0).collect();
        let lut = Lut::Tables { m, k, tables, bias: 3.25 };
        for q in [QuantizedLut::u16_from(&lut).unwrap(),
                  QuantizedLut::u8_from(&lut).unwrap()] {
            let bound = q.max_score_error() + 1e-4;
            for _ in 0..200 {
                let code: Vec<u8> =
                    (0..m).map(|_| rng.below(k) as u8).collect();
                let exact = lut.score(&code);
                let approx = q.approx(q.score_int(&code));
                assert!((approx - exact).abs() <= bound,
                        "|{approx} - {exact}| > {bound}");
            }
        }
    }

    #[test]
    fn quantized_lut_u16_ranks_like_f32_on_wide_margins() {
        // entries 0/1000/2000…: margins dwarf the u16 step, so integer
        // scores must rank codes exactly like the f32 tables
        let tables: Vec<f32> = (0..2 * 4).map(|i| (i * 1000) as f32).collect();
        let lut = Lut::Tables { m: 2, k: 4, tables, bias: 0.0 };
        let q = QuantizedLut::u16_from(&lut).unwrap();
        let codes: Vec<[u8; 2]> = (0..4u8)
            .flat_map(|a| (0..4u8).map(move |b| [a, b]))
            .collect();
        let mut by_f32 = codes.clone();
        by_f32.sort_by(|a, b| lut.score(a).partial_cmp(&lut.score(b)).unwrap());
        let mut by_int = codes;
        by_int.sort_by_key(|c| q.score_int(c));
        assert_eq!(by_f32, by_int);
    }

    #[test]
    fn quantized_lut_saturates_instead_of_wrapping() {
        // a huge outlier entry must clamp at the top of the entry range,
        // not wrap around
        let tables = vec![0.0, 1.0, 2.0, 1.0e9];
        let lut = Lut::Tables { m: 1, k: 4, tables, bias: 0.0 };
        let q = QuantizedLut::u8_from(&lut).unwrap();
        assert_eq!(q.score_int(&[3]), 255);
        assert_eq!(q.score_int(&[0]), 0);
        assert!(q.score_int(&[2]) <= q.score_int(&[3]));
    }

    #[test]
    fn quantized_lut_constant_tables_degenerate() {
        let lut = Lut::Tables { m: 2, k: 3,
                                tables: vec![5.0; 6], bias: 1.0 };
        let q = QuantizedLut::u16_from(&lut).unwrap();
        assert_eq!(q.score_int(&[0, 2]), 0);
        // bias absorbs the per-position minima: approx is still exact
        assert!((q.approx(0) - lut.score(&[1, 1])).abs() < 1e-4);
    }

    #[test]
    fn quantized_lut_rejects_direct_scoring() {
        let lut = Lut::Direct { q: vec![1.0, 0.0], bias: 0.0 };
        assert!(QuantizedLut::u16_from(&lut).is_none());
        assert!(QuantizedLut::u8_from(&lut).is_none());
        assert!(QuantizedLut::u4_from(&lut).is_none());
    }

    #[test]
    fn quantized_lut_u4_error_within_bound() {
        use crate::util::rng::SplitMix64;
        let mut rng = SplitMix64::new(11);
        let (m, k) = (8usize, 16usize);
        let tables: Vec<f32> =
            (0..m * k).map(|_| rng.next_f32() * 7.0 - 2.0).collect();
        let lut = Lut::Tables { m, k, tables, bias: 1.5 };
        let q = QuantizedLut::u4_from(&lut).unwrap();
        let bound = q.max_score_error() + 1e-4;
        for _ in 0..200 {
            let code: Vec<u8> = (0..m).map(|_| rng.below(k) as u8).collect();
            let exact = lut.score(&code);
            let approx = q.approx(q.score_int(&code));
            assert!((approx - exact).abs() <= bound,
                    "|{approx} - {exact}| > {bound}");
        }
    }

    #[test]
    fn quantized_lut_u4_rejects_wide_codebooks_and_strides() {
        let mk = |m: usize, k: usize| Lut::Tables {
            m, k, tables: vec![1.0; m * k], bias: 0.0,
        };
        // k = 17 codewords cannot fit a 16-entry register row
        assert!(QuantizedLut::u4_from(&mk(4, 17)).is_none());
        // m = 257 positions would overflow the 16-bit SIMD lane bound
        assert!(QuantizedLut::u4_from(&mk(257, 2)).is_none());
        // both at their ceilings: fine
        assert!(QuantizedLut::u4_from(&mk(256, 16)).is_some());
    }

    // Satellite regression: integer scores must stay inside the 2^24
    // exact-f32 window.  u16 entries reach 65535, so the ceiling binds
    // at m = 257 (257 · 65535 ≥ 2^24) while m = 256 still fits; u8
    // entries only hit the window at m ≥ 65794, far past any real m.
    #[test]
    fn quantized_lut_rejects_scores_past_exact_f32_window() {
        let mk = |m: usize| Lut::Tables {
            m, k: 2, tables: vec![1.0; m * 2], bias: 0.0,
        };
        assert!(QuantizedLut::u16_from(&mk(256)).is_some());
        assert!(QuantizedLut::u16_from(&mk(257)).is_none());
        assert!(QuantizedLut::u8_from(&mk(257)).is_some());
    }

    #[test]
    fn sketch_planes_deterministic_and_discriminative() {
        let p = SketchPlanes::for_dim(8);
        let v = [1.0, -2.0, 0.5, 3.0, -1.0, 0.25, -0.75, 2.0];
        let w: Vec<f32> = v.iter().map(|x| -x).collect();
        let sv = p.sketch(&v);
        // deterministic: a fresh instance agrees bit-for-bit
        assert_eq!(sv, SketchPlanes::for_dim(8).sketch(&v));
        // a vector and its negation disagree on every plane
        assert_eq!(sv ^ p.sketch(&w), u64::MAX);
        // self-distance is zero
        assert_eq!((sv ^ p.sketch(&v)).count_ones(), 0);
    }

    #[test]
    fn lut_direct_prefers_aligned() {
        let q = vec![1.0, 0.0];
        let lut = Lut::Direct { q, bias: 0.0 };
        let aligned = lut.score(&[5i8 as u8, 0]);
        let anti = lut.score(&[(-5i8) as u8, 0]);
        let ortho = lut.score(&[0, 5]);
        assert!(aligned < ortho && ortho < anti);
    }
}
