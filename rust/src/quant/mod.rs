//! Multi-codebook quantizers: the paper's method and every baseline.
//!
//! All quantizers expose the two modules the paper identifies (§3.1): an
//! *encoder* `f(x) → codes` and a *distance function* `d(q, code)` usable
//! for exhaustive compressed-domain scan.  The scan contract is uniform:
//! a per-query [`Lut`] whose entries are *distance contributions* (lower =
//! closer), summed over code bytes by the index hot loop — exactly the
//! paper's eq. (1)/(8) lookup structure.  The Catalyst lattice quantizer,
//! which has no LUT decomposition, scores codes directly (the paper notes
//! its scan is ~1.5× slower for the same reason).
//!
//! | impl | paper row | encoding | distance |
//! |---|---|---|---|
//! | [`pq::Pq`] | OPQ's ancestor (Table 1) | per-subspace NN | exact ADC |
//! | [`opq::Opq`] | "OPQ" | rotate + PQ | exact ADC |
//! | [`additive::Additive`] greedy | "RVQ" | residual greedy | ADC + norm byte |
//! | [`lsq::Lsq`] | "LSQ" / "LSQ+rerank" | ICM + LS codebooks | ADC + norm byte |
//! | [`lattice::CatalystLattice`] | "Catalyst+Lattice" | whiten→sphere→lattice | direct dot |
//! | [`lattice::CatalystOpq`] | "Catalyst+OPQ" | whiten→sphere→OPQ | ADC in mapped space |
//! | [`unq::UnqQuantizer`] | "UNQ" | AOT encoder (PJRT) | learned-space ADC + decoder rerank |

pub mod additive;
pub mod lattice;
pub mod lsq;
pub mod opq;
pub mod pq;
pub mod unq;

use crate::data::Dataset;

/// Per-query scoring structure handed to the index scan.
pub enum Lut {
    /// Position-major lookup tables, the one layout every scan path
    /// relies on (`Lut::score` here and the `index::scan` hot loop):
    /// `tables[j * k + c]` is the distance contribution of byte value `c`
    /// at code position `j`, i.e. position `j`'s table row occupies
    /// `tables[j*k .. (j+1)*k]` contiguously.  `bias` is the
    /// rank-invariant query constant (kept so scores are interpretable as
    /// approximate squared distances).
    Tables { m: usize, k: usize, tables: Vec<f32>, bias: f32 },
    /// Direct scoring against a transformed query (lattice path).
    Direct { q: Vec<f32>, bias: f32 },
}

impl Lut {
    /// Score one code (lower = closer).
    #[inline]
    pub fn score(&self, code: &[u8]) -> f32 {
        match self {
            Lut::Tables { m, k, tables, bias } => {
                debug_assert_eq!(code.len(), *m);
                let mut acc = *bias;
                for (j, &c) in code.iter().enumerate() {
                    acc += tables[j * k + c as usize];
                }
                acc
            }
            Lut::Direct { q, bias } => {
                // code holds i8 lattice coordinates
                let mut dot = 0.0f32;
                let mut nrm = 0.0f32;
                for (qi, &c) in q.iter().zip(code) {
                    let z = c as i8 as f32;
                    dot += qi * z;
                    nrm += z * z;
                }
                // cosine distance on the sphere (q is unit-norm)
                bias - dot / nrm.sqrt().max(1e-12)
            }
        }
    }
}

/// A trained quantizer: encoder + distance function (paper §3.1).
pub trait Quantizer: Send + Sync {
    /// Paper row label.
    fn name(&self) -> String;

    /// Bytes actually stored per vector (the index stride).
    fn code_bytes(&self) -> usize;

    /// Bytes charged against the paper's budget (= `code_bytes()` for all
    /// LUT methods; the lattice stores `d_out` small ints but is *charged*
    /// its nominal enumerative-coding budget — DESIGN.md §3).
    fn nominal_bytes(&self) -> usize {
        self.code_bytes()
    }

    /// Input dimensionality.
    fn dim(&self) -> usize;

    /// Encode one vector into `code_bytes()` bytes.
    fn encode_one(&self, x: &[f32], out: &mut [u8]);

    /// Encode a flat batch (default: row loop; UNQ overrides to batch
    /// through PJRT).
    fn encode_batch(&self, data: &[f32]) -> Vec<u8> {
        let dim = self.dim();
        let n = data.len() / dim;
        let cb = self.code_bytes();
        let mut out = vec![0u8; n * cb];
        for i in 0..n {
            self.encode_one(&data[i * dim..(i + 1) * dim],
                            &mut out[i * cb..(i + 1) * cb]);
        }
        out
    }

    /// Build the per-query scoring structure.
    fn lut(&self, q: &[f32]) -> Lut;

    /// Build LUTs for a batch of queries (default: loop; UNQ overrides to
    /// push whole batches through one PJRT execution).
    fn lut_batch(&self, queries: &[&[f32]]) -> Vec<Lut> {
        queries.iter().map(|q| self.lut(q)).collect()
    }

    /// Reconstruct the (approximate) vector from a code, for reranking
    /// with `d1(q, i) = ‖q − reconstruct(i)‖²`. Returns false if this
    /// method has no meaningful decoder in the original space (lattice).
    fn reconstruct(&self, code: &[u8], out: &mut [f32]) -> bool;

    /// Batch reconstruction into a flat `rows × dim` buffer (default: row
    /// loop; UNQ overrides to run its decoder graph in AOT batches).
    fn reconstruct_batch(&self, codes: &[u8], out: &mut [f32]) -> bool {
        let cb = self.code_bytes();
        let dim = self.dim();
        let rows = codes.len() / cb;
        for i in 0..rows {
            if !self.reconstruct(&codes[i * cb..(i + 1) * cb],
                                 &mut out[i * dim..(i + 1) * dim]) {
                return false;
            }
        }
        true
    }

    /// Whether the two-stage rerank is meaningful for this method.
    fn supports_rerank(&self) -> bool {
        true
    }
}

/// Encode a whole dataset.
pub fn encode_dataset(q: &dyn Quantizer, data: &Dataset) -> Vec<u8> {
    assert_eq!(q.dim(), data.dim);
    q.encode_batch(&data.data)
}

/// Mean squared reconstruction error over a dataset — the compression
/// quality measure shallow methods optimize directly.
pub fn reconstruction_mse(q: &dyn Quantizer, data: &Dataset) -> f32 {
    let dim = data.dim;
    let codes = encode_dataset(q, data);
    let cb = q.code_bytes();
    let mut rec = vec![0.0f32; dim];
    let mut sse = 0.0f64;
    let mut n_ok = 0usize;
    for i in 0..data.len() {
        if q.reconstruct(&codes[i * cb..(i + 1) * cb], &mut rec) {
            sse += crate::linalg::sq_l2(data.row(i), &rec) as f64;
            n_ok += 1;
        }
    }
    if n_ok == 0 {
        f32::NAN
    } else {
        (sse / n_ok as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lut_tables_scoring() {
        let lut = Lut::Tables {
            m: 2,
            k: 4,
            tables: vec![0., 1., 2., 3., 10., 20., 30., 40.],
            bias: 5.0,
        };
        assert_eq!(lut.score(&[0, 0]), 5.0 + 0.0 + 10.0);
        assert_eq!(lut.score(&[3, 2]), 5.0 + 3.0 + 30.0);
    }

    #[test]
    fn lut_direct_prefers_aligned() {
        let q = vec![1.0, 0.0];
        let lut = Lut::Direct { q, bias: 0.0 };
        let aligned = lut.score(&[5i8 as u8, 0]);
        let anti = lut.score(&[(-5i8) as u8, 0]);
        let ortho = lut.score(&[0, 5]);
        assert!(aligned < ortho && ortho < anti);
    }
}
