//! Multi-codebook quantizers: the paper's method and every baseline.
//!
//! All quantizers expose the two modules the paper identifies (§3.1): an
//! *encoder* `f(x) → codes` and a *distance function* `d(q, code)` usable
//! for exhaustive compressed-domain scan.  The scan contract is uniform:
//! a per-query [`Lut`] whose entries are *distance contributions* (lower =
//! closer), summed over code bytes by the index hot loop — exactly the
//! paper's eq. (1)/(8) lookup structure.  The Catalyst lattice quantizer,
//! which has no LUT decomposition, scores codes directly (the paper notes
//! its scan is ~1.5× slower for the same reason).
//!
//! | impl | paper row | encoding | distance |
//! |---|---|---|---|
//! | [`pq::Pq`] | OPQ's ancestor (Table 1) | per-subspace NN | exact ADC |
//! | [`opq::Opq`] | "OPQ" | rotate + PQ | exact ADC |
//! | [`additive::Additive`] greedy | "RVQ" | residual greedy | ADC + norm byte |
//! | [`lsq::Lsq`] | "LSQ" / "LSQ+rerank" | ICM + LS codebooks | ADC + norm byte |
//! | [`lattice::CatalystLattice`] | "Catalyst+Lattice" | whiten→sphere→lattice | direct dot |
//! | [`lattice::CatalystOpq`] | "Catalyst+OPQ" | whiten→sphere→OPQ | ADC in mapped space |
//! | [`unq::UnqQuantizer`] | "UNQ" | AOT encoder (PJRT) | learned-space ADC + decoder rerank |
//! | [`unq_native::NativeUnq`] | "UNQ-native" | trained in-process (`nn`) | learned-space ADC (`d2`) + decoder rerank (`d1`) |

pub mod additive;
pub mod lattice;
pub mod lsq;
pub mod opq;
pub mod pq;
pub mod unq;
pub mod unq_native;

use crate::data::Dataset;

/// Per-query scoring structure handed to the index scan.
pub enum Lut {
    /// Position-major lookup tables, the one layout every scan path
    /// relies on (`Lut::score` here and the `index::scan` hot loop):
    /// `tables[j * k + c]` is the distance contribution of byte value `c`
    /// at code position `j`, i.e. position `j`'s table row occupies
    /// `tables[j*k .. (j+1)*k]` contiguously.  `bias` is the
    /// rank-invariant query constant (kept so scores are interpretable as
    /// approximate squared distances).
    Tables { m: usize, k: usize, tables: Vec<f32>, bias: f32 },
    /// Direct scoring against a transformed query (lattice path).
    Direct { q: Vec<f32>, bias: f32 },
}

impl Lut {
    /// Score one code (lower = closer).
    #[inline]
    pub fn score(&self, code: &[u8]) -> f32 {
        match self {
            Lut::Tables { m, k, tables, bias } => {
                debug_assert_eq!(code.len(), *m);
                let mut acc = *bias;
                for (j, &c) in code.iter().enumerate() {
                    acc += tables[j * k + c as usize];
                }
                acc
            }
            Lut::Direct { q, bias } => {
                // code holds i8 lattice coordinates
                let mut dot = 0.0f32;
                let mut nrm = 0.0f32;
                for (qi, &c) in q.iter().zip(code) {
                    let z = c as i8 as f32;
                    dot += qi * z;
                    nrm += z * z;
                }
                // cosine distance on the sphere (q is unit-norm)
                bias - dot / nrm.sqrt().max(1e-12)
            }
        }
    }
}

/// Integer-quantized mirror of a [`Lut::Tables`], the scan-precision
/// axis of the blocked fast-scan kernels
/// (`index::scan::scan_lut_topk_{u16,u8}` — rust/DESIGN.md §6).
///
/// Per query, every table row `j` is shifted by its own minimum `lo_j`
/// and scaled by one shared `step`, so integer scores from different
/// positions stay comparable:
///
/// ```text
/// qtables[j·K + c] = clamp(round((tables[j·K + c] − lo_j) / step), 0, 2ʷ−1)
/// step             = max_j (hi_j − lo_j) / (2ʷ − 1)
/// f32 score        ≈ bias + step · Σ_j qtables[j·K + code[j]]
/// bias             = lut bias + Σ_j lo_j
/// ```
///
/// With `step` derived from the per-position min/max, no entry genuinely
/// saturates — the clamp only guards float rounding at the tails (a
/// narrower, outlier-trimmed `step` would trade tail saturation for
/// resolution; see DESIGN.md §6 on when u8 saturation matters).  The
/// per-entry rounding error is ≤ `step/2`, so an integer score deviates
/// from the exact f32 score by at most [`Self::max_score_error`] =
/// `m · step / 2`.
#[derive(Clone, Debug)]
pub enum QuantizedLut {
    /// 16-bit entries: integer scores ≤ `m · 65535` (< 2²⁴ for every
    /// stride we store, so they are also exactly representable as f32).
    U16 { m: usize, k: usize, tables: Vec<u16>, step: f32, bias: f32 },
    /// 8-bit entries: coarser (bigger `step`), faster (quarter the table
    /// bytes of f32, denser in L1).
    U8 { m: usize, k: usize, tables: Vec<u8>, step: f32, bias: f32 },
}

impl QuantizedLut {
    /// Quantize a [`Lut::Tables`] to u16 entries (`None` for the
    /// lattice's `Direct` scoring, which has no table decomposition).
    pub fn u16_from(lut: &Lut) -> Option<QuantizedLut> {
        let (m, k, vals, step, bias) = Self::quantize(lut, 16)?;
        let tables = vals.into_iter().map(|v| v as u16).collect();
        Some(QuantizedLut::U16 { m, k, tables, step, bias })
    }

    /// Quantize a [`Lut::Tables`] to u8 entries.
    pub fn u8_from(lut: &Lut) -> Option<QuantizedLut> {
        let (m, k, vals, step, bias) = Self::quantize(lut, 8)?;
        let tables = vals.into_iter().map(|v| v as u8).collect();
        Some(QuantizedLut::U8 { m, k, tables, step, bias })
    }

    /// The width-independent core shared by both constructors: derive
    /// the affine map (per-position minima, one step over the widest
    /// range, bias absorbing the minima) and quantize every entry into
    /// `[0, 2^bits − 1]` — the clamp saturates the tails against
    /// rounding fuzz.  Entries come back as u32 and are narrowed by the
    /// callers (every value fits their width by construction).
    fn quantize(lut: &Lut, bits: u32)
                -> Option<(usize, usize, Vec<u32>, f32, f32)> {
        let (m, k, tables, bias) = match lut {
            Lut::Tables { m, k, tables, bias } => (*m, *k, tables, *bias),
            Lut::Direct { .. } => return None,
        };
        let max_code = (1u32 << bits) - 1;
        let mut lows = Vec::with_capacity(m);
        let mut step = 0.0f32;
        for j in 0..m {
            let row = &tables[j * k..(j + 1) * k];
            let lo = row.iter().copied().fold(f32::INFINITY, f32::min);
            let hi = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            lows.push(lo);
            step = step.max((hi - lo) / max_code as f32);
        }
        let lo_sum: f32 = lows.iter().sum();
        if !step.is_finite() || !lo_sum.is_finite() {
            return None;
        }
        if step <= 0.0 {
            // constant tables: every entry quantizes to 0 and the exact
            // rescore settles any ordering
            step = 1.0;
        }
        let mut vals = Vec::with_capacity(m * k);
        for j in 0..m {
            for c in 0..k {
                let v = ((tables[j * k + c] - lows[j]) / step).round();
                vals.push(if v >= max_code as f32 {
                    max_code
                } else if v > 0.0 {
                    v as u32
                } else {
                    0
                });
            }
        }
        Some((m, k, vals, step, bias + lo_sum))
    }

    #[inline]
    pub fn m(&self) -> usize {
        match self {
            QuantizedLut::U16 { m, .. } | QuantizedLut::U8 { m, .. } => *m,
        }
    }

    /// The shared score step: one integer unit ≈ this many f32 units.
    #[inline]
    pub fn step(&self) -> f32 {
        match self {
            QuantizedLut::U16 { step, .. }
            | QuantizedLut::U8 { step, .. } => *step,
        }
    }

    /// Upper bound on `|approx(score_int(code)) − lut.score(code)|`:
    /// `m · step / 2` (per-entry round-to-nearest error, summed).
    #[inline]
    pub fn max_score_error(&self) -> f32 {
        self.m() as f32 * self.step() * 0.5
    }

    /// Integer ADC score of one code row (the reference path mirrored by
    /// the blocked kernels; lower = closer).
    #[inline]
    pub fn score_int(&self, code: &[u8]) -> u32 {
        fn sum_entries<T: Copy + Into<u32>>(tables: &[T], k: usize,
                                            code: &[u8]) -> u32 {
            code.iter()
                .enumerate()
                .map(|(j, &c)| tables[j * k + c as usize].into())
                .sum()
        }
        match self {
            QuantizedLut::U16 { m, k, tables, .. } => {
                debug_assert_eq!(code.len(), *m);
                sum_entries(tables, *k, code)
            }
            QuantizedLut::U8 { m, k, tables, .. } => {
                debug_assert_eq!(code.len(), *m);
                sum_entries(tables, *k, code)
            }
        }
    }

    /// Map an integer score back into the f32 score domain.
    #[inline]
    pub fn approx(&self, score: u32) -> f32 {
        match self {
            QuantizedLut::U16 { step, bias, .. }
            | QuantizedLut::U8 { step, bias, .. } => bias + step * score as f32,
        }
    }
}

/// A trained quantizer: encoder + distance function (paper §3.1).
pub trait Quantizer: Send + Sync {
    /// Paper row label.
    fn name(&self) -> String;

    /// Bytes actually stored per vector (the index stride).
    fn code_bytes(&self) -> usize;

    /// Bytes charged against the paper's budget (= `code_bytes()` for all
    /// LUT methods; the lattice stores `d_out` small ints but is *charged*
    /// its nominal enumerative-coding budget — DESIGN.md §3).
    fn nominal_bytes(&self) -> usize {
        self.code_bytes()
    }

    /// Input dimensionality.
    fn dim(&self) -> usize;

    /// Encode one vector into `code_bytes()` bytes.
    fn encode_one(&self, x: &[f32], out: &mut [u8]);

    /// Encode a flat batch (default: row loop; UNQ overrides to batch
    /// through PJRT).
    fn encode_batch(&self, data: &[f32]) -> Vec<u8> {
        let dim = self.dim();
        let n = data.len() / dim;
        let cb = self.code_bytes();
        let mut out = vec![0u8; n * cb];
        for i in 0..n {
            self.encode_one(&data[i * dim..(i + 1) * dim],
                            &mut out[i * cb..(i + 1) * cb]);
        }
        out
    }

    /// Build the per-query scoring structure.
    fn lut(&self, q: &[f32]) -> Lut;

    /// Build LUTs for a batch of queries (default: loop; UNQ overrides to
    /// push whole batches through one PJRT execution).
    fn lut_batch(&self, queries: &[&[f32]]) -> Vec<Lut> {
        queries.iter().map(|q| self.lut(q)).collect()
    }

    /// Reconstruct the (approximate) vector from a code, for reranking
    /// with `d1(q, i) = ‖q − reconstruct(i)‖²`. Returns false if this
    /// method has no meaningful decoder in the original space (lattice).
    fn reconstruct(&self, code: &[u8], out: &mut [f32]) -> bool;

    /// Batch reconstruction into a flat `rows × dim` buffer (default: row
    /// loop; UNQ overrides to run its decoder graph in AOT batches).
    fn reconstruct_batch(&self, codes: &[u8], out: &mut [f32]) -> bool {
        let cb = self.code_bytes();
        let dim = self.dim();
        let rows = codes.len() / cb;
        for i in 0..rows {
            if !self.reconstruct(&codes[i * cb..(i + 1) * cb],
                                 &mut out[i * dim..(i + 1) * dim]) {
                return false;
            }
        }
        true
    }

    /// Whether the two-stage rerank is meaningful for this method.
    fn supports_rerank(&self) -> bool {
        true
    }
}

/// Encode a whole dataset.
pub fn encode_dataset(q: &dyn Quantizer, data: &Dataset) -> Vec<u8> {
    assert_eq!(q.dim(), data.dim);
    q.encode_batch(&data.data)
}

/// Mean squared reconstruction error over a dataset — the compression
/// quality measure shallow methods optimize directly.
pub fn reconstruction_mse(q: &dyn Quantizer, data: &Dataset) -> f32 {
    let dim = data.dim;
    let codes = encode_dataset(q, data);
    let cb = q.code_bytes();
    let mut rec = vec![0.0f32; dim];
    let mut sse = 0.0f64;
    let mut n_ok = 0usize;
    for i in 0..data.len() {
        if q.reconstruct(&codes[i * cb..(i + 1) * cb], &mut rec) {
            sse += crate::linalg::sq_l2(data.row(i), &rec) as f64;
            n_ok += 1;
        }
    }
    if n_ok == 0 {
        f32::NAN
    } else {
        (sse / n_ok as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lut_tables_scoring() {
        let lut = Lut::Tables {
            m: 2,
            k: 4,
            tables: vec![0., 1., 2., 3., 10., 20., 30., 40.],
            bias: 5.0,
        };
        assert_eq!(lut.score(&[0, 0]), 5.0 + 0.0 + 10.0);
        assert_eq!(lut.score(&[3, 2]), 5.0 + 3.0 + 30.0);
    }

    #[test]
    fn quantized_lut_error_within_bound() {
        use crate::util::rng::SplitMix64;
        let mut rng = SplitMix64::new(9);
        let (m, k) = (8usize, 64usize);
        let tables: Vec<f32> =
            (0..m * k).map(|_| rng.next_f32() * 7.0 - 2.0).collect();
        let lut = Lut::Tables { m, k, tables, bias: 3.25 };
        for q in [QuantizedLut::u16_from(&lut).unwrap(),
                  QuantizedLut::u8_from(&lut).unwrap()] {
            let bound = q.max_score_error() + 1e-4;
            for _ in 0..200 {
                let code: Vec<u8> =
                    (0..m).map(|_| rng.below(k) as u8).collect();
                let exact = lut.score(&code);
                let approx = q.approx(q.score_int(&code));
                assert!((approx - exact).abs() <= bound,
                        "|{approx} - {exact}| > {bound}");
            }
        }
    }

    #[test]
    fn quantized_lut_u16_ranks_like_f32_on_wide_margins() {
        // entries 0/1000/2000…: margins dwarf the u16 step, so integer
        // scores must rank codes exactly like the f32 tables
        let tables: Vec<f32> = (0..2 * 4).map(|i| (i * 1000) as f32).collect();
        let lut = Lut::Tables { m: 2, k: 4, tables, bias: 0.0 };
        let q = QuantizedLut::u16_from(&lut).unwrap();
        let codes: Vec<[u8; 2]> = (0..4u8)
            .flat_map(|a| (0..4u8).map(move |b| [a, b]))
            .collect();
        let mut by_f32 = codes.clone();
        by_f32.sort_by(|a, b| lut.score(a).partial_cmp(&lut.score(b)).unwrap());
        let mut by_int = codes;
        by_int.sort_by_key(|c| q.score_int(c));
        assert_eq!(by_f32, by_int);
    }

    #[test]
    fn quantized_lut_saturates_instead_of_wrapping() {
        // a huge outlier entry must clamp at the top of the entry range,
        // not wrap around
        let tables = vec![0.0, 1.0, 2.0, 1.0e9];
        let lut = Lut::Tables { m: 1, k: 4, tables, bias: 0.0 };
        let q = QuantizedLut::u8_from(&lut).unwrap();
        assert_eq!(q.score_int(&[3]), 255);
        assert_eq!(q.score_int(&[0]), 0);
        assert!(q.score_int(&[2]) <= q.score_int(&[3]));
    }

    #[test]
    fn quantized_lut_constant_tables_degenerate() {
        let lut = Lut::Tables { m: 2, k: 3,
                                tables: vec![5.0; 6], bias: 1.0 };
        let q = QuantizedLut::u16_from(&lut).unwrap();
        assert_eq!(q.score_int(&[0, 2]), 0);
        // bias absorbs the per-position minima: approx is still exact
        assert!((q.approx(0) - lut.score(&[1, 1])).abs() < 1e-4);
    }

    #[test]
    fn quantized_lut_rejects_direct_scoring() {
        let lut = Lut::Direct { q: vec![1.0, 0.0], bias: 0.0 };
        assert!(QuantizedLut::u16_from(&lut).is_none());
        assert!(QuantizedLut::u8_from(&lut).is_none());
    }

    #[test]
    fn lut_direct_prefers_aligned() {
        let q = vec![1.0, 0.0];
        let lut = Lut::Direct { q, bias: 0.0 };
        let aligned = lut.score(&[5i8 as u8, 0]);
        let anti = lut.score(&[(-5i8) as u8, 0]);
        let ortho = lut.score(&[0, 5]);
        assert!(aligned < ortho && ortho < anti);
    }
}
