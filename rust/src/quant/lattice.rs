//! Catalyst-style spherical lattice quantization (Sablayrolles et al.,
//! "Spreading vectors for similarity search", 2018) — the paper's
//! strongest non-MCQ baseline ("Catalyst+Lattice" / "Catalyst+OPQ").
//!
//! Substitution (DESIGN.md §3): the original *catalyst* is a small neural
//! net trained with a neighborhood-preserving loss plus a "spreading"
//! entropy regularizer that pushes points toward a uniform distribution
//! on the d_out-sphere.  We approximate that map with **PCA whitening to
//! d_out dims followed by L2 normalization** — whitening equalizes the
//! variance in every retained direction, which is exactly the
//! spreading effect the regularizer targets, and it preserves
//! neighborhoods as well as any linear map can.  The downstream
//! quantizers are faithful:
//!
//! * **Lattice**: the nearest point of the integer lattice on the radius-r
//!   sphere (`z ∈ Zᵈ, ‖z‖² = r²`, maximizing ⟨y, z⟩), found by greedy
//!   norm-repair around the rounded scaling — the same decoder the
//!   Catalyst code uses.  Codes are *charged* the nominal enumerative-
//!   coding budget (8/16 bytes, r² = 79/253 per the paper) but stored as
//!   raw i8 coordinates; bit-packing would not change recall.
//! * **OPQ-on-catalyst**: plain OPQ in the mapped space.

use crate::linalg::{self, covariance, jacobi_eigen, Mat};
use crate::store::Store;
use crate::Result;

use super::opq::Opq;
use super::{Lut, Quantizer};

/// The linear "catalyst": whitening PCA to `d_out` + sphere projection.
pub struct CatalystMap {
    pub dim_in: usize,
    pub d_out: usize,
    /// `(d_out, dim_in)` projection rows (whitened principal directions).
    pub proj: Mat,
    pub mean: Vec<f32>,
}

impl CatalystMap {
    pub fn train(data: &[f32], dim: usize, d_out: usize) -> CatalystMap {
        assert!(d_out <= dim);
        let mean = linalg::mean_rows(data, dim);
        let cov = covariance(data, dim);
        let (vals, vecs) = jacobi_eigen(&cov, 60);
        let mut proj = Mat::zeros(d_out, dim);
        for r in 0..d_out {
            let scale = 1.0 / vals[r].max(1e-8).sqrt();
            for c in 0..dim {
                proj.data[r * dim + c] = vecs.get(r, c) * scale;
            }
        }
        CatalystMap { dim_in: dim, d_out, proj, mean }
    }

    /// Map one vector onto the unit d_out-sphere.
    pub fn apply(&self, x: &[f32]) -> Vec<f32> {
        debug_assert_eq!(x.len(), self.dim_in);
        let centered: Vec<f32> =
            x.iter().zip(&self.mean).map(|(v, m)| v - m).collect();
        let mut y = self.proj.matvec(&centered);
        let n = linalg::norm(&y).max(1e-9);
        y.iter_mut().for_each(|v| *v /= n);
        y
    }

    pub fn save(&self, store: &mut Store, prefix: &str) {
        store.put_f32(&format!("{prefix}proj"),
                      &[self.d_out, self.dim_in], self.proj.data.clone());
        store.put_f32(&format!("{prefix}mean"), &[self.dim_in],
                      self.mean.clone());
    }

    pub fn load(store: &Store, prefix: &str) -> Result<CatalystMap> {
        let (shape, proj) = store.get_f32(&format!("{prefix}proj"))
            .ok_or_else(|| anyhow::anyhow!("missing catalyst proj"))?;
        let (_, mean) = store.get_f32(&format!("{prefix}mean"))
            .ok_or_else(|| anyhow::anyhow!("missing catalyst mean"))?;
        Ok(CatalystMap {
            dim_in: shape[1],
            d_out: shape[0],
            proj: Mat::from_rows(shape[0], shape[1], proj.to_vec()),
            mean: mean.to_vec(),
        })
    }
}

/// Quantize a unit vector to the integer lattice point with `‖z‖² = r²`
/// maximizing `⟨y, z⟩` (greedy norm repair; exported for tests).
pub fn lattice_quantize(y: &[f32], r2: i64) -> Vec<i8> {
    let d = y.len();
    let r = (r2 as f32).sqrt();
    // start from the rounded scaled vector
    let mut z: Vec<i64> = y.iter().map(|v| (v * r).round() as i64).collect();
    let mut norm2: i64 = z.iter().map(|v| v * v).sum();
    // Greedy repair: move one coordinate by ±1 per step, choosing the move
    // with the best ⟨y,z⟩ gain per unit of norm change toward r².
    let mut guard = 0;
    while norm2 != r2 && guard < 10_000 {
        guard += 1;
        let need_up = norm2 < r2;
        let mut best: Option<(usize, i64, f32)> = None; // (idx, delta, score)
        for i in 0..d {
            for delta in [-1i64, 1] {
                let dz = 2 * z[i] * delta + 1; // change in ‖z‖²
                if need_up != (dz > 0) {
                    continue;
                }
                // dot gain per norm distance traveled
                let gain = y[i] * delta as f32;
                let dist = (norm2 + dz - r2).abs() as f32;
                let score = gain - 1e-4 * dist;
                if best.is_none() || score > best.unwrap().2 {
                    best = Some((i, delta, score));
                }
            }
        }
        match best {
            Some((i, delta, _)) => {
                norm2 += 2 * z[i] * delta + 1;
                z[i] += delta;
            }
            None => break,
        }
    }
    z.iter().map(|&v| v.clamp(-127, 127) as i8).collect()
}

/// "Catalyst+Lattice": whiten→sphere→spherical-lattice codec.
pub struct CatalystLattice {
    pub map: CatalystMap,
    pub r2: i64,
    /// bytes charged against the paper budget (8/16)
    pub nominal: usize,
}

impl CatalystLattice {
    /// Paper operating points: 8 B → (d_out 24, r² 79); 16 B → (32, 253).
    pub fn train(data: &[f32], dim: usize, budget_bytes: usize) -> CatalystLattice {
        let (d_out, r2) = match budget_bytes {
            8 => (24usize, 79i64),
            16 => (32usize, 253i64),
            b => ((3 * b).min(dim), (10 * b * b) as i64),
        };
        CatalystLattice {
            map: CatalystMap::train(data, dim, d_out.min(dim)),
            r2,
            nominal: budget_bytes,
        }
    }
}

impl Quantizer for CatalystLattice {
    fn name(&self) -> String {
        "Catalyst+Lattice".into()
    }

    fn code_bytes(&self) -> usize {
        self.map.d_out
    }

    fn nominal_bytes(&self) -> usize {
        self.nominal
    }

    fn dim(&self) -> usize {
        self.map.dim_in
    }

    fn encode_one(&self, x: &[f32], out: &mut [u8]) {
        let y = self.map.apply(x);
        let z = lattice_quantize(&y, self.r2);
        for (o, &v) in out.iter_mut().zip(&z) {
            *o = v as u8;
        }
    }

    fn lut(&self, q: &[f32]) -> Lut {
        Lut::Direct { q: self.map.apply(q), bias: 1.0 }
    }

    fn reconstruct(&self, _code: &[u8], _out: &mut [f32]) -> bool {
        false // no decoder back to the original space
    }

    fn supports_rerank(&self) -> bool {
        false
    }
}

/// "Catalyst+OPQ": OPQ trained in the catalyst-mapped space.
pub struct CatalystOpq {
    pub map: CatalystMap,
    pub opq: Opq,
}

impl CatalystOpq {
    pub fn train(data: &[f32], dim: usize, m: usize, k: usize, seed: u64)
                 -> CatalystOpq {
        // the catalyst output dim must be divisible by m; use the largest
        // multiple of m ≤ min(dim, 4·m) for a compact spread space
        let d_out = ((dim.min(4 * m)) / m) * m;
        let map = CatalystMap::train(data, dim, d_out.max(m));
        let n = data.len() / dim;
        let mut mapped = vec![0.0f32; n * map.d_out];
        for i in 0..n {
            let y = map.apply(&data[i * dim..(i + 1) * dim]);
            mapped[i * map.d_out..(i + 1) * map.d_out].copy_from_slice(&y);
        }
        let opq = Opq::train(&mapped, map.d_out, m, k, seed, 3, 8);
        CatalystOpq { map, opq }
    }
}

impl Quantizer for CatalystOpq {
    fn name(&self) -> String {
        "Catalyst+OPQ".into()
    }

    fn code_bytes(&self) -> usize {
        self.opq.pq.m
    }

    fn dim(&self) -> usize {
        self.map.dim_in
    }

    fn encode_one(&self, x: &[f32], out: &mut [u8]) {
        let y = self.map.apply(x);
        self.opq.encode_one(&y, out);
    }

    fn lut(&self, q: &[f32]) -> Lut {
        let y = self.map.apply(q);
        self.opq.lut(&y)
    }

    fn reconstruct(&self, _code: &[u8], _out: &mut [f32]) -> bool {
        false // reconstruction lives in the mapped space only
    }

    fn supports_rerank(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic::Generator, Family};
    use crate::linalg::{dot, norm};

    fn toy(n: usize) -> crate::data::Dataset {
        Generator::new(Family::DeepLike, 8).generate(0, n)
    }

    #[test]
    fn catalyst_maps_to_unit_sphere() {
        let d = toy(400);
        let map = CatalystMap::train(&d.data, d.dim, 24);
        for i in 0..10 {
            let y = map.apply(d.row(i));
            assert_eq!(y.len(), 24);
            assert!((norm(&y) - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn whitening_spreads_variance() {
        // per-axis variance of mapped (pre-normalization would be 1; after
        // sphere projection axes should still be near-equal)
        let d = toy(1000);
        let map = CatalystMap::train(&d.data, d.dim, 16);
        let mut var = vec![0.0f64; 16];
        for i in 0..d.len() {
            let y = map.apply(d.row(i));
            for (v, yi) in var.iter_mut().zip(&y) {
                *v += (*yi as f64) * (*yi as f64);
            }
        }
        let mx = var.iter().cloned().fold(0.0, f64::max);
        let mn = var.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(mx / mn < 8.0, "axis variance ratio {}", mx / mn);
    }

    #[test]
    fn lattice_point_has_exact_norm() {
        let d = toy(50);
        let map = CatalystMap::train(&d.data, d.dim, 24);
        for i in 0..20 {
            let y = map.apply(d.row(i));
            let z = lattice_quantize(&y, 79);
            let n2: i64 = z.iter().map(|&v| (v as i64) * (v as i64)).sum();
            assert_eq!(n2, 79, "row {i}");
        }
    }

    #[test]
    fn lattice_aligns_with_input() {
        // the chosen lattice point should correlate strongly with y
        let d = toy(30);
        let map = CatalystMap::train(&d.data, d.dim, 24);
        for i in 0..10 {
            let y = map.apply(d.row(i));
            let z = lattice_quantize(&y, 79);
            let zf: Vec<f32> = z.iter().map(|&v| v as f32).collect();
            let cos = dot(&y, &zf) / norm(&zf);
            assert!(cos > 0.7, "row {i}: cos {cos}");
        }
    }

    #[test]
    fn lattice_quantizer_end_to_end() {
        let d = toy(300);
        let q = CatalystLattice::train(&d.data, d.dim, 8);
        assert_eq!(q.nominal_bytes(), 8);
        assert_eq!(q.code_bytes(), 24);
        assert!(!q.supports_rerank());
        let mut code = vec![0u8; q.code_bytes()];
        q.encode_one(d.row(0), &mut code);
        let lut = q.lut(d.row(0));
        // a vector should be closer to its own code than to a far one
        let mut code_far = vec![0u8; q.code_bytes()];
        // find a row with large original distance
        let mut far = 1;
        for i in 1..d.len() {
            if crate::linalg::sq_l2(d.row(0), d.row(i))
                > crate::linalg::sq_l2(d.row(0), d.row(far))
            {
                far = i;
            }
        }
        q.encode_one(d.row(far), &mut code_far);
        assert!(lut.score(&code) < lut.score(&code_far));
    }

    #[test]
    fn catalyst_opq_end_to_end() {
        let d = toy(500);
        let q = CatalystOpq::train(&d.data, d.dim, 8, 16, 0);
        assert_eq!(q.code_bytes(), 8);
        let mut code = vec![0u8; 8];
        q.encode_one(d.row(3), &mut code);
        let lut = q.lut(d.row(3));
        let own = lut.score(&code);
        let mut other = vec![0u8; 8];
        q.encode_one(d.row(100), &mut other);
        assert!(own <= lut.score(&other) + 1e-3);
    }
}
