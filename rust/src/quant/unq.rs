//! UNQ as a [`Quantizer`]: the paper's method, served from AOT artifacts.
//!
//! * `encode_batch` → the AOT `encode` graph (encoder MLP + fused
//!   assignment Pallas kernel) through PJRT,
//! * `lut` → the AOT `lut` graph; the raw dot products ⟨net(q)_m, c_mk⟩
//!   are negated so the uniform scan convention (lower = closer) realizes
//!   the paper's `d2` (eq. 8),
//! * `reconstruct_batch` → the AOT `decode` graph, giving `d1` (eq. 7)
//!   for the two-stage rerank.
//!
//! The struct holds only a [`RuntimeHandle`], so it is `Send + Sync` and
//! plugs into the same index/search machinery as the shallow baselines.
//!
//! **Failure contract.**  The `Quantizer` trait methods cannot return
//! errors, so a runtime failure mid-scan is unrecoverable; construct
//! through [`UnqQuantizer::try_new`], which probes all three graphs and
//! surfaces a broken runtime as a clean `Err` at construction/first-use
//! instead.  After a successful probe the remaining failure mode is the
//! runtime thread dying mid-run, which encode/lut report as a
//! descriptive panic; both reconstruct paths degrade to `false` (the
//! engine then skips the rerank, same as for decoders that don't exist).
//! The fully native training path lives in [`super::unq_native`].

use anyhow::Context;

use crate::runtime::RuntimeHandle;
use crate::Result;

use super::{Lut, Quantizer};

pub struct UnqQuantizer {
    pub rt: RuntimeHandle,
}

impl UnqQuantizer {
    /// Wrap a handle without probing it (callers that already ran their
    /// own health check, e.g. tests against a known-live runtime).
    pub fn new(rt: RuntimeHandle) -> UnqQuantizer {
        UnqQuantizer { rt }
    }

    /// Wrap a handle and probe the encode/lut/decode graphs with one
    /// dummy row, so a broken runtime (missing PJRT, bad artifact,
    /// dead thread) is a clean error here — at construction — rather
    /// than a panic in the middle of a scan.
    pub fn try_new(rt: RuntimeHandle) -> Result<UnqQuantizer> {
        let q = UnqQuantizer { rt };
        let probe = vec![0.0f32; q.dim()];
        q.rt
            .encode(&probe, 1)
            .context("UNQ runtime probe: encode graph")?;
        q.rt.lut(&probe, 1).context("UNQ runtime probe: lut graph")?;
        let code = vec![0u8; q.code_bytes()];
        q.rt
            .decode(&code, 1)
            .context("UNQ runtime probe: decode graph")?;
        Ok(q)
    }

    pub fn m(&self) -> usize {
        self.rt.manifest.m
    }

    pub fn k(&self) -> usize {
        self.rt.manifest.k
    }

    /// The one place the infallible trait methods give up: a runtime
    /// that passed its construction probe stopped serving mid-run.
    fn runtime_died(&self, what: &str, e: anyhow::Error) -> ! {
        panic!(
            "UNQ runtime {what} failed after a successful construction \
             probe (runtime thread died?): {e:#}"
        );
    }
}

impl Quantizer for UnqQuantizer {
    fn name(&self) -> String {
        match self.rt.manifest.variant.as_str() {
            "unq" => "UNQ".to_string(),
            v => format!("UNQ[{v}]"),
        }
    }

    fn code_bytes(&self) -> usize {
        self.rt.manifest.m
    }

    fn dim(&self) -> usize {
        self.rt.manifest.dim
    }

    fn encode_one(&self, x: &[f32], out: &mut [u8]) {
        let codes = self
            .rt
            .encode(x, 1)
            .unwrap_or_else(|e| self.runtime_died("encode", e));
        out.copy_from_slice(&codes);
    }

    fn encode_batch(&self, data: &[f32]) -> Vec<u8> {
        let rows = data.len() / self.dim();
        self.rt
            .encode(data, rows)
            .unwrap_or_else(|e| self.runtime_died("encode", e))
    }

    fn lut(&self, q: &[f32]) -> Lut {
        let dots = self
            .rt
            .lut(q, 1)
            .unwrap_or_else(|e| self.runtime_died("lut", e));
        let (m, k) = (self.m(), self.k());
        // d2(q, i) = −Σ_m ⟨net(q)_m, c_m i_m⟩ (+ rank-invariant const)
        let tables: Vec<f32> = dots.iter().map(|&v| -v).collect();
        Lut::Tables { m, k, tables, bias: 0.0 }
    }

    fn lut_batch(&self, queries: &[&[f32]]) -> Vec<Lut> {
        let dim = self.dim();
        let (m, k) = (self.m(), self.k());
        let mut flat = Vec::with_capacity(queries.len() * dim);
        for q in queries {
            flat.extend_from_slice(q);
        }
        let dots = self
            .rt
            .lut(&flat, queries.len())
            .unwrap_or_else(|e| self.runtime_died("lut", e));
        dots.chunks_exact(m * k)
            .map(|chunk| Lut::Tables {
                m,
                k,
                tables: chunk.iter().map(|&v| -v).collect(),
                bias: 0.0,
            })
            .collect()
    }

    fn reconstruct(&self, code: &[u8], out: &mut [f32]) -> bool {
        match self.rt.decode(code, 1) {
            Ok(rec) if rec.len() == out.len() => {
                out.copy_from_slice(&rec);
                true
            }
            _ => false,
        }
    }

    fn reconstruct_batch(&self, codes: &[u8], out: &mut [f32]) -> bool {
        let rows = codes.len() / self.code_bytes();
        match self.rt.decode(codes, rows) {
            Ok(rec) if rec.len() == out.len() => {
                out.copy_from_slice(&rec);
                true
            }
            _ => false,
        }
    }
}
