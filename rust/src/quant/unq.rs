//! UNQ as a [`Quantizer`]: the paper's method, served from AOT artifacts.
//!
//! * `encode_batch` → the AOT `encode` graph (encoder MLP + fused
//!   assignment Pallas kernel) through PJRT,
//! * `lut` → the AOT `lut` graph; the raw dot products ⟨net(q)_m, c_mk⟩
//!   are negated so the uniform scan convention (lower = closer) realizes
//!   the paper's `d2` (eq. 8),
//! * `reconstruct_batch` → the AOT `decode` graph, giving `d1` (eq. 7)
//!   for the two-stage rerank.
//!
//! The struct holds only a [`RuntimeHandle`], so it is `Send + Sync` and
//! plugs into the same index/search machinery as the shallow baselines.

use crate::runtime::RuntimeHandle;

use super::{Lut, Quantizer};

pub struct UnqQuantizer {
    pub rt: RuntimeHandle,
}

impl UnqQuantizer {
    pub fn new(rt: RuntimeHandle) -> UnqQuantizer {
        UnqQuantizer { rt }
    }

    pub fn m(&self) -> usize {
        self.rt.manifest.m
    }

    pub fn k(&self) -> usize {
        self.rt.manifest.k
    }
}

impl Quantizer for UnqQuantizer {
    fn name(&self) -> String {
        match self.rt.manifest.variant.as_str() {
            "unq" => "UNQ".to_string(),
            v => format!("UNQ[{v}]"),
        }
    }

    fn code_bytes(&self) -> usize {
        self.rt.manifest.m
    }

    fn dim(&self) -> usize {
        self.rt.manifest.dim
    }

    fn encode_one(&self, x: &[f32], out: &mut [u8]) {
        let codes = self.rt.encode(x, 1).expect("runtime encode");
        out.copy_from_slice(&codes);
    }

    fn encode_batch(&self, data: &[f32]) -> Vec<u8> {
        let rows = data.len() / self.dim();
        self.rt.encode(data, rows).expect("runtime encode")
    }

    fn lut(&self, q: &[f32]) -> Lut {
        let dots = self.rt.lut(q, 1).expect("runtime lut");
        let (m, k) = (self.m(), self.k());
        // d2(q, i) = −Σ_m ⟨net(q)_m, c_m i_m⟩ (+ rank-invariant const)
        let tables: Vec<f32> = dots.iter().map(|&v| -v).collect();
        Lut::Tables { m, k, tables, bias: 0.0 }
    }

    fn lut_batch(&self, queries: &[&[f32]]) -> Vec<Lut> {
        let dim = self.dim();
        let (m, k) = (self.m(), self.k());
        let mut flat = Vec::with_capacity(queries.len() * dim);
        for q in queries {
            flat.extend_from_slice(q);
        }
        let dots = self.rt.lut(&flat, queries.len()).expect("runtime lut");
        dots.chunks_exact(m * k)
            .map(|chunk| Lut::Tables {
                m,
                k,
                tables: chunk.iter().map(|&v| -v).collect(),
                bias: 0.0,
            })
            .collect()
    }

    fn reconstruct(&self, code: &[u8], out: &mut [f32]) -> bool {
        match self.rt.decode(code, 1) {
            Ok(rec) => {
                out.copy_from_slice(&rec);
                true
            }
            Err(_) => false,
        }
    }

    fn reconstruct_batch(&self, codes: &[u8], out: &mut [f32]) -> bool {
        let rows = codes.len() / self.code_bytes();
        match self.rt.decode(codes, rows) {
            Ok(rec) => {
                out.copy_from_slice(&rec);
                true
            }
            Err(_) => false,
        }
    }
}
