//! Native UNQ: the paper's DNN quantizer trained **in pure Rust** — no
//! PJRT, no AOT artifacts, no Python (the AOT-backed [`super::unq`] path
//! stays as the accelerator seam; rust/DESIGN.md §8 discusses the seam).
//!
//! Architecture (paper §3.1–3.2, eq. 4–8), built on [`crate::nn`]:
//!
//! * **Encoder** `net(x)`: a skip-connected MLP `R^D → R^{M·ds}` whose
//!   output splits into M per-codebook chunks `net(x)_m ∈ R^{ds}`.
//! * **Codebooks** `C ∈ R^{M × K × ds}`, learnable, initialized by
//!   k-means in the *initial* encoder space.  Because the fresh encoder
//!   is the identity projection (zero-init correction branch), the
//!   untrained model is **exactly PQ** — same codes, same ADC scores,
//!   same reconstructions (pinned by a test) — for training sets up to
//!   [`KMEANS_INIT_CAP`] rows; above the cap the init k-means runs on a
//!   seeded subsample (PQ-*quality*, not PQ-identical).  Either way
//!   training starts from a strong, known-good operating point.
//! * **Assignment** (eq. 4): hard `i_m = argmin_k ‖net(x)_m − c_mk‖²`,
//!   relaxed during training by Gumbel-softmax over the negated squared
//!   distances with temperature τ, straight-through style: the forward
//!   pass uses the hard one-hot selection, the backward pass uses the
//!   soft probabilities (the `hard` flag of [`NativeUnq::step`]; soft
//!   mode is fully differentiable and finite-difference checked).
//! * **Decoder**: a mirror-image skip-connected MLP `R^{M·ds} → R^D`
//!   over the concatenated selected codewords, giving `d1` (eq. 7) for
//!   the two-stage rerank.
//! * **Objective** (unsupervised, eq. 5–6 flavor): reconstruction MSE
//!   `‖dec(ĉ(x)) − x‖²` plus the compressed-domain *consistency* term
//!   `λ · ‖net(x)_m − c_m i_m‖²` that keeps the learned-space ADC scores
//!   (`d2`, eq. 8) faithful to the encoder geometry — without it the
//!   scan-stage distances and the decoder could drift apart.
//!
//! The scan contract: [`NativeUnq::lut`] emits per-position tables
//! `‖c_mk‖² − 2⟨net(q)_m, c_mk⟩` with bias `‖net(q)‖²`, so the scanned
//! score **equals** `d2(q, i) = ‖net(q) − ĉ(i)‖²` exactly — the negated
//! dot products of the AOT convention, completed with the rank-relevant
//! codeword-norm term so lower = closer holds in the repo's uniform
//! sense.  This plugs into every read path (flat, IVF, streaming,
//! packed integer kernels) through the ordinary [`Quantizer`] trait.

use crate::config::UnqNativeConfig;
use crate::linalg::dot;
use crate::nn::{softmax_t_backward, softmax_t_rows, Adam, Mlp};
use crate::store::Store;
use crate::util::rng::SplitMix64;
use crate::Result;

use super::{Lut, Quantizer};

/// Per-epoch training record (the loss curve `train-smoke` uploads).
#[derive(Clone, Copy, Debug)]
pub struct EpochStats {
    pub epoch: usize,
    pub tau: f32,
    /// mean reconstruction MSE term over the epoch
    pub rec_loss: f64,
    /// mean (unweighted) consistency term over the epoch
    pub cons_loss: f64,
}

/// The trained native UNQ model (encoder + codebooks + decoder).
pub struct NativeUnq {
    pub dim: usize,
    pub m: usize,
    pub k: usize,
    /// per-codebook code-space sub-dimension (`dc = m · ds`)
    pub ds: usize,
    pub enc: Mlp,
    pub dec: Mlp,
    /// `m × k × ds` flat codewords
    pub codebooks: Vec<f32>,
    /// codebook gradient accumulator (same layout)
    pub gcode: Vec<f32>,
    /// loss curve of the `fit` that produced this model (empty when
    /// loaded from a store archive)
    pub history: Vec<EpochStats>,
}

/// Rows used for the k-means codebook initialization (full data below
/// this, a seeded subsample above — keeps init O(cap · K · ds) while
/// staying deterministic).
pub const KMEANS_INIT_CAP: usize = 20_000;

impl NativeUnq {
    /// Build the untrained model: seeded-init networks (identity skip,
    /// zero correction) + codebooks from k-means in the initial encoder
    /// space.  With `ds = dim/m` and up to [`KMEANS_INIT_CAP`] training
    /// rows this starting point is exactly PQ (subsampled k-means
    /// above the cap: PQ-quality, not bit-identical to `Pq::train`).
    pub fn init(data: &[f32], dim: usize, m: usize, k: usize,
                cfg: &UnqNativeConfig) -> NativeUnq {
        assert!(dim > 0 && m > 0, "degenerate shape");
        assert!((1..=256).contains(&k), "codes are single bytes");
        let ds = if cfg.ds > 0 {
            cfg.ds
        } else {
            assert!(dim % m == 0,
                    "native UNQ default needs dim % m == 0 ({dim} % {m}); \
                     set unq_native.ds explicitly otherwise");
            dim / m
        };
        let dc = m * ds;
        let mut rng = SplitMix64::from_key(&[cfg.seed, 0x4e51_494e]);
        let enc = Mlp::new(dim, cfg.hidden, dc, &mut rng);
        let dec = Mlp::new(dc, cfg.hidden, dim, &mut rng);
        let mut model = NativeUnq {
            dim,
            m,
            k,
            ds,
            enc,
            dec,
            codebooks: vec![0.0; m * k * ds],
            gcode: vec![0.0; m * k * ds],
            history: Vec::new(),
        };

        // k-means per codebook over the initial encoder outputs
        let n = data.len() / dim;
        let h0: Vec<f32> = if n > KMEANS_INIT_CAP {
            let idx = rng.sample_indices(n, KMEANS_INIT_CAP);
            let mut sub = Vec::with_capacity(idx.len() * dim);
            for &i in &idx {
                sub.extend_from_slice(&data[i * dim..(i + 1) * dim]);
            }
            model.enc.infer(&sub, idx.len())
        } else {
            model.enc.infer(data, n)
        };
        // delegate the per-book subvector k-means to Pq::train over the
        // encoder outputs: same seed schedule, same (m, k, ds) centroid
        // layout — the "untrained model == PQ" invariant holds by
        // construction instead of by two hand-synced loops
        let pq = super::pq::Pq::train(&h0, dc, m, k, cfg.seed,
                                      cfg.kmeans_iters);
        model.codebooks.copy_from_slice(&pq.centroids);
        model
    }

    /// Train from scratch: [`NativeUnq::init`] + [`NativeUnq::fit`].
    pub fn train(data: &[f32], dim: usize, m: usize, k: usize,
                 cfg: &UnqNativeConfig) -> NativeUnq {
        let mut model = Self::init(data, dim, m, k, cfg);
        model.fit(data, cfg);
        model
    }

    /// Run `cfg.epochs` of minibatch Adam on the unsupervised objective
    /// over `data` (flat rows of `self.dim`).  Fully deterministic given
    /// `cfg.seed`: shuffling and Gumbel noise come from one seeded
    /// stream, and execution is single-threaded.
    pub fn fit(&mut self, data: &[f32], cfg: &UnqNativeConfig) {
        let dim = self.dim;
        let n = data.len() / dim;
        if cfg.epochs == 0 || n == 0 {
            return;
        }
        let (m, k) = (self.m, self.k);
        let mut rng = SplitMix64::from_key(&[cfg.seed, 0x7472_4149]);
        let mut opt = Adam::new(cfg.lr);
        let mut perm: Vec<usize> = (0..n).collect();
        let mut xb: Vec<f32> = Vec::new();
        for epoch in 0..cfg.epochs {
            let t_epoch = std::time::Instant::now();
            let tau = if cfg.epochs <= 1 {
                cfg.tau0
            } else {
                let f = epoch as f32 / (cfg.epochs - 1) as f32;
                cfg.tau0 + (cfg.tau1 - cfg.tau0) * f
            };
            rng.shuffle(&mut perm);
            let mut sum_rec = 0.0f64;
            let mut sum_cons = 0.0f64;
            for chunk in perm.chunks(cfg.batch.max(1)) {
                let nb = chunk.len();
                xb.clear();
                for &i in chunk {
                    xb.extend_from_slice(&data[i * dim..(i + 1) * dim]);
                }
                let noise: Option<Vec<f32>> = if cfg.gumbel > 0.0 {
                    Some((0..nb * m * k)
                        .map(|_| {
                            let u = rng.next_f32().max(1e-7);
                            -(-(u.ln())).ln() * cfg.gumbel
                        })
                        .collect())
                } else {
                    None
                };
                self.zero_grad();
                let (lr_, lc_) = self.step(&xb, nb, tau, cfg.lambda_cons,
                                           true, true, noise.as_deref());
                opt.begin_step();
                self.adam_step(&mut opt);
                sum_rec += lr_ * nb as f64;
                sum_cons += lc_ * nb as f64;
            }
            let stats = EpochStats {
                epoch,
                tau,
                rec_loss: sum_rec / n as f64,
                cons_loss: sum_cons / n as f64,
            };
            eprintln!(
                "[unq-native] epoch {:>3}/{} tau {:.3} rec {:.5} cons {:.5}",
                epoch + 1, cfg.epochs, tau, stats.rec_loss, stats.cons_loss
            );
            let reg = crate::obs::global();
            reg.train_epochs.inc();
            reg.train_last_loss
                .set(stats.rec_loss + cfg.lambda_cons as f64 * stats.cons_loss);
            reg.train_epoch_us.record(t_epoch.elapsed().as_micros() as u64);
            self.history.push(stats);
        }
    }

    /// One forward/backward pass over a flat `nb × dim` minibatch,
    /// accumulating parameter gradients; returns the (reconstruction,
    /// consistency) loss terms — the optimized scalar is
    /// `rec + λ · cons`.
    ///
    /// * `hard = true` — training mode: hard one-hot selection forward,
    ///   soft (Gumbel-softmax) gradients backward (straight-through).
    /// * `hard = false` — the fully differentiable relaxation (decoder
    ///   sees `Σ_k p_k c_k`): exact gradients, used by the
    ///   finite-difference checks.
    /// * `update_stats = false` freezes the norm-layer statistics so the
    ///   loss is a deterministic pure function of the parameters.
    pub fn step(&mut self, xb: &[f32], nb: usize, tau: f32, lambda: f32,
                hard: bool, update_stats: bool, noise: Option<&[f32]>)
                -> (f64, f64) {
        let (dim, m, k, ds) = (self.dim, self.m, self.k, self.ds);
        let dc = m * ds;
        debug_assert_eq!(xb.len(), nb * dim);

        // ---- forward ----------------------------------------------------
        let (h, enc_cache) = self.enc.forward(xb, nb, update_stats);
        let mut logits = vec![0.0f32; nb * m * k];
        for b in 0..nb {
            for j in 0..m {
                let hv = &h[b * dc + j * ds..b * dc + (j + 1) * ds];
                let row = &mut logits[(b * m + j) * k..(b * m + j + 1) * k];
                for (c, l) in row.iter_mut().enumerate() {
                    let cw = &self.codebooks[(j * k + c) * ds
                                             ..(j * k + c + 1) * ds];
                    *l = -crate::linalg::sq_l2(hv, cw);
                }
            }
        }
        if let Some(ns) = noise {
            debug_assert_eq!(ns.len(), logits.len());
            for (l, g) in logits.iter_mut().zip(ns) {
                *l += g;
            }
        }
        let p = softmax_t_rows(&logits, nb * m, k, tau);
        // decoder input: hard one-hot selection or the soft mixture
        let mut bvec = vec![0.0f32; nb * dc];
        for b in 0..nb {
            for j in 0..m {
                let row = &p[(b * m + j) * k..(b * m + j + 1) * k];
                let out = &mut bvec[b * dc + j * ds..b * dc + (j + 1) * ds];
                if hard {
                    let mut best = 0usize;
                    for (c, &pv) in row.iter().enumerate() {
                        if pv > row[best] {
                            best = c;
                        }
                    }
                    out.copy_from_slice(
                        &self.codebooks[(j * k + best) * ds
                                        ..(j * k + best + 1) * ds]);
                } else {
                    for (c, &pv) in row.iter().enumerate() {
                        if pv > 1e-12 {
                            let cw = &self.codebooks[(j * k + c) * ds
                                                     ..(j * k + c + 1) * ds];
                            for (o, &w) in out.iter_mut().zip(cw) {
                                *o += pv * w;
                            }
                        }
                    }
                }
            }
        }
        let (recon, dec_cache) = self.dec.forward(&bvec, nb, update_stats);

        // ---- losses -----------------------------------------------------
        let inv_rec = 1.0 / (nb * dim) as f32;
        let inv_cons = 1.0 / (nb * dc) as f32;
        let mut l_rec = 0.0f64;
        for (r, x) in recon.iter().zip(xb) {
            let d = (r - x) as f64;
            l_rec += d * d;
        }
        l_rec *= inv_rec as f64;
        let mut l_cons = 0.0f64;
        for (a, b) in h.iter().zip(&bvec) {
            let d = (a - b) as f64;
            l_cons += d * d;
        }
        l_cons *= inv_cons as f64;

        // ---- backward ---------------------------------------------------
        let drecon: Vec<f32> = recon
            .iter()
            .zip(xb)
            .map(|(&r, &x)| 2.0 * (r - x) * inv_rec)
            .collect();
        let mut dbvec = self.dec.backward(&dec_cache, &drecon, nb);
        // consistency: ∂/∂h directly, ∂/∂bvec through the shared path
        let mut dh = vec![0.0f32; nb * dc];
        for i in 0..nb * dc {
            let d = 2.0 * lambda * (h[i] - bvec[i]) * inv_cons;
            dh[i] += d;
            dbvec[i] -= d;
        }
        // soft-assignment backward (straight-through when `hard`):
        // bvec_m = Σ_k p_k c_k ⇒ dC += p · dbvec, dp_k = ⟨dbvec, c_k⟩
        let mut dp = vec![0.0f32; nb * m * k];
        {
            let code = &self.codebooks;
            let gcode = &mut self.gcode;
            for b in 0..nb {
                for j in 0..m {
                    let dbv = &dbvec[b * dc + j * ds..b * dc + (j + 1) * ds];
                    for c in 0..k {
                        let pv = p[(b * m + j) * k + c];
                        let cw = &code[(j * k + c) * ds
                                       ..(j * k + c + 1) * ds];
                        dp[(b * m + j) * k + c] = dot(dbv, cw);
                        if pv > 1e-12 {
                            let gw = &mut gcode[(j * k + c) * ds
                                                ..(j * k + c + 1) * ds];
                            for (g, &d) in gw.iter_mut().zip(dbv) {
                                *g += pv * d;
                            }
                        }
                    }
                }
            }
        }
        let dlogits = softmax_t_backward(&p, &dp, nb * m, k, tau);
        // logits = −‖h_m − c_mk‖² ⇒ ∂/∂h = −2(h − c), ∂/∂c = 2(h − c)
        {
            let code = &self.codebooks;
            let gcode = &mut self.gcode;
            for b in 0..nb {
                for j in 0..m {
                    let hv_lo = b * dc + j * ds;
                    for c in 0..k {
                        let dl = dlogits[(b * m + j) * k + c];
                        if dl == 0.0 {
                            continue;
                        }
                        let cw_lo = (j * k + c) * ds;
                        for t in 0..ds {
                            let diff = h[hv_lo + t] - code[cw_lo + t];
                            dh[hv_lo + t] -= 2.0 * dl * diff;
                            gcode[cw_lo + t] += 2.0 * dl * diff;
                        }
                    }
                }
            }
        }
        let _dx = self.enc.backward(&enc_cache, &dh, nb);
        (l_rec, l_cons)
    }

    pub fn zero_grad(&mut self) {
        self.enc.zero_grad();
        self.dec.zero_grad();
        self.gcode.iter_mut().for_each(|v| *v = 0.0);
    }

    /// One Adam update over every parameter tensor (call after
    /// `opt.begin_step()`).
    pub fn adam_step(&mut self, opt: &mut Adam) {
        let mut slot = 0usize;
        self.enc.adam_step(opt, &mut slot);
        self.dec.adam_step(opt, &mut slot);
        opt.update(slot, &mut self.codebooks, &self.gcode);
    }

    /// The encoder map `net(x)` for one vector (eval mode).
    pub fn net(&self, x: &[f32]) -> Vec<f32> {
        self.enc.infer(x, 1)
    }

    #[inline]
    fn codeword(&self, j: usize, c: usize) -> &[f32] {
        let lo = (j * self.k + c) * self.ds;
        &self.codebooks[lo..lo + self.ds]
    }

    /// Hard assignment of one encoded vector: per-book nearest codeword
    /// in code space (ties → smallest id, matching the engine's
    /// deterministic tie rule).
    fn assign(&self, h: &[f32], out: &mut [u8]) {
        let ds = self.ds;
        for j in 0..self.m {
            let hv = &h[j * ds..(j + 1) * ds];
            let mut best = 0usize;
            let mut best_d = f32::INFINITY;
            for c in 0..self.k {
                let d = crate::linalg::sq_l2(hv, self.codeword(j, c));
                if d < best_d {
                    best_d = d;
                    best = c;
                }
            }
            out[j] = best as u8;
        }
    }

    /// Concatenated selected codewords of one code (the decoder input).
    fn gather_codewords(&self, code: &[u8], out: &mut [f32]) {
        let ds = self.ds;
        for (j, &c) in code.iter().enumerate() {
            out[j * ds..(j + 1) * ds]
                .copy_from_slice(self.codeword(j, c as usize));
        }
    }

    pub fn param_count(&self) -> usize {
        self.enc.param_count() + self.dec.param_count()
            + self.codebooks.len()
    }

    pub fn save(&self, store: &mut Store, prefix: &str) {
        self.enc.save(store, &format!("{prefix}nenc_"));
        self.dec.save(store, &format!("{prefix}ndec_"));
        store.put_f32(&format!("{prefix}ncodebooks"),
                      &[self.m, self.k, self.ds], self.codebooks.clone());
        store.put_meta(&format!("{prefix}unq_native"),
                       &format!("{},{},{},{}", self.dim, self.m, self.k,
                                self.ds));
    }

    pub fn load(store: &Store, prefix: &str) -> Result<NativeUnq> {
        let meta = store
            .get_meta(&format!("{prefix}unq_native"))
            .ok_or_else(|| anyhow::anyhow!("missing unq_native meta"))?;
        let parts: Vec<usize> =
            meta.split(',').map(|p| p.parse().unwrap_or(0)).collect();
        anyhow::ensure!(parts.len() == 4 && parts.iter().all(|&v| v > 0),
                        "bad unq_native meta {meta:?}");
        let (dim, m, k, ds) = (parts[0], parts[1], parts[2], parts[3]);
        let (_, cb) = store
            .get_f32(&format!("{prefix}ncodebooks"))
            .ok_or_else(|| anyhow::anyhow!("missing native codebooks"))?;
        anyhow::ensure!(cb.len() == m * k * ds, "codebook shape mismatch");
        Ok(NativeUnq {
            dim,
            m,
            k,
            ds,
            enc: Mlp::load(store, &format!("{prefix}nenc_"))?,
            dec: Mlp::load(store, &format!("{prefix}ndec_"))?,
            codebooks: cb.to_vec(),
            gcode: vec![0.0; m * k * ds],
            history: Vec::new(),
        })
    }
}

impl Quantizer for NativeUnq {
    fn name(&self) -> String {
        "UNQ-native".into()
    }

    fn code_bytes(&self) -> usize {
        self.m
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn encode_one(&self, x: &[f32], out: &mut [u8]) {
        debug_assert_eq!(x.len(), self.dim);
        let h = self.enc.infer(x, 1);
        self.assign(&h, out);
    }

    fn encode_batch(&self, data: &[f32]) -> Vec<u8> {
        // chunked: one `infer` over the whole base set would materialize
        // every intermediate activation at dataset scale (gigabytes at
        // n = 1M); fixed-size chunks bound the transient footprint with
        // identical output (inference is row-independent)
        const CHUNK: usize = 4096;
        let n = data.len() / self.dim;
        let dc = self.m * self.ds;
        let mut out = vec![0u8; n * self.m];
        for lo in (0..n).step_by(CHUNK) {
            let hi = (lo + CHUNK).min(n);
            let h = self.enc.infer(&data[lo * self.dim..hi * self.dim],
                                   hi - lo);
            for i in lo..hi {
                self.assign(&h[(i - lo) * dc..(i - lo + 1) * dc],
                            &mut out[i * self.m..(i + 1) * self.m]);
            }
        }
        out
    }

    /// `d2` as position-major ADC tables: entry `(j, c)` is
    /// `‖c_jc‖² − 2⟨net(q)_j, c_jc⟩`, bias `‖net(q)‖²`, so the scanned
    /// score equals `‖net(q) − ĉ(code)‖²` exactly (eq. 8; the negated
    /// dots of the AOT convention plus the codeword-norm completion).
    fn lut(&self, q: &[f32]) -> Lut {
        let h = self.enc.infer(q, 1);
        self.lut_from_net(&h)
    }

    fn lut_batch(&self, queries: &[&[f32]]) -> Vec<Lut> {
        let dim = self.dim;
        let dc = self.m * self.ds;
        let mut flat = Vec::with_capacity(queries.len() * dim);
        for q in queries {
            flat.extend_from_slice(q);
        }
        let h = self.enc.infer(&flat, queries.len());
        (0..queries.len())
            .map(|i| self.lut_from_net(&h[i * dc..(i + 1) * dc]))
            .collect()
    }

    fn reconstruct(&self, code: &[u8], out: &mut [f32]) -> bool {
        let dc = self.m * self.ds;
        let mut bvec = vec![0.0f32; dc];
        self.gather_codewords(code, &mut bvec);
        let rec = self.dec.infer(&bvec, 1);
        if rec.len() != out.len() {
            return false;
        }
        out.copy_from_slice(&rec);
        true
    }

    fn reconstruct_batch(&self, codes: &[u8], out: &mut [f32]) -> bool {
        let rows = codes.len() / self.m;
        let dc = self.m * self.ds;
        if out.len() != rows * self.dim {
            return false;
        }
        let mut bvec = vec![0.0f32; rows * dc];
        for i in 0..rows {
            self.gather_codewords(&codes[i * self.m..(i + 1) * self.m],
                                  &mut bvec[i * dc..(i + 1) * dc]);
        }
        let rec = self.dec.infer(&bvec, rows);
        out.copy_from_slice(&rec);
        true
    }
}

impl NativeUnq {
    /// Build the `d2` LUT from an already-encoded query `net(q)`.
    fn lut_from_net(&self, h: &[f32]) -> Lut {
        let (m, k, ds) = (self.m, self.k, self.ds);
        let mut tables = vec![0.0f32; m * k];
        for j in 0..m {
            let hv = &h[j * ds..(j + 1) * ds];
            for c in 0..k {
                let cw = self.codeword(j, c);
                tables[j * k + c] = dot(cw, cw) - 2.0 * dot(hv, cw);
            }
        }
        Lut::Tables { m, k, tables, bias: dot(h, h) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SearchConfig;
    use crate::data::Dataset;
    use crate::index::{CompressedIndex, SearchEngine};
    use crate::linalg::sq_l2;
    use crate::nn::grads_close;
    use crate::quant::pq::Pq;
    use crate::quant::reconstruction_mse;
    use crate::util::{prop, TempDir};

    /// Correlated random rows (dim 8): a planted 2-cluster mixture so
    /// quantizers have structure to learn.
    fn toy(n: usize, seed: u64) -> Dataset {
        let dim = 8;
        let mut rng = SplitMix64::new(seed);
        let mut data = Vec::with_capacity(n * dim);
        for i in 0..n {
            let center = if i % 2 == 0 { 1.5 } else { -1.5 };
            for j in 0..dim {
                let coupled = if j % 2 == 0 { center } else { -center };
                data.push(coupled + rng.normal() * 0.7);
            }
        }
        Dataset::new(dim, data)
    }

    fn tiny_cfg() -> UnqNativeConfig {
        UnqNativeConfig {
            hidden: 6,
            epochs: 2,
            batch: 32,
            kmeans_iters: 5,
            seed: 3,
            ..Default::default()
        }
    }

    #[test]
    fn untrained_model_is_exactly_pq() {
        // identity skip + zero correction + k-means init in the (then
        // identity) encoder space ⇒ codes, ADC scores and
        // reconstructions all coincide with PQ trained the same way
        let d = toy(300, 1);
        let cfg = tiny_cfg();
        let model = NativeUnq::init(&d.data, d.dim, 2, 4, &cfg);
        let pq = Pq::train(&d.data, d.dim, 2, 4, cfg.seed, cfg.kmeans_iters);
        let codes_n = model.encode_batch(&d.data);
        let codes_p = pq.encode_batch(&d.data);
        assert_eq!(codes_n, codes_p, "init codes must equal PQ");
        let q = d.row(7);
        let lut_n = model.lut(q);
        let lut_p = pq.lut(q);
        for code in codes_n.chunks(2).take(40) {
            let sn = lut_n.score(code);
            let sp = lut_p.score(code);
            assert!((sn - sp).abs() <= 1e-3 * sp.abs().max(1.0),
                    "ADC scores diverge at init: {sn} vs {sp}");
        }
        let mut rn = vec![0.0f32; d.dim];
        let mut rp = vec![0.0f32; d.dim];
        assert!(model.reconstruct(&codes_n[..2], &mut rn));
        pq.reconstruct(&codes_p[..2], &mut rp);
        for (a, b) in rn.iter().zip(&rp) {
            assert!((a - b).abs() < 1e-5, "init reconstructions diverge");
        }
    }

    #[test]
    fn full_stack_grads_match_finite_differences() {
        // soft (differentiable) mode, frozen norm stats, no noise: the
        // analytic gradient of rec + λ·cons through encoder → softmax
        // assignment → codebooks → decoder must match central differences
        let d = toy(64, 2);
        let cfg = tiny_cfg();
        let mut model = NativeUnq::init(&d.data, d.dim, 2, 4, &cfg);
        // move off the all-zero correction branch so every tensor has
        // signal, and perturb bn stats away from the trivial point
        let mut rng = SplitMix64::new(17);
        for v in model.enc.l2.w.iter_mut() {
            *v = rng.normal() * 0.1;
        }
        for v in model.dec.l2.w.iter_mut() {
            *v = rng.normal() * 0.1;
        }
        for f in 0..model.enc.bn.dim {
            model.enc.bn.running_mean[f] = rng.normal() * 0.2;
            model.enc.bn.running_var[f] = 0.5 + rng.next_f32();
        }
        let nb = 6usize;
        let xb = d.data[..nb * d.dim].to_vec();
        let (tau, lambda) = (0.7f32, 0.3f32);
        let loss = |model: &mut NativeUnq| -> f64 {
            let (r, c) = model.step(&xb, nb, tau, lambda, false, false,
                                    None);
            r + lambda as f64 * c
        };
        model.zero_grad();
        let base = loss(&mut model);
        assert!(base.is_finite());
        // grads were accumulated by the base call — snapshot them
        model.zero_grad();
        model.step(&xb, nb, tau, lambda, false, false, None);
        let g_enc_l1 = model.enc.l1.gw.clone();
        let g_enc_skip = model.enc.skip.gw.clone();
        let g_enc_gamma = model.enc.bn.ggamma.clone();
        let g_dec_l2 = model.dec.l2.gw.clone();
        let g_dec_skip = model.dec.skip.gw.clone();
        let g_code = model.gcode.clone();
        let eps = 1e-2f32;
        let tol = 0.05f32;
        macro_rules! fd_tensor {
            ($name:expr, $field:expr, $grad:expr, $stride:expr) => {
                for idx in (0..$grad.len()).step_by($stride) {
                    let old = $field[idx];
                    $field[idx] = old + eps;
                    let lp = loss(&mut model);
                    $field[idx] = old - eps;
                    let lm = loss(&mut model);
                    $field[idx] = old;
                    let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
                    assert!(grads_close($grad[idx], fd, tol),
                            "{}[{idx}]: analytic {} vs fd {fd}", $name,
                            $grad[idx]);
                }
            };
        }
        fd_tensor!("enc.l1.w", model.enc.l1.w, g_enc_l1, 3);
        fd_tensor!("enc.skip.w", model.enc.skip.w, g_enc_skip, 3);
        fd_tensor!("enc.bn.gamma", model.enc.bn.gamma, g_enc_gamma, 1);
        fd_tensor!("dec.l2.w", model.dec.l2.w, g_dec_l2, 3);
        fd_tensor!("dec.skip.w", model.dec.skip.w, g_dec_skip, 3);
        fd_tensor!("codebooks", model.codebooks, g_code, 1);
    }

    #[test]
    fn training_is_seed_deterministic() {
        let d = toy(200, 5);
        let cfg = tiny_cfg();
        let a = NativeUnq::train(&d.data, d.dim, 2, 4, &cfg);
        let b = NativeUnq::train(&d.data, d.dim, 2, 4, &cfg);
        assert_eq!(a.codebooks, b.codebooks, "same seed, same codebooks");
        assert_eq!(a.enc.l1.w, b.enc.l1.w);
        assert_eq!(a.encode_batch(&d.data), b.encode_batch(&d.data));
        assert_eq!(a.history.len(), cfg.epochs);
        assert_eq!(a.history[0].rec_loss, b.history[0].rec_loss);
        let mut cfg2 = cfg;
        cfg2.seed = 99;
        let c = NativeUnq::train(&d.data, d.dim, 2, 4, &cfg2);
        assert_ne!(a.codebooks, c.codebooks, "different seed must differ");
    }

    #[test]
    fn prop_lut_scan_score_equals_explicit_d2() {
        let d = toy(200, 7);
        let cfg = tiny_cfg();
        let model = NativeUnq::train(&d.data, d.dim, 2, 4, &cfg);
        prop::forall_ok(
            31,
            40,
            |r: &mut SplitMix64| {
                let q = prop::vec_f32(r, 8, 3.0);
                let code: Vec<u8> =
                    (0..2).map(|_| r.below(4) as u8).collect();
                (q, code)
            },
            |(q, code)| {
                let lut = model.lut(q);
                let h = model.net(q);
                let mut cw = vec![0.0f32; 8];
                model.gather_codewords(code, &mut cw);
                let d2 = sq_l2(&h, &cw);
                let scanned = lut.score(code);
                if (scanned - d2).abs() <= 1e-3 * d2.abs().max(1.0) {
                    Ok(())
                } else {
                    Err(format!("scan {scanned} != d2 {d2}"))
                }
            },
        );
    }

    #[test]
    fn decoder_rerank_equals_d1_and_batch_matches_rows() {
        let d = toy(240, 9);
        let cfg = tiny_cfg();
        let model = NativeUnq::train(&d.data, d.dim, 2, 4, &cfg);
        let index = CompressedIndex::build(&model, &d);
        // batch reconstruction ≡ row-by-row reconstruction
        let mut batch = vec![0.0f32; index.n * d.dim];
        assert!(model.reconstruct_batch(&index.codes, &mut batch));
        let mut row = vec![0.0f32; d.dim];
        for i in (0..index.n).step_by(17) {
            assert!(model.reconstruct(index.code(i), &mut row));
            assert_eq!(&batch[i * d.dim..(i + 1) * d.dim], &row[..]);
        }
        // the engine's exhaustive rerank must order by exactly
        // d1(q, i) = ‖q − reconstruct(i)‖²
        let search = SearchConfig { rerank_l: 10, k: 10,
                                    exhaustive_rerank: true,
                                    ..Default::default() };
        let engine = SearchEngine::new(&model, &index, search);
        for qi in [0usize, 11, 42] {
            let q = d.row(qi);
            let got = engine.search(q);
            let mut want: Vec<(f32, u32)> = (0..index.n)
                .map(|i| {
                    (sq_l2(q, &batch[i * d.dim..(i + 1) * d.dim]), i as u32)
                })
                .collect();
            want.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let want_scores: Vec<f32> =
                want[..10].iter().map(|&(s, _)| s).collect();
            let got_scores: Vec<f32> = got
                .iter()
                .map(|&id| {
                    sq_l2(q, &batch[id as usize * d.dim
                                    ..(id as usize + 1) * d.dim])
                })
                .collect();
            for (g, w) in got_scores.iter().zip(&want_scores) {
                assert!((g - w).abs() <= 1e-4 * w.abs().max(1.0),
                        "rerank scores diverge from d1: {g} vs {w}");
            }
        }
    }

    #[test]
    fn save_load_roundtrip_preserves_everything() {
        let d = toy(200, 13);
        let cfg = tiny_cfg();
        let model = NativeUnq::train(&d.data, d.dim, 2, 4, &cfg);
        let mut s = Store::new();
        model.save(&mut s, "");
        let dir = TempDir::new("unq-native").unwrap();
        let p = dir.path().join("native.store");
        s.save(&p).unwrap();
        let back = NativeUnq::load(&Store::load(&p).unwrap(), "").unwrap();
        assert_eq!(back.dim, model.dim);
        assert_eq!(back.m, model.m);
        assert_eq!(back.k, model.k);
        assert_eq!(back.ds, model.ds);
        assert_eq!(back.codebooks, model.codebooks);
        assert_eq!(back.encode_batch(&d.data), model.encode_batch(&d.data));
        let q = d.row(3);
        let (la, lb) = (model.lut(q), back.lut(q));
        let code = [1u8, 2u8];
        assert_eq!(la.score(&code), lb.score(&code));
        let mut ra = vec![0.0f32; d.dim];
        let mut rb = vec![0.0f32; d.dim];
        assert!(model.reconstruct(&code, &mut ra));
        assert!(back.reconstruct(&code, &mut rb));
        assert_eq!(ra, rb);
    }

    #[test]
    fn training_does_not_degrade_reconstruction() {
        // the PQ-equivalent init is a strong floor; a few epochs of the
        // unsupervised objective must keep (and typically improve) it
        let d = toy(400, 21);
        let cfg = UnqNativeConfig { hidden: 8, epochs: 4, batch: 64,
                                    kmeans_iters: 8, seed: 5,
                                    ..Default::default() };
        let init = NativeUnq::init(&d.data, d.dim, 2, 8, &cfg);
        let mse_init = reconstruction_mse(&init, &d);
        let trained = NativeUnq::train(&d.data, d.dim, 2, 8, &cfg);
        let mse_trained = reconstruction_mse(&trained, &d);
        assert!(mse_trained.is_finite() && mse_init.is_finite());
        assert!(mse_trained <= mse_init * 1.05,
                "training degraded reconstruction: {mse_trained} vs \
                 init {mse_init}");
    }

    #[test]
    fn reconstruct_rejects_wrong_output_length() {
        let d = toy(120, 23);
        let model = NativeUnq::init(&d.data, d.dim, 2, 4, &tiny_cfg());
        let code = [0u8, 0u8];
        let mut short = vec![0.0f32; d.dim - 1];
        assert!(!model.reconstruct(&code, &mut short));
        let mut bad = vec![0.0f32; 3];
        assert!(!model.reconstruct_batch(&code, &mut bad));
    }
}
