//! LSQ-style additive quantization (Martinez et al., ECCV 2016 / LSQ++):
//! ICM encoding + regularized least-squares codebook updates.
//!
//! Training alternates:
//! 1. **encode** — all training vectors are re-encoded with ICM (warm-
//!    started from their previous codes, greedy RVQ at iteration 0);
//! 2. **codebook update** — with assignments fixed, the reconstruction
//!    objective `‖X − B C‖²` is quadratic in the stacked codeword matrix
//!    `C (m·k × dim)`; solved via the normal equations
//!    `(BᵀB + λI) C = Bᵀ X` with a Cholesky factorization (`B` is the
//!    one-hot assignment matrix, so `BᵀB` is the code co-occurrence
//!    matrix, assembled in O(n·m²)).
//!
//! The result is an [`Additive`] model (ADC with norm byte, eq. 1
//! decomposition) whose codebooks are jointly optimized rather than
//! greedy — the paper's strongest shallow baseline.

use crate::linalg::cholesky_solve_multi;

use super::additive::Additive;
#[cfg(test)]
use super::Quantizer;

/// LSQ training hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct LsqConfig {
    /// outer (encode + update) alternations
    pub iters: usize,
    /// ICM sweeps per encode, both during training and at index time
    pub icm_sweeps: usize,
    /// Tikhonov regularizer on the normal equations
    pub lambda: f32,
    pub kmeans_iters: usize,
    pub seed: u64,
}

impl Default for LsqConfig {
    fn default() -> Self {
        LsqConfig { iters: 4, icm_sweeps: 3, lambda: 1e-3, kmeans_iters: 8,
                    seed: 0 }
    }
}

/// Train an LSQ model: returns an [`Additive`] labeled "LSQ" with
/// `icm_sweeps` enabled for encode-time refinement.
pub fn train_lsq(data: &[f32], dim: usize, m: usize, k: usize,
                 cfg: &LsqConfig) -> Additive {
    let n = data.len() / dim;
    // init from RVQ (greedy residual codebooks)
    let mut q = Additive::train_rvq(data, dim, m, k, cfg.seed,
                                    cfg.kmeans_iters, "LSQ");
    q.icm_sweeps = cfg.icm_sweeps;

    let mk = m * k;
    let mut codes = vec![0u8; n * m];
    for it in 0..cfg.iters {
        // --- encode step (ICM, warm via greedy inside encode_codes) ---
        for i in 0..n {
            let x = &data[i * dim..(i + 1) * dim];
            let c = &mut codes[i * m..(i + 1) * m];
            q.encode_codes(x, c);
        }

        // --- codebook update: (BᵀB + λI) C = Bᵀ X ---
        let mut btb = vec![0.0f32; mk * mk];
        let mut btx = vec![0.0f32; mk * dim];
        for i in 0..n {
            let c = &codes[i * m..(i + 1) * m];
            let x = &data[i * dim..(i + 1) * dim];
            for a_j in 0..m {
                let a = a_j * k + c[a_j] as usize;
                // BᵀX row
                let row = &mut btx[a * dim..(a + 1) * dim];
                for (rv, xv) in row.iter_mut().zip(x) {
                    *rv += xv;
                }
                // BᵀB entries (symmetric; fill full for simplicity)
                for b_j in 0..m {
                    let b = b_j * k + c[b_j] as usize;
                    btb[a * mk + b] += 1.0;
                }
            }
        }
        // Tikhonov: keeps never-used codewords anchored at 0 and the
        // system positive definite.
        let lambda = cfg.lambda * n as f32 / mk as f32 + 1e-6;
        for a in 0..mk {
            btb[a * mk + a] += lambda;
        }
        let solved = cholesky_solve_multi(&mut btb, mk, &btx, dim);
        match solved {
            Some(c_new) => {
                q.codebooks = c_new;
                q.rebuild_gram();
            }
            None => {
                // numerically singular (tiny toy problems): keep codebooks
                eprintln!("[lsq] iter {it}: singular normal equations, \
                           keeping previous codebooks");
                break;
            }
        }
    }
    q.fit_norm_levels(data);
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic::Generator, Family};

    fn toy(n: usize) -> crate::data::Dataset {
        Generator::new(Family::SiftLike, 5).generate(0, n)
    }

    #[test]
    fn lsq_beats_rvq_reconstruction() {
        let d = toy(800);
        let rvq = Additive::train_rvq(&d.data, d.dim, 4, 32, 0, 8, "RVQ");
        let lsq = train_lsq(&d.data, d.dim, 4, 32, &LsqConfig {
            iters: 3, icm_sweeps: 2, ..Default::default()
        });
        let mse_rvq = rvq.code_mse(&d.data);
        let mse_lsq = lsq.code_mse(&d.data);
        assert!(mse_lsq < mse_rvq,
                "LSQ {mse_lsq} should beat RVQ {mse_rvq}");
    }

    #[test]
    fn lsq_improves_over_iterations() {
        let d = toy(500);
        let one = train_lsq(&d.data, d.dim, 4, 16, &LsqConfig {
            iters: 1, icm_sweeps: 2, ..Default::default()
        });
        let four = train_lsq(&d.data, d.dim, 4, 16, &LsqConfig {
            iters: 4, icm_sweeps: 2, ..Default::default()
        });
        assert!(four.code_mse(&d.data) <= one.code_mse(&d.data) * 1.02);
    }

    #[test]
    fn trained_model_has_icm_enabled_and_label() {
        let d = toy(300);
        let lsq = train_lsq(&d.data, d.dim, 3, 8, &LsqConfig::default());
        assert_eq!(lsq.label, "LSQ");
        assert!(lsq.icm_sweeps > 0);
        assert_eq!(lsq.code_bytes(), 4); // m + norm byte
    }
}
