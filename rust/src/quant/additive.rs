//! Additive (non-orthogonal) quantization machinery, shared by RVQ and LSQ.
//!
//! A vector is approximated by a *sum* of M full-dimensional codewords
//! (paper §2, "Non-orthogonal quantizations").  The ADC identity
//!
//! `‖q − x̂‖² = ‖q‖² − 2Σ_m⟨q, c_m⟩ + ‖x̂‖²`
//!
//! is LUT-decomposable except for `‖x̂‖²`, which additive methods quantize
//! into one extra byte (a 256-level scalar codebook) — the standard
//! budget split used by AQ/LSQ: an 8-byte code = 7 codebooks + 1 norm
//! byte.  The norm byte is modeled here as an (M+1)-th LUT row, so the
//! index scan stays one uniform `Σ tables[j][code[j]]` loop.
//!
//! Encoding strategies:
//! * **greedy residual** (= RVQ): codebook m quantizes the residual after
//!   m−1 levels.
//! * **ICM refinement** (used by LSQ): iterated conditional modes over the
//!   code tuple with a precomputed codeword Gram matrix.

use crate::kmeans::{kmeans, KMeansConfig};
use crate::linalg::{axpy, dot, sq_l2};
use crate::store::Store;
use crate::Result;

use super::{Lut, Quantizer};

/// Additive codebook model: `m` codebooks × `k` codewords × `dim`.
pub struct Additive {
    pub dim: usize,
    pub m: usize,
    pub k: usize,
    /// `(m, k, dim)` flat codewords.
    pub codebooks: Vec<f32>,
    /// 256-level scalar codebook for `‖x̂‖²`.
    pub norm_levels: Vec<f32>,
    /// Gram matrix `⟨c_{m,k}, c_{m',k'}⟩`, `(m·k)²`, for ICM.
    pub gram: Vec<f32>,
    /// ICM sweeps at encode time (0 = pure greedy/RVQ).
    pub icm_sweeps: usize,
    /// Display name ("RVQ" or "LSQ").
    pub label: String,
}

impl Additive {
    #[inline]
    pub fn codeword(&self, j: usize, c: usize) -> &[f32] {
        let base = (j * self.k + c) * self.dim;
        &self.codebooks[base..base + self.dim]
    }

    /// Train as a residual vector quantizer (greedy levels). This is both
    /// the RVQ baseline and the LSQ initialization.
    pub fn train_rvq(data: &[f32], dim: usize, m: usize, k: usize,
                     seed: u64, kmeans_iters: usize, label: &str) -> Additive {
        let n = data.len() / dim;
        let mut residual = data.to_vec();
        let mut codebooks = vec![0.0f32; m * k * dim];
        for j in 0..m {
            let km = kmeans(&residual, dim, &KMeansConfig {
                k,
                iters: kmeans_iters,
                seed: seed.wrapping_add(j as u64 * 7919),
            });
            codebooks[j * k * dim..(j + 1) * k * dim]
                .copy_from_slice(&km.centroids);
            // subtract assigned centroids to form the next-level residual
            for i in 0..n {
                let a = km.assignments[i] as usize;
                let c = &km.centroids[a * dim..(a + 1) * dim];
                let r = &mut residual[i * dim..(i + 1) * dim];
                for (rv, cv) in r.iter_mut().zip(c) {
                    *rv -= cv;
                }
            }
        }
        let mut q = Additive {
            dim, m, k, codebooks,
            norm_levels: vec![0.0; 256],
            gram: Vec::new(),
            icm_sweeps: 0,
            label: label.to_string(),
        };
        q.rebuild_gram();
        q.fit_norm_levels(data);
        q
    }

    /// Recompute the codeword Gram matrix (after any codebook update).
    pub fn rebuild_gram(&mut self) {
        let mk = self.m * self.k;
        let mut gram = vec![0.0f32; mk * mk];
        for a in 0..mk {
            let ca = &self.codebooks[a * self.dim..(a + 1) * self.dim];
            for b in a..mk {
                let cb = &self.codebooks[b * self.dim..(b + 1) * self.dim];
                let g = dot(ca, cb);
                gram[a * mk + b] = g;
                gram[b * mk + a] = g;
            }
        }
        self.gram = gram;
    }

    /// Fit the 256-level scalar quantizer of reconstruction norms on a
    /// training sample (1-d k-means via sorted Lloyd).
    pub fn fit_norm_levels(&mut self, data: &[f32]) {
        let n = data.len() / self.dim;
        let sample = n.min(8192);
        let mut norms = Vec::with_capacity(sample);
        let mut codes = vec![0u8; self.m];
        let mut rec = vec![0.0f32; self.dim];
        let step = (n / sample).max(1);
        for i in (0..n).step_by(step).take(sample) {
            self.encode_codes(&data[i * self.dim..(i + 1) * self.dim],
                              &mut codes);
            self.sum_codewords(&codes, &mut rec);
            norms.push(dot(&rec, &rec));
        }
        norms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // quantile-spaced levels then 5 Lloyd sweeps in 1-d
        let mut levels: Vec<f32> = (0..256)
            .map(|i| {
                let idx = (i * norms.len()) / 256 + norms.len() / 512;
                norms[idx.min(norms.len() - 1)]
            })
            .collect();
        for _ in 0..5 {
            let mut sums = vec![0.0f64; 256];
            let mut counts = vec![0u32; 256];
            for &v in &norms {
                let j = nearest_level(&levels, v);
                sums[j] += v as f64;
                counts[j] += 1;
            }
            for j in 0..256 {
                if counts[j] > 0 {
                    levels[j] = (sums[j] / counts[j] as f64) as f32;
                }
            }
        }
        levels.sort_by(|a, b| a.partial_cmp(b).unwrap());
        self.norm_levels = levels;
    }

    /// Greedy residual encoding (RVQ path / ICM warm start): the code
    /// bytes only — the norm byte is appended by `encode_one`.
    pub fn encode_codes(&self, x: &[f32], codes: &mut [u8]) {
        debug_assert_eq!(codes.len(), self.m);
        let mut residual = x.to_vec();
        for j in 0..self.m {
            let mut best = (0usize, f32::INFINITY);
            for c in 0..self.k {
                let d = sq_l2(&residual, self.codeword(j, c));
                if d < best.1 {
                    best = (c, d);
                }
            }
            codes[j] = best.0 as u8;
            let cw = self.codeword(j, best.0);
            for (rv, cv) in residual.iter_mut().zip(cw) {
                *rv -= cv;
            }
        }
        if self.icm_sweeps > 0 {
            self.icm_refine(x, codes);
        }
    }

    /// Iterated conditional modes: cyclically re-pick each codeword with
    /// the others fixed, using precomputed ⟨x,c⟩ and the codeword Gram.
    ///
    /// Objective per (j, c):  −2⟨x,c⟩ + ‖c‖² + 2 Σ_{l≠j} ⟨c, c_l⟩.
    fn icm_refine(&self, x: &[f32], codes: &mut [u8]) {
        let mk = self.m * self.k;
        // xdots[j*k + c] = ⟨x, c_{j,c}⟩
        let mut xdots = vec![0.0f32; mk];
        for a in 0..mk {
            xdots[a] = dot(x, &self.codebooks[a * self.dim..(a + 1) * self.dim]);
        }
        for _sweep in 0..self.icm_sweeps {
            let mut changed = false;
            for j in 0..self.m {
                let mut best = (codes[j] as usize, f32::INFINITY);
                for c in 0..self.k {
                    let a = j * self.k + c;
                    let mut cost = -2.0 * xdots[a] + self.gram[a * mk + a];
                    for l in 0..self.m {
                        if l != j {
                            let b = l * self.k + codes[l] as usize;
                            cost += 2.0 * self.gram[a * mk + b];
                        }
                    }
                    if cost < best.1 {
                        best = (c, cost);
                    }
                }
                if best.0 != codes[j] as usize {
                    codes[j] = best.0 as u8;
                    changed = true;
                }
            }
            if !changed {
                break;
            }
        }
    }

    /// `out = Σ_j c_{j, codes[j]}`.
    pub fn sum_codewords(&self, codes: &[u8], out: &mut [f32]) {
        out.iter_mut().for_each(|v| *v = 0.0);
        for j in 0..self.m {
            axpy(1.0, self.codeword(j, codes[j] as usize), out);
        }
    }

    /// Reconstruction error of the *code* part (ignores the norm byte).
    pub fn code_mse(&self, data: &[f32]) -> f32 {
        let n = data.len() / self.dim;
        let mut codes = vec![0u8; self.m];
        let mut rec = vec![0.0f32; self.dim];
        let mut sse = 0.0f64;
        for i in 0..n {
            let x = &data[i * self.dim..(i + 1) * self.dim];
            self.encode_codes(x, &mut codes);
            self.sum_codewords(&codes, &mut rec);
            sse += sq_l2(x, &rec) as f64;
        }
        (sse / n.max(1) as f64) as f32
    }

    pub fn save(&self, store: &mut Store, prefix: &str) {
        store.put_f32(&format!("{prefix}codebooks"),
                      &[self.m, self.k, self.dim], self.codebooks.clone());
        store.put_f32(&format!("{prefix}norm_levels"), &[256],
                      self.norm_levels.clone());
        store.put_meta(&format!("{prefix}additive"),
                       &format!("{},{},{},{},{}", self.dim, self.m, self.k,
                                self.icm_sweeps, self.label));
    }

    pub fn load(store: &Store, prefix: &str) -> Result<Additive> {
        let meta = store.get_meta(&format!("{prefix}additive"))
            .ok_or_else(|| anyhow::anyhow!("missing additive meta"))?;
        let parts: Vec<&str> = meta.split(',').collect();
        let (dim, m, k, icm): (usize, usize, usize, usize) = (
            parts[0].parse()?, parts[1].parse()?, parts[2].parse()?,
            parts[3].parse()?,
        );
        let label = parts.get(4).unwrap_or(&"RVQ").to_string();
        let (_, cb) = store.get_f32(&format!("{prefix}codebooks"))
            .ok_or_else(|| anyhow::anyhow!("missing additive codebooks"))?;
        let (_, nl) = store.get_f32(&format!("{prefix}norm_levels"))
            .ok_or_else(|| anyhow::anyhow!("missing norm levels"))?;
        let mut q = Additive {
            dim, m, k,
            codebooks: cb.to_vec(),
            norm_levels: nl.to_vec(),
            gram: Vec::new(),
            icm_sweeps: icm,
            label,
        };
        q.rebuild_gram();
        Ok(q)
    }
}

#[inline]
fn nearest_level(levels: &[f32], v: f32) -> usize {
    // levels are sorted: binary search then compare neighbors
    let idx = levels.partition_point(|&l| l < v);
    let mut best = (idx.min(levels.len() - 1), f32::INFINITY);
    for j in idx.saturating_sub(1)..=(idx.min(levels.len() - 1)) {
        let d = (levels[j] - v).abs();
        if d < best.1 {
            best = (j, d);
        }
    }
    best.0
}

impl Quantizer for Additive {
    fn name(&self) -> String {
        self.label.clone()
    }

    /// m codebook bytes + 1 norm byte (the AQ/LSQ budget convention).
    fn code_bytes(&self) -> usize {
        self.m + 1
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn encode_one(&self, x: &[f32], out: &mut [u8]) {
        let (codes, norm_slot) = out.split_at_mut(self.m);
        self.encode_codes(x, codes);
        let mut rec = vec![0.0f32; self.dim];
        self.sum_codewords(codes, &mut rec);
        norm_slot[0] = nearest_level(&self.norm_levels, dot(&rec, &rec)) as u8;
    }

    fn lut(&self, q: &[f32]) -> Lut {
        // rows 0..m: −2⟨q, c⟩ ; row m: quantized ‖x̂‖² levels.
        let rows = self.m + 1;
        let mut tables = vec![0.0f32; rows * self.k.max(256)];
        let k = self.k.max(256);
        for j in 0..self.m {
            for c in 0..self.k {
                tables[j * k + c] = -2.0 * dot(q, self.codeword(j, c));
            }
        }
        tables[self.m * k..self.m * k + 256]
            .copy_from_slice(&self.norm_levels);
        Lut::Tables { m: rows, k, tables, bias: dot(q, q) }
    }

    fn reconstruct(&self, code: &[u8], out: &mut [f32]) -> bool {
        self.sum_codewords(&code[..self.m], out);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic::Generator, Family};

    fn toy() -> crate::data::Dataset {
        Generator::new(Family::SiftLike, 3).generate(0, 600)
    }

    #[test]
    fn rvq_residual_mse_decreases_per_level() {
        let d = toy();
        let a1 = Additive::train_rvq(&d.data, d.dim, 1, 32, 0, 6, "RVQ");
        let a4 = Additive::train_rvq(&d.data, d.dim, 4, 32, 0, 6, "RVQ");
        assert!(a4.code_mse(&d.data) < a1.code_mse(&d.data));
    }

    #[test]
    fn icm_never_hurts_reconstruction() {
        let d = toy();
        let mut a = Additive::train_rvq(&d.data, d.dim, 4, 16, 0, 6, "t");
        let greedy = a.code_mse(&d.data);
        a.icm_sweeps = 3;
        let icm = a.code_mse(&d.data);
        assert!(icm <= greedy + 1e-3, "icm {icm} vs greedy {greedy}");
    }

    #[test]
    fn adc_score_approximates_distance() {
        let d = toy();
        let a = Additive::train_rvq(&d.data, d.dim, 7, 32, 0, 6, "t");
        let q = d.row(2);
        let lut = a.lut(q);
        let cb = a.code_bytes();
        let mut code = vec![0u8; cb];
        let mut rec = vec![0.0f32; d.dim];
        // ADC error should be dominated by the norm-byte quantization:
        // relative error well under 5%.
        for i in 10..30 {
            a.encode_one(d.row(i), &mut code);
            a.reconstruct(&code, &mut rec);
            let exact = sq_l2(q, &rec);
            let adc = lut.score(&code);
            assert!((exact - adc).abs() < 0.05 * exact.max(1.0),
                    "row {i}: exact {exact} adc {adc}");
        }
    }

    #[test]
    fn norm_levels_sorted_and_finite() {
        let d = toy();
        let a = Additive::train_rvq(&d.data, d.dim, 4, 16, 0, 5, "t");
        for w in a.norm_levels.windows(2) {
            assert!(w[0] <= w[1]);
            assert!(w[0].is_finite());
        }
    }

    #[test]
    fn gram_is_symmetric_dot_table() {
        let d = toy();
        let a = Additive::train_rvq(&d.data, d.dim, 2, 8, 0, 4, "t");
        let mk = a.m * a.k;
        for x in 0..mk {
            for y in 0..mk {
                assert_eq!(a.gram[x * mk + y], a.gram[y * mk + x]);
            }
        }
        let g01 = dot(a.codeword(0, 1), a.codeword(1, 3));
        assert!((a.gram[(0 * 8 + 1) * mk + (8 + 3)] - g01).abs() < 1e-4);
    }

    #[test]
    fn save_load_roundtrip() {
        let d = toy();
        let mut a = Additive::train_rvq(&d.data, d.dim, 3, 8, 0, 4, "LSQ");
        a.icm_sweeps = 2;
        let mut s = Store::new();
        a.save(&mut s, "x_");
        let dir = crate::util::TempDir::new("add").unwrap();
        let p = dir.path().join("a.store");
        s.save(&p).unwrap();
        let back = Additive::load(&Store::load(&p).unwrap(), "x_").unwrap();
        assert_eq!(back.icm_sweeps, 2);
        assert_eq!(back.label, "LSQ");
        let mut c1 = vec![0u8; a.code_bytes()];
        let mut c2 = vec![0u8; a.code_bytes()];
        a.encode_one(d.row(5), &mut c1);
        back.encode_one(d.row(5), &mut c2);
        assert_eq!(c1, c2);
    }
}
