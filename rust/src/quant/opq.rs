//! Optimized Product Quantization (Ge et al., CVPR 2013; Norouzi & Fleet's
//! Cartesian k-means) — non-parametric variant.
//!
//! Alternates between (a) training a PQ on the rotated data `X R` and
//! (b) updating the rotation by orthogonal Procrustes against the PQ
//! reconstructions: `R ← procrustes(Xᵀ X̂)`.  Because `R` is orthogonal,
//! distances in the rotated space equal distances in the original space,
//! so the ADC scan remains exact and reconstructions can be rotated back.

use crate::linalg::{procrustes, Mat};
use crate::store::Store;
use crate::Result;

use super::pq::Pq;
use super::{Lut, Quantizer};

pub struct Opq {
    pub pq: Pq,
    /// `dim × dim` rotation, row-major. Applied as `x_rot = R · x`
    /// (i.e. matvec with rows).
    pub rotation: Mat,
    /// number of alternations used in training (kept for reporting)
    pub iters: usize,
}

impl Opq {
    pub fn train(data: &[f32], dim: usize, m: usize, k: usize, seed: u64,
                 opq_iters: usize, kmeans_iters: usize) -> Opq {
        let n = data.len() / dim;
        let mut rotation = Mat::eye(dim);
        let mut rotated = data.to_vec();
        let mut pq = Pq::train(&rotated, dim, m, k, seed, kmeans_iters);

        let mut code = vec![0u8; m];
        let mut rec = vec![0.0f32; dim];
        for _it in 0..opq_iters {
            // X̂ = reconstructions in the rotated space
            // C = X_origᵀ · X̂   (dim × dim)
            let mut c = Mat::zeros(dim, dim);
            for i in 0..n {
                pq.encode_one(&rotated[i * dim..(i + 1) * dim], &mut code);
                pq.reconstruct(&code, &mut rec);
                let orig = &data[i * dim..(i + 1) * dim];
                for r in 0..dim {
                    let o = orig[r];
                    if o != 0.0 {
                        let row = c.row_mut(r);
                        for (rv, xv) in row.iter_mut().zip(&rec) {
                            *rv += o * xv;
                        }
                    }
                }
            }
            // R minimizing ‖X R − X̂‖ : note our apply convention is
            // x_rot = R·x (rows), so store Rᵀ of the Procrustes solution.
            let r_proc = procrustes(&c);
            rotation = r_proc.transpose();
            // re-rotate the data and retrain PQ (warm iterations)
            for i in 0..n {
                let x = &data[i * dim..(i + 1) * dim];
                let xr = rotation.matvec(x);
                rotated[i * dim..(i + 1) * dim].copy_from_slice(&xr);
            }
            pq = Pq::train(&rotated, dim, m, k, seed, kmeans_iters);
        }
        Opq { pq, rotation, iters: opq_iters }
    }

    #[inline]
    fn rotate(&self, x: &[f32]) -> Vec<f32> {
        self.rotation.matvec(x)
    }

    pub fn save(&self, store: &mut Store, prefix: &str) {
        self.pq.save(store, &format!("{prefix}opq_"));
        store.put_f32(&format!("{prefix}rotation"),
                      &[self.rotation.rows, self.rotation.cols],
                      self.rotation.data.clone());
    }

    pub fn load(store: &Store, prefix: &str) -> Result<Opq> {
        let pq = Pq::load(store, &format!("{prefix}opq_"))?;
        let (shape, data) = store.get_f32(&format!("{prefix}rotation"))
            .ok_or_else(|| anyhow::anyhow!("missing opq rotation"))?;
        let rotation = Mat::from_rows(shape[0], shape[1], data.to_vec());
        Ok(Opq { pq, rotation, iters: 0 })
    }
}

impl Quantizer for Opq {
    fn name(&self) -> String {
        "OPQ".into()
    }

    fn code_bytes(&self) -> usize {
        self.pq.m
    }

    fn dim(&self) -> usize {
        self.pq.dim
    }

    fn encode_one(&self, x: &[f32], out: &mut [u8]) {
        let xr = self.rotate(x);
        self.pq.encode_one(&xr, out);
    }

    fn lut(&self, q: &[f32]) -> Lut {
        // rotation is orthogonal ⇒ ‖Rq − Rx‖ = ‖q − x‖
        let qr = self.rotate(q);
        self.pq.lut(&qr)
    }

    fn reconstruct(&self, code: &[u8], out: &mut [f32]) -> bool {
        let mut rec_rot = vec![0.0f32; self.pq.dim];
        self.pq.reconstruct(code, &mut rec_rot);
        // rotate back: x = Rᵀ x_rot  (columns of R)
        let dim = self.pq.dim;
        for r in 0..dim {
            out[r] = 0.0;
        }
        for (i, &v) in rec_rot.iter().enumerate() {
            if v != 0.0 {
                let row = self.rotation.row(i);
                for r in 0..dim {
                    out[r] += row[r] * v;
                }
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic::Generator, Family};
    use crate::linalg::{dot, sq_l2};
    use crate::quant::reconstruction_mse;

    fn toy(family: Family, n: usize) -> crate::data::Dataset {
        Generator::new(family, 2).generate(0, n)
    }

    #[test]
    fn rotation_is_orthogonal() {
        let d = toy(Family::DeepLike, 400);
        let opq = Opq::train(&d.data, d.dim, 8, 16, 0, 3, 5);
        let r = &opq.rotation;
        for i in 0..4 {
            for j in 0..4 {
                let v = dot(r.row(i), r.row(j));
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((v - want).abs() < 1e-3, "({i},{j}) = {v}");
            }
        }
    }

    #[test]
    fn opq_not_worse_than_pq_on_correlated_data() {
        // deep-like data has strongly coupled coordinates: rotation helps
        let d = toy(Family::DeepLike, 1500);
        let pq = super::super::pq::Pq::train(&d.data, d.dim, 8, 32, 0, 8);
        let opq = Opq::train(&d.data, d.dim, 8, 32, 0, 4, 8);
        let mse_pq = reconstruction_mse(&pq, &d);
        let mse_opq = reconstruction_mse(&opq, &d);
        assert!(mse_opq <= mse_pq * 1.02,
                "OPQ {mse_opq} should beat PQ {mse_pq}");
    }

    #[test]
    fn adc_matches_reconstruction_distance() {
        let d = toy(Family::DeepLike, 500);
        let opq = Opq::train(&d.data, d.dim, 8, 16, 0, 2, 5);
        let q = d.row(7);
        let lut = opq.lut(q);
        let mut code = vec![0u8; 8];
        let mut rec = vec![0.0f32; d.dim];
        for i in 0..20 {
            opq.encode_one(d.row(i), &mut code);
            opq.reconstruct(&code, &mut rec);
            let exact = sq_l2(q, &rec);
            let adc = lut.score(&code);
            assert!((exact - adc).abs() < 1e-2 * exact.max(0.1),
                    "{exact} vs {adc}");
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let d = toy(Family::DeepLike, 300);
        let opq = Opq::train(&d.data, d.dim, 4, 8, 0, 2, 4);
        let mut s = Store::new();
        opq.save(&mut s, "");
        let dir = crate::util::TempDir::new("opq").unwrap();
        let p = dir.path().join("opq.store");
        s.save(&p).unwrap();
        let back = Opq::load(&Store::load(&p).unwrap(), "").unwrap();
        let mut c1 = vec![0u8; 4];
        let mut c2 = vec![0u8; 4];
        opq.encode_one(d.row(0), &mut c1);
        back.encode_one(d.row(0), &mut c2);
        assert_eq!(c1, c2);
    }
}
