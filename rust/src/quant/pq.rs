//! Product Quantization (Jégou et al., TPAMI 2011) — the MCQ ancestor.
//!
//! Splits R^D into M orthogonal subspaces of D/M dims, runs k-means in
//! each, and encodes a vector as the tuple of per-subspace centroid ids.
//! The ADC lookup table holds exact per-subspace squared distances, so the
//! scanned score equals `‖q − x̂‖²` exactly (eq. 1 of the paper).

use crate::kmeans::{kmeans, nearest, KMeansConfig};
use crate::linalg::sq_l2;
use crate::store::Store;
use crate::Result;

use super::{Lut, Quantizer};

/// A trained product quantizer.
pub struct Pq {
    pub dim: usize,
    pub m: usize,
    pub k: usize,
    /// dsub = dim / m
    pub dsub: usize,
    /// `(m, k, dsub)` flat centroids.
    pub centroids: Vec<f32>,
}

impl Pq {
    /// Train on `data` (flat rows of `dim`).
    pub fn train(data: &[f32], dim: usize, m: usize, k: usize, seed: u64,
                 kmeans_iters: usize) -> Pq {
        assert!(dim % m == 0, "PQ requires dim % m == 0 ({dim} % {m})");
        assert!(k <= 256, "codes are single bytes");
        let dsub = dim / m;
        let n = data.len() / dim;
        let mut centroids = vec![0.0f32; m * k * dsub];
        let mut sub = vec![0.0f32; n * dsub];
        for j in 0..m {
            // gather the j-th subvector of every row
            for i in 0..n {
                sub[i * dsub..(i + 1) * dsub].copy_from_slice(
                    &data[i * dim + j * dsub..i * dim + (j + 1) * dsub]);
            }
            let km = kmeans(&sub, dsub, &KMeansConfig {
                k,
                iters: kmeans_iters,
                seed: seed.wrapping_add(j as u64),
            });
            centroids[j * k * dsub..(j + 1) * k * dsub]
                .copy_from_slice(&km.centroids);
        }
        Pq { dim, m, k, dsub, centroids }
    }

    #[inline]
    fn sub_centroids(&self, j: usize) -> &[f32] {
        &self.centroids[j * self.k * self.dsub..(j + 1) * self.k * self.dsub]
    }

    #[inline]
    pub fn centroid(&self, j: usize, c: usize) -> &[f32] {
        let base = (j * self.k + c) * self.dsub;
        &self.centroids[base..base + self.dsub]
    }

    pub fn save(&self, store: &mut Store, prefix: &str) {
        store.put_f32(&format!("{prefix}centroids"),
                      &[self.m, self.k, self.dsub], self.centroids.clone());
        store.put_meta(&format!("{prefix}pq"),
                       &format!("{},{},{}", self.dim, self.m, self.k));
    }

    pub fn load(store: &Store, prefix: &str) -> Result<Pq> {
        let meta = store.get_meta(&format!("{prefix}pq"))
            .ok_or_else(|| anyhow::anyhow!("missing pq meta {prefix:?}"))?;
        let parts: Vec<usize> = meta.split(',')
            .map(|p| p.parse().unwrap_or(0)).collect();
        let (dim, m, k) = (parts[0], parts[1], parts[2]);
        let (_, data) = store.get_f32(&format!("{prefix}centroids"))
            .ok_or_else(|| anyhow::anyhow!("missing pq centroids"))?;
        Ok(Pq { dim, m, k, dsub: dim / m, centroids: data.to_vec() })
    }
}

impl Quantizer for Pq {
    fn name(&self) -> String {
        "PQ".into()
    }

    fn code_bytes(&self) -> usize {
        self.m
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn encode_one(&self, x: &[f32], out: &mut [u8]) {
        debug_assert_eq!(x.len(), self.dim);
        for j in 0..self.m {
            let xs = &x[j * self.dsub..(j + 1) * self.dsub];
            let (id, _) = nearest(xs, self.sub_centroids(j), self.dsub);
            out[j] = id as u8;
        }
    }

    fn lut(&self, q: &[f32]) -> Lut {
        let mut tables = vec![0.0f32; self.m * self.k];
        for j in 0..self.m {
            let qs = &q[j * self.dsub..(j + 1) * self.dsub];
            for c in 0..self.k {
                tables[j * self.k + c] = sq_l2(qs, self.centroid(j, c));
            }
        }
        Lut::Tables { m: self.m, k: self.k, tables, bias: 0.0 }
    }

    fn reconstruct(&self, code: &[u8], out: &mut [f32]) -> bool {
        for j in 0..self.m {
            out[j * self.dsub..(j + 1) * self.dsub]
                .copy_from_slice(self.centroid(j, code[j] as usize));
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic::Generator, Family};
    use crate::quant::reconstruction_mse;
    use crate::util::{prop, rng::SplitMix64, TempDir};

    fn toy_data() -> crate::data::Dataset {
        Generator::new(Family::SiftLike, 1).generate(0, 800)
    }

    #[test]
    fn adc_equals_exact_distance_to_reconstruction() {
        let d = toy_data();
        let pq = Pq::train(&d.data, d.dim, 8, 16, 0, 8);
        let mut code = vec![0u8; 8];
        let mut rec = vec![0.0f32; d.dim];
        let q = d.row(5);
        let lut = pq.lut(q);
        for i in 0..20 {
            pq.encode_one(d.row(i), &mut code);
            pq.reconstruct(&code, &mut rec);
            let exact = sq_l2(q, &rec);
            let adc = lut.score(&code);
            assert!((exact - adc).abs() < 1e-2 * exact.max(1.0),
                    "row {i}: {exact} vs {adc}");
        }
    }

    #[test]
    fn encode_is_nearest_subcentroid() {
        let d = toy_data();
        let pq = Pq::train(&d.data, d.dim, 4, 8, 0, 6);
        let mut code = vec![0u8; 4];
        pq.encode_one(d.row(0), &mut code);
        for j in 0..4 {
            let xs = &d.row(0)[j * pq.dsub..(j + 1) * pq.dsub];
            let chosen = sq_l2(xs, pq.centroid(j, code[j] as usize));
            for c in 0..8 {
                assert!(chosen <= sq_l2(xs, pq.centroid(j, c)) + 1e-5);
            }
        }
    }

    #[test]
    fn more_codebooks_reduce_mse() {
        let d = toy_data();
        let pq4 = Pq::train(&d.data, d.dim, 4, 32, 0, 8);
        let pq16 = Pq::train(&d.data, d.dim, 16, 32, 0, 8);
        let mse4 = reconstruction_mse(&pq4, &d);
        let mse16 = reconstruction_mse(&pq16, &d);
        assert!(mse16 < mse4, "{mse16} !< {mse4}");
    }

    #[test]
    fn save_load_roundtrip() {
        let d = toy_data();
        let pq = Pq::train(&d.data, d.dim, 8, 16, 0, 5);
        let dir = TempDir::new("pq").unwrap();
        let p = dir.path().join("pq.store");
        let mut s = Store::new();
        pq.save(&mut s, "");
        s.save(&p).unwrap();
        let back = Pq::load(&Store::load(&p).unwrap(), "").unwrap();
        assert_eq!(back.centroids, pq.centroids);
        assert_eq!(back.m, pq.m);
        let mut c1 = vec![0u8; 8];
        let mut c2 = vec![0u8; 8];
        pq.encode_one(d.row(3), &mut c1);
        back.encode_one(d.row(3), &mut c2);
        assert_eq!(c1, c2);
    }

    #[test]
    fn prop_adc_consistency_random_vectors() {
        // property: for random q and random codes, LUT score ==
        // ‖q − reconstruct(code)‖² within float tolerance
        let d = toy_data();
        let pq = Pq::train(&d.data, d.dim, 8, 16, 0, 4);
        prop::forall_ok(
            42,
            30,
            |r: &mut SplitMix64| {
                let q = prop::vec_f32(r, 128, 100.0);
                let code: Vec<u8> =
                    (0..8).map(|_| r.below(16) as u8).collect();
                (q, code)
            },
            |(q, code)| {
                let lut = pq.lut(q);
                let mut rec = vec![0.0f32; 128];
                pq.reconstruct(code, &mut rec);
                let exact = sq_l2(q, &rec);
                let adc = lut.score(code);
                if (exact - adc).abs() <= 1e-2 * exact.max(1.0) {
                    Ok(())
                } else {
                    Err(format!("{exact} vs {adc}"))
                }
            },
        );
    }
}
