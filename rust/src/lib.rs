//! # unq — Unsupervised Neural Quantization, as a full retrieval system
//!
//! A production-shaped reproduction of *"Unsupervised Neural Quantization
//! for Compressed-Domain Similarity Search"* (Morozov & Babenko, ICCV'19):
//! a three-layer Rust + JAX + Pallas stack in which
//!
//! * **L1/L2 (build time)** — the UNQ model is trained in JAX and its
//!   `encode` / `query_lut` / `decode` graphs (built on Pallas kernels) are
//!   AOT-lowered to HLO text under `artifacts/`;
//! * **L3 (this crate)** — owns everything at run time: synthetic dataset
//!   substrates, every shallow baseline of the paper's evaluation (PQ, OPQ,
//!   RVQ, LSQ, Catalyst-style spherical lattice), the compressed index with
//!   its ADC-scan hot path, the two-stage (scan → rerank) search pipeline,
//!   the PJRT runtime that executes the AOT artifacts, and an async serving
//!   coordinator with dynamic batching and backpressure.
//!
//! Python never runs on the request path; after `make artifacts` the Rust
//! binary is self-contained.
//!
//! ## Crate map
//!
//! | module | role |
//! |---|---|
//! | [`config`] | typed experiment/serving configuration |
//! | [`linalg`] | dense math: distances, matmul, Jacobi eigen/SVD, top-k |
//! | [`data`] | synthetic deep-like / sift-like generators, *vecs I/O |
//! | [`kmeans`] | Lloyd + k-means++ (shared by all shallow quantizers) |
//! | [`gt`] | exact brute-force ground truth (cached) |
//! | [`nn`] | hand-rolled reverse-mode layers + Adam (native UNQ training) |
//! | [`quant`] | `Quantizer` trait + PQ/OPQ/RVQ/LSQ/lattice/UNQ (AOT + native) |
//! | [`index`] | compressed storage, ADC LUT scan, rerank, two-stage search; mutable streaming segments ([`index::segment`]) |
//! | [`ivf`] | coarse-partitioned inverted lists: sub-linear nprobe search |
//! | [`exec`] | batch executor: worker pool + generic scan-task plans |
//! | [`runtime`] | PJRT engine: load + execute the AOT HLO artifacts |
//! | [`coordinator`] | async serving: router, batcher, pipeline, metrics |
//! | [`net`] | TCP front door: wire protocol, reactor, admission control, load generator |
//! | [`obs`] | observability: metrics registry, span tracing, EXPLAIN |
//! | [`eval`] | Recall@k harness + paper-table formatting |
//! | [`store`] | tiny binary tensor store for trained baseline models; write-ahead log ([`store::wal`]) |
//! | [`util`] | offline substrates: JSON, PRNG, bench harness, prop tests |

// Style allowances for the CI clippy gate (-D warnings): indexed loops
// over flat row-major buffers with explicit strides are the idiom
// throughout the numeric kernels, and trainers take the paper's full
// hyperparameter tuple.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments,
         clippy::manual_memcpy)]

pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod exec;
pub mod gt;
pub mod index;
pub mod ivf;
pub mod kmeans;
pub mod linalg;
pub mod net;
pub mod nn;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod store;
pub mod util;

/// Crate-wide result type (anyhow for rich context in binaries).
pub type Result<T> = anyhow::Result<T>;
