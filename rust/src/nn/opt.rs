//! Adam (Kingma & Ba, 2015) over flat parameter tensors.
//!
//! The optimizer is slot-addressed rather than tape-addressed: every
//! parameter tensor of the model is assigned a stable integer slot, and
//! each training step calls [`Adam::update`] once per (slot, param, grad)
//! triple.  First/second-moment state is allocated lazily on the first
//! update of a slot, so the same optimizer serves models of any shape
//! without up-front registration.

/// Adam state for one training run.
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// step counter for bias correction (bump via [`Adam::begin_step`])
    t: u64,
    /// per-slot (first moment, second moment)
    slots: Vec<(Vec<f32>, Vec<f32>)>,
}

impl Adam {
    pub fn new(lr: f32) -> Adam {
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, t: 0,
               slots: Vec::new() }
    }

    /// Advance the bias-correction step counter; call once per minibatch
    /// BEFORE the per-tensor [`Adam::update`] calls of that batch.
    pub fn begin_step(&mut self) {
        self.t += 1;
    }

    /// One Adam update of `param` from `grad` using slot-local moments.
    pub fn update(&mut self, slot: usize, param: &mut [f32], grad: &[f32]) {
        assert_eq!(param.len(), grad.len());
        assert!(self.t > 0, "call begin_step before update");
        if self.slots.len() <= slot {
            self.slots.resize_with(slot + 1, || (Vec::new(), Vec::new()));
        }
        let (m, v) = &mut self.slots[slot];
        if m.len() != param.len() {
            *m = vec![0.0; param.len()];
            *v = vec![0.0; param.len()];
        }
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..param.len() {
            let g = grad[i];
            m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * g;
            v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * g * g;
            let mhat = m[i] / bc1;
            let vhat = v[i] / bc2;
            param[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_descends_a_quadratic() {
        // minimize f(w) = Σ (w_i − target_i)²
        let target = [3.0f32, -2.0, 0.5];
        let mut w = vec![0.0f32; 3];
        let mut opt = Adam::new(0.05);
        for _ in 0..500 {
            let grad: Vec<f32> =
                w.iter().zip(&target).map(|(&wi, &t)| 2.0 * (wi - t)).collect();
            opt.begin_step();
            opt.update(0, &mut w, &grad);
        }
        for (wi, t) in w.iter().zip(&target) {
            assert!((wi - t).abs() < 0.05, "{wi} vs {t}");
        }
    }

    #[test]
    fn slots_keep_independent_state() {
        let mut opt = Adam::new(0.1);
        let mut a = vec![0.0f32];
        let mut b = vec![0.0f32];
        opt.begin_step();
        opt.update(0, &mut a, &[1.0]);
        opt.update(1, &mut b, &[-1.0]);
        // first step of Adam moves by ≈ lr regardless of gradient scale
        assert!(a[0] < 0.0 && b[0] > 0.0);
        assert!((a[0] + b[0]).abs() < 1e-6, "symmetric moves");
    }

    #[test]
    #[should_panic]
    fn update_before_begin_step_panics() {
        let mut opt = Adam::new(0.1);
        let mut w = vec![0.0f32];
        opt.update(0, &mut w, &[1.0]);
    }
}
