//! The layer zoo: Linear, BatchNorm-lite, ReLU, softmax-with-temperature.
//!
//! Every layer is a plain struct over flat row-major `f32` buffers with an
//! explicit `forward` / `backward` pair — no autograd tape, no graph; the
//! caller (e.g. [`super::Mlp`], `quant::unq_native`) owns the wiring and
//! threads the forward caches back into the backward pass by hand.
//! Gradients accumulate into the layer's own `g*` buffers (zeroed via
//! `zero_grad`) so one minibatch can sum contributions from several loss
//! terms before the optimizer step.  Each backward is finite-difference
//! checked in this module's tests.

use crate::store::Store;
use crate::util::rng::SplitMix64;
use crate::Result;

/// Weight initialization scheme for [`Linear::new`].
#[derive(Clone, Copy, Debug)]
pub enum Init {
    /// He/Kaiming: `w ~ N(0, 2/in_dim)` — the ReLU-era default.
    He,
    /// All zeros — used for the last layer of a residual correction
    /// branch so the branch starts as the identity-preserving no-op.
    Zero,
    /// Partial identity: `w[o][i] = [o == i]` on the leading square block
    /// (exact identity when `in_dim == out_dim`) — used for skip paths so
    /// a freshly initialized network starts as a (projection of the)
    /// identity map.
    Identity,
}

/// Fully connected layer `y = x Wᵀ + b` over a flat `n × in_dim` batch.
pub struct Linear {
    pub in_dim: usize,
    pub out_dim: usize,
    /// `out_dim × in_dim`, row-major (`w[o * in_dim + i]`).
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    /// accumulated gradients (same layouts as `w` / `b`)
    pub gw: Vec<f32>,
    pub gb: Vec<f32>,
}

impl Linear {
    pub fn new(in_dim: usize, out_dim: usize, init: Init,
               rng: &mut SplitMix64) -> Linear {
        let mut w = vec![0.0f32; out_dim * in_dim];
        match init {
            Init::He => {
                let scale = (2.0 / in_dim as f32).sqrt();
                for v in w.iter_mut() {
                    *v = rng.normal() * scale;
                }
            }
            Init::Zero => {}
            Init::Identity => {
                for o in 0..out_dim.min(in_dim) {
                    w[o * in_dim + o] = 1.0;
                }
            }
        }
        Linear {
            in_dim,
            out_dim,
            w,
            b: vec![0.0; out_dim],
            gw: vec![0.0; out_dim * in_dim],
            gb: vec![0.0; out_dim],
        }
    }

    /// `y[n × out_dim] = x Wᵀ + b`.
    pub fn forward(&self, x: &[f32], n: usize) -> Vec<f32> {
        debug_assert_eq!(x.len(), n * self.in_dim);
        let mut y = vec![0.0f32; n * self.out_dim];
        for r in 0..n {
            let xr = &x[r * self.in_dim..(r + 1) * self.in_dim];
            let yr = &mut y[r * self.out_dim..(r + 1) * self.out_dim];
            for (o, yv) in yr.iter_mut().enumerate() {
                let wrow = &self.w[o * self.in_dim..(o + 1) * self.in_dim];
                *yv = self.b[o] + crate::linalg::dot(xr, wrow);
            }
        }
        y
    }

    /// Accumulate `gw += dyᵀ x`, `gb += Σ dy`, return `dx = dy W`.
    pub fn backward(&mut self, x: &[f32], dy: &[f32], n: usize) -> Vec<f32> {
        debug_assert_eq!(x.len(), n * self.in_dim);
        debug_assert_eq!(dy.len(), n * self.out_dim);
        let mut dx = vec![0.0f32; n * self.in_dim];
        for r in 0..n {
            let xr = &x[r * self.in_dim..(r + 1) * self.in_dim];
            let dyr = &dy[r * self.out_dim..(r + 1) * self.out_dim];
            let dxr = &mut dx[r * self.in_dim..(r + 1) * self.in_dim];
            for (o, &g) in dyr.iter().enumerate() {
                if g == 0.0 {
                    continue;
                }
                self.gb[o] += g;
                let wrow = &self.w[o * self.in_dim..(o + 1) * self.in_dim];
                let gwrow =
                    &mut self.gw[o * self.in_dim..(o + 1) * self.in_dim];
                for i in 0..self.in_dim {
                    gwrow[i] += g * xr[i];
                    dxr[i] += g * wrow[i];
                }
            }
        }
        dx
    }

    pub fn zero_grad(&mut self) {
        self.gw.iter_mut().for_each(|v| *v = 0.0);
        self.gb.iter_mut().for_each(|v| *v = 0.0);
    }

    pub fn param_count(&self) -> usize {
        self.w.len() + self.b.len()
    }

    pub fn save(&self, store: &mut Store, name: &str) {
        store.put_f32(&format!("{name}_w"), &[self.out_dim, self.in_dim],
                      self.w.clone());
        store.put_f32(&format!("{name}_b"), &[self.out_dim], self.b.clone());
    }

    pub fn load(store: &Store, name: &str) -> Result<Linear> {
        let (shape, w) = store
            .get_f32(&format!("{name}_w"))
            .ok_or_else(|| anyhow::anyhow!("missing linear {name}_w"))?;
        let (_, b) = store
            .get_f32(&format!("{name}_b"))
            .ok_or_else(|| anyhow::anyhow!("missing linear {name}_b"))?;
        let (out_dim, in_dim) = (shape[0], shape[1]);
        Ok(Linear {
            in_dim,
            out_dim,
            w: w.to_vec(),
            b: b.to_vec(),
            gw: vec![0.0; out_dim * in_dim],
            gb: vec![0.0; out_dim],
        })
    }
}

/// Forward caches [`BatchNormLite::forward`] hands back for the backward
/// pass: the normalized activations and the inverse std actually used.
pub struct BnCache {
    pub xhat: Vec<f32>,
    pub inv_std: Vec<f32>,
}

/// Per-feature normalization with learnable scale/shift — the "lite" cut
/// of batch norm: the backward pass treats the normalization statistics
/// as constants (no Jacobian through the batch mean/var), which keeps the
/// layer finite-difference checkable in frozen-stats mode and is accurate
/// enough for the shallow stacks this crate trains.  In training mode the
/// batch statistics are used and folded into running EMAs; in eval mode
/// the running statistics apply (so inference is deterministic and
/// batch-size independent).
pub struct BatchNormLite {
    pub dim: usize,
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
    pub ggamma: Vec<f32>,
    pub gbeta: Vec<f32>,
    pub running_mean: Vec<f32>,
    pub running_var: Vec<f32>,
    pub momentum: f32,
    pub eps: f32,
}

impl BatchNormLite {
    pub fn new(dim: usize) -> BatchNormLite {
        BatchNormLite {
            dim,
            gamma: vec![1.0; dim],
            beta: vec![0.0; dim],
            ggamma: vec![0.0; dim],
            gbeta: vec![0.0; dim],
            running_mean: vec![0.0; dim],
            running_var: vec![1.0; dim],
            momentum: 0.1,
            eps: 1e-5,
        }
    }

    /// Normalize a flat `n × dim` batch.  With `update_stats` the batch
    /// mean/var normalize (and refresh the running EMAs); without it the
    /// running statistics normalize — the deterministic, fd-checkable
    /// mode (`infer` is the cache-free shorthand).
    pub fn forward(&mut self, x: &[f32], n: usize, update_stats: bool)
                   -> (Vec<f32>, BnCache) {
        debug_assert_eq!(x.len(), n * self.dim);
        let d = self.dim;
        let (mean, var) = if update_stats {
            let mut mean = vec![0.0f32; d];
            let mut var = vec![0.0f32; d];
            for r in 0..n {
                for (f, &v) in x[r * d..(r + 1) * d].iter().enumerate() {
                    mean[f] += v;
                }
            }
            let inv_n = 1.0 / n.max(1) as f32;
            mean.iter_mut().for_each(|v| *v *= inv_n);
            for r in 0..n {
                for (f, &v) in x[r * d..(r + 1) * d].iter().enumerate() {
                    let c = v - mean[f];
                    var[f] += c * c;
                }
            }
            var.iter_mut().for_each(|v| *v *= inv_n);
            for f in 0..d {
                self.running_mean[f] = (1.0 - self.momentum)
                    * self.running_mean[f]
                    + self.momentum * mean[f];
                self.running_var[f] = (1.0 - self.momentum)
                    * self.running_var[f]
                    + self.momentum * var[f];
            }
            (mean, var)
        } else {
            (self.running_mean.clone(), self.running_var.clone())
        };
        let inv_std: Vec<f32> =
            var.iter().map(|&v| 1.0 / (v + self.eps).sqrt()).collect();
        let mut xhat = vec![0.0f32; n * d];
        let mut y = vec![0.0f32; n * d];
        for r in 0..n {
            for f in 0..d {
                let h = (x[r * d + f] - mean[f]) * inv_std[f];
                xhat[r * d + f] = h;
                y[r * d + f] = self.gamma[f] * h + self.beta[f];
            }
        }
        (y, BnCache { xhat, inv_std })
    }

    /// Eval-mode forward without caches (running statistics, `&self`).
    pub fn infer(&self, x: &[f32], n: usize) -> Vec<f32> {
        debug_assert_eq!(x.len(), n * self.dim);
        let d = self.dim;
        let inv_std: Vec<f32> = self
            .running_var
            .iter()
            .map(|&v| 1.0 / (v + self.eps).sqrt())
            .collect();
        let mut y = vec![0.0f32; n * d];
        for r in 0..n {
            for f in 0..d {
                let h = (x[r * d + f] - self.running_mean[f]) * inv_std[f];
                y[r * d + f] = self.gamma[f] * h + self.beta[f];
            }
        }
        y
    }

    /// `dx = dy · γ · inv_std` (statistics treated as constants),
    /// accumulating `gγ += Σ dy ⊙ x̂`, `gβ += Σ dy`.
    pub fn backward(&mut self, cache: &BnCache, dy: &[f32], n: usize)
                    -> Vec<f32> {
        let d = self.dim;
        debug_assert_eq!(dy.len(), n * d);
        let mut dx = vec![0.0f32; n * d];
        for r in 0..n {
            for f in 0..d {
                let g = dy[r * d + f];
                self.ggamma[f] += g * cache.xhat[r * d + f];
                self.gbeta[f] += g;
                dx[r * d + f] = g * self.gamma[f] * cache.inv_std[f];
            }
        }
        dx
    }

    pub fn zero_grad(&mut self) {
        self.ggamma.iter_mut().for_each(|v| *v = 0.0);
        self.gbeta.iter_mut().for_each(|v| *v = 0.0);
    }

    pub fn param_count(&self) -> usize {
        self.gamma.len() + self.beta.len()
    }

    pub fn save(&self, store: &mut Store, name: &str) {
        store.put_f32(&format!("{name}_gamma"), &[self.dim],
                      self.gamma.clone());
        store.put_f32(&format!("{name}_beta"), &[self.dim],
                      self.beta.clone());
        store.put_f32(&format!("{name}_rmean"), &[self.dim],
                      self.running_mean.clone());
        store.put_f32(&format!("{name}_rvar"), &[self.dim],
                      self.running_var.clone());
    }

    pub fn load(store: &Store, name: &str) -> Result<BatchNormLite> {
        let get = |suffix: &str| -> Result<Vec<f32>> {
            store
                .get_f32(&format!("{name}_{suffix}"))
                .map(|(_, d)| d.to_vec())
                .ok_or_else(|| anyhow::anyhow!("missing bn {name}_{suffix}"))
        };
        let gamma = get("gamma")?;
        let dim = gamma.len();
        Ok(BatchNormLite {
            dim,
            gamma,
            beta: get("beta")?,
            ggamma: vec![0.0; dim],
            gbeta: vec![0.0; dim],
            running_mean: get("rmean")?,
            running_var: get("rvar")?,
            momentum: 0.1,
            eps: 1e-5,
        })
    }
}

/// Elementwise `max(0, x)`.
pub fn relu(x: &[f32]) -> Vec<f32> {
    x.iter().map(|&v| v.max(0.0)).collect()
}

/// ReLU backward from the cached *pre-activation*: `dx = dy ⊙ [x > 0]`.
pub fn relu_backward(x_pre: &[f32], dy: &[f32]) -> Vec<f32> {
    debug_assert_eq!(x_pre.len(), dy.len());
    x_pre
        .iter()
        .zip(dy)
        .map(|(&x, &g)| if x > 0.0 { g } else { 0.0 })
        .collect()
}

/// Row-wise softmax with temperature: `p = softmax(logits / τ)` over each
/// contiguous row of `k` entries (max-subtracted for stability).
pub fn softmax_t_rows(logits: &[f32], rows: usize, k: usize, tau: f32)
                      -> Vec<f32> {
    debug_assert_eq!(logits.len(), rows * k);
    debug_assert!(tau > 0.0);
    let mut p = vec![0.0f32; rows * k];
    for r in 0..rows {
        let lr = &logits[r * k..(r + 1) * k];
        let pr = &mut p[r * k..(r + 1) * k];
        let hi = lr.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for (pv, &l) in pr.iter_mut().zip(lr) {
            let e = ((l - hi) / tau).exp();
            *pv = e;
            z += e;
        }
        let inv = 1.0 / z.max(1e-30);
        pr.iter_mut().for_each(|v| *v *= inv);
    }
    p
}

/// Softmax-with-temperature backward: given `p` from
/// [`softmax_t_rows`] and upstream `dp`, returns
/// `dlogits_j = p_j (dp_j − Σ_i dp_i p_i) / τ`.
pub fn softmax_t_backward(p: &[f32], dp: &[f32], rows: usize, k: usize,
                          tau: f32) -> Vec<f32> {
    debug_assert_eq!(p.len(), rows * k);
    debug_assert_eq!(dp.len(), rows * k);
    let mut dl = vec![0.0f32; rows * k];
    let inv_tau = 1.0 / tau;
    for r in 0..rows {
        let pr = &p[r * k..(r + 1) * k];
        let dpr = &dp[r * k..(r + 1) * k];
        let dlr = &mut dl[r * k..(r + 1) * k];
        let mean: f32 = pr.iter().zip(dpr).map(|(&a, &b)| a * b).sum();
        for j in 0..k {
            dlr[j] = pr[j] * (dpr[j] - mean) * inv_tau;
        }
    }
    dl
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::grads_close;
    use crate::util::prop;
    use crate::util::rng::SplitMix64;

    const EPS: f32 = 1e-3;
    const TOL: f32 = 2e-2;

    /// Scalar probe loss `L = Σ coef ⊙ forward(x)` — linear in the
    /// output, so `dy = coef` and central differences are accurate.
    fn coef(rng: &mut SplitMix64, len: usize) -> Vec<f32> {
        prop::vec_f32(rng, len, 1.0)
    }

    #[test]
    fn linear_grads_match_finite_differences() {
        let mut rng = SplitMix64::new(3);
        let (n, din, dout) = (4usize, 5usize, 3usize);
        let mut l = Linear::new(din, dout, Init::He, &mut rng);
        let x = prop::vec_f32(&mut rng, n * din, 1.0);
        let dy = coef(&mut rng, n * dout);
        let loss = |l: &Linear, x: &[f32]| -> f32 {
            l.forward(x, n).iter().zip(&dy).map(|(&y, &c)| y * c).sum()
        };
        l.zero_grad();
        let dx = l.backward(&x, &dy, n);
        // weights
        for idx in 0..l.w.len() {
            let old = l.w[idx];
            l.w[idx] = old + EPS;
            let lp = loss(&l, &x);
            l.w[idx] = old - EPS;
            let lm = loss(&l, &x);
            l.w[idx] = old;
            let fd = (lp - lm) / (2.0 * EPS);
            assert!(grads_close(l.gw[idx], fd, TOL),
                    "gw[{idx}]: {} vs fd {fd}", l.gw[idx]);
        }
        // bias
        for idx in 0..l.b.len() {
            let old = l.b[idx];
            l.b[idx] = old + EPS;
            let lp = loss(&l, &x);
            l.b[idx] = old - EPS;
            let lm = loss(&l, &x);
            l.b[idx] = old;
            let fd = (lp - lm) / (2.0 * EPS);
            assert!(grads_close(l.gb[idx], fd, TOL),
                    "gb[{idx}]: {} vs fd {fd}", l.gb[idx]);
        }
        // input
        let mut xm = x.clone();
        for idx in 0..xm.len() {
            let old = xm[idx];
            xm[idx] = old + EPS;
            let lp = loss(&l, &xm);
            xm[idx] = old - EPS;
            let lm = loss(&l, &xm);
            xm[idx] = old;
            let fd = (lp - lm) / (2.0 * EPS);
            assert!(grads_close(dx[idx], fd, TOL),
                    "dx[{idx}]: {} vs fd {fd}", dx[idx]);
        }
    }

    #[test]
    fn batchnorm_lite_grads_match_finite_differences() {
        // frozen-stats mode: the statistics are constants, so the lite
        // backward is the exact gradient and fd must agree
        let mut rng = SplitMix64::new(5);
        let (n, d) = (6usize, 4usize);
        let mut bn = BatchNormLite::new(d);
        for f in 0..d {
            bn.running_mean[f] = rng.normal();
            bn.running_var[f] = 0.5 + rng.next_f32();
            bn.gamma[f] = 0.5 + rng.next_f32();
            bn.beta[f] = rng.normal();
        }
        let x = prop::vec_f32(&mut rng, n * d, 2.0);
        let dy = coef(&mut rng, n * d);
        let loss = |bn: &BatchNormLite, x: &[f32]| -> f32 {
            bn.infer(x, n).iter().zip(&dy).map(|(&y, &c)| y * c).sum()
        };
        bn.zero_grad();
        let (_, cache) = bn.forward(&x, n, false);
        let dx = bn.backward(&cache, &dy, n);
        for f in 0..d {
            let old = bn.gamma[f];
            bn.gamma[f] = old + EPS;
            let lp = loss(&bn, &x);
            bn.gamma[f] = old - EPS;
            let lm = loss(&bn, &x);
            bn.gamma[f] = old;
            let fd = (lp - lm) / (2.0 * EPS);
            assert!(grads_close(bn.ggamma[f], fd, TOL),
                    "ggamma[{f}]: {} vs fd {fd}", bn.ggamma[f]);

            let old = bn.beta[f];
            bn.beta[f] = old + EPS;
            let lp = loss(&bn, &x);
            bn.beta[f] = old - EPS;
            let lm = loss(&bn, &x);
            bn.beta[f] = old;
            let fd = (lp - lm) / (2.0 * EPS);
            assert!(grads_close(bn.gbeta[f], fd, TOL),
                    "gbeta[{f}]: {} vs fd {fd}", bn.gbeta[f]);
        }
        let mut xm = x.clone();
        for idx in 0..xm.len() {
            let old = xm[idx];
            xm[idx] = old + EPS;
            let lp = loss(&bn, &xm);
            xm[idx] = old - EPS;
            let lm = loss(&bn, &xm);
            xm[idx] = old;
            let fd = (lp - lm) / (2.0 * EPS);
            assert!(grads_close(dx[idx], fd, TOL),
                    "dx[{idx}]: {} vs fd {fd}", dx[idx]);
        }
    }

    #[test]
    fn batchnorm_train_mode_normalizes_and_tracks_stats() {
        let mut rng = SplitMix64::new(8);
        let (n, d) = (64usize, 3usize);
        let x: Vec<f32> =
            (0..n * d).map(|i| rng.normal() * 3.0 + (i % d) as f32).collect();
        let mut bn = BatchNormLite::new(d);
        bn.momentum = 1.0; // running stats = this batch's stats
        let (y, _) = bn.forward(&x, n, true);
        // normalized output: per-feature mean ≈ 0, var ≈ 1
        for f in 0..d {
            let mean: f32 =
                (0..n).map(|r| y[r * d + f]).sum::<f32>() / n as f32;
            let var: f32 = (0..n)
                .map(|r| (y[r * d + f] - mean).powi(2))
                .sum::<f32>()
                / n as f32;
            assert!(mean.abs() < 1e-3, "mean[{f}] = {mean}");
            assert!((var - 1.0).abs() < 1e-2, "var[{f}] = {var}");
            assert!((bn.running_mean[f] - (f as f32 + 0.0)).abs() < 2.0);
        }
        // eval mode now reproduces the same normalization
        let y2 = bn.infer(&x, n);
        for (a, b) in y.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn relu_backward_masks_negative_preactivations() {
        let x = vec![-1.0, 0.0, 2.0, -0.5, 3.0];
        let dy = vec![1.0, 1.0, 1.0, 1.0, 2.0];
        assert_eq!(relu(&x), vec![0.0, 0.0, 2.0, 0.0, 3.0]);
        assert_eq!(relu_backward(&x, &dy), vec![0.0, 0.0, 1.0, 0.0, 2.0]);
    }

    #[test]
    fn softmax_t_rows_is_a_distribution_and_sharpens() {
        let logits = vec![1.0, 2.0, 4.0, 0.0, 0.0, 0.0];
        let p1 = softmax_t_rows(&logits, 2, 3, 1.0);
        let p_cold = softmax_t_rows(&logits, 2, 3, 0.1);
        for r in 0..2 {
            let s: f32 = p1[r * 3..(r + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        // lower temperature concentrates mass on the argmax
        assert!(p_cold[2] > p1[2]);
        assert!(p_cold[2] > 0.99);
        // uniform logits stay uniform at any temperature
        assert!((p_cold[3] - 1.0 / 3.0).abs() < 1e-5);
    }

    #[test]
    fn softmax_t_backward_matches_finite_differences() {
        let mut rng = SplitMix64::new(11);
        let (rows, k) = (3usize, 5usize);
        for &tau in &[1.0f32, 0.5, 2.0] {
            let mut logits = prop::vec_f32(&mut rng, rows * k, 2.0);
            let dp = coef(&mut rng, rows * k);
            let loss = |l: &[f32]| -> f32 {
                softmax_t_rows(l, rows, k, tau)
                    .iter()
                    .zip(&dp)
                    .map(|(&p, &c)| p * c)
                    .sum()
            };
            let p = softmax_t_rows(&logits, rows, k, tau);
            let dl = softmax_t_backward(&p, &dp, rows, k, tau);
            for idx in 0..logits.len() {
                let old = logits[idx];
                logits[idx] = old + EPS;
                let lp = loss(&logits);
                logits[idx] = old - EPS;
                let lm = loss(&logits);
                logits[idx] = old;
                let fd = (lp - lm) / (2.0 * EPS);
                assert!(grads_close(dl[idx], fd, TOL),
                        "tau {tau} dl[{idx}]: {} vs fd {fd}", dl[idx]);
            }
        }
    }

    #[test]
    fn linear_identity_and_zero_inits() {
        let mut rng = SplitMix64::new(1);
        let id = Linear::new(3, 3, Init::Identity, &mut rng);
        let x = vec![1.0, 2.0, 3.0];
        assert_eq!(id.forward(&x, 1), x);
        let z = Linear::new(3, 2, Init::Zero, &mut rng);
        assert_eq!(z.forward(&x, 1), vec![0.0, 0.0]);
        // partial identity projects the leading block
        let proj = Linear::new(3, 2, Init::Identity, &mut rng);
        assert_eq!(proj.forward(&x, 1), vec![1.0, 2.0]);
    }

    #[test]
    fn linear_save_load_roundtrip() {
        let mut rng = SplitMix64::new(2);
        let l = Linear::new(4, 3, Init::He, &mut rng);
        let mut s = Store::new();
        l.save(&mut s, "t");
        let back = Linear::load(&s, "t").unwrap();
        assert_eq!(back.w, l.w);
        assert_eq!(back.b, l.b);
        assert_eq!(back.in_dim, 4);
        assert_eq!(back.out_dim, 3);
        assert!(Linear::load(&s, "missing").is_err());
    }

    #[test]
    fn batchnorm_save_load_roundtrip() {
        let mut bn = BatchNormLite::new(3);
        bn.running_mean = vec![1.0, 2.0, 3.0];
        bn.running_var = vec![0.5, 1.5, 2.5];
        bn.gamma = vec![0.9, 1.1, 1.2];
        let mut s = Store::new();
        bn.save(&mut s, "bn");
        let back = BatchNormLite::load(&s, "bn").unwrap();
        assert_eq!(back.running_mean, bn.running_mean);
        assert_eq!(back.running_var, bn.running_var);
        assert_eq!(back.gamma, bn.gamma);
        assert_eq!(back.dim, 3);
    }
}
