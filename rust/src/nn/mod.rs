//! Hand-rolled reverse-mode neural-network substrate (no autograd, no
//! external ML crates — the offline testbed bakes in nothing beyond std).
//!
//! This is the training half of the native UNQ quantizer
//! (`quant::unq_native`): a small library of layers with explicit
//! forward/backward pairs, an [`Adam`] optimizer, and the [`Mlp`]
//! composite both the encoder and the decoder instantiate.  Design
//! choices, in order of importance:
//!
//! * **Explicit caches, no tape.**  Each `forward` returns whatever its
//!   `backward` needs; the composite threads them by hand.  Control flow
//!   is plain Rust, so the straight-through estimator of the quantizer
//!   (hard forward, soft backward) is just two code paths, not a graph
//!   rewrite.
//! * **Finite-difference-checked gradients.**  Every layer's backward and
//!   the full encoder→quantize→decoder stack are pinned against central
//!   differences in tests ([`grads_close`] is the shared tolerance rule).
//! * **Deterministic, seeded initialization** via [`crate::util::rng`] —
//!   the same seed reproduces the same trained model bit-for-bit on one
//!   platform (training is single-threaded by construction).
//! * **Skip-connected MLPs.**  [`Mlp`] computes
//!   `skip(x) + l2(relu(bn(l1(x))))` with the skip initialized to the
//!   (partial) identity and `l2` to zero, so a fresh network starts as
//!   the identity map and training learns a *correction* — which is what
//!   lets the native UNQ start from an exactly-PQ operating point and
//!   improve from there (DESIGN.md §8).

pub mod layers;
pub mod opt;

pub use layers::{relu, relu_backward, softmax_t_backward, softmax_t_rows,
                 BatchNormLite, BnCache, Init, Linear};
pub use opt::Adam;

use crate::store::Store;
use crate::util::rng::SplitMix64;
use crate::Result;

/// Relative-tolerance comparison used by all finite-difference gradient
/// checks: `|a − n| ≤ tol · max(1, |a|, |n|)`.
pub fn grads_close(analytic: f32, numeric: f32, tol: f32) -> bool {
    let scale = 1.0f32.max(analytic.abs()).max(numeric.abs());
    (analytic - numeric).abs() <= tol * scale
}

/// Two-layer perceptron with a linear skip path:
/// `y = skip(x) + l2(relu(bn(l1(x))))`.
///
/// `skip` initializes to the (partial) identity and `l2` to zero, so the
/// fresh network *is* the identity projection; `l1` uses He init so the
/// correction branch has gradient signal from step one.
pub struct Mlp {
    pub in_dim: usize,
    pub hidden: usize,
    pub out_dim: usize,
    pub skip: Linear,
    pub l1: Linear,
    pub bn: BatchNormLite,
    pub l2: Linear,
}

/// Forward activations [`Mlp::forward`] caches for [`Mlp::backward`].
pub struct MlpCache {
    x: Vec<f32>,
    /// post-bn pre-relu activations (the relu mask source)
    hbn: Vec<f32>,
    /// post-relu activations (input of `l2`)
    hr: Vec<f32>,
    bn: BnCache,
}

impl Mlp {
    pub fn new(in_dim: usize, hidden: usize, out_dim: usize,
               rng: &mut SplitMix64) -> Mlp {
        Mlp {
            in_dim,
            hidden,
            out_dim,
            skip: Linear::new(in_dim, out_dim, Init::Identity, rng),
            l1: Linear::new(in_dim, hidden, Init::He, rng),
            bn: BatchNormLite::new(hidden),
            l2: Linear::new(hidden, out_dim, Init::Zero, rng),
        }
    }

    /// Training-path forward over a flat `n × in_dim` batch; returns the
    /// output and the caches `backward` consumes.  `update_stats` selects
    /// batch statistics (and refreshes the EMAs) for the norm layer;
    /// pass `false` for the frozen, finite-difference-checkable mode.
    pub fn forward(&mut self, x: &[f32], n: usize, update_stats: bool)
                   -> (Vec<f32>, MlpCache) {
        let h1 = self.l1.forward(x, n);
        let (hbn, bnc) = self.bn.forward(&h1, n, update_stats);
        let hr = relu(&hbn);
        let mut out = self.l2.forward(&hr, n);
        let sk = self.skip.forward(x, n);
        for (o, s) in out.iter_mut().zip(&sk) {
            *o += s;
        }
        (out, MlpCache { x: x.to_vec(), hbn, hr, bn: bnc })
    }

    /// Inference forward: running statistics, no caches, `&self` (safe
    /// from the `Send + Sync` quantizer trait methods).
    pub fn infer(&self, x: &[f32], n: usize) -> Vec<f32> {
        let h1 = self.l1.forward(x, n);
        let hbn = self.bn.infer(&h1, n);
        let hr = relu(&hbn);
        let mut out = self.l2.forward(&hr, n);
        let sk = self.skip.forward(x, n);
        for (o, s) in out.iter_mut().zip(&sk) {
            *o += s;
        }
        out
    }

    /// Accumulate parameter gradients from upstream `dout`, return `dx`.
    pub fn backward(&mut self, cache: &MlpCache, dout: &[f32], n: usize)
                    -> Vec<f32> {
        let mut dx = self.skip.backward(&cache.x, dout, n);
        let dhr = self.l2.backward(&cache.hr, dout, n);
        let dhbn = relu_backward(&cache.hbn, &dhr);
        let dh1 = self.bn.backward(&cache.bn, &dhbn, n);
        let dx1 = self.l1.backward(&cache.x, &dh1, n);
        for (a, b) in dx.iter_mut().zip(&dx1) {
            *a += b;
        }
        dx
    }

    pub fn zero_grad(&mut self) {
        self.skip.zero_grad();
        self.l1.zero_grad();
        self.bn.zero_grad();
        self.l2.zero_grad();
    }

    /// Apply one Adam update to every parameter tensor, consuming slot
    /// ids from `slot` (callers chain several modules off one counter).
    pub fn adam_step(&mut self, opt: &mut Adam, slot: &mut usize) {
        for (p, g) in [(&mut self.skip.w, &self.skip.gw),
                       (&mut self.skip.b, &self.skip.gb),
                       (&mut self.l1.w, &self.l1.gw),
                       (&mut self.l1.b, &self.l1.gb),
                       (&mut self.bn.gamma, &self.bn.ggamma),
                       (&mut self.bn.beta, &self.bn.gbeta),
                       (&mut self.l2.w, &self.l2.gw),
                       (&mut self.l2.b, &self.l2.gb)] {
            opt.update(*slot, p, g);
            *slot += 1;
        }
    }

    pub fn param_count(&self) -> usize {
        self.skip.param_count() + self.l1.param_count()
            + self.bn.param_count() + self.l2.param_count()
    }

    pub fn save(&self, store: &mut Store, prefix: &str) {
        self.skip.save(store, &format!("{prefix}skip"));
        self.l1.save(store, &format!("{prefix}l1"));
        self.bn.save(store, &format!("{prefix}bn"));
        self.l2.save(store, &format!("{prefix}l2"));
    }

    pub fn load(store: &Store, prefix: &str) -> Result<Mlp> {
        let skip = Linear::load(store, &format!("{prefix}skip"))?;
        let l1 = Linear::load(store, &format!("{prefix}l1"))?;
        let bn = BatchNormLite::load(store, &format!("{prefix}bn"))?;
        let l2 = Linear::load(store, &format!("{prefix}l2"))?;
        Ok(Mlp {
            in_dim: skip.in_dim,
            hidden: l1.out_dim,
            out_dim: skip.out_dim,
            skip,
            l1,
            bn,
            l2,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    const EPS: f32 = 1e-3;
    const TOL: f32 = 2e-2;

    #[test]
    fn fresh_mlp_is_the_identity_projection() {
        let mut rng = SplitMix64::new(7);
        let mlp = Mlp::new(4, 8, 4, &mut rng);
        let x = vec![1.0, -2.0, 3.0, 0.5, 0.0, 1.0, 2.0, -1.0];
        let y = mlp.infer(&x, 2);
        assert_eq!(y, x, "zero-init correction branch must vanish");
    }

    #[test]
    fn mlp_full_stack_grads_match_finite_differences() {
        let mut rng = SplitMix64::new(13);
        let (n, din, hid, dout) = (5usize, 4usize, 6usize, 3usize);
        let mut mlp = Mlp::new(din, hid, dout, &mut rng);
        // give every branch signal: non-zero l2, shifted bn stats
        for v in mlp.l2.w.iter_mut() {
            *v = rng.normal() * 0.3;
        }
        for f in 0..hid {
            mlp.bn.running_mean[f] = rng.normal() * 0.2;
            mlp.bn.running_var[f] = 0.5 + rng.next_f32();
        }
        let x = prop::vec_f32(&mut rng, n * din, 1.0);
        let dy = prop::vec_f32(&mut rng, n * dout, 1.0);
        let loss = |mlp: &Mlp, x: &[f32]| -> f32 {
            mlp.infer(x, n).iter().zip(&dy).map(|(&y, &c)| y * c).sum()
        };
        mlp.zero_grad();
        let (_, cache) = mlp.forward(&x, n, false);
        let dx = mlp.backward(&cache, &dy, n);

        // spot-check a slice of every parameter tensor plus the input
        let mut checks: Vec<(String, f32, f32)> = Vec::new();
        macro_rules! fd_tensor {
            ($name:expr, $tensor:expr, $grad:expr) => {
                for idx in 0..$tensor.len() {
                    let old = $tensor[idx];
                    $tensor[idx] = old + EPS;
                    let lp = loss(&mlp, &x);
                    $tensor[idx] = old - EPS;
                    let lm = loss(&mlp, &x);
                    $tensor[idx] = old;
                    let fd = (lp - lm) / (2.0 * EPS);
                    checks.push((format!("{}[{idx}]", $name), $grad[idx],
                                 fd));
                }
            };
        }
        let gw_skip = mlp.skip.gw.clone();
        let gw1 = mlp.l1.gw.clone();
        let gb1 = mlp.l1.gb.clone();
        let ggamma = mlp.bn.ggamma.clone();
        let gbeta = mlp.bn.gbeta.clone();
        let gw2 = mlp.l2.gw.clone();
        let gb2 = mlp.l2.gb.clone();
        fd_tensor!("skip.w", mlp.skip.w, gw_skip);
        fd_tensor!("l1.w", mlp.l1.w, gw1);
        fd_tensor!("l1.b", mlp.l1.b, gb1);
        fd_tensor!("bn.gamma", mlp.bn.gamma, ggamma);
        fd_tensor!("bn.beta", mlp.bn.beta, gbeta);
        fd_tensor!("l2.w", mlp.l2.w, gw2);
        fd_tensor!("l2.b", mlp.l2.b, gb2);
        let mut xm = x.clone();
        for idx in 0..xm.len() {
            let old = xm[idx];
            xm[idx] = old + EPS;
            let lp = loss(&mlp, &xm);
            xm[idx] = old - EPS;
            let lm = loss(&mlp, &xm);
            xm[idx] = old;
            let fd = (lp - lm) / (2.0 * EPS);
            checks.push((format!("x[{idx}]"), dx[idx], fd));
        }
        for (label, analytic, fd) in checks {
            assert!(grads_close(analytic, fd, TOL),
                    "{label}: analytic {analytic} vs fd {fd}");
        }
    }

    #[test]
    fn mlp_save_load_roundtrip_preserves_inference() {
        let mut rng = SplitMix64::new(21);
        let mut mlp = Mlp::new(5, 7, 5, &mut rng);
        for v in mlp.l2.w.iter_mut() {
            *v = rng.normal() * 0.2;
        }
        let mut s = Store::new();
        mlp.save(&mut s, "enc_");
        let back = Mlp::load(&s, "enc_").unwrap();
        assert_eq!(back.in_dim, 5);
        assert_eq!(back.hidden, 7);
        assert_eq!(back.out_dim, 5);
        let x = prop::vec_f32(&mut rng, 10, 1.0);
        assert_eq!(mlp.infer(&x, 2), back.infer(&x, 2));
        assert_eq!(mlp.param_count(), back.param_count());
    }

    #[test]
    fn adam_trains_mlp_to_fit_a_linear_map() {
        // sanity e2e: fit y = 2x on scalars through the full stack
        let mut rng = SplitMix64::new(33);
        let mut mlp = Mlp::new(1, 4, 1, &mut rng);
        let mut opt = Adam::new(0.01);
        let n = 16usize;
        for _ in 0..400 {
            let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let target: Vec<f32> = x.iter().map(|&v| 2.0 * v).collect();
            let (y, cache) = mlp.forward(&x, n, true);
            let dy: Vec<f32> = y
                .iter()
                .zip(&target)
                .map(|(&a, &t)| 2.0 * (a - t) / n as f32)
                .collect();
            mlp.zero_grad();
            mlp.backward(&cache, &dy, n);
            opt.begin_step();
            let mut slot = 0;
            mlp.adam_step(&mut opt, &mut slot);
            assert_eq!(slot, 8);
        }
        let x = vec![0.5f32, -1.0];
        let y = mlp.infer(&x, 2);
        assert!((y[0] - 1.0).abs() < 0.2, "y(0.5) = {}", y[0]);
        assert!((y[1] + 2.0).abs() < 0.4, "y(-1) = {}", y[1]);
    }
}
