//! Exact brute-force ground truth (the evaluation substrate).
//!
//! For every query, the true top-R nearest base rows under squared L2 —
//! computed by blocked exhaustive scan and cached to disk as ivecs next to
//! the dataset, keyed by (n_base, n_query, R) so scale sweeps reuse
//! prefixes safely.

use std::path::{Path, PathBuf};

use crate::data::{vecs, Dataset};
use crate::linalg::{sq_l2, TopK};
use crate::Result;

/// Ground truth: per query, the ids of its true top-R base neighbors,
/// ascending by distance.
pub struct GroundTruth {
    pub r: usize,
    pub neighbors: Vec<Vec<i32>>,
}

impl GroundTruth {
    /// True nearest neighbor of query `q`.
    pub fn nn(&self, q: usize) -> i32 {
        self.neighbors[q][0]
    }
}

/// Compute exact top-`r` neighbors of every query against the base set.
pub fn brute_force(base: &Dataset, query: &Dataset, r: usize) -> GroundTruth {
    assert_eq!(base.dim, query.dim, "dim mismatch");
    let n = base.len();
    let neighbors = (0..query.len())
        .map(|qi| {
            let q = query.row(qi);
            let mut top = TopK::new(r.min(n));
            for i in 0..n {
                let d = sq_l2(q, base.row(i));
                top.push(d, i as u32);
            }
            top.into_sorted().into_iter().map(|(_, id)| id as i32).collect()
        })
        .collect();
    GroundTruth { r, neighbors }
}

fn cache_path(data_dir: &Path, name: &str, n_base: usize, n_query: usize,
              r: usize) -> PathBuf {
    data_dir
        .join(name)
        .join(format!("gt_b{n_base}_q{n_query}_r{r}.ivecs"))
}

/// Load the cached ground truth for (dataset, sizes, R) or compute + cache.
pub fn load_or_compute(data_dir: &Path, name: &str, base: &Dataset,
                       query: &Dataset, r: usize) -> Result<GroundTruth> {
    let path = cache_path(data_dir, name, base.len(), query.len(), r);
    if path.exists() {
        let neighbors = vecs::read_ivecs(&path)?;
        if neighbors.len() == query.len()
            && neighbors.iter().all(|row| row.len() >= r.min(base.len()))
        {
            return Ok(GroundTruth { r, neighbors });
        }
    }
    let t0 = std::time::Instant::now();
    let gt = brute_force(base, query, r);
    eprintln!(
        "[gt] {name}: exact top-{r} for {}q × {}b in {:.1}s",
        query.len(), base.len(), t0.elapsed().as_secs_f32()
    );
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    vecs::write_ivecs(&path, &gt.neighbors)?;
    Ok(gt)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid_dataset() -> Dataset {
        // base points at x = 0, 1, ..., 9 on a line
        Dataset::new(2, (0..10).flat_map(|i| [i as f32, 0.0]).collect())
    }

    #[test]
    fn exact_neighbors_on_line() {
        let base = grid_dataset();
        let query = Dataset::new(2, vec![3.2, 0.0]);
        let gt = brute_force(&base, &query, 3);
        assert_eq!(gt.neighbors[0], vec![3, 4, 2]);
        assert_eq!(gt.nn(0), 3);
    }

    #[test]
    fn r_capped_at_n() {
        let base = Dataset::new(2, vec![0.0, 0.0, 1.0, 1.0]);
        let query = Dataset::new(2, vec![0.1, 0.1]);
        let gt = brute_force(&base, &query, 10);
        assert_eq!(gt.neighbors[0].len(), 2);
    }

    #[test]
    fn cache_roundtrip() {
        let dir = crate::util::TempDir::new("gt").unwrap();
        let base = grid_dataset();
        let query = Dataset::new(2, vec![7.9, 0.0, 0.2, 0.0]);
        let g1 = load_or_compute(dir.path(), "t", &base, &query, 2).unwrap();
        let g2 = load_or_compute(dir.path(), "t", &base, &query, 2).unwrap();
        assert_eq!(g1.neighbors, g2.neighbors);
        assert_eq!(g1.neighbors[0][0], 8);
        assert_eq!(g1.neighbors[1][0], 0);
    }

    #[test]
    fn distinct_sizes_distinct_caches() {
        let dir = crate::util::TempDir::new("gt").unwrap();
        let base = grid_dataset();
        let q = Dataset::new(2, vec![0.2, 0.0]);
        load_or_compute(dir.path(), "t", &base, &q, 2).unwrap();
        let small = base.prefix(3);
        let g = load_or_compute(dir.path(), "t", &small, &q, 2).unwrap();
        assert_eq!(g.neighbors[0].len(), 2);
        assert!(g.neighbors[0].iter().all(|&id| id < 3));
    }
}
