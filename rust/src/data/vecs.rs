//! fvecs / ivecs file I/O (the TEXMEX interchange formats).
//!
//! fvecs: per row, little-endian `i32` dim then `dim` `f32`s.
//! ivecs: same with `i32` payloads.  These are the on-disk contract
//! between the Rust generators/GT and the build-time Python trainer.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{ensure, Context};

use super::Dataset;
use crate::Result;

/// Read an fvecs file; `limit` caps the number of rows.
pub fn read_fvecs(path: &Path, limit: Option<usize>) -> Result<Dataset> {
    let file = File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = BufReader::new(file);
    let mut dim_buf = [0u8; 4];
    let mut data = Vec::new();
    let mut dim = 0usize;
    let mut rows = 0usize;
    loop {
        if let Some(l) = limit {
            if rows >= l {
                break;
            }
        }
        match r.read_exact(&mut dim_buf) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
            Err(e) => return Err(e.into()),
        }
        let d = i32::from_le_bytes(dim_buf) as usize;
        ensure!(d > 0 && d < 1 << 20, "bad fvecs dim {d} in {path:?}");
        if dim == 0 {
            dim = d;
        }
        ensure!(d == dim, "inconsistent dims in {path:?}: {d} vs {dim}");
        let mut row = vec![0u8; d * 4];
        r.read_exact(&mut row)
            .with_context(|| format!("truncated row {rows} in {path:?}"))?;
        data.extend(row.chunks_exact(4).map(|c| {
            f32::from_le_bytes([c[0], c[1], c[2], c[3]])
        }));
        rows += 1;
    }
    ensure!(rows > 0, "empty fvecs file {path:?}");
    Ok(Dataset::new(dim, data))
}

/// Write a dataset as fvecs.
pub fn write_fvecs(path: &Path, d: &Dataset) -> Result<()> {
    let file = File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(file);
    let dim_le = (d.dim as i32).to_le_bytes();
    for i in 0..d.len() {
        w.write_all(&dim_le)?;
        for v in d.row(i) {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Write int rows (e.g. ground-truth neighbor ids) as ivecs.
pub fn write_ivecs(path: &Path, rows: &[Vec<i32>]) -> Result<()> {
    let file = File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(file);
    for row in rows {
        w.write_all(&(row.len() as i32).to_le_bytes())?;
        for v in row {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Read an ivecs file into row vectors.
pub fn read_ivecs(path: &Path) -> Result<Vec<Vec<i32>>> {
    let file = File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = BufReader::new(file);
    let mut dim_buf = [0u8; 4];
    let mut out = Vec::new();
    loop {
        match r.read_exact(&mut dim_buf) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
            Err(e) => return Err(e.into()),
        }
        let d = i32::from_le_bytes(dim_buf) as usize;
        ensure!(d < 1 << 20, "bad ivecs dim {d} in {path:?}");
        let mut row = vec![0u8; d * 4];
        r.read_exact(&mut row)?;
        out.push(
            row.chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fvecs_roundtrip() {
        let dir = crate::util::TempDir::new("vecs").unwrap();
        let p = dir.path().join("x.fvecs");
        let d = Dataset::new(3, vec![1.5, -2.0, 0.0, 7.25, 8.0, -0.125]);
        write_fvecs(&p, &d).unwrap();
        let back = read_fvecs(&p, None).unwrap();
        assert_eq!(back.dim, 3);
        assert_eq!(back.data, d.data);
    }

    #[test]
    fn fvecs_limit() {
        let dir = crate::util::TempDir::new("vecs").unwrap();
        let p = dir.path().join("x.fvecs");
        let d = Dataset::new(2, (0..10).map(|i| i as f32).collect());
        write_fvecs(&p, &d).unwrap();
        let back = read_fvecs(&p, Some(2)).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.data, vec![0., 1., 2., 3.]);
    }

    #[test]
    fn ivecs_roundtrip() {
        let dir = crate::util::TempDir::new("vecs").unwrap();
        let p = dir.path().join("g.ivecs");
        let rows = vec![vec![1, 2, 3], vec![-4, 5, 6]];
        write_ivecs(&p, &rows).unwrap();
        assert_eq!(read_ivecs(&p).unwrap(), rows);
    }

    #[test]
    fn empty_fvecs_is_error() {
        let dir = crate::util::TempDir::new("vecs").unwrap();
        let p = dir.path().join("e.fvecs");
        std::fs::write(&p, b"").unwrap();
        assert!(read_fvecs(&p, None).is_err());
    }

    #[test]
    fn truncated_fvecs_is_error() {
        let dir = crate::util::TempDir::new("vecs").unwrap();
        let p = dir.path().join("t.fvecs");
        let mut bytes = (3i32).to_le_bytes().to_vec();
        bytes.extend(1.0f32.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        assert!(read_fvecs(&p, None).is_err());
    }
}
