//! Synthetic descriptor generators (the data substrate, DESIGN.md §3).
//!
//! * **deep-like** — simulates Deep1B: a latent GMM in 32-d pushed through
//!   a fixed random 2-layer ReLU net to 96-d, then L2-normalized.  This
//!   reproduces the properties the paper's comparison hinges on: low
//!   intrinsic dimensionality, strongly coupled coordinates (so orthogonal
//!   decompositions like PQ lose accuracy and additive/learned models win)
//!   and unit-norm vectors.
//! * **sift-like** — simulates BigANN SIFT: non-negative, heavy-tailed
//!   gradient-histogram integers with block-level correlation (16 blocks
//!   of 8 bins sharing an orientation energy), saturated at 218 like real
//!   SIFT.  Subspaces are nearly independent, the regime where (O)PQ/LSQ
//!   are strongest.
//!
//! Determinism: every (family, seed, split, row) is generated from a
//! counter-keyed SplitMix/ChaCha stream, so any prefix of any split is
//! stable regardless of how many rows are requested.

use super::{Dataset, Family};
use crate::util::rng::SplitMix64;

/// Number of GMM components in the deep-like latent space.
const DEEP_COMPONENTS: usize = 64;
/// Latent dimensionality of the deep-like generator.
const DEEP_LATENT: usize = 32;
/// Hidden width of the fixed random ReLU net.
const DEEP_HIDDEN: usize = 128;
/// SIFT-like histogram saturation (real SIFT clips at ~218 of 255).
const SIFT_SATURATION: f32 = 218.0;
/// SIFT block structure: 16 spatial cells × 8 orientation bins.
const SIFT_BLOCKS: usize = 16;

/// Deterministic generator for one (family, seed) pair.
pub struct Generator {
    family: Family,
    seed: u64,
    deep: Option<DeepNet>,
}

/// The fixed random network + mixture shared by all deep-like splits.
struct DeepNet {
    centers: Vec<f32>,        // (COMPONENTS, LATENT)
    center_scale: Vec<f32>,   // per-component spread
    w1: Vec<f32>,             // (LATENT, HIDDEN)
    w2: Vec<f32>,             // (HIDDEN, 96)
}

impl Generator {
    pub fn new(family: Family, seed: u64) -> Self {
        let deep = match family {
            Family::DeepLike => Some(DeepNet::new(seed)),
            Family::SiftLike => None,
        };
        Generator { family, seed, deep }
    }

    /// Generate `n` rows of the given split (0=train, 1=base, 2=query).
    pub fn generate(&self, split: u64, n: usize) -> Dataset {
        let dim = self.family.dim();
        let mut data = vec![0.0f32; n * dim];
        for i in 0..n {
            let mut rng = self.row_rng(split, i as u64);
            let row = &mut data[i * dim..(i + 1) * dim];
            match self.family {
                Family::DeepLike => {
                    self.deep.as_ref().unwrap().sample(&mut rng, row)
                }
                Family::SiftLike => sample_sift(&mut rng, row),
            }
        }
        Dataset::new(dim, data)
    }

    fn row_rng(&self, split: u64, row: u64) -> SplitMix64 {
        // counter-keyed: (seed, split, row) → independent stream
        SplitMix64::from_key(&[self.seed, split, row, 0xD1B54A32D192ED03])
    }
}

impl DeepNet {
    fn new(seed: u64) -> Self {
        let mut rng = SplitMix64::from_key(&[seed, 0xA5A5_5A5A]);
        let centers: Vec<f32> = (0..DEEP_COMPONENTS * DEEP_LATENT)
            .map(|_| 2.2 * rng.normal())
            .collect();
        let center_scale: Vec<f32> = (0..DEEP_COMPONENTS)
            .map(|_| 0.12 + 0.3 * rng.next_f32())
            .collect();
        let s1 = (2.0 / DEEP_LATENT as f32).sqrt();
        let w1: Vec<f32> = (0..DEEP_LATENT * DEEP_HIDDEN)
            .map(|_| s1 * rng.normal())
            .collect();
        let s2 = (2.0 / DEEP_HIDDEN as f32).sqrt();
        let w2: Vec<f32> = (0..DEEP_HIDDEN * 96)
            .map(|_| s2 * rng.normal())
            .collect();
        DeepNet { centers, center_scale, w1, w2 }
    }

    fn sample(&self, rng: &mut SplitMix64, out: &mut [f32]) {
        debug_assert_eq!(out.len(), 96);
        let comp = rng.below(DEEP_COMPONENTS);
        let scale = self.center_scale[comp];
        let center = &self.centers[comp * DEEP_LATENT..(comp + 1) * DEEP_LATENT];
        // latent = center + scale * noise
        let mut latent = [0.0f32; DEEP_LATENT];
        for (l, c) in latent.iter_mut().zip(center) {
            *l = c + scale * rng.normal();
        }
        // hidden = relu(latent @ w1)
        let mut hidden = [0.0f32; DEEP_HIDDEN];
        for (j, h) in hidden.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for (i, l) in latent.iter().enumerate() {
                acc += l * self.w1[i * DEEP_HIDDEN + j];
            }
            *h = acc.max(0.0);
        }
        // out = hidden @ w2, L2-normalized
        for (j, o) in out.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for (i, h) in hidden.iter().enumerate() {
                if *h != 0.0 {
                    acc += h * self.w2[i * 96 + j];
                }
            }
            *o = acc;
        }
        let n: f32 = out.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-9);
        out.iter_mut().for_each(|v| *v /= n);
    }
}

/// One sift-like histogram row: 16 blocks × 8 bins, exponential magnitudes
/// modulated by a per-block gamma-ish energy, integer-quantized, saturated.
fn sample_sift(rng: &mut SplitMix64, out: &mut [f32]) {
    debug_assert_eq!(out.len(), 128);
    let bins = out.len() / SIFT_BLOCKS;
    for b in 0..SIFT_BLOCKS {
        // block energy: sum of two exponentials → gamma(2, ·) heavy tail
        let energy = 6.0 * (rng.exponential() + rng.exponential());
        // one dominant orientation per block, as in real gradient patches
        let dominant = rng.below(bins);
        for k in 0..bins {
            let boost = if k == dominant { 3.0 } else { 1.0 };
            let v = energy * boost * rng.exponential();
            out[b * bins + k] = v.floor().min(SIFT_SATURATION);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg;

    #[test]
    fn deep_like_is_unit_norm() {
        let g = Generator::new(Family::DeepLike, 3);
        let d = g.generate(1, 50);
        for i in 0..d.len() {
            let n = linalg::norm(d.row(i));
            assert!((n - 1.0).abs() < 1e-4, "row {i} norm {n}");
        }
    }

    #[test]
    fn sift_like_is_nonneg_saturated_ints() {
        let g = Generator::new(Family::SiftLike, 3);
        let d = g.generate(1, 50);
        for v in &d.data {
            assert!(*v >= 0.0 && *v <= SIFT_SATURATION);
            assert_eq!(v.fract(), 0.0, "sift-like values are integers");
        }
        // heavy tail: some values should be large
        assert!(d.data.iter().any(|v| *v > 50.0));
    }

    #[test]
    fn deterministic_and_prefix_stable() {
        let g1 = Generator::new(Family::DeepLike, 9);
        let g2 = Generator::new(Family::DeepLike, 9);
        let a = g1.generate(1, 20);
        let b = g2.generate(1, 40);
        assert_eq!(a.data[..], b.data[..20 * 96]);
    }

    #[test]
    fn splits_differ() {
        let g = Generator::new(Family::SiftLike, 9);
        let a = g.generate(0, 5);
        let b = g.generate(1, 5);
        assert_ne!(a.data, b.data);
    }

    #[test]
    fn seeds_differ() {
        let a = Generator::new(Family::DeepLike, 1).generate(1, 3);
        let b = Generator::new(Family::DeepLike, 2).generate(1, 3);
        assert_ne!(a.data, b.data);
    }

    #[test]
    fn deep_like_clustered() {
        // GMM latent ⇒ some pairs are much closer than others.
        let g = Generator::new(Family::DeepLike, 5);
        let d = g.generate(1, 200);
        let mut dists = Vec::new();
        for i in 0..50 {
            for j in (i + 1)..50 {
                dists.push(linalg::sq_l2(d.row(i), d.row(j)));
            }
        }
        dists.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // ~1/64 of pairs share a GMM component; those are far closer than
        // the cross-component median.
        let lo = dists[dists.len() / 100];
        let hi = dists[dists.len() / 2];
        assert!(hi > 3.0 * lo, "expected clustered structure: {lo} vs {hi}");
    }
}
