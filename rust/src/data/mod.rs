//! Dataset substrate: synthetic descriptor generators + *vecs file I/O.
//!
//! The paper evaluates on Deep1M/10M/1B (96-d CNN descriptors) and
//! BigANN1M/10M/1B (128-d SIFT).  Neither corpus is available on this
//! testbed, so we *simulate* them (DESIGN.md §3): generators that
//! reproduce the statistical traits each method family is sensitive to,
//! at scaled-down sizes.  Generation is fully deterministic given the
//! catalog seed, and every split is cached to disk as standard fvecs so
//! the build-time Python trainer reads byte-identical data.

pub mod synthetic;
pub mod vecs;

use std::path::{Path, PathBuf};

use crate::Result;

/// A dataset resident in memory: `n` rows of dimension `dim`, flat.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub dim: usize,
    pub data: Vec<f32>,
}

impl Dataset {
    pub fn new(dim: usize, data: Vec<f32>) -> Self {
        assert!(dim > 0 && data.len() % dim == 0, "ragged dataset");
        Dataset { dim, data }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len() / self.dim
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// Borrow a contiguous range of rows `[lo, hi)` as a flat slice.
    #[inline]
    pub fn rows(&self, lo: usize, hi: usize) -> &[f32] {
        &self.data[lo * self.dim..hi * self.dim]
    }

    /// First `n` rows as a new dataset (cheap prefix view for scale sweeps).
    pub fn prefix(&self, n: usize) -> Dataset {
        let n = n.min(self.len());
        Dataset::new(self.dim, self.data[..n * self.dim].to_vec())
    }
}

/// The descriptor family a synthetic dataset mimics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    /// Deep1B-like: 96-d, L2-normalized outputs of a random ReLU net over
    /// a GMM latent — low intrinsic dimension, strong coordinate coupling.
    DeepLike,
    /// BigANN-like: 128-d, non-negative heavy-tailed block-correlated
    /// gradient-histogram integers in [0, 218].
    SiftLike,
}

impl Family {
    pub fn dim(&self) -> usize {
        match self {
            Family::DeepLike => 96,
            Family::SiftLike => 128,
        }
    }
}

/// One named dataset in the catalog: a family plus split sizes.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: String,
    pub family: Family,
    pub n_base: usize,
    pub n_train: usize,
    pub n_query: usize,
    pub seed: u64,
}

impl DatasetSpec {
    pub fn dim(&self) -> usize {
        self.family.dim()
    }
}

/// The standard catalog mirroring the paper's six evaluation corpora at
/// testbed scale (DESIGN.md §3: "1M"→100k, "10M"→300k, "1B"→1M base
/// vectors; 20k train is what the trainer subsamples from the 100k split).
/// `scale` multiplies base sizes (UNQ_SCALE env, for quick runs use < 1).
pub fn catalog(scale: f64) -> Vec<DatasetSpec> {
    let s = |n: usize| ((n as f64 * scale) as usize).max(1000);
    let mk = |name: &str, family: Family, n_base: usize, seed: u64| DatasetSpec {
        name: name.to_string(),
        family,
        n_base: s(n_base),
        n_train: s(100_000),
        n_query: 1000.min(s(100_000)),
        seed,
    };
    vec![
        mk("deep1m", Family::DeepLike, 100_000, 11),
        mk("sift1m", Family::SiftLike, 100_000, 12),
        mk("deep10m", Family::DeepLike, 300_000, 13),
        mk("sift10m", Family::SiftLike, 300_000, 14),
        mk("deep1b", Family::DeepLike, 1_000_000, 15),
        mk("sift1b", Family::SiftLike, 1_000_000, 16),
    ]
}

/// Look up a catalog entry by name.
pub fn spec_by_name(name: &str, scale: f64) -> Option<DatasetSpec> {
    catalog(scale).into_iter().find(|s| s.name == name)
}

/// The three splits of a generated dataset.
pub struct Splits {
    pub train: Dataset,
    pub base: Dataset,
    pub query: Dataset,
}

/// Paths of the cached splits for a spec under `data_dir`.
pub fn split_paths(data_dir: &Path, name: &str) -> (PathBuf, PathBuf, PathBuf) {
    let d = data_dir.join(name);
    (d.join("train.fvecs"), d.join("base.fvecs"), d.join("query.fvecs"))
}

/// Generate (or load from cache) all splits of a dataset spec.
///
/// Splits are generated from disjoint PRNG streams of the same seed, so
/// train/base/query never overlap yet share the distribution — matching
/// the paper's protocol of separate learn/base/query sets.
pub fn load_or_generate(spec: &DatasetSpec, data_dir: &Path) -> Result<Splits> {
    let (tp, bp, qp) = split_paths(data_dir, &spec.name);
    if tp.exists() && bp.exists() && qp.exists() {
        let train = vecs::read_fvecs(&tp, None)?;
        let base = vecs::read_fvecs(&bp, None)?;
        let query = vecs::read_fvecs(&qp, None)?;
        if train.len() >= spec.n_train && base.len() >= spec.n_base
            && query.len() >= spec.n_query
        {
            return Ok(Splits {
                train: train.prefix(spec.n_train),
                base: base.prefix(spec.n_base),
                query: query.prefix(spec.n_query),
            });
        }
        // cached files too small for this spec — regenerate below
    }
    std::fs::create_dir_all(data_dir.join(&spec.name))?;
    let gen = synthetic::Generator::new(spec.family, spec.seed);
    let train = gen.generate(0, spec.n_train);
    let base = gen.generate(1, spec.n_base);
    let query = gen.generate(2, spec.n_query);
    vecs::write_fvecs(&tp, &train)?;
    vecs::write_fvecs(&bp, &base)?;
    vecs::write_fvecs(&qp, &query)?;
    Ok(Splits { train, base, query })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_rows() {
        let d = Dataset::new(3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(d.len(), 2);
        assert_eq!(d.row(1), &[4., 5., 6.]);
        assert_eq!(d.rows(0, 2).len(), 6);
        assert_eq!(d.prefix(1).len(), 1);
    }

    #[test]
    #[should_panic]
    fn ragged_rejected() {
        Dataset::new(4, vec![0.0; 6]);
    }

    #[test]
    fn catalog_names_unique_and_dims() {
        let cat = catalog(1.0);
        let mut names: Vec<_> = cat.iter().map(|s| s.name.clone()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), cat.len());
        for s in &cat {
            assert!(s.dim() == 96 || s.dim() == 128);
            assert!(s.n_base >= s.n_query);
        }
    }

    #[test]
    fn catalog_scale_shrinks() {
        let full = spec_by_name("deep1m", 1.0).unwrap();
        let tiny = spec_by_name("deep1m", 0.05).unwrap();
        assert!(tiny.n_base < full.n_base);
        assert!(tiny.n_base >= 1000);
    }

    #[test]
    fn load_or_generate_roundtrip() {
        let dir = crate::util::TempDir::new("data").unwrap();
        let spec = DatasetSpec {
            name: "t".into(),
            family: Family::DeepLike,
            n_base: 500,
            n_train: 200,
            n_query: 50,
            seed: 7,
        };
        let s1 = load_or_generate(&spec, dir.path()).unwrap();
        let s2 = load_or_generate(&spec, dir.path()).unwrap(); // from cache
        assert_eq!(s1.base.data, s2.base.data);
        assert_eq!(s1.train.len(), 200);
        assert_eq!(s1.query.len(), 50);
        // distinct splits
        assert_ne!(s1.train.row(0), s1.base.row(0));
    }
}
