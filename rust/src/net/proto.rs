//! Wire codec for the TCP front door.  `rust/PROTOCOL.md` is the
//! normative spec; this module is its executable twin, and the
//! doc-sync test in `tests/net.rs` pins the two together (every
//! opcode/error code in the spec table must match a variant here).
//!
//! Framing reuses the WAL's record discipline (rust/DESIGN.md §7):
//!
//! ```text
//! frame   := len:u32le  crc32:u32le  payload[len]
//! payload := opcode:u8  version:u8  request_id:u64le  body
//! ```
//!
//! The CRC covers the payload only.  A frame that fails length or CRC
//! checks is a *framing* error ([`FrameError`]) and closes the
//! connection; a well-framed payload that fails to parse is a
//! *protocol* error ([`ProtoError`]) answered with a typed `ERROR`
//! response while the connection stays open.

use std::io::Read;

use crate::index::Filter;
use crate::store::wal::crc32;

/// Protocol version carried in every payload.  Bump on any
/// layout-incompatible change; see PROTOCOL.md §"Versioning".
pub const PROTO_VERSION: u8 = 1;

/// Frame header bytes (`len` + `crc32`).
pub const FRAME_HEADER: usize = 8;

/// Largest `k` a SEARCH request may carry (PROTOCOL.md §"Opcodes").
/// The server sizes per-query top-k heaps from `k`, so it must be
/// bounds-checked at admission — a wire `k` of `u32::MAX` would
/// otherwise request a multi-gigabyte allocation per query.  Zero and
/// anything above this cap are answered `BAD_REQUEST`.
pub const MAX_SEARCH_K: u32 = 1 << 16;

/// Payload prelude bytes (`opcode` + `version` + `request_id`).
pub const PAYLOAD_PRELUDE: usize = 10;

/// SEARCH trailing-TLV tag: a `tag = value` metadata predicate,
/// `value:u64le` (PROTOCOL.md §"Opcodes").  The TLV is optional and
/// trailing — a SEARCH body without it is byte-identical to the
/// pre-predicate protocol, so old clients keep working unchanged.
pub const FILTER_TAG_EQ: u8 = 0x01;

/// Every opcode on the wire.  Requests have the top bit clear,
/// responses have it set; `0xFF` is the one error shape shared by all
/// ops.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Opcode {
    Search,
    Insert,
    Delete,
    Stats,
    Ping,
    SearchOk,
    InsertOk,
    DeleteOk,
    StatsOk,
    Pong,
    Error,
}

impl Opcode {
    pub fn all() -> &'static [Opcode] {
        use Opcode::*;
        &[Search, Insert, Delete, Stats, Ping, SearchOk, InsertOk,
          DeleteOk, StatsOk, Pong, Error]
    }

    pub fn code(&self) -> u8 {
        match self {
            Opcode::Search => 0x01,
            Opcode::Insert => 0x02,
            Opcode::Delete => 0x03,
            Opcode::Stats => 0x04,
            Opcode::Ping => 0x05,
            Opcode::SearchOk => 0x81,
            Opcode::InsertOk => 0x82,
            Opcode::DeleteOk => 0x83,
            Opcode::StatsOk => 0x84,
            Opcode::Pong => 0x85,
            Opcode::Error => 0xFF,
        }
    }

    /// Spec-table name (PROTOCOL.md §"Opcodes").
    pub fn name(&self) -> &'static str {
        match self {
            Opcode::Search => "SEARCH",
            Opcode::Insert => "INSERT",
            Opcode::Delete => "DELETE",
            Opcode::Stats => "STATS",
            Opcode::Ping => "PING",
            Opcode::SearchOk => "SEARCH_OK",
            Opcode::InsertOk => "INSERT_OK",
            Opcode::DeleteOk => "DELETE_OK",
            Opcode::StatsOk => "STATS_OK",
            Opcode::Pong => "PONG",
            Opcode::Error => "ERROR",
        }
    }

    pub fn from_code(c: u8) -> Option<Opcode> {
        Opcode::all().iter().copied().find(|o| o.code() == c)
    }
}

/// Typed error codes carried in `ERROR` responses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// Admission control shed the request (queue/connection/in-flight
    /// limits) — retry with backoff; never means a hung server.
    Overloaded,
    /// The tenant's QPS or insert-byte budget is exhausted.
    QuotaExceeded,
    /// Well-framed but unparseable or shape-invalid request.
    BadRequest,
    /// Tenant name not in the server's quota table.
    UnknownTenant,
    /// Payload `version` differs from the server's [`PROTO_VERSION`].
    BadVersion,
    /// Frame payload exceeds the server's `net.max_frame`.
    FrameTooLarge,
    /// Server-side failure unrelated to the request shape.
    Internal,
}

impl ErrorCode {
    pub fn all() -> &'static [ErrorCode] {
        use ErrorCode::*;
        &[Overloaded, QuotaExceeded, BadRequest, UnknownTenant,
          BadVersion, FrameTooLarge, Internal]
    }

    pub fn code(&self) -> u8 {
        match self {
            ErrorCode::Overloaded => 0x01,
            ErrorCode::QuotaExceeded => 0x02,
            ErrorCode::BadRequest => 0x03,
            ErrorCode::UnknownTenant => 0x04,
            ErrorCode::BadVersion => 0x05,
            ErrorCode::FrameTooLarge => 0x06,
            ErrorCode::Internal => 0x07,
        }
    }

    /// Spec-table name (PROTOCOL.md §"Error codes").
    pub fn name(&self) -> &'static str {
        match self {
            ErrorCode::Overloaded => "OVERLOADED",
            ErrorCode::QuotaExceeded => "QUOTA_EXCEEDED",
            ErrorCode::BadRequest => "BAD_REQUEST",
            ErrorCode::UnknownTenant => "UNKNOWN_TENANT",
            ErrorCode::BadVersion => "BAD_VERSION",
            ErrorCode::FrameTooLarge => "FRAME_TOO_LARGE",
            ErrorCode::Internal => "INTERNAL",
        }
    }

    pub fn from_code(c: u8) -> Option<ErrorCode> {
        ErrorCode::all().iter().copied().find(|e| e.code() == c)
    }
}

/// Decoded request payload.
#[derive(Clone, Debug, PartialEq)]
pub enum RequestBody {
    /// Top-k neighbor search; `query.len()` must equal the serving
    /// quantizer's dimensionality.  `filter` is the optional metadata
    /// predicate carried as a trailing TLV (absent on the wire ⇒
    /// `None` ⇒ scan everything).
    Search { tenant: String, k: u32, query: Vec<f32>,
             filter: Option<Filter> },
    /// Row-major vectors to encode-and-insert (streaming backends).
    Insert { tenant: String, rows: u32, dim: u32, vectors: Vec<f32> },
    /// External ids to tombstone.
    Delete { tenant: String, ids: Vec<u32> },
    /// Per-tenant accounting snapshot as JSON.
    Stats { tenant: String },
    /// Liveness probe; bypasses admission control.
    Ping,
}

#[derive(Clone, Debug, PartialEq)]
pub struct NetRequest {
    pub id: u64,
    pub body: RequestBody,
}

/// Decoded response payload.
#[derive(Clone, Debug, PartialEq)]
pub enum ResponseBody {
    SearchOk { neighbors: Vec<u32> },
    InsertOk { accepted: bool, ids: Vec<u32> },
    DeleteOk { accepted: bool, removed: u64 },
    StatsOk { json: String },
    Pong,
    Error { code: ErrorCode, msg: String },
}

#[derive(Clone, Debug, PartialEq)]
pub struct NetResponse {
    pub id: u64,
    pub body: ResponseBody,
}

/// Frame-level failures: the connection cannot carry further requests
/// and is closed (PROTOCOL.md §"Framing").
#[derive(Debug)]
pub enum FrameError {
    /// EOF in the middle of a frame (peer vanished mid-write).
    Torn,
    /// Header CRC does not match the payload.
    BadCrc,
    /// Declared payload length exceeds the configured cap.
    TooLarge(usize),
    Io(std::io::Error),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Torn => write!(f, "torn frame (EOF mid-frame)"),
            FrameError::BadCrc => write!(f, "frame CRC mismatch"),
            FrameError::TooLarge(n) => {
                write!(f, "frame payload of {n} bytes exceeds the cap")
            }
            FrameError::Io(e) => write!(f, "frame I/O: {e}"),
        }
    }
}

impl std::error::Error for FrameError {}

/// Payload-level failures: answered with a typed `ERROR` response.
#[derive(Debug, PartialEq, Eq)]
pub enum ProtoError {
    BadVersion(u8),
    UnknownOpcode(u8),
    Malformed(&'static str),
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::BadVersion(v) => {
                write!(f, "protocol version {v} (speaking {PROTO_VERSION})")
            }
            ProtoError::UnknownOpcode(c) => {
                write!(f, "unknown opcode 0x{c:02X}")
            }
            ProtoError::Malformed(what) => write!(f, "malformed {what}"),
        }
    }
}

impl std::error::Error for ProtoError {}

// ---------------------------------------------------------------- frames

/// Wrap a payload into a full frame (header + payload).
pub fn encode_frame(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(FRAME_HEADER + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Read one frame's payload off the wire.  `Ok(None)` is a clean close
/// (EOF exactly at a frame boundary); every other short read is a
/// [`FrameError::Torn`].
pub fn read_frame<R: Read>(r: &mut R, max_frame: usize)
                           -> Result<Option<Vec<u8>>, FrameError> {
    let mut header = [0u8; FRAME_HEADER];
    match read_full(r, &mut header) {
        Ok(true) => {}
        Ok(false) => return Ok(None),
        Err(FullReadError::Torn) => return Err(FrameError::Torn),
        Err(FullReadError::Io(e)) => return Err(FrameError::Io(e)),
    }
    let len = u32::from_le_bytes(header[0..4].try_into().unwrap()) as usize;
    let want_crc = u32::from_le_bytes(header[4..8].try_into().unwrap());
    if len > max_frame {
        return Err(FrameError::TooLarge(len));
    }
    let mut payload = vec![0u8; len];
    match read_full(r, &mut payload) {
        Ok(true) => {}
        // EOF after a header is mid-frame by definition (len may be 0,
        // in which case the empty read trivially succeeds)
        Ok(false) if len > 0 => return Err(FrameError::Torn),
        Ok(false) => {}
        Err(FullReadError::Torn) => return Err(FrameError::Torn),
        Err(FullReadError::Io(e)) => return Err(FrameError::Io(e)),
    }
    if crc32(&payload) != want_crc {
        return Err(FrameError::BadCrc);
    }
    Ok(Some(payload))
}

enum FullReadError {
    Torn,
    Io(std::io::Error),
}

/// Fill `buf` completely.  `Ok(false)` = EOF before the first byte;
/// EOF after at least one byte is [`FullReadError::Torn`].
fn read_full<R: Read>(r: &mut R, buf: &mut [u8])
                      -> Result<bool, FullReadError> {
    let mut got = 0usize;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) if got == 0 => return Ok(false),
            Ok(0) => return Err(FullReadError::Torn),
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(FullReadError::Io(e)),
        }
    }
    Ok(true)
}

// -------------------------------------------------------------- encoding

fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u16).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn payload_prelude(op: Opcode, id: u64) -> Vec<u8> {
    let mut p = Vec::with_capacity(PAYLOAD_PRELUDE);
    p.push(op.code());
    p.push(PROTO_VERSION);
    p.extend_from_slice(&id.to_le_bytes());
    p
}

/// Encode a request into a full frame.
pub fn encode_request(req: &NetRequest) -> Vec<u8> {
    let mut p;
    match &req.body {
        RequestBody::Search { tenant, k, query, filter } => {
            p = payload_prelude(Opcode::Search, req.id);
            put_str(&mut p, tenant);
            p.extend_from_slice(&k.to_le_bytes());
            p.extend_from_slice(&(query.len() as u32).to_le_bytes());
            for v in query {
                p.extend_from_slice(&v.to_le_bytes());
            }
            // optional trailing TLV: absent = the exact pre-predicate
            // byte layout (the compatibility pin in tests below)
            if let Some(Filter::TagEq(v)) = filter {
                p.push(FILTER_TAG_EQ);
                p.extend_from_slice(&v.to_le_bytes());
            }
        }
        RequestBody::Insert { tenant, rows, dim, vectors } => {
            p = payload_prelude(Opcode::Insert, req.id);
            put_str(&mut p, tenant);
            p.extend_from_slice(&rows.to_le_bytes());
            p.extend_from_slice(&dim.to_le_bytes());
            for v in vectors {
                p.extend_from_slice(&v.to_le_bytes());
            }
        }
        RequestBody::Delete { tenant, ids } => {
            p = payload_prelude(Opcode::Delete, req.id);
            put_str(&mut p, tenant);
            p.extend_from_slice(&(ids.len() as u32).to_le_bytes());
            for id in ids {
                p.extend_from_slice(&id.to_le_bytes());
            }
        }
        RequestBody::Stats { tenant } => {
            p = payload_prelude(Opcode::Stats, req.id);
            put_str(&mut p, tenant);
        }
        RequestBody::Ping => {
            p = payload_prelude(Opcode::Ping, req.id);
        }
    }
    encode_frame(&p)
}

/// Encode a response into a full frame.  Response payloads are fully
/// deterministic functions of the result — no timestamps — which is
/// what lets the bit-identity property compare whole frames.
pub fn encode_response(resp: &NetResponse) -> Vec<u8> {
    let mut p;
    match &resp.body {
        ResponseBody::SearchOk { neighbors } => {
            p = payload_prelude(Opcode::SearchOk, resp.id);
            p.extend_from_slice(&(neighbors.len() as u32).to_le_bytes());
            for id in neighbors {
                p.extend_from_slice(&id.to_le_bytes());
            }
        }
        ResponseBody::InsertOk { accepted, ids } => {
            p = payload_prelude(Opcode::InsertOk, resp.id);
            p.push(*accepted as u8);
            p.extend_from_slice(&(ids.len() as u32).to_le_bytes());
            for id in ids {
                p.extend_from_slice(&id.to_le_bytes());
            }
        }
        ResponseBody::DeleteOk { accepted, removed } => {
            p = payload_prelude(Opcode::DeleteOk, resp.id);
            p.push(*accepted as u8);
            p.extend_from_slice(&removed.to_le_bytes());
        }
        ResponseBody::StatsOk { json } => {
            p = payload_prelude(Opcode::StatsOk, resp.id);
            p.extend_from_slice(&(json.len() as u32).to_le_bytes());
            p.extend_from_slice(json.as_bytes());
        }
        ResponseBody::Pong => {
            p = payload_prelude(Opcode::Pong, resp.id);
        }
        ResponseBody::Error { code, msg } => {
            p = payload_prelude(Opcode::Error, resp.id);
            p.push(code.code());
            // truncate on a char boundary: a byte-offset slice panics
            // when byte 65535 lands inside a multi-byte UTF-8 char
            let mut cut = msg.len().min(u16::MAX as usize);
            while !msg.is_char_boundary(cut) {
                cut -= 1;
            }
            put_str(&mut p, &msg[..cut]);
        }
    }
    encode_frame(&p)
}

// -------------------------------------------------------------- decoding

struct Cur<'a> {
    b: &'a [u8],
    p: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize, what: &'static str)
            -> Result<&'a [u8], ProtoError> {
        if self.p + n > self.b.len() {
            return Err(ProtoError::Malformed(what));
        }
        let s = &self.b[self.p..self.p + n];
        self.p += n;
        Ok(s)
    }

    fn u8(&mut self, what: &'static str) -> Result<u8, ProtoError> {
        Ok(self.take(1, what)?[0])
    }

    fn u16(&mut self, what: &'static str) -> Result<u16, ProtoError> {
        Ok(u16::from_le_bytes(self.take(2, what)?.try_into().unwrap()))
    }

    fn u32(&mut self, what: &'static str) -> Result<u32, ProtoError> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &'static str) -> Result<u64, ProtoError> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn str16(&mut self, what: &'static str) -> Result<String, ProtoError> {
        let n = self.u16(what)? as usize;
        let bytes = self.take(n, what)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| ProtoError::Malformed(what))
    }

    /// `n` little-endian f32s, length-checked before allocating.
    fn f32s(&mut self, n: usize, what: &'static str)
            -> Result<Vec<f32>, ProtoError> {
        let bytes = self.take(n.checked_mul(4)
                                  .ok_or(ProtoError::Malformed(what))?,
                              what)?;
        Ok(bytes.chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn u32s(&mut self, n: usize, what: &'static str)
            -> Result<Vec<u32>, ProtoError> {
        let bytes = self.take(n.checked_mul(4)
                                  .ok_or(ProtoError::Malformed(what))?,
                              what)?;
        Ok(bytes.chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn done(&self, what: &'static str) -> Result<(), ProtoError> {
        if self.p != self.b.len() {
            return Err(ProtoError::Malformed(what));
        }
        Ok(())
    }
}

fn prelude(payload: &[u8])
           -> Result<(Opcode, u64, Cur<'_>), ProtoError> {
    let mut c = Cur { b: payload, p: 0 };
    let op_code = c.u8("opcode")?;
    let version = c.u8("version")?;
    let id = c.u64("request id")?;
    if version != PROTO_VERSION {
        return Err(ProtoError::BadVersion(version));
    }
    let op = Opcode::from_code(op_code)
        .ok_or(ProtoError::UnknownOpcode(op_code))?;
    Ok((op, id, c))
}

/// Best-effort request id for error replies when the payload fails to
/// decode: the id sits at a fixed offset, readable even when the
/// version or opcode is unacceptable.  0 when the payload is too short
/// to carry one.
pub fn peek_request_id(payload: &[u8]) -> u64 {
    if payload.len() < PAYLOAD_PRELUDE {
        return 0;
    }
    u64::from_le_bytes(payload[2..10].try_into().unwrap())
}

pub fn decode_request(payload: &[u8]) -> Result<NetRequest, ProtoError> {
    let (op, id, mut c) = prelude(payload)?;
    let body = match op {
        Opcode::Search => {
            let tenant = c.str16("search tenant")?;
            let k = c.u32("search k")?;
            let dim = c.u32("search dim")? as usize;
            let query = c.f32s(dim, "search query")?;
            let filter = if c.p < c.b.len() {
                match c.u8("search filter tag")? {
                    FILTER_TAG_EQ => Some(Filter::TagEq(
                        c.u64("search filter value")?)),
                    _ => {
                        return Err(ProtoError::Malformed(
                            "search filter tag"))
                    }
                }
            } else {
                None
            };
            RequestBody::Search { tenant, k, query, filter }
        }
        Opcode::Insert => {
            let tenant = c.str16("insert tenant")?;
            let rows = c.u32("insert rows")?;
            let dim = c.u32("insert dim")?;
            let n = (rows as usize).checked_mul(dim as usize)
                .ok_or(ProtoError::Malformed("insert shape"))?;
            let vectors = c.f32s(n, "insert vectors")?;
            RequestBody::Insert { tenant, rows, dim, vectors }
        }
        Opcode::Delete => {
            let tenant = c.str16("delete tenant")?;
            let n = c.u32("delete count")? as usize;
            let ids = c.u32s(n, "delete ids")?;
            RequestBody::Delete { tenant, ids }
        }
        Opcode::Stats => {
            RequestBody::Stats { tenant: c.str16("stats tenant")? }
        }
        Opcode::Ping => RequestBody::Ping,
        _ => return Err(ProtoError::Malformed("response opcode in request")),
    };
    c.done("request trailer")?;
    Ok(NetRequest { id, body })
}

pub fn decode_response(payload: &[u8]) -> Result<NetResponse, ProtoError> {
    let (op, id, mut c) = prelude(payload)?;
    let body = match op {
        Opcode::SearchOk => {
            let n = c.u32("search_ok count")? as usize;
            ResponseBody::SearchOk {
                neighbors: c.u32s(n, "search_ok ids")?,
            }
        }
        Opcode::InsertOk => {
            let accepted = c.u8("insert_ok accepted")? != 0;
            let n = c.u32("insert_ok count")? as usize;
            ResponseBody::InsertOk {
                accepted,
                ids: c.u32s(n, "insert_ok ids")?,
            }
        }
        Opcode::DeleteOk => ResponseBody::DeleteOk {
            accepted: c.u8("delete_ok accepted")? != 0,
            removed: c.u64("delete_ok removed")?,
        },
        Opcode::StatsOk => {
            let n = c.u32("stats_ok length")? as usize;
            let bytes = c.take(n, "stats_ok json")?;
            ResponseBody::StatsOk {
                json: String::from_utf8(bytes.to_vec())
                    .map_err(|_| ProtoError::Malformed("stats_ok json"))?,
            }
        }
        Opcode::Pong => ResponseBody::Pong,
        Opcode::Error => {
            let code_byte = c.u8("error code")?;
            let code = ErrorCode::from_code(code_byte)
                .ok_or(ProtoError::Malformed("error code"))?;
            ResponseBody::Error { code, msg: c.str16("error message")? }
        }
        _ => return Err(ProtoError::Malformed("request opcode in response")),
    };
    c.done("response trailer")?;
    Ok(NetResponse { id, body })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn strip_frame(frame: &[u8]) -> &[u8] {
        &frame[FRAME_HEADER..]
    }

    #[test]
    fn request_roundtrips() {
        let reqs = vec![
            NetRequest { id: 7, body: RequestBody::Search {
                tenant: "default".into(), k: 10,
                query: vec![1.0, -2.5, 0.0], filter: None } },
            NetRequest { id: 11, body: RequestBody::Search {
                tenant: "default".into(), k: 3,
                query: vec![0.25, 4.0],
                filter: Some(Filter::TagEq(u64::MAX)) } },
            NetRequest { id: 8, body: RequestBody::Insert {
                tenant: "alice".into(), rows: 2, dim: 3,
                vectors: vec![0.5; 6] } },
            NetRequest { id: 9, body: RequestBody::Delete {
                tenant: String::new(), ids: vec![3, 1, 4] } },
            NetRequest { id: 10, body: RequestBody::Stats {
                tenant: "bob".into() } },
            NetRequest { id: u64::MAX, body: RequestBody::Ping },
        ];
        for req in reqs {
            let frame = encode_request(&req);
            let payload = strip_frame(&frame);
            assert_eq!(peek_request_id(payload), req.id);
            assert_eq!(decode_request(payload).unwrap(), req);
        }
    }

    #[test]
    fn response_roundtrips() {
        let resps = vec![
            NetResponse { id: 1, body: ResponseBody::SearchOk {
                neighbors: vec![5, 2, 9] } },
            NetResponse { id: 2, body: ResponseBody::InsertOk {
                accepted: true, ids: vec![100, 101] } },
            NetResponse { id: 3, body: ResponseBody::DeleteOk {
                accepted: false, removed: 0 } },
            NetResponse { id: 4, body: ResponseBody::StatsOk {
                json: "{\"requests\": 3}".into() } },
            NetResponse { id: 5, body: ResponseBody::Pong },
            NetResponse { id: 6, body: ResponseBody::Error {
                code: ErrorCode::Overloaded, msg: "shed".into() } },
        ];
        for resp in resps {
            let frame = encode_response(&resp);
            assert_eq!(decode_response(strip_frame(&frame)).unwrap(), resp);
        }
    }

    #[test]
    fn frame_reader_roundtrips_and_detects_clean_eof() {
        let a = encode_frame(b"hello");
        let b = encode_frame(b"");
        let stream: Vec<u8> = [a, b].concat();
        let mut r = &stream[..];
        assert_eq!(read_frame(&mut r, 1 << 20).unwrap().unwrap(),
                   b"hello");
        assert_eq!(read_frame(&mut r, 1 << 20).unwrap().unwrap(),
                   Vec::<u8>::new());
        assert!(read_frame(&mut r, 1 << 20).unwrap().is_none());
    }

    #[test]
    fn torn_frames_are_typed_errors() {
        let frame = encode_frame(b"payload bytes");
        // every strictly-short prefix that still has ≥1 byte is torn
        for cut in 1..frame.len() {
            let mut r = &frame[..cut];
            assert!(matches!(read_frame(&mut r, 1 << 20),
                             Err(FrameError::Torn)),
                    "cut at {cut}");
        }
    }

    #[test]
    fn corrupt_crc_is_a_typed_error() {
        let mut frame = encode_frame(b"payload bytes");
        let last = frame.len() - 1;
        frame[last] ^= 0x40;
        let mut r = &frame[..];
        assert!(matches!(read_frame(&mut r, 1 << 20),
                         Err(FrameError::BadCrc)));
    }

    #[test]
    fn oversized_frame_is_rejected_by_length_alone() {
        // header claims 2 MB; reader must refuse before buffering it
        let mut frame = Vec::new();
        frame.extend_from_slice(&(2u32 << 20).to_le_bytes());
        frame.extend_from_slice(&0u32.to_le_bytes());
        let mut r = &frame[..];
        match read_frame(&mut r, 1 << 20) {
            Err(FrameError::TooLarge(n)) => assert_eq!(n, 2 << 20),
            other => panic!("want TooLarge, got {other:?}"),
        }
    }

    #[test]
    fn version_and_opcode_mismatches_are_typed() {
        let req = NetRequest { id: 3, body: RequestBody::Ping };
        let frame = encode_request(&req);
        let mut payload = strip_frame(&frame).to_vec();
        payload[1] = 9; // future version
        assert_eq!(decode_request(&payload), Err(ProtoError::BadVersion(9)));
        // id is still recoverable for the error reply
        assert_eq!(peek_request_id(&payload), 3);
        let mut payload = strip_frame(&frame).to_vec();
        payload[0] = 0x7C;
        assert_eq!(decode_request(&payload),
                   Err(ProtoError::UnknownOpcode(0x7C)));
    }

    #[test]
    fn truncated_and_padded_payloads_are_malformed() {
        let req = NetRequest { id: 1, body: RequestBody::Search {
            tenant: "t".into(), k: 5, query: vec![1.0, 2.0],
            filter: Some(Filter::TagEq(7)) } };
        let frame = encode_request(&req);
        let payload = strip_frame(&frame);
        for cut in PAYLOAD_PRELUDE..payload.len() {
            assert!(decode_request(&payload[..cut]).is_err(),
                    "cut at {cut}");
        }
        // bytes after a complete TLV are still a trailer error
        let mut padded = payload.to_vec();
        padded.push(0);
        assert_eq!(decode_request(&padded),
                   Err(ProtoError::Malformed("request trailer")));
        // a stray byte after a filterless body lands in TLV position:
        // 0x00 is no known TLV tag, so it is malformed there instead
        let req = NetRequest { id: 1, body: RequestBody::Search {
            tenant: "t".into(), k: 5, query: vec![1.0, 2.0],
            filter: None } };
        let frame = encode_request(&req);
        let mut padded = strip_frame(&frame).to_vec();
        padded.push(0);
        assert_eq!(decode_request(&padded),
                   Err(ProtoError::Malformed("search filter tag")));
        // non-search bodies keep the plain trailer check
        let req = NetRequest { id: 2, body: RequestBody::Delete {
            tenant: "t".into(), ids: vec![4] } };
        let mut padded = strip_frame(&encode_request(&req)).to_vec();
        padded.push(0);
        assert_eq!(decode_request(&padded),
                   Err(ProtoError::Malformed("request trailer")));
    }

    #[test]
    fn absent_filter_tlv_reproduces_the_pre_predicate_bytes() {
        // compatibility pin: a filterless SEARCH body must end exactly
        // after the query floats — the predicate feature adds zero
        // bytes unless used
        let req = NetRequest { id: 9, body: RequestBody::Search {
            tenant: "abc".into(), k: 5, query: vec![1.0, 2.0],
            filter: None } };
        let payload_len = strip_frame(&encode_request(&req)).len();
        assert_eq!(payload_len,
                   PAYLOAD_PRELUDE + 2 + 3 + 4 + 4 + 2 * 4);
        // and the TLV costs exactly 9 bytes when present
        let req = NetRequest { id: 9, body: RequestBody::Search {
            tenant: "abc".into(), k: 5, query: vec![1.0, 2.0],
            filter: Some(Filter::TagEq(0)) } };
        assert_eq!(strip_frame(&encode_request(&req)).len(),
                   payload_len + 9);
    }

    #[test]
    fn hostile_counts_cannot_force_allocation() {
        // a delete claiming u32::MAX ids in a 30-byte payload must fail
        // on the length check, not attempt a 16 GB allocation
        let mut p = payload_prelude(Opcode::Delete, 1);
        put_str(&mut p, "t");
        p.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_request(&p).is_err());
        let mut p = payload_prelude(Opcode::Search, 1);
        put_str(&mut p, "t");
        p.extend_from_slice(&10u32.to_le_bytes());
        p.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_request(&p).is_err());
    }

    #[test]
    fn opcode_and_error_code_tables_are_bijective() {
        for op in Opcode::all() {
            assert_eq!(Opcode::from_code(op.code()), Some(*op));
        }
        let mut codes: Vec<u8> =
            Opcode::all().iter().map(|o| o.code()).collect();
        codes.dedup();
        assert_eq!(codes.len(), Opcode::all().len());
        for ec in ErrorCode::all() {
            assert_eq!(ErrorCode::from_code(ec.code()), Some(*ec));
        }
        assert_eq!(Opcode::from_code(0x42), None);
        assert_eq!(ErrorCode::from_code(0xEE), None);
    }
}
