//! The network front door: a TCP serving layer over the coordinator
//! (rust/DESIGN.md §12).
//!
//! * [`proto`] — the wire codec.  Length-prefixed CRC-framed records
//!   (the WAL's framing discipline) carrying search / insert / delete
//!   / stats / ping ops; normative spec in `rust/PROTOCOL.md`.
//! * [`server`] — acceptors + per-connection reader/writer/pump
//!   threads, pipelined out-of-order completion, and admission control
//!   (per-tenant QPS + insert-byte quotas, in-flight window,
//!   connection cap) that sheds load as typed errors instead of
//!   queueing.
//! * [`client`] — the minimal blocking client (pipelining-capable).
//! * [`loadgen`] — closed- and open-loop load generation
//!   (`unq loadgen`, `benches/serve_load.rs`).
//!
//! Operator runbook: `rust/SERVING.md`.  Everything is std-only — no
//! async runtime exists on this testbed, and a thread per connection
//! over bounded channels is the honest design at testbed scale.

pub mod client;
pub mod loadgen;
pub mod proto;
pub mod server;

pub use client::Client;
pub use server::NetServer;

use std::sync::Arc;
use std::time::Duration;

use crate::config::{AppConfig, IndexBackendKind};
use crate::coordinator::pipeline::Server;
use crate::eval::harness;
use crate::ivf::IndexBackend;
use crate::Result;

/// `unq serve --listen` — boot the configured stack (same preparation
/// path as the closed-loop demo) and serve it over TCP until
/// `duration_secs` elapses (`None` = forever).
pub fn run_listen(cfg: &AppConfig, duration_secs: Option<u64>)
                  -> Result<()> {
    let exp = harness::prepare(cfg, "")?;
    let mut search =
        harness::paper_search_config(cfg.quantizer, &cfg.dataset, 100);
    search.nprobe = cfg.search.nprobe;
    search.scan_precision = cfg.search.scan_precision;

    let harness::Experiment { quant, index, splits, runtime, .. } = exp;
    let backend = match cfg.ivf.backend {
        IndexBackendKind::Flat => IndexBackend::Flat(Arc::new(index)),
        IndexBackendKind::Ivf => {
            let ivf = harness::build_or_load_ivf(
                cfg, quant.as_ref(), &splits.train, &splits.base, "")?;
            IndexBackend::Ivf(Arc::new(ivf))
        }
        IndexBackendKind::DiskIvf => {
            let disk = harness::build_or_load_disk_ivf(
                cfg, quant.as_ref(), &splits.train, &splits.base, "")?;
            IndexBackend::DiskIvf(Arc::new(disk))
        }
    };
    let quant: Arc<dyn crate::quant::Quantizer> = Arc::from(quant);
    let server = Arc::new(
        Server::start_with_backend(quant, backend, search, cfg.serve));
    let net = NetServer::start(server.clone(), cfg.net.clone())
        .map_err(|e| anyhow::anyhow!("bind {}: {e}", cfg.net.listen))?;
    println!("[serve] listening on {} ({} on {}, backend {:?}, \
              max_conns {}, max_inflight {})",
             net.local_addr(), cfg.quantizer.name(), cfg.dataset,
             cfg.ivf.backend, cfg.net.max_conns, cfg.net.max_inflight);

    match duration_secs {
        Some(s) => std::thread::sleep(Duration::from_secs(s)),
        None => loop {
            std::thread::sleep(Duration::from_secs(3600));
        },
    }
    println!("[serve] duration elapsed; shutting down");
    net.shutdown();
    // live connections keep their threads (and the coordinator Arc)
    // until their clients hang up; only a fully-quiesced server can be
    // drained gracefully — otherwise process exit reaps the threads
    if let Ok(s) = Arc::try_unwrap(server) {
        s.shutdown();
    }
    drop(runtime); // stop the PJRT thread last
    Ok(())
}
