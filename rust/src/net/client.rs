//! Minimal blocking client for the wire protocol — the in-tree
//! counterpart of `net/server.rs`, used by `unq loadgen`, the serving
//! bench, and the protocol tests.
//!
//! The client supports pipelining: [`Client::send`] queues a request
//! without waiting, [`Client::recv`] pulls whichever response arrives
//! next (the server completes out of order; match on
//! [`NetResponse::id`]).  The `search`/`insert`/`delete`/`stats`/
//! `ping` helpers are strict one-at-a-time round-trips over an
//! otherwise idle connection.

use std::io::{BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::proto::{decode_response, encode_request, read_frame,
                   NetRequest, NetResponse, RequestBody, ResponseBody};

pub struct Client {
    w: TcpStream,
    r: BufReader<TcpStream>,
    next_id: u64,
    max_frame: usize,
}

impl Client {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Client> {
        let w = TcpStream::connect(addr).context("connect")?;
        let _ = w.set_nodelay(true);
        let r = BufReader::new(w.try_clone().context("clone stream")?);
        Ok(Client { w, r, next_id: 1, max_frame: 1 << 24 })
    }

    /// Connect with retries — for harnesses racing a just-spawned
    /// server process.
    pub fn connect_retry<A: ToSocketAddrs + Copy>(
        addr: A, attempts: usize, delay: Duration) -> Result<Client> {
        let mut last = None;
        for _ in 0..attempts.max(1) {
            match Client::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) => last = Some(e),
            }
            std::thread::sleep(delay);
        }
        Err(last.expect("at least one attempt"))
    }

    /// Bound how long [`Client::recv`] blocks (`None` = forever).
    pub fn set_read_timeout(&self, t: Option<Duration>) -> Result<()> {
        self.r.get_ref().set_read_timeout(t).context("read timeout")?;
        Ok(())
    }

    /// Queue one request (pipelined; does not wait).  Returns the
    /// request id to match the eventual response against.
    pub fn send(&mut self, body: RequestBody) -> Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let frame = encode_request(&NetRequest { id, body });
        self.w.write_all(&frame).context("send frame")?;
        Ok(id)
    }

    /// Pull the next response; `None` means the server closed cleanly
    /// at a frame boundary.
    pub fn recv(&mut self) -> Result<Option<NetResponse>> {
        let Some(payload) = read_frame(&mut self.r, self.max_frame)
            .context("read frame")?
        else {
            return Ok(None);
        };
        Ok(Some(decode_response(&payload).context("decode response")?))
    }

    fn round_trip(&mut self, body: RequestBody) -> Result<NetResponse> {
        let id = self.send(body)?;
        let resp = self.recv()?.context("connection closed mid-request")?;
        if resp.id != id {
            bail!("response id {} for request {id} on an idle \
                   connection", resp.id);
        }
        Ok(resp)
    }

    /// One blocking search round-trip; the response body may be a
    /// typed error (overload, quota, …) — see [`Client::search_ids`]
    /// for the bail-on-error convenience.
    pub fn search(&mut self, tenant: &str, query: &[f32], k: u32)
                  -> Result<NetResponse> {
        self.search_filtered(tenant, query, k, None)
    }

    /// Search under an optional metadata predicate: `Some(filter)`
    /// rides the SEARCH frame as the trailing filter TLV, `None`
    /// produces the exact pre-predicate frame bytes.
    pub fn search_filtered(&mut self, tenant: &str, query: &[f32],
                           k: u32,
                           filter: Option<crate::index::Filter>)
                           -> Result<NetResponse> {
        self.round_trip(RequestBody::Search {
            tenant: tenant.to_string(), k, query: query.to_vec(), filter,
        })
    }

    pub fn search_ids(&mut self, tenant: &str, query: &[f32], k: u32)
                      -> Result<Vec<u32>> {
        match self.search(tenant, query, k)?.body {
            ResponseBody::SearchOk { neighbors } => Ok(neighbors),
            ResponseBody::Error { code, msg } => {
                bail!("search failed: {} ({msg})", code.name())
            }
            other => bail!("unexpected search response: {other:?}"),
        }
    }

    pub fn insert(&mut self, tenant: &str, vectors: &[f32], rows: u32,
                  dim: u32) -> Result<NetResponse> {
        self.round_trip(RequestBody::Insert {
            tenant: tenant.to_string(), rows, dim,
            vectors: vectors.to_vec(),
        })
    }

    pub fn delete(&mut self, tenant: &str, ids: &[u32])
                  -> Result<NetResponse> {
        self.round_trip(RequestBody::Delete {
            tenant: tenant.to_string(), ids: ids.to_vec(),
        })
    }

    /// Tenant accounting snapshot as a JSON string.
    pub fn stats(&mut self, tenant: &str) -> Result<String> {
        match self.round_trip(RequestBody::Stats {
            tenant: tenant.to_string(),
        })?.body {
            ResponseBody::StatsOk { json } => Ok(json),
            ResponseBody::Error { code, msg } => {
                bail!("stats failed: {} ({msg})", code.name())
            }
            other => bail!("unexpected stats response: {other:?}"),
        }
    }

    pub fn ping(&mut self) -> Result<()> {
        match self.round_trip(RequestBody::Ping)?.body {
            ResponseBody::Pong => Ok(()),
            other => bail!("unexpected ping response: {other:?}"),
        }
    }
}
