//! Closed- and open-loop load generation against a serving front door
//! (`unq loadgen`, `benches/serve_load.rs`).
//!
//! * **closed loop** — `clients` connections each running send→wait→
//!   send: throughput self-limits to the server's pace, so latency
//!   numbers describe the server *below* saturation.
//! * **open loop** — requests depart on a fixed schedule regardless of
//!   completions (one writer + one reader thread per connection, ids
//!   matched through a shared map).  Latency is measured from the
//!   *scheduled* departure, so queueing delay from a stalled server is
//!   charged to the server, not silently absorbed by the generator
//!   (the coordinated-omission trap).
//!
//! Queries come from the synthetic query split (split 2) of the
//! configured dataset family — same distribution the recall benches
//! use, cycled through a fixed pool.  Everything is seeded; two runs
//! with one config issue the identical request sequence per worker.

use std::collections::HashMap;
use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::data::synthetic::Generator;
use crate::data::{Dataset, Family};
use crate::util::json::Json;
use crate::util::rng::SplitMix64;

use super::client::Client;
use super::proto::{decode_response, encode_request, read_frame,
                   ErrorCode, FrameError, NetRequest, RequestBody,
                   ResponseBody};

/// Queries cycle through a pool of this many rows.
const QUERY_POOL: usize = 256;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LoadMode {
    Closed,
    /// Fixed aggregate arrival rate, split evenly across clients.
    Open { rate_qps: f64 },
}

impl LoadMode {
    pub fn name(&self) -> &'static str {
        match self {
            LoadMode::Closed => "closed",
            LoadMode::Open { .. } => "open",
        }
    }
}

#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    pub addr: String,
    pub clients: usize,
    pub duration: Duration,
    pub mode: LoadMode,
    /// percentage of requests that are single-row inserts (0–100);
    /// the rest are searches
    pub insert_pct: u32,
    pub k: u32,
    /// descriptor family to draw queries from — fixes the vector
    /// dimensionality, which must match the served index
    pub family: Family,
    pub tenant: String,
    /// optional metadata predicate attached to every generated SEARCH
    /// (inserts are unaffected); `None` = plain pre-predicate frames
    pub filter: Option<crate::index::Filter>,
    pub seed: u64,
    pub connect_retries: usize,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: "127.0.0.1:7009".into(),
            clients: 4,
            duration: Duration::from_secs(5),
            mode: LoadMode::Closed,
            insert_pct: 0,
            k: 10,
            family: Family::SiftLike,
            tenant: String::new(),
            filter: None,
            seed: 42,
            connect_retries: 25,
        }
    }
}

/// Aggregated outcome of one run.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub mode: String,
    pub clients: usize,
    pub wall_secs: f64,
    pub sent: u64,
    pub ok: u64,
    pub errors: u64,
    pub overloaded: u64,
    pub qps: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub p999_us: u64,
    pub max_us: u64,
    pub mean_us: f64,
}

impl LoadReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mode", Json::Str(self.mode.clone())),
            ("clients", Json::Num(self.clients as f64)),
            ("wall_secs", Json::Num(self.wall_secs)),
            ("sent", Json::Num(self.sent as f64)),
            ("ok", Json::Num(self.ok as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("overloaded", Json::Num(self.overloaded as f64)),
            ("qps", Json::Num(self.qps)),
            ("p50_us", Json::Num(self.p50_us as f64)),
            ("p99_us", Json::Num(self.p99_us as f64)),
            ("p999_us", Json::Num(self.p999_us as f64)),
            ("max_us", Json::Num(self.max_us as f64)),
            ("mean_us", Json::Num(self.mean_us)),
        ])
    }

    /// The two summary lines CI greps for (`p50` / `p99`).
    pub fn print(&self) {
        println!(
            "[loadgen] mode {}  clients {}  wall {:.1} s  sent {}  \
             ok {}  overloaded {}  errors {}",
            self.mode, self.clients, self.wall_secs, self.sent,
            self.ok, self.overloaded, self.errors);
        println!(
            "[loadgen] qps {:.1}  p50 {} us  p99 {} us  p999 {} us  \
             max {} us  mean {:.1} us",
            self.qps, self.p50_us, self.p99_us, self.p999_us,
            self.max_us, self.mean_us);
    }
}

#[derive(Default)]
struct WorkerOut {
    sent: u64,
    ok: u64,
    errors: u64,
    overloaded: u64,
    lats: Vec<u64>,
}

/// Tally one response; true iff it should contribute a latency sample.
fn classify(out: &mut WorkerOut, body: &ResponseBody) -> bool {
    match body {
        ResponseBody::Error { code: ErrorCode::Overloaded, .. } => {
            out.overloaded += 1;
            false
        }
        ResponseBody::Error { .. } => {
            out.errors += 1;
            false
        }
        _ => {
            out.ok += 1;
            true
        }
    }
}

/// Exact percentile over a sorted sample (nearest-rank on the rounded
/// index; 0 on an empty sample).
fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Drive one full load run and aggregate the per-worker tallies.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadReport> {
    if cfg.insert_pct > 100 {
        bail!("insert_pct must be 0–100");
    }
    if let LoadMode::Open { rate_qps } = cfg.mode {
        if rate_qps <= 0.0 {
            bail!("open-loop rate must be positive");
        }
    }
    let clients = cfg.clients.max(1);
    let pool = Generator::new(cfg.family, cfg.seed).generate(2, QUERY_POOL);
    let start = Instant::now();
    let outs: Vec<Result<WorkerOut>> = std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(clients);
        for tid in 0..clients {
            let pool = &pool;
            handles.push(s.spawn(move || match cfg.mode {
                LoadMode::Closed => closed_worker(cfg, tid as u64, pool),
                LoadMode::Open { rate_qps } => open_worker(
                    cfg, tid as u64, rate_qps / clients as f64, pool),
            }));
        }
        handles.into_iter()
            .map(|h| h.join().expect("loadgen worker panicked"))
            .collect()
    });
    let wall_secs = start.elapsed().as_secs_f64().max(1e-9);

    let mut agg = WorkerOut::default();
    for out in outs {
        let out = out?;
        agg.sent += out.sent;
        agg.ok += out.ok;
        agg.errors += out.errors;
        agg.overloaded += out.overloaded;
        agg.lats.extend(out.lats);
    }
    agg.lats.sort_unstable();
    let mean_us = if agg.lats.is_empty() {
        0.0
    } else {
        agg.lats.iter().sum::<u64>() as f64 / agg.lats.len() as f64
    };
    Ok(LoadReport {
        mode: cfg.mode.name().to_string(),
        clients,
        wall_secs,
        sent: agg.sent,
        ok: agg.ok,
        errors: agg.errors,
        overloaded: agg.overloaded,
        qps: agg.ok as f64 / wall_secs,
        p50_us: percentile(&agg.lats, 0.50),
        p99_us: percentile(&agg.lats, 0.99),
        p999_us: percentile(&agg.lats, 0.999),
        max_us: agg.lats.last().copied().unwrap_or(0),
        mean_us,
    })
}

fn pick_body(cfg: &LoadgenConfig, rng: &mut SplitMix64, pool: &Dataset)
             -> RequestBody {
    let qi = rng.below(pool.len());
    if cfg.insert_pct > 0 && rng.below(100) < cfg.insert_pct as usize {
        RequestBody::Insert {
            tenant: cfg.tenant.clone(),
            rows: 1,
            dim: pool.dim as u32,
            vectors: pool.row(qi).to_vec(),
        }
    } else {
        RequestBody::Search {
            tenant: cfg.tenant.clone(),
            k: cfg.k,
            query: pool.row(qi).to_vec(),
            filter: cfg.filter,
        }
    }
}

fn closed_worker(cfg: &LoadgenConfig, tid: u64, pool: &Dataset)
                 -> Result<WorkerOut> {
    let mut c = Client::connect_retry(cfg.addr.as_str(),
                                      cfg.connect_retries,
                                      Duration::from_millis(200))
        .with_context(|| format!("worker {tid} connect {}", cfg.addr))?;
    let mut rng = SplitMix64::from_key(&[cfg.seed, tid, 0xC105ED]);
    let mut out = WorkerOut::default();
    let deadline = Instant::now() + cfg.duration;
    while Instant::now() < deadline {
        let body = pick_body(cfg, &mut rng, pool);
        let t0 = Instant::now();
        out.sent += 1;
        let id = match c.send(body) {
            Ok(id) => id,
            Err(_) => {
                out.errors += 1;
                break;
            }
        };
        match c.recv() {
            Ok(Some(resp)) => {
                if resp.id == id && classify(&mut out, &resp.body) {
                    out.lats.push(t0.elapsed().as_micros() as u64);
                }
            }
            Ok(None) | Err(_) => {
                out.errors += 1;
                break;
            }
        }
    }
    Ok(out)
}

fn connect_retry_raw(addr: &str, attempts: usize) -> Result<TcpStream> {
    let mut last = None;
    for _ in 0..attempts.max(1) {
        match TcpStream::connect(addr) {
            Ok(s) => {
                let _ = s.set_nodelay(true);
                return Ok(s);
            }
            Err(e) => last = Some(e),
        }
        std::thread::sleep(Duration::from_millis(200));
    }
    Err(last.expect("at least one attempt"))
        .with_context(|| format!("connect {addr}"))
}

fn open_worker(cfg: &LoadgenConfig, tid: u64, rate: f64, pool: &Dataset)
               -> Result<WorkerOut> {
    let mut w = connect_retry_raw(&cfg.addr, cfg.connect_retries)
        .with_context(|| format!("worker {tid}"))?;
    let read_half = w.try_clone().context("clone stream")?;
    let pending: Arc<Mutex<HashMap<u64, Instant>>> = Arc::default();
    let writer_done = Arc::new(AtomicBool::new(false));

    let reader = {
        let pending = pending.clone();
        let writer_done = writer_done.clone();
        std::thread::spawn(move || {
            let mut out = WorkerOut::default();
            let mut r = BufReader::new(read_half);
            // backstop: never hang past shutdown even if responses
            // stop arriving (FrameError::Io covers the timeout).
            // Mid-run, a timeout is just an overloaded server pausing
            // >2 s between responses — exactly the regime open-loop
            // measures — so it only turns terminal once the writer is
            // done and this read is the post-shutdown drain.
            let _ = r.get_ref()
                .set_read_timeout(Some(Duration::from_secs(2)));
            loop {
                match read_frame(&mut r, 1 << 24) {
                    Ok(Some(payload)) => {
                        let Ok(resp) = decode_response(&payload) else {
                            out.errors += 1;
                            break;
                        };
                        let sched = pending.lock()
                            .expect("pending map poisoned")
                            .remove(&resp.id);
                        if classify(&mut out, &resp.body) {
                            if let Some(s) = sched {
                                out.lats.push(
                                    s.elapsed().as_micros() as u64);
                            }
                        }
                        if writer_done.load(Ordering::SeqCst)
                            && pending.lock()
                                .expect("pending map poisoned")
                                .is_empty()
                        {
                            break;
                        }
                    }
                    Ok(None) => break,
                    Err(FrameError::Io(e))
                        if matches!(e.kind(),
                                    std::io::ErrorKind::WouldBlock
                                    | std::io::ErrorKind::TimedOut)
                            && !writer_done.load(Ordering::SeqCst) =>
                    {
                        continue; // in-run lull, keep listening
                    }
                    Err(_) => break, // torn stream or drain backstop
                }
            }
            out
        })
    };

    let mut rng = SplitMix64::from_key(&[cfg.seed, tid, 0x09E7]);
    let interval = Duration::from_secs_f64(1.0 / rate);
    let start = Instant::now();
    let deadline = start + cfg.duration;
    let mut next = start;
    let mut next_id = 1u64;
    let mut sent = 0u64;
    loop {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        if next > now {
            std::thread::sleep(next - now);
        }
        let body = pick_body(cfg, &mut rng, pool);
        let frame = encode_request(&NetRequest { id: next_id, body });
        pending.lock().expect("pending map poisoned")
            .insert(next_id, next);
        if w.write_all(&frame).is_err() {
            pending.lock().expect("pending map poisoned")
                .remove(&next_id);
            break;
        }
        sent += 1;
        next_id += 1;
        next += interval;
    }
    writer_done.store(true, Ordering::SeqCst);
    let mut out = reader.join().expect("open-loop reader panicked");
    out.sent = sent;
    // requests the server never answered within the drain window
    out.errors += pending.lock().expect("pending map poisoned")
        .len() as u64;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    // socket-level runs are exercised by benches/serve_load.rs and the
    // CI smoke; these pin the pure aggregation math

    #[test]
    fn percentiles_are_exact_on_sorted_samples() {
        let xs: Vec<u64> = (1..=1000).collect();
        assert_eq!(percentile(&xs, 0.0), 1);
        assert_eq!(percentile(&xs, 0.5), 500);
        assert_eq!(percentile(&xs, 0.99), 990);
        assert_eq!(percentile(&xs, 1.0), 1000);
        assert_eq!(percentile(&[], 0.5), 0);
        assert_eq!(percentile(&[7], 0.999), 7);
    }

    #[test]
    fn classify_buckets_by_error_code() {
        let mut out = WorkerOut::default();
        assert!(classify(&mut out, &ResponseBody::SearchOk {
            neighbors: vec![1],
        }));
        assert!(!classify(&mut out, &ResponseBody::Error {
            code: ErrorCode::Overloaded, msg: String::new(),
        }));
        assert!(!classify(&mut out, &ResponseBody::Error {
            code: ErrorCode::QuotaExceeded, msg: String::new(),
        }));
        assert_eq!((out.ok, out.overloaded, out.errors), (1, 1, 1));
    }

    #[test]
    fn report_json_carries_the_bench_fields() {
        let r = LoadReport {
            mode: "closed".into(), clients: 4, wall_secs: 5.0,
            sent: 100, ok: 98, errors: 0, overloaded: 2, qps: 19.6,
            p50_us: 800, p99_us: 2200, p999_us: 4000, max_us: 5000,
            mean_us: 900.5,
        };
        let j = r.to_json();
        for key in ["mode", "clients", "wall_secs", "sent", "ok",
                    "errors", "overloaded", "qps", "p50_us", "p99_us",
                    "p999_us", "max_us", "mean_us"] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        assert_eq!(j.get("p999_us").and_then(Json::as_f64), Some(4000.0));
    }

    #[test]
    fn seeded_request_streams_are_reproducible() {
        let cfg = LoadgenConfig { insert_pct: 30, ..Default::default() };
        let pool = Generator::new(cfg.family, cfg.seed)
            .generate(2, QUERY_POOL);
        let mut a = SplitMix64::from_key(&[cfg.seed, 3, 0xC105ED]);
        let mut b = SplitMix64::from_key(&[cfg.seed, 3, 0xC105ED]);
        for _ in 0..50 {
            assert_eq!(pick_body(&cfg, &mut a, &pool),
                       pick_body(&cfg, &mut b, &pool));
        }
    }
}
