//! The TCP reactor: acceptors, per-connection threads, and admission
//! control in front of the coordinator (rust/DESIGN.md §12).
//!
//! Thread layout per server: `io_threads` acceptors share one listener
//! via `try_clone`.  Each accepted connection gets a small private
//! thread set:
//!
//! ```text
//! reader ──admission──▶ coordinator ingress (try_submit)
//!    │                        │ responses (per-type bounded channels)
//!    │ inline: ping/stats     ▼
//!    │                   search/insert/delete pumps ─▶ writer ─▶ socket
//!    └── frame/decode errors ───────────────────────────▲
//! ```
//!
//! The pumps complete requests out of order — whichever coordinator
//! batch flushes first answers first, matched by request id.  Bounded
//! everywhere: the per-type response channels hold `max_inflight`
//! entries and the reader admits at most `max_inflight` outstanding
//! requests, so a coordinator response send can never block.  A slow
//! reader stalls the writer instead; the write timeout then shuts the
//! socket down and the whole thread set unwinds through channel
//! disconnects.  Overload is always a typed [`ErrorCode::Overloaded`]
//! reply, never a hang.

use std::collections::HashMap;
use std::io::{BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{self, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::{NetConfig, TenantQuota};
use crate::coordinator::pipeline::Server;
use crate::coordinator::{DeleteRequest, DeleteResponse, InsertRequest,
                         InsertResponse, Request, SearchRequest,
                         SearchResponse, SubmitError};
use crate::obs;
use crate::util::json::Json;

use super::proto::{decode_request, encode_response, peek_request_id,
                   read_frame, ErrorCode, FrameError, NetResponse,
                   ProtoError, RequestBody, ResponseBody, FRAME_HEADER,
                   MAX_SEARCH_K};

/// Per-tenant accounting: a QPS token bucket plus a lifetime insert
/// byte budget (0 = unlimited for either knob).
struct TenantEntry {
    max_qps: u64,
    max_insert_bytes: u64,
    /// token bucket level; capacity = `max_qps`, refill `max_qps`/s
    tokens: f64,
    last: Instant,
    inserted_bytes: u64,
    requests: u64,
    rejected: u64,
}

impl TenantEntry {
    fn new(q: &TenantQuota, now: Instant) -> TenantEntry {
        TenantEntry {
            max_qps: q.max_qps,
            max_insert_bytes: q.max_insert_bytes,
            tokens: q.max_qps as f64,
            last: now,
            inserted_bytes: 0,
            requests: 0,
            rejected: 0,
        }
    }
}

/// The admission table.  An empty config table means open admission:
/// any tenant name is served unlimited (entries materialize lazily for
/// accounting).  Configuring *any* tenant closes the table — unknown
/// names get [`ErrorCode::UnknownTenant`].
struct Tenants {
    open: bool,
    map: Mutex<HashMap<String, TenantEntry>>,
}

/// The empty wire tenant string maps to this name.
const DEFAULT_TENANT: &str = "default";

fn canon(name: &str) -> &str {
    if name.is_empty() { DEFAULT_TENANT } else { name }
}

impl Tenants {
    fn new(quotas: &[TenantQuota], now: Instant) -> Tenants {
        let mut map = HashMap::new();
        for q in quotas {
            map.insert(q.name.clone(), TenantEntry::new(q, now));
        }
        Tenants { open: quotas.is_empty(), map: Mutex::new(map) }
    }

    /// Admit one request (charging one QPS token), plus `insert_bytes`
    /// against the tenant's lifetime byte budget when nonzero.  The
    /// token is consumed even if the coordinator later sheds the
    /// request — admission is the outer gate.
    fn admit(&self, name: &str, insert_bytes: u64, now: Instant)
             -> Result<(), ErrorCode> {
        let name = canon(name);
        let mut map = self.map.lock().expect("tenant table poisoned");
        let e = match map.entry(name.to_string()) {
            std::collections::hash_map::Entry::Occupied(o) => o.into_mut(),
            std::collections::hash_map::Entry::Vacant(v) => {
                if !self.open {
                    return Err(ErrorCode::UnknownTenant);
                }
                v.insert(TenantEntry::new(&TenantQuota::unlimited(name),
                                          now))
            }
        };
        if e.max_qps > 0 {
            let dt = now.duration_since(e.last).as_secs_f64();
            e.tokens = (e.tokens + dt * e.max_qps as f64)
                .min(e.max_qps as f64);
            e.last = now;
            if e.tokens < 1.0 {
                e.rejected += 1;
                return Err(ErrorCode::QuotaExceeded);
            }
            e.tokens -= 1.0;
        }
        if insert_bytes > 0
            && e.max_insert_bytes > 0
            && e.inserted_bytes + insert_bytes > e.max_insert_bytes
        {
            e.rejected += 1;
            return Err(ErrorCode::QuotaExceeded);
        }
        e.inserted_bytes += insert_bytes;
        e.requests += 1;
        Ok(())
    }

    /// Accounting snapshot for the STATS op.
    fn stats_json(&self, name: &str) -> Result<String, ErrorCode> {
        let name = canon(name);
        let map = self.map.lock().expect("tenant table poisoned");
        let Some(e) = map.get(name) else {
            if self.open {
                // known-by-construction but never seen: all zeros
                return Ok(render_stats(name, 0, 0, 0, 0, 0));
            }
            return Err(ErrorCode::UnknownTenant);
        };
        Ok(render_stats(name, e.requests, e.rejected, e.inserted_bytes,
                        e.max_qps, e.max_insert_bytes))
    }
}

fn render_stats(name: &str, requests: u64, rejected: u64,
                inserted_bytes: u64, max_qps: u64,
                max_insert_bytes: u64) -> String {
    Json::obj(vec![
        ("tenant", Json::Str(name.to_string())),
        ("requests", Json::Num(requests as f64)),
        ("rejected", Json::Num(rejected as f64)),
        ("inserted_bytes", Json::Num(inserted_bytes as f64)),
        ("max_qps", Json::Num(max_qps as f64)),
        ("max_insert_bytes", Json::Num(max_insert_bytes as f64)),
    ])
    .render()
}

/// State shared by every acceptor and connection thread.
struct Shared {
    inner: Arc<Server>,
    cfg: NetConfig,
    dim: usize,
    tenants: Tenants,
    open_conns: AtomicUsize,
    stop: AtomicBool,
}

/// A running TCP front door over a coordinator [`Server`].
pub struct NetServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    acceptors: Vec<std::thread::JoinHandle<()>>,
}

impl NetServer {
    /// Bind `cfg.listen` and start the acceptor pool.  `cfg.listen`
    /// may use port 0 to let the OS pick (tests); the bound address is
    /// [`Self::local_addr`].
    pub fn start(inner: Arc<Server>, cfg: NetConfig)
                 -> std::io::Result<NetServer> {
        let listener = TcpListener::bind(&cfg.listen)?;
        let addr = listener.local_addr()?;
        let dim = inner.dim();
        let tenants = Tenants::new(&cfg.tenants, Instant::now());
        let io_threads = if cfg.io_threads == 0 {
            std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1)
        } else {
            cfg.io_threads
        };
        let shared = Arc::new(Shared {
            inner,
            cfg,
            dim,
            tenants,
            open_conns: AtomicUsize::new(0),
            stop: AtomicBool::new(false),
        });
        let mut acceptors = Vec::with_capacity(io_threads);
        for i in 0..io_threads {
            let l = listener.try_clone()?;
            let sh = shared.clone();
            acceptors.push(
                std::thread::Builder::new()
                    .name(format!("unq-accept-{i}"))
                    .spawn(move || accept_loop(l, sh))
                    .expect("spawn acceptor"),
            );
        }
        Ok(NetServer { addr, shared, acceptors })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the acceptor pool.  Live connections
    /// finish on their own threads and unwind when their clients
    /// disconnect (or the process exits); the coordinator behind the
    /// front door is shut down separately by its owner.
    pub fn shutdown(mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        // wake each blocked accept() with a throwaway connection
        for _ in 0..self.acceptors.len() {
            let _ = TcpStream::connect(self.addr);
        }
        for t in self.acceptors.drain(..) {
            let _ = t.join();
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    loop {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(_) => {
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                // accept() can fail persistently (e.g. EMFILE while the
                // connection cap is under pressure); back off instead of
                // spinning the acceptor at 100% CPU
                std::thread::sleep(Duration::from_millis(50));
                continue;
            }
        };
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        // connection cap: over the limit the peer gets a typed
        // OVERLOADED (request id 0) instead of a silent RST or a queue
        if shared.open_conns.fetch_add(1, Ordering::SeqCst)
            >= shared.cfg.max_conns
        {
            shared.open_conns.fetch_sub(1, Ordering::SeqCst);
            obs::global().net_overloaded.inc();
            reply_and_close(stream, &shared.cfg, ErrorCode::Overloaded,
                            "connection limit reached");
            continue;
        }
        let sh = shared.clone();
        let spawned = std::thread::Builder::new()
            .name("unq-conn".into())
            .spawn(move || {
                handle_conn(stream, &sh);
                sh.open_conns.fetch_sub(1, Ordering::SeqCst);
            });
        if spawned.is_err() {
            shared.open_conns.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

/// Best-effort single error frame on a connection we refuse to serve.
fn reply_and_close(mut stream: TcpStream, cfg: &NetConfig,
                   code: ErrorCode, msg: &str) {
    if cfg.write_timeout_ms > 0 {
        let _ = stream.set_write_timeout(
            Some(Duration::from_millis(cfg.write_timeout_ms)));
    }
    let frame = encode_response(&NetResponse {
        id: 0,
        body: ResponseBody::Error { code, msg: msg.to_string() },
    });
    let _ = stream.write_all(&frame);
    let _ = stream.shutdown(Shutdown::Both);
}

/// Spawn the writer thread: single owner of the socket's write half.
/// Exits when every frame sender drops (after draining buffered
/// frames) or on write error/timeout, shutting the socket down so the
/// reader and pumps unwind too.
fn spawn_writer(stream: TcpStream, cfg: &NetConfig)
                -> SyncSender<Vec<u8>> {
    let (tx, rx) = mpsc::sync_channel::<Vec<u8>>(cfg.max_inflight + 4);
    if cfg.write_timeout_ms > 0 {
        let _ = stream.set_write_timeout(
            Some(Duration::from_millis(cfg.write_timeout_ms)));
    }
    let mut stream = stream;
    std::thread::Builder::new()
        .name("unq-conn-writer".into())
        .spawn(move || {
            while let Ok(frame) = rx.recv() {
                if stream.write_all(&frame).is_err() {
                    // slow or vanished reader: sever both halves so
                    // the reader thread gets EOF and the connection's
                    // thread set unwinds instead of queueing forever
                    let _ = stream.shutdown(Shutdown::Both);
                    return;
                }
                obs::global().net_bytes_out.add(frame.len() as u64);
            }
        })
        .expect("spawn connection writer");
    tx
}

/// Spawn one response pump: coordinator responses of one type flow in,
/// encoded frames flow out to the writer.
fn spawn_pump<T, F>(name: &'static str, rx: mpsc::Receiver<T>,
                    wtx: SyncSender<Vec<u8>>,
                    inflight: Arc<AtomicUsize>, to_resp: F)
where
    T: Send + 'static,
    F: Fn(T) -> (u64, NetResponse) + Send + 'static,
{
    std::thread::Builder::new()
        .name(name.into())
        .spawn(move || {
            while let Ok(item) = rx.recv() {
                let (latency_us, resp) = to_resp(item);
                inflight.fetch_sub(1, Ordering::SeqCst);
                obs::global().net_request_us.record(latency_us);
                obs::global().net_responses.inc();
                if wtx.send(encode_response(&resp)).is_err() {
                    return; // writer gone: connection is unwinding
                }
            }
        })
        .expect("spawn response pump");
}

fn error_frame(id: u64, code: ErrorCode, msg: &str) -> Vec<u8> {
    obs::global().net_errors.inc();
    encode_response(&NetResponse {
        id,
        body: ResponseBody::Error { code, msg: msg.to_string() },
    })
}

fn handle_conn(stream: TcpStream, shared: &Shared) {
    let o = obs::global();
    o.net_connections.inc();
    o.net_conns_open.inc();
    let _ = stream.set_nodelay(true);
    let Ok(write_half) = stream.try_clone() else {
        o.net_conns_open.dec();
        return;
    };
    let cfg = &shared.cfg;
    let wtx = spawn_writer(write_half, cfg);
    let inflight = Arc::new(AtomicUsize::new(0));

    // per-type response channels, each as deep as the whole in-flight
    // window so a coordinator response send can never block on us
    let (search_tx, search_rx) =
        mpsc::sync_channel::<SearchResponse>(cfg.max_inflight);
    let (insert_tx, insert_rx) =
        mpsc::sync_channel::<InsertResponse>(cfg.max_inflight);
    let (delete_tx, delete_rx) =
        mpsc::sync_channel::<DeleteResponse>(cfg.max_inflight);
    spawn_pump("unq-pump-search", search_rx, wtx.clone(),
               inflight.clone(), |r: SearchResponse| {
                   (r.latency_us, NetResponse {
                       id: r.id,
                       body: ResponseBody::SearchOk { neighbors: r.neighbors },
                   })
               });
    spawn_pump("unq-pump-insert", insert_rx, wtx.clone(),
               inflight.clone(), |r: InsertResponse| {
                   (r.latency_us, NetResponse {
                       id: r.id,
                       body: ResponseBody::InsertOk {
                           accepted: r.accepted, ids: r.ids,
                       },
                   })
               });
    spawn_pump("unq-pump-delete", delete_rx, wtx.clone(),
               inflight.clone(), |r: DeleteResponse| {
                   (r.latency_us, NetResponse {
                       id: r.id,
                       body: ResponseBody::DeleteOk {
                           accepted: r.accepted,
                           removed: r.removed as u64,
                       },
                   })
               });

    let mut reader = BufReader::new(stream);
    loop {
        let payload = match read_frame(&mut reader, cfg.max_frame) {
            Ok(Some(p)) => p,
            Ok(None) => break, // clean close at a frame boundary
            Err(FrameError::TooLarge(n)) => {
                // the oversized payload is still unread on the wire, so
                // the stream cannot be resynchronized: reply and close
                o.net_frame_errors.inc();
                let _ = wtx.send(error_frame(
                    0, ErrorCode::FrameTooLarge,
                    &format!("{n} byte payload exceeds max_frame \
                              {}", cfg.max_frame)));
                break;
            }
            Err(FrameError::BadCrc) => {
                o.net_frame_errors.inc();
                let _ = wtx.send(error_frame(
                    0, ErrorCode::BadRequest, "frame crc mismatch"));
                break;
            }
            Err(FrameError::Torn) | Err(FrameError::Io(_)) => {
                o.net_frame_errors.inc();
                break;
            }
        };
        o.net_bytes_in.add((FRAME_HEADER + payload.len()) as u64);
        o.net_requests.inc();

        let req = match decode_request(&payload) {
            Ok(r) => r,
            Err(e) => {
                // well-framed but unparseable: typed reply, connection
                // stays usable (the id is recoverable past the prelude)
                let id = peek_request_id(&payload);
                let code = match e {
                    ProtoError::BadVersion(_) => ErrorCode::BadVersion,
                    _ => ErrorCode::BadRequest,
                };
                if wtx.send(error_frame(id, code, &e.to_string()))
                    .is_err()
                {
                    break;
                }
                continue;
            }
        };

        let verdict = dispatch(shared, &wtx, &inflight, req,
                               &search_tx, &insert_tx, &delete_tx);
        match verdict {
            ConnVerdict::Continue => {}
            ConnVerdict::Close => break,
        }
    }
    // dropping wtx + pump senders unwinds the writer and pumps once any
    // in-flight coordinator responses have been delivered
    o.net_conns_open.dec();
}

enum ConnVerdict {
    Continue,
    Close,
}

/// Admit and route one decoded request.  Ping and stats are answered
/// inline (no coordinator round-trip, no admission charge for ping);
/// search/insert/delete go through tenant quotas, the in-flight
/// window, and the coordinator's own bounded ingress — each gate
/// failing as a typed error reply.
#[allow(clippy::too_many_arguments)]
fn dispatch(shared: &Shared, wtx: &SyncSender<Vec<u8>>,
            inflight: &Arc<AtomicUsize>,
            req: super::proto::NetRequest,
            search_tx: &SyncSender<SearchResponse>,
            insert_tx: &SyncSender<InsertResponse>,
            delete_tx: &SyncSender<DeleteResponse>) -> ConnVerdict {
    let o = obs::global();
    let cfg = &shared.cfg;
    let id = req.id;

    let send = |frame: Vec<u8>| -> ConnVerdict {
        if wtx.send(frame).is_err() {
            ConnVerdict::Close
        } else {
            ConnVerdict::Continue
        }
    };
    let reject = |code: ErrorCode, msg: &str| -> ConnVerdict {
        match code {
            ErrorCode::Overloaded => o.net_overloaded.inc(),
            ErrorCode::QuotaExceeded => o.net_quota_rejected.inc(),
            _ => {}
        }
        send(error_frame(id, code, msg))
    };

    // inline ops first
    match &req.body {
        RequestBody::Ping => {
            o.net_responses.inc();
            return send(encode_response(&NetResponse {
                id, body: ResponseBody::Pong,
            }));
        }
        RequestBody::Stats { tenant } => {
            return match shared.tenants.stats_json(tenant) {
                Ok(json) => {
                    o.net_responses.inc();
                    send(encode_response(&NetResponse {
                        id, body: ResponseBody::StatsOk { json },
                    }))
                }
                Err(code) => reject(code, "tenant not configured"),
            };
        }
        _ => {}
    }

    // shape gates before spending a quota token
    let (tenant, insert_bytes) = match &req.body {
        RequestBody::Search { tenant, k, query, .. } => {
            if query.len() != shared.dim {
                return reject(ErrorCode::BadRequest,
                              &format!("query dim {} (index dim {})",
                                       query.len(), shared.dim));
            }
            // k sizes per-query top-k heaps downstream — gate it here
            // so a hostile k can never reach an allocation
            if *k == 0 || *k > MAX_SEARCH_K {
                return reject(ErrorCode::BadRequest,
                              &format!("k {k} outside [1, {MAX_SEARCH_K}]"));
            }
            (tenant.clone(), 0u64)
        }
        RequestBody::Insert { tenant, rows, dim, vectors } => {
            if *dim as usize != shared.dim
                || (*rows as usize) * (*dim as usize) != vectors.len()
            {
                return reject(ErrorCode::BadRequest,
                              &format!("insert shape {rows}×{dim} with \
                                        {} values (index dim {})",
                                       vectors.len(), shared.dim));
            }
            (tenant.clone(), (vectors.len() * 4) as u64)
        }
        RequestBody::Delete { tenant, .. } => (tenant.clone(), 0u64),
        _ => unreachable!("inline ops handled above"),
    };

    if let Err(code) = shared.tenants.admit(&tenant, insert_bytes,
                                            Instant::now()) {
        let msg = match code {
            ErrorCode::UnknownTenant => "tenant not configured",
            _ => "tenant quota exhausted",
        };
        return reject(code, msg);
    }

    // the in-flight window: bounds this connection's claim on the
    // coordinator AND guarantees the response channels never fill
    if inflight.load(Ordering::SeqCst) >= cfg.max_inflight {
        return reject(ErrorCode::Overloaded,
                      "in-flight window full; drain responses");
    }
    inflight.fetch_add(1, Ordering::SeqCst);

    let request = match req.body {
        RequestBody::Search { k, query, filter, .. } => {
            Request::Search(SearchRequest {
                id,
                query,
                k: k as usize,
                filter,
                submitted: Instant::now(),
                resp: search_tx.clone(),
            })
        }
        RequestBody::Insert { rows, vectors, .. } => {
            Request::Insert(InsertRequest {
                id,
                vectors,
                rows: rows as usize,
                submitted: Instant::now(),
                resp: insert_tx.clone(),
            })
        }
        RequestBody::Delete { ids, .. } => {
            Request::Delete(DeleteRequest {
                id,
                keys: ids,
                submitted: Instant::now(),
                resp: delete_tx.clone(),
            })
        }
        _ => unreachable!("inline ops handled above"),
    };

    match shared.inner.try_submit(request) {
        Ok(()) => ConnVerdict::Continue,
        Err(SubmitError::Overloaded) => {
            inflight.fetch_sub(1, Ordering::SeqCst);
            reject(ErrorCode::Overloaded, "coordinator queue full")
        }
        Err(SubmitError::Closed) => {
            inflight.fetch_sub(1, Ordering::SeqCst);
            reject(ErrorCode::Internal, "server shutting down")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // socket-level behavior is covered end to end in tests/net.rs;
    // these pin the pure admission arithmetic deterministically by
    // driving `admit` with explicit clocks

    fn quota(name: &str, qps: u64, bytes: u64) -> TenantQuota {
        TenantQuota {
            name: name.into(), max_qps: qps, max_insert_bytes: bytes,
        }
    }

    #[test]
    fn token_bucket_charges_and_refills() {
        let t0 = Instant::now();
        let t = Tenants::new(&[quota("a", 2, 0)], t0);
        // full bucket of 2, no refill at the same instant
        assert!(t.admit("a", 0, t0).is_ok());
        assert!(t.admit("a", 0, t0).is_ok());
        assert_eq!(t.admit("a", 0, t0), Err(ErrorCode::QuotaExceeded));
        // one second refills the bucket to its 2-token cap, not beyond
        let t1 = t0 + Duration::from_secs(5);
        assert!(t.admit("a", 0, t1).is_ok());
        assert!(t.admit("a", 0, t1).is_ok());
        assert_eq!(t.admit("a", 0, t1), Err(ErrorCode::QuotaExceeded));
        // half a second refills one token
        let t2 = t1 + Duration::from_millis(500);
        assert!(t.admit("a", 0, t2).is_ok());
        assert_eq!(t.admit("a", 0, t2), Err(ErrorCode::QuotaExceeded));
    }

    #[test]
    fn byte_budget_is_lifetime_and_exact() {
        let t0 = Instant::now();
        let t = Tenants::new(&[quota("a", 0, 100)], t0);
        assert!(t.admit("a", 60, t0).is_ok());
        assert!(t.admit("a", 40, t0).is_ok()); // exactly at the budget
        assert_eq!(t.admit("a", 1, t0), Err(ErrorCode::QuotaExceeded));
        // zero-byte ops (search/delete) still pass
        assert!(t.admit("a", 0, t0).is_ok());
    }

    #[test]
    fn closed_table_rejects_unknown_and_open_table_admits_all() {
        let t0 = Instant::now();
        let closed = Tenants::new(&[quota("a", 0, 0)], t0);
        assert_eq!(closed.admit("nobody", 0, t0),
                   Err(ErrorCode::UnknownTenant));
        assert_eq!(closed.stats_json("nobody"),
                   Err(ErrorCode::UnknownTenant));
        // the implicit default dies with the first configured tenant
        assert_eq!(closed.admit("", 0, t0),
                   Err(ErrorCode::UnknownTenant));
        let open = Tenants::new(&[], t0);
        assert!(open.admit("anyone", 0, t0).is_ok());
        assert!(open.admit("", 0, t0).is_ok()); // → "default"
        let js = open.stats_json("").unwrap();
        assert!(js.contains("\"tenant\": \"default\"")
                    || js.contains("\"tenant\":\"default\""),
                "stats = {js}");
    }

    #[test]
    fn stats_reports_accounting() {
        let t0 = Instant::now();
        let t = Tenants::new(&[quota("a", 1, 50)], t0);
        assert!(t.admit("a", 0, t0).is_ok());
        assert_eq!(t.admit("a", 0, t0), Err(ErrorCode::QuotaExceeded));
        let js = t.stats_json("a").unwrap();
        let parsed = Json::parse(&js).unwrap();
        assert_eq!(parsed.get("requests").and_then(Json::as_f64), Some(1.0));
        assert_eq!(parsed.get("rejected").and_then(Json::as_f64), Some(1.0));
        assert_eq!(parsed.get("max_qps").and_then(Json::as_f64), Some(1.0));
    }
}
