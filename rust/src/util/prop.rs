//! Tiny randomized property-testing loop (proptest is unavailable
//! offline).
//!
//! `forall(seed, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and asserts `prop` on each; on failure it panics with the case index
//! and seed so the exact failing input can be replayed deterministically.
//! No shrinking — inputs are kept small by construction instead.

use super::rng::SplitMix64;

/// Run a property over `cases` randomly generated inputs.
pub fn forall<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut SplitMix64) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    for case in 0..cases {
        let mut rng = SplitMix64::from_key(&[seed, case as u64]);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property failed at case {case} (seed {seed})\ninput: {input:#?}"
            );
        }
    }
}

/// Like [`forall`] but the property returns `Result` with a reason.
pub fn forall_ok<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut SplitMix64) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let mut rng = SplitMix64::from_key(&[seed, case as u64]);
        let input = gen(&mut rng);
        if let Err(reason) = prop(&input) {
            panic!(
                "property failed at case {case} (seed {seed}): {reason}\ninput: {input:#?}"
            );
        }
    }
}

/// Generate a random f32 vector with entries in [-scale, scale].
pub fn vec_f32(rng: &mut SplitMix64, len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|_| (rng.next_f32() * 2.0 - 1.0) * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(1, 50, |r| r.below(100), |_| {
            count += 1;
            true
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_input() {
        forall(2, 50, |r| r.below(10), |&x| x < 5);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        forall(3, 10, |r| r.below(1000), |&x| {
            a.push(x);
            true
        });
        forall(3, 10, |r| r.below(1000), |&x| {
            b.push(x);
            true
        });
        assert_eq!(a, b);
    }

    #[test]
    fn vec_f32_in_range() {
        let mut r = SplitMix64::new(4);
        let v = vec_f32(&mut r, 100, 2.5);
        assert_eq!(v.len(), 100);
        assert!(v.iter().all(|x| x.abs() <= 2.5));
    }
}
