//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! The `rust/benches/*` targets are `harness = false` binaries built on
//! this module: warmup, fixed-duration sampling, and a report line with
//! median / mean / p95 and derived throughput.  Deliberately simple —
//! single-threaded timing on a quiet box — but honest about variance.

use std::time::{Duration, Instant};

/// One benchmark's collected samples (seconds per iteration).
pub struct Samples {
    pub name: String,
    pub secs: Vec<f64>,
    /// work items per iteration (for throughput reporting)
    pub items_per_iter: u64,
}

impl Samples {
    pub fn median(&self) -> f64 {
        percentile(&self.secs, 50.0)
    }

    pub fn mean(&self) -> f64 {
        self.secs.iter().sum::<f64>() / self.secs.len().max(1) as f64
    }

    pub fn p95(&self) -> f64 {
        percentile(&self.secs, 95.0)
    }

    /// Render a criterion-style report line.
    pub fn report(&self) -> String {
        let med = self.median();
        let mut line = format!(
            "{:<44} {:>12}  mean {:>12}  p95 {:>12}  ({} samples)",
            self.name,
            fmt_time(med),
            fmt_time(self.mean()),
            fmt_time(self.p95()),
            self.secs.len()
        );
        if self.items_per_iter > 1 && med > 0.0 {
            line.push_str(&format!(
                "  [{:.2} Melem/s, {} per elem]",
                self.items_per_iter as f64 / med / 1e6,
                fmt_time(med / self.items_per_iter as f64)
            ));
        }
        line
    }
}

fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
    s[idx.min(s.len() - 1)]
}

pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

/// Bench runner with a time budget per benchmark.
pub struct Bench {
    warmup: Duration,
    measure: Duration,
    max_samples: usize,
    results: Vec<Samples>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench::new(Duration::from_millis(300), Duration::from_secs(2), 200)
    }
}

impl Bench {
    pub fn new(warmup: Duration, measure: Duration, max_samples: usize) -> Self {
        Bench { warmup, measure, max_samples, results: Vec::new() }
    }

    /// Quick preset for long-running end-to-end benches.
    pub fn e2e() -> Self {
        Bench::new(Duration::ZERO, Duration::from_secs(1), 5)
    }

    /// Time `f`, which performs `items` units of work per call.
    /// The closure's return value is black-boxed to keep the work alive.
    pub fn run<T>(&mut self, name: &str, items: u64, mut f: impl FnMut() -> T) {
        // warmup
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            black_box(f());
        }
        // measure
        let mut secs = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure && secs.len() < self.max_samples {
            let t0 = Instant::now();
            black_box(f());
            secs.push(t0.elapsed().as_secs_f64());
        }
        if secs.is_empty() {
            // always record at least one sample
            let t0 = Instant::now();
            black_box(f());
            secs.push(t0.elapsed().as_secs_f64());
        }
        let s = Samples { name: name.to_string(), secs, items_per_iter: items };
        println!("{}", s.report());
        self.results.push(s);
    }

    pub fn results(&self) -> &[Samples] {
        &self.results
    }
}

/// Opaque value sink (stable `std::hint::black_box`).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples_and_reports() {
        let mut b = Bench::new(Duration::ZERO, Duration::from_millis(50), 20);
        let mut acc = 0u64;
        b.run("spin", 100, || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert_eq!(b.results().len(), 1);
        let s = &b.results()[0];
        assert!(!s.secs.is_empty());
        assert!(s.median() > 0.0);
        assert!(s.report().contains("spin"));
    }

    #[test]
    fn percentile_ordering() {
        let xs = vec![1.0, 2.0, 3.0, 4.0, 100.0];
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert!(percentile(&xs, 95.0) >= 4.0);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.5e-9).contains("ns"));
        assert!(fmt_time(2.5e-6).contains("µs"));
        assert!(fmt_time(2.5e-3).contains("ms"));
        assert!(fmt_time(2.5).contains(" s"));
    }
}
