//! Deterministic PRNG substrate (no external `rand` available offline).
//!
//! * [`SplitMix64`] — the classic 64-bit mixer; tiny state, passes BigCrush
//!   on its output function, and is *counter-friendly*: seeding a fresh
//!   instance per (seed, stream, row) key gives independent streams, which
//!   the dataset generators rely on for prefix-stable output.
//! * Gaussian variates via Box–Muller, exponential via inverse CDF.

/// SplitMix64 PRNG (Steele, Lea, Flood 2014).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
    /// cached second Box–Muller variate
    spare_normal: Option<f32>,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed, spare_normal: None }
    }

    /// Key several values into one independent stream
    /// (seed/stream/row-keyed generators all funnel through this).
    pub fn from_key(parts: &[u64]) -> Self {
        let mut h = 0x9E3779B97F4A7C15u64;
        for &p in parts {
            h ^= p.wrapping_add(0x9E3779B97F4A7C15)
                .wrapping_add(h << 6)
                .wrapping_add(h >> 2);
            // run one mix round so each part diffuses fully
            h = mix(h);
        }
        SplitMix64::new(h)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        mix(self.state)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        // top 24 bits → exactly representable in f32
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        // multiply-shift bounded sampling (Lemire); bias < 2^-32 for our sizes
        ((self.next_u64() >> 32).wrapping_mul(bound as u64) >> 32) as usize
    }

    /// Standard normal (Box–Muller with caching).
    pub fn normal(&mut self) -> f32 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u1 = self.next_f32();
            if u1 <= f32::EPSILON {
                continue;
            }
            let u2 = self.next_f32();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f32::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Exponential with rate 1 (inverse CDF).
    #[inline]
    pub fn exponential(&mut self) -> f32 {
        let u = 1.0 - self.next_f32(); // (0, 1]
        -u.ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (floyd's algorithm for
    /// k ≪ n, fallback to shuffle otherwise).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        if k * 4 > n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in n - k..n {
            let t = self.below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[inline]
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn keyed_streams_differ() {
        let a = SplitMix64::from_key(&[1, 2, 3]).clone().next_u64();
        let b = SplitMix64::from_key(&[1, 2, 4]).clone().next_u64();
        let c = SplitMix64::from_key(&[1, 3, 3]).clone().next_u64();
        assert_ne!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = SplitMix64::new(7);
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = SplitMix64::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(11);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let v = r.normal() as f64;
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = SplitMix64::new(13);
        let n = 50_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let v = r.exponential();
            assert!(v >= 0.0);
            sum += v as f64;
        }
        assert!((sum / n as f64 - 1.0).abs() < 0.05);
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = SplitMix64::new(17);
        let s = r.sample_indices(1000, 50);
        assert_eq!(s.len(), 50);
        let mut t = s.clone();
        t.sort();
        t.dedup();
        assert_eq!(t.len(), 50);
        let s2 = r.sample_indices(10, 10);
        assert_eq!(s2.len(), 10);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(19);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
