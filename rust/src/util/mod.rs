//! In-crate substrates for facilities unavailable on this offline testbed
//! (no serde / rand / tokio / criterion / proptest in the vendored set):
//!
//! * [`json`] — minimal JSON parser/renderer (manifests, configs, reports)
//! * [`rng`] — SplitMix64 PRNG with normal/exponential variates
//! * [`bench`] — micro-benchmark harness used by `rust/benches/*`
//! * [`prop`] — tiny randomized property-testing loop
//! * [`tempdir`] — scoped temp directories for tests

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// A temp directory removed on drop (tests + benches only).
pub struct TempDir {
    path: PathBuf,
}

static TEMP_COUNTER: AtomicU64 = AtomicU64::new(0);

impl TempDir {
    pub fn new(tag: &str) -> std::io::Result<TempDir> {
        let id = TEMP_COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "unq-{tag}-{}-{id}",
            std::process::id()
        ));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tempdir_creates_and_cleans() {
        let p;
        {
            let t = TempDir::new("t").unwrap();
            p = t.path().to_path_buf();
            std::fs::write(p.join("f"), b"x").unwrap();
            assert!(p.exists());
        }
        assert!(!p.exists());
    }

    #[test]
    fn tempdirs_are_distinct() {
        let a = TempDir::new("t").unwrap();
        let b = TempDir::new("t").unwrap();
        assert_ne!(a.path(), b.path());
    }
}
