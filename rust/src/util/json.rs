//! Minimal JSON substrate (no serde available on this offline testbed).
//!
//! Supports the full JSON grammar needed here: the artifact manifests
//! emitted by `python/compile/aot.py`, config files, and experiment
//! reports.  Numbers are kept as `f64`; object key order is preserved.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Required-field helpers with contextual errors.
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("missing/invalid string field {key:?}"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("missing/invalid numeric field {key:?}"))
    }

    // -- construction ------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_map(map: &BTreeMap<String, String>) -> Json {
        Json::Obj(map.iter()
            .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
            .collect())
    }

    // -- serialization -----------------------------------------------------

    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    pub fn render_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = if pretty { "  ".repeat(indent + 1) } else { String::new() };
        let close_pad = if pretty { "  ".repeat(indent) } else { String::new() };
        let nl = if pretty { "\n" } else { "" };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad);
                    item.write(out, indent + 1, pretty);
                }
                if !items.is_empty() {
                    out.push_str(nl);
                    out.push_str(&close_pad);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !pairs.is_empty() {
                    out.push_str(nl);
                    out.push_str(&close_pad);
                }
                out.push('}');
            }
        }
    }

    // -- parsing -----------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {} (found {:?})",
                  b as char, self.pos, self.peek().map(|c| c as char))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number {s:?}: {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected , or ] (found {other:?})"),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                other => bail!("expected , or }} (found {other:?})"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].req_str("b").unwrap(),
            "c"
        );
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let j = Json::obj(vec![
            ("name", Json::Str("sift1m_8b".into())),
            ("m", Json::Num(8.0)),
            ("files", Json::obj(vec![("encode", Json::Str("encode.hlo.txt".into()))])),
            ("hist", Json::Arr(vec![Json::Num(1.5), Json::Num(2.0)])),
            ("ok", Json::Bool(true)),
        ]);
        for text in [j.render(), j.render_pretty()] {
            let back = Json::parse(&text).unwrap();
            assert_eq!(back, j);
        }
    }

    #[test]
    fn string_escapes() {
        let j = Json::Str("a\"b\\c\nd\tê".into());
        let back = Json::parse(&j.render()).unwrap();
        assert_eq!(back, j);
        let u = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(u, Json::Str("Aé".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn parses_python_manifest_shape() {
        // the exact structure aot.py emits
        let text = r#"{
          "name": "sift1m_8b", "dataset": "sift1m", "dim": 128, "m": 8,
          "k": 256, "encode_batch": 512,
          "files": {"encode": "encode.hlo.txt", "lut": "lut.hlo.txt"},
          "train": {"seconds": 93.2, "final_loss": 118.25}
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.req_usize("dim").unwrap(), 128);
        assert_eq!(j.get("files").unwrap().req_str("lut").unwrap(), "lut.hlo.txt");
        assert!(j.get("train").unwrap().get("final_loss").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn integers_render_without_decimal_point() {
        assert_eq!(Json::Num(512.0).render(), "512");
        assert_eq!(Json::Num(0.5).render(), "0.5");
    }
}
