//! k-means (Lloyd's algorithm with k-means++ seeding).
//!
//! The workhorse behind every shallow quantizer in this crate: PQ/OPQ run
//! it per subspace, RVQ per residual level, LSQ for codebook
//! initialization.  Single-threaded but written so the inner distance
//! loops autovectorize; empty clusters are repaired by stealing the point
//! farthest from its centroid (the Faiss strategy).

use crate::linalg::sq_l2;
use crate::util::rng::SplitMix64;

/// Configuration for one k-means run.
#[derive(Clone, Copy, Debug)]
pub struct KMeansConfig {
    pub k: usize,
    pub iters: usize,
    pub seed: u64,
}

impl Default for KMeansConfig {
    fn default() -> Self {
        KMeansConfig { k: 256, iters: 15, seed: 0 }
    }
}

/// Result of a k-means run.
pub struct KMeans {
    pub dim: usize,
    /// `(k, dim)` centroids, flat row-major.
    pub centroids: Vec<f32>,
    /// Final assignment of each training row.
    pub assignments: Vec<u32>,
    /// Final mean squared quantization error.
    pub mse: f32,
}

impl KMeans {
    #[inline]
    pub fn centroid(&self, j: usize) -> &[f32] {
        &self.centroids[j * self.dim..(j + 1) * self.dim]
    }

    /// Index of the nearest centroid to `x`.
    pub fn assign_one(&self, x: &[f32]) -> u32 {
        nearest(x, &self.centroids, self.dim).0
    }
}

/// Nearest centroid (id, distance) among `centroids` (flat, k rows).
#[inline]
pub fn nearest(x: &[f32], centroids: &[f32], dim: usize) -> (u32, f32) {
    let k = centroids.len() / dim;
    let mut best = (0u32, f32::INFINITY);
    for j in 0..k {
        let d = sq_l2(x, &centroids[j * dim..(j + 1) * dim]);
        if d < best.1 {
            best = (j as u32, d);
        }
    }
    best
}

/// Run k-means over `n = data.len()/dim` rows.
///
/// If `n < k`, duplicates rows so every centroid is defined (the caller's
/// codebook size contract is preserved).
pub fn kmeans(data: &[f32], dim: usize, cfg: &KMeansConfig) -> KMeans {
    assert!(dim > 0 && data.len() % dim == 0);
    let n = data.len() / dim;
    assert!(n > 0, "kmeans on empty data");
    let k = cfg.k;
    let mut rng = SplitMix64::from_key(&[cfg.seed, 0x6B6D65616E73]);

    let mut centroids = kmeanspp_init(data, dim, k, &mut rng);
    let mut assignments = vec![0u32; n];
    let mut dists = vec![0.0f32; n];
    let mut mse = f32::INFINITY;

    for _iter in 0..cfg.iters {
        // assignment step
        let mut sse = 0.0f64;
        for i in 0..n {
            let (a, d) = nearest(&data[i * dim..(i + 1) * dim], &centroids, dim);
            assignments[i] = a;
            dists[i] = d;
            sse += d as f64;
        }
        mse = (sse / n as f64) as f32;

        // update step
        let mut counts = vec![0u32; k];
        let mut sums = vec![0.0f32; k * dim];
        for i in 0..n {
            let a = assignments[i] as usize;
            counts[a] += 1;
            let row = &data[i * dim..(i + 1) * dim];
            let s = &mut sums[a * dim..(a + 1) * dim];
            for (sv, rv) in s.iter_mut().zip(row) {
                *sv += rv;
            }
        }
        // repair empty clusters: move them onto the currently worst-fit row
        for j in 0..k {
            if counts[j] == 0 {
                let worst = (0..n)
                    .max_by(|&a, &b| dists[a].partial_cmp(&dists[b]).unwrap())
                    .unwrap();
                sums[j * dim..(j + 1) * dim]
                    .copy_from_slice(&data[worst * dim..(worst + 1) * dim]);
                counts[j] = 1;
                dists[worst] = 0.0; // don't steal the same row twice
            }
        }
        for j in 0..k {
            let inv = 1.0 / counts[j] as f32;
            for v in &mut sums[j * dim..(j + 1) * dim] {
                *v *= inv;
            }
        }
        centroids = sums;
    }

    // final assignment against the last update
    let mut sse = 0.0f64;
    for i in 0..n {
        let (a, d) = nearest(&data[i * dim..(i + 1) * dim], &centroids, dim);
        assignments[i] = a;
        sse += d as f64;
    }
    mse = mse.min((sse / n as f64) as f32);

    KMeans { dim, centroids, assignments, mse }
}

/// k-means++ seeding (D² sampling).
fn kmeanspp_init(data: &[f32], dim: usize, k: usize,
                 rng: &mut SplitMix64) -> Vec<f32> {
    let n = data.len() / dim;
    let mut centroids = Vec::with_capacity(k * dim);
    let first = rng.below(n);
    centroids.extend_from_slice(&data[first * dim..(first + 1) * dim]);

    let mut d2: Vec<f32> = (0..n)
        .map(|i| sq_l2(&data[i * dim..(i + 1) * dim], &centroids[..dim]))
        .collect();

    while centroids.len() / dim < k {
        let total: f64 = d2.iter().map(|&d| d as f64).sum();
        let pick = if total <= 0.0 {
            rng.below(n)
        } else {
            let mut target = rng.next_f64() * total;
            let mut idx = n - 1;
            for (i, &d) in d2.iter().enumerate() {
                target -= d as f64;
                if target <= 0.0 {
                    idx = i;
                    break;
                }
            }
            idx
        };
        let new_c = &data[pick * dim..(pick + 1) * dim];
        centroids.extend_from_slice(new_c);
        for i in 0..n {
            let d = sq_l2(&data[i * dim..(i + 1) * dim], new_c);
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }
    centroids
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_blobs(n_per: usize) -> Vec<f32> {
        // blobs at (0,0) and (10,10) with tiny deterministic jitter
        let mut data = Vec::new();
        for i in 0..n_per {
            let j = (i % 7) as f32 * 0.01;
            data.extend_from_slice(&[j, -j]);
            data.extend_from_slice(&[10.0 + j, 10.0 - j]);
        }
        data
    }

    #[test]
    fn finds_two_blobs() {
        let data = two_blobs(50);
        let km = kmeans(&data, 2, &KMeansConfig { k: 2, iters: 10, seed: 1 });
        let mut cs: Vec<(f32, f32)> =
            (0..2).map(|j| (km.centroid(j)[0], km.centroid(j)[1])).collect();
        cs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        assert!(cs[0].0.abs() < 0.5 && cs[1].0 > 9.5);
        assert!(km.mse < 0.1);
    }

    #[test]
    fn mse_decreases_with_more_k() {
        let mut data = Vec::new();
        let mut seed = 7u64;
        for _ in 0..400 {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
            data.push((seed >> 33) as f32 / (1u64 << 31) as f32);
            data.push((seed >> 17) as f32 / (1u64 << 47) as f32);
        }
        let m2 = kmeans(&data, 2, &KMeansConfig { k: 2, iters: 10, seed: 0 }).mse;
        let m16 = kmeans(&data, 2, &KMeansConfig { k: 16, iters: 10, seed: 0 }).mse;
        assert!(m16 < m2);
    }

    #[test]
    fn handles_k_larger_than_n() {
        let data = vec![0.0f32, 0.0, 1.0, 1.0];
        let km = kmeans(&data, 2, &KMeansConfig { k: 8, iters: 5, seed: 0 });
        assert_eq!(km.centroids.len(), 8 * 2);
        for v in &km.centroids {
            assert!(v.is_finite());
        }
    }

    #[test]
    fn assignments_are_nearest() {
        let data = two_blobs(20);
        let km = kmeans(&data, 2, &KMeansConfig { k: 2, iters: 10, seed: 3 });
        for i in 0..km.assignments.len() {
            let row = &data[i * 2..(i + 1) * 2];
            assert_eq!(km.assignments[i], km.assign_one(row));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let data = two_blobs(30);
        let a = kmeans(&data, 2, &KMeansConfig { k: 4, iters: 8, seed: 9 });
        let b = kmeans(&data, 2, &KMeansConfig { k: 4, iters: 8, seed: 9 });
        assert_eq!(a.centroids, b.centroids);
    }
}
