//! IVF: coarse-partitioned inverted-file index for sub-linear
//! compressed-domain search.
//!
//! The flat index scans every code per query — the paper's "3 s per
//! 10⁹ × 8-byte scan" exhaustive regime.  This subsystem puts a coarse
//! k-means codebook ([`coarse::CoarseQuantizer`]) in front of the LUT
//! scan: the database is partitioned into `num_lists` inverted lists,
//! each stored *contiguously* inside one code matrix, and a query scans
//! only its `nprobe` nearest lists — the coarse+fine decomposition that
//! lets IVFADC-style systems search billion-scale corpora.
//!
//! Layout (`rust/DESIGN.md` §5):
//!
//! ```text
//! codes   row:  0 ……… off[1] ……… off[2] ………………… off[L] = n
//!               └ list 0 ┘└ list 1 ┘   …   └ list L−1 ┘
//! remap[row] = original id   (ascending within each list)
//! ```
//!
//! * **Residual encoding** (optional): codes quantize `x − centroid(x)`,
//!   so any existing [`crate::quant`] backend plugs in unchanged — its
//!   LUT just gets the *residual query* `q − centroid(list)` per probed
//!   list.
//! * **Execution**: search plans one [`crate::exec::ScanTask`] slot per
//!   `(query, probed list)` pair through the shared executor pool, so a
//!   batch of queries probing different lists still fills every worker.
//! * **Degenerate-case contract**: with `nprobe = num_lists` and
//!   non-residual encoding, results are bit-identical to the flat
//!   [`crate::index::SearchEngine::search_batch`] for every
//!   `(num_threads, shard_rows)` — pinned by property tests in
//!   [`search`].
//! * **Scan precision**: `SearchConfig::scan_precision` selects the
//!   per-list scan kernel exactly as on the flat path (f32 exact, or
//!   u16/u8 blocked integer selection + exact rescore over one shared
//!   packed layout — [`IvfIndex::ensure_packed`], DESIGN.md §6);
//!   residual LUTs quantize per probed slot like any other LUT.

pub mod coarse;
pub mod disk;
pub mod persist;
pub mod search;

use std::sync::Arc;

use crate::data::Dataset;
use crate::exec::Executor;
use crate::index::{CompressedIndex, SearchRequest};
use crate::quant::Quantizer;

pub use coarse::CoarseQuantizer;

/// A coarse-partitioned compressed index: per-list contiguous code
/// storage + id-remap table over one [`CompressedIndex`].
pub struct IvfIndex {
    pub coarse: CoarseQuantizer,
    /// Whether codes quantize `x − centroid(x)` (residual) or `x` raw.
    pub residual: bool,
    /// List `l` occupies code rows `[offsets[l], offsets[l + 1])`;
    /// `offsets.len() == num_lists + 1`, `offsets[num_lists] == n`.
    pub offsets: Vec<usize>,
    /// `remap[row]` = original database id of stored row `row`
    /// (ascending within each list — the tie-break invariant the
    /// flat-equivalence guarantee rests on).
    pub remap: Vec<u32>,
    /// The per-list contiguous code storage (n rows total).
    pub codes: CompressedIndex,
}

impl IvfIndex {
    /// Partition, (residual-)encode and lay out a dataset.
    ///
    /// Rows are appended to their list in ascending original-id order,
    /// and encoding happens in one `encode_batch` call over the gathered
    /// (optionally residualized) rows — one PJRT execution for UNQ.
    pub fn build(quant: &dyn Quantizer, data: &Dataset,
                 coarse: CoarseQuantizer, residual: bool) -> IvfIndex {
        assert_eq!(coarse.dim, data.dim, "coarse codebook dim mismatch");
        assert_eq!(quant.dim(), data.dim, "quantizer dim mismatch");
        let n = data.len();
        let nl = coarse.num_lists();
        let dim = data.dim;

        let assign: Vec<u32> =
            (0..n).map(|i| coarse.assign(data.row(i))).collect();
        let mut offsets = vec![0usize; nl + 1];
        for &a in &assign {
            offsets[a as usize + 1] += 1;
        }
        for l in 0..nl {
            offsets[l + 1] += offsets[l];
        }

        // gather rows into list order (stable: ascending id within list)
        let mut cursor: Vec<usize> = offsets[..nl].to_vec();
        let mut remap = vec![0u32; n];
        let mut gathered = vec![0.0f32; n * dim];
        for id in 0..n {
            let l = assign[id] as usize;
            let row = cursor[l];
            cursor[l] += 1;
            remap[row] = id as u32;
            let dst = &mut gathered[row * dim..(row + 1) * dim];
            dst.copy_from_slice(data.row(id));
            if residual {
                for (d, c) in dst.iter_mut().zip(coarse.centroid(l)) {
                    *d -= c;
                }
            }
        }

        let code_bytes = quant.code_bytes();
        let codes = quant.encode_batch(&gathered);
        IvfIndex {
            coarse,
            residual,
            offsets,
            remap,
            codes: CompressedIndex::from_codes(n, code_bytes, codes),
        }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.codes.n
    }

    #[inline]
    pub fn num_lists(&self) -> usize {
        self.coarse.num_lists()
    }

    /// Rows stored in list `l`.
    #[inline]
    pub fn list_len(&self, l: usize) -> usize {
        self.offsets[l + 1] - self.offsets[l]
    }

    /// Code storage bytes (same accounting as the flat index).
    pub fn storage_bytes(&self) -> usize {
        self.codes.storage_bytes()
    }

    /// Build the blocked fast-scan mirror of the per-list code matrix
    /// for the integer scan precisions (one packed layout serves every
    /// list: per-list scans walk the blocks covering `[offsets[l],
    /// offsets[l+1])` and skip out-of-range lanes — DESIGN.md §6).
    pub fn ensure_packed(&mut self) {
        self.codes.ensure_packed();
    }

    /// Build the 1-bit sign sketches of the per-list code rows for the
    /// pre-filter stage (DESIGN.md §9).  Sketches live on the shared
    /// [`CompressedIndex`], so per-list scans prune by the same Hamming
    /// triple resolution as the flat path.  Returns `false` when the
    /// quantizer cannot decode (no sketches — searches fall back to the
    /// plain precision scan).
    pub fn ensure_sketches(&mut self, quant: &dyn Quantizer) -> bool {
        self.codes.ensure_sketches(quant)
    }

    /// Attach the metadata tag column, given in **original-id order**
    /// (`tags_by_id[id]` tags database row `id`).  Tags are permuted
    /// through `remap` into the stored per-list row order so the filter
    /// bitmap lines up with the code matrix the scans walk
    /// (rust/DESIGN.md §13).
    pub fn set_tags(&mut self, tags_by_id: Vec<u64>) {
        assert_eq!(tags_by_id.len(), self.codes.n, "one tag per row");
        let row_tags: Vec<u64> = self
            .remap
            .iter()
            .map(|&id| tags_by_id[id as usize])
            .collect();
        self.codes.set_tags(row_tags);
    }
}

/// The serving coordinator's index dispatch: one enum, three index
/// organizations, identical request-path semantics.
pub enum IndexBackend {
    /// Exhaustive ADC scan over a flat code matrix.
    Flat(Arc<CompressedIndex>),
    /// Coarse-partitioned `nprobe` search.
    Ivf(Arc<IvfIndex>),
    /// Disk-resident `nprobe` search: routing in RAM, per-list blocks
    /// paged from a block archive through the hot-list cache
    /// ([`disk::DiskIvfIndex`], rust/DESIGN.md §11).
    DiskIvf(Arc<disk::DiskIvfIndex>),
    /// Mutable streaming index (WAL-backed segments): the only backend
    /// the coordinator's insert/delete ops accept.
    Streaming(Arc<crate::index::StreamingIndex>),
}

impl IndexBackend {
    pub fn n(&self) -> usize {
        match self {
            IndexBackend::Flat(ix) => ix.n,
            IndexBackend::Ivf(ix) => ix.n(),
            IndexBackend::DiskIvf(ix) => ix.n(),
            IndexBackend::Streaming(ix) => ix.len(),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            IndexBackend::Flat(_) => "flat",
            IndexBackend::Ivf(_) => "ivf",
            IndexBackend::DiskIvf(_) => "disk-ivf",
            IndexBackend::Streaming(_) => "stream",
        }
    }

    /// Backend-agnostic batched two-stage search on one
    /// [`SearchRequest`] — the coordinator's entry point.  Every arm
    /// consumes the same request shape (per-query `k`s plus the
    /// [`crate::index::QuerySpec`] scan axes); the flat arm reproduces
    /// the classic `SearchEngine` path, the IVF arms plan per-probed-list
    /// tasks through the same executor.
    pub fn search_batch_on(&self, quant: &dyn Quantizer, exec: &Executor,
                           queries: &[&[f32]], req: &SearchRequest)
                           -> Vec<Vec<u32>> {
        match self {
            IndexBackend::Flat(ix) => ix
                .search_batch_on(quant, exec, queries, req)
                .expect("in-memory flat search cannot fail"),
            IndexBackend::Ivf(ix) => ix
                .search_batch_on(quant, exec, queries, req)
                .expect("in-memory IVF search cannot fail"),
            // the enum's search contract is infallible; a disk-tier
            // I/O or CRC failure is unrecoverable mid-request here
            IndexBackend::DiskIvf(ix) => ix
                .search_batch_on(quant, exec, queries, req)
                .expect("disk-ivf block fetch failed"),
            IndexBackend::Streaming(ix) => {
                ix.search_batch_on(quant, exec, queries, req)
            }
        }
    }
}
