//! `nprobe` search over an [`IvfIndex`]: coarse list selection, per-list
//! scan plans on the shared executor, deterministic cross-list merge,
//! and the (residual-aware) batched decode rerank.
//!
//! Determinism is the load-bearing property.  Stage 1 selects, per
//! query, the top-`l` candidates under the total order
//! `(ADC score, original id)`:
//!
//! * each `(query, probed list)` pair is one executor *slot*, its list
//!   range sharded into [`crate::exec::ScanTask`]s merged in ascending
//!   row order — within a list, stored rows ascend in original id, so
//!   per-list ties keep the smallest id exactly like the flat scan;
//! * per-list winners are remapped to original ids and reduced through
//!   the shared [`merge_topk`], whose bounded heap orders candidates
//!   lexicographically on `(score, id)` — decomposition-invariant by
//!   construction, so no list interleaving can change the survivors.
//!
//! Hence `nprobe = num_lists` with non-residual codes returns results
//! bit-identical to [`crate::index::SearchEngine::search_batch`]: every
//! code contributes the same f32 score through the same LUT, and the
//! selection order is identical.  The property tests below pin this over
//! the `(num_threads, shard_rows)` grid.
//!
//! The scan-precision knob (`SearchConfig::scan_precision`) applies
//! per-list exactly as on the flat path: residual LUTs quantize
//! identically (one `QuantizedLut` per slot LUT), integer selection runs
//! over the shared per-list blocked layout, and survivors are re-scored
//! in exact f32 before the cross-list merge (rust/DESIGN.md §6).  The
//! 1-bit pre-filter (`SearchConfig::prefilter`, DESIGN.md §9) engages
//! for non-residual indexes with sketches built
//! ([`IvfIndex::ensure_sketches`]); residual deployments keep it off —
//! stored sketches cover raw reconstructions, not residual space.

use std::collections::HashMap;

use anyhow::Result;

use crate::config::SearchConfig;
use crate::exec::{shard_ranges_in, Executor, PrefilterPlan, ScanSpec,
                  ScanTask};
use crate::index::scan::merge_topk;
use crate::index::{FilterPlan, SearchRequest};
use crate::linalg::{sq_l2, TopK};
use crate::obs;
use crate::quant::{Lut, Quantizer, SketchPlanes};

use super::IvfIndex;

/// One stage-1 candidate: `(ADC score, original id, stored row, list)`.
type Candidate = (f32, u32, u32, u32);

impl IvfIndex {
    /// Single-query convenience: a batch of one on the inline executor
    /// (mirrors `SearchEngine::search`).
    pub fn search(&self, quant: &dyn Quantizer, q: &[f32],
                  cfg: &SearchConfig) -> Vec<u32> {
        let req = SearchRequest::from_config(cfg, vec![cfg.k]);
        self.search_batch_on(quant, &Executor::Inline, &[q], &req)
            .expect("in-memory IVF search cannot fail")
            .pop()
            .expect("one query in, one result out")
    }

    /// Batched two-stage `nprobe` search with per-query `k`.
    ///
    /// `req.nprobe == 0` (or ≥ `num_lists`) probes every list — the
    /// flat-equivalent degenerate case.  `exhaustive_rerank` is a
    /// flat-index diagnostic and is treated as the normal two-stage path
    /// here (reranking rows outside the probed lists would defeat the
    /// point of probing).  A metadata predicate
    /// (`QuerySpec::filter`) compiles to one row bitmap over the stored
    /// (list-contiguous) layout and is applied inside the per-list scan
    /// kernels, so only admitted rows reach the cross-list merge and
    /// rerank.
    pub fn search_batch_on(&self, quant: &dyn Quantizer, exec: &Executor,
                           queries: &[&[f32]], req: &SearchRequest)
                           -> Result<Vec<Vec<u32>>> {
        let cfg = req.to_search_config();
        let ks: &[usize] = &req.ks;
        assert_eq!(queries.len(), ks.len(), "one k per query");
        if queries.is_empty() {
            return Ok(Vec::new());
        }
        let nl = self.num_lists();
        let nprobe = if cfg.nprobe == 0 { nl } else { cfg.nprobe.min(nl) };
        let do_rerank = !cfg.no_rerank && quant.supports_rerank();
        // stage-1 candidate count per query (≥ 1 keeps TopK happy even
        // for degenerate configs)
        let ls: Vec<usize> = ks
            .iter()
            .map(|&k| {
                let l = if do_rerank { cfg.rerank_l.max(k) } else { k };
                l.max(1)
            })
            .collect();

        // coarse selection
        let probes: Vec<Vec<u32>> = {
            let mut span = crate::span!("route");
            let probes: Vec<Vec<u32>> = queries
                .iter()
                .map(|q| self.coarse.nearest_lists(q, nprobe))
                .collect();
            let probed: usize = probes.iter().map(Vec::len).sum();
            obs::global().ivf_lists_probed.add(probed as u64);
            span.add_rows(probed as u64);
            probes
        };

        // one slot per non-empty (query, probed list); LUTs are shared
        // per query (non-residual) or built per slot from the residual
        // query `q − centroid(list)` in one lut_batch call
        let mut slot_query: Vec<usize> = Vec::new();
        let mut slot_list: Vec<usize> = Vec::new();
        let mut slot_ks: Vec<usize> = Vec::new();
        let mut slot_lut: Vec<usize> = Vec::new();
        let mut residual_qs: Vec<Vec<f32>> = Vec::new();
        for (qi, probe) in probes.iter().enumerate() {
            for &l in probe {
                let l = l as usize;
                if self.list_len(l) == 0 {
                    continue;
                }
                slot_lut.push(if self.residual {
                    let c = self.coarse.centroid(l);
                    residual_qs.push(
                        queries[qi].iter().zip(c).map(|(a, b)| a - b).collect());
                    residual_qs.len() - 1
                } else {
                    qi
                });
                slot_query.push(qi);
                slot_list.push(l);
                slot_ks.push(ls[qi]);
            }
        }
        let luts: Vec<Lut> = {
            let mut span = crate::span!("lut_build");
            let luts = if self.residual {
                obs::global().ivf_residual_luts
                    .add(residual_qs.len() as u64);
                let refs: Vec<&[f32]> =
                    residual_qs.iter().map(|v| v.as_slice()).collect();
                quant.lut_batch(&refs)
            } else {
                quant.lut_batch(queries)
            };
            span.add_rows(luts.len() as u64);
            luts
        };

        // shard each slot's list range; shard size derives from the whole
        // index so long lists split across workers and short ones don't
        let es = exec.effective_shard_rows(self.codes.n.max(1),
                                           cfg.shard_rows);
        let mut tasks: Vec<ScanTask> = Vec::new();
        for (slot, &l) in slot_list.iter().enumerate() {
            for (lo, hi) in
                shard_ranges_in(self.offsets[l], self.offsets[l + 1], es)
            {
                tasks.push(ScanTask {
                    index: 0, slot, lut: slot_lut[slot], lo, hi,
                });
            }
        }
        // optional 1-bit pre-filter (DESIGN.md §9): non-residual only —
        // stored sketches cover raw reconstructions, so a residual LUT's
        // query lives in a different space and the plan stays off.  With
        // non-residual codes slot LUTs are per query, so query sketches
        // index by the same `lut` the tasks carry.
        let pre = if cfg.prefilter && !self.residual
            && self.codes.sketches.is_some()
        {
            let planes = SketchPlanes::for_dim(quant.dim());
            Some(PrefilterPlan {
                qsketches: queries
                    .iter()
                    .map(|q| Some(planes.sketch(q)))
                    .collect(),
                margin: cfg.prefilter_margin,
            })
        } else {
            None
        };
        // metadata predicate → one bitmap over the stored row layout
        let fplan = cfg.filter
            .map(|f| FilterPlan::compile(&f, &[&self.codes]));
        let spec = ScanSpec {
            precision: cfg.scan_precision,
            prefilter: pre.as_ref(),
            filter: fplan.as_ref(),
        };
        let parts = exec.run_scan_tasks(&luts, &[&self.codes], &slot_ks,
                                        &tasks, &spec);

        // cross-list reduce per query: remap each slot's winners to
        // original ids and fold the per-slot lists through the shared
        // lexicographic `merge_topk` — the (score, id)-ordered heap makes
        // the reduction decomposition-invariant by construction, so the
        // hand-rolled total-order sort this used to compensate with is
        // gone.  (row, list) context for the rerank gather rides in a
        // per-query side map keyed by original id (unique per query: an
        // id lives in exactly one list).
        let mut parts_by_q: Vec<Vec<Vec<(f32, u32)>>> =
            (0..queries.len()).map(|_| Vec::new()).collect();
        let mut aux: Vec<HashMap<u32, (u32, u32)>> =
            (0..queries.len()).map(|_| HashMap::new()).collect();
        for (slot, part) in parts.into_iter().enumerate() {
            let (qi, l) = (slot_query[slot], slot_list[slot] as u32);
            let mapped: Vec<(f32, u32)> = part
                .into_iter()
                .map(|(score, row)| {
                    let id = self.remap[row as usize];
                    aux[qi].insert(id, (row, l));
                    (score, id)
                })
                .collect();
            parts_by_q[qi].push(mapped);
        }
        let cands: Vec<Vec<Candidate>> = parts_by_q
            .into_iter()
            .enumerate()
            .map(|(qi, q_parts)| {
                merge_topk(q_parts, ls[qi])
                    .into_iter()
                    .map(|(score, id)| {
                        let (row, l) = aux[qi][&id];
                        (score, id, row, l)
                    })
                    .collect()
            })
            .collect();

        if !do_rerank {
            return Ok(cands
                .iter()
                .zip(ks)
                .map(|(c, &k)| c.iter().take(k).map(|p| p.1).collect())
                .collect());
        }
        Ok(self.rerank_batch(quant, queries, &cands, ks))
    }

    /// Stage 2: gather every query's candidate codes into one contiguous
    /// buffer, decode with a **single** `reconstruct_batch` call, add the
    /// list centroid back when codes are residual, and rank by exact
    /// `d1(q, i) = ‖q − x̂_i‖²`.  Mirrors `exec::plan::rerank_batch`
    /// (identical ordering for non-residual codes); quantizers without a
    /// decoder keep scan order.
    fn rerank_batch(&self, quant: &dyn Quantizer, queries: &[&[f32]],
                    cands: &[Vec<Candidate>], ks: &[usize]) -> Vec<Vec<u32>> {
        let dim = quant.dim();
        let cb = self.codes.stride;
        let total: usize = cands.iter().map(|c| c.len()).sum();
        let mut span = crate::span!("rerank");
        span.add_rows(total as u64);
        let mut codes = Vec::with_capacity(total * cb);
        for c in cands {
            for &(_, _, row, _) in c {
                codes.extend_from_slice(self.codes.code(row as usize));
            }
        }
        let mut recons = vec![0.0f32; total * dim];
        if !quant.reconstruct_batch(&codes, &mut recons) {
            // no decoder: keep scan order
            return cands
                .iter()
                .zip(ks)
                .map(|(c, &k)| c.iter().take(k).map(|p| p.1).collect())
                .collect();
        }
        let mut out = Vec::with_capacity(queries.len());
        let mut off = 0usize;
        for ((&q, c), &k) in queries.iter().zip(cands).zip(ks) {
            if c.is_empty() {
                out.push(Vec::new());
                continue;
            }
            let mut top = TopK::new(k.min(c.len()));
            for (ci, &(_, id, _, l)) in c.iter().enumerate() {
                let rec = &recons[(off + ci) * dim..(off + ci + 1) * dim];
                let d = if self.residual {
                    d1_residual(q, rec, self.coarse.centroid(l as usize))
                } else {
                    sq_l2(q, rec)
                };
                top.push(d, id);
            }
            off += c.len();
            out.push(top.into_sorted().into_iter().map(|(_, id)| id).collect());
        }
        out
    }
}

/// `‖q − (centroid + recon)‖²` without materializing the sum (shared
/// with the disk tier's rerank — [`super::disk`]).
#[inline]
pub(crate) fn d1_residual(q: &[f32], recon: &[f32], centroid: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for ((&qv, &rv), &cv) in q.iter().zip(recon).zip(centroid) {
        let d = qv - (rv + cv);
        acc += d * d;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SearchConfig;
    use crate::data::{synthetic::Generator, Dataset, Family};
    use crate::index::{CompressedIndex, SearchEngine};
    use crate::ivf::CoarseQuantizer;
    use crate::quant::pq::Pq;
    use crate::util::{prop, rng::SplitMix64};

    fn setup(n_base: usize) -> (Dataset, Dataset, Pq) {
        let gen = Generator::new(Family::SiftLike, 55);
        let train = gen.generate(0, 1200);
        let base = gen.generate(1, n_base);
        let pq = Pq::train(&train.data, train.dim, 8, 32, 0, 8);
        (train, base, pq)
    }

    fn qrefs(d: &Dataset) -> Vec<&[f32]> {
        (0..d.len()).map(|qi| d.row(qi)).collect()
    }

    /// Positional shim over the request API so the property grids below
    /// stay readable.
    fn batch(ivf: &IvfIndex, quant: &dyn Quantizer, exec: &Executor,
             qs: &[&[f32]], ks: &[usize], cfg: &SearchConfig)
             -> Vec<Vec<u32>> {
        let req = SearchRequest::from_config(cfg, ks.to_vec());
        ivf.search_batch_on(quant, exec, qs, &req).unwrap()
    }

    #[test]
    fn partition_layout_invariants() {
        let (train, base, pq) = setup(3000);
        let coarse = CoarseQuantizer::train(&train.data, train.dim, 16, 1, 8);
        let ivf = IvfIndex::build(&pq, &base, coarse, false);
        assert_eq!(ivf.n(), 3000);
        assert_eq!(ivf.offsets.len(), 17);
        assert_eq!(*ivf.offsets.last().unwrap(), 3000);
        // remap is a permutation of 0..n, ascending within each list
        let mut seen = vec![false; 3000];
        for l in 0..16 {
            let rows = &ivf.remap[ivf.offsets[l]..ivf.offsets[l + 1]];
            for w in rows.windows(2) {
                assert!(w[0] < w[1], "ids ascend within list {l}");
            }
            for &id in rows {
                assert!(!seen[id as usize], "id {id} appears twice");
                seen[id as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "every id stored exactly once");
        // stored codes match per-row flat encoding (non-residual)
        let flat = CompressedIndex::build(&pq, &base);
        for row in 0..ivf.n() {
            let id = ivf.remap[row] as usize;
            assert_eq!(ivf.codes.code(row), flat.code(id), "row {row}");
        }
    }

    #[test]
    fn prop_nprobe_all_non_residual_is_bit_identical_to_flat() {
        // THE acceptance property: IvfIndex with nprobe = num_lists and
        // non-residual codes returns exactly SearchEngine::search_batch
        // for every (num_threads, shard_rows) grid point, rerank included
        let (train, base, pq) = setup(2500);
        let flat = CompressedIndex::build(&pq, &base);
        let coarse = CoarseQuantizer::train(&train.data, train.dim, 12, 2, 8);
        let ivf = IvfIndex::build(&pq, &base, coarse, false);
        let queries = Generator::new(Family::SiftLike, 55).generate(2, 8);
        let qs = qrefs(&queries);
        prop::forall_ok(
            777,
            10,
            |r: &mut SplitMix64| {
                let threads = 1 + r.below(4);
                let shard_rows = [0usize, 1, 37, 128, 1000][r.below(5)];
                let no_rerank = r.below(2) == 0;
                (threads, shard_rows, no_rerank)
            },
            |&(threads, shard_rows, no_rerank)| {
                let cfg = SearchConfig {
                    rerank_l: 60, k: 10, no_rerank, num_threads: threads,
                    shard_rows, nprobe: ivf.num_lists(),
                    ..Default::default()
                };
                let exec = Executor::new(threads);
                let want = SearchEngine::new(&pq, &flat, cfg)
                    .search_batch_on(&exec, &qs);
                let ks = vec![cfg.k; qs.len()];
                let got = batch(&ivf, &pq, &exec, &qs, &ks, &cfg);
                if got == want {
                    Ok(())
                } else {
                    Err(format!(
                        "threads={threads} shard_rows={shard_rows} \
                         no_rerank={no_rerank} diverged from flat"
                    ))
                }
            },
        );
    }

    #[test]
    fn tracing_is_inert_on_ivf_results_and_accounts_spans() {
        // observability on the IVF path (rust/DESIGN.md §10): a live
        // trace changes nothing about results, and the collected tree
        // names every stage with routing fan-out carried in `rows`
        let (train, base, pq) = setup(1500);
        let coarse = CoarseQuantizer::train(&train.data, train.dim, 8, 3, 8);
        let ivf = IvfIndex::build(&pq, &base, coarse, false);
        let queries = Generator::new(Family::SiftLike, 55).generate(2, 6);
        let qs = qrefs(&queries);
        let ks = vec![10usize; qs.len()];
        let cfg = SearchConfig { rerank_l: 60, k: 10, nprobe: 3,
                                 num_threads: 2, shard_rows: 128,
                                 ..Default::default() };
        let exec = Executor::new(2);
        let want = batch(&ivf, &pq, &exec, &qs, &ks, &cfg);
        let (trace, root) = crate::obs::Trace::begin("query");
        let got = batch(&ivf, &pq, &exec, &qs, &ks, &cfg);
        drop(root);
        assert_eq!(got, want, "tracing changed IVF results");
        let probed = trace.rows("route");
        assert!(probed >= qs.len() as u64
                    && probed <= (3 * qs.len()) as u64,
                "lists probed {probed} outside [{}, {}]", qs.len(),
                3 * qs.len());
        assert!(trace.rows("scan_task") > 0, "tasks must account rows");
        let txt = trace.render();
        for stage in ["route", "lut_build", "scan", "rerank"] {
            assert!(txt.contains(stage), "missing {stage} in:\n{txt}");
        }
    }

    #[test]
    fn precision_full_rerank_at_nprobe_all_matches_f32() {
        // with rerank_l ≥ n and every list probed, the stage-1 candidate
        // pool is the whole database at any scan precision, so the exact
        // rerank must return f32-identical results — packed per-list
        // layout included
        use crate::config::ScanPrecision;
        let (train, base, pq) = setup(1500);
        let coarse = CoarseQuantizer::train(&train.data, train.dim, 8, 9, 8);
        let mut ivf = IvfIndex::build(&pq, &base, coarse, false);
        let queries = Generator::new(Family::SiftLike, 55).generate(2, 5);
        let qs = qrefs(&queries);
        let ks = vec![10usize; qs.len()];
        let base_cfg = SearchConfig { rerank_l: 1500, k: 10, nprobe: 0,
                                      ..Default::default() };
        let want = batch(&ivf, &pq, &Executor::Inline, &qs, &ks, &base_cfg);
        ivf.ensure_packed();
        for precision in [ScanPrecision::U16, ScanPrecision::U8] {
            let cfg = SearchConfig { scan_precision: precision, ..base_cfg };
            let got = batch(&ivf, &pq, &Executor::new(2), &qs, &ks, &cfg);
            assert_eq!(got, want, "{precision:?}");
        }
    }

    #[test]
    fn u16_precision_nprobe_recall_stays_sane() {
        // integer selection at nprobe < num_lists: results must stay in
        // the same league as the f32 scan (overwhelming id overlap)
        use crate::config::ScanPrecision;
        let (train, base, pq) = setup(3000);
        let coarse = CoarseQuantizer::train(&train.data, train.dim, 12, 3, 8);
        let mut ivf = IvfIndex::build(&pq, &base, coarse, false);
        ivf.ensure_packed();
        let queries = Generator::new(Family::SiftLike, 55).generate(2, 10);
        let qs = qrefs(&queries);
        let ks = vec![10usize; qs.len()];
        let f32_cfg = SearchConfig { rerank_l: 60, k: 10, nprobe: 4,
                                     ..Default::default() };
        let u16_cfg = SearchConfig { scan_precision: ScanPrecision::U16,
                                     ..f32_cfg };
        let a = batch(&ivf, &pq, &Executor::Inline, &qs, &ks, &f32_cfg);
        let b = batch(&ivf, &pq, &Executor::Inline, &qs, &ks, &u16_cfg);
        let overlap: usize = a
            .iter()
            .zip(&b)
            .map(|(x, y)| x.iter().filter(|&id| y.contains(id)).count())
            .sum();
        assert!(overlap * 10 >= 10 * qs.len() * 9,
                "u16 IVF overlap collapsed: {overlap}/{}", 10 * qs.len());
    }

    #[test]
    fn prefilter_full_keep_is_bit_identical_on_ivf() {
        // keep = k·margin covers every probed list outright, so the
        // pruned per-list scans delegate to the plain kernels and the
        // whole search must match bit for bit on any executor
        let (train, base, pq) = setup(2000);
        let coarse = CoarseQuantizer::train(&train.data, train.dim, 10, 3, 8);
        let mut ivf = IvfIndex::build(&pq, &base, coarse, false);
        assert!(ivf.ensure_sketches(&pq), "PQ decodes, sketches must build");
        let queries = Generator::new(Family::SiftLike, 55).generate(2, 5);
        let qs = qrefs(&queries);
        let ks = vec![10usize; qs.len()];
        let base_cfg = SearchConfig { rerank_l: 50, k: 10, nprobe: 4,
                                      ..Default::default() };
        let want = batch(&ivf, &pq, &Executor::Inline, &qs, &ks, &base_cfg);
        let cfg = SearchConfig { prefilter: true, prefilter_margin: 10_000,
                                 ..base_cfg };
        for exec in [Executor::Inline, Executor::new(3)] {
            let got = batch(&ivf, &pq, &exec, &qs, &ks, &cfg);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn prefilter_is_inert_on_residual_ivf() {
        // stored sketches cover raw reconstructions while residual LUT
        // queries live in centroid-relative space, so the plan must stay
        // off for residual indexes even with sketches built — results
        // identical to the unfiltered search at any margin
        let (train, base, pq) = setup(1500);
        let coarse = CoarseQuantizer::train(&train.data, train.dim, 8, 3, 8);
        let mut ivf = IvfIndex::build(&pq, &base, coarse, true);
        assert!(ivf.ensure_sketches(&pq));
        let queries = Generator::new(Family::SiftLike, 55).generate(2, 4);
        let qs = qrefs(&queries);
        let ks = vec![8usize; qs.len()];
        let base_cfg = SearchConfig { rerank_l: 40, k: 8, nprobe: 3,
                                      ..Default::default() };
        let want = batch(&ivf, &pq, &Executor::Inline, &qs, &ks, &base_cfg);
        let cfg = SearchConfig { prefilter: true, prefilter_margin: 1,
                                 ..base_cfg };
        let got = batch(&ivf, &pq, &Executor::Inline, &qs, &ks, &cfg);
        assert_eq!(got, want);
    }

    #[test]
    fn nprobe_zero_means_all_lists() {
        let (train, base, pq) = setup(1500);
        let coarse = CoarseQuantizer::train(&train.data, train.dim, 8, 3, 8);
        let ivf = IvfIndex::build(&pq, &base, coarse, false);
        let queries = Generator::new(Family::SiftLike, 55).generate(2, 4);
        let qs = qrefs(&queries);
        let mut cfg = SearchConfig { rerank_l: 40, k: 10,
                                     ..Default::default() };
        let ks = vec![10usize; qs.len()];
        cfg.nprobe = 0;
        let all = batch(&ivf, &pq, &Executor::Inline, &qs, &ks, &cfg);
        cfg.nprobe = ivf.num_lists();
        let explicit = batch(&ivf, &pq, &Executor::Inline, &qs, &ks, &cfg);
        assert_eq!(all, explicit);
    }

    #[test]
    fn residual_encoding_recall_sane_and_roundtrips_through_lists() {
        // residual IVF at nprobe = all must rank the true NN of an
        // in-database query first: d1 through centroid + residual decode
        // is a faithful reconstruction
        let (train, base, pq_raw) = setup(2000);
        let coarse =
            CoarseQuantizer::train(&train.data, train.dim, 10, 4, 8);
        // train the fine quantizer on residuals, as a residual deployment
        // would
        let mut res_train = train.data.clone();
        for i in 0..train.len() {
            let l = coarse.assign(train.row(i)) as usize;
            let c = coarse.centroid(l);
            for (v, cv) in res_train[i * train.dim..(i + 1) * train.dim]
                .iter_mut()
                .zip(c)
            {
                *v -= cv;
            }
        }
        let pq_res = Pq::train(&res_train, train.dim, 8, 32, 0, 8);
        let ivf = IvfIndex::build(&pq_res, &base, coarse.clone(), true);
        let flat = CompressedIndex::build(&pq_raw, &base);
        let cfg = SearchConfig { rerank_l: 100, k: 10, nprobe: 0,
                                 ..Default::default() };
        let mut hits_res = 0;
        let mut hits_raw = 0;
        for qi in 0..50 {
            let q = base.row(qi);
            let got = ivf.search(&pq_res, q, &cfg);
            hits_res += (got.first() == Some(&(qi as u32))) as usize;
            let raw = SearchEngine::new(&pq_raw, &flat, cfg).search(q);
            hits_raw += (raw.first() == Some(&(qi as u32))) as usize;
        }
        // self-retrieval through the residual path must work in the same
        // league as the raw flat quantizer (residuals are easier to
        // code, so a collapse here means the centroid add-back is wrong)
        assert!(hits_res + 5 >= hits_raw,
                "residual {hits_res} vs raw {hits_raw}");
        assert!(hits_res >= 25, "residual self-retrieval collapsed: \
                                 {hits_res}/50");
    }

    #[test]
    fn recall_grows_monotonically_with_nprobe() {
        let (train, base, pq) = setup(4000);
        let coarse = CoarseQuantizer::train(&train.data, train.dim, 16, 5, 8);
        let ivf = IvfIndex::build(&pq, &base, coarse, false);
        let flat = CompressedIndex::build(&pq, &base);
        let queries = Generator::new(Family::SiftLike, 55).generate(2, 40);
        let qs = qrefs(&queries);
        let ks = vec![10usize; qs.len()];
        let mut cfg = SearchConfig { rerank_l: 50, k: 10,
                                     ..Default::default() };
        let want = SearchEngine::new(&pq, &flat, cfg).search_batch(&qs);
        let mut prev_overlap = 0usize;
        for nprobe in [1usize, 4, 16] {
            cfg.nprobe = nprobe;
            let got = batch(&ivf, &pq, &Executor::Inline, &qs, &ks, &cfg);
            let overlap: usize = got
                .iter()
                .zip(&want)
                .map(|(g, w)| g.iter().filter(|&id| w.contains(id)).count())
                .sum();
            // near-monotone: probing more lists can only widen the
            // stage-1 candidate pool (small slack: rerank can reshuffle
            // the tail)
            assert!(overlap + 5 >= prev_overlap,
                    "nprobe={nprobe}: overlap {overlap} < {prev_overlap}");
            prev_overlap = overlap;
        }
        // probing everything recovers the flat result set exactly
        assert_eq!(prev_overlap, 10 * qs.len());
    }

    #[test]
    fn degenerate_empty_lists_are_skipped() {
        // hand-built coarse codebook: centroid 3 is far from all data, so
        // its list is empty; searches (including ones probing it) work
        let (_, base, pq) = setup(800);
        let dim = base.dim;
        let mut cents = Vec::new();
        for off in [0.0f32, 50.0, 100.0, 1.0e6] {
            cents.extend((0..dim).map(|d| off + d as f32));
        }
        let coarse = CoarseQuantizer::from_centroids(dim, cents);
        let ivf = IvfIndex::build(&pq, &base, coarse, false);
        assert!((0..4).any(|l| ivf.list_len(l) == 0),
                "expected at least one empty list");
        let queries = Generator::new(Family::SiftLike, 55).generate(2, 3);
        let qs = qrefs(&queries);
        let cfg = SearchConfig { rerank_l: 30, k: 5, nprobe: 4,
                                 ..Default::default() };
        let got = batch(&ivf, &pq, &Executor::new(2), &qs, &[5, 5, 5], &cfg);
        for r in &got {
            assert_eq!(r.len(), 5);
        }
    }

    #[test]
    fn degenerate_fewer_rows_than_lists() {
        let (train, base, pq) = setup(1000);
        let tiny = base.prefix(7); // n = 7 < num_lists = 32
        let coarse = CoarseQuantizer::train(&train.data, train.dim, 32, 6, 5);
        let ivf = IvfIndex::build(&pq, &tiny, coarse, false);
        assert_eq!(ivf.n(), 7);
        let queries = Generator::new(Family::SiftLike, 55).generate(2, 2);
        let qs = qrefs(&queries);
        for nprobe in [1usize, 5, 32] {
            let cfg = SearchConfig { rerank_l: 10, k: 3, nprobe,
                                     ..Default::default() };
            let got = batch(&ivf, &pq, &Executor::Inline, &qs, &[3, 3], &cfg);
            for r in &got {
                assert!(r.len() <= 3);
                for &id in r {
                    assert!((id as usize) < 7);
                }
            }
        }
    }

    #[test]
    fn degenerate_whole_batch_probes_one_list() {
        // every query is (near-)identical → nprobe = 1 sends the whole
        // batch into the same list; the per-slot plan must still fan out
        // and merge correctly on a pool
        let (train, base, pq) = setup(3000);
        let coarse = CoarseQuantizer::train(&train.data, train.dim, 8, 7, 8);
        let ivf = IvfIndex::build(&pq, &base, coarse, false);
        let q0 = base.row(0).to_vec();
        let qs: Vec<&[f32]> = (0..6).map(|_| q0.as_slice()).collect();
        let cfg = SearchConfig { rerank_l: 40, k: 8, nprobe: 1,
                                 num_threads: 3, shard_rows: 64,
                                 ..Default::default() };
        let pool = Executor::new(3);
        let got = batch(&ivf, &pq, &pool, &qs, &[8; 6], &cfg);
        let want = batch(&ivf, &pq, &Executor::Inline, &qs, &[8; 6], &cfg);
        assert_eq!(got, want, "pool and inline must agree");
        for r in &got[1..] {
            assert_eq!(r, &got[0], "identical queries, identical results");
        }
    }

    #[test]
    fn degenerate_k_larger_than_n() {
        let (train, base, pq) = setup(1000);
        let tiny = base.prefix(12);
        let coarse = CoarseQuantizer::train(&train.data, train.dim, 4, 8, 5);
        let ivf = IvfIndex::build(&pq, &tiny, coarse, false);
        let queries = Generator::new(Family::SiftLike, 55).generate(2, 2);
        let qs = qrefs(&queries);
        let cfg = SearchConfig { rerank_l: 500, k: 100, nprobe: 0,
                                 ..Default::default() };
        let got = batch(&ivf, &pq, &Executor::Inline, &qs, &[100, 100], &cfg);
        for r in &got {
            assert_eq!(r.len(), 12, "k > n returns all rows");
            let mut ids = r.clone();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), 12, "no duplicate ids");
        }
    }

    #[test]
    fn filtered_ivf_search_matches_post_filter_oracle() {
        // the filtered-search contract on IVF (rust/DESIGN.md §13): at
        // nprobe = all with full rerank, filtered search must equal the
        // unfiltered full ranking post-filtered to admitted ids — per
        // scan precision, and through the residual path (tags ride the
        // remap permutation, so the bitmap must line up with stored rows)
        use crate::config::ScanPrecision;
        use crate::index::Filter;
        let (train, base, pq) = setup(2000);
        let n = 2000usize;
        let coarse = CoarseQuantizer::train(&train.data, train.dim, 10, 3, 8);
        let queries = Generator::new(Family::SiftLike, 55).generate(2, 4);
        let qs = qrefs(&queries);
        for residual in [false, true] {
            let mut ivf =
                IvfIndex::build(&pq, &base, coarse.clone(), residual);
            ivf.set_tags((0..n as u64).map(|i| i % 2).collect());
            ivf.ensure_packed();
            // oracle: unfiltered full ranking, post-filtered to odd ids
            let full_cfg = SearchConfig { rerank_l: n, k: n, nprobe: 0,
                                          ..Default::default() };
            let full = batch(&ivf, &pq, &Executor::Inline, &qs,
                             &vec![n; qs.len()], &full_cfg);
            let oracle: Vec<Vec<u32>> = full
                .iter()
                .map(|r| {
                    r.iter().copied().filter(|id| id % 2 == 1).take(10)
                        .collect()
                })
                .collect();
            let precisions: &[ScanPrecision] = if residual {
                &[ScanPrecision::F32, ScanPrecision::U16]
            } else {
                &[ScanPrecision::F32, ScanPrecision::U16,
                  ScanPrecision::U8, ScanPrecision::U4]
            };
            for &precision in precisions {
                let cfg = SearchConfig {
                    rerank_l: n, k: 10, nprobe: 0,
                    scan_precision: precision,
                    filter: Some(Filter::TagEq(1)),
                    ..Default::default()
                };
                let got = batch(&ivf, &pq, &Executor::new(2), &qs,
                                &vec![10; qs.len()], &cfg);
                assert_eq!(got, oracle,
                           "residual={residual} {precision:?}");
            }
            // partial probing keeps the predicate: every id admitted
            let part_cfg = SearchConfig { rerank_l: 40, k: 10, nprobe: 3,
                                          filter: Some(Filter::TagEq(1)),
                                          ..Default::default() };
            let part = batch(&ivf, &pq, &Executor::Inline, &qs,
                             &vec![10; qs.len()], &part_cfg);
            for r in &part {
                assert!(!r.is_empty(), "half the rows are admitted");
                assert!(r.iter().all(|id| id % 2 == 1),
                        "filtered result leaked an even id: {r:?}");
            }
            // selectivity 0: empty results, not a panic
            let none_cfg = SearchConfig { rerank_l: 40, k: 10, nprobe: 0,
                                          filter: Some(Filter::TagEq(9)),
                                          ..Default::default() };
            let none = batch(&ivf, &pq, &Executor::Inline, &qs,
                             &vec![10; qs.len()], &none_cfg);
            assert!(none.iter().all(Vec::is_empty), "tag 9 admits nothing");
        }
        // selectivity 1: a uniform tag column is bit-identical to the
        // unfiltered search
        let mut ivf = IvfIndex::build(&pq, &base, coarse, false);
        ivf.set_tags(vec![5u64; n]);
        let plain_cfg = SearchConfig { rerank_l: 50, k: 10, nprobe: 4,
                                       ..Default::default() };
        let want = batch(&ivf, &pq, &Executor::Inline, &qs,
                         &vec![10; qs.len()], &plain_cfg);
        let all_cfg = SearchConfig { filter: Some(Filter::TagEq(5)),
                                     ..plain_cfg };
        let got = batch(&ivf, &pq, &Executor::Inline, &qs,
                        &vec![10; qs.len()], &all_cfg);
        assert_eq!(got, want, "full-selectivity filter must be inert");
    }
}
