//! The coarse quantizer: a small k-means codebook whose cells are the
//! inverted lists.
//!
//! Unlike the fine quantizers in [`crate::quant`], the coarse codebook is
//! tiny (tens to thousands of centroids) and is consulted once per
//! database vector at build time and `num_lists` times per query at
//! search time — it never touches the scan hot path.  Training reuses
//! [`crate::kmeans`] (Lloyd + k-means++), the same workhorse behind every
//! shallow quantizer.

use crate::kmeans::{kmeans, nearest, KMeansConfig};
use crate::linalg::{sq_l2, TopK};

/// A trained coarse codebook: `num_lists` centroids of `dim` floats.
#[derive(Clone, Debug)]
pub struct CoarseQuantizer {
    pub dim: usize,
    /// `(num_lists, dim)` flat row-major centroids.
    pub centroids: Vec<f32>,
}

impl CoarseQuantizer {
    /// Train on flat rows (k-means over the training split).
    pub fn train(data: &[f32], dim: usize, num_lists: usize, seed: u64,
                 iters: usize) -> CoarseQuantizer {
        assert!(num_lists > 0, "at least one inverted list");
        let km = kmeans(data, dim, &KMeansConfig {
            k: num_lists,
            iters,
            seed,
        });
        CoarseQuantizer { dim, centroids: km.centroids }
    }

    /// Construct from explicit centroids (tests, loaded archives).
    pub fn from_centroids(dim: usize, centroids: Vec<f32>) -> CoarseQuantizer {
        assert!(dim > 0 && !centroids.is_empty()
                && centroids.len() % dim == 0,
                "centroids must be a non-empty (num_lists, dim) matrix");
        CoarseQuantizer { dim, centroids }
    }

    #[inline]
    pub fn num_lists(&self) -> usize {
        self.centroids.len() / self.dim
    }

    #[inline]
    pub fn centroid(&self, l: usize) -> &[f32] {
        &self.centroids[l * self.dim..(l + 1) * self.dim]
    }

    /// The list a vector belongs to (nearest centroid; ties resolve to
    /// the lowest list id — `kmeans::nearest` keeps the first strict
    /// minimum).
    #[inline]
    pub fn assign(&self, x: &[f32]) -> u32 {
        nearest(x, &self.centroids, self.dim).0
    }

    /// The `nprobe` nearest lists to a query, ordered by ascending
    /// `(distance, list id)` — deterministic under centroid-distance ties.
    pub fn nearest_lists(&self, q: &[f32], nprobe: usize) -> Vec<u32> {
        let nl = self.num_lists();
        let mut top = TopK::new(nprobe.min(nl).max(1));
        for l in 0..nl {
            top.push(sq_l2(q, self.centroid(l)), l as u32);
        }
        top.into_sorted().into_iter().map(|(_, l)| l).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid_coarse() -> CoarseQuantizer {
        // four 2-d centroids on a line
        CoarseQuantizer::from_centroids(
            2, vec![0.0, 0.0, 10.0, 0.0, 20.0, 0.0, 30.0, 0.0])
    }

    #[test]
    fn assign_picks_nearest_centroid() {
        let c = grid_coarse();
        assert_eq!(c.num_lists(), 4);
        assert_eq!(c.assign(&[1.0, 0.5]), 0);
        assert_eq!(c.assign(&[19.0, 0.0]), 2);
        // exactly between centroids 0 and 1: strict-less keeps list 0
        assert_eq!(c.assign(&[5.0, 0.0]), 0);
    }

    #[test]
    fn nearest_lists_orders_by_distance_then_id() {
        let c = grid_coarse();
        assert_eq!(c.nearest_lists(&[11.0, 0.0], 3), vec![1, 2, 0]);
        // nprobe clamps to num_lists
        assert_eq!(c.nearest_lists(&[0.0, 0.0], 99).len(), 4);
        // equidistant lists break ties by ascending id
        assert_eq!(c.nearest_lists(&[15.0, 0.0], 2), vec![1, 2]);
    }

    #[test]
    fn train_on_blobs_separates_them() {
        let mut data = Vec::new();
        for i in 0..60 {
            let j = (i % 5) as f32 * 0.01;
            data.extend_from_slice(&[j, j]);
            data.extend_from_slice(&[8.0 + j, 8.0 - j]);
        }
        let c = CoarseQuantizer::train(&data, 2, 2, 3, 10);
        assert_eq!(c.num_lists(), 2);
        let a = c.assign(&[0.0, 0.0]);
        let b = c.assign(&[8.0, 8.0]);
        assert_ne!(a, b, "blobs must land in different lists");
    }
}
