//! IVF persistence: trained coarse centroids + list layout + codes +
//! id-remap table in one [`Store`] archive.
//!
//! Entry names are prefixed `ivf_` so an IVF bundle can share an archive
//! with other tensors (e.g. the fine quantizer's codebooks).  The
//! id-remap and offset tables use the store's `u32` dtype; structural
//! scalars travel in a JSON meta entry.

use anyhow::{ensure, Context};

use crate::index::CompressedIndex;
use crate::store::Store;
use crate::util::json::Json;
use crate::Result;

use super::{CoarseQuantizer, IvfIndex};

impl IvfIndex {
    /// Write all index tensors + layout meta into `store`.
    pub fn save(&self, store: &mut Store) {
        let nl = self.num_lists();
        let dim = self.coarse.dim;
        store.put_f32("ivf_centroids", &[nl, dim],
                      self.coarse.centroids.clone());
        store.put_u8("ivf_codes", &[self.codes.n, self.codes.stride],
                     self.codes.codes.clone());
        store.put_u32("ivf_remap", &[self.remap.len()], self.remap.clone());
        let offsets32: Vec<u32> =
            self.offsets.iter().map(|&o| o as u32).collect();
        store.put_u32("ivf_offsets", &[offsets32.len()], offsets32);
        let meta = Json::obj(vec![
            ("residual", Json::Bool(self.residual)),
            ("num_lists", Json::Num(nl as f64)),
            ("dim", Json::Num(dim as f64)),
        ]);
        store.put_meta("ivf", &meta.render());
    }

    /// Reconstruct an index from an archive written by [`Self::save`].
    pub fn load(store: &Store) -> Result<IvfIndex> {
        let meta = store.get_meta("ivf").context("missing ivf meta")?;
        let meta = Json::parse(meta).context("parse ivf meta")?;
        let residual = meta
            .get("residual")
            .and_then(Json::as_bool)
            .context("ivf meta missing residual")?;
        let num_lists = meta.req_usize("num_lists")?;
        let dim = meta.req_usize("dim")?;

        let (cshape, cents) =
            store.get_f32("ivf_centroids").context("missing ivf_centroids")?;
        ensure!(cshape == [num_lists, dim],
                "ivf_centroids shape {cshape:?} != ({num_lists}, {dim})");
        let coarse = CoarseQuantizer::from_centroids(dim, cents.to_vec());

        let (kshape, codes) =
            store.get_u8("ivf_codes").context("missing ivf_codes")?;
        ensure!(kshape.len() == 2, "ivf_codes must be (n, stride)");
        let (n, stride) = (kshape[0], kshape[1]);

        let (_, remap) =
            store.get_u32("ivf_remap").context("missing ivf_remap")?;
        ensure!(remap.len() == n, "ivf_remap length {} != n {n}",
                remap.len());
        ensure!(remap.iter().all(|&id| (id as usize) < n),
                "ivf_remap has out-of-range ids (n = {n})");

        let (_, offsets32) =
            store.get_u32("ivf_offsets").context("missing ivf_offsets")?;
        ensure!(offsets32.len() == num_lists + 1,
                "ivf_offsets length {} != num_lists + 1", offsets32.len());
        let offsets: Vec<usize> =
            offsets32.iter().map(|&o| o as usize).collect();
        ensure!(offsets.first() == Some(&0),
                "ivf_offsets must start at 0");
        ensure!(offsets.last() == Some(&n),
                "ivf_offsets must end at n = {n}");
        // a corrupt layout must fail here, not panic rows-out-of-range
        // deep inside a scan worker
        ensure!(offsets.windows(2).all(|w| w[0] <= w[1] && w[1] <= n),
                "ivf_offsets must be non-decreasing and bounded by n");

        Ok(IvfIndex {
            coarse,
            residual,
            offsets,
            remap: remap.to_vec(),
            codes: CompressedIndex::from_codes(n, stride, codes.to_vec()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SearchConfig;
    use crate::data::{synthetic::Generator, Family};
    use crate::exec::Executor;
    use crate::quant::pq::Pq;
    use crate::util::TempDir;

    #[test]
    fn ivf_archive_roundtrip_preserves_search_results() {
        let gen = Generator::new(Family::SiftLike, 91);
        let train = gen.generate(0, 900);
        let base = gen.generate(1, 1500);
        let pq = Pq::train(&train.data, train.dim, 8, 32, 0, 6);
        let coarse = CoarseQuantizer::train(&train.data, train.dim, 8, 1, 6);
        let ivf = IvfIndex::build(&pq, &base, coarse, true);

        let dir = TempDir::new("ivf").unwrap();
        let path = dir.path().join("ivf.store");
        let mut store = Store::new();
        ivf.save(&mut store);
        store.save(&path).unwrap();

        let back = IvfIndex::load(&Store::load(&path).unwrap()).unwrap();
        assert_eq!(back.n(), ivf.n());
        assert_eq!(back.num_lists(), ivf.num_lists());
        assert_eq!(back.residual, ivf.residual);
        assert_eq!(back.offsets, ivf.offsets);
        assert_eq!(back.remap, ivf.remap);
        assert_eq!(back.codes.codes, ivf.codes.codes);

        let queries = gen.generate(2, 5);
        let qs: Vec<&[f32]> =
            (0..queries.len()).map(|qi| queries.row(qi)).collect();
        let cfg = SearchConfig { rerank_l: 40, k: 10, nprobe: 3,
                                 ..Default::default() };
        let req = crate::index::SearchRequest::from_config(&cfg, vec![10; 5]);
        let a = ivf
            .search_batch_on(&pq, &Executor::Inline, &qs, &req)
            .unwrap();
        let b = back
            .search_batch_on(&pq, &Executor::Inline, &qs, &req)
            .unwrap();
        assert_eq!(a, b, "loaded index must search identically");
    }

    #[test]
    fn load_rejects_torn_layout() {
        let gen = Generator::new(Family::SiftLike, 92);
        let train = gen.generate(0, 600);
        let base = gen.generate(1, 800);
        let pq = Pq::train(&train.data, train.dim, 8, 16, 0, 4);
        let coarse = CoarseQuantizer::train(&train.data, train.dim, 4, 2, 4);
        let ivf = IvfIndex::build(&pq, &base, coarse, false);
        let mut store = Store::new();
        ivf.save(&mut store);
        // a non-monotone offsets table must fail at load, not panic
        // rows-out-of-range inside a later scan
        let (shape, mut offs) = store.take_u32("ivf_offsets").unwrap();
        offs[1] = ivf.n() as u32 + 999;
        store.put_u32("ivf_offsets", &shape, offs);
        assert!(IvfIndex::load(&store).is_err());
        // drop the remap table: load must fail loudly, not mis-map ids
        store.take_u32("ivf_remap").unwrap();
        assert!(IvfIndex::load(&store).is_err());
    }
}
