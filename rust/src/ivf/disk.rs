//! Disk-resident IVF tier (rust/DESIGN.md §11): coarse routing in RAM,
//! per-list code blocks on disk, a byte-budgeted hot-list cache between
//! them.
//!
//! A [`DiskIvfIndex`] is the SPANN-style sibling of the RAM
//! [`IvfIndex`]: the coarse codebook, list offsets, and id remap stay
//! resident (a few MB even at billion scale), while the per-list code
//! matrices live in a [`crate::store::blocks`] archive — one block per
//! inverted list — and page in on demand through a
//! [`crate::store::cache::ListCache`].  A fetched list rebuilds its
//! full scan surface (flat codes, blocked [`PackedIndex`] mirror with
//! the U4 nibble twin when codes allow, per-row sketches), so every
//! `ScanPrecision` and the 1-bit pre-filter run against a cached list
//! exactly as they would against the RAM index.
//!
//! **Bit-identity contract.**  Search here must return exactly what
//! [`IvfIndex::search_batch_on`] returns, at every precision, nprobe,
//! executor shape, and cache budget — including budgets smaller than a
//! single list, where every batch re-reads its lists from disk.  The
//! argument:
//!
//! * Per-list scan tasks shard `[0, len)` with the same `shard_rows`
//!   the RAM planner derives from the *total* index size, and
//!   [`shard_ranges_in`] steps from the range start — so the relative
//!   decomposition of every list is identical to the RAM plan's
//!   `[offsets[l], offsets[l+1])` sharding.
//! * Local row `r` of list `l` is global row `offsets[l] + r`, so the
//!   remap to original ids is the same function.
//! * Per-slot partials merge in task-submission order, and each slot's
//!   tasks are emitted in ascending row order, so the `(score, id)`
//!   lexicographic reduction is decomposition-invariant exactly as on
//!   the RAM path.
//! * Residency planning reorders only *whole slots* (resident lists'
//!   tasks are enqueued ahead of freshly-fetched ones so warm data
//!   scans first); slot indices, not queue positions, address the
//!   result grid, so the reorder cannot change any result.
//!
//! **Arc-pinning.**  Every list a plan scans is held as an
//! `Arc<CompressedIndex>` for the whole search, so a concurrent search
//! thrashing the cache can evict the entry without ever invalidating
//! in-flight scans — eviction drops the cache's reference, never the
//! data.
//!
//! Fetch misses are batched per search: distinct missing lists are
//! read in ascending block order (one forward sweep of the archive)
//! under a `blockio` span, CRC-verified per block, and offered to the
//! cache (admission on second touch — see `store::cache`).  A CRC
//! mismatch or I/O failure surfaces as a typed error from search, not
//! a panic.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{ensure, Context};

use crate::config::SearchConfig;
use crate::exec::{shard_ranges_in, Executor, PrefilterPlan, ScanSpec,
                  ScanTask};
use crate::index::scan::merge_topk;
use crate::index::{CompressedIndex, FilterPlan, SearchRequest};
use crate::linalg::{sq_l2, TopK};
use crate::obs;
use crate::quant::{Lut, Quantizer, SketchPlanes};
use crate::store::blocks::{write_archive, BlockReader};
use crate::store::cache::ListCache;
use crate::util::json::Json;
use crate::Result;

use super::search::d1_residual;
use super::IvfIndex;

/// One stage-1 candidate: `(ADC score, original id, local row, list)`.
type Candidate = (f32, u32, u32, u32);

/// Cache stripes: enough to keep concurrent searches off each other's
/// locks without fragmenting tiny budgets into uselessly small shards.
const CACHE_SHARDS: usize = 8;

/// The disk-resident IVF backend: RAM routing state + a lazily-read
/// block archive + the hot-list cache.
pub struct DiskIvfIndex {
    pub coarse: super::CoarseQuantizer,
    pub residual: bool,
    /// List `l`'s rows are archive block `l + 1`, global rows
    /// `[offsets[l], offsets[l + 1])`.
    pub offsets: Vec<usize>,
    /// `remap[global_row]` = original database id.
    pub remap: Vec<u32>,
    n: usize,
    stride: usize,
    has_sketches: bool,
    has_tags: bool,
    reader: BlockReader,
    cache: ListCache<CompressedIndex>,
}

impl DiskIvfIndex {
    /// Serialize a built RAM [`IvfIndex`] into a block archive:
    /// block 0 = routing state (centroids ‖ remap ‖ offsets), block
    /// `l + 1` = list `l`'s codes (‖ its row sketches when built,
    /// ‖ its u64 metadata tags when attached).  Sketches and tags
    /// present at save time ride along so the pre-filter and metadata
    /// predicate filters work identically after a reload; the packed
    /// mirrors are *rebuilt* per list on fetch (they are derived
    /// data).
    pub fn save_archive(ivf: &IvfIndex, path: &Path) -> Result<()> {
        let nl = ivf.num_lists();
        let dim = ivf.coarse.dim;
        let n = ivf.n();
        let stride = ivf.codes.stride;
        let has_sketches = ivf.codes.sketches.is_some();
        let has_tags = ivf.codes.tags.is_some();

        let mut b0 =
            Vec::with_capacity(nl * dim * 4 + n * 4 + (nl + 1) * 8);
        for &c in &ivf.coarse.centroids {
            b0.extend_from_slice(&c.to_le_bytes());
        }
        for &id in &ivf.remap {
            b0.extend_from_slice(&id.to_le_bytes());
        }
        for &o in &ivf.offsets {
            b0.extend_from_slice(&(o as u64).to_le_bytes());
        }

        let mut payloads: Vec<Vec<u8>> = Vec::with_capacity(nl + 1);
        payloads.push(b0);
        for l in 0..nl {
            let (lo, hi) = (ivf.offsets[l], ivf.offsets[l + 1]);
            let per_row = stride
                + if has_sketches { 8 } else { 0 }
                + if has_tags { 8 } else { 0 };
            let mut b = Vec::with_capacity((hi - lo) * per_row);
            b.extend_from_slice(&ivf.codes.codes[lo * stride..hi * stride]);
            if let Some(sk) = &ivf.codes.sketches {
                for &s in &sk[lo..hi] {
                    b.extend_from_slice(&s.to_le_bytes());
                }
            }
            if let Some(tags) = &ivf.codes.tags {
                for &t in &tags[lo..hi] {
                    b.extend_from_slice(&t.to_le_bytes());
                }
            }
            payloads.push(b);
        }

        let meta = Json::obj(vec![
            ("kind", Json::Str("disk_ivf".into())),
            ("residual", Json::Bool(ivf.residual)),
            ("num_lists", Json::Num(nl as f64)),
            ("dim", Json::Num(dim as f64)),
            ("n", Json::Num(n as f64)),
            ("stride", Json::Num(stride as f64)),
            ("has_sketches", Json::Bool(has_sketches)),
            ("has_tags", Json::Bool(has_tags)),
        ]);
        let blocks: Vec<(&[u8], u64)> = payloads
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let rows = if i == 0 {
                    0
                } else {
                    (ivf.offsets[i] - ivf.offsets[i - 1]) as u64
                };
                (p.as_slice(), rows)
            })
            .collect();
        write_archive(path, &meta, &blocks)
    }

    /// Open an archive for lazy serving with a `cache_bytes` hot-list
    /// budget.  Block 0 (routing state) loads eagerly and every
    /// directory entry is cross-checked against the metadata, so a
    /// truncated or mislabeled archive fails here, not mid-query.
    pub fn open(path: &Path, cache_bytes: usize) -> Result<DiskIvfIndex> {
        let reader = BlockReader::open(path)?;
        let m = &reader.meta;
        ensure!(m.get("kind").and_then(Json::as_str) == Some("disk_ivf"),
                "not a disk_ivf archive: {path:?}");
        let field = |k: &str| {
            m.get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("meta field {k:?} in {path:?}"))
        };
        let nl = field("num_lists")?;
        let dim = field("dim")?;
        let n = field("n")?;
        let stride = field("stride")?;
        let residual = m.get("residual").and_then(Json::as_bool)
            .with_context(|| format!("meta field \"residual\" in {path:?}"))?;
        let has_sketches = m.get("has_sketches").and_then(Json::as_bool)
            .unwrap_or(false);
        // absent in pre-tag archives: those simply carry no tag column
        let has_tags = m.get("has_tags").and_then(Json::as_bool)
            .unwrap_or(false);
        ensure!(nl > 0 && dim > 0 && stride > 0,
                "degenerate disk_ivf meta in {path:?}");
        ensure!(reader.num_blocks() == nl + 1,
                "{path:?} has {} blocks, expected {} (1 routing + {nl} \
                 lists)", reader.num_blocks(), nl + 1);

        let b0 = reader.read_block(0)?;
        let want0 = nl * dim * 4 + n * 4 + (nl + 1) * 8;
        ensure!(b0.len() == want0,
                "routing block is {}B, expected {want0}B in {path:?}",
                b0.len());
        let mut centroids = Vec::with_capacity(nl * dim);
        let mut at = 0usize;
        for _ in 0..nl * dim {
            centroids.push(f32::from_le_bytes(
                b0[at..at + 4].try_into().unwrap()));
            at += 4;
        }
        let mut remap = Vec::with_capacity(n);
        for _ in 0..n {
            remap.push(u32::from_le_bytes(b0[at..at + 4].try_into().unwrap()));
            at += 4;
        }
        let mut offsets = Vec::with_capacity(nl + 1);
        for _ in 0..nl + 1 {
            let o = u64::from_le_bytes(b0[at..at + 8].try_into().unwrap());
            offsets.push(o as usize);
            at += 8;
        }
        ensure!(offsets.first() == Some(&0) && offsets.last() == Some(&n),
                "offsets must span [0, {n}] in {path:?}");
        ensure!(offsets.windows(2).all(|w| w[0] <= w[1]),
                "offsets must be non-decreasing in {path:?}");
        ensure!(remap.iter().all(|&id| (id as usize) < n),
                "remap ids must be < {n} in {path:?}");
        let row_bytes = stride
            + if has_sketches { 8 } else { 0 }
            + if has_tags { 8 } else { 0 };
        for l in 0..nl {
            let len = offsets[l + 1] - offsets[l];
            let e = reader.entry(l + 1);
            ensure!(e.rows == len as u64
                        && e.len == (len * row_bytes) as u64,
                    "list {l} block is {}B/{} rows, expected {}B/{len} \
                     rows in {path:?}", e.len, e.rows, len * row_bytes);
        }
        Ok(DiskIvfIndex {
            coarse: super::CoarseQuantizer::from_centroids(dim, centroids),
            residual,
            offsets,
            remap,
            n,
            stride,
            has_sketches,
            has_tags,
            reader,
            cache: ListCache::new(cache_bytes, CACHE_SHARDS),
        })
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn num_lists(&self) -> usize {
        self.coarse.num_lists()
    }

    #[inline]
    pub fn list_len(&self, l: usize) -> usize {
        self.offsets[l + 1] - self.offsets[l]
    }

    /// Code storage bytes (archive payload accounting, flat-equivalent).
    pub fn storage_bytes(&self) -> usize {
        self.n * self.stride
    }

    /// Resident hot-cache bytes right now (diagnostics).
    pub fn cache_bytes_resident(&self) -> usize {
        self.cache.bytes_resident()
    }

    /// Read list `l` from disk and rebuild its full scan surface:
    /// flat codes, packed fast-scan mirror (U4 nibble twin included
    /// when all codes fit), row sketches and metadata tags when
    /// archived.  Returns the value plus its resident-byte estimate
    /// for cache accounting.
    fn load_list(&self, l: usize) -> Result<(Arc<CompressedIndex>, usize)> {
        let len = self.list_len(l);
        let bytes = self.reader.read_block(l + 1)?;
        let code_bytes = len * self.stride;
        let mut ix = CompressedIndex::from_codes(
            len, self.stride, bytes[..code_bytes].to_vec());
        if self.has_sketches {
            let mut sk = Vec::with_capacity(len);
            for r in 0..len {
                let at = code_bytes + r * 8;
                sk.push(u64::from_le_bytes(
                    bytes[at..at + 8].try_into().unwrap()));
            }
            ix.sketches = Some(sk);
        }
        if self.has_tags {
            let base =
                code_bytes + if self.has_sketches { len * 8 } else { 0 };
            let mut tags = Vec::with_capacity(len);
            for r in 0..len {
                let at = base + r * 8;
                tags.push(u64::from_le_bytes(
                    bytes[at..at + 8].try_into().unwrap()));
            }
            ix.set_tags(tags);
        }
        ix.ensure_packed();
        let resident = ix.codes.len()
            + ix.packed.as_ref().map_or(0, |p| {
                p.data.len() + p.nibbles.as_ref().map_or(0, Vec::len)
            })
            + ix.sketches.as_ref().map_or(0, |s| s.len() * 8)
            + ix.tags.as_ref().map_or(0, |t| t.len() * 8);
        Ok((Arc::new(ix), resident))
    }

    /// Resolve every distinct probed list to an `Arc`'d scan surface:
    /// cache hits immediately, misses in one ascending-offset batched
    /// read pass (then offered to the cache).  The returned map pins
    /// every list for the caller's plan lifetime.
    fn fetch_lists(&self, probed: &[usize])
                   -> Result<HashMap<usize, (Arc<CompressedIndex>, bool)>> {
        let mut out: HashMap<usize, (Arc<CompressedIndex>, bool)> =
            HashMap::new();
        let mut misses: Vec<usize> = Vec::new();
        for &l in probed {
            if out.contains_key(&l) || misses.contains(&l) {
                continue;
            }
            match self.cache.get(l) {
                Some(arc) => {
                    out.insert(l, (arc, true));
                }
                None => misses.push(l),
            }
        }
        if !misses.is_empty() {
            // ascending list id == ascending file offset: the batched
            // miss I/O is one forward sweep of the archive
            misses.sort_unstable();
            let mut span = crate::span!("blockio");
            let mut bytes = 0u64;
            for &l in &misses {
                let (arc, resident) = self.load_list(l)?;
                bytes += self.reader.entry(l + 1).len;
                self.cache.insert(l, Arc::clone(&arc), resident);
                out.insert(l, (arc, false));
            }
            span.add_rows(bytes);
        }
        Ok(out)
    }

    /// Single-query convenience: a batch of one on the inline executor.
    pub fn search(&self, quant: &dyn Quantizer, q: &[f32],
                  cfg: &SearchConfig) -> Result<Vec<u32>> {
        let req = SearchRequest::from_config(cfg, vec![cfg.k]);
        Ok(self
            .search_batch_on(quant, &Executor::Inline, &[q], &req)?
            .pop()
            .expect("one query in, one result out"))
    }

    /// Batched two-stage `nprobe` search, bit-identical to
    /// [`IvfIndex::search_batch_on`] (see the module docs for the
    /// argument).  Errors surface I/O and CRC failures from the lazy
    /// block fetches; the RAM path has no failing stage.
    pub fn search_batch_on(&self, quant: &dyn Quantizer, exec: &Executor,
                           queries: &[&[f32]], req: &SearchRequest)
                           -> Result<Vec<Vec<u32>>> {
        let cfg = req.to_search_config();
        let ks: &[usize] = &req.ks;
        assert_eq!(queries.len(), ks.len(), "one k per query");
        if queries.is_empty() {
            return Ok(Vec::new());
        }
        let nl = self.num_lists();
        let nprobe = if cfg.nprobe == 0 { nl } else { cfg.nprobe.min(nl) };
        let do_rerank = !cfg.no_rerank && quant.supports_rerank();
        let ls: Vec<usize> = ks
            .iter()
            .map(|&k| {
                let l = if do_rerank { cfg.rerank_l.max(k) } else { k };
                l.max(1)
            })
            .collect();

        // coarse selection — identical to the RAM path
        let probes: Vec<Vec<u32>> = {
            let mut span = crate::span!("route");
            let probes: Vec<Vec<u32>> = queries
                .iter()
                .map(|q| self.coarse.nearest_lists(q, nprobe))
                .collect();
            let probed: usize = probes.iter().map(Vec::len).sum();
            obs::global().ivf_lists_probed.add(probed as u64);
            span.add_rows(probed as u64);
            probes
        };

        // one slot per non-empty (query, probed list), exactly the RAM
        // slot layout (residual LUTs per slot, shared per query else)
        let mut slot_query: Vec<usize> = Vec::new();
        let mut slot_list: Vec<usize> = Vec::new();
        let mut slot_ks: Vec<usize> = Vec::new();
        let mut slot_lut: Vec<usize> = Vec::new();
        let mut residual_qs: Vec<Vec<f32>> = Vec::new();
        for (qi, probe) in probes.iter().enumerate() {
            for &l in probe {
                let l = l as usize;
                if self.list_len(l) == 0 {
                    continue;
                }
                slot_lut.push(if self.residual {
                    let c = self.coarse.centroid(l);
                    residual_qs.push(
                        queries[qi].iter().zip(c).map(|(a, b)| a - b).collect());
                    residual_qs.len() - 1
                } else {
                    qi
                });
                slot_query.push(qi);
                slot_list.push(l);
                slot_ks.push(ls[qi]);
            }
        }
        let luts: Vec<Lut> = {
            let mut span = crate::span!("lut_build");
            let luts = if self.residual {
                obs::global().ivf_residual_luts
                    .add(residual_qs.len() as u64);
                let refs: Vec<&[f32]> =
                    residual_qs.iter().map(|v| v.as_slice()).collect();
                quant.lut_batch(&refs)
            } else {
                quant.lut_batch(queries)
            };
            span.add_rows(luts.len() as u64);
            luts
        };

        // residency: pin every probed list (cache hit or batched read)
        let fetched = self.fetch_lists(&slot_list)?;
        // index slab for the multi-index plan, resident lists first so
        // warm data is at the front of the pool queue
        let mut index_refs: Vec<&CompressedIndex> = Vec::new();
        let mut index_of: HashMap<usize, usize> = HashMap::new();
        for want_resident in [true, false] {
            for &l in &slot_list {
                if index_of.contains_key(&l) {
                    continue;
                }
                let (arc, resident) = &fetched[&l];
                if *resident == want_resident {
                    index_of.insert(l, index_refs.len());
                    index_refs.push(arc.as_ref());
                }
            }
        }

        // shard size derives from the WHOLE index, exactly like the
        // RAM planner, so each list's relative decomposition matches
        let es = exec.effective_shard_rows(self.n.max(1), cfg.shard_rows);
        // tasks: resident slots first, then miss slots; within a slot,
        // ascending row ranges (the determinism requirement)
        let mut tasks: Vec<ScanTask> = Vec::new();
        for want_resident in [true, false] {
            for (slot, &l) in slot_list.iter().enumerate() {
                if fetched[&l].1 != want_resident {
                    continue;
                }
                for (lo, hi) in shard_ranges_in(0, self.list_len(l), es) {
                    tasks.push(ScanTask {
                        index: index_of[&l], slot, lut: slot_lut[slot],
                        lo, hi,
                    });
                }
            }
        }
        // 1-bit pre-filter under the same engagement rule as RAM:
        // non-residual codes with archived sketches only
        let pre = if cfg.prefilter && !self.residual && self.has_sketches {
            let planes = SketchPlanes::for_dim(quant.dim());
            Some(PrefilterPlan {
                qsketches: queries
                    .iter()
                    .map(|q| Some(planes.sketch(q)))
                    .collect(),
                margin: cfg.prefilter_margin,
            })
        } else {
            None
        };
        // predicate bitmaps are compiled against the per-list slab in
        // `index_refs` order, so `ScanTask::index` addresses the right
        // bitmap; each fetched list carries its own tag column (strict
        // semantics: a tag-less archive admits no rows, like any other
        // frozen index — rust/DESIGN.md §13)
        let fplan =
            cfg.filter.map(|f| FilterPlan::compile(&f, &index_refs));
        let spec = ScanSpec {
            precision: cfg.scan_precision,
            prefilter: pre.as_ref(),
            filter: fplan.as_ref(),
        };
        let parts =
            exec.run_scan_tasks(&luts, &index_refs, &slot_ks, &tasks, &spec);

        // cross-list reduce: local rows lift to global through the
        // list base offset, then remap to original ids — the same
        // function of (list, row) the RAM reduce computes
        let mut parts_by_q: Vec<Vec<Vec<(f32, u32)>>> =
            (0..queries.len()).map(|_| Vec::new()).collect();
        let mut aux: Vec<HashMap<u32, (u32, u32)>> =
            (0..queries.len()).map(|_| HashMap::new()).collect();
        for (slot, part) in parts.into_iter().enumerate() {
            let (qi, l) = (slot_query[slot], slot_list[slot]);
            let base = self.offsets[l];
            let mapped: Vec<(f32, u32)> = part
                .into_iter()
                .map(|(score, row)| {
                    let id = self.remap[base + row as usize];
                    aux[qi].insert(id, (row, l as u32));
                    (score, id)
                })
                .collect();
            parts_by_q[qi].push(mapped);
        }
        let cands: Vec<Vec<Candidate>> = parts_by_q
            .into_iter()
            .enumerate()
            .map(|(qi, q_parts)| {
                merge_topk(q_parts, ls[qi])
                    .into_iter()
                    .map(|(score, id)| {
                        let (row, l) = aux[qi][&id];
                        (score, id, row, l)
                    })
                    .collect()
            })
            .collect();

        if !do_rerank {
            return Ok(cands
                .iter()
                .zip(ks)
                .map(|(c, &k)| c.iter().take(k).map(|p| p.1).collect())
                .collect());
        }
        Ok(self.rerank_batch(quant, queries, &cands, ks, &fetched))
    }

    /// Stage 2 over the pinned lists: gather candidate codes from each
    /// candidate's (still-`Arc`-pinned) list, decode with a single
    /// `reconstruct_batch` call, rank by exact `d1` — the RAM
    /// [`IvfIndex::search_batch_on`] rerank with local-row gathers.
    fn rerank_batch(&self, quant: &dyn Quantizer, queries: &[&[f32]],
                    cands: &[Vec<Candidate>], ks: &[usize],
                    fetched: &HashMap<usize, (Arc<CompressedIndex>, bool)>)
                    -> Vec<Vec<u32>> {
        let dim = quant.dim();
        let cb = self.stride;
        let total: usize = cands.iter().map(|c| c.len()).sum();
        let mut span = crate::span!("rerank");
        span.add_rows(total as u64);
        let mut codes = Vec::with_capacity(total * cb);
        for c in cands {
            for &(_, _, row, l) in c {
                codes.extend_from_slice(
                    fetched[&(l as usize)].0.code(row as usize));
            }
        }
        let mut recons = vec![0.0f32; total * dim];
        if !quant.reconstruct_batch(&codes, &mut recons) {
            // no decoder: keep scan order
            return cands
                .iter()
                .zip(ks)
                .map(|(c, &k)| c.iter().take(k).map(|p| p.1).collect())
                .collect();
        }
        let mut out = Vec::with_capacity(queries.len());
        let mut off = 0usize;
        for ((&q, c), &k) in queries.iter().zip(cands).zip(ks) {
            if c.is_empty() {
                out.push(Vec::new());
                continue;
            }
            let mut top = TopK::new(k.min(c.len()));
            for (ci, &(_, id, _, l)) in c.iter().enumerate() {
                let rec = &recons[(off + ci) * dim..(off + ci + 1) * dim];
                let d = if self.residual {
                    d1_residual(q, rec, self.coarse.centroid(l as usize))
                } else {
                    sq_l2(q, rec)
                };
                top.push(d, id);
            }
            off += c.len();
            out.push(top.into_sorted().into_iter().map(|(_, id)| id).collect());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ScanPrecision, SearchConfig};
    use crate::data::{synthetic::Generator, Dataset, Family};
    use crate::ivf::CoarseQuantizer;
    use crate::quant::pq::Pq;
    use crate::util::{prop, rng::SplitMix64, TempDir};

    /// 16-codeword PQ so `ScanPrecision::U4` exercises the real 4-bit
    /// kernel (nibble mirrors build: all codes < 16).
    fn setup16(n_base: usize) -> (Dataset, Dataset, Pq) {
        let gen = Generator::new(Family::SiftLike, 55);
        let train = gen.generate(0, 1200);
        let base = gen.generate(1, n_base);
        let pq = Pq::train(&train.data, train.dim, 8, 16, 0, 8);
        (train, base, pq)
    }

    fn qrefs(d: &Dataset) -> Vec<&[f32]> {
        (0..d.len()).map(|qi| d.row(qi)).collect()
    }

    /// RAM-side reference search through the request API.
    fn ram(ivf: &IvfIndex, pq: &Pq, exec: &Executor, qs: &[&[f32]],
           ks: &[usize], cfg: &SearchConfig) -> Vec<Vec<u32>> {
        let req = SearchRequest::from_config(cfg, ks.to_vec());
        ivf.search_batch_on(pq, exec, qs, &req).unwrap()
    }

    /// Disk-side search through the request API (fallible: block I/O).
    fn dsk(disk: &DiskIvfIndex, pq: &Pq, exec: &Executor, qs: &[&[f32]],
           ks: &[usize], cfg: &SearchConfig) -> Result<Vec<Vec<u32>>> {
        let req = SearchRequest::from_config(cfg, ks.to_vec());
        disk.search_batch_on(pq, exec, qs, &req)
    }

    fn save_ram(ivf: &IvfIndex, dir: &TempDir, name: &str)
                -> std::path::PathBuf {
        let path = dir.path().join(name);
        DiskIvfIndex::save_archive(ivf, &path).unwrap();
        path
    }

    #[test]
    fn archive_roundtrips_routing_state() {
        let (train, base, pq) = setup16(2000);
        let coarse = CoarseQuantizer::train(&train.data, train.dim, 10, 1, 8);
        let ivf = IvfIndex::build(&pq, &base, coarse, false);
        let dir = TempDir::new("diskivf").unwrap();
        let disk =
            DiskIvfIndex::open(&save_ram(&ivf, &dir, "a.blocks"), 1 << 20)
                .unwrap();
        assert_eq!(disk.n(), ivf.n());
        assert_eq!(disk.num_lists(), ivf.num_lists());
        assert_eq!(disk.offsets, ivf.offsets);
        assert_eq!(disk.remap, ivf.remap);
        assert_eq!(disk.coarse.centroids, ivf.coarse.centroids);
        assert!(!disk.residual);
        // per-list payloads reproduce the RAM code rows exactly
        for l in 0..disk.num_lists() {
            let (arc, _) = disk.load_list(l).unwrap();
            for r in 0..disk.list_len(l) {
                assert_eq!(arc.code(r),
                           ivf.codes.code(ivf.offsets[l] + r),
                           "list {l} row {r}");
            }
            assert!(arc.is_packed(), "fetched lists carry packed mirrors");
        }
    }

    #[test]
    fn prop_disk_bit_identical_to_ram_across_precision_nprobe_budget() {
        // THE acceptance property: at every (precision, nprobe, cache
        // budget, executor shape) — including budgets smaller than one
        // list — DiskIvfIndex returns exactly the RAM IvfIndex results.
        // Two searches per case: the second runs against whatever the
        // first left resident, so hits, misses, and evictions all mix.
        let (train, base, pq) = setup16(2500);
        let coarse = CoarseQuantizer::train(&train.data, train.dim, 12, 2, 8);
        let mut ivf = IvfIndex::build(&pq, &base, coarse, false);
        ivf.ensure_packed();
        let dir = TempDir::new("diskivf").unwrap();
        let path = save_ram(&ivf, &dir, "p.blocks");
        let queries = Generator::new(Family::SiftLike, 55).generate(2, 6);
        let qs = qrefs(&queries);
        prop::forall_ok(
            4242,
            12,
            |r: &mut SplitMix64| {
                let threads = 1 + r.below(3);
                let shard_rows = [0usize, 1, 37, 500][r.below(4)];
                let nprobe = [1usize, 3, 12, 0][r.below(4)];
                let prec = [ScanPrecision::F32, ScanPrecision::U16,
                            ScanPrecision::U8, ScanPrecision::U4]
                    [r.below(4)];
                // 64B: smaller than any list → pure-miss thrash path
                let budget = [64usize, 20 << 10, 4 << 20][r.below(3)];
                (threads, shard_rows, nprobe, prec, budget)
            },
            |&(threads, shard_rows, nprobe, prec, budget)| {
                let cfg = SearchConfig {
                    rerank_l: 40, k: 10, num_threads: threads, shard_rows,
                    nprobe, scan_precision: prec, ..Default::default()
                };
                let exec = Executor::new(threads);
                let ks = vec![cfg.k; qs.len()];
                let want = ram(&ivf, &pq, &exec, &qs, &ks, &cfg);
                let disk = DiskIvfIndex::open(&path, budget).unwrap();
                for round in 0..2 {
                    let got = dsk(&disk, &pq, &exec, &qs, &ks, &cfg)
                        .map_err(|e| format!("search failed: {e:#}"))?;
                    if got != want {
                        return Err(format!(
                            "round {round} threads={threads} \
                             shard_rows={shard_rows} nprobe={nprobe} \
                             {prec:?} budget={budget} diverged from RAM"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn residual_disk_matches_residual_ram() {
        let (train, base, pq) = setup16(1500);
        let coarse = CoarseQuantizer::train(&train.data, train.dim, 8, 3, 8);
        let ivf = IvfIndex::build(&pq, &base, coarse, true);
        let dir = TempDir::new("diskivf").unwrap();
        let path = save_ram(&ivf, &dir, "r.blocks");
        let queries = Generator::new(Family::SiftLike, 55).generate(2, 5);
        let qs = qrefs(&queries);
        let ks = vec![10usize; qs.len()];
        for nprobe in [2usize, 0] {
            let cfg = SearchConfig { rerank_l: 40, k: 10, nprobe,
                                     ..Default::default() };
            let want = ram(&ivf, &pq, &Executor::Inline, &qs, &ks, &cfg);
            let disk = DiskIvfIndex::open(&path, 1 << 20).unwrap();
            let got =
                dsk(&disk, &pq, &Executor::Inline, &qs, &ks, &cfg).unwrap();
            assert_eq!(got, want, "nprobe={nprobe}");
        }
    }

    #[test]
    fn prefilter_engages_identically_through_archived_sketches() {
        let (train, base, pq) = setup16(1800);
        let coarse = CoarseQuantizer::train(&train.data, train.dim, 9, 4, 8);
        let mut ivf = IvfIndex::build(&pq, &base, coarse, false);
        assert!(ivf.ensure_sketches(&pq), "PQ decodes, sketches must build");
        let dir = TempDir::new("diskivf").unwrap();
        let path = save_ram(&ivf, &dir, "s.blocks");
        let queries = Generator::new(Family::SiftLike, 55).generate(2, 4);
        let qs = qrefs(&queries);
        let ks = vec![10usize; qs.len()];
        for margin in [2usize, 10_000] {
            let cfg = SearchConfig { rerank_l: 40, k: 10, nprobe: 4,
                                     prefilter: true,
                                     prefilter_margin: margin,
                                     ..Default::default() };
            let want = ram(&ivf, &pq, &Executor::Inline, &qs, &ks, &cfg);
            let disk = DiskIvfIndex::open(&path, 1 << 20).unwrap();
            let got =
                dsk(&disk, &pq, &Executor::Inline, &qs, &ks, &cfg).unwrap();
            assert_eq!(got, want, "margin={margin}");
        }
    }

    #[test]
    fn corrupt_list_block_is_typed_search_error_not_panic() {
        let (train, base, pq) = setup16(1200);
        let coarse = CoarseQuantizer::train(&train.data, train.dim, 6, 5, 8);
        let ivf = IvfIndex::build(&pq, &base, coarse, false);
        let dir = TempDir::new("diskivf").unwrap();
        let path = save_ram(&ivf, &dir, "c.blocks");
        // flip a bit in the last list's payload (the file tail)
        let mut bytes = std::fs::read(&path).unwrap();
        let at = bytes.len() - 3;
        bytes[at] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();

        let disk = DiskIvfIndex::open(&path, 1 << 20).unwrap();
        let queries = Generator::new(Family::SiftLike, 55).generate(2, 3);
        let qs = qrefs(&queries);
        let ks = vec![5usize; qs.len()];
        // probing every list must hit the corrupted block
        let cfg = SearchConfig { rerank_l: 20, k: 5, nprobe: 0,
                                 ..Default::default() };
        let err =
            dsk(&disk, &pq, &Executor::Inline, &qs, &ks, &cfg).unwrap_err();
        assert!(format!("{err:#}").contains("crc mismatch"),
                "want a crc error, got: {err:#}");
    }

    #[test]
    fn concurrent_searches_under_eviction_stay_equal() {
        // several threads share ONE DiskIvfIndex whose budget holds
        // only a couple of lists: constant admission/eviction churn
        // while scans are in flight.  Arc-pinning must keep every
        // thread's results bit-identical to RAM throughout.
        let (train, base, pq) = setup16(2400);
        let coarse = CoarseQuantizer::train(&train.data, train.dim, 10, 6, 8);
        let ivf = IvfIndex::build(&pq, &base, coarse, false);
        let dir = TempDir::new("diskivf").unwrap();
        let path = save_ram(&ivf, &dir, "e.blocks");
        // ~2 lists' worth of budget (each list ≈ 240 rows × 8B codes,
        // doubled by the packed mirror and nibble twin)
        let disk = DiskIvfIndex::open(&path, 8 << 10).unwrap();
        let queries = Generator::new(Family::SiftLike, 55).generate(2, 6);
        let qs = qrefs(&queries);
        let ks = vec![8usize; qs.len()];
        let cfg = SearchConfig { rerank_l: 30, k: 8, nprobe: 3,
                                 ..Default::default() };
        let want = ram(&ivf, &pq, &Executor::Inline, &qs, &ks, &cfg);
        std::thread::scope(|s| {
            for t in 0..4 {
                let (disk, want, qs, ks, cfg, pq) =
                    (&disk, &want, &qs, &ks, &cfg, &pq);
                s.spawn(move || {
                    let exec = Executor::Inline;
                    for round in 0..6 {
                        let got =
                            dsk(disk, pq, &exec, qs, ks, cfg).unwrap();
                        assert_eq!(&got, want,
                                   "thread {t} round {round} diverged");
                    }
                });
            }
        });
    }

    #[test]
    fn filtered_disk_search_matches_filtered_ram_and_oracle() {
        use crate::index::Filter;
        // Tags ride the archive: filtered disk search must equal the
        // filtered RAM search bit-for-bit AND, at full probe + full
        // rerank, the unfiltered full ranking post-filtered to the
        // admitted ids — at every scan precision.
        let (train, base, pq) = setup16(2000);
        let coarse = CoarseQuantizer::train(&train.data, train.dim, 10, 8, 8);
        let mut ivf = IvfIndex::build(&pq, &base, coarse, false);
        let n = ivf.n();
        ivf.set_tags((0..n as u64).map(|i| i % 2).collect());
        ivf.ensure_packed();
        let dir = TempDir::new("diskivf").unwrap();
        let path = save_ram(&ivf, &dir, "f.blocks");
        let queries = Generator::new(Family::SiftLike, 55).generate(2, 5);
        let qs = qrefs(&queries);
        let ks = vec![10usize; qs.len()];

        for prec in [ScanPrecision::F32, ScanPrecision::U16,
                     ScanPrecision::U8, ScanPrecision::U4] {
            let cfg = SearchConfig { rerank_l: n, k: 10, nprobe: 0,
                                     scan_precision: prec,
                                     filter: Some(Filter::TagEq(1)),
                                     ..Default::default() };
            let oracle_cfg = SearchConfig { filter: None, ..cfg };
            let full = ram(&ivf, &pq, &Executor::Inline, &qs,
                           &vec![n; qs.len()], &oracle_cfg);
            let filtered_ram =
                ram(&ivf, &pq, &Executor::Inline, &qs, &ks, &cfg);
            // small budget: the second round mixes cache hits, misses
            // and evictions under the same predicate
            let disk = DiskIvfIndex::open(&path, 16 << 10).unwrap();
            for round in 0..2 {
                let filtered_disk =
                    dsk(&disk, &pq, &Executor::Inline, &qs, &ks, &cfg)
                        .unwrap();
                assert_eq!(filtered_disk, filtered_ram,
                           "{prec:?} round {round}: disk != RAM");
            }
            for (qi, got) in filtered_ram.iter().enumerate() {
                let want: Vec<u32> = full[qi]
                    .iter()
                    .copied()
                    .filter(|id| id % 2 == 1)
                    .take(10)
                    .collect();
                assert_eq!(got, &want, "{prec:?} query {qi}: != oracle");
            }
        }

        // partial probe: still bit-identical to filtered RAM, every
        // hit admitted
        let cfg = SearchConfig { rerank_l: 40, k: 10, nprobe: 3,
                                 filter: Some(Filter::TagEq(1)),
                                 ..Default::default() };
        let disk = DiskIvfIndex::open(&path, 1 << 20).unwrap();
        let got = dsk(&disk, &pq, &Executor::Inline, &qs, &ks, &cfg).unwrap();
        assert_eq!(got, ram(&ivf, &pq, &Executor::Inline, &qs, &ks, &cfg));
        for r in &got {
            assert!(!r.is_empty(), "partial probe still finds odd rows");
            assert!(r.iter().all(|id| id % 2 == 1), "inadmissible id");
        }

        // selectivity 0: empty results, never a panic
        let cfg = SearchConfig { rerank_l: 40, k: 10, nprobe: 0,
                                 filter: Some(Filter::TagEq(9)),
                                 ..Default::default() };
        let got = dsk(&disk, &pq, &Executor::Inline, &qs, &ks, &cfg).unwrap();
        assert!(got.iter().all(Vec::is_empty), "tag 9 admits nothing");
    }

    #[test]
    fn untagged_archive_admits_nothing_under_a_filter() {
        use crate::index::Filter;
        // Strict frozen-index semantics survive the disk round-trip: an
        // archive written without a tag column (has_tags absent/false)
        // admits no rows under any predicate, including TagEq(0).
        let (train, base, pq) = setup16(900);
        let coarse = CoarseQuantizer::train(&train.data, train.dim, 5, 9, 8);
        let ivf = IvfIndex::build(&pq, &base, coarse, false);
        let dir = TempDir::new("diskivf").unwrap();
        let disk =
            DiskIvfIndex::open(&save_ram(&ivf, &dir, "u.blocks"), 1 << 20)
                .unwrap();
        let queries = Generator::new(Family::SiftLike, 55).generate(2, 3);
        let qs = qrefs(&queries);
        let ks = vec![5usize; qs.len()];
        let cfg = SearchConfig { rerank_l: 20, k: 5, nprobe: 0,
                                 filter: Some(Filter::TagEq(0)),
                                 ..Default::default() };
        let got = dsk(&disk, &pq, &Executor::Inline, &qs, &ks, &cfg).unwrap();
        assert!(got.iter().all(Vec::is_empty),
                "no tag column ⇒ no admitted rows");
        // and without a predicate the same archive serves normally
        let cfg = SearchConfig { rerank_l: 20, k: 5, nprobe: 0,
                                 ..Default::default() };
        let got = dsk(&disk, &pq, &Executor::Inline, &qs, &ks, &cfg).unwrap();
        assert!(got.iter().all(|r| r.len() == 5));
    }

    #[test]
    fn tiny_budget_never_caches_but_still_answers() {
        let (train, base, pq) = setup16(1000);
        let coarse = CoarseQuantizer::train(&train.data, train.dim, 5, 7, 8);
        let ivf = IvfIndex::build(&pq, &base, coarse, false);
        let dir = TempDir::new("diskivf").unwrap();
        let path = save_ram(&ivf, &dir, "t.blocks");
        let disk = DiskIvfIndex::open(&path, 1).unwrap();
        let queries = Generator::new(Family::SiftLike, 55).generate(2, 3);
        let qs = qrefs(&queries);
        let ks = vec![5usize; qs.len()];
        let cfg = SearchConfig { rerank_l: 20, k: 5, nprobe: 0,
                                 ..Default::default() };
        let want = ram(&ivf, &pq, &Executor::Inline, &qs, &ks, &cfg);
        for _ in 0..3 {
            let got =
                dsk(&disk, &pq, &Executor::Inline, &qs, &ks, &cfg).unwrap();
            assert_eq!(got, want);
            assert_eq!(disk.cache_bytes_resident(), 0,
                       "1-byte budget must never admit a list");
        }
    }
}
