//! Typed configuration system.
//!
//! Every binary (CLI, examples, benches) shares one [`AppConfig`],
//! loadable from a JSON file with environment overrides — the usual
//! launcher pattern: defaults ← config file ← env ← CLI flags.
//! (Serialization runs over the in-crate [`crate::util::json`] substrate;
//! serde/toml are unavailable on this offline testbed.)

use std::path::{Path, PathBuf};

use anyhow::{bail, Context};

use crate::util::json::Json;
use crate::Result;

/// Which quantizer a run uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QuantizerKind {
    Pq,
    Opq,
    Rvq,
    Lsq,
    LsqRerank,
    CatalystLattice,
    CatalystOpq,
    Unq,
    /// The paper's method trained natively in Rust (`quant::unq_native`)
    /// — no PJRT runtime or AOT artifacts involved.
    UnqNative,
}

impl QuantizerKind {
    pub fn all() -> &'static [QuantizerKind] {
        use QuantizerKind::*;
        &[Pq, Opq, Rvq, Lsq, LsqRerank, CatalystLattice, CatalystOpq, Unq,
          UnqNative]
    }

    /// Paper row label.
    pub fn name(&self) -> &'static str {
        match self {
            QuantizerKind::Pq => "PQ",
            QuantizerKind::Opq => "OPQ",
            QuantizerKind::Rvq => "RVQ",
            QuantizerKind::Lsq => "LSQ",
            QuantizerKind::LsqRerank => "LSQ+rerank",
            QuantizerKind::CatalystLattice => "Catalyst+Lattice",
            QuantizerKind::CatalystOpq => "Catalyst+OPQ",
            QuantizerKind::Unq => "UNQ",
            QuantizerKind::UnqNative => "UNQ-native",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        let norm = s.to_ascii_lowercase().replace(['-', '_', '+'], "");
        Some(match norm.as_str() {
            "pq" => QuantizerKind::Pq,
            "opq" => QuantizerKind::Opq,
            "rvq" => QuantizerKind::Rvq,
            "lsq" => QuantizerKind::Lsq,
            "lsqrerank" => QuantizerKind::LsqRerank,
            "catalystlattice" | "lattice" => QuantizerKind::CatalystLattice,
            "catalystopq" => QuantizerKind::CatalystOpq,
            "unq" => QuantizerKind::Unq,
            "unqnative" | "nativeunq" | "native" => QuantizerKind::UnqNative,
            _ => return None,
        })
    }
}

/// Arithmetic of the ADC scan stage (rust/DESIGN.md §6).
///
/// `F32` is the exact reference kernel (the paper's setup).  `U16`/`U8`
/// quantize each per-query LUT to integer entries, select candidates
/// with a blocked integer kernel over the packed code layout, and
/// exactly re-score the survivors in f32 — returned rankings match the
/// f32 kernel whenever score margins exceed the quantization step, at a
/// multiple of its scan throughput.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ScanPrecision {
    /// Exact f32 table gathers (the default: the unchanged scan
    /// kernel; results are `(num_threads, shard_rows)`-invariant, with
    /// exact score ties anywhere in the engine resolving to the
    /// smallest id — see `linalg::TopK`).
    #[default]
    F32,
    /// 16-bit LUT entries, u32 accumulation: near-lossless selection.
    U16,
    /// 8-bit LUT entries: coarsest, fastest; boundary swaps are bounded
    /// by `stride · step / 2` in score units.
    U8,
    /// 4-bit codes against 16-entry 8-bit LUT rows gathered in-register
    /// (PSHUFB/TBL — the FAISS fast-scan idiom, rust/DESIGN.md §9).
    /// Applies when the quantizer uses ≤ 16 codewords per position;
    /// wider codebooks fall back to the exact f32 kernel.
    U4,
}

impl ScanPrecision {
    pub fn all() -> &'static [ScanPrecision] {
        &[ScanPrecision::F32, ScanPrecision::U16, ScanPrecision::U8,
          ScanPrecision::U4]
    }

    pub fn name(&self) -> &'static str {
        match self {
            ScanPrecision::F32 => "f32",
            ScanPrecision::U16 => "u16",
            ScanPrecision::U8 => "u8",
            ScanPrecision::U4 => "u4",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float" | "exact" => Some(ScanPrecision::F32),
            "u16" | "uint16" | "16" => Some(ScanPrecision::U16),
            "u8" | "uint8" | "8" => Some(ScanPrecision::U8),
            "u4" | "uint4" | "4" | "nibble" => Some(ScanPrecision::U4),
            _ => None,
        }
    }
}

/// Search-time parameters (paper §3.3/§4: two-stage search).
#[derive(Clone, Copy, Debug)]
pub struct SearchConfig {
    /// Candidates taken from the ADC scan for reranking (paper: 500 at 1M
    /// scale, 1000 at 1B scale).
    pub rerank_l: usize,
    /// Final neighbors returned.
    pub k: usize,
    /// Disable the rerank stage (Table 5 "No reranking").
    pub no_rerank: bool,
    /// Rerank *everything* with d1 (Table 5 "Exhaustive reranking").
    pub exhaustive_rerank: bool,
    /// Scan worker threads for `SearchEngine::search_batch`; 1 runs the
    /// plan inline on the calling thread (the classic path).
    pub num_threads: usize,
    /// Rows per index shard in the executor's scan plan; 0 = auto (whole
    /// index inline, ~4 shards per worker on a pool).
    pub shard_rows: usize,
    /// Inverted lists probed per query on the IVF backend; 0 = probe all
    /// lists (the flat-equivalent degenerate case).  Ignored by the flat
    /// backend.
    pub nprobe: usize,
    /// ADC scan kernel arithmetic: exact f32 (default) or blocked
    /// integer fast-scan at u16/u8/u4 LUT entries with exact rescoring
    /// (rust/DESIGN.md §6/§9; env `UNQ_SCAN_PRECISION`,
    /// CLI `--precision`).
    pub scan_precision: ScanPrecision,
    /// 1-bit sign-sketch pre-filter before the scan (rust/DESIGN.md §9):
    /// prunes to ~`k · prefilter_margin` sketch-nearest candidates by
    /// XOR+popcount, then scores survivors exactly.  Requires sketches
    /// on the index (`ensure_sketches`) — silently a no-op where absent
    /// (env `UNQ_PREFILTER`, CLI `--prefilter`).
    pub prefilter: bool,
    /// Over-fetch margin of the pre-filter: candidates kept per scan
    /// task ≈ `k × this` (env `UNQ_PREFILTER_MARGIN`,
    /// CLI `--prefilter-margin`).
    pub prefilter_margin: usize,
    /// Metadata predicate over the row attribute column
    /// (rust/DESIGN.md §13): only rows whose tag satisfies the filter
    /// are eligible — pruned *inside* the scan's selection loop, at
    /// every backend and precision.  Strict semantics: filtering an
    /// index with no attribute column matches nothing (env
    /// `UNQ_FILTER=tag=V`, CLI `--filter tag=V`).
    pub filter: Option<crate::index::Filter>,
    /// Per-query span tracing (rust/DESIGN.md §10): when on, searches
    /// build a span tree (route → scan → rerank …) rendered as EXPLAIN
    /// by `unq search --explain` and attached to coordinator responses.
    /// Off = one relaxed atomic load per span site (env `UNQ_TRACE`).
    pub trace: bool,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig { rerank_l: 500, k: 100, no_rerank: false,
                       exhaustive_rerank: false, num_threads: 1,
                       shard_rows: 0, nprobe: 0,
                       scan_precision: ScanPrecision::F32,
                       prefilter: false, prefilter_margin: 4,
                       filter: None, trace: false }
    }
}

/// Which index organization serves queries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IndexBackendKind {
    /// Exhaustive ADC scan over one flat code matrix.
    Flat,
    /// IVF: coarse k-means partition, scan only the `nprobe` nearest
    /// inverted lists per query.
    Ivf,
    /// Disk-resident IVF: routing state in RAM, per-list code blocks
    /// paged from an offset-addressable archive through a byte-budgeted
    /// hot-list cache (rust/DESIGN.md §11).  Bit-identical results to
    /// `Ivf` at every precision/nprobe — only residency differs.
    DiskIvf,
}

impl IndexBackendKind {
    pub fn name(&self) -> &'static str {
        match self {
            IndexBackendKind::Flat => "flat",
            IndexBackendKind::Ivf => "ivf",
            IndexBackendKind::DiskIvf => "disk-ivf",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "flat" => Some(IndexBackendKind::Flat),
            "ivf" => Some(IndexBackendKind::Ivf),
            "disk-ivf" | "disk_ivf" | "diskivf" | "disk" => {
                Some(IndexBackendKind::DiskIvf)
            }
            _ => None,
        }
    }
}

/// IVF index-construction parameters (build-time; `nprobe` in
/// [`SearchConfig`] is the query-time knob).
#[derive(Clone, Copy, Debug)]
pub struct IvfConfig {
    /// Which backend `unq eval` / `unq serve` build and query.
    pub backend: IndexBackendKind,
    /// Coarse codebook size (number of inverted lists).
    pub num_lists: usize,
    /// Encode `x − centroid(x)` instead of `x` (classic IVFADC; any
    /// `quant` backend plugs in unchanged).  Off by default: the stock
    /// harness trains fine quantizers on *raw* vectors, and residual
    /// codes only pay off with a residual-trained quantizer
    /// (rust/DESIGN.md §5) — opt in via `--residual` / `UNQ_RESIDUAL=1`.
    pub residual: bool,
    /// Hot-list cache byte budget for the disk-resident backend, in
    /// MiB (env `UNQ_CACHE_MB`, CLI `--cache-mb`).  Ignored by the
    /// RAM backends.
    pub cache_mb: usize,
}

impl Default for IvfConfig {
    fn default() -> Self {
        IvfConfig { backend: IndexBackendKind::Flat, num_lists: 64,
                    residual: false, cache_mb: 64 }
    }
}

/// Mutable streaming-index parameters (build/write-path knobs; the
/// read path keeps using [`SearchConfig`]).  See `rust/DESIGN.md` §7.
#[derive(Clone, Copy, Debug)]
pub struct StreamConfig {
    /// Active-segment seal threshold: once the tail holds this many
    /// rows it is packed and frozen (env `UNQ_SEGMENT_ROWS`,
    /// CLI `--segment-rows`).
    pub segment_rows: usize,
    /// Sealed-segment count that triggers online compaction (merge +
    /// tombstone drop + repack; env `UNQ_COMPACT_SEGMENTS`,
    /// CLI `--compact-segments`).
    pub compact_segments: usize,
    /// WAL records per fsync batch; 1 syncs every record
    /// (env `UNQ_WAL_SYNC`).
    pub wal_sync: usize,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig { segment_rows: 4096, compact_segments: 4,
                       wal_sync: 64 }
    }
}

/// Training hyperparameters of the native (pure-Rust) UNQ quantizer
/// (`quant::unq_native`, rust/DESIGN.md §8).  These are *build-time*
/// knobs: they key nothing in the runs cache, so changing them without
/// clearing `runs/` reuses the previously trained model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct UnqNativeConfig {
    /// Hidden width of the encoder/decoder correction MLPs.
    pub hidden: usize,
    /// Per-codebook code sub-dimension; 0 = `dim / m` (the PQ-aligned
    /// default, requires `dim % m == 0`).
    pub ds: usize,
    /// Training epochs over the train split (0 = keep the k-means
    /// initialized, PQ-equivalent starting point).
    pub epochs: usize,
    /// Minibatch rows.
    pub batch: usize,
    /// Adam learning rate.
    pub lr: f32,
    /// Gumbel-softmax temperature, annealed linearly `tau0 → tau1`.
    pub tau0: f32,
    pub tau1: f32,
    /// Weight of the compressed-domain consistency term
    /// `‖net(x)_m − c_m‖²` against the reconstruction MSE.
    pub lambda_cons: f32,
    /// Gumbel exploration-noise scale (0 disables the noise; assignment
    /// becomes plain softmax straight-through).
    pub gumbel: f32,
    /// Lloyd iterations for the codebook initialization k-means.
    pub kmeans_iters: usize,
    /// Seed for init, shuffling and Gumbel noise (full determinism).
    pub seed: u64,
}

impl Default for UnqNativeConfig {
    fn default() -> Self {
        UnqNativeConfig { hidden: 128, ds: 0, epochs: 8, batch: 128,
                          lr: 1e-3, tau0: 1.0, tau1: 0.25,
                          lambda_cons: 0.25, gumbel: 1.0, kmeans_iters: 10,
                          seed: 0 }
    }
}

/// Serving parameters for the coordinator.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Max queries coalesced into one LUT batch.
    pub max_batch: usize,
    /// Batching deadline in microseconds: a partial batch flushes after
    /// this long even if not full.
    pub max_delay_us: u64,
    /// Bounded request-queue depth (backpressure boundary).
    pub queue_depth: usize,
    /// Executor pool size for the coordinator's batch scan (1 = inline).
    pub num_threads: usize,
    /// Rows per scan shard handed to the executor (0 = auto).
    pub shard_rows: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { max_batch: 16, max_delay_us: 2000, queue_depth: 1024,
                      num_threads: 1, shard_rows: 0 }
    }
}

/// One tenant's admission-control budget on the network front door
/// (rust/DESIGN.md §12, rust/SERVING.md).  A tenant is a quota
/// namespace: requests carry a tenant name and are admitted against
/// that tenant's token bucket and insert-byte budget before they reach
/// the coordinator queue.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TenantQuota {
    pub name: String,
    /// Sustained search/insert/delete requests per second (token
    /// bucket with a 1-second burst capacity); 0 = unlimited.
    pub max_qps: u64,
    /// Lifetime insert-payload budget in bytes (vector data only);
    /// 0 = unlimited.  Exceeding it answers `QuotaExceeded`.
    pub max_insert_bytes: u64,
}

impl TenantQuota {
    /// An unlimited tenant (the implicit `"default"` namespace).
    pub fn unlimited(name: &str) -> Self {
        TenantQuota { name: name.to_string(), max_qps: 0,
                      max_insert_bytes: 0 }
    }

    /// Parse one `name:max_qps:max_insert_bytes` spec (the `UNQ_TENANTS`
    /// / `--tenants` wire format; both numbers optional).
    pub fn parse_spec(spec: &str) -> Option<Self> {
        let mut parts = spec.split(':');
        let name = parts.next()?.trim();
        if name.is_empty() {
            return None;
        }
        let num = |p: Option<&str>| -> Option<u64> {
            match p {
                None | Some("") => Some(0),
                Some(s) => s.trim().parse().ok(),
            }
        };
        let max_qps = num(parts.next())?;
        let max_insert_bytes = num(parts.next())?;
        if parts.next().is_some() {
            return None;
        }
        Some(TenantQuota { name: name.to_string(), max_qps,
                           max_insert_bytes })
    }
}

/// Network front door (rust/src/net/, rust/PROTOCOL.md): the TCP
/// listener, per-connection pipelining depth, and tenant quotas.
#[derive(Clone, Debug, PartialEq)]
pub struct NetConfig {
    /// Listen address for `unq serve --listen` (port 0 = ephemeral).
    pub listen: String,
    /// Acceptor threads; 0 = one per available core (thread-per-core).
    pub io_threads: usize,
    /// Concurrent connections admitted; the next one is answered
    /// `Overloaded` and closed.
    pub max_conns: usize,
    /// Pipelined requests in flight per connection before the server
    /// answers `Overloaded` instead of queueing (admission control —
    /// never queue-blocking; rust/DESIGN.md §12).
    pub max_inflight: usize,
    /// Largest accepted frame payload in bytes; larger frames are
    /// answered `FrameTooLarge` and the connection is closed.
    pub max_frame: usize,
    /// Per-write timeout on response frames in ms: a reader stalled
    /// longer than this is disconnected rather than allowed to pin
    /// server memory (slow-reader backpressure).
    pub write_timeout_ms: u64,
    /// Tenant quota table; empty = one unlimited `"default"` tenant.
    pub tenants: Vec<TenantQuota>,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig { listen: "127.0.0.1:7009".into(), io_threads: 0,
                    max_conns: 256, max_inflight: 64,
                    max_frame: 1 << 24, write_timeout_ms: 5000,
                    tenants: Vec::new() }
    }
}

/// Root configuration.
#[derive(Clone, Debug)]
pub struct AppConfig {
    /// Dataset name from the catalog (deep1m, sift1m, ...).
    pub dataset: String,
    /// Quantizer under test.
    pub quantizer: QuantizerKind,
    /// Bytes per vector (8 or 16 in the paper).
    pub bytes_per_vector: usize,
    /// Codebook size K (paper: 256 everywhere).
    pub k_codewords: usize,
    pub search: SearchConfig,
    pub serve: ServeConfig,
    pub net: NetConfig,
    pub ivf: IvfConfig,
    pub stream: StreamConfig,
    pub unq_native: UnqNativeConfig,
    /// Directory roots (relative to CWD unless absolute).
    pub data_dir: PathBuf,
    pub artifacts_dir: PathBuf,
    pub runs_dir: PathBuf,
    /// Dataset scale multiplier (UNQ_SCALE env for quick runs).
    pub scale: f64,
}

impl Default for AppConfig {
    fn default() -> Self {
        AppConfig {
            dataset: "sift1m".into(),
            quantizer: QuantizerKind::Unq,
            bytes_per_vector: 8,
            k_codewords: 256,
            search: SearchConfig::default(),
            serve: ServeConfig::default(),
            net: NetConfig::default(),
            ivf: IvfConfig::default(),
            stream: StreamConfig::default(),
            unq_native: UnqNativeConfig::default(),
            data_dir: "data".into(),
            artifacts_dir: "artifacts".into(),
            runs_dir: "runs".into(),
            scale: 1.0,
        }
    }
}

impl AppConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dataset", Json::Str(self.dataset.clone())),
            ("quantizer", Json::Str(self.quantizer.name().to_string())),
            ("bytes_per_vector", Json::Num(self.bytes_per_vector as f64)),
            ("k_codewords", Json::Num(self.k_codewords as f64)),
            ("search", Json::obj(vec![
                ("rerank_l", Json::Num(self.search.rerank_l as f64)),
                ("k", Json::Num(self.search.k as f64)),
                ("no_rerank", Json::Bool(self.search.no_rerank)),
                ("exhaustive_rerank", Json::Bool(self.search.exhaustive_rerank)),
                ("num_threads", Json::Num(self.search.num_threads as f64)),
                ("shard_rows", Json::Num(self.search.shard_rows as f64)),
                ("nprobe", Json::Num(self.search.nprobe as f64)),
                ("scan_precision",
                 Json::Str(self.search.scan_precision.name().to_string())),
                ("prefilter", Json::Bool(self.search.prefilter)),
                ("prefilter_margin",
                 Json::Num(self.search.prefilter_margin as f64)),
                ("filter", match self.search.filter {
                    Some(f) => Json::Str(f.to_string()),
                    None => Json::Null,
                }),
                ("trace", Json::Bool(self.search.trace)),
            ])),
            ("ivf", Json::obj(vec![
                ("backend", Json::Str(self.ivf.backend.name().to_string())),
                ("num_lists", Json::Num(self.ivf.num_lists as f64)),
                ("residual", Json::Bool(self.ivf.residual)),
                ("cache_mb", Json::Num(self.ivf.cache_mb as f64)),
            ])),
            ("stream", Json::obj(vec![
                ("segment_rows", Json::Num(self.stream.segment_rows as f64)),
                ("compact_segments",
                 Json::Num(self.stream.compact_segments as f64)),
                ("wal_sync", Json::Num(self.stream.wal_sync as f64)),
            ])),
            ("unq_native", Json::obj(vec![
                ("hidden", Json::Num(self.unq_native.hidden as f64)),
                ("ds", Json::Num(self.unq_native.ds as f64)),
                ("epochs", Json::Num(self.unq_native.epochs as f64)),
                ("batch", Json::Num(self.unq_native.batch as f64)),
                ("lr", Json::Num(self.unq_native.lr as f64)),
                ("tau0", Json::Num(self.unq_native.tau0 as f64)),
                ("tau1", Json::Num(self.unq_native.tau1 as f64)),
                ("lambda_cons", Json::Num(self.unq_native.lambda_cons as f64)),
                ("gumbel", Json::Num(self.unq_native.gumbel as f64)),
                ("kmeans_iters",
                 Json::Num(self.unq_native.kmeans_iters as f64)),
                ("seed", Json::Num(self.unq_native.seed as f64)),
            ])),
            ("serve", Json::obj(vec![
                ("max_batch", Json::Num(self.serve.max_batch as f64)),
                ("max_delay_us", Json::Num(self.serve.max_delay_us as f64)),
                ("queue_depth", Json::Num(self.serve.queue_depth as f64)),
                ("num_threads", Json::Num(self.serve.num_threads as f64)),
                ("shard_rows", Json::Num(self.serve.shard_rows as f64)),
            ])),
            ("net", Json::obj(vec![
                ("listen", Json::Str(self.net.listen.clone())),
                ("io_threads", Json::Num(self.net.io_threads as f64)),
                ("max_conns", Json::Num(self.net.max_conns as f64)),
                ("max_inflight", Json::Num(self.net.max_inflight as f64)),
                ("max_frame", Json::Num(self.net.max_frame as f64)),
                ("write_timeout_ms",
                 Json::Num(self.net.write_timeout_ms as f64)),
                ("tenants", Json::Arr(
                    self.net.tenants.iter()
                        .map(|t| Json::obj(vec![
                            ("name", Json::Str(t.name.clone())),
                            ("max_qps", Json::Num(t.max_qps as f64)),
                            ("max_insert_bytes",
                             Json::Num(t.max_insert_bytes as f64)),
                        ]))
                        .collect())),
            ])),
            ("data_dir", Json::Str(self.data_dir.display().to_string())),
            ("artifacts_dir", Json::Str(self.artifacts_dir.display().to_string())),
            ("runs_dir", Json::Str(self.runs_dir.display().to_string())),
            ("scale", Json::Num(self.scale)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut cfg = AppConfig::default();
        if let Some(v) = j.get("dataset").and_then(Json::as_str) {
            cfg.dataset = v.to_string();
        }
        if let Some(v) = j.get("quantizer").and_then(Json::as_str) {
            cfg.quantizer = QuantizerKind::parse(v)
                .with_context(|| format!("unknown quantizer {v:?}"))?;
        }
        if let Some(v) = j.get("bytes_per_vector").and_then(Json::as_usize) {
            cfg.bytes_per_vector = v;
        }
        if let Some(v) = j.get("k_codewords").and_then(Json::as_usize) {
            cfg.k_codewords = v;
        }
        if let Some(s) = j.get("search") {
            if let Some(v) = s.get("rerank_l").and_then(Json::as_usize) {
                cfg.search.rerank_l = v;
            }
            if let Some(v) = s.get("k").and_then(Json::as_usize) {
                cfg.search.k = v;
            }
            if let Some(v) = s.get("no_rerank").and_then(Json::as_bool) {
                cfg.search.no_rerank = v;
            }
            if let Some(v) = s.get("exhaustive_rerank").and_then(Json::as_bool) {
                cfg.search.exhaustive_rerank = v;
            }
            if let Some(v) = s.get("num_threads").and_then(Json::as_usize) {
                cfg.search.num_threads = v;
            }
            if let Some(v) = s.get("shard_rows").and_then(Json::as_usize) {
                cfg.search.shard_rows = v;
            }
            if let Some(v) = s.get("nprobe").and_then(Json::as_usize) {
                cfg.search.nprobe = v;
            }
            if let Some(v) = s.get("scan_precision").and_then(Json::as_str) {
                cfg.search.scan_precision = ScanPrecision::parse(v)
                    .with_context(|| format!("unknown scan precision {v:?}"))?;
            }
            if let Some(v) = s.get("prefilter").and_then(Json::as_bool) {
                cfg.search.prefilter = v;
            }
            if let Some(v) = s.get("prefilter_margin").and_then(Json::as_usize)
            {
                cfg.search.prefilter_margin = v;
            }
            if let Some(v) = s.get("filter").and_then(Json::as_str) {
                cfg.search.filter =
                    Some(crate::index::Filter::parse(v).map_err(|e| {
                        anyhow::anyhow!("search.filter: {e}")
                    })?);
            }
            if let Some(v) = s.get("trace").and_then(Json::as_bool) {
                cfg.search.trace = v;
            }
        }
        if let Some(s) = j.get("ivf") {
            if let Some(v) = s.get("backend").and_then(Json::as_str) {
                cfg.ivf.backend = IndexBackendKind::parse(v)
                    .with_context(|| format!("unknown index backend {v:?}"))?;
            }
            if let Some(v) = s.get("num_lists").and_then(Json::as_usize) {
                cfg.ivf.num_lists = v;
            }
            if let Some(v) = s.get("residual").and_then(Json::as_bool) {
                cfg.ivf.residual = v;
            }
            if let Some(v) = s.get("cache_mb").and_then(Json::as_usize) {
                cfg.ivf.cache_mb = v;
            }
        }
        if let Some(s) = j.get("stream") {
            if let Some(v) = s.get("segment_rows").and_then(Json::as_usize) {
                cfg.stream.segment_rows = v;
            }
            if let Some(v) =
                s.get("compact_segments").and_then(Json::as_usize)
            {
                cfg.stream.compact_segments = v;
            }
            if let Some(v) = s.get("wal_sync").and_then(Json::as_usize) {
                cfg.stream.wal_sync = v;
            }
        }
        if let Some(s) = j.get("unq_native") {
            if let Some(v) = s.get("hidden").and_then(Json::as_usize) {
                cfg.unq_native.hidden = v;
            }
            if let Some(v) = s.get("ds").and_then(Json::as_usize) {
                cfg.unq_native.ds = v;
            }
            if let Some(v) = s.get("epochs").and_then(Json::as_usize) {
                cfg.unq_native.epochs = v;
            }
            if let Some(v) = s.get("batch").and_then(Json::as_usize) {
                cfg.unq_native.batch = v;
            }
            if let Some(v) = s.get("lr").and_then(Json::as_f64) {
                cfg.unq_native.lr = v as f32;
            }
            if let Some(v) = s.get("tau0").and_then(Json::as_f64) {
                cfg.unq_native.tau0 = v as f32;
            }
            if let Some(v) = s.get("tau1").and_then(Json::as_f64) {
                cfg.unq_native.tau1 = v as f32;
            }
            if let Some(v) = s.get("lambda_cons").and_then(Json::as_f64) {
                cfg.unq_native.lambda_cons = v as f32;
            }
            if let Some(v) = s.get("gumbel").and_then(Json::as_f64) {
                cfg.unq_native.gumbel = v as f32;
            }
            if let Some(v) = s.get("kmeans_iters").and_then(Json::as_usize) {
                cfg.unq_native.kmeans_iters = v;
            }
            if let Some(v) = s.get("seed").and_then(Json::as_usize) {
                cfg.unq_native.seed = v as u64;
            }
        }
        if let Some(s) = j.get("serve") {
            if let Some(v) = s.get("max_batch").and_then(Json::as_usize) {
                cfg.serve.max_batch = v;
            }
            if let Some(v) = s.get("max_delay_us").and_then(Json::as_usize) {
                cfg.serve.max_delay_us = v as u64;
            }
            if let Some(v) = s.get("queue_depth").and_then(Json::as_usize) {
                cfg.serve.queue_depth = v;
            }
            // legacy pre-executor key: `shards` named the scan parallelism,
            // so map it onto the pool size (explicit num_threads wins)
            if let Some(v) = s.get("shards").and_then(Json::as_usize) {
                cfg.serve.num_threads = v;
            }
            if let Some(v) = s.get("num_threads").and_then(Json::as_usize) {
                cfg.serve.num_threads = v;
            }
            if let Some(v) = s.get("shard_rows").and_then(Json::as_usize) {
                cfg.serve.shard_rows = v;
            }
        }
        if let Some(s) = j.get("net") {
            if let Some(v) = s.get("listen").and_then(Json::as_str) {
                cfg.net.listen = v.to_string();
            }
            if let Some(v) = s.get("io_threads").and_then(Json::as_usize) {
                cfg.net.io_threads = v;
            }
            if let Some(v) = s.get("max_conns").and_then(Json::as_usize) {
                cfg.net.max_conns = v;
            }
            if let Some(v) = s.get("max_inflight").and_then(Json::as_usize) {
                cfg.net.max_inflight = v;
            }
            if let Some(v) = s.get("max_frame").and_then(Json::as_usize) {
                cfg.net.max_frame = v;
            }
            if let Some(v) =
                s.get("write_timeout_ms").and_then(Json::as_usize)
            {
                cfg.net.write_timeout_ms = v as u64;
            }
            if let Some(arr) = s.get("tenants").and_then(Json::as_arr) {
                cfg.net.tenants.clear();
                for t in arr {
                    let name = t.get("name").and_then(Json::as_str)
                        .context("net.tenants entries need a \"name\"")?
                        .to_string();
                    let max_qps = t.get("max_qps")
                        .and_then(Json::as_usize).unwrap_or(0)
                        as u64;
                    let max_insert_bytes = t.get("max_insert_bytes")
                        .and_then(Json::as_usize).unwrap_or(0)
                        as u64;
                    cfg.net.tenants.push(TenantQuota {
                        name, max_qps, max_insert_bytes,
                    });
                }
            }
        }
        if let Some(v) = j.get("data_dir").and_then(Json::as_str) {
            cfg.data_dir = v.into();
        }
        if let Some(v) = j.get("artifacts_dir").and_then(Json::as_str) {
            cfg.artifacts_dir = v.into();
        }
        if let Some(v) = j.get("runs_dir").and_then(Json::as_str) {
            cfg.runs_dir = v.into();
        }
        if let Some(v) = j.get("scale").and_then(Json::as_f64) {
            cfg.scale = v;
        }
        if cfg.bytes_per_vector == 0 || cfg.k_codewords == 0 {
            bail!("bytes_per_vector and k_codewords must be positive");
        }
        if cfg.ivf.num_lists == 0 {
            bail!("ivf.num_lists must be positive");
        }
        if cfg.ivf.cache_mb == 0 {
            bail!("ivf.cache_mb must be positive");
        }
        if cfg.stream.segment_rows == 0 || cfg.stream.compact_segments == 0 {
            bail!("stream.segment_rows and stream.compact_segments must \
                   be positive");
        }
        if cfg.unq_native.hidden == 0 || cfg.unq_native.batch == 0 {
            bail!("unq_native.hidden and unq_native.batch must be positive");
        }
        if cfg.unq_native.tau0 <= 0.0 || cfg.unq_native.tau1 <= 0.0 {
            bail!("unq_native temperatures must be positive");
        }
        if cfg.unq_native.lr <= 0.0 || !cfg.unq_native.lr.is_finite() {
            bail!("unq_native.lr must be positive and finite");
        }
        if cfg.unq_native.lambda_cons < 0.0 || cfg.unq_native.gumbel < 0.0 {
            bail!("unq_native.lambda_cons and unq_native.gumbel must be \
                   non-negative");
        }
        if cfg.net.max_conns == 0 || cfg.net.max_inflight == 0 {
            bail!("net.max_conns and net.max_inflight must be positive");
        }
        if cfg.net.max_frame < 4096 {
            bail!("net.max_frame must be at least 4096 bytes (one \
                   modest query frame)");
        }
        for (i, t) in cfg.net.tenants.iter().enumerate() {
            if t.name.is_empty() {
                bail!("net.tenants[{i}] has an empty name");
            }
            if cfg.net.tenants[..i].iter().any(|o| o.name == t.name) {
                bail!("net.tenants has duplicate name {:?}", t.name);
            }
        }
        Ok(cfg)
    }

    /// Load from a JSON file.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {path:?}"))?;
        let j = Json::parse(&text).with_context(|| format!("parse {path:?}"))?;
        Self::from_json(&j)
    }

    /// Apply environment overrides (`UNQ_SCALE`, `UNQ_THREADS`, ...).
    pub fn apply_env(mut self) -> Self {
        if let Ok(s) = std::env::var("UNQ_QUANTIZER") {
            if let Some(q) = QuantizerKind::parse(&s) {
                self.quantizer = q;
            }
        }
        if let Ok(s) = std::env::var("UNQ_NATIVE_EPOCHS") {
            if let Ok(v) = s.parse::<usize>() {
                self.unq_native.epochs = v;
            }
        }
        if let Ok(s) = std::env::var("UNQ_NATIVE_HIDDEN") {
            if let Ok(v) = s.parse::<usize>() {
                if v > 0 {
                    self.unq_native.hidden = v;
                }
            }
        }
        if let Ok(s) = std::env::var("UNQ_NATIVE_BATCH") {
            if let Ok(v) = s.parse::<usize>() {
                if v > 0 {
                    self.unq_native.batch = v;
                }
            }
        }
        if let Ok(s) = std::env::var("UNQ_NATIVE_LR") {
            if let Ok(v) = s.parse::<f32>() {
                if v > 0.0 {
                    self.unq_native.lr = v;
                }
            }
        }
        if let Ok(s) = std::env::var("UNQ_NATIVE_SEED") {
            if let Ok(v) = s.parse::<u64>() {
                self.unq_native.seed = v;
            }
        }
        if let Ok(s) = std::env::var("UNQ_SCALE") {
            if let Ok(v) = s.parse::<f64>() {
                self.scale = v;
            }
        }
        if let Ok(s) = std::env::var("UNQ_THREADS") {
            if let Ok(v) = s.parse::<usize>() {
                self.search.num_threads = v;
                self.serve.num_threads = v;
            }
        }
        if let Ok(s) = std::env::var("UNQ_SHARD_ROWS") {
            if let Ok(v) = s.parse::<usize>() {
                self.search.shard_rows = v;
                self.serve.shard_rows = v;
            }
        }
        if let Ok(s) = std::env::var("UNQ_NPROBE") {
            if let Ok(v) = s.parse::<usize>() {
                self.search.nprobe = v;
            }
        }
        if let Ok(s) = std::env::var("UNQ_SCAN_PRECISION") {
            if let Some(p) = ScanPrecision::parse(&s) {
                self.search.scan_precision = p;
            }
        }
        if let Ok(s) = std::env::var("UNQ_PREFILTER") {
            match s.to_ascii_lowercase().as_str() {
                "1" | "true" | "yes" => self.search.prefilter = true,
                "0" | "false" | "no" => self.search.prefilter = false,
                _ => {}
            }
        }
        if let Ok(s) = std::env::var("UNQ_FILTER") {
            if s.is_empty() {
                self.search.filter = None;
            } else if let Ok(f) = crate::index::Filter::parse(&s) {
                self.search.filter = Some(f);
            }
        }
        if let Ok(s) = std::env::var("UNQ_PREFILTER_MARGIN") {
            if let Ok(v) = s.parse::<usize>() {
                if v > 0 {
                    self.search.prefilter_margin = v;
                }
            }
        }
        if let Ok(s) = std::env::var("UNQ_TRACE") {
            match s.to_ascii_lowercase().as_str() {
                "1" | "true" | "yes" => self.search.trace = true,
                "0" | "false" | "no" => self.search.trace = false,
                _ => {}
            }
        }
        if let Ok(s) = std::env::var("UNQ_LISTS") {
            if let Ok(v) = s.parse::<usize>() {
                if v > 0 {
                    self.ivf.num_lists = v;
                }
            }
        }
        if let Ok(s) = std::env::var("UNQ_CACHE_MB") {
            if let Ok(v) = s.parse::<usize>() {
                if v > 0 {
                    self.ivf.cache_mb = v;
                }
            }
        }
        if let Ok(s) = std::env::var("UNQ_RESIDUAL") {
            match s.to_ascii_lowercase().as_str() {
                "1" | "true" | "yes" => self.ivf.residual = true,
                "0" | "false" | "no" => self.ivf.residual = false,
                _ => {}
            }
        }
        if let Ok(s) = std::env::var("UNQ_SEGMENT_ROWS") {
            if let Ok(v) = s.parse::<usize>() {
                if v > 0 {
                    self.stream.segment_rows = v;
                }
            }
        }
        if let Ok(s) = std::env::var("UNQ_COMPACT_SEGMENTS") {
            if let Ok(v) = s.parse::<usize>() {
                if v > 0 {
                    self.stream.compact_segments = v;
                }
            }
        }
        if let Ok(s) = std::env::var("UNQ_WAL_SYNC") {
            if let Ok(v) = s.parse::<usize>() {
                if v > 0 {
                    self.stream.wal_sync = v;
                }
            }
        }
        if let Ok(s) = std::env::var("UNQ_BACKEND") {
            if let Some(b) = IndexBackendKind::parse(&s) {
                self.ivf.backend = b;
            }
        }
        if let Ok(s) = std::env::var("UNQ_LISTEN") {
            if !s.is_empty() {
                self.net.listen = s;
            }
        }
        if let Ok(s) = std::env::var("UNQ_NET_THREADS") {
            if let Ok(v) = s.parse::<usize>() {
                self.net.io_threads = v;
            }
        }
        if let Ok(s) = std::env::var("UNQ_MAX_CONNS") {
            if let Ok(v) = s.parse::<usize>() {
                if v > 0 {
                    self.net.max_conns = v;
                }
            }
        }
        if let Ok(s) = std::env::var("UNQ_MAX_INFLIGHT") {
            if let Ok(v) = s.parse::<usize>() {
                if v > 0 {
                    self.net.max_inflight = v;
                }
            }
        }
        if let Ok(s) = std::env::var("UNQ_MAX_FRAME") {
            if let Ok(v) = s.parse::<usize>() {
                if v >= 4096 {
                    self.net.max_frame = v;
                }
            }
        }
        if let Ok(s) = std::env::var("UNQ_WRITE_TIMEOUT_MS") {
            if let Ok(v) = s.parse::<u64>() {
                if v > 0 {
                    self.net.write_timeout_ms = v;
                }
            }
        }
        // UNQ_TENANTS="alice:100:1000000,bob:10:0" — name:qps:bytes specs
        if let Ok(s) = std::env::var("UNQ_TENANTS") {
            let parsed: Vec<TenantQuota> = s
                .split(',')
                .filter(|p| !p.trim().is_empty())
                .filter_map(TenantQuota::parse_spec)
                .collect();
            if !parsed.is_empty() {
                self.net.tenants = parsed;
            }
        }
        if let Ok(s) = std::env::var("UNQ_DATA_DIR") {
            self.data_dir = s.into();
        }
        if let Ok(s) = std::env::var("UNQ_ARTIFACTS_DIR") {
            self.artifacts_dir = s.into();
        }
        if let Ok(s) = std::env::var("UNQ_RUNS_DIR") {
            self.runs_dir = s.into();
        }
        self
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().render_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TempDir;

    #[test]
    fn default_is_sane() {
        let c = AppConfig::default();
        assert_eq!(c.k_codewords, 256);
        assert_eq!(c.bytes_per_vector, 8);
        assert_eq!(c.search.rerank_l, 500);
    }

    #[test]
    fn json_roundtrip() {
        let dir = TempDir::new("cfg").unwrap();
        let p = dir.path().join("c.json");
        let mut c = AppConfig::default();
        c.dataset = "deep1m".into();
        c.quantizer = QuantizerKind::Lsq;
        c.search.rerank_l = 123;
        c.search.num_threads = 4;
        c.serve.max_batch = 99;
        c.serve.shard_rows = 4096;
        c.save(&p).unwrap();
        let back = AppConfig::from_file(&p).unwrap();
        assert_eq!(back.dataset, "deep1m");
        assert_eq!(back.quantizer, QuantizerKind::Lsq);
        assert_eq!(back.search.rerank_l, 123);
        assert_eq!(back.search.num_threads, 4);
        assert_eq!(back.serve.max_batch, 99);
        assert_eq!(back.serve.shard_rows, 4096);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let j = Json::parse(r#"{"dataset": "sift10m"}"#).unwrap();
        let c = AppConfig::from_json(&j).unwrap();
        assert_eq!(c.dataset, "sift10m");
        assert_eq!(c.k_codewords, 256);
    }

    #[test]
    fn legacy_shards_key_maps_to_pool_size() {
        let j = Json::parse(r#"{"serve": {"shards": 8}}"#).unwrap();
        let c = AppConfig::from_json(&j).unwrap();
        assert_eq!(c.serve.num_threads, 8);
        // an explicit num_threads wins over the legacy alias
        let j = Json::parse(r#"{"serve": {"shards": 8, "num_threads": 2}}"#)
            .unwrap();
        let c = AppConfig::from_json(&j).unwrap();
        assert_eq!(c.serve.num_threads, 2);
    }

    #[test]
    fn ivf_section_roundtrip_and_defaults() {
        let mut c = AppConfig::default();
        assert_eq!(c.ivf.backend, IndexBackendKind::Flat);
        assert!(!c.ivf.residual, "residual is opt-in");
        assert_eq!(c.ivf.cache_mb, 64);
        assert_eq!(c.search.nprobe, 0);
        c.ivf.backend = IndexBackendKind::DiskIvf;
        c.ivf.num_lists = 128;
        c.ivf.residual = true;
        c.ivf.cache_mb = 7;
        c.search.nprobe = 9;
        let dir = TempDir::new("cfg").unwrap();
        let p = dir.path().join("ivf.json");
        c.save(&p).unwrap();
        let back = AppConfig::from_file(&p).unwrap();
        assert_eq!(back.ivf.backend, IndexBackendKind::DiskIvf);
        assert_eq!(back.ivf.num_lists, 128);
        assert!(back.ivf.residual);
        assert_eq!(back.ivf.cache_mb, 7);
        assert_eq!(back.search.nprobe, 9);
    }

    #[test]
    fn stream_section_roundtrip_defaults_and_rejects() {
        let c = AppConfig::default();
        assert_eq!(c.stream.segment_rows, 4096);
        assert_eq!(c.stream.compact_segments, 4);
        assert_eq!(c.stream.wal_sync, 64);
        let dir = TempDir::new("cfg").unwrap();
        let p = dir.path().join("stream.json");
        let mut c = AppConfig::default();
        c.stream.segment_rows = 128;
        c.stream.compact_segments = 2;
        c.stream.wal_sync = 1;
        c.save(&p).unwrap();
        let back = AppConfig::from_file(&p).unwrap();
        assert_eq!(back.stream.segment_rows, 128);
        assert_eq!(back.stream.compact_segments, 2);
        assert_eq!(back.stream.wal_sync, 1);
        let j = Json::parse(r#"{"stream": {"segment_rows": 0}}"#).unwrap();
        assert!(AppConfig::from_json(&j).is_err());
    }

    #[test]
    fn ivf_invalid_rejected() {
        let j = Json::parse(r#"{"ivf": {"backend": "nope"}}"#).unwrap();
        assert!(AppConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"ivf": {"num_lists": 0}}"#).unwrap();
        assert!(AppConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"ivf": {"cache_mb": 0}}"#).unwrap();
        assert!(AppConfig::from_json(&j).is_err());
    }

    #[test]
    fn backend_kind_parse_names() {
        assert_eq!(IndexBackendKind::parse("IVF"), Some(IndexBackendKind::Ivf));
        assert_eq!(IndexBackendKind::parse("flat"),
                   Some(IndexBackendKind::Flat));
        assert_eq!(IndexBackendKind::parse("disk-ivf"),
                   Some(IndexBackendKind::DiskIvf));
        assert_eq!(IndexBackendKind::parse("DISK_IVF"),
                   Some(IndexBackendKind::DiskIvf));
        assert_eq!(IndexBackendKind::parse("disk"),
                   Some(IndexBackendKind::DiskIvf));
        assert_eq!(IndexBackendKind::parse("hnsw"), None);
        assert_eq!(IndexBackendKind::Ivf.name(), "ivf");
        assert_eq!(IndexBackendKind::DiskIvf.name(), "disk-ivf");
    }

    #[test]
    fn scan_precision_roundtrip_defaults_and_rejects() {
        let c = AppConfig::default();
        assert_eq!(c.search.scan_precision, ScanPrecision::F32,
                   "f32 must stay the unchanged default");
        let dir = TempDir::new("cfg").unwrap();
        let p = dir.path().join("prec.json");
        let mut c = AppConfig::default();
        c.search.scan_precision = ScanPrecision::U16;
        c.save(&p).unwrap();
        let back = AppConfig::from_file(&p).unwrap();
        assert_eq!(back.search.scan_precision, ScanPrecision::U16);
        let j = Json::parse(r#"{"search": {"scan_precision": "u8"}}"#).unwrap();
        assert_eq!(AppConfig::from_json(&j).unwrap().search.scan_precision,
                   ScanPrecision::U8);
        let j = Json::parse(r#"{"search": {"scan_precision": "f64"}}"#)
            .unwrap();
        assert!(AppConfig::from_json(&j).is_err());
    }

    #[test]
    fn scan_precision_parse_aliases() {
        assert_eq!(ScanPrecision::parse("F32"), Some(ScanPrecision::F32));
        assert_eq!(ScanPrecision::parse("exact"), Some(ScanPrecision::F32));
        assert_eq!(ScanPrecision::parse("uint16"), Some(ScanPrecision::U16));
        assert_eq!(ScanPrecision::parse("8"), Some(ScanPrecision::U8));
        assert_eq!(ScanPrecision::parse("u4"), Some(ScanPrecision::U4));
        assert_eq!(ScanPrecision::parse("nibble"), Some(ScanPrecision::U4));
        assert_eq!(ScanPrecision::parse("i4"), None);
        assert_eq!(ScanPrecision::U16.name(), "u16");
        assert_eq!(ScanPrecision::U4.name(), "u4");
        assert_eq!(ScanPrecision::all().len(), 4);
    }

    #[test]
    fn prefilter_roundtrip_defaults_and_parses() {
        let c = AppConfig::default();
        assert!(!c.search.prefilter, "pre-filter must default off");
        assert_eq!(c.search.prefilter_margin, 4);
        let dir = TempDir::new("cfg").unwrap();
        let p = dir.path().join("pre.json");
        let mut c = AppConfig::default();
        c.search.prefilter = true;
        c.search.prefilter_margin = 9;
        c.save(&p).unwrap();
        let back = AppConfig::from_file(&p).unwrap();
        assert!(back.search.prefilter);
        assert_eq!(back.search.prefilter_margin, 9);
        let j = Json::parse(
            r#"{"search": {"prefilter": true, "prefilter_margin": 2}}"#)
            .unwrap();
        let cfg = AppConfig::from_json(&j).unwrap();
        assert!(cfg.search.prefilter);
        assert_eq!(cfg.search.prefilter_margin, 2);
    }

    #[test]
    fn filter_roundtrip_defaults_off_and_rejects_malformed() {
        use crate::index::Filter;
        let c = AppConfig::default();
        assert!(c.search.filter.is_none(), "filter must default off");
        let dir = TempDir::new("cfg").unwrap();
        let p = dir.path().join("filter.json");
        let mut c = AppConfig::default();
        c.search.filter = Some(Filter::TagEq(7));
        c.save(&p).unwrap();
        assert_eq!(AppConfig::from_file(&p).unwrap().search.filter,
                   Some(Filter::TagEq(7)));
        let j = Json::parse(r#"{"search": {"filter": "tag=3"}}"#).unwrap();
        assert_eq!(AppConfig::from_json(&j).unwrap().search.filter,
                   Some(Filter::TagEq(3)));
        let bad =
            Json::parse(r#"{"search": {"filter": "color=red"}}"#).unwrap();
        assert!(AppConfig::from_json(&bad).is_err());
    }

    #[test]
    fn trace_roundtrip_defaults_off() {
        assert!(!AppConfig::default().search.trace, "trace must default off");
        let dir = TempDir::new("cfg").unwrap();
        let p = dir.path().join("trace.json");
        let mut c = AppConfig::default();
        c.search.trace = true;
        c.save(&p).unwrap();
        assert!(AppConfig::from_file(&p).unwrap().search.trace);
        let j = Json::parse(r#"{"search": {"trace": true}}"#).unwrap();
        assert!(AppConfig::from_json(&j).unwrap().search.trace);
    }

    #[test]
    fn unq_native_section_roundtrip_defaults_and_rejects() {
        let c = AppConfig::default();
        assert_eq!(c.unq_native, UnqNativeConfig::default());
        assert_eq!(c.unq_native.hidden, 128);
        assert_eq!(c.unq_native.ds, 0, "ds 0 = dim/m default");
        let dir = TempDir::new("cfg").unwrap();
        let p = dir.path().join("native.json");
        let mut c = AppConfig::default();
        c.quantizer = QuantizerKind::UnqNative;
        c.unq_native.hidden = 32;
        c.unq_native.epochs = 3;
        c.unq_native.batch = 64;
        c.unq_native.lr = 0.005;
        c.unq_native.seed = 9;
        c.save(&p).unwrap();
        let back = AppConfig::from_file(&p).unwrap();
        assert_eq!(back.quantizer, QuantizerKind::UnqNative);
        assert_eq!(back.unq_native.hidden, 32);
        assert_eq!(back.unq_native.epochs, 3);
        assert_eq!(back.unq_native.batch, 64);
        assert!((back.unq_native.lr - 0.005).abs() < 1e-9);
        assert_eq!(back.unq_native.seed, 9);
        let j = Json::parse(r#"{"unq_native": {"hidden": 0}}"#).unwrap();
        assert!(AppConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"unq_native": {"tau1": 0.0}}"#).unwrap();
        assert!(AppConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"unq_native": {"lr": -0.001}}"#).unwrap();
        assert!(AppConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"unq_native": {"gumbel": -1.0}}"#).unwrap();
        assert!(AppConfig::from_json(&j).is_err());
    }

    #[test]
    fn unq_native_parse_aliases() {
        assert_eq!(QuantizerKind::parse("unq-native"),
                   Some(QuantizerKind::UnqNative));
        assert_eq!(QuantizerKind::parse("UNQ_NATIVE"),
                   Some(QuantizerKind::UnqNative));
        assert_eq!(QuantizerKind::parse("native"),
                   Some(QuantizerKind::UnqNative));
        assert_eq!(QuantizerKind::UnqNative.name(), "UNQ-native");
        assert!(QuantizerKind::all().contains(&QuantizerKind::UnqNative));
    }

    #[test]
    fn net_section_roundtrip_defaults_and_rejects() {
        let c = AppConfig::default();
        assert_eq!(c.net, NetConfig::default());
        assert_eq!(c.net.listen, "127.0.0.1:7009");
        assert_eq!(c.net.io_threads, 0, "0 = thread per core");
        assert_eq!(c.net.max_inflight, 64);
        assert!(c.net.tenants.is_empty(),
                "empty table = one unlimited default tenant");
        let dir = TempDir::new("cfg").unwrap();
        let p = dir.path().join("net.json");
        let mut c = AppConfig::default();
        c.net.listen = "0.0.0.0:9000".into();
        c.net.io_threads = 4;
        c.net.max_conns = 12;
        c.net.max_inflight = 8;
        c.net.max_frame = 65536;
        c.net.write_timeout_ms = 250;
        c.net.tenants = vec![
            TenantQuota { name: "alice".into(), max_qps: 100,
                          max_insert_bytes: 1 << 20 },
            TenantQuota::unlimited("default"),
        ];
        c.save(&p).unwrap();
        let back = AppConfig::from_file(&p).unwrap();
        assert_eq!(back.net, c.net);
        let j = Json::parse(r#"{"net": {"max_inflight": 0}}"#).unwrap();
        assert!(AppConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"net": {"max_frame": 100}}"#).unwrap();
        assert!(AppConfig::from_json(&j).is_err());
        let j = Json::parse(
            r#"{"net": {"tenants": [{"name": "a"}, {"name": "a"}]}}"#)
            .unwrap();
        assert!(AppConfig::from_json(&j).is_err(), "duplicate tenant");
        let j = Json::parse(r#"{"net": {"tenants": [{"max_qps": 5}]}}"#)
            .unwrap();
        assert!(AppConfig::from_json(&j).is_err(), "nameless tenant");
    }

    #[test]
    fn tenant_spec_parses() {
        assert_eq!(TenantQuota::parse_spec("alice:100:1000000"),
                   Some(TenantQuota { name: "alice".into(), max_qps: 100,
                                      max_insert_bytes: 1_000_000 }));
        assert_eq!(TenantQuota::parse_spec("bob"),
                   Some(TenantQuota::unlimited("bob")));
        assert_eq!(TenantQuota::parse_spec("carol:7"),
                   Some(TenantQuota { name: "carol".into(), max_qps: 7,
                                      max_insert_bytes: 0 }));
        assert_eq!(TenantQuota::parse_spec(""), None);
        assert_eq!(TenantQuota::parse_spec(":5"), None);
        assert_eq!(TenantQuota::parse_spec("d:x"), None);
        assert_eq!(TenantQuota::parse_spec("e:1:2:3"), None);
    }

    #[test]
    fn invalid_quantizer_rejected() {
        let j = Json::parse(r#"{"quantizer": "nope"}"#).unwrap();
        assert!(AppConfig::from_json(&j).is_err());
    }

    #[test]
    fn quantizer_parse_aliases() {
        assert_eq!(QuantizerKind::parse("LSQ+rerank"),
                   Some(QuantizerKind::LsqRerank));
        assert_eq!(QuantizerKind::parse("catalyst-lattice"),
                   Some(QuantizerKind::CatalystLattice));
        assert_eq!(QuantizerKind::parse("unq"), Some(QuantizerKind::Unq));
        assert_eq!(QuantizerKind::parse("wat"), None);
    }

    #[test]
    fn names_match_paper_rows() {
        assert_eq!(QuantizerKind::CatalystLattice.name(), "Catalyst+Lattice");
        assert_eq!(QuantizerKind::LsqRerank.name(), "LSQ+rerank");
    }
}
