//! The serving coordinator — L3's runtime contribution.
//!
//! A compressed-domain similarity-search service in the shape the paper's
//! system implies (encode offline, LUT + scan + rerank online), built as a
//! thread-per-stage pipeline over bounded channels (tokio is unavailable
//! on this offline testbed; on one core a thread pipeline is also the
//! honest design):
//!
//! ```text
//! clients → Router (bounded queue, backpressure)
//!             ├─ search → QueryBatcher (size/deadline)
//!             │            → IndexBackend (Flat | Ivf | Streaming)
//!             │              batch plan on the exec pool (flat:
//!             │              QueryBatch × IndexShard; ivf/stream: one
//!             │              slot per (query, probed list[, segment]))
//!             │            → batched decode rerank → respond
//!             ├─ ingest → IngestBatcher → StreamingIndex insert/delete
//!             │            (contiguous runs coalesce into one
//!             │             encode-on-insert + WAL fsync batch each)
//!             └─ encode → EncodeBatcher → encoder → respond
//! ```
//!
//! * [`batch::BatchPolicy`] — the pure flush-decision core (proptested);
//! * [`pipeline`] — the worker threads and wiring;
//! * [`Metrics`] — the lock-free serving aggregate (histograms live in
//!   [`crate::obs::hist`]);
//! * [`demo`] — the `unq serve` closed-loop load generator.
//!
//! The TCP front door over this coordinator lives in [`crate::net`]
//! (rust/PROTOCOL.md, rust/DESIGN.md §12).

pub mod batch;
pub mod demo;
pub mod pipeline;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::time::Instant;

use crate::obs::hist::LatencyHistogram;

/// Client-visible request ids (unique per server lifetime).
pub type RequestId = u64;

/// A search request: find the top-k neighbors of `query`.
///
/// (This is the *coordinator's* queued-request envelope; the index
/// layer's batch-plan shape is [`crate::index::SearchRequest`] — the
/// pipeline builds one of those per flushed batch group.)
pub struct SearchRequest {
    pub id: RequestId,
    pub query: Vec<f32>,
    pub k: usize,
    /// per-request metadata predicate; `None` scans everything
    /// (rust/DESIGN.md §13).  Requests with different predicates never
    /// share an index batch plan — the batcher may still flush them
    /// together, the pipeline groups by predicate before planning.
    pub filter: Option<crate::index::Filter>,
    pub submitted: Instant,
    pub resp: mpsc::SyncSender<SearchResponse>,
}

#[derive(Clone, Debug)]
pub struct SearchResponse {
    pub id: RequestId,
    pub neighbors: Vec<u32>,
    /// end-to-end latency observed inside the server
    pub latency_us: u64,
    /// rendered EXPLAIN span tree for the flushed batch this response
    /// rode in (rust/DESIGN.md §10); `Some` only when the server's
    /// `SearchConfig::trace` is on
    pub trace: Option<String>,
}

/// An encode request: compress `vectors` (flat rows) into codes.
pub struct EncodeRequest {
    pub id: RequestId,
    pub vectors: Vec<f32>,
    pub rows: usize,
    pub submitted: Instant,
    pub resp: mpsc::SyncSender<EncodeResponse>,
}

#[derive(Clone, Debug)]
pub struct EncodeResponse {
    pub id: RequestId,
    pub codes: Vec<u8>,
    pub latency_us: u64,
}

/// An insert request: encode `vectors` (flat rows) into the streaming
/// index and assign external ids.  Rejected (empty `ids`, `accepted =
/// false`) on non-streaming backends.
pub struct InsertRequest {
    pub id: RequestId,
    pub vectors: Vec<f32>,
    pub rows: usize,
    pub submitted: Instant,
    pub resp: mpsc::SyncSender<InsertResponse>,
}

#[derive(Clone, Debug)]
pub struct InsertResponse {
    pub id: RequestId,
    /// external ids assigned to the rows, in order
    pub ids: Vec<u32>,
    pub accepted: bool,
    pub latency_us: u64,
}

/// A delete request: tombstone external ids in the streaming index.
pub struct DeleteRequest {
    pub id: RequestId,
    pub keys: Vec<u32>,
    pub submitted: Instant,
    pub resp: mpsc::SyncSender<DeleteResponse>,
}

#[derive(Clone, Debug)]
pub struct DeleteResponse {
    pub id: RequestId,
    /// rows actually tombstoned (unknown ids are ignored)
    pub removed: usize,
    pub accepted: bool,
    pub latency_us: u64,
}

/// Typed ingress.
pub enum Request {
    Search(SearchRequest),
    Encode(EncodeRequest),
    Insert(InsertRequest),
    Delete(DeleteRequest),
}

impl Request {
    pub fn id(&self) -> RequestId {
        match self {
            Request::Search(r) => r.id,
            Request::Encode(r) => r.id,
            Request::Insert(r) => r.id,
            Request::Delete(r) => r.id,
        }
    }
}

/// Submission failure modes surfaced to clients.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// bounded queue full — backpressure; client should retry/shed
    Overloaded,
    /// server is shutting down
    Closed,
}

/// Aggregate serving metrics.  (Lived in a `coordinator/metrics.rs`
/// shim after the histogram moved to `obs::hist`; the shim is gone and
/// the aggregate lives with the request types it counts.)
#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub batches: AtomicU64,
    pub batch_items: AtomicU64,
    pub search_latency: LatencyHistogram,
    pub encode_latency: LatencyHistogram,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics { search_latency: LatencyHistogram::new(),
                  encode_latency: LatencyHistogram::new(),
                  ..Default::default() }
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batch_items.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn report(&self) -> String {
        format!(
            "submitted {}  rejected {}  completed {}  batches {} \
             (mean size {:.1})\nsearch latency: mean {:.1} µs  p50 {} µs  \
             p95 {} µs  p99 {} µs  max {} µs",
            self.submitted.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.search_latency.mean_us(),
            self.search_latency.quantile_us(0.5),
            self.search_latency.quantile_us(0.95),
            self.search_latency.quantile_us(0.99),
            self.search_latency.max_us(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // histogram behavior is tested where it lives (obs::hist); these
    // cover the coordinator aggregate only

    #[test]
    fn metrics_batch_accounting() {
        let m = Metrics::new();
        m.batches.fetch_add(2, Ordering::Relaxed);
        m.batch_items.fetch_add(24, Ordering::Relaxed);
        assert!((m.mean_batch_size() - 12.0).abs() < 1e-9);
        assert!(m.report().contains("mean size 12.0"));
    }

    #[test]
    fn histogram_is_the_obs_one() {
        // spot-check the corrected √2 half-bucket semantics through the
        // coordinator path (the old in-module histogram placed the
        // boundary wrong; obs::hist is the single implementation now)
        let m = Metrics::new();
        for us in 1..=1000u64 {
            m.search_latency.record(us);
        }
        let p50 = m.search_latency.quantile_us(0.5);
        assert!((256..=1024).contains(&p50), "p50 = {p50}");
    }
}
