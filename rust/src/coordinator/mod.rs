//! The serving coordinator — L3's runtime contribution.
//!
//! A compressed-domain similarity-search service in the shape the paper's
//! system implies (encode offline, LUT + scan + rerank online), built as a
//! thread-per-stage pipeline over bounded channels (tokio is unavailable
//! on this offline testbed; on one core a thread pipeline is also the
//! honest design):
//!
//! ```text
//! clients → Router (bounded queue, backpressure)
//!             ├─ search → QueryBatcher (size/deadline)
//!             │            → IndexBackend (Flat | Ivf | Streaming)
//!             │              batch plan on the exec pool (flat:
//!             │              QueryBatch × IndexShard; ivf/stream: one
//!             │              slot per (query, probed list[, segment]))
//!             │            → batched decode rerank → respond
//!             ├─ ingest → IngestBatcher → StreamingIndex insert/delete
//!             │            (contiguous runs coalesce into one
//!             │             encode-on-insert + WAL fsync batch each)
//!             └─ encode → EncodeBatcher → encoder → respond
//! ```
//!
//! * [`batch::BatchPolicy`] — the pure flush-decision core (proptested);
//! * [`pipeline`] — the worker threads and wiring;
//! * [`metrics`] — lock-free counters + latency histogram;
//! * [`demo`] — the `unq serve` closed-loop load generator.

pub mod batch;
pub mod demo;
pub mod metrics;
pub mod pipeline;

use std::sync::mpsc;
use std::time::Instant;

/// Client-visible request ids (unique per server lifetime).
pub type RequestId = u64;

/// A search request: find the top-k neighbors of `query`.
pub struct SearchRequest {
    pub id: RequestId,
    pub query: Vec<f32>,
    pub k: usize,
    pub submitted: Instant,
    pub resp: mpsc::SyncSender<SearchResponse>,
}

#[derive(Clone, Debug)]
pub struct SearchResponse {
    pub id: RequestId,
    pub neighbors: Vec<u32>,
    /// end-to-end latency observed inside the server
    pub latency_us: u64,
    /// rendered EXPLAIN span tree for the flushed batch this response
    /// rode in (rust/DESIGN.md §10); `Some` only when the server's
    /// `SearchConfig::trace` is on
    pub trace: Option<String>,
}

/// An encode request: compress `vectors` (flat rows) into codes.
pub struct EncodeRequest {
    pub id: RequestId,
    pub vectors: Vec<f32>,
    pub rows: usize,
    pub submitted: Instant,
    pub resp: mpsc::SyncSender<EncodeResponse>,
}

#[derive(Clone, Debug)]
pub struct EncodeResponse {
    pub id: RequestId,
    pub codes: Vec<u8>,
    pub latency_us: u64,
}

/// An insert request: encode `vectors` (flat rows) into the streaming
/// index and assign external ids.  Rejected (empty `ids`, `accepted =
/// false`) on non-streaming backends.
pub struct InsertRequest {
    pub id: RequestId,
    pub vectors: Vec<f32>,
    pub rows: usize,
    pub submitted: Instant,
    pub resp: mpsc::SyncSender<InsertResponse>,
}

#[derive(Clone, Debug)]
pub struct InsertResponse {
    pub id: RequestId,
    /// external ids assigned to the rows, in order
    pub ids: Vec<u32>,
    pub accepted: bool,
    pub latency_us: u64,
}

/// A delete request: tombstone external ids in the streaming index.
pub struct DeleteRequest {
    pub id: RequestId,
    pub keys: Vec<u32>,
    pub submitted: Instant,
    pub resp: mpsc::SyncSender<DeleteResponse>,
}

#[derive(Clone, Debug)]
pub struct DeleteResponse {
    pub id: RequestId,
    /// rows actually tombstoned (unknown ids are ignored)
    pub removed: usize,
    pub accepted: bool,
    pub latency_us: u64,
}

/// Typed ingress.
pub enum Request {
    Search(SearchRequest),
    Encode(EncodeRequest),
    Insert(InsertRequest),
    Delete(DeleteRequest),
}

impl Request {
    pub fn id(&self) -> RequestId {
        match self {
            Request::Search(r) => r.id,
            Request::Encode(r) => r.id,
            Request::Insert(r) => r.id,
            Request::Delete(r) => r.id,
        }
    }
}

/// Submission failure modes surfaced to clients.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// bounded queue full — backpressure; client should retry/shed
    Overloaded,
    /// server is shutting down
    Closed,
}
