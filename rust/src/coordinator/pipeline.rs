//! The serving pipeline: router + batchers + workers over bounded
//! channels, with graceful shutdown.
//!
//! Thread layout (one thread per stage; see module docs in `mod.rs`):
//! * **router** — drains the bounded ingress queue and fans requests out
//!   to the per-type batcher queues (also bounded: backpressure
//!   propagates to `try_submit`).
//! * **search worker** — dynamic batcher ([`BatchPolicy`]) in front of
//!   the batch engine: every flushed batch is handed *whole* to the
//!   [`Executor`] — one `lut_batch` call (one PJRT execution for UNQ),
//!   one `QueryBatch × IndexShard` scan plan on the persistent
//!   `unq-exec-*` pool, one batched gather + decode rerank.
//! * **encode worker** — batches encode requests into one
//!   `encode_batch` call (one PJRT execution per AOT batch).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::{SearchConfig, ServeConfig};
use crate::exec::Executor;
use crate::index::{CompressedIndex, Filter};
use crate::ivf::IndexBackend;
use crate::quant::Quantizer;

use super::batch::BatchPolicy;
use super::{DeleteRequest, DeleteResponse, EncodeRequest, EncodeResponse,
            InsertRequest, InsertResponse, Metrics, Request, SearchRequest,
            SearchResponse, SubmitError};

/// One item in the ingest worker's batcher: inserts and deletes share a
/// queue so their relative order is preserved end to end.
enum IngestRequest {
    Insert(InsertRequest),
    Delete(DeleteRequest),
}

/// Shared immutable serving state.
pub struct ServerState {
    pub quant: Arc<dyn Quantizer>,
    /// The index organization behind the search worker — flat exhaustive
    /// scan or IVF nprobe search; the worker is backend-agnostic.
    pub backend: IndexBackend,
    pub search_cfg: SearchConfig,
    pub serve_cfg: ServeConfig,
    pub metrics: Arc<Metrics>,
}

/// A running coordinator.
pub struct Server {
    ingress: mpsc::SyncSender<Request>,
    pub metrics: Arc<Metrics>,
    next_id: Arc<AtomicU64>,
    dim: usize,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Spin up router + workers over a flat index (the classic
    /// entry point; delegates to [`Self::start_with_backend`]).
    pub fn start(quant: Arc<dyn Quantizer>, index: Arc<CompressedIndex>,
                 search_cfg: SearchConfig, serve_cfg: ServeConfig) -> Server {
        Self::start_with_backend(quant, IndexBackend::Flat(index),
                                 search_cfg, serve_cfg)
    }

    /// Spin up router + workers over any [`IndexBackend`].
    pub fn start_with_backend(quant: Arc<dyn Quantizer>,
                              backend: IndexBackend,
                              search_cfg: SearchConfig,
                              serve_cfg: ServeConfig) -> Server {
        let metrics = Arc::new(Metrics::new());
        let dim = quant.dim();
        let state = Arc::new(ServerState {
            quant, backend, search_cfg, serve_cfg,
            metrics: metrics.clone(),
        });

        let (ingress_tx, ingress_rx) =
            mpsc::sync_channel::<Request>(serve_cfg.queue_depth);
        let (search_tx, search_rx) =
            mpsc::sync_channel::<SearchRequest>(serve_cfg.queue_depth);
        let (encode_tx, encode_rx) =
            mpsc::sync_channel::<EncodeRequest>(serve_cfg.queue_depth);
        let (ingest_tx, ingest_rx) =
            mpsc::sync_channel::<IngestRequest>(serve_cfg.queue_depth);

        let mut threads = Vec::new();
        // router
        threads.push(
            std::thread::Builder::new()
                .name("unq-router".into())
                .spawn(move || {
                    router_main(ingress_rx, search_tx, encode_tx, ingest_tx)
                })
                .expect("spawn router"),
        );
        // search worker
        {
            let state = state.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("unq-search".into())
                    .spawn(move || search_worker(state, search_rx))
                    .expect("spawn search worker"),
            );
        }
        // encode worker
        {
            let state = state.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("unq-encode".into())
                    .spawn(move || encode_worker(state, encode_rx))
                    .expect("spawn encode worker"),
            );
        }
        // ingest worker (streaming-backend insert/delete batches)
        {
            let state = state.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("unq-ingest".into())
                    .spawn(move || ingest_worker(state, ingest_rx))
                    .expect("spawn ingest worker"),
            );
        }

        Server {
            ingress: ingress_tx,
            metrics,
            next_id: Arc::new(AtomicU64::new(1)),
            dim,
            threads,
        }
    }

    pub fn next_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Vector dimensionality the quantizer behind this server expects —
    /// the shape contract the network front door validates against
    /// before admitting a request (rust/DESIGN.md §12).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Non-blocking submit with backpressure.
    pub fn try_submit(&self, req: Request) -> Result<(), SubmitError> {
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        match self.ingress.try_send(req) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::Overloaded)
            }
            Err(TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
        }
    }

    /// Blocking submit (demo clients).
    pub fn submit(&self, req: Request) -> Result<(), SubmitError> {
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        self.ingress.send(req).map_err(|_| SubmitError::Closed)
    }

    /// Convenience: blocking round-trip search (no predicate).
    pub fn search_blocking(&self, query: &[f32], k: usize)
                           -> Result<SearchResponse, SubmitError> {
        self.search_blocking_filtered(query, k, None)
    }

    /// Blocking round-trip search under an optional metadata predicate
    /// (rust/DESIGN.md §13) — what the TCP front door calls when a
    /// SEARCH frame carries a filter TLV.
    pub fn search_blocking_filtered(&self, query: &[f32], k: usize,
                                    filter: Option<Filter>)
                                    -> Result<SearchResponse, SubmitError> {
        let (tx, rx) = mpsc::sync_channel(1);
        let req = SearchRequest {
            id: self.next_id(),
            query: query.to_vec(),
            k,
            filter,
            submitted: Instant::now(),
            resp: tx,
        };
        self.submit(Request::Search(req))?;
        rx.recv().map_err(|_| SubmitError::Closed)
    }

    /// Convenience: blocking round-trip encode.
    pub fn encode_blocking(&self, vectors: &[f32], rows: usize)
                           -> Result<EncodeResponse, SubmitError> {
        let (tx, rx) = mpsc::sync_channel(1);
        let req = EncodeRequest {
            id: self.next_id(),
            vectors: vectors.to_vec(),
            rows,
            submitted: Instant::now(),
            resp: tx,
        };
        self.submit(Request::Encode(req))?;
        rx.recv().map_err(|_| SubmitError::Closed)
    }

    /// Convenience: blocking round-trip insert into a streaming backend
    /// (`accepted = false` on frozen backends).
    pub fn insert_blocking(&self, vectors: &[f32], rows: usize)
                           -> Result<InsertResponse, SubmitError> {
        let (tx, rx) = mpsc::sync_channel(1);
        let req = InsertRequest {
            id: self.next_id(),
            vectors: vectors.to_vec(),
            rows,
            submitted: Instant::now(),
            resp: tx,
        };
        self.submit(Request::Insert(req))?;
        rx.recv().map_err(|_| SubmitError::Closed)
    }

    /// Convenience: blocking round-trip delete of external ids.
    pub fn delete_blocking(&self, keys: &[u32])
                           -> Result<DeleteResponse, SubmitError> {
        let (tx, rx) = mpsc::sync_channel(1);
        let req = DeleteRequest {
            id: self.next_id(),
            keys: keys.to_vec(),
            submitted: Instant::now(),
            resp: tx,
        };
        self.submit(Request::Delete(req))?;
        rx.recv().map_err(|_| SubmitError::Closed)
    }

    /// Graceful shutdown: close ingress, drain, join workers.
    pub fn shutdown(mut self) {
        drop(self.ingress);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn router_main(rx: mpsc::Receiver<Request>,
               search_tx: mpsc::SyncSender<SearchRequest>,
               encode_tx: mpsc::SyncSender<EncodeRequest>,
               ingest_tx: mpsc::SyncSender<IngestRequest>) {
    // ends when ingress disconnects; downstream queues close on drop
    while let Ok(req) = rx.recv() {
        match req {
            Request::Search(r) => {
                if search_tx.send(r).is_err() {
                    break;
                }
            }
            Request::Encode(r) => {
                if encode_tx.send(r).is_err() {
                    break;
                }
            }
            Request::Insert(r) => {
                if ingest_tx.send(IngestRequest::Insert(r)).is_err() {
                    break;
                }
            }
            Request::Delete(r) => {
                if ingest_tx.send(IngestRequest::Delete(r)).is_err() {
                    break;
                }
            }
        }
    }
}

fn search_worker(state: Arc<ServerState>, rx: mpsc::Receiver<SearchRequest>) {
    let serve = state.serve_cfg;
    // the persistent executor: spawned once, reused for every flushed
    // batch, joined on shutdown when this worker returns
    let exec = Executor::new(serve.num_threads);
    let mut batcher = BatchPolicy::<SearchRequest>::new(
        serve.max_batch, Duration::from_micros(serve.max_delay_us));
    loop {
        let wait = batcher
            .time_to_deadline(Instant::now())
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(wait) {
            Ok(req) => {
                if let Some(batch) = batcher.push(req, Instant::now()) {
                    process_search_batch(&state, &exec, batch);
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if let Some(batch) = batcher.poll(Instant::now()) {
                    process_search_batch(&state, &exec, batch);
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                let rest = batcher.take();
                if !rest.is_empty() {
                    process_search_batch(&state, &exec, rest);
                }
                break;
            }
        }
    }
}

fn process_search_batch(state: &ServerState, exec: &Executor,
                        batch: Vec<SearchRequest>) {
    let m = &state.metrics;
    m.batches.fetch_add(1, Ordering::Relaxed);
    m.batch_items.fetch_add(batch.len() as u64, Ordering::Relaxed);

    // The flushed batch goes to the backend grouped by predicate: a
    // scan plan compiles one filter-bitmap set, so requests with
    // different predicates cannot share a plan.  The common case —
    // every request unfiltered — stays a single whole-batch plan: the
    // flat arm builds all LUTs in one call (one PJRT batch for UNQ)
    // and runs the QueryBatch × IndexShard plan; the IVF arm plans one
    // slot per (query, probed list) through the same executor.  (Pool
    // size is fixed by the Executor built at worker startup; only the
    // serve-level shard knob flows through the search config.)
    let mut cfg = state.search_cfg;
    cfg.shard_rows = state.serve_cfg.shard_rows;
    // per-request predicate wins; the server-level config filter is
    // the default for requests that carry none
    let mut groups: Vec<(Option<Filter>, Vec<usize>)> = Vec::new();
    for (i, r) in batch.iter().enumerate() {
        let f = r.filter.or(cfg.filter);
        match groups.iter_mut().find(|(gf, _)| *gf == f) {
            Some((_, members)) => members.push(i),
            None => groups.push((f, vec![i])),
        }
    }
    let run_groups = |cfg: &SearchConfig| -> Vec<Vec<u32>> {
        let mut results: Vec<Vec<u32>> = vec![Vec::new(); batch.len()];
        for (f, members) in &groups {
            let queries: Vec<&[f32]> = members
                .iter()
                .map(|&i| batch[i].query.as_slice())
                .collect();
            let ks: Vec<usize> = members.iter().map(|&i| batch[i].k).collect();
            let gcfg = SearchConfig { filter: *f, ..*cfg };
            let req = crate::index::SearchRequest::from_config(&gcfg, ks);
            let out = state.backend.search_batch_on(
                state.quant.as_ref(), exec, &queries, &req);
            for (&i, r) in members.iter().zip(out) {
                results[i] = r;
            }
        }
        results
    };
    // one span tree per flushed batch (a batch of one ⇒ per query):
    // the root opens on this worker thread, the plan's task spans cross
    // the exec pool through TraceHandle, and the rendered tree rides
    // back on every response in the batch
    let (results, rendered) = if cfg.trace {
        let (trace, root) = crate::obs::Trace::begin("search_batch");
        let results = run_groups(&cfg);
        drop(root);
        (results, Some(trace.render()))
    } else {
        (run_groups(&cfg), None)
    };

    for (req, neighbors) in batch.into_iter().zip(results) {
        let latency_us = req.submitted.elapsed().as_micros() as u64;
        m.search_latency.record(latency_us);
        m.completed.fetch_add(1, Ordering::Relaxed);
        let _ = req.resp.send(SearchResponse {
            id: req.id, neighbors, latency_us,
            trace: rendered.clone(),
        });
    }
}

fn encode_worker(state: Arc<ServerState>, rx: mpsc::Receiver<EncodeRequest>) {
    let serve = state.serve_cfg;
    let mut batcher = BatchPolicy::<EncodeRequest>::new(
        serve.max_batch, Duration::from_micros(serve.max_delay_us));
    loop {
        let wait = batcher
            .time_to_deadline(Instant::now())
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(wait) {
            Ok(req) => {
                if let Some(batch) = batcher.push(req, Instant::now()) {
                    process_encode_batch(&state, batch);
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if let Some(batch) = batcher.poll(Instant::now()) {
                    process_encode_batch(&state, batch);
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                let rest = batcher.take();
                if !rest.is_empty() {
                    process_encode_batch(&state, rest);
                }
                break;
            }
        }
    }
}

fn process_encode_batch(state: &ServerState, batch: Vec<EncodeRequest>) {
    let m = &state.metrics;
    m.batches.fetch_add(1, Ordering::Relaxed);
    m.batch_items.fetch_add(batch.len() as u64, Ordering::Relaxed);

    // concatenate all rows, one encode_batch call, then split
    let dim = state.quant.dim();
    let cb = state.quant.code_bytes();
    let total_rows: usize = batch.iter().map(|r| r.rows).sum();
    let mut flat = Vec::with_capacity(total_rows * dim);
    for req in &batch {
        flat.extend_from_slice(&req.vectors);
    }
    let codes = state.quant.encode_batch(&flat);
    let mut offset = 0usize;
    for req in batch {
        let take = req.rows * cb;
        let slice = codes[offset..offset + take].to_vec();
        offset += take;
        let latency_us = req.submitted.elapsed().as_micros() as u64;
        m.encode_latency.record(latency_us);
        m.completed.fetch_add(1, Ordering::Relaxed);
        let _ = req.resp.send(EncodeResponse {
            id: req.id, codes: slice, latency_us,
        });
    }
}

fn ingest_worker(state: Arc<ServerState>, rx: mpsc::Receiver<IngestRequest>) {
    let serve = state.serve_cfg;
    let mut batcher = BatchPolicy::<IngestRequest>::new(
        serve.max_batch, Duration::from_micros(serve.max_delay_us));
    loop {
        let wait = batcher
            .time_to_deadline(Instant::now())
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(wait) {
            Ok(req) => {
                if let Some(batch) = batcher.push(req, Instant::now()) {
                    process_ingest_batch(&state, batch);
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if let Some(batch) = batcher.poll(Instant::now()) {
                    process_ingest_batch(&state, batch);
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                let rest = batcher.take();
                if !rest.is_empty() {
                    process_ingest_batch(&state, rest);
                }
                break;
            }
        }
    }
}

/// Apply one flushed ingest batch in arrival order, coalescing each
/// contiguous run of inserts into a single `insert_batch` call (one
/// `encode_batch`, one WAL fsync) and each run of deletes into one
/// `delete_batch` — same-id insert→delete sequences keep their meaning.
/// On non-streaming backends every request is answered `accepted =
/// false` instead of silently dropped.
fn process_ingest_batch(state: &ServerState, batch: Vec<IngestRequest>) {
    let m = &state.metrics;
    m.batches.fetch_add(1, Ordering::Relaxed);
    m.batch_items.fetch_add(batch.len() as u64, Ordering::Relaxed);
    let ix = match &state.backend {
        crate::ivf::IndexBackend::Streaming(ix) => Some(ix.clone()),
        _ => None,
    };

    let reply_insert = |req: InsertRequest, ids: Vec<u32>, ok: bool| {
        let latency_us = req.submitted.elapsed().as_micros() as u64;
        m.completed.fetch_add(1, Ordering::Relaxed);
        let _ = req.resp.send(InsertResponse {
            id: req.id, ids, accepted: ok, latency_us,
        });
    };
    let reply_delete = |req: DeleteRequest, removed: usize, ok: bool| {
        let latency_us = req.submitted.elapsed().as_micros() as u64;
        m.completed.fetch_add(1, Ordering::Relaxed);
        let _ = req.resp.send(DeleteResponse {
            id: req.id, removed, accepted: ok, latency_us,
        });
    };

    let mut it = batch.into_iter().peekable();
    while let Some(head) = it.next() {
        match head {
            IngestRequest::Insert(first) => {
                let mut run = vec![first];
                while let Some(IngestRequest::Insert(r)) =
                    it.next_if(|x| matches!(x, IngestRequest::Insert(_)))
                {
                    run.push(r);
                }
                let Some(ix) = &ix else {
                    for req in run {
                        reply_insert(req, Vec::new(), false);
                    }
                    continue;
                };
                // validate shapes before coalescing: `rows` is a public
                // field, and a mismatch would misalign the id split for
                // every later request in the run (or panic the worker)
                let dim = state.quant.dim();
                let mut valid = Vec::with_capacity(run.len());
                for req in run {
                    if req.rows * dim == req.vectors.len() {
                        valid.push(req);
                    } else {
                        reply_insert(req, Vec::new(), false);
                    }
                }
                let run = valid;
                if run.is_empty() {
                    continue;
                }
                let mut flat = Vec::new();
                for req in &run {
                    flat.extend_from_slice(&req.vectors);
                }
                match ix.insert_batch(state.quant.as_ref(), &flat) {
                    Ok(ids) => {
                        let mut off = 0usize;
                        for req in run {
                            let take = req.rows;
                            let slice = ids[off..off + take].to_vec();
                            off += take;
                            reply_insert(req, slice, true);
                        }
                    }
                    Err(e) => {
                        eprintln!("[coordinator] insert batch failed: {e:#}");
                        for req in run {
                            reply_insert(req, Vec::new(), false);
                        }
                    }
                }
            }
            IngestRequest::Delete(req) => {
                // deletes are cheap (no encode, one snapshot swap), and
                // per-request `removed` accounting wants per-request
                // calls — no coalescing needed
                let Some(ix) = &ix else {
                    reply_delete(req, 0, false);
                    continue;
                };
                match ix.delete_batch(&req.keys) {
                    Ok(removed) => reply_delete(req, removed, true),
                    Err(e) => {
                        eprintln!("[coordinator] delete batch failed: {e:#}");
                        reply_delete(req, 0, false);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SearchConfig, ServeConfig};
    use crate::data::{synthetic::Generator, Family};
    use crate::index::SearchEngine;
    use crate::ivf::{CoarseQuantizer, IvfIndex};
    use crate::quant::pq::Pq;

    fn start_pq_server(max_batch: usize, queue_depth: usize) -> (Server, crate::data::Dataset) {
        let train = Generator::new(Family::SiftLike, 31).generate(0, 600);
        let base = Generator::new(Family::SiftLike, 31).generate(1, 1500);
        let pq = Pq::train(&train.data, train.dim, 8, 32, 0, 6);
        let index = CompressedIndex::build(&pq, &base);
        let server = Server::start(
            Arc::new(pq),
            Arc::new(index),
            SearchConfig { rerank_l: 64, k: 10, ..Default::default() },
            ServeConfig { max_batch, max_delay_us: 500, queue_depth,
                          num_threads: 2, shard_rows: 512 },
        );
        (server, base)
    }

    #[test]
    fn end_to_end_search_matches_direct_engine() {
        let (server, base) = start_pq_server(4, 64);
        let queries = Generator::new(Family::SiftLike, 31).generate(2, 8);
        // direct reference
        let train = Generator::new(Family::SiftLike, 31).generate(0, 600);
        let pq = Pq::train(&train.data, train.dim, 8, 32, 0, 6);
        let index = CompressedIndex::build(&pq, &base);
        let engine = SearchEngine::new(&pq, &index, SearchConfig {
            rerank_l: 64, k: 10, ..Default::default()
        });
        for qi in 0..queries.len() {
            let resp = server.search_blocking(queries.row(qi), 10).unwrap();
            let want = engine.search(queries.row(qi));
            assert_eq!(resp.neighbors, want, "query {qi}");
            assert!(resp.latency_us > 0);
        }
        assert_eq!(server.metrics.completed.load(Ordering::Relaxed), 8);
        server.shutdown();
    }

    #[test]
    fn encode_roundtrip_matches_direct() {
        let (server, base) = start_pq_server(4, 64);
        let rows = 5;
        let resp = server.encode_blocking(base.rows(0, rows), rows).unwrap();
        assert_eq!(resp.codes.len(), rows * 8);
        // direct
        let train = Generator::new(Family::SiftLike, 31).generate(0, 600);
        let pq = Pq::train(&train.data, train.dim, 8, 32, 0, 6);
        let want = pq.encode_batch(base.rows(0, rows));
        assert_eq!(resp.codes, want);
        server.shutdown();
    }

    #[test]
    fn batching_coalesces_concurrent_clients() {
        let (server, _) = start_pq_server(8, 256);
        let server = Arc::new(server);
        let queries = Generator::new(Family::SiftLike, 31).generate(2, 64);
        let mut handles = Vec::new();
        for t in 0..4 {
            let s = server.clone();
            let q = queries.clone();
            handles.push(std::thread::spawn(move || {
                for qi in (t * 16)..(t * 16 + 16) {
                    let r = s.search_blocking(q.row(qi), 5).unwrap();
                    assert_eq!(r.neighbors.len(), 5);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let m = &server.metrics;
        assert_eq!(m.completed.load(Ordering::Relaxed), 64);
        // at least some batches should hold >1 query (4 concurrent clients,
        // 500 µs window)
        assert!(m.mean_batch_size() >= 1.0);
        Arc::try_unwrap(server).ok().map(|s| s.shutdown());
    }

    #[test]
    fn graceful_shutdown_drains() {
        let (server, base) = start_pq_server(64, 64);
        // single in-flight request then immediate shutdown
        let (tx, rx) = mpsc::sync_channel(1);
        server.submit(Request::Search(SearchRequest {
            id: 1,
            query: base.row(0).to_vec(),
            k: 3,
            filter: None,
            submitted: Instant::now(),
            resp: tx,
        })).unwrap();
        server.shutdown(); // must flush the partial batch
        let resp = rx.try_recv().expect("drained response");
        assert_eq!(resp.neighbors.len(), 3);
    }

    #[test]
    fn pooled_scan_equals_inline() {
        // two servers differing only in executor configuration must agree
        let (s_pool, base) = start_pq_server(1, 64);
        let train = Generator::new(Family::SiftLike, 31).generate(0, 600);
        let pq = Pq::train(&train.data, train.dim, 8, 32, 0, 6);
        let index = CompressedIndex::build(&pq, &base);
        let s_inline = Server::start(
            Arc::new(pq), Arc::new(index),
            SearchConfig { rerank_l: 64, k: 10, ..Default::default() },
            ServeConfig { max_batch: 1, max_delay_us: 100, queue_depth: 64,
                          num_threads: 1, shard_rows: 0 },
        );
        let queries = Generator::new(Family::SiftLike, 31).generate(2, 5);
        for qi in 0..queries.len() {
            let a = s_pool.search_blocking(queries.row(qi), 10).unwrap();
            let b = s_inline.search_blocking(queries.row(qi), 10).unwrap();
            assert_eq!(a.neighbors, b.neighbors);
        }
        s_pool.shutdown();
        s_inline.shutdown();
    }

    #[test]
    fn flushed_batches_under_load_match_direct_engine() {
        // many concurrent clients force multi-query flushes through the
        // executor; every response must equal the classic offline engine
        let (server, base) = start_pq_server(8, 256);
        let server = Arc::new(server);
        let queries = Generator::new(Family::SiftLike, 31).generate(2, 32);
        let train = Generator::new(Family::SiftLike, 31).generate(0, 600);
        let pq = Pq::train(&train.data, train.dim, 8, 32, 0, 6);
        let index = CompressedIndex::build(&pq, &base);
        let engine = SearchEngine::new(&pq, &index, SearchConfig {
            rerank_l: 64, k: 10, ..Default::default()
        });
        let want: Vec<Vec<u32>> = (0..queries.len())
            .map(|qi| engine.search(queries.row(qi)))
            .collect();
        let mut handles = Vec::new();
        for t in 0..4 {
            let s = server.clone();
            let q = queries.clone();
            let want = want.clone();
            handles.push(std::thread::spawn(move || {
                for qi in (t * 8)..(t * 8 + 8) {
                    let r = s.search_blocking(q.row(qi), 10).unwrap();
                    assert_eq!(r.neighbors, want[qi], "query {qi}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let m = &server.metrics;
        assert_eq!(m.completed.load(Ordering::Relaxed), 32);
        Arc::try_unwrap(server).ok().map(|s| s.shutdown());
    }

    #[test]
    fn streaming_backend_insert_search_delete_roundtrip() {
        use crate::config::StreamConfig;
        use crate::index::StreamingIndex;
        let train = Generator::new(Family::SiftLike, 31).generate(0, 600);
        let base = Generator::new(Family::SiftLike, 31).generate(1, 1200);
        let pq = Pq::train(&train.data, train.dim, 8, 32, 0, 6);
        let ix = Arc::new(StreamingIndex::new(
            8, None,
            StreamConfig { segment_rows: 256, ..Default::default() }));
        let search = SearchConfig { rerank_l: 64, k: 10,
                                    ..Default::default() };
        let server = Server::start_with_backend(
            Arc::new(Pq::train(&train.data, train.dim, 8, 32, 0, 6)),
            IndexBackend::Streaming(ix.clone()),
            search,
            ServeConfig { max_batch: 4, max_delay_us: 300, queue_depth: 64,
                          num_threads: 2, shard_rows: 256 },
        );
        // ingest the whole base through the coordinator in chunks
        let mut all_ids = Vec::new();
        for chunk in (0..base.len()).step_by(200) {
            let hi = (chunk + 200).min(base.len());
            let resp = server
                .insert_blocking(base.rows(chunk, hi), hi - chunk)
                .unwrap();
            assert!(resp.accepted);
            assert_eq!(resp.ids.len(), hi - chunk);
            all_ids.extend(resp.ids);
        }
        // ids are the monotonic insert order = dataset row order
        assert_eq!(all_ids, (0..base.len() as u32).collect::<Vec<_>>());
        // served results equal the direct streaming search
        let queries = Generator::new(Family::SiftLike, 31).generate(2, 6);
        let mut cfg = search;
        cfg.shard_rows = 256;
        for qi in 0..queries.len() {
            let resp = server.search_blocking(queries.row(qi), 10).unwrap();
            let want = ix.search(&pq, queries.row(qi), &cfg);
            assert_eq!(resp.neighbors, want, "query {qi}");
        }
        // delete a served neighbor and make sure it stops being served
        let victim = server
            .search_blocking(queries.row(0), 1)
            .unwrap()
            .neighbors[0];
        let del = server.delete_blocking(&[victim, 4_000_000]).unwrap();
        assert!(del.accepted);
        assert_eq!(del.removed, 1, "unknown ids are ignored");
        let after = server.search_blocking(queries.row(0), 10).unwrap();
        assert!(!after.neighbors.contains(&victim));
        server.shutdown();
    }

    #[test]
    fn filtered_and_unfiltered_requests_share_one_flush_correctly() {
        // a flushed batch mixing predicates splits into per-predicate
        // plans; each response must equal the direct engine under the
        // same predicate, and unfiltered requests must be untouched
        let train = Generator::new(Family::SiftLike, 31).generate(0, 600);
        let base = Generator::new(Family::SiftLike, 31).generate(1, 1500);
        let pq = Pq::train(&train.data, train.dim, 8, 32, 0, 6);
        let mut index = CompressedIndex::build(&pq, &base);
        index.set_tags((0..base.len() as u64).map(|i| i % 3).collect());
        let cfg = SearchConfig { rerank_l: 64, k: 10, ..Default::default() };
        let server = Server::start(
            Arc::new(Pq::train(&train.data, train.dim, 8, 32, 0, 6)),
            Arc::new({
                let mut ix = CompressedIndex::build(&pq, &base);
                ix.set_tags((0..base.len() as u64).map(|i| i % 3).collect());
                ix
            }),
            cfg,
            ServeConfig { max_batch: 8, max_delay_us: 500, queue_depth: 64,
                          num_threads: 2, shard_rows: 512 },
        );
        let queries = Generator::new(Family::SiftLike, 31).generate(2, 6);
        let mut fcfg = cfg;
        fcfg.shard_rows = 512;
        fcfg.filter = Some(Filter::TagEq(1));
        let f_engine = SearchEngine::new(&pq, &index, fcfg);
        let mut ucfg = fcfg;
        ucfg.filter = None;
        let u_engine = SearchEngine::new(&pq, &index, ucfg);
        // fire filtered and unfiltered requests concurrently so flushes
        // mix predicates
        let server = Arc::new(server);
        std::thread::scope(|s| {
            for t in 0..2 {
                let (server, queries, f_engine, u_engine) =
                    (&server, &queries, &f_engine, &u_engine);
                s.spawn(move || {
                    for qi in 0..queries.len() {
                        let q = queries.row(qi);
                        if t == 0 {
                            let r = server
                                .search_blocking_filtered(
                                    q, 10, Some(Filter::TagEq(1)))
                                .unwrap();
                            assert_eq!(r.neighbors, f_engine.search(q),
                                       "filtered query {qi}");
                            assert!(r.neighbors
                                        .iter()
                                        .all(|id| id % 3 == 1),
                                    "inadmissible id served");
                        } else {
                            let r = server.search_blocking(q, 10).unwrap();
                            assert_eq!(r.neighbors, u_engine.search(q),
                                       "unfiltered query {qi}");
                        }
                    }
                });
            }
        });
        // selectivity 0 through the full pipeline: empty, not a panic
        let r = server
            .search_blocking_filtered(queries.row(0), 10,
                                      Some(Filter::TagEq(77)))
            .unwrap();
        assert!(r.neighbors.is_empty());
        Arc::try_unwrap(server).ok().map(|s| s.shutdown());
    }

    #[test]
    fn frozen_backend_rejects_ingest() {
        let (server, base) = start_pq_server(4, 64);
        let resp = server.insert_blocking(base.rows(0, 3), 3).unwrap();
        assert!(!resp.accepted);
        assert!(resp.ids.is_empty());
        let del = server.delete_blocking(&[1, 2]).unwrap();
        assert!(!del.accepted);
        assert_eq!(del.removed, 0);
        server.shutdown();
    }

    #[test]
    fn ivf_backend_serves_same_results_as_direct_ivf_search() {
        let train = Generator::new(Family::SiftLike, 31).generate(0, 600);
        let base = Generator::new(Family::SiftLike, 31).generate(1, 2000);
        let pq = Pq::train(&train.data, train.dim, 8, 32, 0, 6);
        let coarse = CoarseQuantizer::train(&train.data, train.dim, 8, 0, 6);
        let ivf = Arc::new(IvfIndex::build(&pq, &base, coarse, true));
        let search = SearchConfig { rerank_l: 64, k: 10, nprobe: 3,
                                    ..Default::default() };
        let server = Server::start_with_backend(
            Arc::new(Pq::train(&train.data, train.dim, 8, 32, 0, 6)),
            IndexBackend::Ivf(ivf.clone()),
            search,
            ServeConfig { max_batch: 4, max_delay_us: 300, queue_depth: 64,
                          num_threads: 2, shard_rows: 256 },
        );
        let queries = Generator::new(Family::SiftLike, 31).generate(2, 8);
        let mut cfg = search;
        cfg.shard_rows = 256; // what the serve worker threads through
        for qi in 0..queries.len() {
            let resp = server.search_blocking(queries.row(qi), 10).unwrap();
            let want = ivf.search(&pq, queries.row(qi), &cfg);
            assert_eq!(resp.neighbors, want, "query {qi}");
        }
        server.shutdown();
    }
}
