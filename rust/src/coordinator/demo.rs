//! `unq serve` — closed-loop serving demo / load generator.
//!
//! Boots the full stack for the configured (dataset, quantizer): loads or
//! trains the model, encodes the base set (cached), starts the
//! coordinator, then drives it with a multi-client closed loop and prints
//! the throughput/latency report — the measurement the e2e example and
//! the timings bench reuse.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use crate::config::{AppConfig, IndexBackendKind};
use crate::eval::harness;
use crate::ivf::IndexBackend;
use crate::Result;

use super::pipeline::Server;

/// Outcome of a serving run (consumed by benches/examples).
pub struct ServeReport {
    pub queries: usize,
    pub wall_secs: f64,
    pub qps: f64,
    pub mean_latency_us: f64,
    pub p95_latency_us: u64,
    pub mean_batch: f64,
    pub recall_at10: f32,
}

/// Boot the stack and run `total_queries` closed-loop queries from 4
/// client threads. Returns the report (also printed).
pub fn run_serve(cfg: &AppConfig, total_queries: usize) -> Result<ServeReport> {
    let exp = harness::prepare(cfg, "")?;
    let mut search =
        harness::paper_search_config(cfg.quantizer, &cfg.dataset, 100);
    search.nprobe = cfg.search.nprobe;
    search.scan_precision = cfg.search.scan_precision;

    // Move the heavy pieces into Arcs for the server, building the
    // configured index backend (flat exhaustive scan, or IVF with the
    // runs-cached coarse partition).
    let harness::Experiment { quant, index, splits, gt, runtime, .. } = exp;
    let backend = match cfg.ivf.backend {
        IndexBackendKind::Flat => IndexBackend::Flat(Arc::new(index)),
        IndexBackendKind::Ivf => {
            let ivf = harness::build_or_load_ivf(
                cfg, quant.as_ref(), &splits.train, &splits.base, "")?;
            IndexBackend::Ivf(Arc::new(ivf))
        }
        IndexBackendKind::DiskIvf => {
            let disk = harness::build_or_load_disk_ivf(
                cfg, quant.as_ref(), &splits.train, &splits.base, "")?;
            IndexBackend::DiskIvf(Arc::new(disk))
        }
    };
    let quant: Arc<dyn crate::quant::Quantizer> = Arc::from(quant);
    let server = Arc::new(
        Server::start_with_backend(quant, backend, search, cfg.serve));

    let n_clients = 4usize;
    let queries = Arc::new(splits.query);
    let per_client = total_queries.div_ceil(n_clients);
    let t0 = Instant::now();
    let mut handles = Vec::new();
    let results = Arc::new(std::sync::Mutex::new(vec![
        Vec::new();
        queries.len()
    ]));
    for c in 0..n_clients {
        let server = server.clone();
        let queries = queries.clone();
        let results = results.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..per_client {
                let qi = (c * per_client + i) % queries.len();
                match server.search_blocking(queries.row(qi), 100) {
                    Ok(resp) => {
                        results.lock().unwrap()[qi] = resp.neighbors;
                    }
                    Err(e) => panic!("client {c}: {e:?}"),
                }
            }
        }));
    }
    for h in handles {
        h.join().map_err(|_| anyhow::anyhow!("client thread panicked"))?;
    }
    let wall = t0.elapsed().as_secs_f64();
    let metrics = server.metrics.clone();

    // recall over the answered queries only (a closed loop shorter than
    // the query set leaves some rows empty)
    let all = results.lock().unwrap().clone();
    let mut answered = Vec::new();
    let mut answered_gt = Vec::new();
    for (qi, r) in all.into_iter().enumerate() {
        if !r.is_empty() {
            answered.push(r);
            answered_gt.push(gt.neighbors[qi].clone());
        }
    }
    let rec = crate::eval::recall(&answered, &crate::gt::GroundTruth {
        r: gt.r,
        neighbors: answered_gt,
    });

    let report = ServeReport {
        queries: n_clients * per_client,
        wall_secs: wall,
        qps: (n_clients * per_client) as f64 / wall,
        mean_latency_us: metrics.search_latency.mean_us(),
        p95_latency_us: metrics.search_latency.quantile_us(0.95),
        mean_batch: metrics.mean_batch_size(),
        recall_at10: rec.at10,
    };
    println!(
        "[serve] {} on {} (n={}): {} queries in {:.2}s → {:.1} QPS\n\
         [serve] latency mean {:.1} µs  p95 {} µs  mean batch {:.1}\n\
         [serve] completed {}  rejected {}  Recall@10 {:.1}",
        cfg.quantizer.name(), cfg.dataset, queries.len(), report.queries,
        report.wall_secs, report.qps, report.mean_latency_us,
        report.p95_latency_us, report.mean_batch,
        metrics.completed.load(Ordering::Relaxed),
        metrics.rejected.load(Ordering::Relaxed),
        report.recall_at10,
    );

    Arc::try_unwrap(server)
        .map_err(|_| anyhow::anyhow!("server still referenced"))?
        .shutdown();
    drop(runtime); // stop the PJRT thread last
    Ok(report)
}

/// CLI wrapper.
pub fn run_demo(cfg: &AppConfig, queries: usize) -> Result<()> {
    run_serve(cfg, queries).map(|_| ())
}
