//! Serving metrics: lock-free counters and a log-bucketed latency
//! histogram (no external metrics crate offline; this is the usual
//! HDR-style power-of-√2 bucketing).

use std::sync::atomic::{AtomicU64, Ordering};

/// Log-bucketed latency histogram over microseconds.
///
/// 64 buckets at √2 spacing cover 1 µs … ~6 000 s; recording is a single
/// relaxed fetch_add, safe from any thread.
pub struct LatencyHistogram {
    buckets: [AtomicU64; 64],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    #[inline]
    fn bucket_of(us: u64) -> usize {
        // two buckets per power of two: index = 2·log2 + high-half bit
        let us = us.max(1);
        let log2 = 63 - us.leading_zeros() as usize;
        let half = if us & (1 << log2) != 0 && log2 > 0
            && us & (1 << (log2 - 1)) != 0
        {
            1
        } else {
            0
        };
        (2 * log2 + half).min(63)
    }

    pub fn record(&self, us: u64) {
        self.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate quantile from the bucket histogram (upper bound of the
    /// containing bucket).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                // upper bound of bucket i
                let log2 = i / 2;
                let half = i % 2;
                return (1u64 << log2) + ((half as u64) << log2.saturating_sub(1));
            }
        }
        self.max_us()
    }
}

/// Aggregate serving metrics.
#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub batches: AtomicU64,
    pub batch_items: AtomicU64,
    pub search_latency: LatencyHistogram,
    pub encode_latency: LatencyHistogram,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics { search_latency: LatencyHistogram::new(),
                  encode_latency: LatencyHistogram::new(),
                  ..Default::default() }
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batch_items.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn report(&self) -> String {
        format!(
            "submitted {}  rejected {}  completed {}  batches {} \
             (mean size {:.1})\nsearch latency: mean {:.1} µs  p50 {} µs  \
             p95 {} µs  p99 {} µs  max {} µs",
            self.submitted.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.search_latency.mean_us(),
            self.search_latency.quantile_us(0.5),
            self.search_latency.quantile_us(0.95),
            self.search_latency.quantile_us(0.99),
            self.search_latency.max_us(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_counts_and_mean() {
        let h = LatencyHistogram::new();
        for us in [10, 20, 30, 40] {
            h.record(us);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean_us() - 25.0).abs() < 1e-9);
        assert_eq!(h.max_us(), 40);
    }

    #[test]
    fn quantiles_monotone() {
        let h = LatencyHistogram::new();
        for us in 1..=1000u64 {
            h.record(us);
        }
        let p50 = h.quantile_us(0.5);
        let p95 = h.quantile_us(0.95);
        let p99 = h.quantile_us(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        // bucketed approximation: p50 of uniform 1..1000 is within [256,1024]
        assert!((256..=1024).contains(&p50), "p50 = {p50}");
    }

    #[test]
    fn bucket_of_is_monotone() {
        let mut last = 0;
        for us in [1u64, 2, 3, 5, 9, 17, 100, 1000, 10_000, 1 << 40] {
            let b = LatencyHistogram::bucket_of(us);
            assert!(b >= last, "bucket({us}) = {b} < {last}");
            last = b;
        }
    }

    #[test]
    fn metrics_batch_accounting() {
        let m = Metrics::new();
        m.batches.fetch_add(2, Ordering::Relaxed);
        m.batch_items.fetch_add(24, Ordering::Relaxed);
        assert!((m.mean_batch_size() - 12.0).abs() < 1e-9);
        assert!(m.report().contains("mean size 12.0"));
    }
}
