//! Serving metrics.  The latency histogram moved to [`crate::obs::hist`]
//! (with its √2 half-bucket boundary fixed — the old condition here
//! tested the top bit of the value, which is vacuously true, and placed
//! the boundary at `1.5·2^k`); this module re-exports it and keeps only
//! the coordinator-specific aggregate.

use std::sync::atomic::{AtomicU64, Ordering};

pub use crate::obs::hist::LatencyHistogram;

/// Aggregate serving metrics.
#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub batches: AtomicU64,
    pub batch_items: AtomicU64,
    pub search_latency: LatencyHistogram,
    pub encode_latency: LatencyHistogram,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics { search_latency: LatencyHistogram::new(),
                  encode_latency: LatencyHistogram::new(),
                  ..Default::default() }
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batch_items.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn report(&self) -> String {
        format!(
            "submitted {}  rejected {}  completed {}  batches {} \
             (mean size {:.1})\nsearch latency: mean {:.1} µs  p50 {} µs  \
             p95 {} µs  p99 {} µs  max {} µs",
            self.submitted.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.search_latency.mean_us(),
            self.search_latency.quantile_us(0.5),
            self.search_latency.quantile_us(0.95),
            self.search_latency.quantile_us(0.99),
            self.search_latency.max_us(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // histogram behavior is tested where it lives now (obs::hist);
    // this module keeps the coordinator-aggregate tests only

    #[test]
    fn metrics_batch_accounting() {
        let m = Metrics::new();
        m.batches.fetch_add(2, Ordering::Relaxed);
        m.batch_items.fetch_add(24, Ordering::Relaxed);
        assert!((m.mean_batch_size() - 12.0).abs() < 1e-9);
        assert!(m.report().contains("mean size 12.0"));
    }

    #[test]
    fn reexported_histogram_is_the_obs_one() {
        // the re-export keeps old call sites compiling; spot-check the
        // corrected bucketing semantics through the coordinator path
        let m = Metrics::new();
        for us in 1..=1000u64 {
            m.search_latency.record(us);
        }
        let p50 = m.search_latency.quantile_us(0.5);
        assert!((256..=1024).contains(&p50), "p50 = {p50}");
    }
}
