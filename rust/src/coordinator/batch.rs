//! Dynamic batching policy — the pure decision core of the coordinator.
//!
//! Separated from the threading so the invariants are directly testable
//! (and property-tested below): a batch flushes when it reaches
//! `max_batch` items **or** when its oldest item has waited `max_delay`,
//! whichever comes first; items never reorder within a batch; nothing is
//! dropped or duplicated.

use std::time::{Duration, Instant};

/// Accumulates items and decides when to flush.
pub struct BatchPolicy<T> {
    pub max_batch: usize,
    pub max_delay: Duration,
    items: Vec<T>,
    oldest: Option<Instant>,
}

impl<T> BatchPolicy<T> {
    pub fn new(max_batch: usize, max_delay: Duration) -> Self {
        assert!(max_batch > 0);
        BatchPolicy { max_batch, max_delay, items: Vec::new(), oldest: None }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Add an item (arrival time `now`); returns a full batch if the size
    /// bound was hit.
    pub fn push(&mut self, item: T, now: Instant) -> Option<Vec<T>> {
        if self.items.is_empty() {
            self.oldest = Some(now);
        }
        self.items.push(item);
        if self.items.len() >= self.max_batch {
            return Some(self.take());
        }
        None
    }

    /// Deadline-driven flush: returns the batch if the oldest item has
    /// waited at least `max_delay` by `now`.
    pub fn poll(&mut self, now: Instant) -> Option<Vec<T>> {
        match self.oldest {
            Some(t0) if now.duration_since(t0) >= self.max_delay
                && !self.items.is_empty() =>
            {
                Some(self.take())
            }
            _ => None,
        }
    }

    /// How long the batcher may sleep before the next deadline.
    pub fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        self.oldest.map(|t0| {
            self.max_delay
                .saturating_sub(now.duration_since(t0))
        })
    }

    /// Unconditional flush (shutdown path).
    pub fn take(&mut self) -> Vec<T> {
        self.oldest = None;
        std::mem::take(&mut self.items)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::SplitMix64};

    fn t0() -> Instant {
        Instant::now()
    }

    #[test]
    fn flushes_at_size_bound() {
        let mut b = BatchPolicy::new(3, Duration::from_secs(10));
        let now = t0();
        assert!(b.push(1, now).is_none());
        assert!(b.push(2, now).is_none());
        let batch = b.push(3, now).expect("size flush");
        assert_eq!(batch, vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn flushes_at_deadline() {
        let mut b = BatchPolicy::new(100, Duration::from_millis(5));
        let start = t0();
        b.push(7, start);
        assert!(b.poll(start).is_none());
        assert!(b.poll(start + Duration::from_millis(4)).is_none());
        let batch = b.poll(start + Duration::from_millis(5)).expect("deadline");
        assert_eq!(batch, vec![7]);
        // empty batcher never deadline-flushes
        assert!(b.poll(start + Duration::from_secs(1)).is_none());
    }

    #[test]
    fn deadline_tracks_oldest_item() {
        let mut b = BatchPolicy::new(100, Duration::from_millis(10));
        let start = t0();
        b.push(1, start);
        b.push(2, start + Duration::from_millis(9));
        // oldest is item 1: must flush at start+10 even though item 2 is fresh
        let batch = b.poll(start + Duration::from_millis(10)).unwrap();
        assert_eq!(batch, vec![1, 2]);
    }

    #[test]
    fn time_to_deadline_decreases() {
        let mut b = BatchPolicy::new(10, Duration::from_millis(20));
        let start = t0();
        assert!(b.time_to_deadline(start).is_none());
        b.push(1, start);
        let d1 = b.time_to_deadline(start).unwrap();
        let d2 = b.time_to_deadline(start + Duration::from_millis(15)).unwrap();
        assert!(d2 < d1);
        assert_eq!(b.time_to_deadline(start + Duration::from_millis(25)).unwrap(),
                   Duration::ZERO);
    }

    #[test]
    fn prop_no_loss_no_dup_no_reorder() {
        // property: any interleaving of pushes and polls preserves the
        // exact item sequence across concatenated flushed batches
        prop::forall_ok(
            7,
            50,
            |r: &mut SplitMix64| {
                let n = 1 + r.below(200);
                let max_batch = 1 + r.below(16);
                let ops: Vec<u8> = (0..n).map(|_| r.below(4) as u8).collect();
                (max_batch, ops)
            },
            |(max_batch, ops)| {
                let mut b = BatchPolicy::new(*max_batch,
                                             Duration::from_millis(3));
                let start = t0();
                let mut now = start;
                let mut flushed: Vec<u32> = Vec::new();
                let mut next = 0u32;
                for &op in ops {
                    match op {
                        0..=2 => {
                            if let Some(batch) = b.push(next, now) {
                                flushed.extend(batch);
                            }
                            next += 1;
                        }
                        _ => {
                            now += Duration::from_millis(2);
                            if let Some(batch) = b.poll(now) {
                                flushed.extend(batch);
                            }
                        }
                    }
                }
                flushed.extend(b.take());
                let want: Vec<u32> = (0..next).collect();
                if flushed == want {
                    Ok(())
                } else {
                    Err(format!("sequence broken: {flushed:?}"))
                }
            },
        );
    }

    #[test]
    fn prop_batches_bounded_by_max() {
        prop::forall(
            8,
            30,
            |r: &mut SplitMix64| (1 + r.below(8), 1 + r.below(100)),
            |&(max_batch, n)| {
                let mut b = BatchPolicy::new(max_batch, Duration::from_secs(1));
                let now = t0();
                let mut ok = true;
                for i in 0..n {
                    if let Some(batch) = b.push(i, now) {
                        ok &= batch.len() <= max_batch;
                    }
                }
                ok
            },
        );
    }
}
