//! Compressed index: code storage, the ADC scan hot path, and the paper's
//! two-stage (scan → rerank) search (§3.3).
//!
//! Storage is a flat `n × stride` byte matrix (SoA per row).  The scan is
//! the system's innermost loop: for LUT quantizers it is
//! `score[i] = bias + Σ_j tables[j·K + code[i][j]]`, specialized here with
//! fixed-stride row iteration and a branch-light bounded heap, processing
//! ~1 code byte per table lookup per vector — the same lookup structure
//! whose cost the paper reports as 3 s per 10⁹ × 8-byte scan.

pub mod scan;

use crate::config::SearchConfig;
use crate::data::Dataset;
use crate::linalg::{sq_l2, TopK};
use crate::quant::{Lut, Quantizer};

pub use scan::{scan_lut_topk, scan_topk};

/// Flat compressed database.
pub struct CompressedIndex {
    pub n: usize,
    pub stride: usize,
    pub codes: Vec<u8>,
}

impl CompressedIndex {
    /// Build by encoding a dataset with a quantizer.
    pub fn build(q: &dyn Quantizer, data: &Dataset) -> CompressedIndex {
        let codes = crate::quant::encode_dataset(q, data);
        CompressedIndex {
            n: data.len(),
            stride: q.code_bytes(),
            codes,
        }
    }

    pub fn from_codes(n: usize, stride: usize, codes: Vec<u8>) -> Self {
        assert_eq!(codes.len(), n * stride);
        CompressedIndex { n, stride, codes }
    }

    #[inline]
    pub fn code(&self, i: usize) -> &[u8] {
        &self.codes[i * self.stride..(i + 1) * self.stride]
    }

    /// Bytes of code storage (the paper's per-vector memory accounting).
    pub fn storage_bytes(&self) -> usize {
        self.codes.len()
    }

    /// A contiguous shard view `[lo, hi)` for multi-worker scans.
    pub fn shard(&self, lo: usize, hi: usize) -> IndexShard<'_> {
        IndexShard { index: self, lo, hi: hi.min(self.n) }
    }
}

/// Borrowed contiguous range of an index (scan work unit).
pub struct IndexShard<'a> {
    pub index: &'a CompressedIndex,
    pub lo: usize,
    pub hi: usize,
}

/// The paper's full search pipeline over one index.
pub struct SearchEngine<'a> {
    pub quant: &'a dyn Quantizer,
    pub index: &'a CompressedIndex,
    pub cfg: SearchConfig,
}

impl<'a> SearchEngine<'a> {
    pub fn new(quant: &'a dyn Quantizer, index: &'a CompressedIndex,
               cfg: SearchConfig) -> Self {
        SearchEngine { quant, index, cfg }
    }

    /// Stage 1 only: ADC scan for the top-`l` candidate ids.
    pub fn scan(&self, lut: &Lut, l: usize) -> Vec<(f32, u32)> {
        scan_topk(lut, self.index, l)
    }

    /// Full two-stage search: returns the final top-k ids, best first.
    pub fn search(&self, q: &[f32]) -> Vec<u32> {
        let lut = self.quant.lut(q);
        self.search_with_lut(q, &lut)
    }

    /// Search with a precomputed LUT (the serving path computes LUTs in
    /// batches through PJRT and hands them over individually).
    pub fn search_with_lut(&self, q: &[f32], lut: &Lut) -> Vec<u32> {
        let k = self.cfg.k;
        let do_rerank = !self.cfg.no_rerank && self.quant.supports_rerank();
        if !do_rerank {
            return self.scan(lut, k).into_iter().map(|(_, id)| id).collect();
        }
        let candidates: Vec<u32> = if self.cfg.exhaustive_rerank {
            (0..self.index.n as u32).collect()
        } else {
            let l = self.cfg.rerank_l.max(k);
            self.scan(lut, l).into_iter().map(|(_, id)| id).collect()
        };
        self.rerank(q, &candidates, k)
    }

    /// Stage 2: decode candidates and rank by exact `d1` (eq. 7).
    pub fn rerank(&self, q: &[f32], candidates: &[u32], k: usize) -> Vec<u32> {
        let dim = self.quant.dim();
        let cb = self.index.stride;
        // gather candidate codes into one contiguous batch
        let mut codes = Vec::with_capacity(candidates.len() * cb);
        for &id in candidates {
            codes.extend_from_slice(self.index.code(id as usize));
        }
        let mut recons = vec![0.0f32; candidates.len() * dim];
        if !self.quant.reconstruct_batch(&codes, &mut recons) {
            // no decoder: keep scan order
            return candidates.iter().take(k).copied().collect();
        }
        let mut top = TopK::new(k.min(candidates.len()));
        for (ci, &id) in candidates.iter().enumerate() {
            let d = sq_l2(q, &recons[ci * dim..(ci + 1) * dim]);
            top.push(d, id);
        }
        top.into_sorted().into_iter().map(|(_, id)| id).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic::Generator, Family};
    use crate::quant::pq::Pq;

    fn setup() -> (crate::data::Dataset, Pq) {
        let d = Generator::new(Family::SiftLike, 21).generate(1, 2000);
        let train = Generator::new(Family::SiftLike, 21).generate(0, 800);
        let pq = Pq::train(&train.data, train.dim, 8, 32, 0, 8);
        (d, pq)
    }

    #[test]
    fn build_and_storage_accounting() {
        let (d, pq) = setup();
        let idx = CompressedIndex::build(&pq, &d);
        assert_eq!(idx.n, 2000);
        assert_eq!(idx.stride, 8);
        assert_eq!(idx.storage_bytes(), 2000 * 8);
        assert_eq!(idx.code(5).len(), 8);
    }

    #[test]
    fn two_stage_equals_exhaustive_when_l_is_n() {
        let (d, pq) = setup();
        let idx = CompressedIndex::build(&pq, &d);
        let q = Generator::new(Family::SiftLike, 21).generate(2, 1);
        let full = SearchEngine::new(&pq, &idx, SearchConfig {
            rerank_l: idx.n, k: 10, no_rerank: false, exhaustive_rerank: false,
        });
        let exh = SearchEngine::new(&pq, &idx, SearchConfig {
            rerank_l: 10, k: 10, no_rerank: false, exhaustive_rerank: true,
        });
        assert_eq!(full.search(q.row(0)), exh.search(q.row(0)));
    }

    #[test]
    fn rerank_improves_or_matches_scan_quality() {
        // the reranked top-1 must have d1 ≤ the scan-only top-1's d1
        let (d, pq) = setup();
        let idx = CompressedIndex::build(&pq, &d);
        let queries = Generator::new(Family::SiftLike, 21).generate(2, 10);
        for qi in 0..queries.len() {
            let q = queries.row(qi);
            let scan_only = SearchEngine::new(&pq, &idx, SearchConfig {
                rerank_l: 100, k: 5, no_rerank: true, exhaustive_rerank: false,
            }).search(q);
            let two_stage = SearchEngine::new(&pq, &idx, SearchConfig {
                rerank_l: 100, k: 5, no_rerank: false, exhaustive_rerank: false,
            }).search(q);
            let d1 = |id: u32| {
                let mut rec = vec![0.0; d.dim];
                pq.reconstruct(idx.code(id as usize), &mut rec);
                sq_l2(q, &rec)
            };
            assert!(d1(two_stage[0]) <= d1(scan_only[0]) + 1e-4);
        }
    }

    #[test]
    fn no_rerank_returns_scan_order() {
        let (d, pq) = setup();
        let idx = CompressedIndex::build(&pq, &d);
        let q = Generator::new(Family::SiftLike, 21).generate(2, 1);
        let eng = SearchEngine::new(&pq, &idx, SearchConfig {
            rerank_l: 50, k: 7, no_rerank: true, exhaustive_rerank: false,
        });
        let lut = pq.lut(q.row(0));
        let scan: Vec<u32> = eng.scan(&lut, 7).into_iter().map(|p| p.1).collect();
        assert_eq!(eng.search(q.row(0)), scan);
    }

    #[test]
    fn shard_bounds_clamped() {
        let (d, pq) = setup();
        let idx = CompressedIndex::build(&pq, &d);
        let s = idx.shard(1500, 99999);
        assert_eq!(s.hi, 2000);
        assert_eq!(s.lo, 1500);
    }
}
