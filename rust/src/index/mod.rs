//! Compressed index: code storage, the ADC scan hot path, and the paper's
//! two-stage (scan → rerank) search (§3.3).
//!
//! Storage is a flat `n × stride` byte matrix (SoA per row).  The scan is
//! the system's innermost loop: for LUT quantizers it is
//! `score[i] = bias + Σ_j tables[j·K + code[i][j]]`, specialized here with
//! fixed-stride row iteration and a branch-light bounded heap, processing
//! ~1 code byte per table lookup per vector — the same lookup structure
//! whose cost the paper reports as 3 s per 10⁹ × 8-byte scan.
//!
//! Search is **batch-first**: [`SearchEngine::search_batch`] plans one
//! `QueryBatch × IndexShard` execution through [`crate::exec`] (sharded
//! scan on a worker pool, one batched decode for the rerank stage), and
//! the single-query [`SearchEngine::search`] is literally a batch of one.
//! Results are bit-identical for every `(num_threads, shard_rows)`.
//!
//! The scan stage has a **precision axis** (`SearchConfig::scan_precision`,
//! DESIGN.md §6): `F32` is the exact reference kernel; `U16`/`U8` select
//! candidates with integer-quantized LUTs over the blocked [`packed`]
//! layout and exactly re-score the survivors in f32, trading a bounded
//! boundary error for scan throughput.

pub mod filter;
pub mod packed;
pub mod scan;
pub mod segment;
pub mod simd;

use anyhow::Result;

use crate::config::{ScanPrecision, SearchConfig};
use crate::data::Dataset;
use crate::exec::{plan, Executor};
use crate::quant::{Lut, Quantizer, SketchPlanes};

pub use filter::{Filter, FilterBitmap, FilterPlan};
pub use packed::{PackedIndex, BLOCK};
pub use scan::{scan_lut_topk, scan_lut_topk_u16, scan_lut_topk_u4,
               scan_lut_topk_u8, scan_topk};
pub use segment::{Routing, StreamStats, StreamingIndex};

/// Flat compressed database.
pub struct CompressedIndex {
    pub n: usize,
    pub stride: usize,
    pub codes: Vec<u8>,
    /// Optional blocked position-major mirror of `codes` for the integer
    /// fast-scan kernels ([`packed::PackedIndex`], DESIGN.md §6).  The
    /// u16/u8 kernels transpose 32-row blocks on the fly when absent
    /// (identical results, more memory traffic); [`Self::ensure_packed`]
    /// builds it once for hot read paths.
    pub packed: Option<PackedIndex>,
    /// Optional per-row 1-bit sign sketches of the *reconstructions*
    /// (the vectors ADC scores against) for the scan pre-filter
    /// (DESIGN.md §9); [`Self::ensure_sketches`] builds them once.  One
    /// u64 per row.
    pub sketches: Option<Vec<u64>>,
    /// Optional row attribute column for metadata predicate filtering
    /// (DESIGN.md §13): one u64 tag per row, row-aligned with `codes`.
    /// `None` means "no attribute column" — a [`Filter`] over such an
    /// index admits no rows (strict semantics, see [`filter`]).
    pub tags: Option<Vec<u64>>,
}

impl CompressedIndex {
    /// Build by encoding a dataset with a quantizer.
    pub fn build(q: &dyn Quantizer, data: &Dataset) -> CompressedIndex {
        let codes = crate::quant::encode_dataset(q, data);
        CompressedIndex {
            n: data.len(),
            stride: q.code_bytes(),
            codes,
            packed: None,
            sketches: None,
            tags: None,
        }
    }

    pub fn from_codes(n: usize, stride: usize, codes: Vec<u8>) -> Self {
        assert_eq!(codes.len(), n * stride);
        CompressedIndex {
            n, stride, codes, packed: None, sketches: None, tags: None,
        }
    }

    /// Attach the row attribute column (one tag per row, row-aligned
    /// with the codes — see DESIGN.md §13).
    pub fn set_tags(&mut self, tags: Vec<u64>) {
        assert_eq!(tags.len(), self.n, "one tag per row");
        self.tags = Some(tags);
    }

    /// Build the blocked fast-scan mirror if it doesn't exist yet (cheap:
    /// one pass over the codes; ~2× code storage while held).
    pub fn ensure_packed(&mut self) {
        if self.packed.is_none() {
            let p = PackedIndex::pack(self.n, self.stride, &self.codes);
            self.packed = Some(p);
        }
    }

    #[inline]
    pub fn is_packed(&self) -> bool {
        self.packed.is_some()
    }

    /// Build the 1-bit pre-filter sketches if they don't exist yet (one
    /// decode pass over the codes; 8 B per row while held).  Returns
    /// whether sketches are available afterwards — `false` when the
    /// quantizer has no meaningful decoder, in which case searches with
    /// `cfg.prefilter` simply never prune on this index.
    pub fn ensure_sketches(&mut self, quant: &dyn Quantizer) -> bool {
        if self.sketches.is_none() {
            self.sketches =
                crate::quant::sketch_codes(quant, &self.codes, self.stride);
        }
        self.sketches.is_some()
    }

    #[inline]
    pub fn code(&self, i: usize) -> &[u8] {
        &self.codes[i * self.stride..(i + 1) * self.stride]
    }

    /// Bytes of code storage (the paper's per-vector memory accounting).
    pub fn storage_bytes(&self) -> usize {
        self.codes.len()
    }

    /// A contiguous shard view `[lo, hi)` for multi-worker scans.
    pub fn shard(&self, lo: usize, hi: usize) -> IndexShard<'_> {
        IndexShard { index: self, lo, hi: hi.min(self.n) }
    }
}

/// Borrowed contiguous range of an index (scan work unit).
pub struct IndexShard<'a> {
    pub index: &'a CompressedIndex,
    pub lo: usize,
    pub hi: usize,
}

/// Per-query scan options shared by every backend: the precision axis
/// (DESIGN.md §6), the 1-bit pre-filter (§9), and the metadata
/// predicate (§13).  The request-level mirror of
/// [`crate::exec::ScanSpec`] — that one holds compiled, borrowed plans;
/// this one holds the plain options each backend compiles them from.
#[derive(Clone, Copy, Debug)]
pub struct QuerySpec {
    pub precision: ScanPrecision,
    pub prefilter: bool,
    pub prefilter_margin: usize,
    pub filter: Option<Filter>,
}

/// One batch search, in the single shape every backend accepts:
/// `CompressedIndex`, `IvfIndex`, `DiskIvfIndex`, and `StreamingIndex`
/// all expose `search_batch_on(quant, exec, queries, &req)` over this
/// struct (and [`crate::ivf::IndexBackend`] dispatches it).  Replaces
/// the four divergent positional signatures that grew one parameter per
/// feature; the coordinator and the TCP front door build one request
/// object from config + wire frame.
#[derive(Clone, Debug)]
pub struct SearchRequest {
    /// Top-k per query — one entry per query in the batch.
    pub ks: Vec<usize>,
    /// IVF lists probed per query (ignored by the flat backend).
    pub nprobe: usize,
    /// Stage-1 candidate depth, floored at each query's `k`.
    pub rerank_l: usize,
    pub no_rerank: bool,
    pub exhaustive_rerank: bool,
    pub shard_rows: usize,
    pub spec: QuerySpec,
}

impl SearchRequest {
    /// The standard construction: every knob from the config, plus the
    /// per-query result sizes.
    pub fn from_config(cfg: &SearchConfig, ks: Vec<usize>) -> SearchRequest {
        SearchRequest {
            ks,
            nprobe: cfg.nprobe,
            rerank_l: cfg.rerank_l,
            no_rerank: cfg.no_rerank,
            exhaustive_rerank: cfg.exhaustive_rerank,
            shard_rows: cfg.shard_rows,
            spec: QuerySpec {
                precision: cfg.scan_precision,
                prefilter: cfg.prefilter,
                prefilter_margin: cfg.prefilter_margin,
                filter: cfg.filter,
            },
        }
    }

    /// Bridge to the config struct the backend internals consume
    /// (request-less callers construct a [`SearchConfig`] directly; the
    /// unified entry points go the other way).
    pub(crate) fn to_search_config(&self) -> SearchConfig {
        SearchConfig {
            nprobe: self.nprobe,
            rerank_l: self.rerank_l,
            no_rerank: self.no_rerank,
            exhaustive_rerank: self.exhaustive_rerank,
            shard_rows: self.shard_rows,
            scan_precision: self.spec.precision,
            prefilter: self.spec.prefilter,
            prefilter_margin: self.spec.prefilter_margin,
            filter: self.spec.filter,
            ..Default::default()
        }
    }
}

impl CompressedIndex {
    /// The unified batch entry point (one shape across all backends —
    /// see [`SearchRequest`]): the paper's two-stage scan → rerank
    /// pipeline over this flat index.  Infallible in practice; the
    /// `Result` matches the disk-backed implementations.
    pub fn search_batch_on(&self, quant: &dyn Quantizer, exec: &Executor,
                           queries: &[&[f32]], req: &SearchRequest)
                           -> Result<Vec<Vec<u32>>> {
        assert_eq!(queries.len(), req.ks.len(), "one k per query");
        if queries.is_empty() {
            return Ok(Vec::new());
        }
        let luts = {
            let mut span = crate::span!("lut_build");
            span.add_rows(queries.len() as u64);
            quant.lut_batch(queries)
        };
        let eng = SearchEngine::new(quant, self, req.to_search_config());
        Ok(eng.search_batch_with_luts_on(exec, queries, &luts, &req.ks))
    }
}

/// The paper's full search pipeline over one index.
pub struct SearchEngine<'a> {
    pub quant: &'a dyn Quantizer,
    pub index: &'a CompressedIndex,
    pub cfg: SearchConfig,
}

impl<'a> SearchEngine<'a> {
    pub fn new(quant: &'a dyn Quantizer, index: &'a CompressedIndex,
               cfg: SearchConfig) -> Self {
        SearchEngine { quant, index, cfg }
    }

    /// Stage 1 only: ADC scan for the top-`l` candidate ids.
    pub fn scan(&self, lut: &Lut, l: usize) -> Vec<(f32, u32)> {
        scan_topk(lut, self.index, l)
    }

    /// Full two-stage search: a batch of one, always on the inline
    /// executor — a single query gains nothing from a pool, and spawning
    /// threads per call would dominate the microsecond-scale scan.
    /// (`cfg.num_threads` applies to [`Self::search_batch`].)
    pub fn search(&self, q: &[f32]) -> Vec<u32> {
        self.search_batch_on(&Executor::Inline, &[q])
            .pop()
            .expect("one query in, one result out")
    }

    /// Search with a precomputed LUT (callers that build LUTs themselves,
    /// e.g. across repeated sweeps over the same query); inline for the
    /// same reason as [`Self::search`].
    pub fn search_with_lut(&self, q: &[f32], lut: &Lut) -> Vec<u32> {
        self.search_batch_with_luts_on(&Executor::Inline, &[q],
                                       std::slice::from_ref(lut),
                                       &[self.cfg.k])
            .pop()
            .expect("one query in, one result out")
    }

    /// Batch-first two-stage search on a transient executor sized by
    /// `cfg.num_threads`.  Serving paths that amortize thread spawn
    /// should hold an [`Executor`] and call [`Self::search_batch_on`].
    pub fn search_batch(&self, queries: &[&[f32]]) -> Vec<Vec<u32>> {
        let exec = Executor::new(self.cfg.num_threads);
        self.search_batch_on(&exec, queries)
    }

    /// Batch search on a caller-owned executor: builds all LUTs in one
    /// `lut_batch` call (one PJRT execution for UNQ), then runs the
    /// `QueryBatch × IndexShard` plan.
    pub fn search_batch_on(&self, exec: &Executor, queries: &[&[f32]])
                           -> Vec<Vec<u32>> {
        if queries.is_empty() {
            return Vec::new();
        }
        let luts = {
            let mut span = crate::span!("lut_build");
            span.add_rows(queries.len() as u64);
            self.quant.lut_batch(queries)
        };
        let ks = vec![self.cfg.k; queries.len()];
        self.search_batch_with_luts_on(exec, queries, &luts, &ks)
    }

    /// The full plan with precomputed LUTs and a per-query `k` (the
    /// coordinator's entry point: its clients ask for different `k`s
    /// within one flushed batch).
    pub fn search_batch_with_luts_on(&self, exec: &Executor,
                                     queries: &[&[f32]], luts: &[Lut],
                                     ks: &[usize]) -> Vec<Vec<u32>> {
        assert_eq!(queries.len(), luts.len(), "one LUT per query");
        assert_eq!(queries.len(), ks.len(), "one k per query");
        let ids = |pairs: Vec<(f32, u32)>| -> Vec<u32> {
            pairs.into_iter().map(|(_, id)| id).collect()
        };
        let pre = self.prefilter_plan(queries);
        let fplan = self.cfg.filter
            .map(|f| FilterPlan::compile(&f, &[self.index]));
        let spec = plan::ScanSpec {
            precision: self.cfg.scan_precision,
            prefilter: pre.as_ref(),
            filter: fplan.as_ref(),
        };
        let do_rerank = !self.cfg.no_rerank && self.quant.supports_rerank();
        if !do_rerank {
            return exec
                .scan_batch(luts, self.index, ks, self.cfg.shard_rows, &spec)
                .into_iter()
                .map(ids)
                .collect();
        }
        if self.cfg.exhaustive_rerank {
            // exhaustive d1 decodes the WHOLE index per query (~n×dim
            // floats each) — batching those reconstructions across
            // queries would multiply that working set by the batch size,
            // so this path stays one query at a time.  Under a filter
            // "the whole index" is the admitted subset: exhaustive
            // filtered search IS the post-filter oracle.
            let all: Vec<u32> = match &fplan {
                Some(fp) => (0..self.index.n as u32)
                    .filter(|&id| fp.bitmap(0).is_admitted(id as usize))
                    .collect(),
                None => (0..self.index.n as u32).collect(),
            };
            let all = vec![all];
            return queries
                .iter()
                .zip(ks)
                .map(|(&q, &k)| {
                    plan::rerank_batch(self.quant, self.index, &[q], &all,
                                       &[k])
                        .pop()
                        .expect("one query in, one result out")
                })
                .collect();
        }
        let ls: Vec<usize> =
            ks.iter().map(|&k| self.cfg.rerank_l.max(k)).collect();
        let candidates: Vec<Vec<u32>> =
            exec.scan_batch(luts, self.index, &ls, self.cfg.shard_rows,
                            &spec)
                .into_iter()
                .map(ids)
                .collect();
        plan::rerank_batch(self.quant, self.index, queries, &candidates, ks)
    }

    /// Resolve the 1-bit pre-filter stage for a query batch: engaged
    /// only when configured AND the index carries row sketches
    /// ([`CompressedIndex::ensure_sketches`]); the query side re-derives
    /// the same hyperplanes from the dimensionality (DESIGN.md §9).
    fn prefilter_plan(&self, queries: &[&[f32]])
                      -> Option<plan::PrefilterPlan> {
        if !self.cfg.prefilter || self.index.sketches.is_none() {
            return None;
        }
        let planes = SketchPlanes::for_dim(self.quant.dim());
        Some(plan::PrefilterPlan {
            qsketches: queries.iter().map(|q| Some(planes.sketch(q))).collect(),
            margin: self.cfg.prefilter_margin,
        })
    }

    /// Stage 2: decode candidates and rank by exact `d1` (eq. 7) — a
    /// batch of one through the shared batched-rerank reduction.
    pub fn rerank(&self, q: &[f32], candidates: &[u32], k: usize) -> Vec<u32> {
        let cands = vec![candidates.to_vec()];
        plan::rerank_batch(self.quant, self.index, &[q], &cands, &[k])
            .pop()
            .expect("one query in, one result out")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic::Generator, Family};
    use crate::linalg::sq_l2;
    use crate::quant::pq::Pq;

    fn setup() -> (crate::data::Dataset, Pq) {
        let d = Generator::new(Family::SiftLike, 21).generate(1, 2000);
        let train = Generator::new(Family::SiftLike, 21).generate(0, 800);
        let pq = Pq::train(&train.data, train.dim, 8, 32, 0, 8);
        (d, pq)
    }

    #[test]
    fn build_and_storage_accounting() {
        let (d, pq) = setup();
        let idx = CompressedIndex::build(&pq, &d);
        assert_eq!(idx.n, 2000);
        assert_eq!(idx.stride, 8);
        assert_eq!(idx.storage_bytes(), 2000 * 8);
        assert_eq!(idx.code(5).len(), 8);
    }

    #[test]
    fn two_stage_equals_exhaustive_when_l_is_n() {
        let (d, pq) = setup();
        let idx = CompressedIndex::build(&pq, &d);
        let q = Generator::new(Family::SiftLike, 21).generate(2, 1);
        let full = SearchEngine::new(&pq, &idx, SearchConfig {
            rerank_l: idx.n, k: 10, ..Default::default()
        });
        let exh = SearchEngine::new(&pq, &idx, SearchConfig {
            rerank_l: 10, k: 10, exhaustive_rerank: true, ..Default::default()
        });
        assert_eq!(full.search(q.row(0)), exh.search(q.row(0)));
    }

    #[test]
    fn rerank_improves_or_matches_scan_quality() {
        // the reranked top-1 must have d1 ≤ the scan-only top-1's d1
        let (d, pq) = setup();
        let idx = CompressedIndex::build(&pq, &d);
        let queries = Generator::new(Family::SiftLike, 21).generate(2, 10);
        for qi in 0..queries.len() {
            let q = queries.row(qi);
            let scan_only = SearchEngine::new(&pq, &idx, SearchConfig {
                rerank_l: 100, k: 5, no_rerank: true, ..Default::default()
            }).search(q);
            let two_stage = SearchEngine::new(&pq, &idx, SearchConfig {
                rerank_l: 100, k: 5, ..Default::default()
            }).search(q);
            let d1 = |id: u32| {
                let mut rec = vec![0.0; d.dim];
                pq.reconstruct(idx.code(id as usize), &mut rec);
                sq_l2(q, &rec)
            };
            assert!(d1(two_stage[0]) <= d1(scan_only[0]) + 1e-4);
        }
    }

    #[test]
    fn no_rerank_returns_scan_order() {
        let (d, pq) = setup();
        let idx = CompressedIndex::build(&pq, &d);
        let q = Generator::new(Family::SiftLike, 21).generate(2, 1);
        let eng = SearchEngine::new(&pq, &idx, SearchConfig {
            rerank_l: 50, k: 7, no_rerank: true, ..Default::default()
        });
        let lut = pq.lut(q.row(0));
        let scan: Vec<u32> = eng.scan(&lut, 7).into_iter().map(|p| p.1).collect();
        assert_eq!(eng.search(q.row(0)), scan);
    }

    #[test]
    fn shard_bounds_clamped() {
        let (d, pq) = setup();
        let idx = CompressedIndex::build(&pq, &d);
        let s = idx.shard(1500, 99999);
        assert_eq!(s.hi, 2000);
        assert_eq!(s.lo, 1500);
    }

    #[test]
    fn prop_search_batch_matches_sequential_over_thread_grid() {
        // the acceptance property at engine level: for any
        // (num_threads, shard_rows) the batch engine returns exactly the
        // classic one-query-at-a-time results, rerank included
        let (d, pq) = setup();
        let idx = CompressedIndex::build(&pq, &d);
        let queries = Generator::new(Family::SiftLike, 21).generate(3, 8);
        let qrefs: Vec<&[f32]> =
            (0..queries.len()).map(|qi| queries.row(qi)).collect();
        let seq_cfg = SearchConfig {
            rerank_l: 50, k: 10, ..Default::default()
        };
        let want: Vec<Vec<u32>> = qrefs
            .iter()
            .map(|q| SearchEngine::new(&pq, &idx, seq_cfg).search(q))
            .collect();
        crate::util::prop::forall_ok(
            2024,
            10,
            |r: &mut crate::util::rng::SplitMix64| {
                let threads = 1 + r.below(4);
                let shard_rows = [0usize, 64, 300, 1024, 5000][r.below(5)];
                (threads, shard_rows)
            },
            |&(threads, shard_rows)| {
                let cfg = SearchConfig {
                    num_threads: threads, shard_rows, ..seq_cfg
                };
                let got =
                    SearchEngine::new(&pq, &idx, cfg).search_batch(&qrefs);
                if got == want {
                    Ok(())
                } else {
                    Err(format!(
                        "threads={threads} shard_rows={shard_rows} diverged \
                         from sequential search"
                    ))
                }
            },
        );
    }

    #[test]
    fn precision_with_full_rerank_matches_f32_exactly() {
        // with rerank_l = n the stage-1 candidate set is the whole index
        // at every precision, so the exact d1 rerank must return results
        // identical to the f32 engine — packed or not
        use crate::config::ScanPrecision;
        let (d, pq) = setup();
        let idx = CompressedIndex::build(&pq, &d);
        let mut packed_idx = CompressedIndex::build(&pq, &d);
        packed_idx.ensure_packed();
        let queries = Generator::new(Family::SiftLike, 21).generate(4, 6);
        let qrefs: Vec<&[f32]> =
            (0..queries.len()).map(|qi| queries.row(qi)).collect();
        let base = SearchConfig { rerank_l: idx.n, k: 10,
                                  ..Default::default() };
        let want = SearchEngine::new(&pq, &idx, base).search_batch(&qrefs);
        // U4 exercises the wide-codebook fallback here: PQ carries 32
        // codewords, so u4_from declines and the f32 path must kick in.
        for precision in
            [ScanPrecision::U16, ScanPrecision::U8, ScanPrecision::U4]
        {
            for ix in [&idx, &packed_idx] {
                let cfg = SearchConfig { scan_precision: precision, ..base };
                let got = SearchEngine::new(&pq, ix, cfg).search_batch(&qrefs);
                assert_eq!(got, want,
                           "{precision:?} packed={}", ix.is_packed());
            }
        }
    }

    #[test]
    fn u16_no_rerank_recall_tracks_f32_closely() {
        // real PQ tables: the u16 step is tiny relative to distance
        // margins, so the selected top-10 overlaps f32's almost entirely
        use crate::config::ScanPrecision;
        let (d, pq) = setup();
        let mut idx = CompressedIndex::build(&pq, &d);
        idx.ensure_packed();
        let queries = Generator::new(Family::SiftLike, 21).generate(5, 20);
        let qrefs: Vec<&[f32]> =
            (0..queries.len()).map(|qi| queries.row(qi)).collect();
        let base = SearchConfig { rerank_l: 50, k: 10, no_rerank: true,
                                  ..Default::default() };
        let f32_res = SearchEngine::new(&pq, &idx, base).search_batch(&qrefs);
        let cfg = SearchConfig { scan_precision: ScanPrecision::U16, ..base };
        let u16_res = SearchEngine::new(&pq, &idx, cfg).search_batch(&qrefs);
        let overlap: usize = f32_res
            .iter()
            .zip(&u16_res)
            .map(|(a, b)| a.iter().filter(|&id| b.contains(id)).count())
            .sum();
        let total = 10 * qrefs.len();
        assert!(overlap * 10 >= total * 9,
                "u16 overlap {overlap}/{total} collapsed");
    }

    #[test]
    fn search_batch_no_rerank_matches_scan_order_per_query() {
        let (d, pq) = setup();
        let idx = CompressedIndex::build(&pq, &d);
        let queries = Generator::new(Family::SiftLike, 21).generate(2, 4);
        let qrefs: Vec<&[f32]> =
            (0..queries.len()).map(|qi| queries.row(qi)).collect();
        let eng = SearchEngine::new(&pq, &idx, SearchConfig {
            rerank_l: 50, k: 7, no_rerank: true, num_threads: 2,
            shard_rows: 256, ..Default::default()
        });
        let got = eng.search_batch(&qrefs);
        for (qi, q) in qrefs.iter().enumerate() {
            let lut = pq.lut(q);
            let want: Vec<u32> =
                eng.scan(&lut, 7).into_iter().map(|p| p.1).collect();
            assert_eq!(got[qi], want, "query {qi}");
        }
    }

    #[test]
    fn filtered_flat_search_matches_post_filter_oracle_at_all_precisions() {
        // the tentpole contract on the flat backend: filtered search
        // through the unified request equals the unfiltered search
        // post-filtered to the admitted rows — at every precision, plus
        // the selectivity-0 (empty, no panic) and selectivity-1
        // (identical to unfiltered) endpoints
        let (d, pq) = setup();
        let mut idx = CompressedIndex::build(&pq, &d);
        idx.set_tags((0..idx.n).map(|i| (i % 2) as u64).collect());
        let queries = Generator::new(Family::SiftLike, 21).generate(8, 5);
        let qrefs: Vec<&[f32]> =
            (0..queries.len()).map(|qi| queries.row(qi)).collect();
        let exec = Executor::Inline;
        let base = SearchConfig { rerank_l: idx.n, k: idx.n,
                                  ..Default::default() };
        let full = SearchEngine::new(&pq, &idx, base).search_batch(&qrefs);
        let oracle: Vec<Vec<u32>> = full
            .iter()
            .map(|ids| ids.iter().copied()
                .filter(|&id| id % 2 == 1)
                .take(10)
                .collect())
            .collect();
        for precision in [ScanPrecision::F32, ScanPrecision::U16,
                          ScanPrecision::U8, ScanPrecision::U4]
        {
            let cfg = SearchConfig { rerank_l: idx.n, k: 10,
                                     scan_precision: precision,
                                     filter: Some(Filter::TagEq(1)),
                                     ..Default::default() };
            let req =
                SearchRequest::from_config(&cfg, vec![10; qrefs.len()]);
            let got =
                idx.search_batch_on(&pq, &exec, &qrefs, &req).unwrap();
            assert_eq!(got, oracle, "{precision:?}");
        }
        // selectivity 0: empty results, not a panic
        let cfg = SearchConfig { rerank_l: idx.n, k: 10,
                                 filter: Some(Filter::TagEq(9)),
                                 ..Default::default() };
        let req = SearchRequest::from_config(&cfg, vec![10; qrefs.len()]);
        let got = idx.search_batch_on(&pq, &exec, &qrefs, &req).unwrap();
        assert!(got.iter().all(Vec::is_empty));
        // selectivity 1: bit-identical to the unfiltered engine
        idx.set_tags(vec![5u64; idx.n]);
        let base10 = SearchConfig { rerank_l: 50, k: 10,
                                    ..Default::default() };
        let want =
            SearchEngine::new(&pq, &idx, base10).search_batch(&qrefs);
        let cfg = SearchConfig { filter: Some(Filter::TagEq(5)), ..base10 };
        let req = SearchRequest::from_config(&cfg, vec![10; qrefs.len()]);
        let got = idx.search_batch_on(&pq, &exec, &qrefs, &req).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn prefilter_with_full_keep_is_bit_identical_to_plain_engine() {
        // keep = k·margin ≥ n makes the pre-filter admit every row, and
        // the pruned scan delegates to the plain one — so results must
        // match bit for bit, with and without rerank
        let (d, pq) = setup();
        let mut idx = CompressedIndex::build(&pq, &d);
        assert!(idx.ensure_sketches(&pq), "PQ decodes, sketches must build");
        assert_eq!(idx.sketches.as_ref().map(Vec::len), Some(idx.n));
        let queries = Generator::new(Family::SiftLike, 21).generate(6, 5);
        let qrefs: Vec<&[f32]> =
            (0..queries.len()).map(|qi| queries.row(qi)).collect();
        for no_rerank in [true, false] {
            let base = SearchConfig { rerank_l: 60, k: 9, no_rerank,
                                      ..Default::default() };
            let plain = SearchEngine::new(&pq, &idx, base)
                .search_batch(&qrefs);
            let cfg = SearchConfig { prefilter: true,
                                     prefilter_margin: 10_000, ..base };
            let pre = SearchEngine::new(&pq, &idx, cfg).search_batch(&qrefs);
            assert_eq!(pre, plain, "no_rerank={no_rerank}");
        }
    }

    #[test]
    fn prefilter_without_sketches_is_a_no_op() {
        // prefilter: true on an index that never built sketches must
        // resolve to the plain scan (the plan needs both sides)
        let (d, pq) = setup();
        let idx = CompressedIndex::build(&pq, &d);
        assert!(idx.sketches.is_none());
        let queries = Generator::new(Family::SiftLike, 21).generate(6, 4);
        let qrefs: Vec<&[f32]> =
            (0..queries.len()).map(|qi| queries.row(qi)).collect();
        let base = SearchConfig { rerank_l: 50, k: 10,
                                  ..Default::default() };
        let plain = SearchEngine::new(&pq, &idx, base).search_batch(&qrefs);
        let cfg = SearchConfig { prefilter: true, prefilter_margin: 2,
                                 ..base };
        let got = SearchEngine::new(&pq, &idx, cfg).search_batch(&qrefs);
        assert_eq!(got, plain);
    }

    #[test]
    fn prefilter_recall_stays_high_under_real_pruning() {
        // margin 40 admits ~20% of the 2000 rows per query (keep = 400),
        // so the prune genuinely engages; sign sketches of the PQ
        // reconstructions must still retain the bulk of the f32 top-10
        let (d, pq) = setup();
        let mut idx = CompressedIndex::build(&pq, &d);
        assert!(idx.ensure_sketches(&pq));
        let queries = Generator::new(Family::SiftLike, 21).generate(7, 20);
        let qrefs: Vec<&[f32]> =
            (0..queries.len()).map(|qi| queries.row(qi)).collect();
        let base = SearchConfig { rerank_l: 10, k: 10, no_rerank: true,
                                  ..Default::default() };
        let full = SearchEngine::new(&pq, &idx, base).search_batch(&qrefs);
        let cfg = SearchConfig { prefilter: true, prefilter_margin: 40,
                                 ..base };
        // non-vacuity: keep = 10·40 = 400 < n = 2000, so every query
        // is scored on a strict subset of the index
        assert!(base.k * cfg.prefilter_margin < idx.n);
        let pruned = SearchEngine::new(&pq, &idx, cfg).search_batch(&qrefs);
        let overlap: usize = full
            .iter()
            .zip(&pruned)
            .map(|(a, b)| a.iter().filter(|&id| b.contains(id)).count())
            .sum();
        let total = 10 * qrefs.len();
        assert!(overlap * 2 >= total,
                "prefilter overlap {overlap}/{total} collapsed");
    }
}
