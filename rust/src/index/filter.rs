//! Metadata predicate filtering pushed *into* the LUT scan
//! (rust/DESIGN.md §13).
//!
//! The paper's premise is that distances are computed in the compressed
//! domain, so a metadata predicate must prune rows *before* top-k
//! selection — post-filtering decoded results either starves top-k or
//! forces decode-side work the architecture exists to avoid.  The
//! pieces:
//!
//! * an **attribute column**: one `u64` tag per row, stored alongside
//!   the codes on [`CompressedIndex`] (and mirrored through segments
//!   and the disk tier's block archive);
//! * a per-query [`Filter`] predicate, compiled once per search into a
//!   [`FilterPlan`] — one row [`FilterBitmap`] per scanned index;
//! * scan kernels that consult the bitmap *inside* the selection loop
//!   ([`crate::index::scan`]), so filtered rows never enter the top-k
//!   heap and filtered search is exactly the search over the admitted
//!   subset — a strictly stronger guarantee than tombstone-style
//!   over-fetch, with the same can't-starve consequence.
//!
//! **Strict semantics:** filtering an index that has no attribute
//! column admits *no* rows (a predicate over a column that does not
//! exist matches nothing).  This keeps "filtered ≡ post-filtered
//! oracle" honest instead of silently degrading to an unfiltered scan.

use crate::index::CompressedIndex;
use crate::obs;

/// A per-query metadata predicate over the row attribute column.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Filter {
    /// Admit exactly the rows whose tag equals the value.
    TagEq(u64),
}

impl Filter {
    /// Parse the CLI/config surface syntax (`tag=V`).
    pub fn parse(s: &str) -> Result<Filter, String> {
        let Some(v) = s.strip_prefix("tag=") else {
            return Err(format!("bad filter {s:?}: expected tag=<u64>"));
        };
        v.trim().parse::<u64>()
            .map(Filter::TagEq)
            .map_err(|_| format!("bad filter value {v:?}: expected u64"))
    }

    /// Does `tag` satisfy the predicate?
    #[inline]
    pub fn admits(&self, tag: u64) -> bool {
        match self {
            Filter::TagEq(v) => tag == *v,
        }
    }
}

impl std::fmt::Display for Filter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Filter::TagEq(v) => write!(f, "tag={v}"),
        }
    }
}

/// A row-admission bitmap for one index: bit `i` set ⇔ stored row `i`
/// satisfies the predicate.
pub struct FilterBitmap {
    words: Vec<u64>,
    n: usize,
}

impl FilterBitmap {
    /// Compile a predicate against an index's attribute column.  An
    /// index without tags yields an all-zero bitmap (strict semantics —
    /// see the module docs).
    pub fn build(filter: &Filter, index: &CompressedIndex) -> FilterBitmap {
        let n = index.n;
        let mut words = vec![0u64; n.div_ceil(64)];
        if let Some(tags) = &index.tags {
            for (i, &t) in tags.iter().enumerate() {
                if filter.admits(t) {
                    words[i / 64] |= 1u64 << (i % 64);
                }
            }
        }
        FilterBitmap { words, n }
    }

    /// Number of rows the bitmap covers.
    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Is stored row `row` admitted by the predicate?
    #[inline]
    pub fn is_admitted(&self, row: usize) -> bool {
        debug_assert!(row < self.n, "row {row} out of bitmap range {}", self.n);
        (self.words[row / 64] >> (row % 64)) & 1 == 1
    }

    /// Admitted rows in `[lo, hi)` — the fast-path/pruning accounting
    /// the executor charges `filter.rows_pruned` from.
    pub fn admitted_in(&self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi && hi <= self.n);
        let mut count = 0usize;
        let (w0, w1) = (lo / 64, hi.div_ceil(64));
        for w in w0..w1 {
            let mut word = self.words[w];
            let base = w * 64;
            if base < lo {
                word &= !0u64 << (lo - base);
            }
            if base + 64 > hi {
                word &= !0u64 >> (base + 64 - hi);
            }
            count += word.count_ones() as usize;
        }
        count
    }
}

/// A compiled filter for one scan plan: bitmap `i` covers the plan's
/// index `i` (the `indexes` slice handed to
/// [`crate::exec::Executor::run_scan_tasks`]).
pub struct FilterPlan {
    pub bitmaps: Vec<FilterBitmap>,
}

impl FilterPlan {
    /// Compile `filter` against every index a plan will scan.
    pub fn compile(filter: &Filter, indexes: &[&CompressedIndex])
                   -> FilterPlan {
        let bitmaps: Vec<FilterBitmap> = indexes
            .iter()
            .map(|ix| FilterBitmap::build(filter, ix))
            .collect();
        obs::global().filter_bitmaps_built.add(bitmaps.len() as u64);
        FilterPlan { bitmaps }
    }

    /// The bitmap for plan index `index`.
    #[inline]
    pub fn bitmap(&self, index: usize) -> &FilterBitmap {
        &self.bitmaps[index]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ix_with_tags(tags: Vec<u64>) -> CompressedIndex {
        let n = tags.len();
        let mut ix = CompressedIndex::from_codes(n, 1, vec![0u8; n]);
        ix.set_tags(tags);
        ix
    }

    #[test]
    fn parse_and_display_roundtrip() {
        let f = Filter::parse("tag=42").unwrap();
        assert_eq!(f, Filter::TagEq(42));
        assert_eq!(f.to_string(), "tag=42");
        assert!(Filter::parse("tag=").is_err());
        assert!(Filter::parse("color=red").is_err());
        assert!(Filter::parse("tag=-1").is_err());
    }

    #[test]
    fn bitmap_matches_scalar_predicate() {
        let tags: Vec<u64> = (0..131).map(|i| i % 3).collect();
        let ix = ix_with_tags(tags.clone());
        let bm = FilterBitmap::build(&Filter::TagEq(1), &ix);
        assert_eq!(bm.len(), 131);
        for (i, &t) in tags.iter().enumerate() {
            assert_eq!(bm.is_admitted(i), t == 1, "row {i}");
        }
    }

    #[test]
    fn admitted_in_counts_every_subrange() {
        let tags: Vec<u64> = (0..200).map(|i| (i * 7 + 3) % 5).collect();
        let ix = ix_with_tags(tags.clone());
        let bm = FilterBitmap::build(&Filter::TagEq(2), &ix);
        for lo in (0..200).step_by(13) {
            for hi in (lo..=200).step_by(17) {
                let want = tags[lo..hi].iter().filter(|&&t| t == 2).count();
                assert_eq!(bm.admitted_in(lo, hi), want, "[{lo}, {hi})");
            }
        }
        assert_eq!(bm.admitted_in(64, 64), 0);
    }

    #[test]
    fn untagged_index_admits_no_rows() {
        let ix = CompressedIndex::from_codes(70, 1, vec![0u8; 70]);
        let bm = FilterBitmap::build(&Filter::TagEq(0), &ix);
        for row in 0..70 {
            assert!(!bm.is_admitted(row));
        }
        assert_eq!(bm.admitted_in(0, 70), 0);
    }

    #[test]
    fn plan_compiles_one_bitmap_per_index() {
        let a = ix_with_tags(vec![1, 2, 1]);
        let b = CompressedIndex::from_codes(2, 1, vec![0u8; 2]);
        let plan = FilterPlan::compile(&Filter::TagEq(1), &[&a, &b]);
        assert_eq!(plan.bitmaps.len(), 2);
        assert!(plan.bitmap(0).is_admitted(0));
        assert!(!plan.bitmap(0).is_admitted(1));
        assert!(!plan.bitmap(1).is_admitted(0));
    }
}
