//! Mutable streaming index: WAL-backed segments with online compaction.
//!
//! The paper's system (and PRs 1–3) assume a frozen database: encode
//! once, search forever.  This module opens the streaming workload —
//! continuous inserts and deletes that keep the read path hot — with a
//! log-structured design (`rust/DESIGN.md` §7):
//!
//! ```text
//!            inserts (encode-on-insert)          deletes
//!                     │                             │
//!                     ▼                             ▼
//!   WAL ──fsync──► active segment           tombstone bitmaps
//!                     │ seal at segment_rows        │
//!                     ▼                             │
//!        sealed segments (packed, immutable) ◄──────┘
//!                     │ compactor: drop dead rows, repack
//!                     ▼
//!            one merged sealed segment
//! ```
//!
//! * **Segments** — every segment is an immutable `(codes, ids, offsets,
//!   tombstones)` quadruple.  Sealed segments carry the BLOCK=32 packed
//!   mirror for the integer fast-scan kernels; the active tail stays
//!   flat (its blocked scan transposes on the fly — identical results).
//!   With a [`Routing`], rows are grouped per coarse list inside every
//!   segment (the IVF write path: inserts route through the coarse
//!   quantizer, optionally encoding residuals).  Every segment also
//!   carries a row-aligned metadata tag column (u64 per row, default 0
//!   for untagged inserts — [`StreamingIndex::insert_batch_tagged`]):
//!   tags ride the WAL insert records, survive seal/compact/checkpoint,
//!   and back the per-query predicate filter (rust/DESIGN.md §13).
//! * **Epoch guard** — readers take an [`Arc`] snapshot of the whole
//!   [`SegmentSet`]; every mutation builds a *new* set (sharing
//!   untouched segments) and swaps it in under a short write lock,
//!   bumping `generation`.  In-flight `run_scan_tasks` plans
//!   keep their snapshot alive, so a concurrent seal/compact can never
//!   tear an index out from under a scan.
//! * **Durability** — when opened on a directory, every mutation is
//!   logged through [`crate::store::wal`] and fsync'd before it becomes
//!   visible; sealed state is checkpointed into [`crate::store::Store`]
//!   archives (atomic save) at compaction, and recovery = load archives
//!   + replay the WAL tail through the same apply paths the live
//!   operations use.
//! * **Search** — exactly the two-stage pipeline of the frozen indexes:
//!   one executor plan fans out over `(query, segment[, probed list])`
//!   slots at all three scan precisions, per-slot winners are remapped
//!   to external ids, tombstones filtered (each slot over-fetches by its
//!   segment's dead count so filtering can never starve the top-k), and
//!   the lexicographic `merge_topk` reduce plus the batched decode
//!   rerank finish per query.  A metadata predicate compiles to one
//!   bitmap per segment and prunes inside the scan kernels — in-kernel
//!   skipping needs no extra over-fetch, filtered rows never enter the
//!   per-slot heaps at all.  With no deletes pending the results are
//!   bit-identical to a flat [`super::SearchEngine`] over the same rows
//!   — pinned by the equivalence property tests below.

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{ensure, Context};

use crate::config::{SearchConfig, StreamConfig};
use crate::exec::{shard_ranges_in, Executor, PrefilterPlan, ScanSpec,
                  ScanTask};
use crate::index::scan::merge_topk;
use crate::index::{CompressedIndex, FilterPlan, SearchRequest};
use crate::ivf::CoarseQuantizer;
use crate::linalg::{sq_l2, TopK};
use crate::quant::{Lut, Quantizer, SketchPlanes};
use crate::store::wal::{replay, Wal, WalRecord};
use crate::store::{atomic_write, Store};
use crate::util::json::Json;
use crate::Result;

/// Coarse routing of the write path: inserts are assigned to inverted
/// lists via `coarse` and grouped per list inside every segment;
/// `residual` encodes `x − centroid(x)` (classic IVFADC — searches then
/// build residual-query LUTs per probed list, exactly like
/// [`crate::ivf::IvfIndex`]).  The codebook must stay stable for the
/// lifetime of a durable index (it is not persisted here).
#[derive(Clone)]
pub struct Routing {
    pub coarse: Arc<CoarseQuantizer>,
    pub residual: bool,
}

/// The immutable payload of a segment, shared by copy-on-write clones.
struct SegmentBody {
    codes: CompressedIndex,
    /// external id of each stored row (ascending within every list)
    ids: Vec<u32>,
    /// list `l` occupies rows `[offsets[l], offsets[l+1])`; unrouted
    /// indexes are a single list, `offsets == [0, n]`
    offsets: Vec<usize>,
}

/// One segment: an immutable code matrix + row ids + tombstone bitmap.
/// Cloning for a delete shares the body and copies only the bitmap.
pub struct Segment {
    pub seg_id: u64,
    body: Arc<SegmentBody>,
    /// tombstone bitmap, one bit per row
    dead: Vec<u64>,
    pub n_dead: usize,
}

impl Segment {
    fn empty(seg_id: u64, stride: usize, num_lists: usize) -> Segment {
        let mut codes = CompressedIndex::from_codes(0, stride, Vec::new());
        codes.set_tags(Vec::new());
        Segment {
            seg_id,
            body: Arc::new(SegmentBody {
                codes,
                ids: Vec::new(),
                offsets: vec![0; num_lists + 1],
            }),
            dead: Vec::new(),
            n_dead: 0,
        }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.body.codes.n
    }

    #[inline]
    pub fn live(&self) -> usize {
        self.n() - self.n_dead
    }

    #[inline]
    pub fn codes(&self) -> &CompressedIndex {
        &self.body.codes
    }

    #[inline]
    pub fn row_ids(&self) -> &[u32] {
        &self.body.ids
    }

    #[inline]
    pub fn offsets(&self) -> &[usize] {
        &self.body.offsets
    }

    /// The row-aligned metadata tag column (every segment carries one;
    /// untagged inserts hold the default tag 0).
    #[inline]
    fn tags(&self) -> &[u64] {
        self.body.codes.tags.as_deref().expect("segment tag column")
    }

    #[inline]
    pub fn is_dead(&self, row: usize) -> bool {
        self.dead
            .get(row / 64)
            .is_some_and(|w| (w >> (row % 64)) & 1 == 1)
    }

    /// The list a stored row belongs to.
    fn list_of(&self, row: usize) -> u32 {
        (self.body.offsets.partition_point(|&o| o <= row) - 1) as u32
    }

    /// Copy-on-write tombstoning: same body, `rows` newly dead.
    fn with_dead(&self, rows: &[u32]) -> Segment {
        let mut dead = self.dead.clone();
        dead.resize(self.n().div_ceil(64), 0);
        for &row in rows {
            dead[row as usize / 64] |= 1 << (row as usize % 64);
        }
        Segment {
            seg_id: self.seg_id,
            body: self.body.clone(),
            dead,
            n_dead: self.n_dead + rows.len(),
        }
    }

    /// Persist into a [`Store`] archive (checkpoint path).
    fn save(&self, store: &mut Store) {
        let n = self.n();
        let stride = self.body.codes.stride;
        store.put_u8("seg_codes", &[n, stride], self.body.codes.codes.clone());
        store.put_u32("seg_ids", &[n], self.body.ids.clone());
        let tag_bytes: Vec<u8> = self
            .tags()
            .iter()
            .flat_map(|t| t.to_le_bytes())
            .collect();
        store.put_u8("seg_tags", &[n, 8], tag_bytes);
        let offs: Vec<u32> =
            self.body.offsets.iter().map(|&o| o as u32).collect();
        store.put_u32("seg_offsets", &[offs.len()], offs);
        let dead_rows: Vec<u32> = (0..n)
            .filter(|&r| self.is_dead(r))
            .map(|r| r as u32)
            .collect();
        store.put_u32("seg_dead", &[dead_rows.len()], dead_rows);
        let meta = Json::obj(vec![
            ("seg_id", Json::Num(self.seg_id as f64)),
            ("n", Json::Num(n as f64)),
            ("stride", Json::Num(stride as f64)),
        ]);
        store.put_meta("seg", &meta.render());
    }

    /// Load an archive written by [`Self::save`], validating the layout
    /// so a corrupt file fails here instead of panicking inside a scan.
    fn load(store: &Store, stride: usize, num_lists: usize)
            -> Result<Segment> {
        let meta = store.get_meta("seg").context("missing seg meta")?;
        let meta = Json::parse(meta).context("parse seg meta")?;
        let seg_id = meta.req_usize("seg_id")? as u64;
        let n = meta.req_usize("n")?;
        ensure!(meta.req_usize("stride")? == stride,
                "segment stride mismatch");
        let (cshape, codes) =
            store.get_u8("seg_codes").context("missing seg_codes")?;
        ensure!(cshape == [n, stride], "seg_codes shape {cshape:?}");
        let (_, ids) = store.get_u32("seg_ids").context("missing seg_ids")?;
        ensure!(ids.len() == n, "seg_ids length {}", ids.len());
        let (tshape, tag_bytes) =
            store.get_u8("seg_tags").context("missing seg_tags")?;
        ensure!(tshape == [n, 8], "seg_tags shape {tshape:?}");
        let tags: Vec<u64> = tag_bytes
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().expect("8 bytes")))
            .collect();
        let (_, offs) =
            store.get_u32("seg_offsets").context("missing seg_offsets")?;
        ensure!(offs.len() == num_lists + 1,
                "seg_offsets length {} != num_lists + 1", offs.len());
        let offsets: Vec<usize> = offs.iter().map(|&o| o as usize).collect();
        ensure!(offsets.first() == Some(&0) && offsets.last() == Some(&n)
                    && offsets.windows(2).all(|w| w[0] <= w[1]),
                "seg_offsets must be non-decreasing over [0, {n}]");
        let (_, dead_rows) =
            store.get_u32("seg_dead").context("missing seg_dead")?;
        ensure!(dead_rows.iter().all(|&r| (r as usize) < n),
                "seg_dead has out-of-range rows");
        let mut codes_ix =
            CompressedIndex::from_codes(n, stride, codes.to_vec());
        codes_ix.set_tags(tags);
        codes_ix.ensure_packed();
        let seg = Segment {
            seg_id,
            body: Arc::new(SegmentBody {
                codes: codes_ix,
                ids: ids.to_vec(),
                offsets,
            }),
            dead: Vec::new(),
            n_dead: 0,
        };
        Ok(seg.with_dead(dead_rows))
    }
}

/// An immutable snapshot of the whole index at one generation — what a
/// search plans against, and what every mutation atomically replaces.
pub struct SegmentSet {
    pub generation: u64,
    /// sealed segments, oldest first (ids ascend segment-to-segment)
    pub sealed: Vec<Arc<Segment>>,
    /// the append-only tail
    pub active: Arc<Segment>,
}

impl SegmentSet {
    pub fn total_rows(&self) -> usize {
        self.sealed.iter().map(|s| s.n()).sum::<usize>() + self.active.n()
    }

    pub fn live_rows(&self) -> usize {
        self.sealed.iter().map(|s| s.live()).sum::<usize>()
            + self.active.live()
    }
}

/// Writer-side state, serialized under one mutex (single-writer,
/// snapshot-reader discipline).
struct Writer {
    /// next external id (monotonic for the index lifetime; u32 so ids
    /// flow through the shared `(f32, u32)` scan/merge plumbing)
    next_id: u32,
    next_seg: u64,
    /// external id → (segment id, stored row); pruned on delete
    locate: HashMap<u32, (u64, u32)>,
    durable: Option<Durable>,
}

struct Durable {
    dir: PathBuf,
    wal: Wal,
    wal_epoch: u64,
}

/// Point-in-time counters for operators and tests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamStats {
    pub generation: u64,
    pub sealed_segments: usize,
    pub total_rows: usize,
    pub live_rows: usize,
    pub dead_rows: usize,
}

/// The mutable streaming index.  Shared-reference API throughout:
/// searches never block behind writers (they clone the current snapshot
/// Arc), and writers serialize under an internal mutex.
pub struct StreamingIndex {
    pub stride: usize,
    pub routing: Option<Routing>,
    cfg: StreamConfig,
    snap: RwLock<Arc<SegmentSet>>,
    writer: Mutex<Writer>,
}

impl StreamingIndex {
    /// In-memory index (no durability) — benches, tests, and callers
    /// that checkpoint some other way.
    pub fn new(stride: usize, routing: Option<Routing>, cfg: StreamConfig)
               -> StreamingIndex {
        assert!(stride > 0, "stride must be positive");
        assert!(cfg.segment_rows > 0, "segment_rows must be positive");
        let nl = routing.as_ref().map_or(1, |r| r.coarse.num_lists());
        let active = Arc::new(Segment::empty(0, stride, nl));
        StreamingIndex {
            stride,
            routing,
            cfg,
            snap: RwLock::new(Arc::new(SegmentSet {
                generation: 0,
                sealed: Vec::new(),
                active,
            })),
            writer: Mutex::new(Writer {
                next_id: 0,
                next_seg: 1,
                locate: HashMap::new(),
                durable: None,
            }),
        }
    }

    /// Durable index rooted at `dir`: creates the directory on first
    /// open; afterwards recovers by loading the checkpointed segment
    /// archives and replaying the WAL tail (torn tails are truncated —
    /// see [`crate::store::wal`]).  `routing` must match what the index
    /// was created with (the coarse codebook itself is the caller's to
    /// persist; only its shape is validated here).
    pub fn open(dir: &Path, stride: usize, routing: Option<Routing>,
                cfg: StreamConfig) -> Result<StreamingIndex> {
        std::fs::create_dir_all(dir)?;
        let index = Self::new(stride, routing, cfg);
        let nl = index.num_lists();
        let sync = index.cfg.wal_sync.max(1);
        let manifest_path = dir.join("manifest.json");
        let mut w = index.writer.lock().expect("writer lock");

        if !manifest_path.exists() {
            let wal = Wal::create(&dir.join("wal_0.log"), stride, sync)?;
            w.durable = Some(Durable {
                dir: dir.to_path_buf(),
                wal,
                wal_epoch: 0,
            });
            let set = index.snapshot();
            index.write_manifest(&w, &set)?;
            drop(w);
            return Ok(index);
        }

        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("read {manifest_path:?}"))?;
        let m = Json::parse(&text)
            .with_context(|| format!("parse {manifest_path:?}"))?;
        ensure!(m.req_usize("stride")? == stride,
                "manifest stride != index stride {stride}");
        ensure!(m.req_usize("num_lists")? == nl,
                "manifest num_lists != routing num_lists {nl}");
        let next_id = m.req_usize("next_id")? as u32;
        let next_seg = m.req_usize("next_seg")? as u64;
        let active_seg = m.req_usize("active_seg")? as u64;
        let wal_epoch = m.req_usize("wal_epoch")? as u64;
        let seg_ids: Vec<u64> = m
            .get("segments")
            .and_then(Json::as_arr)
            .context("manifest missing segments")?
            .iter()
            .map(|v| v.as_usize().map(|s| s as u64)
                .context("bad segment id"))
            .collect::<Result<_>>()?;

        let mut sealed = Vec::with_capacity(seg_ids.len());
        for id in seg_ids {
            let path = dir.join(format!("seg_{id}.store"));
            let seg = Segment::load(&Store::load(&path)?, stride, nl)
                .with_context(|| format!("load segment {path:?}"))?;
            ensure!(seg.seg_id == id, "segment {path:?} carries id {}",
                    seg.seg_id);
            sealed.push(Arc::new(seg));
        }
        w.next_id = next_id;
        w.next_seg = next_seg;
        for seg in &sealed {
            for (row, &id) in seg.row_ids().iter().enumerate() {
                if seg.is_dead(row) {
                    continue;
                }
                ensure!(
                    w.locate.insert(id, (seg.seg_id, row as u32)).is_none(),
                    "external id {id} stored twice across segments"
                );
            }
        }
        index.install(SegmentSet {
            generation: 1,
            sealed,
            active: Arc::new(Segment::empty(active_seg, stride, nl)),
        });

        // replay the WAL tail through the live apply paths (durable is
        // still None here, so replay never re-logs)
        let wal_path = dir.join(format!("wal_{wal_epoch}.log"));
        let wal = if wal_path.exists() {
            let (records, good) = replay(&wal_path, stride)?;
            index.apply_records(&mut w, &records)?;
            Wal::open_append(&wal_path, stride, good, sync)?
        } else {
            Wal::create(&wal_path, stride, sync)?
        };
        w.durable = Some(Durable {
            dir: dir.to_path_buf(),
            wal,
            wal_epoch,
        });
        drop(w);
        Ok(index)
    }

    #[inline]
    pub fn num_lists(&self) -> usize {
        self.routing.as_ref().map_or(1, |r| r.coarse.num_lists())
    }

    /// The current immutable snapshot (cheap: one Arc clone).
    pub fn snapshot(&self) -> Arc<SegmentSet> {
        self.snap.read().expect("snapshot lock").clone()
    }

    pub fn generation(&self) -> u64 {
        self.snapshot().generation
    }

    /// Live (non-tombstoned) rows.
    pub fn len(&self) -> usize {
        self.snapshot().live_rows()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> StreamStats {
        let s = self.snapshot();
        let total = s.total_rows();
        let live = s.live_rows();
        StreamStats {
            generation: s.generation,
            sealed_segments: s.sealed.len(),
            total_rows: total,
            live_rows: live,
            dead_rows: total - live,
        }
    }

    fn install(&self, set: SegmentSet) {
        *self.snap.write().expect("snapshot lock") = Arc::new(set);
    }

    // -- write path --------------------------------------------------------

    /// Encode-on-insert: assign fresh external ids to `vectors` (flat
    /// `rows × dim`), route + encode them in one `encode_batch` call,
    /// log + fsync, then publish a new snapshot.  Seals the active
    /// segment at `segment_rows` and compacts when enough sealed
    /// segments accumulate.  Returns the assigned ids.  Rows carry the
    /// default metadata tag 0 — use [`Self::insert_batch_tagged`] to
    /// attach per-row predicate tags.
    pub fn insert_batch(&self, quant: &dyn Quantizer, vectors: &[f32])
                        -> Result<Vec<u32>> {
        self.insert_batch_tagged(quant, vectors, None)
    }

    /// [`Self::insert_batch`] with an optional per-row metadata tag
    /// column (`tags.len()` must equal the batch's row count).  Tags are
    /// WAL-logged with their rows and served by the predicate filter
    /// (`SearchConfig::filter`, rust/DESIGN.md §13).
    pub fn insert_batch_tagged(&self, quant: &dyn Quantizer,
                               vectors: &[f32], tags: Option<&[u64]>)
                               -> Result<Vec<u32>> {
        let dim = quant.dim();
        ensure!(quant.code_bytes() == self.stride,
                "quantizer code_bytes {} != index stride {}",
                quant.code_bytes(), self.stride);
        ensure!(dim > 0 && vectors.len() % dim == 0,
                "vectors must be rows × dim = {dim}");
        let rows = vectors.len() / dim;
        ensure!(tags.map_or(true, |t| t.len() == rows),
                "one tag per inserted row");
        if rows == 0 {
            return Ok(Vec::new());
        }
        let row_tags: Vec<u64> = match tags {
            Some(t) => t.to_vec(),
            None => vec![0; rows],
        };
        let mut w = self.writer.lock().expect("writer lock");
        ensure!(
            (w.next_id as u64) + (rows as u64) < u32::MAX as u64,
            "external id space exhausted"
        );

        let lists: Vec<u32> = match &self.routing {
            Some(r) => (0..rows)
                .map(|i| r.coarse.assign(&vectors[i * dim..(i + 1) * dim]))
                .collect(),
            None => vec![0; rows],
        };
        let residual = self.routing.as_ref().is_some_and(|r| r.residual);
        let codes = if residual {
            let r = self.routing.as_ref().expect("residual implies routing");
            let mut shifted = vectors.to_vec();
            for i in 0..rows {
                let c = r.coarse.centroid(lists[i] as usize);
                for (v, cv) in shifted[i * dim..(i + 1) * dim]
                    .iter_mut()
                    .zip(c)
                {
                    *v -= cv;
                }
            }
            quant.encode_batch(&shifted)
        } else {
            quant.encode_batch(vectors)
        };
        let ids: Vec<u32> =
            (w.next_id..w.next_id + rows as u32).collect();
        // reserve the ids up front: even if the WAL lands only part of
        // this batch (fsync failure mid-batch at large `wal_sync`), the
        // ids are consumed and can never be re-issued — recovery may
        // resurrect a prefix of a failed batch, never a duplicate id
        w.next_id += rows as u32;

        if let Some(d) = &mut w.durable {
            let mut failed = None;
            for i in 0..rows {
                let rec = WalRecord::Insert {
                    id: ids[i],
                    list: lists[i],
                    tag: row_tags[i],
                    code: codes[i * self.stride..(i + 1) * self.stride]
                        .to_vec(),
                };
                if let Err(e) = d.wal.append(&rec) {
                    failed = Some(e);
                    break;
                }
            }
            if failed.is_none() {
                failed = d.wal.commit().err();
            }
            if let Some(e) = failed {
                d.wal.abort_batch();
                return Err(e);
            }
        }
        self.apply_insert(&mut w, &ids, &lists, &row_tags, &codes)?;

        if self.snapshot().active.n() >= self.cfg.segment_rows {
            self.seal(&mut w)?;
            if self.snapshot().sealed.len() >= self.cfg.compact_segments {
                self.compact_locked(&mut w)?;
            }
        }
        Ok(ids)
    }

    /// Tombstone external ids; unknown or already-deleted ids are
    /// ignored.  Returns how many rows were actually tombstoned.
    pub fn delete_batch(&self, ids: &[u32]) -> Result<usize> {
        let mut w = self.writer.lock().expect("writer lock");
        // resolve first, mutate nothing until the WAL batch is durable —
        // a failed log write must leave the rows deletable (locate
        // intact), not silently undead
        let mut hits: HashMap<u64, Vec<u32>> = HashMap::new();
        let mut removed: Vec<u32> = Vec::new();
        let mut seen: HashSet<u32> = HashSet::with_capacity(ids.len());
        for &id in ids {
            if !seen.insert(id) {
                continue; // duplicate id within one batch
            }
            if let Some(&(seg, row)) = w.locate.get(&id) {
                hits.entry(seg).or_default().push(row);
                removed.push(id);
            }
        }
        if removed.is_empty() {
            return Ok(0);
        }
        if let Some(d) = &mut w.durable {
            let mut failed = None;
            for &id in &removed {
                if let Err(e) = d.wal.append(&WalRecord::Delete { id }) {
                    failed = Some(e);
                    break;
                }
            }
            if failed.is_none() {
                failed = d.wal.commit().err();
            }
            if let Some(e) = failed {
                d.wal.abort_batch();
                return Err(e);
            }
        }
        for id in &removed {
            w.locate.remove(id);
        }
        self.apply_delete(&w, &hits);
        Ok(removed.len())
    }

    /// Merge all sealed segments into one, dropping tombstoned rows and
    /// repacking into the BLOCK=32 fast-scan layout, then atomically
    /// swap the new set in.  On a durable index this is also the
    /// checkpoint: merged archives are saved (atomic rename), the WAL
    /// rotates to a fresh epoch carrying only the re-logged active tail,
    /// and the manifest commits the whole transition in one rename.
    /// Returns whether a merge actually happened.
    pub fn compact(&self) -> Result<bool> {
        let mut w = self.writer.lock().expect("writer lock");
        self.compact_locked(&mut w)
    }

    // Shared apply paths: the live operations call these after logging,
    // and WAL replay calls them directly — recovery is the same code.

    fn apply_insert(&self, w: &mut Writer, ids: &[u32], lists: &[u32],
                    tags: &[u64], codes: &[u8]) -> Result<()> {
        let nl = self.num_lists();
        let stride = self.stride;
        let rows = ids.len();
        ensure!(lists.len() == rows && tags.len() == rows
                    && codes.len() == rows * stride,
                "insert batch shape mismatch");
        let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); nl];
        for (i, &l) in lists.iter().enumerate() {
            ensure!((l as usize) < nl, "routed list {l} out of range");
            buckets[l as usize].push(i);
        }

        let snap = self.snapshot();
        let act = &snap.active;
        let n = act.n() + rows;
        let mut codes_out = Vec::with_capacity(n * stride);
        let mut ids_out: Vec<u32> = Vec::with_capacity(n);
        let mut tags_out: Vec<u64> = Vec::with_capacity(n);
        let mut offsets = Vec::with_capacity(nl + 1);
        offsets.push(0usize);
        let mut dead = vec![0u64; n.div_ceil(64)];
        let mut n_dead = act.n_dead;
        if nl == 1 {
            // unrouted fast path: rows never shift, so bulk-copy the
            // tail, keep its bitmap, and touch `locate` only for the
            // appended rows — O(batch + memcpy) instead of a per-row
            // rebuild of the whole active segment
            codes_out.extend_from_slice(&act.codes().codes);
            ids_out.extend_from_slice(act.row_ids());
            tags_out.extend_from_slice(act.tags());
            dead[..act.dead.len()].copy_from_slice(&act.dead);
            for (i, &id) in ids.iter().enumerate() {
                w.locate.insert(id, (act.seg_id, (act.n() + i) as u32));
                ids_out.push(id);
            }
            codes_out.extend_from_slice(codes);
            tags_out.extend_from_slice(tags);
            offsets.push(n);
        } else {
            n_dead = 0;
            for l in 0..nl {
                for row in act.offsets()[l]..act.offsets()[l + 1] {
                    let new_row = ids_out.len();
                    let id = act.row_ids()[row];
                    if act.is_dead(row) {
                        dead[new_row / 64] |= 1 << (new_row % 64);
                        n_dead += 1;
                    } else {
                        w.locate.insert(id, (act.seg_id, new_row as u32));
                    }
                    ids_out.push(id);
                    tags_out.push(act.tags()[row]);
                    codes_out.extend_from_slice(act.codes().code(row));
                }
                for &i in &buckets[l] {
                    let new_row = ids_out.len();
                    w.locate.insert(ids[i], (act.seg_id, new_row as u32));
                    ids_out.push(ids[i]);
                    tags_out.push(tags[i]);
                    codes_out.extend_from_slice(
                        &codes[i * stride..(i + 1) * stride]);
                }
                offsets.push(ids_out.len());
            }
        }
        let max_id = *ids.iter().max().expect("rows > 0");
        w.next_id = w.next_id.max(max_id + 1);

        let mut codes_ix = CompressedIndex::from_codes(n, stride, codes_out);
        codes_ix.set_tags(tags_out);
        self.install(SegmentSet {
            generation: snap.generation + 1,
            sealed: snap.sealed.clone(),
            active: Arc::new(Segment {
                seg_id: act.seg_id,
                body: Arc::new(SegmentBody {
                    codes: codes_ix,
                    ids: ids_out,
                    offsets,
                }),
                dead,
                n_dead,
            }),
        });
        Ok(())
    }

    fn apply_delete(&self, _w: &Writer, hits: &HashMap<u64, Vec<u32>>) {
        let snap = self.snapshot();
        let sealed: Vec<Arc<Segment>> = snap
            .sealed
            .iter()
            .map(|s| match hits.get(&s.seg_id) {
                Some(rows) => Arc::new(s.with_dead(rows)),
                None => s.clone(),
            })
            .collect();
        let active = match hits.get(&snap.active.seg_id) {
            Some(rows) => Arc::new(snap.active.with_dead(rows)),
            None => snap.active.clone(),
        };
        self.install(SegmentSet {
            generation: snap.generation + 1,
            sealed,
            active,
        });
    }

    /// Convert the active tail into a packed sealed segment and start a
    /// fresh one.  Row numbering is unchanged, so the id map needs no
    /// update (the sealed segment keeps its `seg_id`).
    fn seal(&self, w: &mut Writer) -> Result<()> {
        let snap = self.snapshot();
        let act = &snap.active;
        if act.n() == 0 {
            return Ok(());
        }
        if let Some(d) = &mut w.durable {
            d.wal.append(&WalRecord::Seal { seg_id: act.seg_id })?;
            d.wal.commit()?;
        }
        let mut codes_ix = CompressedIndex::from_codes(
            act.n(), self.stride, act.codes().codes.clone());
        codes_ix.set_tags(act.tags().to_vec());
        codes_ix.ensure_packed();
        let mut sealed = snap.sealed.clone();
        sealed.push(Arc::new(Segment {
            seg_id: act.seg_id,
            body: Arc::new(SegmentBody {
                codes: codes_ix,
                ids: act.row_ids().to_vec(),
                offsets: act.offsets().to_vec(),
            }),
            dead: act.dead.clone(),
            n_dead: act.n_dead,
        }));
        let next = w.next_seg;
        w.next_seg += 1;
        self.install(SegmentSet {
            generation: snap.generation + 1,
            sealed,
            active: Arc::new(Segment::empty(next, self.stride,
                                            self.num_lists())),
        });
        Ok(())
    }

    fn compact_locked(&self, w: &mut Writer) -> Result<bool> {
        let t0 = std::time::Instant::now();
        let snap = self.snapshot();
        let sealed_dead: usize =
            snap.sealed.iter().map(|s| s.n_dead).sum();
        let needs_merge = snap.sealed.len() > 1
            || (snap.sealed.len() == 1 && sealed_dead > 0);
        if !needs_merge {
            // nothing to merge; still checkpoint durable state so an
            // explicit compact() bounds WAL growth
            if w.durable.is_some() {
                self.checkpoint(w, &self.snapshot())?;
            }
            return Ok(false);
        }

        let nl = self.num_lists();
        let stride = self.stride;
        let live: usize = snap.sealed.iter().map(|s| s.live()).sum();
        let mut codes_out = Vec::with_capacity(live * stride);
        let mut ids_out: Vec<u32> = Vec::with_capacity(live);
        let mut tags_out: Vec<u64> = Vec::with_capacity(live);
        let mut offsets = Vec::with_capacity(nl + 1);
        offsets.push(0usize);
        for l in 0..nl {
            // oldest-first keeps ids ascending within the merged list
            // (ids ascend segment-to-segment by construction)
            for seg in &snap.sealed {
                for row in seg.offsets()[l]..seg.offsets()[l + 1] {
                    if seg.is_dead(row) {
                        continue;
                    }
                    ids_out.push(seg.row_ids()[row]);
                    tags_out.push(seg.tags()[row]);
                    codes_out.extend_from_slice(seg.codes().code(row));
                }
            }
            offsets.push(ids_out.len());
        }

        let sealed = if ids_out.is_empty() {
            Vec::new() // every sealed row was dead — drop them all
        } else {
            let seg_id = w.next_seg;
            w.next_seg += 1;
            for (row, &id) in ids_out.iter().enumerate() {
                w.locate.insert(id, (seg_id, row as u32));
            }
            let mut codes_ix =
                CompressedIndex::from_codes(ids_out.len(), stride, codes_out);
            codes_ix.set_tags(tags_out);
            codes_ix.ensure_packed();
            vec![Arc::new(Segment {
                seg_id,
                body: Arc::new(SegmentBody {
                    codes: codes_ix,
                    ids: ids_out,
                    offsets,
                }),
                dead: Vec::new(),
                n_dead: 0,
            })]
        };
        self.install(SegmentSet {
            generation: snap.generation + 1,
            sealed,
            active: snap.active.clone(),
        });
        if w.durable.is_some() {
            self.checkpoint(w, &self.snapshot())?;
        }
        let reg = crate::obs::global();
        reg.compaction_runs.inc();
        reg.compaction_us.record(t0.elapsed().as_micros() as u64);
        Ok(true)
    }

    /// Durable checkpoint: archive every sealed segment, write a fresh
    /// WAL epoch re-logging the active tail, then commit the manifest —
    /// the single atomic rename that makes the transition real.  A crash
    /// anywhere before that rename recovers the previous checkpoint +
    /// previous WAL instead; nothing is ever half-applied.
    fn checkpoint(&self, w: &mut Writer, set: &SegmentSet) -> Result<()> {
        let (dir, old_epoch) = {
            let d = w.durable.as_ref().expect("checkpoint needs durability");
            (d.dir.clone(), d.wal_epoch)
        };
        for seg in &set.sealed {
            let mut store = Store::new();
            seg.save(&mut store);
            store.save(&dir.join(format!("seg_{}.store", seg.seg_id)))?;
        }
        let new_epoch = old_epoch + 1;
        let wal_path = dir.join(format!("wal_{new_epoch}.log"));
        let mut wal =
            Wal::create(&wal_path, self.stride, self.cfg.wal_sync.max(1))?;
        let act = &set.active;
        for row in 0..act.n() {
            wal.append(&WalRecord::Insert {
                id: act.row_ids()[row],
                list: act.list_of(row),
                tag: act.tags()[row],
                code: act.codes().code(row).to_vec(),
            })?;
        }
        for row in 0..act.n() {
            if act.is_dead(row) {
                wal.append(&WalRecord::Delete { id: act.row_ids()[row] })?;
            }
        }
        wal.commit()?;
        // the manifest rename is the commit point; swap the live handle
        // only after it lands, so a failed checkpoint leaves the old
        // epoch (and its intact WAL) in charge
        w.durable.as_mut().expect("still durable").wal_epoch = new_epoch;
        if let Err(e) = self.write_manifest(w, set) {
            w.durable.as_mut().expect("still durable").wal_epoch = old_epoch;
            return Err(e);
        }
        let d = w.durable.as_mut().expect("still durable");
        d.wal = wal;
        if let Ok(entries) = std::fs::read_dir(&d.dir) {
            let keep: Vec<String> = set
                .sealed
                .iter()
                .map(|s| format!("seg_{}.store", s.seg_id))
                .collect();
            let live_wal = format!("wal_{new_epoch}.log");
            for e in entries.flatten() {
                let name = e.file_name().to_string_lossy().into_owned();
                let stale_seg = name.starts_with("seg_")
                    && name.ends_with(".store")
                    && !keep.contains(&name);
                // also reap wal epochs orphaned by earlier crashes
                // between a checkpoint's commit point and its cleanup
                let stale_wal = name.starts_with("wal_")
                    && name.ends_with(".log")
                    && name != live_wal;
                if stale_seg || stale_wal {
                    let _ = std::fs::remove_file(e.path());
                }
            }
        }
        Ok(())
    }

    fn write_manifest(&self, w: &Writer, set: &SegmentSet) -> Result<()> {
        let d = w.durable.as_ref().expect("manifest needs durability");
        let manifest = Json::obj(vec![
            ("version", Json::Num(1.0)),
            ("stride", Json::Num(self.stride as f64)),
            ("num_lists", Json::Num(self.num_lists() as f64)),
            ("next_id", Json::Num(w.next_id as f64)),
            ("next_seg", Json::Num(w.next_seg as f64)),
            ("active_seg", Json::Num(set.active.seg_id as f64)),
            ("wal_epoch", Json::Num(d.wal_epoch as f64)),
            ("segments", Json::Arr(
                set.sealed
                    .iter()
                    .map(|s| Json::Num(s.seg_id as f64))
                    .collect(),
            )),
        ]);
        atomic_write(&d.dir.join("manifest.json"),
                     manifest.render_pretty().as_bytes())
    }

    /// Replay a WAL tail through the live apply paths (recovery).
    fn apply_records(&self, w: &mut Writer, records: &[WalRecord])
                     -> Result<()> {
        let mut i = 0usize;
        while i < records.len() {
            match &records[i] {
                WalRecord::Insert { .. } => {
                    let mut ids = Vec::new();
                    let mut lists = Vec::new();
                    let mut tags = Vec::new();
                    let mut codes = Vec::new();
                    while let Some(WalRecord::Insert {
                        id, list, tag, code,
                    }) = records.get(i)
                    {
                        ids.push(*id);
                        lists.push(*list);
                        tags.push(*tag);
                        codes.extend_from_slice(code);
                        i += 1;
                    }
                    self.apply_insert(w, &ids, &lists, &tags, &codes)?;
                }
                WalRecord::Delete { .. } => {
                    let mut hits: HashMap<u64, Vec<u32>> = HashMap::new();
                    while let Some(WalRecord::Delete { id }) = records.get(i)
                    {
                        if let Some((seg, row)) = w.locate.remove(id) {
                            hits.entry(seg).or_default().push(row);
                        }
                        i += 1;
                    }
                    if !hits.is_empty() {
                        self.apply_delete(w, &hits);
                    }
                }
                WalRecord::Seal { seg_id } => {
                    ensure!(self.snapshot().active.seg_id == *seg_id,
                            "wal seal of segment {seg_id} does not match \
                             the active segment");
                    self.seal_replayed(w)?;
                    i += 1;
                }
            }
        }
        Ok(())
    }

    /// [`Self::seal`] without the WAL append (the record being replayed
    /// *is* the seal).
    fn seal_replayed(&self, w: &mut Writer) -> Result<()> {
        let durable = w.durable.take();
        let r = self.seal(w);
        w.durable = durable;
        r
    }

    // -- read path ---------------------------------------------------------

    /// Single query on the inline executor (mirrors
    /// [`super::SearchEngine::search`]).
    pub fn search(&self, quant: &dyn Quantizer, q: &[f32],
                  cfg: &SearchConfig) -> Vec<u32> {
        let req = SearchRequest::from_config(cfg, vec![cfg.k]);
        self.search_batch_on(quant, &Executor::Inline, &[q], &req)
            .pop()
            .expect("one query in, one result out")
    }

    /// Batched two-stage search over the current snapshot, returning
    /// external ids.  `req.nprobe` applies when routed (0 = all lists);
    /// `QuerySpec::precision` selects the per-segment kernel exactly as
    /// on the frozen paths, and `QuerySpec::filter` compiles to one
    /// bitmap per segment pruned inside the kernels (tombstones compose
    /// on top — rust/DESIGN.md §13).
    pub fn search_batch_on(&self, quant: &dyn Quantizer, exec: &Executor,
                           queries: &[&[f32]], req: &SearchRequest)
                           -> Vec<Vec<u32>> {
        let cfg = req.to_search_config();
        let ks: &[usize] = &req.ks;
        assert_eq!(queries.len(), ks.len(), "one k per query");
        if queries.is_empty() {
            return Vec::new();
        }
        let snap = self.snapshot();
        let segs: Vec<&Segment> = snap
            .sealed
            .iter()
            .map(|s| s.as_ref())
            .chain(std::iter::once(snap.active.as_ref()))
            .collect();
        let nl = self.num_lists();
        let routed = self.routing.is_some();
        let residual = self.routing.as_ref().is_some_and(|r| r.residual);
        let nprobe = if !routed || cfg.nprobe == 0 {
            nl
        } else {
            cfg.nprobe.min(nl)
        };
        let do_rerank = !cfg.no_rerank && quant.supports_rerank();
        let ls: Vec<usize> = ks
            .iter()
            .map(|&k| {
                let l = if do_rerank { cfg.rerank_l.max(k) } else { k };
                l.max(1)
            })
            .collect();

        // coarse selection + one LUT per query (flat / non-residual) or
        // per (query, probed list) (residual), shared across segments
        let probes: Vec<Vec<u32>> = match (&self.routing, nprobe == nl) {
            (Some(r), false) => queries
                .iter()
                .map(|q| r.coarse.nearest_lists(q, nprobe))
                .collect(),
            _ => queries
                .iter()
                .map(|_| (0..nl as u32).collect())
                .collect(),
        };
        let mut lut_of: Vec<Vec<usize>> = Vec::with_capacity(queries.len());
        let mut residual_qs: Vec<Vec<f32>> = Vec::new();
        for (qi, probe) in probes.iter().enumerate() {
            let mut per = Vec::with_capacity(probe.len());
            for &l in probe {
                per.push(if residual {
                    let r = self.routing.as_ref().expect("routed");
                    let c = r.coarse.centroid(l as usize);
                    residual_qs.push(
                        queries[qi].iter().zip(c).map(|(a, b)| a - b)
                            .collect());
                    residual_qs.len() - 1
                } else {
                    qi
                });
            }
            lut_of.push(per);
        }
        let luts: Vec<Lut> = if residual {
            let refs: Vec<&[f32]> =
                residual_qs.iter().map(|v| v.as_slice()).collect();
            quant.lut_batch(&refs)
        } else {
            quant.lut_batch(queries)
        };

        // one slot per (query, probed list, segment) with live rows; the
        // slot over-fetches by the segment's dead count so tombstone
        // filtering can never starve the merged top-k
        let total = snap.total_rows();
        let es = exec.effective_shard_rows(total.max(1), cfg.shard_rows);
        let mut slot_query: Vec<usize> = Vec::new();
        let mut slot_list: Vec<u32> = Vec::new();
        let mut slot_seg: Vec<usize> = Vec::new();
        let mut slot_ks: Vec<usize> = Vec::new();
        let mut tasks: Vec<ScanTask> = Vec::new();
        let mut overfetch = 0u64;
        for (qi, probe) in probes.iter().enumerate() {
            for (pi, &l) in probe.iter().enumerate() {
                for (si, seg) in segs.iter().enumerate() {
                    let (lo, hi) = (seg.offsets()[l as usize],
                                    seg.offsets()[l as usize + 1]);
                    if lo == hi {
                        continue;
                    }
                    let slot = slot_ks.len();
                    slot_query.push(qi);
                    slot_list.push(l);
                    slot_seg.push(si);
                    // the range can hold at most min(n_dead, hi - lo)
                    // tombstones, so this over-fetch stays lossless while
                    // bounding heap work when lists are much smaller than
                    // the segment's total dead count
                    let extra = seg.n_dead.min(hi - lo);
                    overfetch += extra as u64;
                    slot_ks.push(ls[qi] + extra);
                    for (a, b) in shard_ranges_in(lo, hi, es) {
                        tasks.push(ScanTask {
                            index: si,
                            slot,
                            lut: lut_of[qi][pi],
                            lo: a,
                            hi: b,
                        });
                    }
                }
            }
        }
        // segment fan-out evidence: one "segment scanned" per
        // (query, probed list, segment) slot, plus the tombstone
        // over-fetch this batch paid across all slots
        let reg = crate::obs::global();
        reg.stream_segments_scanned.add(slot_ks.len() as u64);
        reg.stream_overfetch_rows.add(overfetch);
        let indexes: Vec<&CompressedIndex> =
            segs.iter().map(|s| s.codes()).collect();
        // the 1-bit pre-filter plan is threaded through like the frozen
        // paths (query sketches per LUT, non-residual only), but segment
        // code matrices never build row sketches — mutation would
        // invalidate them — so today the per-task triple resolution
        // falls back to the plain precision scan on every task
        // (DESIGN.md §9; sketch maintenance under mutation is future
        // work)
        let pre = if cfg.prefilter && !residual {
            let planes = SketchPlanes::for_dim(quant.dim());
            Some(PrefilterPlan {
                qsketches: queries
                    .iter()
                    .map(|q| Some(planes.sketch(q)))
                    .collect(),
                margin: cfg.prefilter_margin,
            })
        } else {
            None
        };
        // predicate filter: one bitmap per segment, pruned in-kernel —
        // no extra over-fetch needed (filtered rows never enter the
        // per-slot heaps, unlike tombstones which are dropped after)
        let fplan = cfg.filter
            .map(|f| FilterPlan::compile(&f, &indexes));
        let spec = ScanSpec {
            precision: cfg.scan_precision,
            prefilter: pre.as_ref(),
            filter: fplan.as_ref(),
        };
        let parts =
            exec.run_scan_tasks(&luts, &indexes, &slot_ks, &tasks, &spec);

        // per-query reduce: drop tombstones, remap rows to external ids,
        // fold through the lexicographic merge (decomposition-invariant)
        let mut parts_by_q: Vec<Vec<Vec<(f32, u32)>>> =
            (0..queries.len()).map(|_| Vec::new()).collect();
        let mut aux: Vec<HashMap<u32, (u32, u32, u32)>> =
            (0..queries.len()).map(|_| HashMap::new()).collect();
        for (slot, part) in parts.into_iter().enumerate() {
            let (qi, si) = (slot_query[slot], slot_seg[slot]);
            let seg = segs[si];
            let mapped: Vec<(f32, u32)> = part
                .into_iter()
                .filter(|&(_, row)| !seg.is_dead(row as usize))
                .map(|(score, row)| {
                    let id = seg.row_ids()[row as usize];
                    aux[qi].insert(id, (si as u32, row, slot_list[slot]));
                    (score, id)
                })
                .collect();
            parts_by_q[qi].push(mapped);
        }
        let cands: Vec<Vec<(f32, u32)>> = parts_by_q
            .into_iter()
            .enumerate()
            .map(|(qi, q_parts)| merge_topk(q_parts, ls[qi]))
            .collect();

        if !do_rerank {
            return cands
                .iter()
                .zip(ks)
                .map(|(c, &k)| c.iter().take(k).map(|p| p.1).collect())
                .collect();
        }
        self.rerank_batch(quant, &segs, queries, &cands, &aux, ks)
    }

    /// Stage 2 over segments: gather candidate codes, decode them with
    /// one `reconstruct_batch` call, add the list centroid back for
    /// residual codes, rank by exact `d1` (mirrors the IVF reranker).
    #[allow(clippy::type_complexity)]
    fn rerank_batch(&self, quant: &dyn Quantizer, segs: &[&Segment],
                    queries: &[&[f32]], cands: &[Vec<(f32, u32)>],
                    aux: &[HashMap<u32, (u32, u32, u32)>], ks: &[usize])
                    -> Vec<Vec<u32>> {
        let dim = quant.dim();
        let total: usize = cands.iter().map(|c| c.len()).sum();
        let mut codes = Vec::with_capacity(total * self.stride);
        for (qi, c) in cands.iter().enumerate() {
            for &(_, id) in c {
                let (si, row, _) = aux[qi][&id];
                codes.extend_from_slice(
                    segs[si as usize].codes().code(row as usize));
            }
        }
        let mut recons = vec![0.0f32; total * dim];
        if !quant.reconstruct_batch(&codes, &mut recons) {
            // no decoder: keep scan order
            return cands
                .iter()
                .zip(ks)
                .map(|(c, &k)| c.iter().take(k).map(|p| p.1).collect())
                .collect();
        }
        let residual = self.routing.as_ref().is_some_and(|r| r.residual);
        let mut out = Vec::with_capacity(queries.len());
        let mut off = 0usize;
        for (qi, (&q, c)) in queries.iter().zip(cands).enumerate() {
            let k = ks[qi];
            if c.is_empty() {
                out.push(Vec::new());
                continue;
            }
            let mut top = TopK::new(k.min(c.len()));
            for (ci, &(_, id)) in c.iter().enumerate() {
                let rec = &recons[(off + ci) * dim..(off + ci + 1) * dim];
                let d = if residual {
                    let (_, _, l) = aux[qi][&id];
                    let r = self.routing.as_ref().expect("routed");
                    d1_residual(q, rec, r.coarse.centroid(l as usize))
                } else {
                    sq_l2(q, rec)
                };
                top.push(d, id);
            }
            off += c.len();
            out.push(
                top.into_sorted().into_iter().map(|(_, id)| id).collect());
        }
        out
    }
}

/// `‖q − (centroid + recon)‖²` without materializing the sum.
#[inline]
fn d1_residual(q: &[f32], recon: &[f32], centroid: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for ((&qv, &rv), &cv) in q.iter().zip(recon).zip(centroid) {
        let d = qv - (rv + cv);
        acc += d * d;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ScanPrecision;
    use crate::data::{synthetic::Generator, Dataset, Family};
    use crate::index::SearchEngine;
    use crate::ivf::IvfIndex;
    use crate::quant::pq::Pq;
    use crate::util::{prop, rng::SplitMix64};

    fn setup(n_base: usize) -> (Dataset, Dataset, Dataset, Pq) {
        let gen = Generator::new(Family::SiftLike, 77);
        let train = gen.generate(0, 1000);
        let base = gen.generate(1, n_base);
        let queries = gen.generate(2, 8);
        let pq = Pq::train(&train.data, train.dim, 8, 32, 0, 8);
        (train, base, queries, pq)
    }

    fn qrefs(d: &Dataset) -> Vec<&[f32]> {
        (0..d.len()).map(|qi| d.row(qi)).collect()
    }

    fn scfg(segment_rows: usize) -> StreamConfig {
        StreamConfig { segment_rows, compact_segments: 1000, wal_sync: 8 }
    }

    /// Positional shim over the request API for the grids below.
    fn batch(ix: &StreamingIndex, quant: &dyn Quantizer, exec: &Executor,
             qs: &[&[f32]], ks: &[usize], cfg: &SearchConfig)
             -> Vec<Vec<u32>> {
        let req = SearchRequest::from_config(cfg, ks.to_vec());
        ix.search_batch_on(quant, exec, qs, &req)
    }

    /// Flat rebuild of the surviving rows, ordered by ascending external
    /// id, plus the row → external-id map.
    fn rebuild(pq: &Pq, base: &Dataset, survivors: &[u32])
               -> (CompressedIndex, Vec<u32>) {
        let mut kept = Vec::with_capacity(survivors.len() * base.dim);
        for &id in survivors {
            kept.extend_from_slice(base.row(id as usize));
        }
        let kept = Dataset::new(base.dim, kept);
        (CompressedIndex::build(pq, &kept), survivors.to_vec())
    }

    fn map_rows(results: Vec<Vec<u32>>, to_ext: &[u32]) -> Vec<Vec<u32>> {
        results
            .into_iter()
            .map(|r| r.into_iter().map(|row| to_ext[row as usize]).collect())
            .collect()
    }

    #[test]
    fn insert_only_is_bit_identical_to_flat_engine() {
        // fresh inserts assign ids 0..n in dataset order, so the
        // streaming search must equal the frozen engine exactly —
        // across seal boundaries, with and without rerank
        let (_, base, queries, pq) = setup(1700);
        let ix = StreamingIndex::new(8, None, scfg(300));
        // chunked inserts so several seal boundaries actually fire (a
        // single oversized batch seals as one big segment)
        for lo in (0..base.len()).step_by(257) {
            let hi = (lo + 257).min(base.len());
            ix.insert_batch(&pq, base.rows(lo, hi)).unwrap();
        }
        assert!(ix.snapshot().sealed.len() >= 4, "seals must have fired");
        assert_eq!(ix.len(), 1700);
        let flat = CompressedIndex::build(&pq, &base);
        let qs = qrefs(&queries);
        let ks = vec![10usize; qs.len()];
        for no_rerank in [false, true] {
            let cfg = SearchConfig { rerank_l: 60, k: 10, no_rerank,
                                     ..Default::default() };
            let want =
                SearchEngine::new(&pq, &flat, cfg).search_batch(&qs);
            let got = batch(&ix, &pq, &Executor::Inline, &qs, &ks, &cfg);
            assert_eq!(got, want, "no_rerank={no_rerank}");
        }
    }

    #[test]
    fn empty_and_fully_deleted_index_return_empty_results() {
        let (_, base, queries, pq) = setup(300);
        let ix = StreamingIndex::new(8, None, scfg(100));
        let qs = qrefs(&queries);
        let ks = vec![5usize; qs.len()];
        let cfg = SearchConfig { rerank_l: 20, k: 5, ..Default::default() };
        let empty = batch(&ix, &pq, &Executor::Inline, &qs, &ks, &cfg);
        assert!(empty.iter().all(|r| r.is_empty()));
        let ids = ix.insert_batch(&pq, &base.data).unwrap();
        assert_eq!(ix.delete_batch(&ids).unwrap(), 300);
        assert_eq!(ix.len(), 0);
        let gone = batch(&ix, &pq, &Executor::Inline, &qs, &ks, &cfg);
        assert!(gone.iter().all(|r| r.is_empty()));
        // compaction drops every sealed tombstone
        assert!(ix.compact().unwrap());
        let st = ix.stats();
        assert_eq!(st.total_rows - st.live_rows, ix.snapshot().active.n_dead,
                   "sealed segments must hold no tombstones after compact");
    }

    #[test]
    fn deletes_pull_in_the_next_best_exactly() {
        let (_, base, queries, pq) = setup(1200);
        let ix = StreamingIndex::new(8, None, scfg(250));
        ix.insert_batch(&pq, &base.data).unwrap();
        let cfg = SearchConfig { rerank_l: 50, k: 5, num_threads: 2,
                                 shard_rows: 128, ..Default::default() };
        let q = queries.row(0);
        let before = ix.search(&pq, q, &cfg);
        assert_eq!(ix.delete_batch(&before).unwrap(), 5);
        let after = ix.search(&pq, q, &cfg);
        for id in &before {
            assert!(!after.contains(id), "deleted id {id} served");
        }
        // equals the flat rebuild of the survivors
        let survivors: Vec<u32> = (0..1200u32)
            .filter(|id| !before.contains(id))
            .collect();
        let (flat, to_ext) = rebuild(&pq, &base, &survivors);
        let want = map_rows(
            vec![SearchEngine::new(&pq, &flat, cfg).search(q)], &to_ext);
        assert_eq!(after, want[0]);
    }

    #[test]
    fn seal_and_compact_preserve_results_and_bump_generation() {
        let (_, base, queries, pq) = setup(1500);
        let ix = StreamingIndex::new(8, None, scfg(200));
        let mut ids = Vec::new();
        for lo in (0..base.len()).step_by(220) {
            let hi = (lo + 220).min(base.len());
            ids.extend(ix.insert_batch(&pq, base.rows(lo, hi)).unwrap());
        }
        let victims: Vec<u32> = ids.iter().copied().step_by(7).collect();
        ix.delete_batch(&victims).unwrap();
        let qs = qrefs(&queries);
        let ks = vec![10usize; qs.len()];
        let cfg = SearchConfig { rerank_l: 40, k: 10, ..Default::default() };
        let before = batch(&ix, &pq, &Executor::Inline, &qs, &ks, &cfg);
        let st = ix.stats();
        assert!(st.sealed_segments > 1);
        assert!(ix.compact().unwrap());
        let st2 = ix.stats();
        assert!(st2.generation > st.generation, "epoch must advance");
        assert_eq!(st2.sealed_segments, 1, "merged into one segment");
        assert!(st2.total_rows < st.total_rows, "tombstones dropped");
        assert_eq!(st2.live_rows, st.live_rows, "no live row lost");
        let after = batch(&ix, &pq, &Executor::Inline, &qs, &ks, &cfg);
        assert_eq!(after, before, "compaction must not change results");
        // the merged segment is packed for the integer kernels
        assert!(ix.snapshot().sealed[0].codes().is_packed());
    }

    #[test]
    fn prop_interleaved_ops_match_flat_rebuild_at_all_precisions() {
        // THE acceptance property: after any interleaving of batch
        // inserts, deletes, and compactions, the segmented search equals
        // a flat rebuild of the surviving rows — exactly at f32, and
        // exactly at u16/u8 under a full rerank (integer selection may
        // swap candidates inside the quantization margin, so the int
        // precisions are pinned through the exact d1 stage like the
        // engine's own precision tests)
        let (_, base, queries, pq) = setup(2000);
        let qs = qrefs(&queries);
        prop::forall_ok(
            4321,
            6,
            |r: &mut SplitMix64| {
                (r.next_u64(), 1 + r.below(3), [120usize, 256, 4096][r.below(3)])
            },
            |&(seed, threads, segment_rows)| {
                let mut r = SplitMix64::new(seed);
                let ix = StreamingIndex::new(8, None, scfg(segment_rows));
                let mut cursor = 0usize; // next unused base row
                let mut live: Vec<u32> = Vec::new();
                for _ in 0..6 {
                    match r.below(4) {
                        0 | 1 => {
                            // insert a fresh chunk
                            let take = (50 + r.below(300))
                                .min(base.len() - cursor);
                            if take == 0 {
                                continue;
                            }
                            let got = ix
                                .insert_batch(
                                    &pq,
                                    base.rows(cursor, cursor + take))
                                .map_err(|e| format!("insert: {e:#}"))?;
                            live.extend(&got);
                            cursor += take;
                        }
                        2 => {
                            // delete a random slice of live ids
                            if live.is_empty() {
                                continue;
                            }
                            let k = 1 + r.below(live.len().min(120));
                            let mut dead = Vec::with_capacity(k);
                            for _ in 0..k {
                                dead.push(live.remove(r.below(live.len())));
                                if live.is_empty() {
                                    break;
                                }
                            }
                            ix.delete_batch(&dead)
                                .map_err(|e| format!("delete: {e:#}"))?;
                        }
                        _ => {
                            ix.compact()
                                .map_err(|e| format!("compact: {e:#}"))?;
                        }
                    }
                }
                live.sort_unstable();
                if ix.len() != live.len() {
                    return Err(format!("live {} != expected {}",
                                       ix.len(), live.len()));
                }
                if live.is_empty() {
                    return Ok(());
                }
                let (flat, to_ext) = rebuild(&pq, &base, &live);
                let exec = Executor::new(threads);
                let ks = vec![10usize; qs.len()];
                // exact f32 equality at a scan-selective rerank_l
                let f32_cfg = SearchConfig {
                    rerank_l: 50, k: 10, num_threads: threads,
                    shard_rows: 64, ..Default::default()
                };
                let want = map_rows(
                    SearchEngine::new(&pq, &flat, f32_cfg)
                        .search_batch_on(&exec, &qs),
                    &to_ext);
                let got = batch(&ix, &pq, &exec, &qs, &ks, &f32_cfg);
                if got != want {
                    return Err(format!(
                        "f32 diverged (threads={threads}, \
                         segment_rows={segment_rows})"));
                }
                // integer precisions under full rerank (U4 exercises the
                // wide-codebook fallback: PQ carries 32 codewords)
                for precision in [ScanPrecision::U16, ScanPrecision::U8,
                                  ScanPrecision::U4] {
                    let cfg = SearchConfig {
                        rerank_l: flat.n, scan_precision: precision,
                        ..f32_cfg
                    };
                    let want = map_rows(
                        SearchEngine::new(&pq, &flat, cfg)
                            .search_batch_on(&exec, &qs),
                        &to_ext);
                    let got = batch(&ix, &pq, &exec, &qs, &ks, &cfg);
                    if got != want {
                        return Err(format!("{precision:?} diverged"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prefilter_cfg_is_inert_on_streaming_segments() {
        // segment code matrices never build row sketches, so a prefilter
        // plan's per-task triple resolution must fall back on every task
        // — results identical to the plain scan even at an aggressive
        // margin, across segment boundaries and tombstones
        let (_, base, queries, pq) = setup(1200);
        let ix = StreamingIndex::new(8, None, scfg(250));
        let ids = ix.insert_batch(&pq, &base.data).unwrap();
        let victims: Vec<u32> = ids.iter().copied().step_by(7).collect();
        ix.delete_batch(&victims).unwrap();
        let qs = qrefs(&queries);
        let ks = vec![10usize; qs.len()];
        let base_cfg = SearchConfig { rerank_l: 50, k: 10,
                                      ..Default::default() };
        let want = batch(&ix, &pq, &Executor::new(2), &qs, &ks, &base_cfg);
        let cfg = SearchConfig { prefilter: true, prefilter_margin: 1,
                                 ..base_cfg };
        let got = batch(&ix, &pq, &Executor::new(2), &qs, &ks, &cfg);
        assert_eq!(got, want);
    }

    #[test]
    fn routed_non_residual_nprobe_all_matches_flat_rebuild() {
        let (train, base, queries, pq) = setup(1800);
        let coarse =
            CoarseQuantizer::train(&train.data, train.dim, 8, 3, 8);
        let routing = Routing { coarse: Arc::new(coarse), residual: false };
        let ix = StreamingIndex::new(8, Some(routing), scfg(300));
        let ids = ix.insert_batch(&pq, &base.data).unwrap();
        let victims: Vec<u32> = ids.iter().copied().step_by(9).collect();
        ix.delete_batch(&victims).unwrap();
        let survivors: Vec<u32> = ids
            .iter()
            .copied()
            .filter(|id| victims.binary_search(id).is_err())
            .collect();
        let (flat, to_ext) = rebuild(&pq, &base, &survivors);
        let qs = qrefs(&queries);
        let ks = vec![10usize; qs.len()];
        let cfg = SearchConfig { rerank_l: 60, k: 10, nprobe: 0,
                                 ..Default::default() };
        let want = map_rows(
            SearchEngine::new(&pq, &flat, cfg).search_batch(&qs), &to_ext);
        let got = batch(&ix, &pq, &Executor::new(2), &qs, &ks, &cfg);
        assert_eq!(got, want, "nprobe=all must equal the flat rebuild");
        // sub-linear probing stays in the same league (overlap, not
        // equality: fewer lists genuinely prune candidates)
        let cfg4 = SearchConfig { nprobe: 4, ..cfg };
        let got4 = batch(&ix, &pq, &Executor::Inline, &qs, &ks, &cfg4);
        let overlap: usize = got4
            .iter()
            .zip(&want)
            .map(|(g, w)| g.iter().filter(|id| w.contains(id)).count())
            .sum();
        assert!(overlap * 2 >= 10 * qs.len(),
                "nprobe=4 overlap collapsed: {overlap}/{}", 10 * qs.len());
    }

    #[test]
    fn routed_residual_matches_frozen_ivf_rebuild() {
        // streaming residual inserts vs IvfIndex::build over the same
        // surviving rows with the same coarse codebook: identical codes,
        // identical nprobe=all searches (ids mapped through the rebuild)
        let (train, base, queries, _) = setup(1400);
        let coarse =
            CoarseQuantizer::train(&train.data, train.dim, 6, 4, 8);
        // a residual deployment trains the fine quantizer on residuals
        let mut res_train = train.data.clone();
        for i in 0..train.len() {
            let c = coarse.centroid(coarse.assign(train.row(i)) as usize);
            for (v, cv) in res_train[i * train.dim..(i + 1) * train.dim]
                .iter_mut()
                .zip(c)
            {
                *v -= cv;
            }
        }
        let pq = Pq::train(&res_train, train.dim, 8, 32, 0, 8);
        let routing =
            Routing { coarse: Arc::new(coarse.clone()), residual: true };
        let ix = StreamingIndex::new(8, Some(routing), scfg(250));
        let ids = ix.insert_batch(&pq, &base.data).unwrap();
        let victims: Vec<u32> = ids.iter().copied().step_by(5).collect();
        ix.delete_batch(&victims).unwrap();
        ix.compact().unwrap();
        let survivors: Vec<u32> = ids
            .iter()
            .copied()
            .filter(|id| victims.binary_search(id).is_err())
            .collect();
        let mut kept = Vec::with_capacity(survivors.len() * base.dim);
        for &id in &survivors {
            kept.extend_from_slice(base.row(id as usize));
        }
        let kept = Dataset::new(base.dim, kept);
        let ivf = IvfIndex::build(&pq, &kept, coarse, true);
        let qs = qrefs(&queries);
        let ks = vec![10usize; qs.len()];
        let cfg = SearchConfig { rerank_l: 60, k: 10, nprobe: 0,
                                 ..Default::default() };
        let ivf_req = SearchRequest::from_config(&cfg, ks.clone());
        let want = map_rows(
            ivf.search_batch_on(&pq, &Executor::Inline, &qs, &ivf_req)
                .unwrap(),
            &survivors);
        let got = batch(&ix, &pq, &Executor::Inline, &qs, &ks, &cfg);
        assert_eq!(got, want);
    }

    #[test]
    fn snapshot_survives_concurrent_compaction() {
        // the epoch guard: a snapshot taken before compaction keeps
        // serving the old segment set unchanged while the index moves on
        let (_, base, queries, pq) = setup(900);
        let ix = StreamingIndex::new(8, None, scfg(150));
        let ids = ix.insert_batch(&pq, &base.data).unwrap();
        let old = ix.snapshot();
        let old_segs = old.sealed.len();
        ix.delete_batch(&ids[..300]).unwrap();
        ix.compact().unwrap();
        assert_eq!(old.sealed.len(), old_segs,
                   "in-flight snapshot must be frozen");
        assert_eq!(old.live_rows(), 900,
                   "old epoch still sees every pre-delete row");
        assert!(ix.snapshot().generation > old.generation);
        // and the live index serves the post-delete truth
        let got = ix.search(&pq, queries.row(0),
                            &SearchConfig { rerank_l: 40, k: 5,
                                            ..Default::default() });
        for id in got {
            assert!(ids[..300].binary_search(&id).is_err(),
                    "deleted id {id} served after compaction");
        }
    }

    #[test]
    fn filtered_streaming_search_composes_with_tombstones() {
        // the filtered-search contract under mutation (rust/DESIGN.md
        // §13): tags ride WAL inserts and survive seal + compaction, the
        // predicate composes with tombstones, and filtered search equals
        // the flat rebuild of the ADMITTED survivors — exactly at f32,
        // and at the integer precisions under a full rerank (same
        // pinning as the unfiltered interleaved property)
        use crate::index::Filter;
        let (_, base, queries, pq) = setup(1500);
        let ix = StreamingIndex::new(8, None, scfg(250));
        let mut ids = Vec::new();
        for lo in (0..base.len()).step_by(260) {
            let hi = (lo + 260).min(base.len());
            // fresh inserts assign ids in dataset order, so id i carries
            // tag i % 2
            let tags: Vec<u64> = (lo..hi).map(|i| (i % 2) as u64).collect();
            ids.extend(
                ix.insert_batch_tagged(&pq, base.rows(lo, hi), Some(&tags))
                    .unwrap());
        }
        let victims: Vec<u32> = ids.iter().copied().step_by(7).collect();
        ix.delete_batch(&victims).unwrap();
        assert!(ix.compact().unwrap(), "tags must survive the merge");
        let admitted: Vec<u32> = ids
            .iter()
            .copied()
            .filter(|id| id % 2 == 1
                    && victims.binary_search(id).is_err())
            .collect();
        let (flat, to_ext) = rebuild(&pq, &base, &admitted);
        let qs = qrefs(&queries);
        let ks = vec![10usize; qs.len()];
        let f32_cfg = SearchConfig { rerank_l: 50, k: 10,
                                     filter: Some(Filter::TagEq(1)),
                                     ..Default::default() };
        let want = map_rows(
            SearchEngine::new(&pq, &flat,
                              SearchConfig { filter: None, ..f32_cfg })
                .search_batch(&qs),
            &to_ext);
        let got = batch(&ix, &pq, &Executor::new(2), &qs, &ks, &f32_cfg);
        assert_eq!(got, want, "filtered f32 diverged from admitted rebuild");
        for precision in [ScanPrecision::U16, ScanPrecision::U8,
                          ScanPrecision::U4] {
            let cfg = SearchConfig { rerank_l: flat.n,
                                     scan_precision: precision, ..f32_cfg };
            let want = map_rows(
                SearchEngine::new(&pq, &flat,
                                  SearchConfig { filter: None, ..cfg })
                    .search_batch(&qs),
                &to_ext);
            let got = batch(&ix, &pq, &Executor::Inline, &qs, &ks, &cfg);
            assert_eq!(got, want, "filtered {precision:?} diverged");
        }
        // mutation after the filtered search keeps composing: delete the
        // current filtered winners and they vanish from the next search
        let q0 = queries.row(0);
        let one_cfg = SearchConfig { rerank_l: 50, k: 5,
                                     filter: Some(Filter::TagEq(1)),
                                     ..Default::default() };
        let winners = ix.search(&pq, q0, &one_cfg);
        assert!(!winners.is_empty());
        ix.delete_batch(&winners).unwrap();
        let next = ix.search(&pq, q0, &one_cfg);
        for id in &winners {
            assert!(!next.contains(id),
                    "tombstoned id {id} served through the filter");
        }
        // selectivity 0: no admitted rows means empty results, no panic
        let none_cfg = SearchConfig { rerank_l: 50, k: 5,
                                      filter: Some(Filter::TagEq(9)),
                                      ..Default::default() };
        let none = batch(&ix, &pq, &Executor::Inline, &qs, &ks, &none_cfg);
        assert!(none.iter().all(Vec::is_empty), "tag 9 admits nothing");
        // untagged inserts carry the default tag 0: TagEq(0) over a
        // plain insert_batch index equals the unfiltered search
        let ix0 = StreamingIndex::new(8, None, scfg(300));
        ix0.insert_batch(&pq, base.rows(0, 600)).unwrap();
        let plain = SearchConfig { rerank_l: 40, k: 10,
                                   ..Default::default() };
        let zero = SearchConfig { filter: Some(Filter::TagEq(0)), ..plain };
        assert_eq!(
            batch(&ix0, &pq, &Executor::Inline, &qs, &ks, &zero),
            batch(&ix0, &pq, &Executor::Inline, &qs, &ks, &plain),
            "default tag 0 must admit every untagged row");
    }
}
