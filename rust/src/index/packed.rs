//! Blocked code layout for the integer fast-scan kernels.
//!
//! The flat `CompressedIndex` stores codes row-major: scanning walks one
//! row's `stride` bytes, gathers `stride` table entries, moves on — one
//! dependent gather chain per row.  [`PackedIndex`] interleaves blocks of
//! [`BLOCK`] = 32 rows *position-major*:
//!
//! ```text
//! block b = rows [b·32, b·32 + 32)
//! data[(b·stride + j)·32 + r] = code byte of row b·32 + r at position j
//!
//!        ┌ lane r → 0 … 31 ┐
//! j = 0  │ c₀ c₁ c₂ … c₃₁  │   ← 32 sequential bytes, one table row
//! j = 1  │ c₀ c₁ c₂ … c₃₁  │
//!  ⋮     │        ⋮        │
//! ```
//!
//! so the inner scan loop fixes position `j`, reads 32 *sequential* code
//! bytes, and accumulates into 32 independent integer lanes — every load
//! on the (cache-missing) code stream is sequential and every table
//! access pattern is shared by the whole block.  The tail block pads
//! missing lanes with byte 0 (a valid codeword id; padded lanes are
//! computed but never emitted).  See rust/DESIGN.md §6.
//!
//! When every code byte is below 16 the pack additionally builds a
//! half-width *nibble mirror* for the 4-bit fast-scan kernels
//! (DESIGN.md §9): each 32-lane position row is squeezed into 16 bytes,
//! lane `i` in the low nibble and lane `i + 16` in the high nibble, so
//! one 128-bit load feeds a PSHUFB/TBL table gather directly.

use super::CompressedIndex;

/// Rows interleaved per block.  32 lanes × u32 accumulators fit
/// comfortably in registers/L1 and divide every power-of-two shard size.
pub const BLOCK: usize = 32;

/// Position-major blocked mirror of a code matrix (same `n × stride`
/// logical content as the flat layout it was packed from).
#[derive(Clone, Debug)]
pub struct PackedIndex {
    pub n: usize,
    pub stride: usize,
    /// `ceil(n / 32) · stride · 32` bytes, laid out as documented above.
    pub data: Vec<u8>,
    /// Half-width mirror of `data` for 4-bit codes — present iff every
    /// code byte is `< 16`.  `nibbles[(b·stride + j)·16 + i]` packs lane
    /// `i` (low nibble) with lane `i + 16` (high nibble) of position `j`
    /// in block `b`.
    pub nibbles: Option<Vec<u8>>,
}

impl PackedIndex {
    /// Pack a flat row-major code matrix.
    pub fn pack(n: usize, stride: usize, codes: &[u8]) -> PackedIndex {
        assert_eq!(codes.len(), n * stride, "codes must be n × stride");
        assert!(stride > 0, "stride must be positive");
        let nb = n.div_ceil(BLOCK);
        let mut data = vec![0u8; nb * stride * BLOCK];
        for row in 0..n {
            let (b, r) = (row / BLOCK, row % BLOCK);
            let src = &codes[row * stride..(row + 1) * stride];
            let base = b * stride * BLOCK;
            for (j, &c) in src.iter().enumerate() {
                data[base + j * BLOCK + r] = c;
            }
        }
        let nibbles = if codes.iter().all(|&c| c < 16) {
            let mut nib = vec![0u8; nb * stride * (BLOCK / 2)];
            for (pos, half) in nib.iter_mut().enumerate() {
                let row = pos / (BLOCK / 2) * BLOCK;
                let lane = pos % (BLOCK / 2);
                *half = data[row + lane] | (data[row + lane + BLOCK / 2] << 4);
            }
            Some(nib)
        } else {
            None
        };
        PackedIndex { n, stride, data, nibbles }
    }

    /// Pack an existing flat index.
    pub fn from_index(index: &CompressedIndex) -> PackedIndex {
        Self::pack(index.n, index.stride, &index.codes)
    }

    /// Number of 32-row blocks (the tail block may be partial).
    #[inline]
    pub fn num_blocks(&self) -> usize {
        self.n.div_ceil(BLOCK)
    }

    /// The `stride × 32` byte slab of block `b`.
    #[inline]
    pub fn block(&self, b: usize) -> &[u8] {
        let span = self.stride * BLOCK;
        &self.data[b * span..(b + 1) * span]
    }

    /// The `stride × 16` nibble slab of block `b`, when the mirror
    /// exists (all codes `< 16`).
    #[inline]
    pub fn nibble_block(&self, b: usize) -> Option<&[u8]> {
        let span = self.stride * (BLOCK / 2);
        self.nibbles.as_ref().map(|nib| &nib[b * span..(b + 1) * span])
    }

    /// Read one logical row back out of the blocked layout (test and
    /// verification path; the scan kernels never call this).
    pub fn unpack_row(&self, row: usize, out: &mut [u8]) {
        assert!(row < self.n);
        assert_eq!(out.len(), self.stride);
        let blk = self.block(row / BLOCK);
        let r = row % BLOCK;
        for (j, o) in out.iter_mut().enumerate() {
            *o = blk[j * BLOCK + r];
        }
    }

    /// Bytes of packed storage (layout overhead is only tail padding).
    pub fn storage_bytes(&self) -> usize {
        self.data.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    fn mk_codes(n: usize, stride: usize, seed: u64) -> Vec<u8> {
        let mut rng = SplitMix64::new(seed);
        (0..n * stride).map(|_| rng.below(256) as u8).collect()
    }

    #[test]
    fn pack_roundtrips_every_row_for_ragged_sizes() {
        // exact multiples, ragged tails, and n < BLOCK
        for n in [0usize, 1, 5, 31, 32, 33, 64, 100, 257] {
            for stride in [1usize, 3, 8, 16] {
                let codes = mk_codes(n, stride, (n * 31 + stride) as u64);
                let p = PackedIndex::pack(n, stride, &codes);
                assert_eq!(p.num_blocks(), n.div_ceil(BLOCK));
                assert_eq!(p.data.len(), p.num_blocks() * stride * BLOCK);
                let mut row = vec![0u8; stride];
                for i in 0..n {
                    p.unpack_row(i, &mut row);
                    assert_eq!(row, codes[i * stride..(i + 1) * stride],
                               "n={n} stride={stride} row {i}");
                }
            }
        }
    }

    #[test]
    fn tail_lanes_are_zero_padded() {
        let codes = mk_codes(33, 4, 7);
        let p = PackedIndex::pack(33, 4, &codes);
        let tail = p.block(1);
        for j in 0..4 {
            for r in 1..BLOCK {
                assert_eq!(tail[j * BLOCK + r], 0,
                           "pad lane j={j} r={r} must be zero");
            }
        }
    }

    #[test]
    fn nibble_mirror_matches_byte_layout_for_small_codes() {
        let mut rng = SplitMix64::new(21);
        for n in [1usize, 31, 32, 33, 100] {
            for stride in [1usize, 3, 8] {
                let codes: Vec<u8> =
                    (0..n * stride).map(|_| rng.below(16) as u8).collect();
                let p = PackedIndex::pack(n, stride, &codes);
                for b in 0..p.num_blocks() {
                    let bytes = p.block(b);
                    let nib = p.nibble_block(b)
                               .expect("codes < 16 must build the mirror");
                    for j in 0..stride {
                        for i in 0..BLOCK / 2 {
                            let half = nib[j * (BLOCK / 2) + i];
                            assert_eq!(half & 0x0F, bytes[j * BLOCK + i]);
                            assert_eq!(half >> 4,
                                       bytes[j * BLOCK + i + BLOCK / 2]);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn nibble_mirror_absent_for_wide_codes() {
        let p = PackedIndex::pack(2, 2, &[1, 2, 16, 3]);
        assert!(p.nibbles.is_none());
        assert!(p.nibble_block(0).is_none());
    }

    #[test]
    fn block_is_position_major() {
        // hand-built 2×3 matrix: rows [1,2,3] and [4,5,6]
        let p = PackedIndex::pack(2, 3, &[1, 2, 3, 4, 5, 6]);
        let b = p.block(0);
        assert_eq!(&b[0..2], &[1, 4], "position 0 lanes");
        assert_eq!(&b[BLOCK..BLOCK + 2], &[2, 5], "position 1 lanes");
        assert_eq!(&b[2 * BLOCK..2 * BLOCK + 2], &[3, 6], "position 2 lanes");
    }
}
